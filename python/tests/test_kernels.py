"""L1 kernel correctness: Pallas vs the pure-jnp oracle.

Hypothesis sweeps shapes, dtypes and block parameters; every case
asserts allclose against ref.py — the core correctness signal gating
`make artifacts`.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

jax.config.update("jax_enable_x64", True)

from compile.kernels.gemm import gemm_accum, gemm_blocked, vmem_footprint_bytes
from compile.kernels.micro import micro_kernel
from compile.kernels.ref import gemm_accum_ref, gemm_ref, micro_kernel_ref

RNG = np.random.default_rng(0xA3)


def rand(shape, dtype=np.float64):
    return jnp.asarray(RNG.uniform(-1, 1, size=shape).astype(dtype))


def tol(dtype, k):
    eps = 1e-12 if dtype == np.float64 else 1e-5
    return eps * max(k, 1) * 8


# ---------------------------------------------------------------- micro

class TestMicroKernel:
    def test_paper_4x4_blocking(self):
        # The paper's mr = nr = 4 register block at both optimal kc's.
        for kc in (352, 952):
            a = rand((4, kc))
            b = rand((kc, 4))
            np.testing.assert_allclose(
                micro_kernel(a, b), gemm_ref(a, b), atol=tol(np.float64, kc))

    def test_micro_matches_rank1_reference(self):
        a = rand((4, 64))
        b = rand((64, 4))
        np.testing.assert_allclose(
            micro_kernel(a, b), micro_kernel_ref(a, b), atol=1e-12)

    @settings(max_examples=25, deadline=None)
    @given(mr=st.integers(1, 8), nr=st.integers(1, 8), kc=st.integers(1, 128))
    def test_micro_shape_sweep(self, mr, nr, kc):
        a = rand((mr, kc))
        b = rand((kc, nr))
        got = micro_kernel(a, b)
        assert got.shape == (mr, nr)
        np.testing.assert_allclose(got, gemm_ref(a, b), atol=tol(np.float64, kc))

    def test_micro_f32(self):
        a = rand((4, 96), np.float32)
        b = rand((96, 4), np.float32)
        np.testing.assert_allclose(
            micro_kernel(a, b), gemm_ref(a, b), atol=tol(np.float32, 96))


# -------------------------------------------------------------- blocked

class TestGemmBlocked:
    def test_divisible_shapes(self):
        a = rand((256, 256))
        b = rand((256, 256))
        np.testing.assert_allclose(
            gemm_blocked(a, b, bm=64, bn=64, bk=64), gemm_ref(a, b),
            atol=tol(np.float64, 256))

    def test_paper_variant_blockings(self):
        from compile.model import VARIANTS
        a = rand((200, 300))
        b = rand((300, 150))
        for name, blocks in VARIANTS.items():
            np.testing.assert_allclose(
                gemm_blocked(a, b, **blocks), gemm_ref(a, b),
                atol=tol(np.float64, 300), err_msg=name)

    @settings(max_examples=30, deadline=None)
    @given(
        m=st.integers(1, 160),
        n=st.integers(1, 160),
        k=st.integers(1, 160),
        bm=st.sampled_from([16, 32, 128]),
        bn=st.sampled_from([16, 64, 128]),
        bk=st.sampled_from([16, 32, 256]),
    )
    def test_shape_and_block_sweep(self, m, n, k, bm, bn, bk):
        a = rand((m, k))
        b = rand((k, n))
        got = gemm_blocked(a, b, bm=bm, bn=bn, bk=bk)
        assert got.shape == (m, n)
        np.testing.assert_allclose(got, gemm_ref(a, b), atol=tol(np.float64, k))

    @settings(max_examples=10, deadline=None)
    @given(
        m=st.integers(1, 96), n=st.integers(1, 96), k=st.integers(1, 96),
        dtype=st.sampled_from([np.float32, np.float64]),
    )
    def test_dtype_sweep(self, m, n, k, dtype):
        a = rand((m, k), dtype)
        b = rand((k, n), dtype)
        got = gemm_blocked(a, b, bm=32, bn=32, bk=32)
        assert got.dtype == a.dtype
        np.testing.assert_allclose(got, gemm_ref(a, b), atol=tol(dtype, k))

    def test_accumulate_semantics(self):
        a = rand((48, 32))
        b = rand((32, 40))
        c = rand((48, 40))
        np.testing.assert_allclose(
            gemm_accum(a, b, c, bm=16, bn=16, bk=16),
            gemm_accum_ref(a, b, c), atol=tol(np.float64, 32))

    def test_block_larger_than_problem(self):
        a = rand((5, 7))
        b = rand((7, 3))
        np.testing.assert_allclose(
            gemm_blocked(a, b, bm=128, bn=128, bk=256), gemm_ref(a, b),
            atol=1e-12)

    def test_mismatched_inner_dims_rejected(self):
        with pytest.raises(AssertionError):
            gemm_blocked(rand((4, 5)), rand((6, 4)))

    def test_vmem_footprint_math(self):
        # big variant, f64: 2·(128·512 + 512·128)·8 + 128·128·8 ≈ 2.1 MiB.
        got = vmem_footprint_bytes(128, 128, 512, 8)
        assert got == 2 * (128 * 512 + 512 * 128) * 8 + 128 * 128 * 8
        assert got < 16 * 2**20, "must fit the TPU VMEM budget"
