"""L2 model + AOT pipeline tests: variant semantics, VMEM budgets,
HLO-text emission and manifest integrity."""

import jax
import jax.numpy as jnp
import numpy as np

jax.config.update("jax_enable_x64", True)

from compile import aot
from compile.kernels.ref import gemm_ref
from compile.model import (GemmSpec, VARIANTS, default_artifact_specs,
                           make_gemm, make_gemm_accum, validate_vmem_budget)

RNG = np.random.default_rng(7)


def rand(shape):
    return jnp.asarray(RNG.uniform(-1, 1, size=shape))


class TestModel:
    def test_both_variants_compute_gemm(self):
        spec_b = GemmSpec("t_big", 96, 80, 112, "big")
        spec_l = GemmSpec("t_little", 96, 80, 112, "little")
        a, b = rand((96, 112)), rand((112, 80))
        want = gemm_ref(a, b)
        for spec in (spec_b, spec_l):
            (got,) = make_gemm(spec)(a, b)
            np.testing.assert_allclose(got, want, atol=1e-9, err_msg=spec.variant)

    def test_accum_variant(self):
        spec = GemmSpec("t", 32, 32, 32, "little")
        a, b, c = rand((32, 32)), rand((32, 32)), rand((32, 32))
        (got,) = make_gemm_accum(spec)(a, b, c)
        np.testing.assert_allclose(got, c + gemm_ref(a, b), atol=1e-10)

    def test_variants_are_asymmetric(self):
        # The big variant's VMEM working set must exceed the little one's,
        # mirroring the paper's A15-vs-A7 cache-parameter asymmetry.
        big = GemmSpec("b", 512, 512, 512, "big").vmem_bytes()
        little = GemmSpec("l", 512, 512, 512, "little").vmem_bytes()
        assert big > 2 * little

    def test_vmem_budget_all_variants(self):
        for spec in default_artifact_specs():
            assert validate_vmem_budget(spec), spec

    def test_default_specs_cover_both_variants_and_shapes(self):
        specs = default_artifact_specs()
        variants = {s.variant for s in specs}
        assert variants == set(VARIANTS)
        assert any(s.m != s.n or s.n != s.k for s in specs), "needs a rectangular case"
        names = [s.name for s in specs]
        assert len(names) == len(set(names)), "artifact names must be unique"


class TestAot:
    def test_hlo_text_emission(self):
        spec = GemmSpec("t_small", 16, 16, 16, "little")
        text = aot.lower_spec(spec)
        assert "HloModule" in text
        assert "f64" in text
        # The blocked kernel lowers to a loop/fusion structure containing
        # a dot — make sure real compute is present, not a stub.
        assert "dot(" in text or "dot " in text

    def test_build_writes_manifest_and_artifacts(self, tmp_path):
        specs = [
            GemmSpec("m_one", 16, 16, 16, "big"),
            GemmSpec("m_two", 8, 24, 16, "little"),
        ]
        manifest = aot.build(tmp_path, specs, verbose=False)
        lines = manifest.read_text().strip().splitlines()
        assert len(lines) == 2
        name, m, n, k, dtype, variant, fname = lines[0].split()
        assert (name, m, n, k, dtype, variant) == ("m_one", "16", "16", "16", "f64", "big")
        assert (tmp_path / fname).exists()
        assert "HloModule" in (tmp_path / fname).read_text()

    def test_f32_spec_lowers(self):
        text = aot.lower_spec(GemmSpec("t_f32", 8, 8, 8, "little", dtype="f32"))
        assert "f32" in text
