"""Make `compile.*` importable when pytest runs from the repo root
(the Makefile runs pytest from python/; CI runs it from /root/repo)."""
import pathlib
import sys

sys.path.insert(0, str(pathlib.Path(__file__).resolve().parents[1]))
