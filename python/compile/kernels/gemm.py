"""Layer-1 Pallas blocked GEMM.

The five-loop cache-blocked structure of BLIS GEMM (paper Fig. 1/2),
re-expressed for the TPU memory model (DESIGN.md §4 Hardware-Adaptation):

* the paper's (mc, kc, nc) cache parameters become the `BlockSpec` block
  shapes (bm, bk, bn) — the declaration of what resides in VMEM
  (the TPU's explicitly-managed analogue of the L1/L2 the paper tunes);
* the grid (n/bn, m/bm, k/bk) walks the same jc → ic → pc traversal, and
  the innermost grid axis accumulates into `o_ref` exactly as Loop 2
  accumulates into C — sequential, race-free (the paper's reason never
  to parallelize Loop 2);
* the per-block `jnp.dot` is the MXU-tile "micro-kernel".

`interpret=True` everywhere: real-TPU lowering emits Mosaic custom-calls
that the CPU PJRT plugin cannot execute (see /opt/xla-example/README.md).
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

jax.config.update("jax_enable_x64", True)


def _gemm_body(a_ref, b_ref, o_ref):
    # Zero-initialize on the first k step, then accumulate: the Loop-2
    # discipline (C updated by one block-panel product per pc step).
    @pl.when(pl.program_id(2) == 0)
    def _():
        o_ref[...] = jnp.zeros_like(o_ref)

    o_ref[...] += jnp.dot(a_ref[...], b_ref[...],
                          preferred_element_type=o_ref.dtype)


def _pad_to(x: jax.Array, rows: int, cols: int) -> jax.Array:
    pr, pc = rows - x.shape[0], cols - x.shape[1]
    if pr == 0 and pc == 0:
        return x
    return jnp.pad(x, ((0, pr), (0, pc)))


@functools.partial(jax.jit, static_argnames=("bm", "bn", "bk"))
def gemm_blocked(a: jax.Array, b: jax.Array, *, bm: int = 128, bn: int = 128,
                 bk: int = 256) -> jax.Array:
    """C = A·B with explicit (bm, bn, bk) VMEM blocking.

    Arbitrary shapes are zero-padded up to block multiples (the same
    job the paper's edge micro-kernels do) and the result sliced back.
    """
    m, k = a.shape
    k2, n = b.shape
    assert k == k2, f"inner dims differ: {k} vs {k2}"
    assert a.dtype == b.dtype

    bm_, bn_, bk_ = min(bm, max(m, 1)), min(bn, max(n, 1)), min(bk, max(k, 1))
    mp = -(-m // bm_) * bm_
    np_ = -(-n // bn_) * bn_
    kp = -(-k // bk_) * bk_
    a_p = _pad_to(a, mp, kp)
    b_p = _pad_to(b, kp, np_)

    grid = (np_ // bn_, mp // bm_, kp // bk_)
    out = pl.pallas_call(
        _gemm_body,
        grid=grid,
        in_specs=[
            pl.BlockSpec((bm_, bk_), lambda jn, im, lk: (im, lk)),
            pl.BlockSpec((bk_, bn_), lambda jn, im, lk: (lk, jn)),
        ],
        out_specs=pl.BlockSpec((bm_, bn_), lambda jn, im, lk: (im, jn)),
        out_shape=jax.ShapeDtypeStruct((mp, np_), a.dtype),
        interpret=True,
    )(a_p, b_p)
    return out[:m, :n]


def gemm_accum(a: jax.Array, b: jax.Array, c: jax.Array, **blocks) -> jax.Array:
    """The paper's BLAS semantics: C += A·B."""
    return c + gemm_blocked(a, b, **blocks)


def vmem_footprint_bytes(bm: int, bn: int, bk: int, itemsize: int = 8) -> int:
    """Estimated VMEM residency of one grid step (A block + B block +
    O block), the quantity DESIGN.md §7 budgets against the 16 MiB VMEM.
    Double-buffering doubles the input blocks."""
    a = bm * bk * itemsize
    b = bk * bn * itemsize
    o = bm * bn * itemsize
    return 2 * (a + b) + o
