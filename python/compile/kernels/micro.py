"""Layer-1 Pallas micro-kernel.

The paper's innermost unit (Fig. 1 "Micro-kernel"): an (mr×kc) packed A
slice times a (kc×nr) packed B micro-panel producing an mr×nr register
block, implemented on the CPU interpret path as a single VMEM-resident
contraction.

Hardware adaptation (DESIGN.md §4): the ARM NEON 4×4 rank-1-update loop
does not port mechanically to TPU. The insight that *does* port is that
the micro-kernel operands are sized to the innermost memory level; here
both panels are declared VMEM-resident via `pallas_call` with no grid,
and the rank-1 loop collapses into one `jnp.dot` that the TPU backend
would map onto the MXU systolic array (`preferred_element_type` pins the
accumulator width). `interpret=True` everywhere: the CPU PJRT plugin
cannot execute Mosaic custom-calls.
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

jax.config.update("jax_enable_x64", True)


def _micro_body(a_ref, b_ref, o_ref):
    # One MXU-shaped contraction over the whole kc depth: the TPU
    # analogue of the paper's kc-long rank-1 update loop.
    o_ref[...] = jnp.dot(a_ref[...], b_ref[...],
                         preferred_element_type=o_ref.dtype)


@functools.partial(jax.jit, static_argnames=())
def micro_kernel(a_panel: jax.Array, b_panel: jax.Array) -> jax.Array:
    """(mr, kc) @ (kc, nr) -> (mr, nr), single-invocation Pallas call."""
    mr, kc = a_panel.shape
    kc2, nr = b_panel.shape
    assert kc == kc2, f"panel depth mismatch: {kc} vs {kc2}"
    return pl.pallas_call(
        _micro_body,
        out_shape=jax.ShapeDtypeStruct((mr, nr), a_panel.dtype),
        interpret=True,
    )(a_panel, b_panel)
