"""Pure-jnp correctness oracles for the Pallas kernels.

These are the build-time ground truth: every Pallas kernel in this
package is validated against them by pytest/hypothesis before the AOT
artifacts are emitted (the CORE correctness signal of the L1 layer).
"""

import jax
import jax.numpy as jnp

jax.config.update("jax_enable_x64", True)


def gemm_ref(a: jax.Array, b: jax.Array) -> jax.Array:
    """C = A·B with accumulation in the operand dtype (paper: f64)."""
    return jnp.dot(a, b, preferred_element_type=a.dtype)


def gemm_accum_ref(a: jax.Array, b: jax.Array, c: jax.Array) -> jax.Array:
    """The BLAS semantics the paper's GEMM implements: C += A·B."""
    return c + gemm_ref(a, b)


def micro_kernel_ref(a_panel: jax.Array, b_panel: jax.Array) -> jax.Array:
    """Reference for the (mr×kc)·(kc×nr) micro-kernel, computed the way
    the paper's kernel does: as a sum of kc rank-1 outer products."""
    mr, kc = a_panel.shape
    kc2, nr = b_panel.shape
    assert kc == kc2

    def body(l, acc):
        return acc + jnp.outer(a_panel[:, l], b_panel[l, :])

    init = jnp.zeros((mr, nr), dtype=a_panel.dtype)
    return jax.lax.fori_loop(0, kc, body, init)
