"""AOT lowering: JAX model → HLO *text* artifacts + manifest.

The interchange format is HLO text, NOT a serialized HloModuleProto:
jax ≥ 0.5 emits protos with 64-bit instruction ids which the xla crate's
xla_extension 0.5.1 rejects (`proto.id() <= INT_MAX`); the text parser
reassigns ids and round-trips cleanly. See /opt/xla-example/README.md.

Run once at build time (`make artifacts`); the Rust binary is then
self-contained. Python never runs on the request path.

Manifest format (one artifact per line):
    name m n k dtype variant file
"""

import argparse
import sys
from pathlib import Path

import jax

jax.config.update("jax_enable_x64", True)

from jax._src.lib import xla_client as xc  # noqa: E402

from compile.model import GemmSpec, default_artifact_specs, make_gemm  # noqa: E402

MANIFEST_NAME = "manifest.txt"


def to_hlo_text(lowered) -> str:
    """StableHLO → XlaComputation → HLO text (see module docstring)."""
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def lower_spec(spec: GemmSpec) -> str:
    dtype = {"f64": jax.numpy.float64, "f32": jax.numpy.float32}[spec.dtype]
    a = jax.ShapeDtypeStruct((spec.m, spec.k), dtype)
    b = jax.ShapeDtypeStruct((spec.k, spec.n), dtype)
    lowered = jax.jit(make_gemm(spec)).lower(a, b)
    return to_hlo_text(lowered)


def build(out_dir: Path, specs=None, verbose: bool = True) -> Path:
    specs = specs if specs is not None else default_artifact_specs()
    out_dir.mkdir(parents=True, exist_ok=True)
    lines = []
    for spec in specs:
        text = lower_spec(spec)
        fname = f"{spec.name}.hlo.txt"
        (out_dir / fname).write_text(text)
        lines.append(
            f"{spec.name} {spec.m} {spec.n} {spec.k} {spec.dtype} {spec.variant} {fname}"
        )
        if verbose:
            print(f"  lowered {spec.name}: {len(text)} chars", file=sys.stderr)
    manifest = out_dir / MANIFEST_NAME
    manifest.write_text("\n".join(lines) + "\n")
    if verbose:
        print(f"wrote {len(specs)} artifacts + {manifest}", file=sys.stderr)
    return manifest


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--out", default="../artifacts", help="artifact directory")
    ap.add_argument("--quick", action="store_true",
                    help="only the smallest artifact (CI smoke)")
    args = ap.parse_args()
    specs = None
    if args.quick:
        specs = [GemmSpec("gemm_big_64", 64, 64, 64, "big")]
    build(Path(args.out), specs)


if __name__ == "__main__":
    main()
