"""Layer-2 JAX model: the GEMM compute graph the Rust runtime executes.

One jitted function per *core-type variant*: the paper's duplicated
control trees (§5.3) become distinct AOT artifacts with different
blocking, chosen by the Rust coordinator at dispatch time. Block shapes
derive from the paper's cache parameters, re-quantized for the TPU
memory model (DESIGN.md §4): the "big" variant uses large VMEM blocks
(the 2 MiB-L2 analogue), the "little" variant small ones (512 KiB L2).
"""

from dataclasses import dataclass

import jax

from compile.kernels.gemm import gemm_accum, gemm_blocked, vmem_footprint_bytes

jax.config.update("jax_enable_x64", True)

#: TPU-adapted blocking per core-type variant. MXU-tile-aligned (mult.
#: of 128 where the shape allows) and VMEM-bounded; the ratio between
#: the two mirrors the paper's A15 (152, 952) vs A7 (80, 352) asymmetry.
VARIANTS = {
    "big": dict(bm=128, bn=128, bk=512),
    "little": dict(bm=64, bn=128, bk=128),
}


@dataclass(frozen=True)
class GemmSpec:
    """One artifact's static description (also the manifest schema)."""

    name: str
    m: int
    n: int
    k: int
    variant: str
    dtype: str = "f64"

    def blocks(self):
        return VARIANTS[self.variant]

    def vmem_bytes(self) -> int:
        b = self.blocks()
        itemsize = 8 if self.dtype == "f64" else 4
        return vmem_footprint_bytes(b["bm"], b["bn"], b["bk"], itemsize)


def make_gemm(spec: GemmSpec):
    """The jitted C = A·B for one artifact (pure function of (A, B))."""
    blocks = spec.blocks()

    def fn(a, b):
        return (gemm_blocked(a, b, **blocks),)

    return fn


def make_gemm_accum(spec: GemmSpec):
    """C += A·B variant taking (A, B, C)."""
    blocks = spec.blocks()

    def fn(a, b, c):
        return (gemm_accum(a, b, c, **blocks),)

    return fn


def default_artifact_specs():
    """The artifact set `make artifacts` builds: square problems at the
    runtime service's supported shapes, for both core-type variants,
    plus one rectangular sanity shape."""
    specs = []
    for r in (64, 128, 256, 512):
        for variant in ("big", "little"):
            specs.append(GemmSpec(f"gemm_{variant}_{r}", r, r, r, variant))
    specs.append(GemmSpec("gemm_big_96x160x224", 96, 160, 224, "big"))
    return specs


def validate_vmem_budget(spec: GemmSpec, budget_bytes: int = 16 * 2**20) -> bool:
    """DESIGN.md §7: every variant's working set must clear the 16 MiB
    VMEM budget (with double buffering)."""
    return spec.vmem_bytes() <= budget_bytes
