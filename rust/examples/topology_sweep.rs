//! Topology sweep: the same four schedulers — SSS, SAS, CA-SAS and
//! CA-DAS — across three different cluster topologies, with zero
//! per-topology scheduler code:
//!
//! * the paper's Samsung Exynos 5422 (two clusters: 4 big + 4 LITTLE),
//! * a tri-cluster DynamIQ-style SoC (2 big + 3 mid + 4 LITTLE),
//! * a symmetric 4-core SMP (the degenerate single-cluster case).
//!
//! SAS/CA-SAS weight vectors are derived from the performance model
//! (`PerfModel::sas_weights` / `ca_sas_weights`) — on the Exynos these
//! land at the paper's ratio ≈ 5; on the tri-cluster they become a
//! 3-way vector; on the SMP they collapse to `[1]`.
//!
//! The Exynos block double-checks the pre-refactor figure anchors
//! (Fig. 7/9/12), so this example is also the regression gate for the
//! N-cluster generalization.
//!
//! Run: `cargo run --release --example topology_sweep [-- --size 4096]`

use amp_gemm::blis::gemm::GemmShape;
use amp_gemm::figures::ideal_gflops;
use amp_gemm::model::PerfModel;
use amp_gemm::sched::ScheduleSpec;
use amp_gemm::sim::simulate;
use amp_gemm::soc::{SocSpec, BIG};
use amp_gemm::util::cli::Args;
use amp_gemm::util::table::Table;

fn main() {
    let args = Args::from_env().expect("args");
    let r = args.usize_or("size", 4096).expect("--size");

    for soc in [
        SocSpec::exynos5422(),
        SocSpec::dynamiq_3c(),
        SocSpec::symmetric(4),
    ] {
        let model = PerfModel::new(soc.clone());
        let ideal = ideal_gflops(&model, r);

        let specs = vec![
            ScheduleSpec::sss(),
            ScheduleSpec::sas_weighted(model.sas_weights()),
            ScheduleSpec::ca_sas_weighted(model.ca_sas_weights()),
            ScheduleSpec::ca_das(),
        ];

        let mut table = Table::new(
            &format!("{} — r = {r} (ideal {ideal:.2} GFLOPS)", soc.name),
            &["schedule", "GFLOPS", "% of ideal", "GFLOPS/W", "grabs"],
        );
        let mut by_name = Vec::new();
        for spec in &specs {
            let st = simulate(&model, spec, GemmShape::square(r));
            table.push_row(vec![
                st.label.clone(),
                format!("{:.2}", st.gflops),
                format!("{:.0}%", st.gflops / ideal * 100.0),
                format!("{:.3}", st.gflops_per_watt),
                st.grabs.to_string(),
            ]);
            by_name.push(st);
        }
        println!("{}", table.to_markdown());

        // Cross-topology invariants of the paper's story.
        let (sss, cadas) = (&by_name[0], &by_name[3]);
        assert!(
            cadas.gflops <= ideal * 1.001,
            "CA-DAS cannot beat the ideal aggregate"
        );
        if soc.num_clusters() > 1 {
            assert!(
                cadas.gflops > 0.85 * ideal,
                "{}: CA-DAS {:.2} must approach the ideal {ideal:.2}",
                soc.name,
                cadas.gflops
            );
            assert!(
                cadas.gflops > 1.5 * sss.gflops,
                "{}: asymmetry-aware must crush oblivious SSS",
                soc.name
            );
        } else {
            // Degenerate SMP: everything collapses to plain BLIS.
            for st in &by_name {
                assert!(
                    (st.gflops / sss.gflops - 1.0).abs() < 0.05,
                    "symmetric SMP: {} must match SSS",
                    st.label
                );
            }
        }

        // Exynos block: the pre-refactor figure anchors must reproduce.
        if soc.name.contains("Exynos") {
            let a15 = simulate(&model, &ScheduleSpec::cluster_only(BIG, 4), GemmShape::square(r));
            let sas5 = simulate(&model, &ScheduleSpec::sas(5.0), GemmShape::square(r));
            let frac = sss.gflops / a15.gflops;
            assert!(
                (0.32..0.50).contains(&frac),
                "Fig. 7 anchor: SSS ≈ 40 % of A15-only, got {frac:.2}"
            );
            let gain = sas5.gflops / a15.gflops;
            assert!(
                (1.10..1.30).contains(&gain),
                "Fig. 9 anchor: SAS(5) ≈ +20 % over A15-only, got {gain:.2}"
            );
            assert!(
                cadas.gflops > 0.90 * ideal,
                "Fig. 12 anchor: CA-DAS within 10 % of ideal"
            );
            println!(
                "Exynos anchors hold: SSS/A15x4 = {frac:.2}, SAS(5)/A15x4 = {gain:.2}, \
                 CA-DAS = {:.0} % of ideal\n",
                cadas.gflops / ideal * 100.0
            );
        }
    }
    println!("topology sweep: all invariants hold");
}
