//! Fleet sweep: the paper's scheduling story retold at the board level
//! (cluster : SoC :: board : fleet), in deterministic virtual time.
//!
//! Three sweeps, each with machine-checked invariants:
//!
//! * **strategy sweep** on a skewed heterogeneous fleet (Exynos 5422 +
//!   DynamIQ tri-cluster): equal-shard fleet-SSS loses to the
//!   throughput-weighted fleet-SAS and the dynamic fleet-DAS — the
//!   Fig. 7-vs-Fig. 12 result one level up;
//! * **mixed-fleet completion**: 1–4 boards of mixed presets drain
//!   every batch exactly under fleet-DAS;
//! * **capacity planning**: how many Exynos boards sustain a target
//!   request rate.
//!
//! Run: `cargo run --release --example fleet_sweep [-- --size 1024 --batch 32]`

use amp_gemm::blis::gemm::GemmShape;
use amp_gemm::fleet::sim::{boards_to_sustain, simulate_fleet};
use amp_gemm::fleet::{Board, Fleet, FleetStrategy};
use amp_gemm::util::cli::Args;
use amp_gemm::util::table::Table;

fn main() {
    let args = Args::from_env().expect("args");
    let r = args.usize_or("size", 1024).expect("--size");
    // The inline invariants (DAS-beats-SSS, capacity targets up to
    // 3.5×) need enough items to shard meaningfully; clamp tiny
    // batches rather than panic on a vacuous split.
    let requested = args.usize_or("batch", 32).expect("--batch");
    let batch = requested.max(8);
    if batch != requested {
        println!("note: --batch {requested} raised to {batch} (sweep invariant minimum)\n");
    }
    let shape = GemmShape::square(r);

    // --- Strategy sweep on a skewed heterogeneous two-board fleet. ---
    let fleet = Fleet::parse("exynos5422,dynamiq_3c").expect("presets");
    let mut table = Table::new(
        &format!("strategy sweep — exynos5422 + dynamiq_3c, r = {r}, batch = {batch}"),
        &["strategy", "makespan [s]", "req/s", "GFLOPS", "GFLOPS/W", "items/board"],
    );
    let mut stats = Vec::new();
    for strategy in [FleetStrategy::Sss, FleetStrategy::Sas, FleetStrategy::Das] {
        let st = simulate_fleet(&fleet, strategy, shape, batch);
        table.push_row(vec![
            strategy.label().to_string(),
            format!("{:.3}", st.makespan_s),
            format!("{:.2}", st.throughput_rps),
            format!("{:.2}", st.gflops),
            format!("{:.3}", st.gflops_per_watt),
            st.boards
                .iter()
                .map(|b| b.items.to_string())
                .collect::<Vec<_>>()
                .join("+"),
        ]);
        stats.push(st);
    }
    println!("{}", table.to_markdown());
    let (sss, sas, das) = (&stats[0], &stats[1], &stats[2]);
    assert!(
        das.makespan_s < 0.90 * sss.makespan_s,
        "fleet-DAS {:.3}s must beat equal-shard fleet-SSS {:.3}s",
        das.makespan_s,
        sss.makespan_s
    );
    assert!(
        sas.makespan_s < 0.95 * sss.makespan_s,
        "fleet-SAS must beat fleet-SSS"
    );
    assert!(
        das.gflops_per_watt > sss.gflops_per_watt,
        "balanced shards also win on energy"
    );

    // --- Mixed fleets, 1–4 boards: fleet-DAS drains every batch. ---
    let mixes = [
        "exynos5422",
        "exynos5422,juno_r0",
        "exynos5422,juno_r0,dynamiq_3c",
        "exynos5422,juno_r0,dynamiq_3c,pe_hybrid",
    ];
    let mut mix_table = Table::new(
        &format!("mixed fleets under fleet-DAS — r = {r}, batch = {batch}"),
        &["fleet", "boards", "req/s", "GFLOPS", "items/board"],
    );
    let mut prev_rps = 0.0;
    for mix in mixes {
        let f = Fleet::parse(mix).expect("presets");
        let st = simulate_fleet(&f, FleetStrategy::Das, shape, batch);
        assert_eq!(
            st.items_completed(),
            batch,
            "{mix}: fleet-DAS must complete the whole batch"
        );
        // Non-strict: a tiny --batch can leave a newly added board with
        // zero items, in which case throughput merely holds steady.
        assert!(
            st.throughput_rps >= prev_rps,
            "{mix}: adding a board must never lower sustained throughput"
        );
        prev_rps = st.throughput_rps;
        mix_table.push_row(vec![
            mix.to_string(),
            f.num_boards().to_string(),
            format!("{:.2}", st.throughput_rps),
            format!("{:.2}", st.gflops),
            st.boards
                .iter()
                .map(|b| b.items.to_string())
                .collect::<Vec<_>>()
                .join("+"),
        ]);
    }
    println!("{}", mix_table.to_markdown());

    // --- Capacity planning: boards to sustain a target rate. ---
    let exynos = Board::from_preset("exynos5422").expect("preset");
    let one = simulate_fleet(
        &Fleet::homogeneous(1, &exynos),
        FleetStrategy::Das,
        shape,
        batch,
    );
    let mut plan_table = Table::new(
        &format!(
            "capacity plan — Exynos boards per target (1 board sustains {:.2} req/s)",
            one.throughput_rps
        ),
        &["target [req/s]", "boards"],
    );
    let mut last = 0usize;
    for mult in [0.5, 1.5, 2.5, 3.5] {
        let target = mult * one.throughput_rps;
        let need = boards_to_sustain(&exynos, shape, batch, target, 8)
            .expect("8 boards must cover a 3.5x target");
        assert!(need >= last, "plan must grow with the target");
        last = need;
        plan_table.push_row(vec![format!("{target:.2}"), need.to_string()]);
    }
    println!("{}", plan_table.to_markdown());

    println!("fleet sweep: all invariants hold");
}
