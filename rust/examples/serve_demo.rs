//! GEMM-as-a-service demo: starts the coordinator's TCP server on an
//! ephemeral port, drives it with concurrent clients across backends,
//! and prints the protocol exchange plus final service metrics.
//!
//! Run: `cargo run --release --example serve_demo`

use amp_gemm::coordinator::{server, Coordinator};
use amp_gemm::soc::SocSpec;
use std::path::Path;
use std::sync::Arc;

fn main() {
    let artifacts = Path::new("artifacts");
    let coord = if artifacts.join("manifest.txt").exists() {
        println!("starting service with PJRT artifacts");
        Coordinator::with_artifacts(SocSpec::exynos5422(), artifacts).expect("coordinator")
    } else {
        println!("starting service without artifacts (native/sim only)");
        Coordinator::new(SocSpec::exynos5422())
    };
    let coord = Arc::new(coord);
    let handle = server::serve(coord.clone(), "127.0.0.1:0").expect("bind");
    println!("listening on {}\n", handle.addr);

    // Scripted exchange on one connection.
    let mut cl = server::Client::connect(handle.addr).expect("connect");
    for req in [
        "PING",
        "GEMM 128 128 128 7 native",
        "GEMM 256 256 256 7 native",
        "GEMM 128 128 128 7 pjrt:little",
        "GEMM 1024 1024 1024 7 sim",
        "GEMM 0 1 1 1 native",
        "STATS",
    ] {
        let reply = cl.call(req).expect("call");
        println!("> {req}\n< {reply}");
    }

    // Concurrent clients hammering the service.
    println!("\n8 concurrent clients × 6 requests each …");
    let addr = handle.addr;
    let t0 = std::time::Instant::now();
    let joins: Vec<_> = (0..8u64)
        .map(|id| {
            std::thread::spawn(move || {
                let mut cl = server::Client::connect(addr).expect("connect");
                for i in 0..6u64 {
                    let r = [64, 96, 128][(i % 3) as usize];
                    let reply = cl
                        .call(&format!("GEMM {r} {r} {r} {} native", id * 10 + i))
                        .expect("call");
                    assert!(reply.starts_with("OK"), "{reply}");
                }
            })
        })
        .collect();
    for j in joins {
        j.join().unwrap();
    }
    let dt = t0.elapsed().as_secs_f64();

    let m = coord.metrics();
    println!(
        "done: {} requests total, {:.1} req/s, aggregate {:.2} GFLOP dispatched",
        m.completed,
        48.0 / dt,
        m.total_flops / 1e9
    );
    handle.shutdown();
    println!("server stopped. serve_demo OK");
}
