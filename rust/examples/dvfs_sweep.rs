//! DVFS sweep: the operating-point axis of the paper's scheduling
//! story, in deterministic virtual time.
//!
//! Three sweeps, each with machine-checked invariants:
//!
//! * **OPP Pareto** — CA-SAS pinned at every joint ladder rung of the
//!   Exynos 5422: GFLOPS climbs with the clock while GFLOPS/W falls
//!   with the `f·V²` law, so the energy-optimal rung differs from the
//!   performance-optimal one (arXiv:1507.05129);
//! * **online retuning vs stale boot weights** under an
//!   `ondemand`-style ramp: recomputing the `sched::Weights` vector at
//!   every transition must beat the ratio knob configured once at boot
//!   (arXiv:1509.02058's governor interplay);
//! * **mid-run transition drain** — the dynamic queue completes every
//!   row even when the governor fires mid-simulation, twice, with
//!   identical timelines.
//!
//! Run: `cargo run --release --example dvfs_sweep [-- --size 1024 --period-ms 250]`

use amp_gemm::blis::gemm::GemmShape;
use amp_gemm::dvfs::sim::{simulate_dvfs, DvfsStrategy, Retune};
use amp_gemm::dvfs::{DvfsSchedule, Governor, Ondemand};
use amp_gemm::soc::{SocSpec, BIG, LITTLE};
use amp_gemm::util::cli::Args;
use amp_gemm::util::table::Table;

fn main() {
    let args = Args::from_env().expect("args");
    // The ramp invariants need the run to span the governor's
    // transitions; clamp tiny sizes rather than assert on a vacuous
    // sweep.
    let requested = args.usize_or("size", 1024).expect("--size");
    let r = requested.max(512);
    if r != requested {
        println!("note: --size {requested} raised to {r} (sweep invariant minimum)\n");
    }
    let period_ms = args.f64_or("period-ms", 100.0).expect("--period-ms");
    assert!(period_ms > 0.0, "--period-ms must be positive");
    let soc = SocSpec::exynos5422();
    let shape = GemmShape::square(r);
    let strat = DvfsStrategy::Sas { cache_aware: true };

    // --- OPP Pareto frontier. ---
    let mut pareto = Table::new(
        &format!("OPP Pareto — CA-SAS pinned per joint rung, r = {r}"),
        &["opp", "A15 [GHz]", "A7 [GHz]", "GFLOPS", "GFLOPS/W"],
    );
    let mut stats = Vec::new();
    for o in 0..soc[BIG].opps.len() {
        let st = simulate_dvfs(&soc, strat, shape, &DvfsSchedule::pinned(&[o, o]), Retune::Online);
        pareto.push_row(vec![
            o.to_string(),
            format!("{:.1}", soc[BIG].opps.get(o).freq_ghz),
            format!("{:.1}", soc[LITTLE].opps.get(o).freq_ghz),
            format!("{:.2}", st.gflops),
            format!("{:.3}", st.gflops_per_watt),
        ]);
        stats.push(st);
    }
    println!("{}", pareto.to_markdown());
    assert!(
        stats.windows(2).all(|w| w[1].gflops > w[0].gflops),
        "GFLOPS must climb the ladder"
    );
    assert!(
        stats[0].gflops_per_watt > stats.last().unwrap().gflops_per_watt,
        "the bottom rung must be the more efficient end"
    );
    println!(
        "invariant: energy-optimal rung 0 ({:.3} GFLOPS/W) != performance-optimal rung {} ({:.2} GFLOPS)\n",
        stats[0].gflops_per_watt,
        stats.len() - 1,
        stats.last().unwrap().gflops
    );

    // --- Online retuning vs stale boot weights under ondemand. ---
    let plan = Ondemand::new(period_ms / 1e3).plan(&soc, 1e3);
    let stale = simulate_dvfs(&soc, strat, shape, &plan, Retune::Boot);
    let online = simulate_dvfs(&soc, strat, shape, &plan, Retune::Online);
    let mut ramp = Table::new(
        &format!("ondemand ramp, period {period_ms} ms — online retuning vs stale boot weights"),
        &["weights", "makespan [s]", "GFLOPS", "GFLOPS/W", "retunes"],
    );
    for st in [&stale, &online] {
        ramp.push_row(vec![
            st.label.clone(),
            format!("{:.3}", st.time_s),
            format!("{:.2}", st.gflops),
            format!("{:.3}", st.gflops_per_watt),
            st.retunes.to_string(),
        ]);
    }
    println!("{}", ramp.to_markdown());
    if online.transitions_applied > 0 {
        assert!(
            online.gflops >= stale.gflops,
            "online retuning must never lose to stale weights: {} vs {}",
            online.gflops,
            stale.gflops
        );
    }
    println!(
        "invariant: online {:.2} GFLOPS >= stale {:.2} GFLOPS ({} retunes)\n",
        online.gflops, stale.gflops, online.retunes
    );

    // --- Mid-run transitions drain, deterministically. ---
    let das = DvfsStrategy::Das { cache_aware: true };
    let a = simulate_dvfs(&soc, das, shape, &plan, Retune::Online);
    let b = simulate_dvfs(&soc, das, shape, &plan, Retune::Online);
    assert_eq!(a, b, "same schedule must replay the same timeline");
    let drained: f64 = a.cluster_share.iter().sum();
    assert!((drained - 1.0).abs() < 1e-9, "queue must drain: {drained}");
    println!(
        "invariant: CA-DAS drained 100% of the work in {:.3} s across {} grabs ({} transitions applied), twice, identically",
        a.time_s, a.grabs, a.transitions_applied
    );
}
