//! The §3.3 empirical cache-parameter search (Fig. 4), as a runnable
//! tool: coarse sweep → fine refinement → optima, for every cluster,
//! plus the §5.3 shared-kc refit — with a terminal heatmap rendering.
//!
//! Run: `cargo run --release --example cache_search`

use amp_gemm::model::PerfModel;
use amp_gemm::search::{shared_kc_refit, two_phase_search, SearchResult};
use amp_gemm::soc::{BIG, LITTLE};

/// Coarse ASCII heatmap: rows = mc buckets, cols = kc buckets, shading
/// by GFLOPS decile (the terminal stand-in for Fig. 4's color plots).
fn render_heatmap(result: &SearchResult, buckets: usize) {
    let max = result.best.gflops;
    let min = result
        .points
        .iter()
        .map(|p| p.gflops)
        .fold(f64::INFINITY, f64::min);
    let mcs: Vec<usize> = {
        let mut v: Vec<usize> = result.points.iter().map(|p| p.mc).collect();
        v.sort_unstable();
        v.dedup();
        v
    };
    let kcs: Vec<usize> = {
        let mut v: Vec<usize> = result.points.iter().map(|p| p.kc).collect();
        v.sort_unstable();
        v.dedup();
        v
    };
    let shades: Vec<char> = " .:-=+*#%@".chars().collect();
    let pick = |mc: usize, kc: usize| -> f64 {
        result
            .points
            .iter()
            .find(|p| p.mc == mc && p.kc == kc)
            .map(|p| p.gflops)
            .unwrap_or(min)
    };
    let step_m = (mcs.len() / buckets).max(1);
    let step_k = (kcs.len() / buckets).max(1);
    println!("      kc {} .. {}", kcs[0], kcs[kcs.len() - 1]);
    for mi in (0..mcs.len()).step_by(step_m) {
        let mut line = String::new();
        for ki in (0..kcs.len()).step_by(step_k) {
            let g = pick(mcs[mi], kcs[ki]);
            let t = ((g - min) / (max - min + 1e-12) * (shades.len() - 1) as f64) as usize;
            line.push(shades[t.min(shades.len() - 1)]);
        }
        println!("mc={:>4} {}", mcs[mi], line);
    }
}

fn main() {
    let model = PerfModel::exynos();
    for cluster in model.soc.cluster_ids() {
        println!("=== {} ===", model.soc[cluster].name);
        let (coarse, fine) = two_phase_search(&model, cluster);
        render_heatmap(&coarse, 20);
        println!(
            "coarse optimum: (mc, kc) = ({}, {}) @ {:.3} GFLOPS",
            coarse.best.mc, coarse.best.kc, coarse.best.gflops
        );
        println!(
            "fine optimum:   (mc, kc) = ({}, {}) @ {:.3} GFLOPS   [paper: {}]\n",
            fine.best.mc,
            fine.best.kc,
            fine.best.gflops,
            match cluster {
                BIG => "(152, 952)",
                LITTLE => "(80, 352)",
                _ => "n/a",
            }
        );
    }

    println!("=== §5.3: A7 refit under shared kc = 952 ===");
    let refit = shared_kc_refit(&model, LITTLE, 952);
    println!(
        "constrained optimum: mc = {} @ {:.3} GFLOPS   [paper: mc = 32]",
        refit.best.mc, refit.best.gflops
    );
    let sample: Vec<String> = refit
        .points
        .iter()
        .filter(|p| p.mc % 16 == 0 || p.mc <= 48)
        .map(|p| format!("mc={:<3} {:.3}", p.mc, p.gflops))
        .collect();
    println!("{}", sample.join("\n"));
}
