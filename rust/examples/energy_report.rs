//! Energy deep-dive: the pmlib-style view of the paper's §3.4/§5
//! energy story — per-rail power, polling waste, and the GFLOPS/W
//! ranking across schedules, rendered the way the ODROID board's four
//! sensors would have reported it (250 ms samples).
//!
//! Run: `cargo run --release --example energy_report [-- --size 4096]`

use amp_gemm::blis::gemm::GemmShape;
use amp_gemm::energy::{PmlibSampler, PowerModel};
use amp_gemm::model::PerfModel;
use amp_gemm::sched::ScheduleSpec;
use amp_gemm::sim::simulate;
use amp_gemm::soc::{BIG, LITTLE};
use amp_gemm::util::cli::Args;
use amp_gemm::util::table::Table;

fn main() {
    let args = Args::from_env().expect("args");
    let r = args.usize_or("size", 4096).expect("--size");
    let model = PerfModel::exynos();
    let power = PowerModel::exynos();

    let specs = [
        ScheduleSpec::cluster_only(BIG, 1),
        ScheduleSpec::cluster_only(BIG, 3),
        ScheduleSpec::cluster_only(BIG, 4),
        ScheduleSpec::cluster_only(LITTLE, 4),
        ScheduleSpec::sss(),
        ScheduleSpec::sas(1.0),
        ScheduleSpec::sas(5.0),
        ScheduleSpec::ca_das(),
    ];

    let mut table = Table::new(
        &format!("Energy breakdown at r = {r} (virtual pmlib rails)"),
        &[
            "schedule", "time s", "GFLOPS", "E total J", "E A15 J", "E A7 J", "E DRAM J",
            "avg W", "poll s (Σcores)", "GFLOPS/W",
        ],
    );
    let mut ranking: Vec<(String, f64)> = Vec::new();
    for spec in &specs {
        let st = simulate(&model, spec, GemmShape::square(r));
        let poll_total: f64 = st.activity.iter().map(|a| a.poll_s).sum();
        table.push_row(vec![
            st.label.clone(),
            format!("{:.3}", st.time_s),
            format!("{:.2}", st.gflops),
            format!("{:.2}", st.energy.energy_j),
            format!("{:.2}", st.energy.cluster_rail_j(BIG)),
            format!("{:.2}", st.energy.cluster_rail_j(LITTLE)),
            format!("{:.2}", st.energy.energy_dram_j),
            format!("{:.2}", st.energy.avg_power_w),
            format!("{:.3}", poll_total),
            format!("{:.3}", st.gflops_per_watt),
        ]);
        ranking.push((st.label.clone(), st.gflops_per_watt));
    }
    println!("{}", table.to_markdown());

    ranking.sort_by(|a, b| b.1.partial_cmp(&a.1).unwrap());
    println!("GFLOPS/W ranking:");
    for (i, (name, eff)) in ranking.iter().enumerate() {
        println!("  {}. {:<22} {:.3}", i + 1, name, eff);
    }
    assert_eq!(
        ranking.last().map(|(n, _)| n.contains("SSS") || n.contains("SAS(r=1)")),
        Some(true),
        "the unbalanced schedules must rank last (§4/§5.2.2)"
    );

    // pmlib-style trace for one run: what the 250 ms sensors would see.
    let st = simulate(&model, &ScheduleSpec::sss(), GemmShape::square(r));
    let samples = PmlibSampler::default().sample(&power, st.time_s, &st.activity);
    println!("\npmlib trace of {} ({} samples @ 250 ms):", st.label, samples.len());
    for s in samples.iter().take(8) {
        println!(
            "  t={:>6.2}s  total {:>5.2} W  (A15 rail {:>5.2} W, A7 rail {:>5.2} W)",
            s.t_s, s.total_w, s.cluster_w[BIG.0], s.cluster_w[LITTLE.0]
        );
    }
    if samples.len() > 8 {
        println!("  ... ({} more)", samples.len() - 8);
    }
}
