//! Scheduler comparison: every strategy of the paper on one problem
//! size, as a markdown table — the "§5 at a glance" view.
//!
//! Run: `cargo run --release --example scheduler_comparison [-- --size 4096]`

use amp_gemm::blis::gemm::GemmShape;
use amp_gemm::figures::ideal_gflops;
use amp_gemm::model::PerfModel;
use amp_gemm::sched::{CoarseLoop, FineLoop, ScheduleSpec, Strategy};
use amp_gemm::sim::simulate;
use amp_gemm::soc::{BIG, LITTLE};
use amp_gemm::util::cli::Args;
use amp_gemm::util::table::Table;

fn main() {
    let args = Args::from_env().expect("args");
    let r = args.usize_or("size", 4096).expect("--size");
    let model = PerfModel::exynos();

    let mut specs: Vec<ScheduleSpec> = vec![
        ScheduleSpec::cluster_only(LITTLE, 4),
        ScheduleSpec::cluster_only(BIG, 4),
        ScheduleSpec::sss(),
    ];
    for ratio in [1.0, 3.0, 5.0, 7.0] {
        specs.push(ScheduleSpec::sas(ratio));
    }
    for ratio in [3.0, 5.0] {
        specs.push(ScheduleSpec::ca_sas(ratio));
    }
    specs.push(ScheduleSpec::das());
    specs.push(ScheduleSpec::ca_das());
    specs.push(ScheduleSpec::new(
        Strategy::CaDas,
        CoarseLoop::Loop3,
        FineLoop::Loop5,
    ));

    let mut table = Table::new(
        &format!("All schedulers at r = {r} (virtual Exynos 5422)"),
        &["schedule", "GFLOPS", "% of ideal", "GFLOPS/W", "busy util %", "grabs"],
    );
    let ideal = ideal_gflops(&model, r);
    let mut best: Option<(String, f64)> = None;
    for spec in &specs {
        let st = simulate(&model, spec, GemmShape::square(r));
        table.push_row(vec![
            st.label.clone(),
            format!("{:.2}", st.gflops),
            format!("{:.0}%", st.gflops / ideal * 100.0),
            format!("{:.3}", st.gflops_per_watt),
            format!("{:.0}%", st.mean_busy_utilization() * 100.0),
            st.grabs.to_string(),
        ]);
        if best.as_ref().map(|(_, g)| st.gflops > *g).unwrap_or(true) {
            best = Some((st.label.clone(), st.gflops));
        }
    }
    println!("{}", table.to_markdown());
    let (name, g) = best.unwrap();
    println!("ideal aggregate: {ideal:.2} GFLOPS");
    println!("best schedule:   {name} at {g:.2} GFLOPS ({:.0}% of ideal)", g / ideal * 100.0);
    assert!(name.starts_with("CA-DAS L3+L4"), "paper's winner should win");
}
