//! Streaming sweep (ISSUE 4): the admission-level retelling of the
//! paper's static-vs-dynamic story, with machine-checked invariants:
//!
//! * **degeneracy** — an all-at-t=0 single-shape stream reproduces the
//!   one-wave fleet-DAS simulation bit for bit (the correctness anchor
//!   of the streaming dispatcher);
//! * **pinned scenario** — on the exynos5422 + juno_r0 pair under
//!   staggered Poisson-like arrivals, streaming admission never loses
//!   on makespan to any synchronous wave mode and strictly raises
//!   aggregate board utilization;
//! * **exactly-once** — every request of the ad-hoc stream executes
//!   exactly once (per-shape shard-sum invariant) and merges back in
//!   submission order.
//!
//! Run: `cargo run --release --example stream_sweep [-- --requests 32
//! --rate 80 --seed 42 --sizes 384,512,640 --boards exynos5422,juno_r0]`

use amp_gemm::blis::gemm::GemmShape;
use amp_gemm::figures::fleet::{pinned_stream_arrivals, pinned_stream_fleet, stream_table};
use amp_gemm::fleet::sim::{
    burst_arrivals, poisson_arrivals, simulate_fleet, simulate_fleet_stream,
};
use amp_gemm::fleet::{Fleet, FleetStrategy};
use amp_gemm::util::cli::Args;
use amp_gemm::util::rng::Rng;
use amp_gemm::util::table::Table;

fn main() {
    let args = Args::from_env().expect("args");
    let fleet = Fleet::parse(args.get_or("boards", "exynos5422,juno_r0")).expect("--boards");
    let count = args.usize_or("requests", 32).expect("--requests").max(1);
    let rate = args.f64_or("rate", 80.0).expect("--rate");
    assert!(rate.is_finite() && rate > 0.0, "--rate must be positive");
    let seed = args.usize_or("seed", 42).expect("--seed") as u64;
    let sizes = args
        .usize_list("sizes")
        .expect("--sizes")
        .unwrap_or_else(|| vec![384, 512, 640]);
    assert!(sizes.iter().all(|&r| r > 0), "--sizes entries must be >= 1");

    // --- Degeneracy: burst stream == one-wave fleet-DAS, bit for bit. ---
    let shape = GemmShape::square(512);
    let wave = simulate_fleet(&fleet, FleetStrategy::Das, shape, 16);
    let burst = simulate_fleet_stream(&fleet, &burst_arrivals(shape, 16));
    assert_eq!(burst.makespan_s, wave.makespan_s, "degenerate makespan must match exactly");
    assert_eq!(burst.energy_j, wave.energy_j, "degenerate energy must match exactly");
    for (s, w) in burst.boards.iter().zip(&wave.boards) {
        assert_eq!(s.items, w.items, "degenerate per-board items");
        assert_eq!(s.finish_s, w.finish_s, "degenerate per-board finish");
    }
    println!(
        "degeneracy: burst stream == one-wave fleet-DAS ({:.4} s, {:.1} J)\n",
        burst.makespan_s, burst.energy_j
    );

    // --- Pinned scenario: streaming vs every wave mode. ---
    let pinned_fleet = pinned_stream_fleet();
    let arrivals = pinned_stream_arrivals(true);
    let (table, waves, stream) = stream_table(
        &format!(
            "pinned exynos5422 + juno_r0 — {} staggered arrivals",
            arrivals.len()
        ),
        &pinned_fleet,
        &arrivals,
    );
    println!("{}", table.to_markdown());
    for w in &waves {
        assert!(
            stream.makespan_s <= w.makespan_s,
            "streaming {:.4}s must not lose to {} {:.4}s",
            stream.makespan_s,
            w.label,
            w.makespan_s
        );
        assert!(
            stream.utilization > w.utilization,
            "streaming utilization {:.3} must strictly beat {} {:.3}",
            stream.utilization,
            w.label,
            w.utilization
        );
    }

    // --- Ad-hoc stream on the requested fleet: exactly-once + order. ---
    let shapes: Vec<GemmShape> = sizes.iter().map(|&r| GemmShape::square(r)).collect();
    let mut rng = Rng::new(seed);
    let adhoc = poisson_arrivals(&mut rng, &shapes, count, rate);
    let st = simulate_fleet_stream(&fleet, &adhoc);
    assert_eq!(st.items_completed(), count, "every request executes exactly once");
    // Engine-layer invariant: the run cache collapses the whole sweep
    // onto at most one DES run per (board config, shape) pair.
    assert!(
        st.des_runs as usize <= fleet.num_boards() * sizes.len(),
        "{} DES runs for {} board x shape pairs",
        st.des_runs,
        fleet.num_boards() * sizes.len()
    );
    println!(
        "engine: {} intra-SoC DES runs priced {} grabs ({} cache hits)\n",
        st.des_runs,
        st.boards.iter().map(|b| b.grabs).sum::<u64>(),
        st.cache_hits
    );
    for (job, executed) in &st.per_job {
        let submitted = adhoc.iter().filter(|a| a.job == *job).count();
        assert_eq!(*executed, submitted, "per-job shard-sum invariant ({job:?})");
    }
    for (i, (&done, a)) in st.completions.iter().zip(&adhoc).enumerate() {
        assert!(done.is_finite() && done > a.arrive_s, "request {i} completion");
    }
    let again = simulate_fleet_stream(&fleet, &adhoc);
    assert_eq!(st.makespan_s, again.makespan_s, "virtual-time replay is deterministic");
    assert_eq!(st.completions, again.completions);

    let mut boards = Table::new(
        &format!("{} — {} requests at {:.0} req/s", st.label, count, rate),
        &["board", "items", "grabs", "busy [s]", "idle tail [s]", "util", "energy [J]"],
    );
    for b in &st.boards {
        boards.push_row(vec![
            b.name.clone(),
            b.items.to_string(),
            b.grabs.to_string(),
            format!("{:.3}", b.busy_s),
            format!("{:.3}", b.idle_tail_s),
            format!("{:.3}", b.utilization),
            format!("{:.1}", b.energy_j),
        ]);
    }
    println!("{}", boards.to_markdown());

    println!("stream sweep: all invariants hold");
}
