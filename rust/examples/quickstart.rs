//! Quickstart: the 60-second tour of the public API.
//!
//! 1. Run one GEMM through the native asymmetric executor (CA-DAS).
//! 2. Verify it against the naive oracle.
//! 3. Simulate the same problem on the virtual Exynos 5422 and print
//!    the paper-style GFLOPS / GFLOPS/W numbers.
//! 4. If `make artifacts` has been run, execute the same problem through
//!    the PJRT runtime (the Pallas-lowered HLO) and cross-check.
//!
//! Run: `cargo run --release --example quickstart`

use amp_gemm::blis::gemm::{gemm_naive, GemmShape};
use amp_gemm::model::PerfModel;
use amp_gemm::native::gemm_parallel;
use amp_gemm::runtime::worker::PjrtHandle;
use amp_gemm::sched::ScheduleSpec;
use amp_gemm::sim::simulate;
use amp_gemm::soc::SocSpec;
use amp_gemm::util::rng::Rng;
use amp_gemm::util::stats::{gemm_tolerance, max_abs_diff};
use std::path::Path;

fn main() {
    let soc = SocSpec::exynos5422();
    println!("SoC: {}\n", soc.name);

    // --- 1+2: native CA-DAS GEMM, verified -------------------------
    let r = 256;
    let shape = GemmShape::square(r);
    let mut rng = Rng::new(2015);
    let a = rng.fill_matrix(r * r);
    let b = rng.fill_matrix(r * r);
    let mut c = vec![0.0; r * r];
    let spec = ScheduleSpec::ca_das();
    let stats = gemm_parallel(&soc, &spec, shape, &a, &b, &mut c);
    let mut want = vec![0.0; r * r];
    gemm_naive(shape, &a, &b, &mut want);
    let diff = max_abs_diff(&c, &want);
    assert!(diff < gemm_tolerance(r), "native result diverged: {diff}");
    println!(
        "native {}: {}x{}x{} in {:.2} ms on {} threads ({} dynamic grabs) — verified, max|Δ| = {diff:.2e}",
        stats.label, r, r, r, stats.wall_s * 1e3, stats.threads, stats.grabs
    );

    // --- 3: the same schedule on the virtual AMP --------------------
    let model = PerfModel::exynos();
    for spec in [
        ScheduleSpec::sss(),
        ScheduleSpec::sas(5.0),
        ScheduleSpec::ca_das(),
    ] {
        let st = simulate(&model, &spec, GemmShape::square(2048));
        println!(
            "sim    {:<16} r=2048: {:>6.2} GFLOPS, {:>5.3} GFLOPS/W",
            st.label, st.gflops, st.gflops_per_watt
        );
    }

    // --- 4: PJRT artifact path (optional) ---------------------------
    let dir = Path::new("artifacts");
    if dir.join("manifest.txt").exists() {
        let h = PjrtHandle::spawn(dir).expect("runtime");
        let shape = GemmShape::square(256);
        let (name, c_pjrt) = h
            .execute(shape, "big", a.clone(), b.clone())
            .expect("pjrt execute");
        let d = max_abs_diff(&c_pjrt, &want);
        assert!(d < gemm_tolerance(r));
        println!("pjrt   {name}: verified against the same oracle, max|Δ| = {d:.2e}");
        h.shutdown();
    } else {
        println!("(run `make artifacts` to enable the PJRT quickstart step)");
    }
    println!("\nquickstart OK");
}
