//! End-to-end driver: proves all layers compose on a real workload.
//!
//! Pipeline exercised, in order:
//!   1. **L1/L2 → runtime**: the Pallas-lowered HLO artifacts execute on
//!      the PJRT CPU client and agree with the native executor and the
//!      naive oracle on identical inputs (three-way cross-check).
//!   2. **L3 coordinator service**: a batch of mixed-shape GEMM requests
//!      flows through the TCP service (native + PJRT backends), with
//!      per-request latency and aggregate throughput reported.
//!   3. **The paper's evaluation**: the complete figure suite (Figs. 4,
//!      5, 7, 9, 10, 11, 12) regenerated on the virtual Exynos 5422,
//!      CSVs written to `results/`, every shape assertion checked.
//!   4. **Headline metric**: CA-DAS vs SSS vs A15-only at r = 4096 —
//!      the paper's architecture-aware-vs-oblivious claim.
//!
//! Run: `make artifacts && cargo run --release --example e2e_gemm`
//! The experiment index lives in DESIGN.md §9.

use amp_gemm::blis::gemm::{gemm_naive, GemmShape};
use amp_gemm::coordinator::{server, Coordinator};
use amp_gemm::figures;
use amp_gemm::model::PerfModel;
use amp_gemm::native::gemm_parallel;
use amp_gemm::runtime::worker::PjrtHandle;
use amp_gemm::sched::ScheduleSpec;
use amp_gemm::soc::{SocSpec, BIG};
use amp_gemm::util::rng::Rng;
use amp_gemm::util::stats::{gemm_tolerance, max_abs_diff, Summary};
use std::path::Path;
use std::sync::Arc;
use std::time::Instant;

fn main() {
    let t_start = Instant::now();
    let soc = SocSpec::exynos5422();
    let artifacts = Path::new("artifacts");
    let have_artifacts = artifacts.join("manifest.txt").exists();

    // ---------- 1. three-way cross-check ---------------------------
    println!("== stage 1: L1 Pallas → HLO → PJRT vs native vs oracle ==");
    if have_artifacts {
        let h = PjrtHandle::spawn(artifacts).expect("pjrt runtime");
        for (r, variant) in [(64usize, "big"), (128, "little"), (256, "big"), (512, "big")] {
            let shape = GemmShape::square(r);
            let mut rng = Rng::new(0xE2E + r as u64);
            let a = rng.fill_matrix(r * r);
            let b = rng.fill_matrix(r * r);
            let mut oracle = vec![0.0; r * r];
            gemm_naive(shape, &a, &b, &mut oracle);

            let (name, c_pjrt) = h
                .execute(shape, variant, a.clone(), b.clone())
                .expect("pjrt");
            let mut c_native = vec![0.0; r * r];
            gemm_parallel(&soc, &ScheduleSpec::ca_das(), shape, &a, &b, &mut c_native);

            let d_pjrt = max_abs_diff(&c_pjrt, &oracle);
            let d_native = max_abs_diff(&c_native, &oracle);
            let tol = gemm_tolerance(r);
            assert!(d_pjrt < tol && d_native < tol, "r={r}: {d_pjrt} / {d_native}");
            println!(
                "  r={r:<4} {name:<22} pjrt|Δ|={d_pjrt:.2e}  native|Δ|={d_native:.2e}  ✓"
            );
        }
        h.shutdown();
    } else {
        println!("  SKIPPED — run `make artifacts` first for the PJRT leg");
    }

    // ---------- 2. coordinator service under a mixed workload -------
    println!("\n== stage 2: coordinator service (TCP, batched) ==");
    let coord = if have_artifacts {
        Coordinator::with_artifacts(soc.clone(), artifacts).expect("coordinator")
    } else {
        Coordinator::new(soc.clone())
    };
    let handle = server::serve(Arc::new(coord), "127.0.0.1:0").expect("serve");
    let addr = handle.addr;
    let mut lat_native = Vec::new();
    let mut lat_pjrt = Vec::new();
    let t_wl = Instant::now();
    let mut joins = Vec::new();
    for client_id in 0..4u64 {
        joins.push(std::thread::spawn(move || {
            let mut cl = server::Client::connect(addr).expect("connect");
            let mut native = Vec::new();
            let mut pjrt = Vec::new();
            for i in 0..8u64 {
                let r = [64usize, 128, 256][(i % 3) as usize];
                let seed = client_id * 100 + i;
                let reply = cl
                    .call(&format!("GEMM {r} {r} {r} {seed} native"))
                    .expect("call");
                assert!(reply.starts_with("OK"), "{reply}");
                native.push(parse_latency_ms(&reply));
                let reply = cl
                    .call(&format!("GEMM {r} {r} {r} {seed} pjrt:big"))
                    .unwrap_or_default();
                if reply.starts_with("OK") {
                    pjrt.push(parse_latency_ms(&reply));
                }
            }
            (native, pjrt)
        }));
    }
    let mut total_reqs = 0;
    for j in joins {
        let (n, p) = j.join().unwrap();
        total_reqs += n.len() + p.len();
        lat_native.extend(n);
        lat_pjrt.extend(p);
    }
    let wl_s = t_wl.elapsed().as_secs_f64();
    let sn = Summary::of(&lat_native).unwrap();
    println!(
        "  native backend : {} reqs, latency mean {:.2} ms (p min {:.2} / max {:.2})",
        sn.n, sn.mean, sn.min, sn.max
    );
    if let Some(sp) = Summary::of(&lat_pjrt) {
        println!(
            "  pjrt backend   : {} reqs, latency mean {:.2} ms (min {:.2} / max {:.2})",
            sp.n, sp.mean, sp.min, sp.max
        );
    }
    println!(
        "  workload       : {total_reqs} requests over 4 concurrent clients in {wl_s:.2} s ({:.1} req/s)",
        total_reqs as f64 / wl_s
    );
    handle.shutdown();

    // ---------- 3. the paper's evaluation --------------------------
    println!("\n== stage 3: full figure suite on the virtual Exynos 5422 ==");
    let model = PerfModel::exynos();
    let out = Path::new("results");
    let mut all_pass = true;
    for fig in figures::run_all(&model, false) {
        let n_csv = fig.write_csvs(out).expect("write csvs").len();
        let pass = fig.passed();
        all_pass &= pass;
        println!(
            "  {:<6} {:<55} {} assertions {}  ({n_csv} CSVs)",
            fig.id,
            fig.title,
            fig.assertions.len(),
            if pass { "✓" } else { "✗ FAIL" }
        );
        if !pass {
            for a in fig.assertions.iter().filter(|a| !a.pass) {
                println!("      FAIL {}: {}", a.name, a.detail);
            }
        }
    }
    assert!(all_pass, "figure shape assertions failed");

    // ---------- 4. headline metric ----------------------------------
    println!("\n== stage 4: headline (paper §5 claims at r = 4096) ==");
    let r = 4096;
    let sss = figures::sim_square(&model, &ScheduleSpec::sss(), r);
    let a15 = figures::sim_square(&model, &ScheduleSpec::cluster_only(BIG, 4), r);
    let sas5 = figures::sim_square(&model, &ScheduleSpec::sas(5.0), r);
    let cadas = figures::sim_square(&model, &ScheduleSpec::ca_das(), r);
    let ideal = figures::ideal_gflops(&model, r);
    println!("  ideal aggregate              : {ideal:>6.2} GFLOPS");
    println!(
        "  A15-only (4 cores)           : {:>6.2} GFLOPS   {:>5.3} GFLOPS/W",
        a15.gflops, a15.gflops_per_watt
    );
    println!(
        "  SSS  (oblivious, 8 cores)    : {:>6.2} GFLOPS   {:>5.3} GFLOPS/W   ({:.0}% of A15-only)",
        sss.gflops,
        sss.gflops_per_watt,
        sss.gflops / a15.gflops * 100.0
    );
    println!(
        "  SAS(r=5)                     : {:>6.2} GFLOPS   {:>5.3} GFLOPS/W   (+{:.0}% vs A15-only)",
        sas5.gflops,
        sas5.gflops_per_watt,
        (sas5.gflops / a15.gflops - 1.0) * 100.0
    );
    println!(
        "  CA-DAS (architecture-aware)  : {:>6.2} GFLOPS   {:>5.3} GFLOPS/W   ({:.0}% of ideal)",
        cadas.gflops,
        cadas.gflops_per_watt,
        cadas.gflops / ideal * 100.0
    );
    assert!(cadas.gflops > sas5.gflops * 0.97 && cadas.gflops > sss.gflops * 2.0);

    println!("\ne2e OK in {:.1} s — CSVs in results/, experiment index in DESIGN.md §9", t_start.elapsed().as_secs_f64());
}

fn parse_latency_ms(reply: &str) -> f64 {
    reply
        .split_whitespace()
        .nth(2)
        .and_then(|s| s.parse().ok())
        .expect("latency field")
}
