//! Cross-layer integration: the simulator, the native executor, the
//! figure harness and the coordinator exercised together.

use amp_gemm::blis::gemm::{gemm_naive, GemmShape};
use amp_gemm::blis::params::BlisParams;
use amp_gemm::figures;
use amp_gemm::model::PerfModel;
use amp_gemm::native::gemm_parallel;
use amp_gemm::sched::{CoarseLoop, FineLoop, ScheduleSpec, Strategy, Weights};
use amp_gemm::sim::simulate;
use amp_gemm::soc::{SocSpec, BIG, LITTLE};
use amp_gemm::util::rng::Rng;
use amp_gemm::util::stats::{gemm_tolerance, max_abs_diff};

/// Every schedule the figures rely on must be *both* simulatable and
/// natively executable, and the native result must be exact.
#[test]
fn every_figure_schedule_runs_on_both_engines() {
    let soc = SocSpec::exynos5422();
    let model = PerfModel::exynos();
    let mut specs: Vec<ScheduleSpec> = vec![ScheduleSpec::sss(), ScheduleSpec::das(), ScheduleSpec::ca_das()];
    for t in 1..=4 {
        specs.push(ScheduleSpec::cluster_only(BIG, t));
        specs.push(ScheduleSpec::cluster_only(LITTLE, t));
    }
    for r in 1..=7 {
        specs.push(ScheduleSpec::sas(r as f64));
    }
    for r in [1.0, 3.0, 5.0] {
        specs.push(ScheduleSpec::ca_sas(r));
    }
    for coarse in [CoarseLoop::Loop1, CoarseLoop::Loop3] {
        for fine in [FineLoop::Loop4, FineLoop::Loop5, FineLoop::Both] {
            specs.push(ScheduleSpec::new(
                Strategy::CaSas { weights: Weights::ratio(5.0) },
                coarse,
                fine,
            ));
        }
    }

    let shape = GemmShape { m: 70, n: 54, k: 38 };
    let mut rng = Rng::new(0x517AC4);
    let a = rng.fill_matrix(shape.m * shape.k);
    let b = rng.fill_matrix(shape.k * shape.n);
    let mut want = vec![0.0; shape.m * shape.n];
    gemm_naive(shape, &a, &b, &mut want);

    for spec in specs {
        // Virtual engine.
        let st = simulate(&model, &spec, GemmShape::square(1024));
        assert!(st.gflops > 0.0 && st.time_s > 0.0, "{}", spec.label());
        assert!(
            st.gflops < model.soc.aggregate_peak_gflops(),
            "{} exceeds aggregate peak",
            spec.label()
        );
        // Real engine.
        let mut c = vec![0.0; shape.m * shape.n];
        gemm_parallel(&soc, &spec, shape, &a, &b, &mut c);
        let d = max_abs_diff(&c, &want);
        assert!(d < gemm_tolerance(shape.k), "{}: diff {d}", spec.label());
    }
}

/// The simulated GFLOPS of any 8-core schedule is bounded by the ideal
/// aggregate; CA-DAS dominates every other 8-core schedule at medium
/// and large sizes (the paper's bottom line), and stays within reach of
/// the best static schedule at small sizes, where the mc-granular
/// dynamic chunks are coarser than a Loop-1 static column split.
#[test]
fn ca_das_dominates_at_scale() {
    let model = PerfModel::exynos();
    for r in [768usize, 1536, 3072, 6144] {
        let ideal = figures::ideal_gflops(&model, r);
        let cadas = simulate(&model, &ScheduleSpec::ca_das(), GemmShape::square(r)).gflops;
        assert!(cadas <= ideal * 1.001, "r={r}: {cadas} vs ideal {ideal}");
        for other in [
            ScheduleSpec::sss(),
            ScheduleSpec::sas(3.0),
            ScheduleSpec::sas(5.0),
            ScheduleSpec::das(),
            ScheduleSpec::ca_sas(3.0),
        ] {
            let g = simulate(&model, &other, GemmShape::square(r)).gflops;
            if r >= 2048 {
                assert!(
                    cadas >= g * 0.98,
                    "r={r}: CA-DAS {cadas} vs {} {g}",
                    other.label()
                );
            } else {
                assert!(
                    cadas >= g * 0.85,
                    "r={r}: CA-DAS {cadas} too far below {} {g}",
                    other.label()
                );
            }
        }
    }
}

/// Energy conservation: the per-rail energies always sum to the total,
/// and more imbalance ⇒ more poll energy (SSS vs SAS(5)).
#[test]
fn energy_accounting_consistency() {
    let model = PerfModel::exynos();
    for spec in [ScheduleSpec::sss(), ScheduleSpec::sas(5.0), ScheduleSpec::ca_das()] {
        let st = simulate(&model, &spec, GemmShape::square(2048));
        let sum = st.energy.energy_clusters_j.iter().sum::<f64>()
            + st.energy.energy_dram_j
            + st.energy.energy_gpu_j;
        assert!((sum - st.energy.energy_j).abs() < 1e-9, "{}", spec.label());
    }
    let sss = simulate(&model, &ScheduleSpec::sss(), GemmShape::square(2048));
    let sas = simulate(&model, &ScheduleSpec::sas(5.0), GemmShape::square(2048));
    let poll = |st: &amp_gemm::sim::RunStats| -> f64 {
        st.activity.iter().map(|a| a.poll_s).sum::<f64>() / st.time_s
    };
    assert!(poll(&sss) > 2.0 * poll(&sas), "SSS must poll far more");
}

/// The native executor agrees with the sequential blocked GEMM bit-for-
/// bit when run single-threaded (same loop order, same summation order).
#[test]
fn single_thread_native_is_bitwise_sequential() {
    use amp_gemm::blis::gemm::{gemm_blocked, Workspace};
    let soc = SocSpec::exynos5422();
    let shape = GemmShape { m: 61, n: 47, k: 53 };
    let mut rng = Rng::new(9);
    let a = rng.fill_matrix(shape.m * shape.k);
    let b = rng.fill_matrix(shape.k * shape.n);

    let mut c_seq = vec![0.0; shape.m * shape.n];
    gemm_blocked(
        &BlisParams::a15_opt(),
        shape,
        &a,
        &b,
        &mut c_seq,
        &mut Workspace::default(),
    );
    let mut c_par = vec![0.0; shape.m * shape.n];
    gemm_parallel(
        &soc,
        &ScheduleSpec::cluster_only(BIG, 1),
        shape,
        &a,
        &b,
        &mut c_par,
    );
    assert_eq!(c_seq, c_par, "single-thread parallel path must be bitwise identical");
}

/// Quick figure suite: regenerates, passes, and emits parseable CSVs
/// whose numeric columns round-trip.
#[test]
fn figure_csvs_round_trip() {
    let model = PerfModel::exynos();
    for fig in figures::run_all(&model, true) {
        assert!(fig.passed(), "{}", fig.to_markdown());
        for t in &fig.tables {
            let csv = t.to_csv();
            assert!(csv.lines().count() == t.rows.len() + 1);
            if let Some(col) = t.columns.first() {
                if col == "r" {
                    let rs = t.f64_column("r");
                    assert!(rs.windows(2).all(|w| w[0] < w[1]), "sizes must ascend");
                }
            }
        }
    }
}

/// Determinism across the whole stack: same seed ⇒ identical sim stats,
/// native checksums and figure tables.
#[test]
fn whole_stack_determinism() {
    let model = PerfModel::exynos();
    let s1 = simulate(&model, &ScheduleSpec::ca_das(), GemmShape::square(1999));
    let s2 = simulate(&model, &ScheduleSpec::ca_das(), GemmShape::square(1999));
    assert_eq!(s1.time_s, s2.time_s);
    assert_eq!(s1.dram_bytes, s2.dram_bytes);

    let f1 = figures::run_figure(9, &model, true).unwrap();
    let f2 = figures::run_figure(9, &model, true).unwrap();
    assert_eq!(f1.tables[0].rows, f2.tables[0].rows);
}
