//! Property tests for the streaming dispatcher (ISSUE 4 satellite):
//! over random fleets (1–4 boards of mixed presets), random mixed
//! shapes and random arrival orders from `util::rng`,
//!
//! * results merge in exact submission order (the completions vector is
//!   submission-indexed and every entry is set exactly once);
//! * every request executes exactly once — the per-shape shard-sum
//!   invariant (executed histogram == submitted histogram);
//! * the virtual-time replay is deterministic across two runs;
//!
//! plus the ISSUE acceptance pin: an all-at-t=0 single-shape stream
//! through the *real-thread* `StreamDispatcher` reproduces
//! `FleetDispatcher::dispatch` bit for bit.

use amp_gemm::blis::gemm::GemmShape;
use amp_gemm::coordinator::{
    Backend, FleetDispatcher, Request, StreamDispatcher, StreamRequest, MAX_GROUP_LEN,
};
use amp_gemm::figures::fleet::pinned_stream_fleet;
use amp_gemm::fleet::sim::{
    burst_arrivals, poisson_arrivals, simulate_fleet, simulate_fleet_cached,
    simulate_fleet_stream, simulate_fleet_stream_cached, simulate_fleet_waves,
    simulate_fleet_waves_cached, Arrival, FleetStats, StreamStats,
};
use amp_gemm::fleet::{Board, Fleet, FleetStrategy};
use amp_gemm::sim::RunCache;
use amp_gemm::soc::SocSpec;
use amp_gemm::util::prop;
use amp_gemm::util::rng::Rng;
use std::sync::Arc;

const PRESETS: [&str; 4] = ["exynos5422", "juno_r0", "dynamiq_3c", "symmetric2"];
const SIZES: [usize; 4] = [96, 128, 192, 256];

/// A random fleet of 1–4 boards and a random mixed-shape stream whose
/// arrival order is independent of submission order (instants are drawn
/// i.i.d., including exact ties via a coarse grid).
fn random_stream(r: &mut Rng) -> (String, Vec<Arrival>) {
    let n = r.gen_range(1, 5); // 1..=4 boards
    let toks: Vec<&str> = (0..n).map(|_| *r.choose(&PRESETS)).collect();
    let count = r.gen_range(1, 25);
    let arrivals: Vec<Arrival> = (0..count)
        .map(|_| {
            let shape = GemmShape::square(*r.choose(&SIZES));
            // Coarse grid so equal instants (tie-breaking by submission
            // index) actually occur.
            let arrive = r.gen_range(0, 8) as f64 * 0.01;
            Arrival::at(shape, arrive)
        })
        .collect();
    (toks.join(","), arrivals)
}

/// The tentpole property: submission-order merge, exactly-once
/// execution and bit-for-bit replay determinism on random streams.
#[test]
fn prop_stream_merges_in_order_exactly_once_deterministically() {
    prop::check_default(
        |r| random_stream(r),
        |(list, arrivals)| {
            let fleet = Fleet::parse(list).map_err(|e| e.to_string())?;
            let a = simulate_fleet_stream(&fleet, arrivals);
            // Exactly once, in total and per shape.
            if a.items_completed() != arrivals.len() {
                return Err(format!(
                    "{} of {} requests executed",
                    a.items_completed(),
                    arrivals.len()
                ));
            }
            for &(job, executed) in &a.per_job {
                let submitted = arrivals.iter().filter(|x| x.job == job).count();
                if executed != submitted {
                    return Err(format!(
                        "job {job:?}: executed {executed} vs submitted {submitted}"
                    ));
                }
            }
            // Submission-order merge: completions are indexed by
            // submission order and every request finishes after it
            // arrives.
            if a.completions.len() != arrivals.len() {
                return Err("completions must be submission-indexed".into());
            }
            for (i, (&done, arr)) in a.completions.iter().zip(arrivals.iter()).enumerate() {
                if !done.is_finite() {
                    return Err(format!("request {i} never completed"));
                }
                if done <= arr.arrive_s {
                    return Err(format!(
                        "request {i} completed at {done} before arriving at {}",
                        arr.arrive_s
                    ));
                }
                if done > a.makespan_s + 1e-12 {
                    return Err(format!("request {i} completed after the makespan"));
                }
            }
            // Deterministic replay, bit for bit.
            let b = simulate_fleet_stream(&fleet, arrivals);
            if a.makespan_s != b.makespan_s
                || a.energy_j != b.energy_j
                || a.completions != b.completions
                || a.max_queue_depth != b.max_queue_depth
            {
                return Err("virtual-time replay must be deterministic".into());
            }
            // Board accounting stays coherent.
            for bd in &a.boards {
                if bd.finish_s > a.makespan_s + 1e-12 {
                    return Err(format!("board {} finishes after the makespan", bd.name));
                }
                if bd.items > 0 && bd.grabs == 0 {
                    return Err(format!("board {} has items but no grabs", bd.name));
                }
            }
            Ok(())
        },
    );
}

/// The wave-mode comparator obeys the same exactly-once and
/// submission-order contracts on random streams, for every strategy.
#[test]
fn prop_wave_replay_completes_in_submission_order() {
    prop::check_default(
        |r| {
            let (list, arrivals) = random_stream(r);
            let strategy = *r.choose(&[FleetStrategy::Sss, FleetStrategy::Sas, FleetStrategy::Das]);
            (list, arrivals, strategy)
        },
        |(list, arrivals, strategy)| {
            let fleet = Fleet::parse(list).map_err(|e| e.to_string())?;
            let st = simulate_fleet_waves(&fleet, *strategy, arrivals, MAX_GROUP_LEN);
            if st.items_completed() != arrivals.len() {
                return Err(format!(
                    "{}: {} of {} requests executed",
                    st.label,
                    st.items_completed(),
                    arrivals.len()
                ));
            }
            for (i, (&done, arr)) in st.completions.iter().zip(arrivals.iter()).enumerate() {
                if !done.is_finite() || done <= arr.arrive_s {
                    return Err(format!("{}: request {i} completion {done}", st.label));
                }
            }
            let again = simulate_fleet_waves(&fleet, *strategy, arrivals, MAX_GROUP_LEN);
            if st.makespan_s != again.makespan_s || st.completions != again.completions {
                return Err(format!("{}: wave replay must be deterministic", st.label));
            }
            Ok(())
        },
    );
}

/// ISSUE acceptance criterion: the all-at-t=0 single-shape stream
/// through the real-thread dispatcher matches
/// `FleetDispatcher::dispatch` bit for bit — responses (result
/// matrices, checksums, board labels) and deterministic per-board
/// metrics alike — for both static board strategies.
#[test]
fn stream_dispatcher_degenerate_burst_matches_fleet_dispatcher() {
    let fleet = || {
        Fleet::new(vec![
            Board::native("exynos", SocSpec::exynos5422()),
            Board::native("smp2", SocSpec::symmetric(2)),
        ])
    };
    let make = |i: u64| -> Request {
        let r = 64;
        let mut rng = Rng::new(400 + i);
        Request {
            id: i,
            shape: GemmShape::square(r),
            a: Arc::new(rng.fill_matrix(r * r)),
            b: Arc::new(rng.fill_matrix(r * r)),
            backend: Backend::Auto,
        }
    };
    for strategy in [FleetStrategy::Sss, FleetStrategy::Sas] {
        let wave = FleetDispatcher::new(fleet());
        let stream = StreamDispatcher::new(fleet());
        let wave_out = wave.dispatch((0..8).map(make).collect(), strategy);
        let stream_out = stream.dispatch_stream(
            (0..8).map(|i| StreamRequest::at(0.0, make(i))).collect(),
            strategy,
        );
        assert_eq!(wave_out.len(), stream_out.len());
        for (i, (a, b)) in wave_out.iter().zip(&stream_out).enumerate() {
            let (a, b) = (a.as_ref().unwrap(), b.as_ref().unwrap());
            assert_eq!(a.id, i as u64, "{}", strategy.label());
            assert_eq!(a.id, b.id);
            assert_eq!(a.c, b.c, "{}: request {i} result matrix", strategy.label());
            assert_eq!(a.checksum, b.checksum, "{}: request {i}", strategy.label());
            assert_eq!(
                a.backend_label, b.backend_label,
                "{}: request {i} board assignment",
                strategy.label()
            );
        }
        let (mw, ms) = (wave.metrics(), stream.metrics());
        assert_eq!(mw.batches, ms.batches, "{}", strategy.label());
        assert_eq!(mw.completed(), ms.completed());
        assert_eq!(mw.total_flops(), ms.total_flops());
        for ((na, a), (nb, b)) in mw.boards.iter().zip(&ms.boards) {
            assert_eq!(na, nb);
            assert_eq!(a.completed, b.completed, "{}: board {na}", strategy.label());
            assert_eq!(a.total_flops, b.total_flops, "{}: board {na}", strategy.label());
        }
    }
}

/// Sim-layer twin of the degeneracy pin, over every preset pair: the
/// burst stream is `simulate_fleet` under fleet-DAS, bit for bit.
#[test]
fn degenerate_burst_stream_is_one_wave_das_on_preset_pairs() {
    for pair in ["exynos5422,juno_r0", "exynos5422,dynamiq_3c", "juno_r0,symmetric2"] {
        let fleet = Fleet::parse(pair).unwrap();
        let shape = GemmShape::square(256);
        let wave = simulate_fleet(&fleet, FleetStrategy::Das, shape, 12);
        let stream = simulate_fleet_stream(&fleet, &burst_arrivals(shape, 12));
        assert_eq!(stream.makespan_s, wave.makespan_s, "{pair}");
        assert_eq!(stream.energy_j, wave.energy_j, "{pair}");
        for (s, w) in stream.boards.iter().zip(&wave.boards) {
            assert_eq!(s.items, w.items, "{pair}/{}", w.name);
            assert_eq!(s.grabs, w.grabs, "{pair}/{}", w.name);
            assert_eq!(s.busy_s, w.busy_s, "{pair}/{}", w.name);
            assert_eq!(s.finish_s, w.finish_s, "{pair}/{}", w.name);
        }
    }
}

/// Field-by-field bit equality for the stats a cached replay must
/// reproduce. The `des_runs`/`cache_hits` counters are *expected* to
/// differ between a fresh and a warm run, so they are excluded.
fn same_stream(tag: &str, a: &StreamStats, b: &StreamStats) -> Result<(), String> {
    let agg = [
        (a.makespan_s, b.makespan_s),
        (a.energy_j, b.energy_j),
        (a.utilization, b.utilization),
        (a.mean_queue_depth, b.mean_queue_depth),
        (a.sojourn_p50_s, b.sojourn_p50_s),
        (a.sojourn_p99_s, b.sojourn_p99_s),
    ];
    if agg.iter().any(|(x, y)| x != y)
        || a.completions != b.completions
        || a.max_queue_depth != b.max_queue_depth
    {
        return Err(format!("{tag}: aggregate stream stats diverge"));
    }
    for (x, y) in a.boards.iter().zip(&b.boards) {
        if x.items != y.items
            || x.grabs != y.grabs
            || x.busy_s != y.busy_s
            || x.finish_s != y.finish_s
            || x.idle_tail_s != y.idle_tail_s
            || x.energy_j != y.energy_j
        {
            return Err(format!("{tag}: board {} diverges", x.name));
        }
    }
    Ok(())
}

/// [`same_stream`]'s twin for the one-wave batch path.
fn same_fleet(tag: &str, a: &FleetStats, b: &FleetStats) -> Result<(), String> {
    let agg = [
        (a.makespan_s, b.makespan_s),
        (a.gflops, b.gflops),
        (a.throughput_rps, b.throughput_rps),
        (a.energy_j, b.energy_j),
        (a.gflops_per_watt, b.gflops_per_watt),
    ];
    if agg.iter().any(|(x, y)| x != y) {
        return Err(format!("{tag}: aggregate fleet stats diverge"));
    }
    for (x, y) in a.boards.iter().zip(&b.boards) {
        if x.items != y.items
            || x.grabs != y.grabs
            || x.busy_s != y.busy_s
            || x.finish_s != y.finish_s
            || x.energy_j != y.energy_j
        {
            return Err(format!("{tag}: board {} diverges", x.name));
        }
    }
    Ok(())
}

/// ISSUE 6 satellite: memoized replays are bit-for-bit identical to
/// fresh runs. One `RunCache` is shared across every run below —
/// stream, all three wave strategies, all three batch strategies — so
/// later runs price their items from earlier runs' DES results, and a
/// warm stream replay executes zero DES runs.
#[test]
fn prop_cached_replays_match_fresh_bit_for_bit() {
    prop::check_default(
        |r| random_stream(r),
        |(list, arrivals)| {
            let fleet = Fleet::parse(list).map_err(|e| e.to_string())?;
            let mut cache = RunCache::new();
            let fresh = simulate_fleet_stream(&fleet, arrivals);
            let cached = simulate_fleet_stream_cached(&fleet, arrivals, &mut cache);
            if cached.des_runs == 0 {
                return Err("a cold cache must execute DES runs".into());
            }
            same_stream("stream cold", &fresh, &cached)?;
            let warm = simulate_fleet_stream_cached(&fleet, arrivals, &mut cache);
            if warm.des_runs != 0 {
                return Err(format!("warm replay ran {} DES runs", warm.des_runs));
            }
            if warm.cache_hits == 0 {
                return Err("warm replay must price from the cache".into());
            }
            same_stream("stream warm", &fresh, &warm)?;
            let (shape, batch) = (
                arrivals[0].job.gemm().expect("random streams are GEMM-only"),
                arrivals.len(),
            );
            for strategy in [FleetStrategy::Sss, FleetStrategy::Sas, FleetStrategy::Das] {
                let tag = strategy.label();
                let fw = simulate_fleet_waves(&fleet, strategy, arrivals, MAX_GROUP_LEN);
                let cw = simulate_fleet_waves_cached(
                    &fleet,
                    strategy,
                    arrivals,
                    MAX_GROUP_LEN,
                    &mut cache,
                );
                same_stream(tag, &fw, &cw)?;
                let fb = simulate_fleet(&fleet, strategy, shape, batch);
                let cb = simulate_fleet_cached(&fleet, strategy, shape, batch, &mut cache);
                same_fleet(tag, &fb, &cb)?;
            }
            Ok(())
        },
    );
}

/// ISSUE 10 satellite: the consolidated [`StreamSim`] builder is
/// bit-for-bit the legacy entry points it absorbed, on random fleets
/// and streams — streaming admission, every wave strategy, and the
/// live-calibration replay (stats and board reports alike).
#[test]
fn prop_stream_sim_builder_matches_legacy_entry_points() {
    use amp_gemm::fleet::sim::{simulate_fleet_stream_live, LiveStreamConfig, StreamSim};
    prop::check(
        &prop::Config { cases: 24, seed: 0x51B_0B15 },
        |r| random_stream(r),
        |(list, arrivals)| {
            let fleet = Fleet::parse(list).map_err(|e| e.to_string())?;
            let legacy = simulate_fleet_stream(&fleet, arrivals);
            let built = StreamSim::new(&fleet).run(arrivals);
            if built != legacy {
                return Err("StreamSim streaming replay diverges from the wrapper".into());
            }
            for strategy in [FleetStrategy::Sss, FleetStrategy::Sas, FleetStrategy::Das] {
                let legacy_w = simulate_fleet_waves(&fleet, strategy, arrivals, MAX_GROUP_LEN);
                let built_w =
                    StreamSim::new(&fleet).waves(strategy, MAX_GROUP_LEN).run(arrivals);
                if built_w != legacy_w {
                    return Err(format!("{}: StreamSim wave replay diverges", strategy.label()));
                }
            }
            let cfg = LiveStreamConfig::default();
            let (legacy_live, legacy_reports) = simulate_fleet_stream_live(&fleet, arrivals, cfg);
            let (built_live, built_reports) =
                StreamSim::new(&fleet).live(cfg).run_live(arrivals);
            if built_live != legacy_live || built_reports != legacy_reports {
                return Err("StreamSim live replay diverges from the wrapper".into());
            }
            Ok(())
        },
    );
}

/// ISSUE 6 acceptance pin: a 10^6-arrival mixed-shape stream replays
/// through the engine inside the tier-1 budget. On the pinned two-board
/// fleet the run cache collapses the whole sweep onto at most six
/// intra-SoC DES runs — every service event beyond those is a heap
/// pop, a grab and a cache hit.
#[test]
fn million_arrival_stream_sweep_completes() {
    let fleet = pinned_stream_fleet();
    let shapes = [256, 384, 512].map(GemmShape::square);
    let arrivals = poisson_arrivals(&mut Rng::new(0x1E6), &shapes, 1_000_000, 120.0);
    let mut cache = RunCache::new();
    let st = simulate_fleet_stream_cached(&fleet, &arrivals, &mut cache);
    assert_eq!(st.items_completed(), 1_000_000);
    assert!(st.des_runs <= 6, "expected at most 6 DES runs, got {}", st.des_runs);
    let grabs: u64 = st.boards.iter().map(|b| b.grabs).sum();
    assert_eq!(st.des_runs + st.cache_hits, grabs, "every grab is a hit or a miss");
    assert!(st.makespan_s.is_finite() && st.makespan_s > 0.0);
    assert!(st.completions.iter().all(|c| c.is_finite()));
}

/// The real-thread dispatcher on randomized sim-backend fleets: mixed
/// shapes, scrambled arrival order, every strategy — responses always
/// come back in submission order and every request executes once.
#[test]
fn prop_stream_dispatcher_orders_responses_on_sim_fleets() {
    prop::check(
        &prop::Config { cases: 12, seed: 0x57BEA7 },
        |r| {
            let n = r.gen_range(1, 4); // 1..=3 boards
            let toks: Vec<&str> = (0..n).map(|_| *r.choose(&PRESETS)).collect();
            let count = r.gen_range(1, 10);
            let spec: Vec<(usize, f64)> = (0..count)
                .map(|_| (*r.choose(&[48usize, 64, 96]), r.gen_range(0, 5) as f64 * 0.02))
                .collect();
            let strategy =
                *r.choose(&[FleetStrategy::Sss, FleetStrategy::Sas, FleetStrategy::Das]);
            (toks.join(","), spec, strategy)
        },
        |(list, spec, strategy)| {
            let boards: Vec<Board> = list
                .split(',')
                .map(Board::from_preset)
                .collect::<Result<_, _>>()
                .map_err(|e| e.to_string())?;
            let d = StreamDispatcher::new(Fleet::new(boards));
            let reqs: Vec<StreamRequest> = spec
                .iter()
                .enumerate()
                .map(|(i, &(r, arrive))| {
                    let mut rng = Rng::new(900 + i as u64);
                    StreamRequest::at(
                        arrive,
                        Request {
                            id: i as u64,
                            shape: GemmShape::square(r),
                            a: Arc::new(rng.fill_matrix(r * r)),
                            b: Arc::new(rng.fill_matrix(r * r)),
                            backend: Backend::Auto,
                        },
                    )
                })
                .collect();
            let out = d.dispatch_stream(reqs, *strategy);
            if out.len() != spec.len() {
                return Err(format!("{} responses for {} requests", out.len(), spec.len()));
            }
            for (i, resp) in out.iter().enumerate() {
                let resp = resp.as_ref().map_err(|e| format!("request {i}: {e}"))?;
                if resp.id != i as u64 {
                    return Err(format!(
                        "response {i} carries id {} — submission order broken",
                        resp.id
                    ));
                }
            }
            if d.metrics().completed() != spec.len() as u64 {
                return Err(format!(
                    "{} completed of {}",
                    d.metrics().completed(),
                    spec.len()
                ));
            }
            Ok(())
        },
    );
}
