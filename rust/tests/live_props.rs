//! Property tests for online calibration while serving (ISSUE 9,
//! proptest-style over randomized 1–4-cluster descriptors):
//!
//! * a *cold* [`WeightSource::Live`] degenerates to
//!   [`WeightSource::Analytical`] bit for bit — through the weight
//!   vector, the coordinator's SAS ratio knob and the DVFS strategy
//!   specs alike;
//! * once every cell a weight call needs is confident, `Live` equals
//!   [`WeightSource::Empirical`] over the frozen
//!   [`LiveRateTable::snapshot`] bit for bit (the replay contract);
//! * the live-calibrating streaming replay is deterministic: two runs
//!   over the same arrivals produce identical stats *and* identical
//!   learned tables, re-plan counts included;
//! * cold-start convergence: serving a stream from a cold table drives
//!   the live weight shares toward the offline-measured
//!   ([`RateTable::measure`]) shares on randomized descriptors;
//! * degenerate observations (zero/negative/NaN flops or service) are
//!   counted at the gate and never poison learned rates;
//! * `ShapeClass::of` boundary audit: `k == kc` is Medium, `k == 4·kc`
//!   is Large — live classification can never disagree with the
//!   offline measurement path over the same `kc_ref`.

use amp_gemm::blis::gemm::GemmShape;
use amp_gemm::calibrate::live::{live_source, LiveRateTable};
use amp_gemm::calibrate::{current_opps, Family, RateTable, ShapeClass, WeightSource};
use amp_gemm::coordinator::Coordinator;
use amp_gemm::dvfs::sim::DvfsStrategy;
use amp_gemm::fleet::sim::{poisson_arrivals, simulate_fleet_stream_live, LiveStreamConfig};
use amp_gemm::fleet::{Board, Fleet};
use amp_gemm::model::PerfModel;
use amp_gemm::soc::{ClusterId, ClusterSpec, OperatingPoint, OppTable, SocSpec};
use amp_gemm::util::prop;
use amp_gemm::util::rng::Rng;
use amp_gemm::{prop_assert, prop_assert_eq};

/// A random 1–4-cluster topology with 1–3-rung OPP ladders: donor
/// clusters from the presets with randomized frequencies, the nominal
/// rung pinned to the boot frequency (the `dvfs_props` generator,
/// bounded to the ISSUE 9 acceptance envelope).
fn random_soc(r: &mut Rng, max_clusters: usize, max_rungs: usize) -> SocSpec {
    let exynos = SocSpec::exynos5422();
    let tri = SocSpec::dynamiq_3c();
    let donors: Vec<ClusterSpec> = vec![
        exynos.clusters[0].clone(),
        exynos.clusters[1].clone(),
        tri.clusters[1].clone(),
    ];
    let n = r.gen_range(1, max_clusters + 1);
    let clusters: Vec<ClusterSpec> = (0..n)
        .map(|i| {
            let mut cl = donors[r.gen_range(0, donors.len())].clone();
            cl.name = format!("c{i}-{}", cl.name);
            cl.core.freq_ghz = r.gen_f64(0.4, 2.5);
            let rungs = r.gen_range(1, max_rungs + 1);
            let lo = r.gen_f64(0.3, 0.8);
            let points: Vec<OperatingPoint> = (0..rungs)
                .map(|k| {
                    // The nominal (last) rung must be *exactly* the boot
                    // frequency — `lo + (1-lo)` is not exactly 1.0 in
                    // floating point.
                    let frac = if k + 1 == rungs {
                        1.0
                    } else {
                        lo + (1.0 - lo) * k as f64 / (rungs - 1).max(1) as f64
                    };
                    let volt = 0.9 + 0.25 * k as f64 / (rungs - 1).max(1) as f64;
                    OperatingPoint::new(cl.core.freq_ghz * frac, volt)
                })
                .collect();
            cl.opps = if rungs == 1 {
                OppTable::single(cl.core.freq_ghz)
            } else {
                OppTable::new(points)
            };
            cl
        })
        .collect();
    SocSpec {
        name: format!("random-{n}c"),
        clusters,
        l3: None,
        dram_bw_gbs: 3.2,
        dram_total_bytes: 2 * 1024 * 1024 * 1024,
    }
}

/// A cold live table behaves exactly like the analytical source — same
/// weight vector (both families, every shape class), same coordinator
/// SAS ratio, same DVFS strategy specs. Bit for bit, not approximately:
/// both paths build `Weights::from_slice` over the same per-cluster
/// `cluster_rate_gflops` values.
#[test]
fn prop_cold_live_degenerates_to_analytical() {
    prop::check_default(
        |r| {
            let soc = random_soc(r, 4, 3);
            let half_life = r.gen_f64(1.0, 128.0);
            let min_samples = r.gen_range(1, 64) as u64;
            (soc, half_life, min_samples)
        },
        |(soc, half_life, min_samples)| {
            let model = PerfModel::new(soc.clone());
            let cold = live_source(LiveRateTable::new(soc, *half_life), *min_samples);
            for cache_aware in [false, true] {
                for class in ShapeClass::ALL {
                    let live = cold.weights(&model, cache_aware, class);
                    let ana = WeightSource::Analytical.weights(&model, cache_aware, class);
                    prop_assert_eq!(live.as_slice(), ana.as_slice());
                    for strategy in [
                        DvfsStrategy::Sas { cache_aware },
                        DvfsStrategy::Das { cache_aware },
                    ] {
                        prop_assert_eq!(
                            strategy.to_spec_with(&model, &cold, class),
                            strategy.to_spec_with(&model, &WeightSource::Analytical, class)
                        );
                    }
                }
            }
            if soc.num_clusters() == 2 {
                let coord = Coordinator::new(soc.clone());
                let shape = GemmShape::square(512);
                prop_assert_eq!(
                    coord.auto_ratio_from(&cold, shape),
                    coord.auto_ratio_from(&WeightSource::Analytical, shape)
                );
            }
            Ok(())
        },
    );
}

/// Once every cell a weight call touches is confident, `Live` equals
/// `Empirical` over the frozen snapshot bit for bit — the determinism
/// contract replays are stated in (DESIGN.md §5).
#[test]
fn prop_confident_live_matches_frozen_snapshot() {
    prop::check_default(
        |r| {
            let soc = random_soc(r, 4, 3);
            let half_life = r.gen_f64(1.0, 128.0);
            let min_samples = r.gen_range(1, 16) as u64;
            let cache_aware = r.gen_bool(0.5);
            let class = ShapeClass::ALL[r.gen_range(0, 3)];
            // Per-cluster observation streams: (observed GFLOPS, extra
            // events past the confidence gate).
            let obs: Vec<(f64, u64)> = (0..soc.num_clusters())
                .map(|_| (r.gen_f64(0.1, 50.0), r.gen_range(0, 8) as u64))
                .collect();
            (soc, half_life, min_samples, cache_aware, class, obs)
        },
        |(soc, half_life, min_samples, cache_aware, class, obs)| {
            let model = PerfModel::new(soc.clone());
            let mut table = LiveRateTable::new(soc, *half_life);
            let opps = current_opps(soc);
            let family = Family::of(*cache_aware);
            let shape = class.rep_shape(table.kc_ref);
            prop_assert_eq!(table.classify(shape), *class);
            for c in soc.cluster_ids() {
                let (gflops, extra) = obs[c.0];
                for _ in 0..(*min_samples + extra) {
                    // `service = flops / (rate · 1e9)` feeds the cell an
                    // observation of exactly `gflops`.
                    let flops = 2.0 * (shape.m * shape.n * shape.k) as f64;
                    let ok =
                        table.observe(c, opps[c.0], family, shape, flops, flops / (gflops * 1e9));
                    prop_assert!(ok, "valid observation rejected at the gate");
                }
                prop_assert!(
                    table.confident(c, opps[c.0], family, *class, *min_samples),
                    "cluster {c} fed past the gate is not confident"
                );
            }
            let frozen = WeightSource::Empirical(table.snapshot(soc, *min_samples));
            let live = live_source(table, *min_samples);
            prop_assert_eq!(
                live.weights(&model, *cache_aware, *class).as_slice(),
                frozen.weights(&model, *cache_aware, *class).as_slice()
            );
            Ok(())
        },
    );
}

/// Degenerate observations (zero / negative / non-finite flops or
/// service time) are counted at the gate and change *nothing* else:
/// not the accepted count, not any learned cell.
#[test]
fn prop_degenerate_observations_are_counted_not_poisoning() {
    prop::check_default(
        |r| {
            let soc = random_soc(r, 4, 3);
            let half_life = r.gen_f64(1.0, 128.0);
            let valid = r.gen_range(1, 32);
            (soc, half_life, valid)
        },
        |(soc, half_life, valid)| {
            let mut table = LiveRateTable::new(soc, *half_life);
            let opps = current_opps(soc);
            let shape = GemmShape::square(512);
            let mut r = Rng::new(0xD00_D1E);
            for _ in 0..*valid {
                let c = ClusterId(r.gen_range(0, soc.num_clusters()));
                table.observe(c, opps[c.0], Family::CacheAware, shape, 1e9, r.gen_f64(0.01, 2.0));
            }
            let before = table.clone();
            let c0 = ClusterId(0);
            let bad = [
                (0.0, 1.0),
                (-3.0, 1.0),
                (f64::NAN, 1.0),
                (1e9, 0.0),
                (1e9, -1.0),
                (1e9, f64::NAN),
                (f64::INFINITY, 1.0),
                (1e9, f64::INFINITY),
            ];
            for (i, (flops, service)) in bad.iter().enumerate() {
                let ok = table.observe(c0, opps[0], Family::CacheAware, shape, *flops, *service);
                prop_assert!(!ok, "degenerate observation ({flops}, {service}) accepted");
                prop_assert_eq!(table.rejected(), before.rejected() + 1 + i as u64);
            }
            prop_assert_eq!(table.accepted(), before.accepted());
            prop_assert_eq!(table.num_cells(), before.num_cells());
            for ((ka, ca), (kb, cb)) in table.cells().zip(before.cells()) {
                prop_assert_eq!(ka, kb);
                prop_assert_eq!(ca, cb);
            }
            Ok(())
        },
    );
}

/// Boundary audit: the class edges sit exactly at `k == kc` (Small →
/// Medium) and `k == 4·kc` (Medium → Large), and a live table pinned at
/// a descriptor's lead `kc` classifies every shape exactly like the
/// offline path ([`ShapeClass::for_soc`]) does.
#[test]
fn prop_shape_class_boundaries_pin_kc() {
    prop::check_default(
        |r| {
            let kc = r.gen_range(2, 3000);
            let m = r.gen_range(1, 4096);
            let n = r.gen_range(1, 4096);
            (kc, m, n)
        },
        |(kc, m, n)| {
            let at = |k: usize| ShapeClass::of(GemmShape { m: *m, n: *n, k }, *kc);
            prop_assert_eq!(at(*kc - 1), ShapeClass::Small);
            prop_assert_eq!(at(*kc), ShapeClass::Medium);
            prop_assert_eq!(at(4 * *kc - 1), ShapeClass::Medium);
            prop_assert_eq!(at(4 * *kc), ShapeClass::Large);
            Ok(())
        },
    );
    // The live table's pinned `kc_ref` is the lead cluster's tuned kc —
    // the exact reference `ShapeClass::for_soc` classifies against.
    let soc = SocSpec::exynos5422();
    let table = LiveRateTable::new(&soc, 32.0);
    for k in [1, 476, 951, 952, 953, 3807, 3808, 8192] {
        let shape = GemmShape { m: 640, n: 640, k };
        assert_eq!(table.classify(shape), ShapeClass::for_soc(&soc, shape));
    }
}

/// The live-calibrating streaming replay is deterministic: two runs
/// over the same arrivals are bit-for-bit identical — stream stats,
/// learned tables, warmup instants and re-plan counts alike.
#[test]
fn prop_live_stream_replay_is_deterministic() {
    prop::check(
        &prop::Config { cases: 8, seed: 0x11FE_DE7 },
        |r| {
            let soc = random_soc(r, 4, 2);
            let weighted_static = r.gen_bool(0.5);
            let size = 128 * r.gen_range(2, 6);
            let seed = r.gen_range(1, 1 << 30) as u64;
            (soc, weighted_static, size, seed)
        },
        |(soc, weighted_static, size, seed)| {
            let mut board = Board::sim("rand", soc.clone());
            if *weighted_static {
                // CA-SAS exercises the mid-stream re-plan arm; the
                // default CA-DAS board only feeds observations.
                board.sched = amp_gemm::calibrate::ca_sas_spec(
                    &WeightSource::Analytical,
                    board.model(),
                    ShapeClass::for_soc(soc, GemmShape::square(*size)),
                );
            }
            let fleet = Fleet::new(vec![board]);
            let mut rng = Rng::new(*seed);
            let arrivals = poisson_arrivals(&mut rng, &[GemmShape::square(*size)], 24, 50.0);
            let cfg = LiveStreamConfig::default();
            let a = simulate_fleet_stream_live(&fleet, &arrivals, cfg);
            let b = simulate_fleet_stream_live(&fleet, &arrivals, cfg);
            prop_assert_eq!(&a.0, &b.0);
            prop_assert_eq!(&a.1, &b.1);
            prop_assert_eq!(a.1.len(), 1);
            Ok(())
        },
    );
}

/// Cold-start convergence (the ISSUE 9 acceptance property): serving a
/// stream from a *cold* table on a randomized 1–4-cluster descriptor
/// drives the live weight shares to within 10 pp of the shares an
/// offline [`RateTable::measure`] pass produces — without ever running
/// the offline probe. Vacuous when the stream is too short to warm
/// every cluster's cell past the confidence gate (the fallback serves
/// analytically there, which the cold-degeneracy property pins).
#[test]
fn prop_cold_start_converges_toward_offline_rates() {
    prop::check(
        &prop::Config { cases: 6, seed: 0xC0_1DCA1B },
        |r| {
            let soc = random_soc(r, 4, 2);
            let seed = r.gen_range(1, 1 << 30) as u64;
            (soc, seed)
        },
        |(soc, seed)| {
            let model = PerfModel::new(soc.clone());
            let cfg = LiveStreamConfig::default();
            // One mid-class shape: every grab feeds the same cell per
            // cluster, so 40 requests comfortably clear min_samples.
            let shape = ShapeClass::Medium.rep_shape(soc[soc.lead()].tuned.kc);
            let class = ShapeClass::for_soc(soc, shape);
            let fleet = Fleet::new(vec![Board::sim("rand", soc.clone())]);
            let mut rng = Rng::new(*seed);
            let arrivals = poisson_arrivals(&mut rng, &[shape], 40, 100.0);
            let (_, reports) = simulate_fleet_stream_live(&fleet, &arrivals, cfg);
            let table = &reports[0].table;
            let opps = current_opps(soc);
            let all_confident = soc
                .cluster_ids()
                .all(|c| table.confident(c, opps[c.0], Family::CacheAware, class, cfg.min_samples));
            if !all_confident {
                // Too few observations to warm up — the analytical
                // fallback serves, which is covered elsewhere.
                return Ok(());
            }
            let live = live_source(table.clone(), cfg.min_samples)
                .weights(&model, true, class)
                .normalized();
            let offline = WeightSource::Empirical(RateTable::measure(soc, &[]))
                .weights(&model, true, class)
                .normalized();
            for c in 0..soc.num_clusters() {
                let gap = (live.share(c) - offline.share(c)).abs();
                prop_assert!(
                    gap <= 0.10,
                    "cluster {c}: live share {:.4} vs offline {:.4} (gap {gap:.4})",
                    live.share(c),
                    offline.share(c)
                );
            }
            Ok(())
        },
    );
}

/// Pinned end-to-end check on the exynos5422 preset (the descriptor the
/// `calibrate --live` report runs): a CA-SAS board re-plans mid-stream
/// at the default period, warms up at exactly `clusters · min_samples`
/// accepted observations (one Small-class cell per cluster), rejects
/// nothing, and the learned table freezes into a snapshot whose
/// empirical weights equal the live ones bit for bit.
#[test]
fn pinned_exynos_live_stream_warms_up_and_freezes() {
    let mut board = Board::from_preset("exynos5422").expect("preset");
    let class = ShapeClass::Small; // every stream k (384..640) < kc_ref 952
    board.sched =
        amp_gemm::calibrate::ca_sas_spec(&WeightSource::Analytical, board.model(), class);
    let model = board.model().clone();
    let soc = model.soc.clone();
    let fleet = Fleet::new(vec![board]);
    let shapes = [GemmShape::square(384), GemmShape::square(512), GemmShape::square(640)];
    let mut rng = Rng::new(0x11FE_CA1B);
    let arrivals = poisson_arrivals(&mut rng, &shapes, 48, 80.0);
    let cfg = LiveStreamConfig::default();
    let (stats, reports) = simulate_fleet_stream_live(&fleet, &arrivals, cfg);
    assert_eq!(reports.len(), 1);
    let rep = &reports[0];
    assert_eq!(rep.table.rejected(), 0, "degenerate observations on the pinned stream");
    assert!(rep.table.accepted() > 0);
    // Both clusters observe once per grab (grain 1), so every cell
    // crosses min_samples on the same grab: warmup at 2 · 8 events.
    assert_eq!(rep.warmup_events, Some(2 * cfg.min_samples));
    assert!(rep.replans >= 1, "48 grabs at replan_every=16 must re-plan");
    assert_eq!(stats.requests, 48);
    // Frozen-snapshot replay: Empirical over the snapshot == Live.
    let live_w = live_source(rep.table.clone(), cfg.min_samples).weights(&model, true, class);
    let frozen_w = WeightSource::Empirical(rep.table.snapshot(&soc, cfg.min_samples))
        .weights(&model, true, class);
    assert_eq!(live_w.as_slice(), frozen_w.as_slice());
}
