//! Golden regression for the Perfetto trace exporter (ISSUE 7
//! satellite): the pinned two-board stream (exynos5422 + juno_r0, 24
//! Poisson arrivals — the same fixture `tests/fleet_golden.rs` pins
//! numerically) is traced and the emitted Chrome-trace document is
//! checked structurally:
//!
//! * the JSON parses (in-repo parser; CI re-checks with
//!   `python3 -m json.tool`) and is byte-identical across runs
//!   (deterministic ordering — the DES replay is pure virtual time);
//! * event counts derive from the replay: one `s`/`t`/`f` flow anchor
//!   and one execute span per request, one cache instant per grab, one
//!   queue-depth sample per arrival and per grab, process/thread
//!   metadata matching the fleet topology;
//! * per-board execute-span durations sum to that board's busy time,
//!   and each flow end lands exactly on the request's completion
//!   instant — the trace and the [`StreamStats`] it rode along with
//!   describe the same schedule;
//! * phase spans replay the per-`(board, shape)` [`simulate_traced`]
//!   timelines, segment for segment;
//! * the DVFS tracer emits OPP transition instants, epoch spans and
//!   per-rung residency spans that tile `[0, makespan]`, without
//!   moving a bit of the untraced [`DvfsStats`].

use amp_gemm::blis::gemm::GemmShape;
use amp_gemm::calibrate::WeightSource;
use amp_gemm::dvfs::sim::{simulate_dvfs_traced, simulate_dvfs_with, DvfsStrategy, Retune};
use amp_gemm::dvfs::{DvfsSchedule, Transition};
use amp_gemm::figures::fleet::{pinned_stream_arrivals, pinned_stream_fleet};
use amp_gemm::fleet::sim::{simulate_fleet_stream_cached, simulate_fleet_stream_traced, StreamStats};
use amp_gemm::obs::trace::validate_chrome_json;
use amp_gemm::obs::{json, MemorySink, MetricsRegistry, TraceEvent};
use amp_gemm::sim::{simulate_traced, RunCache};
use amp_gemm::soc::{ClusterId, SocSpec};

fn traced_pinned_stream() -> (Vec<TraceEvent>, MetricsRegistry, StreamStats) {
    let fleet = pinned_stream_fleet();
    let arrivals = pinned_stream_arrivals(true);
    let mut sink = MemorySink::new();
    let mut metrics = MetricsRegistry::new();
    let stats =
        simulate_fleet_stream_traced(&fleet, &arrivals, &mut RunCache::new(), &mut sink, &mut metrics);
    (sink.events, metrics, stats)
}

fn count<'a>(
    events: &'a [TraceEvent],
    pred: impl Fn(&&'a TraceEvent) -> bool,
) -> usize {
    events.iter().filter(pred).count()
}

/// The document is valid Chrome-trace JSON and byte-identical across
/// two fresh runs.
#[test]
fn pinned_stream_trace_is_deterministic_and_valid() {
    let (events_a, _, _) = traced_pinned_stream();
    let (events_b, _, _) = traced_pinned_stream();
    let doc_a = amp_gemm::obs::to_chrome_json(&events_a);
    let doc_b = amp_gemm::obs::to_chrome_json(&events_b);
    assert_eq!(doc_a, doc_b, "trace must be deterministic");
    let n = validate_chrome_json(&doc_a).expect("valid Chrome trace JSON");
    assert_eq!(n, events_a.len());
    // Spot-check the parsed shape: every event is an object carrying
    // the mandatory keys.
    let v = json::parse(&doc_a).unwrap();
    assert_eq!(v.get("displayTimeUnit").unwrap().as_str(), Some("ms"));
    for e in v.get("traceEvents").unwrap().as_arr().unwrap() {
        for key in ["name", "ph", "ts", "pid", "tid"] {
            assert!(e.get(key).is_some(), "event missing {key}: {e:?}");
        }
    }
}

/// Event counts, metadata topology, flow/completion agreement, busy
/// sums and phase-span replay on the pinned stream.
#[test]
fn pinned_stream_trace_structure_pinned() {
    let fleet = pinned_stream_fleet();
    let arrivals = pinned_stream_arrivals(true);
    let (events, metrics, stats) = traced_pinned_stream();
    let n_req = arrivals.len();
    let n_boards = fleet.num_boards();
    assert_eq!(n_req, 24);
    assert_eq!(n_boards, 2);

    // Process/thread metadata mirrors the fleet topology: one process
    // per board plus the dispatcher.
    let procs: Vec<(usize, &str)> = events
        .iter()
        .filter(|e| e.name == "process_name")
        .map(|e| match &e.args[0].1 {
            amp_gemm::obs::trace::ArgValue::Str(s) => (e.pid, s.as_str()),
            other => panic!("process_name arg {other:?}"),
        })
        .collect();
    assert_eq!(procs, vec![(0, "exynos5422"), (1, "juno_r0"), (2, "dispatcher")]);
    let expected_threads: usize = fleet
        .boards
        .iter()
        .map(|b| 1 + b.soc().clusters.len())
        .sum::<usize>()
        + 1;
    assert_eq!(count(&events, |e| e.name == "thread_name"), expected_threads);

    // Request lifecycle: one admit instant + one s/t/f anchor each.
    assert_eq!(count(&events, |e| e.name == "admit" && e.pid == n_boards), n_req);
    for ph in ['s', 't', 'f'] {
        assert_eq!(count(&events, |e| e.ph == ph), n_req, "flow anchors '{ph}'");
        let mut ids: Vec<u64> =
            events.iter().filter(|e| e.ph == ph).map(|e| e.id.unwrap()).collect();
        ids.sort_unstable();
        assert_eq!(ids, (0..n_req as u64).collect::<Vec<_>>(), "flow ids '{ph}'");
    }
    // Each flow end lands exactly on the request's completion instant.
    for e in events.iter().filter(|e| e.ph == 'f') {
        let id = e.id.unwrap() as usize;
        assert_eq!(e.ts_us, stats.completions[id] * 1e6, "flow end of request {id}");
    }

    // One execute span per request; per-board durations sum to the
    // board's busy time.
    let execs: Vec<&TraceEvent> = events.iter().filter(|e| e.cat == "execute").collect();
    assert_eq!(execs.len(), n_req);
    for (b, board) in stats.boards.iter().enumerate() {
        let sum_us: f64 =
            execs.iter().filter(|e| e.pid == b).map(|e| e.dur_us.unwrap()).sum();
        let want_us = board.busy_s * 1e6;
        assert!(
            (sum_us - want_us).abs() <= 1e-9 * want_us.max(1.0),
            "board {b}: execute spans sum to {sum_us}us, busy time is {want_us}us"
        );
    }

    // Cache instants: one per grab, split hit/miss exactly as the
    // surfaced StreamStats counters report.
    let grabs_total: u64 = stats.boards.iter().map(|b| b.grabs).sum();
    assert_eq!(count(&events, |e| e.cat == "cache"), grabs_total as usize);
    assert_eq!(count(&events, |e| e.name == "cache_miss"), stats.des_runs as usize);
    assert_eq!(count(&events, |e| e.name == "cache_hit"), stats.cache_hits as usize);

    // Queue-depth counter: one sample per arrival and per grab.
    assert_eq!(count(&events, |e| e.ph == 'C'), n_req + grabs_total as usize);

    // Phase spans replay the per-(board, shape) simulate_traced
    // timelines, segment for segment.
    for b in 0..n_boards {
        let board = &fleet.boards[b];
        let mut expected = 0usize;
        for size in [384usize, 512, 640] {
            let shape = GemmShape::square(size);
            let runs = execs
                .iter()
                .filter(|e| e.pid == b && e.name == format!("gemm {size}x{size}x{size}"))
                .count();
            if runs > 0 {
                let (_, tl) = simulate_traced(board.model(), &board.sched, shape);
                expected += runs * tl.segments.len();
            }
        }
        assert!(expected > 0, "board {b} executed nothing in the pinned stream");
        assert_eq!(
            count(&events, |e| e.cat == "phase" && e.pid == b),
            expected,
            "board {b} phase spans"
        );
    }

    // The stats the trace rode along with are the fast path's, bit for
    // bit, and the registry agrees with them.
    let untraced = simulate_fleet_stream_cached(&fleet, &arrivals, &mut RunCache::new());
    assert_eq!(stats, untraced);
    assert_eq!(metrics.counter("stream_admissions"), Some(n_req as f64));
    assert_eq!(metrics.counter("stream_completions"), Some(n_req as f64));
    assert_eq!(metrics.counter("stream_grabs"), Some(grabs_total as f64));
    assert_eq!(metrics.gauge("queue_depth_max"), Some(stats.max_queue_depth as f64));
    let sojourn = metrics.histogram("sojourn_s").expect("sojourn histogram");
    assert_eq!(sojourn.count(), n_req as u64);
    assert_eq!(sojourn.quantile(50.0), stats.sojourn_p50_s);
    assert_eq!(sojourn.quantile(99.0), stats.sojourn_p99_s);
    let service = metrics.histogram("service_time_s").expect("service histogram");
    assert_eq!(service.count(), n_req as u64);
}

/// The DVFS tracer: OPP transition instants on the cluster tracks,
/// epoch spans on tid 0, per-rung residency spans tiling
/// `[0, makespan]` per cluster — derived without perturbing the
/// untraced replay.
#[test]
fn dvfs_trace_emits_opp_instants_and_residency() {
    let soc = SocSpec::exynos5422();
    let shape = GemmShape::square(1024);
    let schedule = DvfsSchedule::new(
        soc.clusters.iter().map(|c| c.opps.nominal_idx()).collect(),
        vec![
            Transition { t_s: 0.03, cluster: ClusterId(0), opp: 0 },
            Transition { t_s: 0.06, cluster: ClusterId(1), opp: 0 },
        ],
    );
    let strat = DvfsStrategy::Sas { cache_aware: true };
    let source = WeightSource::Analytical;

    let plain = simulate_dvfs_with(&soc, strat, shape, &schedule, Retune::Online, &source);
    let mut sink = MemorySink::new();
    let mut metrics = MetricsRegistry::new();
    let traced = simulate_dvfs_traced(
        &soc,
        strat,
        shape,
        &schedule,
        Retune::Online,
        &source,
        &mut sink,
        &mut metrics,
    );
    assert_eq!(plain, traced, "tracing must not move the replay");
    let makespan = traced.time_s;
    assert!(makespan > 0.06, "fixture transitions must land inside the run");

    let events = &sink.events;
    let opp_instants: Vec<&TraceEvent> =
        events.iter().filter(|e| e.ph == 'i' && e.cat == "dvfs").collect();
    assert_eq!(opp_instants.len(), 2);
    assert_eq!(opp_instants[0].name, "opp c0->0");
    assert_eq!(opp_instants[0].tid, 1);
    assert_eq!(opp_instants[1].name, "opp c1->0");
    assert_eq!(opp_instants[1].tid, 2);

    // Epochs between the boundaries: [0, 0.03, 0.06, makespan].
    assert_eq!(count(events, |e| e.ph == 'X' && e.tid == 0), 3);

    // Residency spans tile [0, makespan] per cluster: each cluster has
    // one transition, so two spans whose durations sum to the makespan.
    for c in 0..soc.clusters.len() {
        let spans: Vec<&TraceEvent> = events
            .iter()
            .filter(|e| e.ph == 'X' && e.tid == 1 + c && e.name.starts_with("opp"))
            .collect();
        assert_eq!(spans.len(), 2, "cluster {c} residency spans");
        let sum_us: f64 = spans.iter().map(|e| e.dur_us.unwrap()).sum();
        assert!(
            (sum_us - makespan * 1e6).abs() <= 1e-6 * makespan * 1e6,
            "cluster {c}: residency {sum_us}us vs makespan {}us",
            makespan * 1e6
        );
        // The registry carries the same residency, keyed by rung.
        let total: f64 = metrics
            .counter_names()
            .filter(|n| n.starts_with(&format!("dvfs_residency_c{c}_")))
            .map(|n| metrics.counter(n).unwrap())
            .sum();
        assert!(
            (total - makespan).abs() <= 1e-9 * makespan,
            "cluster {c}: residency counters sum to {total}, makespan {makespan}"
        );
    }
    assert_eq!(
        metrics.counter("dvfs_transitions_applied"),
        Some(traced.transitions_applied as f64)
    );

    let doc = sink.to_chrome_json();
    assert_eq!(validate_chrome_json(&doc).unwrap(), events.len());
}
