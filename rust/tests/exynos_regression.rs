//! Regression guard: pins the `exynos5422()` preset to the paper's §3.2
//! hardware description and the §3.3/§3.4 calibration anchors, so the
//! N-cluster topology generalization (or any future refactor) can never
//! silently drift the reproduction. Every constant asserted here is a
//! number the paper states outright.

use amp_gemm::blis::gemm::GemmShape;
use amp_gemm::blis::params::BlisParams;
use amp_gemm::model::PerfModel;
use amp_gemm::sched::ScheduleSpec;
use amp_gemm::sim::simulate;
use amp_gemm::soc::{SocSpec, BIG, LITTLE};

/// §3.2: the Exynos 5422 hardware description, field by field.
#[test]
fn paper_section_3_2_hardware_constants() {
    let soc = SocSpec::exynos5422();
    assert_eq!(soc.num_clusters(), 2, "Exynos 5422 is big.LITTLE");
    assert_eq!(soc.total_cores(), 8);

    // Cortex-A15 cluster: 4 cores @ 1.6 GHz, 32 KiB L1d, 2 MiB L2.
    let big = &soc[BIG];
    assert_eq!(big.name, "Cortex-A15");
    assert_eq!(big.num_cores, 4);
    assert_eq!(big.core.freq_ghz, 1.6);
    assert_eq!(big.core.l1d.size_bytes, 32 * 1024);
    assert_eq!(big.core.l1d.line_bytes, 64);
    assert_eq!(big.l2.size_bytes, 2 * 1024 * 1024);
    assert_eq!(big.core.dp_flops_per_cycle, 2.0);
    assert_eq!(big.core.peak_gflops(), 3.2);

    // Cortex-A7 cluster: 4 cores @ 1.4 GHz, 32 KiB L1d, 512 KiB L2.
    let little = &soc[LITTLE];
    assert_eq!(little.name, "Cortex-A7");
    assert_eq!(little.num_cores, 4);
    assert_eq!(little.core.freq_ghz, 1.4);
    assert_eq!(little.core.l1d.size_bytes, 32 * 1024);
    assert_eq!(little.l2.size_bytes, 512 * 1024);
    assert_eq!(little.core.dp_flops_per_cycle, 0.5);
    assert_eq!(little.core.peak_gflops(), 0.7);

    // Shared DRAM.
    assert_eq!(soc.dram_bw_gbs, 3.2);
    assert_eq!(soc.dram_total_bytes, 2 * 1024 * 1024 * 1024);
}

/// §3.3: the tuned blocking parameters carried by the descriptor are
/// exactly the paper's empirically found optima.
#[test]
fn paper_section_3_3_tuned_blocking_parameters() {
    let soc = SocSpec::exynos5422();
    assert_eq!(soc[BIG].tuned, BlisParams::new(4096, 952, 152, 4, 4));
    assert_eq!(soc[LITTLE].tuned, BlisParams::new(4096, 352, 80, 4, 4));
    // §5.3 shared-kc refit: (mc, kc) = (32, 952) on the LITTLE cluster.
    assert_eq!(
        soc[LITTLE].params_shared_kc(952),
        BlisParams::new(4096, 952, 32, 4, 4)
    );
}

/// §3.4 + Fig. 5/7 anchors: the calibrated model's headline rates.
#[test]
fn paper_section_3_4_performance_anchors() {
    let m = PerfModel::exynos();
    let a15 = BlisParams::a15_opt();
    let a7 = BlisParams::a7_opt();

    let single_a15 = m.steady_rate_gflops(BIG, &a15, 1);
    assert!((2.80..3.00).contains(&single_a15), "1×A15 {single_a15}");
    let quad_a15 = m.cluster_rate_gflops(BIG, &a15, 4);
    assert!((9.2..10.0).contains(&quad_a15), "4×A15 {quad_a15}");
    let single_a7 = m.steady_rate_gflops(LITTLE, &a7, 1);
    assert!((0.55..0.63).contains(&single_a7), "1×A7 {single_a7}");
    let quad_a7 = m.cluster_rate_gflops(LITTLE, &a7, 4);
    assert!((2.2..2.5).contains(&quad_a7), "4×A7 {quad_a7}");
    // Fig. 9: the SAS knob's sweet spot.
    let ratio = m.ideal_ratio(&a15, &a15);
    assert!((4.4..5.6).contains(&ratio), "SAS ideal ratio {ratio}");
}

/// End-to-end guard: the headline simulated figures on the Exynos
/// preset. If any future topology work shifts these, the reproduction
/// has drifted even though unit-level constants may still pass.
#[test]
fn simulated_headline_figures_pinned() {
    let m = PerfModel::exynos();
    let r = GemmShape::square(4096);
    let a15 = simulate(&m, &ScheduleSpec::cluster_only(BIG, 4), r).gflops;
    let a7 = simulate(&m, &ScheduleSpec::cluster_only(LITTLE, 4), r).gflops;
    let sss = simulate(&m, &ScheduleSpec::sss(), r).gflops;
    let sas5 = simulate(&m, &ScheduleSpec::sas(5.0), r).gflops;
    let cadas = simulate(&m, &ScheduleSpec::ca_das(), r).gflops;

    assert!((8.8..10.0).contains(&a15), "A15x4 {a15}");
    assert!((2.0..2.5).contains(&a7), "A7x4 {a7}");
    assert!((0.32..0.50).contains(&(sss / a15)), "SSS fraction {}", sss / a15);
    assert!((1.10..1.30).contains(&(sas5 / a15)), "SAS(5) gain {}", sas5 / a15);
    assert!(cadas > 0.90 * (a15 + a7), "CA-DAS {cadas} vs ideal {}", a15 + a7);
}

/// The preset must stay bit-for-bit stable across calls (no hidden
/// global state, no drift between the model and the descriptor).
#[test]
fn preset_is_pure() {
    assert_eq!(SocSpec::exynos5422(), SocSpec::exynos5422());
    let a = simulate(
        &PerfModel::exynos(),
        &ScheduleSpec::ca_das(),
        GemmShape::square(1024),
    );
    let b = simulate(
        &PerfModel::exynos(),
        &ScheduleSpec::ca_das(),
        GemmShape::square(1024),
    );
    assert_eq!(a.time_s, b.time_s);
    assert_eq!(a.energy.energy_j, b.energy.energy_j);
}

/// ISSUE 3: the DVFS layer is provably a no-op at fixed frequency —
/// `exynos5422()` under the `performance` governor at the default OPP
/// reproduces the pre-DVFS pinned results bit-for-bit, and the retuned
/// weight vector degenerates to the static one exactly.
#[test]
fn dvfs_performance_governor_is_a_bit_for_bit_noop() {
    use amp_gemm::dvfs::sim::{simulate_dvfs, DvfsStrategy, Retune};
    use amp_gemm::dvfs::{DvfsSchedule, Governor, Performance};

    let soc = SocSpec::exynos5422();
    // The ladders top out at the paper's §3.2 operating point.
    assert_eq!(soc[BIG].opps.nominal().freq_ghz, 1.6);
    assert_eq!(soc[LITTLE].opps.nominal().freq_ghz, 1.4);

    let plan = Performance.plan(&soc, 1e3);
    assert!(plan.is_static(), "performance pins one rung forever");
    assert_eq!(plan, DvfsSchedule::nominal(&soc));
    // The descriptor in effect is the boot descriptor, field for field.
    assert_eq!(plan.soc_at(&soc, 0.0), soc);
    assert_eq!(plan.soc_at(&soc, 42.0), soc);

    // The retuned weights degenerate to the static vectors exactly.
    let m = PerfModel::exynos();
    for cache_aware in [false, true] {
        assert_eq!(
            plan.weights_at(&soc, 7.0, cache_aware).as_slice(),
            m.auto_weights(cache_aware).normalized().as_slice()
        );
    }

    // And the DVFS execution path returns the static DES results
    // bit-for-bit, for both retuning policies and both families.
    let shape = GemmShape::square(1024);
    let cases = [
        (
            DvfsStrategy::Sas { cache_aware: true },
            ScheduleSpec::ca_sas_weighted(m.ca_sas_weights()),
        ),
        (DvfsStrategy::Das { cache_aware: true }, ScheduleSpec::ca_das()),
    ];
    for (strat, spec) in cases {
        let direct = simulate(&m, &spec, shape);
        for retune in [Retune::Boot, Retune::Online] {
            let st = simulate_dvfs(&soc, strat, shape, &plan, retune);
            assert_eq!(st.time_s, direct.time_s, "{}", st.label);
            assert_eq!(st.gflops, direct.gflops, "{}", st.label);
            assert_eq!(st.energy_j, direct.energy.energy_j, "{}", st.label);
            assert_eq!(st.grabs, direct.grabs, "{}", st.label);
            assert_eq!(st.transitions_applied, 0);
            assert_eq!(st.retunes, 0);
        }
    }
}

/// The OPP ladders themselves are part of the pinned descriptor: any
/// drift in the Exynos frequency/voltage steps shows up here.
#[test]
fn dvfs_exynos_ladders_pinned() {
    let soc = SocSpec::exynos5422();
    let big: Vec<(f64, f64)> = (0..soc[BIG].opps.len())
        .map(|o| (soc[BIG].opps.get(o).freq_ghz, soc[BIG].opps.get(o).volt_v))
        .collect();
    assert_eq!(
        big,
        vec![(0.8, 0.9000), (1.0, 0.9500), (1.2, 1.0125), (1.4, 1.0875), (1.6, 1.1625)]
    );
    let little: Vec<(f64, f64)> = (0..soc[LITTLE].opps.len())
        .map(|o| (soc[LITTLE].opps.get(o).freq_ghz, soc[LITTLE].opps.get(o).volt_v))
        .collect();
    assert_eq!(
        little,
        vec![(0.5, 0.9000), (0.8, 0.9500), (1.0, 1.0000), (1.2, 1.0500), (1.4, 1.1375)]
    );
    // The power-scale law at the ladder ends (f·V² relative to nominal).
    let s_big = soc[BIG].opps.power_scale(0);
    assert!((s_big - 0.5 * (0.9 / 1.1625f64).powi(2)).abs() < 1e-12);
    assert_eq!(soc[BIG].opps.power_scale(4), 1.0);
}
