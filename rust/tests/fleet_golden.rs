//! Golden regression for the `figures::fleet` report text on the
//! pinned exynos5422 + juno_r0 two-board fleet (ISSUE 4 satellite):
//! the streaming table's *wave-mode* rows are reconstructed here from
//! independent `simulate_fleet_waves` runs with the format strings
//! duplicated verbatim, so a streaming-layer change that silently
//! shifts the wave-mode numbers (or their rendering) fails this test
//! rather than drifting the report. The wave engine itself is tied
//! back to the pre-streaming `simulate_fleet` numbers through the
//! burst degeneracy, closing the loop to the pinned fleet regression
//! suite.

use amp_gemm::blis::gemm::GemmShape;
use amp_gemm::coordinator::MAX_GROUP_LEN;
use amp_gemm::figures::fleet::{pinned_stream_arrivals, pinned_stream_fleet, stream_section};
use amp_gemm::fleet::sim::{burst_arrivals, simulate_fleet, simulate_fleet_waves, StreamStats};
use amp_gemm::fleet::FleetStrategy;

/// The report's row format, duplicated on purpose: if
/// `figures::fleet::stream_row` changes formatting, the golden breaks.
fn golden_row(st: &StreamStats) -> String {
    format!(
        "| {} | {:.3} | {:.2} | {:.3} | {:.3} | {:.3} | {:.2} | {} | {:.1} |",
        st.label,
        st.makespan_s,
        st.throughput_rps,
        st.utilization,
        st.sojourn_p50_s,
        st.sojourn_p99_s,
        st.mean_queue_depth,
        st.max_queue_depth,
        st.energy_j
    )
}

/// Title, header and every wave-mode row of the streaming table are
/// pinned against an independent replay of the pinned scenario.
#[test]
fn stream_report_wave_mode_text_pinned() {
    let (table, waves, stream) = stream_section(true);
    let md = table.to_markdown();

    // Structural golden: title and header are literal.
    assert!(
        md.starts_with(
            "### Streaming vs wave dispatch — exynos5422 + juno_r0, 24 staggered arrivals\n"
        ),
        "table title drifted:\n{md}"
    );
    assert!(
        md.contains(
            "| mode | makespan [s] | req/s | utilization | p50 [s] | p99 [s] | \
             mean depth | max depth | energy [J] |"
        ),
        "table header drifted:\n{md}"
    );
    assert_eq!(table.rows.len(), 4, "three wave modes + the stream");

    // Numeric golden: wave-mode rows must equal an independent replay,
    // rendered with the duplicated format strings.
    let fleet = pinned_stream_fleet();
    let arrivals = pinned_stream_arrivals(true);
    for (strategy, reported) in
        [FleetStrategy::Sss, FleetStrategy::Sas, FleetStrategy::Das].iter().zip(&waves)
    {
        let independent = simulate_fleet_waves(&fleet, *strategy, &arrivals, MAX_GROUP_LEN);
        let row = golden_row(&independent);
        assert!(
            md.contains(&row),
            "{}: wave-mode row drifted.\nexpected: {row}\nreport:\n{md}",
            independent.label
        );
        assert_eq!(reported.makespan_s, independent.makespan_s, "{}", independent.label);
        assert_eq!(reported.energy_j, independent.energy_j, "{}", independent.label);
    }
    assert!(md.contains(&golden_row(&stream)), "stream row drifted:\n{md}");

    // Rendering is deterministic: a second regeneration is identical.
    let (again, _, _) = stream_section(true);
    assert_eq!(md, again.to_markdown(), "report text must be reproducible");
}

/// Closes the loop to the pre-streaming engine: on the pinned fleet, a
/// same-shape burst replayed through the wave comparator is
/// `simulate_fleet` bit for bit — so the wave-mode numbers in the
/// report are exactly the numbers the fleet regression suite pins.
#[test]
fn wave_mode_numbers_are_the_simulate_fleet_numbers() {
    let fleet = pinned_stream_fleet();
    let shape = GemmShape::square(1024);
    for strategy in [FleetStrategy::Sss, FleetStrategy::Sas, FleetStrategy::Das] {
        let direct = simulate_fleet(&fleet, strategy, shape, 32);
        let waves =
            simulate_fleet_waves(&fleet, strategy, &burst_arrivals(shape, 32), MAX_GROUP_LEN);
        assert_eq!(waves.makespan_s, direct.makespan_s, "{}", direct.label);
        assert_eq!(waves.energy_j, direct.energy_j, "{}", direct.label);
        assert_eq!(waves.items_completed(), 32);
    }
}
