//! Property tests for the DVFS weight retuner (ISSUE 3 satellite,
//! proptest-style over 1–6 clusters and random OPP ladders):
//!
//! * retuned `sched::Weights` always sum to 1 and are finite/positive;
//! * they are monotone in a cluster's frequency — raising a cluster's
//!   GHz never lowers its share;
//! * they degenerate to the static weights when the schedule has a
//!   single OPP;
//! * the degenerate inputs (`scale(0)`, zero/NaN frequency) are clamped
//!   or rejected cleanly instead of panicking or poisoning the weights.
//!
//! ISSUE 4 satellite: the differential suite at the bottom pins the
//! `dvfs::sim` replay against a fixed-point DES run on *static*
//! schedules across all four presets (`exynos5422`, `juno_r0`,
//! `dynamiq_3c`, `pe_hybrid`) — extending the exynos-only bit-for-bit
//! pin in `tests/exynos_regression.rs` to every preset, and exercising
//! the epoch-fluid machinery at a fixed operating point via a same-rung
//! transition.

use amp_gemm::blis::gemm::GemmShape;
use amp_gemm::dvfs::sim::{simulate_dvfs, DvfsStrategy, Retune};
use amp_gemm::dvfs::{DvfsSchedule, Governor, Ondemand, Powersave, Transition};
use amp_gemm::fleet::sim::{simulate_fleet_dvfs, simulate_fleet_dvfs_cached, FleetStats};
use amp_gemm::fleet::{Fleet, FleetStrategy};
use amp_gemm::model::PerfModel;
use amp_gemm::sim::{simulate, RunCache};
use amp_gemm::soc::{ClusterId, ClusterSpec, OperatingPoint, OppTable, SocSpec};
use amp_gemm::util::prop;
use amp_gemm::util::rng::Rng;

/// A random 1–6-cluster topology: donor clusters from the presets with
/// randomized frequencies and randomized (strictly ascending) OPP
/// ladders of 1–6 rungs, the nominal rung pinned to the boot frequency.
fn random_soc(r: &mut Rng) -> SocSpec {
    let exynos = SocSpec::exynos5422();
    let tri = SocSpec::dynamiq_3c();
    let donors: Vec<ClusterSpec> = vec![
        exynos.clusters[0].clone(),
        exynos.clusters[1].clone(),
        tri.clusters[1].clone(),
    ];
    let n = r.gen_range(1, 7);
    let clusters: Vec<ClusterSpec> = (0..n)
        .map(|i| {
            let mut cl = donors[r.gen_range(0, donors.len())].clone();
            cl.name = format!("c{i}-{}", cl.name);
            cl.core.freq_ghz = r.gen_f64(0.4, 2.5);
            let rungs = r.gen_range(1, 7);
            // Strictly ascending frequency fractions ending at 1.0, with
            // a non-decreasing voltage schedule.
            let lo = r.gen_f64(0.3, 0.8);
            let points: Vec<OperatingPoint> = (0..rungs)
                .map(|k| {
                    // The nominal (last) rung must be *exactly* the boot
                    // frequency — `frac = lo + (1-lo)` is not exactly 1.0
                    // in floating point.
                    let frac = if k + 1 == rungs {
                        1.0
                    } else {
                        lo + (1.0 - lo) * k as f64 / (rungs - 1).max(1) as f64
                    };
                    let volt = 0.9 + 0.25 * k as f64 / (rungs - 1).max(1) as f64;
                    OperatingPoint::new(cl.core.freq_ghz * frac, volt)
                })
                .collect();
            cl.opps = if rungs == 1 {
                OppTable::single(cl.core.freq_ghz)
            } else {
                OppTable::new(points)
            };
            cl
        })
        .collect();
    SocSpec {
        name: format!("random-{n}c"),
        clusters,
        l3: None,
        dram_bw_gbs: 3.2,
        dram_total_bytes: 2 * 1024 * 1024 * 1024,
    }
}

/// Retuned weights always sum to 1 and stay finite and positive, at
/// random instants of random governor plans over random topologies.
#[test]
fn prop_retuned_weights_sum_to_one() {
    prop::check_default(
        |r| {
            let soc = random_soc(r);
            let period = r.gen_f64(0.05, 1.0);
            let t = r.gen_f64(0.0, 8.0);
            let cache_aware = r.gen_bool(0.5);
            (soc, period, t, cache_aware)
        },
        |(soc, period, t, cache_aware)| {
            let plan = Ondemand::new(*period).plan(soc, 1e3);
            plan.validate(soc)?;
            let w = plan.weights_at(soc, *t, *cache_aware);
            if w.len() != soc.num_clusters() {
                return Err(format!("arity {} vs {}", w.len(), soc.num_clusters()));
            }
            let sum: f64 = w.as_slice().iter().sum();
            if (sum - 1.0).abs() > 1e-9 {
                return Err(format!("weights sum to {sum}"));
            }
            if !w.as_slice().iter().all(|x| x.is_finite() && *x > 0.0) {
                return Err(format!("non-finite or non-positive share: {:?}", w.as_slice()));
            }
            Ok(())
        },
    );
}

/// Monotonicity: raising one cluster's frequency never lowers its
/// share of the retuned weight vector.
#[test]
fn prop_share_is_monotone_in_frequency() {
    prop::check_default(
        |r| {
            let soc = random_soc(r);
            let c = r.gen_range(0, soc.num_clusters());
            let boost = 1.0 + r.gen_f64(0.05, 1.5);
            let cache_aware = r.gen_bool(0.5);
            (soc, c, boost, cache_aware)
        },
        |(soc, c, boost, cache_aware)| {
            let id = ClusterId(*c);
            let before = PerfModel::new(soc.clone())
                .auto_weights(*cache_aware)
                .normalized()
                .share(*c);
            let faster = soc
                .clone()
                .try_with_cluster_freq(id, soc[id].core.freq_ghz * boost)?;
            let after = PerfModel::new(faster)
                .auto_weights(*cache_aware)
                .normalized()
                .share(*c);
            if after + 1e-12 < before {
                return Err(format!(
                    "share fell from {before} to {after} when c{c} sped up x{boost}"
                ));
            }
            // On a multi-cluster SoC the share must strictly grow.
            if soc.num_clusters() > 1 && after <= before {
                return Err(format!("share did not grow: {before} -> {after}"));
            }
            Ok(())
        },
    );
}

/// Degeneracy: a single-OPP (static) schedule retunes to exactly the
/// static weight vector, at any instant.
#[test]
fn prop_static_schedule_degenerates_to_static_weights() {
    prop::check_default(
        |r| {
            let soc = random_soc(r);
            let t = r.gen_f64(0.0, 100.0);
            let cache_aware = r.gen_bool(0.5);
            (soc, t, cache_aware)
        },
        |(soc, t, cache_aware)| {
            let plan = DvfsSchedule::nominal(soc);
            if !plan.is_static() {
                return Err("nominal plan must be static".into());
            }
            let retuned = plan.weights_at(soc, *t, *cache_aware);
            let statics = PerfModel::new(soc.clone())
                .auto_weights(*cache_aware)
                .normalized();
            if retuned.as_slice() != statics.as_slice() {
                return Err(format!(
                    "retuned {:?} != static {:?}",
                    retuned.as_slice(),
                    statics.as_slice()
                ));
            }
            Ok(())
        },
    );
}

/// Degenerate inputs stay clean: `scale(0)` clamps, zero/negative/NaN
/// frequencies are rejected with an `Err`, and the weights derived from
/// any valid random descriptor never contain NaN.
#[test]
fn prop_degenerate_inputs_never_poison_weights() {
    prop::check_default(
        |r| {
            let soc = random_soc(r);
            let c = r.gen_range(0, soc.num_clusters());
            (soc, c)
        },
        |(soc, c)| {
            let id = ClusterId(*c);
            // scale(0) clamps to the single-core entry.
            let s0 = soc[id].tuning.scale(0);
            if !(s0.is_finite() && s0 > 0.0 && s0 == soc[id].tuning.scale(1)) {
                return Err(format!("scale(0) = {s0} must clamp to scale(1)"));
            }
            // Invalid frequencies error instead of panicking.
            for bad in [0.0, -2.0, f64::NAN, f64::INFINITY] {
                if soc.clone().try_with_cluster_freq(id, bad).is_ok() {
                    return Err(format!("frequency {bad} must be rejected"));
                }
            }
            Ok(())
        },
    );
}

fn all_presets() -> Vec<SocSpec> {
    vec![
        SocSpec::exynos5422(),
        SocSpec::juno_r0(),
        SocSpec::dynamiq_3c(),
        SocSpec::pe_hybrid(),
    ]
}

fn nominal_rungs(soc: &SocSpec) -> Vec<usize> {
    soc.clusters.iter().map(|c| c.opps.nominal_idx()).collect()
}

/// Differential, part 1 — *static* schedules delegate to the DES
/// exactly, on every preset: both the nominal pin (boot descriptor)
/// and the powersave pin (bottom rungs), for SAS and DAS families,
/// must reproduce a direct fixed-point DES run bit for bit.
#[test]
fn static_schedules_match_fixed_point_des_on_every_preset() {
    for soc in all_presets() {
        let plans = [DvfsSchedule::nominal(&soc), Powersave.plan(&soc, 10.0)];
        for plan in &plans {
            assert!(plan.is_static());
            plan.validate(&soc).unwrap();
            let model = PerfModel::new(plan.soc_at(&soc, 0.0));
            let shape = GemmShape::square(1024);
            for strat in [
                DvfsStrategy::Sas { cache_aware: true },
                DvfsStrategy::Das { cache_aware: true },
            ] {
                let direct = simulate(&model, &strat.to_spec(&model), shape);
                for retune in [Retune::Boot, Retune::Online] {
                    let st = simulate_dvfs(&soc, strat, shape, plan, retune);
                    assert_eq!(st.time_s, direct.time_s, "{}: {}", soc.name, st.label);
                    assert_eq!(st.gflops, direct.gflops, "{}: {}", soc.name, st.label);
                    assert_eq!(
                        st.energy_j, direct.energy.energy_j,
                        "{}: {}",
                        soc.name, st.label
                    );
                    assert_eq!(st.grabs, direct.grabs, "{}: {}", soc.name, st.label);
                    assert_eq!(st.transitions_applied, 0);
                    assert_eq!(st.retunes, 0);
                }
            }
        }
    }
}

/// Differential, part 2 — the *epoch-fluid* replay at a fixed point:
/// a same-rung transition forces the fluid machinery to run while the
/// operating point never actually changes, so its calibrated rates
/// must reproduce the fixed-point DES makespan — tightly for the SAS
/// fluid drain (the calibration makes every cluster finish at the DES
/// instant), within quantization for the chunk-grained DAS drain.
#[test]
fn forced_epoch_fluid_matches_fixed_point_des_on_every_preset() {
    for soc in all_presets() {
        let rungs = nominal_rungs(&soc);
        // A "transition" to the rung already in effect: epochs split at
        // t = 1 ms, rates identical on both sides.
        let plan = DvfsSchedule::new(
            rungs.clone(),
            vec![Transition { t_s: 1e-3, cluster: ClusterId(0), opp: rungs[0] }],
        );
        assert!(!plan.is_static(), "the same-rung transition must force the fluid path");
        plan.validate(&soc).unwrap();
        let model = PerfModel::new(soc.clone());
        // Large enough that one dynamic chunk (the slow cluster's `mc`
        // rows) is a small fraction of the makespan — the fluid and DES
        // drains may disagree by up to a chunk at the queue's end.
        let shape = GemmShape::square(2048);

        let sas = DvfsStrategy::Sas { cache_aware: true };
        let direct_sas = simulate(&model, &sas.to_spec(&model), shape);
        let fluid_sas = simulate_dvfs(&soc, sas, shape, &plan, Retune::Boot);
        let rel = (fluid_sas.time_s / direct_sas.time_s - 1.0).abs();
        assert!(
            rel < 1e-6,
            "{}: fluid SAS {} s vs DES {} s (rel {rel:e})",
            soc.name,
            fluid_sas.time_s,
            direct_sas.time_s
        );
        assert_eq!(fluid_sas.transitions_applied, 1, "{}", soc.name);
        let share_sum: f64 = fluid_sas.cluster_share.iter().sum();
        assert!((share_sum - 1.0).abs() < 1e-9, "{}: shares {share_sum}", soc.name);

        let das = DvfsStrategy::Das { cache_aware: true };
        let direct_das = simulate(&model, &das.to_spec(&model), shape);
        let fluid_das = simulate_dvfs(&soc, das, shape, &plan, Retune::Boot);
        let rel = (fluid_das.time_s / direct_das.time_s - 1.0).abs();
        assert!(
            rel < 0.30,
            "{}: fluid DAS {} s vs DES {} s (rel {rel:.3})",
            soc.name,
            fluid_das.time_s,
            direct_das.time_s
        );
        let share_sum: f64 = fluid_das.cluster_share.iter().sum();
        assert!((share_sum - 1.0).abs() < 1e-9, "{}: shares {share_sum}", soc.name);
        assert!(fluid_das.grabs > 0);
        // Energy stays in the same regime (loose sanity, both models
        // charge busy/poll rails plus DRAM).
        for (fluid, direct) in [
            (fluid_sas.energy_j, direct_sas.energy.energy_j),
            (fluid_das.energy_j, direct_das.energy.energy_j),
        ] {
            assert!(
                fluid.is_finite() && fluid > 0.0 && (fluid / direct - 1.0).abs() < 0.40,
                "{}: fluid energy {fluid} J vs DES {direct} J",
                soc.name
            );
        }
    }
}

/// ISSUE 6 satellite: the DVFS fleet replay prices bit for bit through
/// a shared [`RunCache`] under random OPP rung vectors — random initial
/// rungs plus random in-flight transitions on random preset fleets, for
/// every strategy. A warm replay executes zero DES runs: the cache keys
/// on the *derived* at-OPP descriptor, so the rung vector is part of
/// the fingerprint.
#[test]
fn prop_dvfs_cached_replays_match_fresh_bit_for_bit() {
    let presets = ["exynos5422", "juno_r0", "dynamiq_3c", "pe_hybrid"];
    let same_fleet = |tag: &str, a: &FleetStats, b: &FleetStats| -> Result<(), String> {
        if a.makespan_s != b.makespan_s
            || a.gflops != b.gflops
            || a.throughput_rps != b.throughput_rps
            || a.energy_j != b.energy_j
            || a.gflops_per_watt != b.gflops_per_watt
        {
            return Err(format!("{tag}: aggregate fleet stats diverge"));
        }
        for (x, y) in a.boards.iter().zip(&b.boards) {
            if x.items != y.items
                || x.grabs != y.grabs
                || x.busy_s != y.busy_s
                || x.finish_s != y.finish_s
                || x.energy_j != y.energy_j
            {
                return Err(format!("{tag}: board {} diverges", x.name));
            }
        }
        Ok(())
    };
    prop::check(
        &prop::Config { cases: 12, seed: 0xD1F5 },
        |r| {
            let n = r.gen_range(1, 5); // 1..=4 boards
            let toks: Vec<&str> = (0..n).map(|_| *r.choose(&presets)).collect();
            let size = *r.choose(&[128usize, 192, 256]);
            let batch = r.gen_range(1, 13);
            let strategy =
                *r.choose(&[FleetStrategy::Sss, FleetStrategy::Sas, FleetStrategy::Das]);
            (toks.join(","), size, batch, r.next_u64(), strategy)
        },
        |(list, size, batch, plan_seed, strategy)| {
            let (strategy, batch) = (*strategy, *batch);
            let fleet = Fleet::parse(list).map_err(|e| e.to_string())?;
            let mut pr = Rng::new(*plan_seed);
            let plans: Vec<DvfsSchedule> = fleet
                .boards
                .iter()
                .map(|bd| {
                    let soc = bd.soc();
                    let initial: Vec<usize> = soc
                        .clusters
                        .iter()
                        .map(|c| pr.gen_range(0, c.opps.len()))
                        .collect();
                    let transitions: Vec<Transition> = (0..pr.gen_range(0, 4))
                        .map(|_| {
                            let c = pr.gen_range(0, soc.num_clusters());
                            Transition {
                                t_s: pr.gen_f64(0.0, 0.05),
                                cluster: ClusterId(c),
                                opp: pr.gen_range(0, soc.clusters[c].opps.len()),
                            }
                        })
                        .collect();
                    DvfsSchedule::new(initial, transitions)
                })
                .collect();
            let shape = GemmShape::square(*size);
            let fresh = simulate_fleet_dvfs(&fleet, strategy, shape, batch, &plans);
            let mut cache = RunCache::new();
            let cold =
                simulate_fleet_dvfs_cached(&fleet, strategy, shape, batch, &plans, &mut cache);
            same_fleet("cold", &fresh, &cold)?;
            let warm =
                simulate_fleet_dvfs_cached(&fleet, strategy, shape, batch, &plans, &mut cache);
            if warm.des_runs != 0 {
                return Err(format!("warm replay ran {} DES runs", warm.des_runs));
            }
            same_fleet("warm", &fresh, &warm)?;
            Ok(())
        },
    );
}

/// A hand-written multi-rung schedule over a random topology keeps
/// `opp_at` consistent with its transition list (the replay contract
/// the engine and the fleet simulator both rely on).
#[test]
fn prop_opp_at_replays_transitions_in_order() {
    prop::check_default(
        |r| {
            let soc = random_soc(r);
            let c = r.gen_range(0, soc.num_clusters());
            let t1 = r.gen_f64(0.1, 2.0);
            let dt = r.gen_f64(0.1, 2.0);
            (soc, c, t1, dt)
        },
        |(soc, c, t1, dt)| {
            let id = ClusterId(*c);
            let top = soc[id].opps.len() - 1;
            let initial: Vec<usize> = soc.clusters.iter().map(|_| 0).collect();
            let plan = DvfsSchedule::new(
                initial,
                vec![
                    Transition { t_s: *t1 + *dt, cluster: id, opp: 0 },
                    Transition { t_s: *t1, cluster: id, opp: top },
                ],
            );
            plan.validate(soc)?;
            if plan.opp_at(id, 0.0) != 0 {
                return Err("initial rung must hold before the first transition".into());
            }
            if plan.opp_at(id, *t1 + 0.5 * *dt) != top {
                return Err("first transition must be in effect mid-window".into());
            }
            if plan.opp_at(id, *t1 + *dt + 1.0) != 0 {
                return Err("second transition must win after it fires".into());
            }
            Ok(())
        },
    );
}
