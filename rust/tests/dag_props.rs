//! Property tests for the task-DAG runtime (ISSUE 10, proptest-style
//! over `util::rng`):
//!
//! * graph structure: random blocked Cholesky/LU graphs validate, and
//!   both scheduling policies execute every task exactly once in an
//!   order that respects every dependency edge;
//! * replay determinism: over randomized 1–4-cluster descriptors, a
//!   schedule replays bit for bit (order, makespan, energy rails);
//! * the ISSUE acceptance pin: on the exynos5422, the
//!   criticality-aware policy (critical path to the big cluster at its
//!   tuned `(mc, kc)`, trailing updates split by the weight vector)
//!   strictly beats the cluster-oblivious round-robin comparator;
//! * the numeric executor logs the graph's own topological id order —
//!   scheduling policy changes never reorder the in-place algebra.

use amp_gemm::blis::gemm::GemmShape;
use amp_gemm::calibrate::{ShapeClass, WeightSource};
use amp_gemm::dag::{schedule, tile_costs, DagPolicy, FactorKind, TaskGraph};
use amp_gemm::model::PerfModel;
use amp_gemm::sim::RunCache;
use amp_gemm::soc::{ClusterSpec, OppTable, SocSpec};
use amp_gemm::util::prop;
use amp_gemm::util::rng::Rng;

/// A random 1–4-cluster topology (single-rung ladders — the DAG layer
/// schedules at nominal frequency), donor clusters from the presets
/// with randomized frequencies: the `live_props`/`dvfs_props`
/// generator bounded to what `tile_costs` consumes.
fn random_soc(r: &mut Rng, max_clusters: usize) -> SocSpec {
    let exynos = SocSpec::exynos5422();
    let tri = SocSpec::dynamiq_3c();
    let donors: Vec<ClusterSpec> = vec![
        exynos.clusters[0].clone(),
        exynos.clusters[1].clone(),
        tri.clusters[1].clone(),
    ];
    let n = r.gen_range(1, max_clusters + 1);
    let clusters: Vec<ClusterSpec> = (0..n)
        .map(|i| {
            let mut cl = donors[r.gen_range(0, donors.len())].clone();
            cl.name = format!("c{i}-{}", cl.name);
            cl.core.freq_ghz = r.gen_f64(0.4, 2.5);
            cl.opps = OppTable::single(cl.core.freq_ghz);
            cl
        })
        .collect();
    SocSpec {
        name: format!("random-{n}c"),
        clusters,
        l3: None,
        dram_bw_gbs: 3.2,
        dram_total_bytes: 2 * 1024 * 1024 * 1024,
    }
}

/// A random factorization descriptor: kind, tile grid of 2–6 tiles,
/// tile size from the small-search grid.
fn random_factor(r: &mut Rng) -> (FactorKind, usize, usize) {
    let kind = *r.choose(&[FactorKind::Cholesky, FactorKind::Lu]);
    let nb = *r.choose(&[64usize, 96, 128]);
    let nt = r.gen_range(2, 7);
    (kind, nt * nb, nb)
}

/// Both policies place every task exactly once, never before one of
/// its dependencies, and never beat the critical-path bound — on
/// random graphs over random descriptors.
#[test]
fn prop_schedules_respect_dependencies_exactly_once() {
    prop::check(
        &prop::Config { cases: 24, seed: 0xDA6_001 },
        |r| {
            let soc = random_soc(r, 4);
            let (kind, n, nb) = random_factor(r);
            (soc, kind, n, nb)
        },
        |(soc, kind, n, nb)| {
            let graph = TaskGraph::build(*kind, *n, *nb);
            graph.validate()?;
            let model = PerfModel::new(soc.clone());
            let mut cache = RunCache::new();
            let costs = tile_costs(&model, *nb, &mut cache);
            let class = ShapeClass::for_soc(&model.soc, GemmShape::square(*nb));
            let w = WeightSource::Analytical.weights(&model, true, class);
            for policy in [DagPolicy::CriticalityAware, DagPolicy::Oblivious] {
                let s = schedule(&graph, &costs, &w, policy);
                if s.order.len() != graph.num_tasks() {
                    return Err(format!(
                        "{}: {} placements for {} tasks",
                        policy.label(),
                        s.order.len(),
                        graph.num_tasks()
                    ));
                }
                let mut finish = vec![f64::NAN; graph.num_tasks()];
                for st in &s.order {
                    if !finish[st.task].is_nan() {
                        return Err(format!("{}: task {} placed twice", policy.label(), st.task));
                    }
                    for &d in &graph.tasks[st.task].deps {
                        if finish[d].is_nan() {
                            return Err(format!(
                                "{}: task {} dispatched before dep {d}",
                                policy.label(),
                                st.task
                            ));
                        }
                        if st.start_s < finish[d] - 1e-12 {
                            return Err(format!(
                                "{}: task {} starts before dep {d} finishes",
                                policy.label(),
                                st.task
                            ));
                        }
                    }
                    finish[st.task] = st.finish_s;
                }
                if s.makespan_s < s.critical_path_s - 1e-12 {
                    return Err(format!(
                        "{}: makespan {} beats the critical-path bound {}",
                        policy.label(),
                        s.makespan_s,
                        s.critical_path_s
                    ));
                }
                let busy: f64 = s.busy_s.iter().sum();
                if !(s.makespan_s > 0.0 && s.energy_j > 0.0 && busy > 0.0) {
                    return Err(format!("{}: degenerate schedule totals", policy.label()));
                }
                let rails: f64 = s.energy_clusters_j.iter().sum();
                if (rails - s.energy_j).abs() > 1e-9 * s.energy_j.max(1.0) {
                    return Err(format!(
                        "{}: energy rails {} do not sum to {}",
                        policy.label(),
                        rails,
                        s.energy_j
                    ));
                }
            }
            Ok(())
        },
    );
}

/// Replay determinism across randomized 1–4-cluster descriptors: the
/// whole pipeline — tile costing through a fresh cache, critical-path
/// analysis, placement — replays bit for bit, both policies.
#[test]
fn prop_schedules_replay_bit_for_bit() {
    prop::check(
        &prop::Config { cases: 24, seed: 0xDA6_002 },
        |r| {
            let soc = random_soc(r, 4);
            let (kind, n, nb) = random_factor(r);
            (soc, kind, n, nb)
        },
        |(soc, kind, n, nb)| {
            let graph = TaskGraph::build(*kind, *n, *nb);
            let model = PerfModel::new(soc.clone());
            let class = ShapeClass::for_soc(&model.soc, GemmShape::square(*nb));
            let w = WeightSource::Analytical.weights(&model, true, class);
            for policy in [DagPolicy::CriticalityAware, DagPolicy::Oblivious] {
                let mut c1 = RunCache::new();
                let a = schedule(&graph, &tile_costs(&model, *nb, &mut c1), &w, policy);
                let mut c2 = RunCache::new();
                let b = schedule(&graph, &tile_costs(&model, *nb, &mut c2), &w, policy);
                if a != b {
                    return Err(format!("{}: schedule replay diverged", policy.label()));
                }
            }
            Ok(())
        },
    );
}

/// The ISSUE 10 acceptance pin: criticality-awareness strictly beats
/// the cluster-oblivious comparator on the exynos5422, for both
/// factorizations at the pinned descriptor — and the critical tasks
/// all land on the big cluster (cluster 0 is fastest on this SoC).
#[test]
fn critical_path_to_big_beats_oblivious_on_exynos() {
    let model = PerfModel::new(SocSpec::exynos5422());
    let mut cache = RunCache::new();
    let costs = tile_costs(&model, 128, &mut cache);
    assert_eq!(costs.fastest(), 0, "the A15 cluster prices fastest");
    let class = ShapeClass::for_soc(&model.soc, GemmShape::square(128));
    let w = WeightSource::Analytical.weights(&model, true, class);
    for kind in [FactorKind::Cholesky, FactorKind::Lu] {
        let graph = TaskGraph::build(kind, 1024, 128);
        let ca = schedule(&graph, &costs, &w, DagPolicy::CriticalityAware);
        let obl = schedule(&graph, &costs, &w, DagPolicy::Oblivious);
        assert!(
            ca.makespan_s < obl.makespan_s,
            "{}: CA {} vs oblivious {}",
            kind.label(),
            ca.makespan_s,
            obl.makespan_s
        );
        assert!(ca.critical_tasks > 0, "{}: no critical tasks found", kind.label());
        // Every task the policy deemed critical ran on the fast cluster.
        let order = &ca.order;
        let fast_tasks = order.iter().filter(|t| t.cluster.0 == 0).count();
        assert!(
            fast_tasks >= ca.critical_tasks,
            "{}: {} fast-cluster placements for {} critical tasks",
            kind.label(),
            fast_tasks,
            ca.critical_tasks
        );
    }
    // Cholesky specifically must clear the 5% figure-level bar.
    let graph = TaskGraph::cholesky(1024, 128);
    let ca = schedule(&graph, &costs, &w, DagPolicy::CriticalityAware);
    let obl = schedule(&graph, &costs, &w, DagPolicy::Oblivious);
    assert!(
        ca.makespan_s * 1.05 <= obl.makespan_s,
        "CA {} vs oblivious {} — under the 5% acceptance bar",
        ca.makespan_s,
        obl.makespan_s
    );
}

/// The numeric executor runs tasks in the graph's own id order
/// (topological by construction) — exactly once, every task, so the
/// in-place tile algebra is schedule-independent.
#[test]
fn executor_log_is_the_topological_id_order() {
    let soc = SocSpec::exynos5422();
    let spec = amp_gemm::sched::ScheduleSpec::ca_das();
    let n = 128;
    let mut rng = Rng::new(0xDA6_E7E);
    let mut a = rng.fill_matrix(n * n);
    for i in 0..n {
        for j in 0..i {
            let avg = 0.5 * (a[i * n + j] + a[j * n + i]);
            a[i * n + j] = avg;
            a[j * n + i] = avg;
        }
        a[i * n + i] = a[i * n + i].abs() + n as f64;
    }
    let log = amp_gemm::dag::exec::cholesky(&soc, &spec, n, 32, &mut a);
    let graph = TaskGraph::cholesky(n, 32);
    assert_eq!(log.executed.len(), graph.num_tasks());
    assert!(log.executed.iter().enumerate().all(|(i, &t)| i == t));
}
