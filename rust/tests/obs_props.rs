//! Property tests for the observability layer (ISSUE 7 satellite):
//! over random fleets and random Poisson-ish streams from `util::rng`,
//!
//! * trace spans on any one `(pid, tid)` track never overlap — the
//!   Perfetto rendering invariant (a track is a timeline of disjoint
//!   slices);
//! * request flows conserve: every admitted request id carries exactly
//!   one flow start (`s`), one step (`t`) and one end (`f`), in
//!   non-decreasing virtual time;
//! * [`Histogram::merge`] equals pooled observation — bit-for-bit
//!   quantiles with retained samples, and bit-for-bit bucket quantiles
//!   without (bucket counts are integers, so sharded merge cannot
//!   drift);
//! * the zero-overhead-when-off contract: a [`MemorySink`] + enabled
//!   registry run returns `StreamStats` bit-for-bit equal to the
//!   [`NullSink`] + disabled-registry fast path (`PartialEq` compares
//!   every field, completions vector included), and `simulate_traced`
//!   returns `RunStats` bit-for-bit equal to `simulate`.

use amp_gemm::blis::gemm::GemmShape;
use amp_gemm::fleet::sim::{
    poisson_arrivals, simulate_fleet_stream_cached, simulate_fleet_stream_traced, Arrival,
};
use amp_gemm::fleet::Fleet;
use amp_gemm::obs::{Histogram, MemorySink, MetricsRegistry, TraceEvent};
use amp_gemm::sim::{simulate, simulate_traced, RunCache};
use amp_gemm::util::prop;
use amp_gemm::util::rng::Rng;
use amp_gemm::{prop_assert, prop_assert_eq};

const PRESETS: [&str; 4] = ["exynos5422", "juno_r0", "dynamiq_3c", "symmetric2"];
const SIZES: [usize; 4] = [96, 128, 192, 256];

/// A random fleet of 1–3 boards and a random mixed-shape stream.
fn random_stream(r: &mut Rng) -> (String, Vec<Arrival>) {
    let n = r.gen_range(1, 4);
    let toks: Vec<&str> = (0..n).map(|_| *r.choose(&PRESETS)).collect();
    let shapes: Vec<GemmShape> = (0..r.gen_range(1, 4))
        .map(|_| GemmShape::square(*r.choose(&SIZES)))
        .collect();
    let count = r.gen_range(1, 20);
    let rate = r.gen_f64(20.0, 200.0);
    let mut arr_rng = Rng::new(r.next_u64());
    (toks.join(","), poisson_arrivals(&mut arr_rng, &shapes, count, rate))
}

fn traced_run(list: &str, arrivals: &[Arrival]) -> Result<(Vec<TraceEvent>, MetricsRegistry), String> {
    let fleet = Fleet::parse(list).map_err(|e| e.to_string())?;
    let mut sink = MemorySink::new();
    let mut metrics = MetricsRegistry::new();
    simulate_fleet_stream_traced(&fleet, arrivals, &mut RunCache::new(), &mut sink, &mut metrics);
    Ok((sink.events, metrics))
}

/// Spans on one `(pid, tid)` track are pairwise disjoint. The slack
/// covers the float noise between `offset + j·t + t` and
/// `offset + (j+1)·t` plus the 1e-9 s tolerance `Timeline::validate`
/// itself grants adjacent phase segments.
#[test]
fn prop_track_spans_never_overlap() {
    prop::check(
        &prop::Config { cases: 48, seed: 0x0B5_1 },
        random_stream,
        |(list, arrivals)| {
            let (events, _) = traced_run(list, arrivals)?;
            let mut tracks: std::collections::BTreeMap<(usize, usize), Vec<(f64, f64)>> =
                std::collections::BTreeMap::new();
            for e in &events {
                if e.ph == 'X' {
                    let dur = e.dur_us.ok_or("X event without dur")?;
                    prop_assert!(dur >= 0.0, "negative span duration {dur}");
                    tracks.entry((e.pid, e.tid)).or_default().push((e.ts_us, dur));
                }
            }
            prop_assert!(!tracks.is_empty(), "traced run recorded no spans");
            for ((pid, tid), spans) in &mut tracks {
                spans.sort_by(|a, b| a.0.total_cmp(&b.0));
                for w in spans.windows(2) {
                    let (t0, d0) = w[0];
                    let (t1, _) = w[1];
                    let end = t0 + d0;
                    let slack = 1e-2 + 1e-9 * end.abs();
                    prop_assert!(
                        t1 >= end - slack,
                        "track ({pid},{tid}): span at {t1}us overlaps previous end {end}us"
                    );
                }
            }
            Ok(())
        },
    );
}

/// Flow conservation: each admitted request id has exactly one
/// `s`/`t`/`f` anchor, ordered admit ≤ dispatch ≤ complete.
#[test]
fn prop_request_flows_conserve_exactly_once() {
    prop::check(
        &prop::Config { cases: 48, seed: 0x0B5_2 },
        random_stream,
        |(list, arrivals)| {
            let (events, metrics) = traced_run(list, arrivals)?;
            prop_assert_eq!(
                metrics.counter("stream_admissions"),
                Some(arrivals.len() as f64)
            );
            for id in 0..arrivals.len() as u64 {
                let mut anchors: Vec<(char, f64)> = events
                    .iter()
                    .filter(|e| e.id == Some(id) && matches!(e.ph, 's' | 't' | 'f'))
                    .map(|e| (e.ph, e.ts_us))
                    .collect();
                anchors.sort_by(|a, b| a.1.total_cmp(&b.1).then(a.0.cmp(&b.0)));
                let phases: String = anchors.iter().map(|a| a.0).collect();
                prop_assert!(
                    phases == "stf" || phases == "sft",
                    "request {id}: flow anchors are {phases:?}, want one each of s/t/f"
                );
                // s (admit) precedes t (dispatch) precedes f (complete).
                let ts = |ph: char| anchors.iter().find(|a| a.0 == ph).unwrap().1;
                prop_assert!(
                    ts('s') <= ts('t') && ts('t') <= ts('f'),
                    "request {id}: flow anchors out of order"
                );
            }
            Ok(())
        },
    );
}

/// Merged shards equal pooled observation: exact quantiles with
/// retained samples (same sorted multiset), exact bucket quantiles
/// without (integer bucket counts, exact min/max of maxima).
#[test]
fn prop_histogram_merge_equals_pooled() {
    prop::check_default(
        |r| {
            let n = r.gen_range(1, 40);
            let xs: Vec<f64> = (0..n)
                .map(|_| 10f64.powf(r.gen_f64(-6.0, 6.0)) * r.gen_f64(0.5, 1.5))
                .collect();
            let split = r.gen_range(0, n + 1);
            (xs, split)
        },
        |(xs, split)| {
            for sampled in [true, false] {
                let fresh = || if sampled { Histogram::with_samples() } else { Histogram::new() };
                let mut pooled = fresh();
                let (mut left, mut right) = (fresh(), fresh());
                for (i, &x) in xs.iter().enumerate() {
                    pooled.observe(x);
                    if i < *split {
                        left.observe(x);
                    } else {
                        right.observe(x);
                    }
                }
                let mut merged = left.clone();
                merged.merge(&right);
                prop_assert_eq!(merged.count(), pooled.count());
                prop_assert_eq!(merged.min(), pooled.min());
                prop_assert_eq!(merged.max(), pooled.max());
                for p in [0.0, 10.0, 25.0, 50.0, 75.0, 90.0, 99.0, 100.0] {
                    let (m, q) = (merged.quantile(p), pooled.quantile(p));
                    prop_assert!(
                        m == q,
                        "sampled={sampled} p{p}: merged {m} != pooled {q}"
                    );
                }
            }
            Ok(())
        },
    );
}

/// The zero-overhead-when-off contract, stated as bit-for-bit equality:
/// attaching a live sink + registry must not move a single bit of the
/// returned statistics relative to the `NullSink` fast path.
#[test]
fn prop_traced_stream_stats_match_fast_path_bit_for_bit() {
    prop::check(
        &prop::Config { cases: 48, seed: 0x0B5_4 },
        random_stream,
        |(list, arrivals)| {
            let fleet = Fleet::parse(list).map_err(|e| e.to_string())?;
            let mut cache_off = RunCache::new();
            let off = simulate_fleet_stream_cached(&fleet, arrivals, &mut cache_off);
            let mut cache_on = RunCache::new();
            let mut sink = MemorySink::new();
            let mut metrics = MetricsRegistry::new();
            let on = simulate_fleet_stream_traced(
                &fleet,
                arrivals,
                &mut cache_on,
                &mut sink,
                &mut metrics,
            );
            prop_assert_eq!(off, on);
            // The replay's own cache is untouched by trace bookkeeping
            // (phase timelines come from a side `simulate_traced`).
            prop_assert_eq!(cache_off.hits(), cache_on.hits());
            prop_assert_eq!(cache_off.misses(), cache_on.misses());
            prop_assert_eq!(cache_off.cached_runs(), cache_on.cached_runs());
            Ok(())
        },
    );
}

/// `simulate_traced` vs `simulate`: the per-run half of the same
/// contract (already relied on by the stream's phase tracks).
#[test]
fn prop_traced_run_stats_match_untraced_bit_for_bit() {
    prop::check(
        &prop::Config { cases: 32, seed: 0x0B5_5 },
        |r| (String::from(*r.choose(&PRESETS)), *r.choose(&SIZES)),
        |(preset, size)| {
            let fleet = Fleet::parse(preset).map_err(|e| e.to_string())?;
            let board = &fleet.boards[0];
            let shape = GemmShape::square(*size);
            let plain = simulate(board.model(), &board.sched, shape);
            let (traced, timeline) = simulate_traced(board.model(), &board.sched, shape);
            prop_assert_eq!(plain, traced);
            timeline.validate()?;
            Ok(())
        },
    );
}
