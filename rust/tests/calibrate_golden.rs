//! Golden regression for the calibration layer (ISSUE 5).
//!
//! The load-bearing guarantee: a `RateTable` synthesized *from* the
//! analytical model, consumed through `WeightSource::Empirical`, yields
//! `sched::Weights` — and whole scheduled runs — bit-for-bit identical
//! to the analytical path on every preset. That anchor is what lets
//! the empirical plumbing thread through sched/dvfs/fleet without
//! perturbing a single existing regression: `Empirical` differs from
//! `Analytical` only by what was measured.
//!
//! Plus the persistence fuzz the ISSUE asks for: random rate tables
//! and preset stores must round-trip through TSV bit for bit, and
//! malformed inputs must be rejected, beyond the three cases pinned in
//! `rust/src/search/mod.rs`. ISSUE 9 extends the same suite to
//! [`LiveRateTable`] rows — EWMA numerator/denominator pairs,
//! sample counts and the half-life header field included.

use amp_gemm::blis::gemm::GemmShape;
use amp_gemm::calibrate::live::LiveRateTable;
use amp_gemm::calibrate::{
    ca_sas_spec, sas_spec, Family, RateRow, RateTable, ShapeClass, WeightSource,
};
use amp_gemm::dvfs::sim::{simulate_dvfs, simulate_dvfs_with, DvfsStrategy, Retune};
use amp_gemm::dvfs::{Governor, Ondemand};
use amp_gemm::model::PerfModel;
use amp_gemm::search::{OppPreset, OppPresetStore};
use amp_gemm::sim::simulate;
use amp_gemm::soc::{ClusterId, SocSpec};
use amp_gemm::util::prop;
use amp_gemm::util::rng::Rng;

fn presets() -> [SocSpec; 4] {
    [
        SocSpec::exynos5422(),
        SocSpec::juno_r0(),
        SocSpec::dynamiq_3c(),
        SocSpec::pe_hybrid(),
    ]
}

/// Acceptance criterion: the analytical-degeneracy anchor. On all four
/// presets, for both families and every shape class, the synthesized
/// table reproduces today's weight vectors bit for bit — and the specs
/// built from them are (PartialEq-) identical, so every downstream DES
/// run is too.
#[test]
fn analytical_degeneracy_anchor_bit_for_bit() {
    for soc in presets() {
        let model = PerfModel::new(soc.clone());
        let source = WeightSource::Empirical(RateTable::from_analytical(&soc));
        for cache_aware in [true, false] {
            for class in ShapeClass::ALL {
                assert_eq!(
                    source.weights(&model, cache_aware, class),
                    model.auto_weights(cache_aware),
                    "{}: ca={cache_aware} class={}",
                    soc.name,
                    class.label()
                );
            }
        }
        // Spec-level identity (what schedulers actually consume).
        assert_eq!(
            ca_sas_spec(&source, &model, ShapeClass::Large),
            amp_gemm::sched::ScheduleSpec::ca_sas_weighted(model.ca_sas_weights()),
            "{}",
            soc.name
        );
        assert_eq!(
            sas_spec(&source, &model, ShapeClass::Large),
            amp_gemm::sched::ScheduleSpec::sas_weighted(model.sas_weights()),
            "{}",
            soc.name
        );
        // And a full DES run through the empirically sourced spec is
        // the analytical run, exactly.
        let shape = GemmShape::square(768);
        let ana = simulate(
            &model,
            &amp_gemm::sched::ScheduleSpec::ca_sas_weighted(model.ca_sas_weights()),
            shape,
        );
        let emp = simulate(&model, &ca_sas_spec(&source, &model, ShapeClass::Small), shape);
        assert_eq!(ana.time_s, emp.time_s, "{}", soc.name);
        assert_eq!(ana.gflops, emp.gflops, "{}", soc.name);
        assert_eq!(ana.energy.energy_j, emp.energy.energy_j, "{}", soc.name);
    }
}

/// The DVFS online-retune path under a synthesized table replays bit
/// for bit on every preset — per-OPP lookups included (the ondemand
/// ramp visits every rung of every cluster).
#[test]
fn dvfs_retune_degeneracy_across_presets() {
    for soc in presets() {
        let source = WeightSource::Empirical(RateTable::from_analytical(&soc));
        let plan = Ondemand::new(0.2).plan(&soc, 30.0);
        let shape = GemmShape::square(1024);
        for strat in [
            DvfsStrategy::Sas { cache_aware: true },
            DvfsStrategy::Sas { cache_aware: false },
        ] {
            for retune in [Retune::Boot, Retune::Online] {
                let ana = simulate_dvfs(&soc, strat, shape, &plan, retune);
                let emp = simulate_dvfs_with(&soc, strat, shape, &plan, retune, &source);
                assert_eq!(
                    ana,
                    emp,
                    "{}: {} [{}]",
                    soc.name,
                    strat.label(),
                    retune.label()
                );
            }
        }
    }
}

/// The exynos ondemand acceptance path with *measured* rates: the
/// empirical weights feed the retuner per OPP, the split differs from
/// the analytical one at the bottom of the ladder as well as the top,
/// and online still beats the stale boot split.
#[test]
fn measured_rates_drive_per_opp_retuning() {
    let soc = SocSpec::exynos5422();
    let table = RateTable::measure(&soc, &[]);
    // Per-rung empirical shares differ from the analytical ones at
    // every rung (the DES measurement is never bitwise the steady-state
    // model), materially so at the nominal rung — this is a per-OPP
    // calibration, not one global ratio.
    let mut shares = Vec::new();
    for o in 0..soc.clusters[0].opps.len() {
        let opps = vec![o, o];
        let emp = table
            .weights_at(&opps, Family::CacheAware, ShapeClass::Medium)
            .unwrap()
            .normalized();
        let derived = soc.at_opp(ClusterId(0), o).at_opp(ClusterId(1), o);
        let ana = PerfModel::new(derived).auto_weights(true).normalized();
        assert!(
            emp.share(0) != ana.share(0),
            "rung {o}: empirical share coincides with analytical ({})",
            emp.share(0)
        );
        shares.push(emp.share(0));
    }
    let nominal = soc.clusters[0].opps.nominal_idx();
    let ana_nominal = PerfModel::new(soc.clone()).auto_weights(true).normalized();
    assert!(
        (shares[nominal] - ana_nominal.share(0)).abs() > 1e-4,
        "nominal rung: empirical {} vs analytical {}",
        shares[nominal],
        ana_nominal.share(0)
    );
    // The empirical share itself moves along the ladder (per-OPP, not
    // one constant): the frequency ratio swings 1.6x -> 1.14x.
    let spread = shares.iter().cloned().fold(f64::NEG_INFINITY, f64::max)
        - shares.iter().cloned().fold(f64::INFINITY, f64::min);
    assert!(spread > 0.005, "per-rung shares {shares:?} are one global ratio");
    let source = WeightSource::Empirical(table);
    let plan = Ondemand::new(0.25).plan(&soc, 30.0);
    let shape = GemmShape::square(2048);
    let strat = DvfsStrategy::Sas { cache_aware: true };
    let boot = simulate_dvfs_with(&soc, strat, shape, &plan, Retune::Boot, &source);
    let online = simulate_dvfs_with(&soc, strat, shape, &plan, Retune::Online, &source);
    assert!(
        online.gflops > boot.gflops * 1.01,
        "online {} must beat boot {}",
        online.gflops,
        boot.gflops
    );
    assert!(online.retunes > 0);
    let sum: f64 = online.cluster_share.iter().sum();
    assert!((sum - 1.0).abs() < 1e-9, "shares {sum}");
}

fn rand_name(r: &mut Rng) -> String {
    let len = r.gen_range(1, 12);
    (0..len)
        .map(|_| char::from(b'a' + r.gen_range(0, 26) as u8))
        .collect()
}

/// A random positive, finite f64 spanning many magnitudes (exercises
/// the shortest-repr round-trip on awkward mantissas).
fn rand_rate(r: &mut Rng) -> f64 {
    let mag = r.gen_range(0, 7) as i32 - 3;
    r.gen_f64(0.001, 1.0) * 10f64.powi(mag) + f64::MIN_POSITIVE
}

/// ISSUE satellite: rate-table round-trip fuzzing — random tables
/// (random soc names, 1–6 clusters, 1–6 rungs, awkward f64 rates) →
/// TSV → parse → bit-for-bit equal.
#[test]
fn prop_rate_table_round_trips_exactly() {
    prop::check_default(
        |r| {
            let clusters = r.gen_range(1, 7);
            let mut rows = Vec::new();
            for c in 0..clusters {
                let rungs = r.gen_range(1, 7);
                for opp in 0..rungs {
                    for family in Family::ALL {
                        rows.push(RateRow {
                            cluster: ClusterId(c),
                            opp,
                            freq_ghz: r.gen_f64(0.1, 4.0),
                            family,
                            rates: [rand_rate(r), rand_rate(r), rand_rate(r)],
                        });
                    }
                }
            }
            RateTable {
                soc: rand_name(r),
                num_clusters: clusters,
                rows,
            }
        },
        |table| {
            let text = table.to_text();
            let back = RateTable::parse_text(&text)?;
            if &back != table {
                return Err(format!("round-trip drift:\n{text}"));
            }
            // Idempotent re-render.
            if back.to_text() != text {
                return Err(format!("re-render drift:\n{text}"));
            }
            Ok(())
        },
    );
}

/// ISSUE satellite: preset-store round-trip fuzzing with the measured
/// extension — random stores mixing 5-field and 8-field rows survive
/// TSV exactly.
#[test]
fn prop_opp_preset_store_round_trips_exactly() {
    prop::check_default(
        |r| {
            let rungs = r.gen_range(1, 8);
            let presets: Vec<OppPreset> = (0..rungs)
                .map(|opp| OppPreset {
                    opp,
                    freq_ghz: r.gen_f64(0.1, 4.0),
                    mc: 4 * r.gen_range(1, 120),
                    kc: r.gen_range(8, 1200),
                    gflops: rand_rate(r),
                    measured: if r.gen_bool(0.5) {
                        Some([rand_rate(r), rand_rate(r), rand_rate(r)])
                    } else {
                        None
                    },
                })
                .collect();
            OppPresetStore {
                soc: rand_name(r),
                cluster: ClusterId(r.gen_range(0, 8)),
                presets,
            }
        },
        |store| {
            let text = store.to_text();
            let back = OppPresetStore::parse_text(&text)?;
            if &back != store {
                return Err(format!("round-trip drift:\n{text}"));
            }
            if back.to_text() != text {
                return Err(format!("re-render drift:\n{text}"));
            }
            Ok(())
        },
    );
}

/// Malformed-input rejection beyond the three cases pinned in
/// `search::tests`: every mutation of a valid file must fail parsing,
/// never panic or silently truncate.
#[test]
fn malformed_inputs_rejected_not_mangled() {
    // --- OppPresetStore ---
    let valid = "# soc\t1\n0\t0.5\t80\t352\t0.31\t0.9\t1.7\t2.2\n";
    assert!(OppPresetStore::parse_text(valid).is_ok());
    for bad in [
        "# soc\t1\n0\t0.5\t80\t352\n",                        // 4 fields
        "# soc\t1\n0\t0.5\t80\t352\t0.31\t0.9\n",             // 6 fields
        "# soc\t1\n0\t0.5\t80\t352\t0.31\t0.9\t1.7\n",        // 7 fields
        "# soc\t1\n0\t0.5\t80\t352\t0.31\t0.9\t1.7\t2.2\t9\n", // 9 fields
        "# soc\t1\nx\t0.5\t80\t352\t0.31\n",                  // bad opp
        "# soc\t1\n0\tx\t80\t352\t0.31\n",                    // bad freq
        "# soc\t1\n0\t0.5\tx\t352\t0.31\n",                   // bad mc
        "# soc\t1\n0\t0.5\t80\tx\t0.31\n",                    // bad kc
        "# soc\t1\n0\t0.5\t80\t352\tx\n",                     // bad gflops
        "# soc\t1\n0\t0.5\t80\t352\t0.31\tNaN\t1.7\t2.2\n",   // non-finite rate
        "# soc\t1\n0\t0.5\t80\t352\t0.31\t-inf\t1.7\t2.2\n",  // non-finite rate
        "# soc-without-cluster\n0\t0.5\t80\t352\t0.31\n",     // bad header
        "#\t\n",                                              // degenerate header
    ] {
        assert!(OppPresetStore::parse_text(bad).is_err(), "accepted: {bad:?}");
    }

    // --- RateTable ---
    let valid = "# soc\t2\n0\t0\t1.6\tca\t1\t2\t3\n1\t0\t1.4\tobl\t0.5\t0.6\t0.7\n";
    assert!(RateTable::parse_text(valid).is_ok());
    for bad in [
        "",                                                  // empty
        "# soc\tx\n",                                        // bad count
        "# soc\t0\n",                                        // zero clusters
        "no-header\n0\t0\t1.6\tca\t1\t2\t3\n",               // missing marker
        "# soc\t2\n0\t0\t1.6\tca\t1\t2\n",                   // 6 fields
        "# soc\t2\n0\t0\t1.6\tca\t1\t2\t3\t4\n",             // 8 fields
        "# soc\t2\n2\t0\t1.6\tca\t1\t2\t3\n",                // cluster out of range
        "# soc\t2\n0\t0\t1.6\twarp\t1\t2\t3\n",              // bad family
        "# soc\t2\n0\tx\t1.6\tca\t1\t2\t3\n",                // bad opp
        "# soc\t2\n0\t0\t-1.6\tca\t1\t2\t3\n",               // bad freq
        "# soc\t2\n0\t0\t1.6\tca\t0\t2\t3\n",                // zero rate
        "# soc\t2\n0\t0\t1.6\tca\t-1\t2\t3\n",               // negative rate
        "# soc\t2\n0\t0\t1.6\tca\tNaN\t2\t3\n",              // NaN rate
        "# soc\t2\n0\t0\t1.6\tca\tinf\t2\t3\n",              // infinite rate
    ] {
        assert!(RateTable::parse_text(bad).is_err(), "accepted: {bad:?}");
    }
}

/// ISSUE 9 satellite: live-table round-trip fuzzing. Random tables —
/// random soc names, 1–6 declared clusters, random `kc_ref` /
/// half-life headers, cells grown through the real `observe` fold (so
/// the EWMA numerators and denominators are awkward decayed-sum
/// mantissas, not round numbers) plus a few gate-rejected observations
/// to fuzz the rejected counter — survive TSV bit for bit.
#[test]
fn prop_live_rate_table_round_trips_exactly() {
    prop::check_default(
        |r| {
            let soc = SocSpec::exynos5422();
            let mut table = LiveRateTable::new(&soc, r.gen_f64(0.5, 200.0));
            // The labeling fields are pub: fuzz them past what any real
            // descriptor would produce.
            table.soc = rand_name(r);
            table.num_clusters = r.gen_range(1, 7);
            table.kc_ref = r.gen_range(8, 3000);
            table.half_life_events = r.gen_f64(0.5, 200.0);
            for _ in 0..r.gen_range(1, 40) {
                let c = ClusterId(r.gen_range(0, table.num_clusters));
                let opp = r.gen_range(0, 6);
                let family = Family::ALL[r.gen_range(0, Family::ALL.len())];
                // k spans all three classes relative to the fuzzed kc_ref.
                let shape = GemmShape {
                    m: r.gen_range(1, 2048),
                    n: r.gen_range(1, 2048),
                    k: r.gen_range(1, 8 * table.kc_ref),
                };
                table.observe(c, opp, family, shape, rand_rate(r) * 1e9, rand_rate(r));
            }
            for _ in 0..r.gen_range(0, 4) {
                let c = ClusterId(r.gen_range(0, table.num_clusters));
                table.observe(c, 0, Family::CacheAware, GemmShape::square(64), f64::NAN, 1.0);
            }
            table
        },
        |table| {
            let text = table.to_text();
            let back = LiveRateTable::parse_text(&text)?;
            if &back != table {
                return Err(format!("round-trip drift:\n{text}"));
            }
            if back.to_text() != text {
                return Err(format!("re-render drift:\n{text}"));
            }
            Ok(())
        },
    );
}

/// ISSUE 9 satellite: malformed live rows are rejected, never panicked
/// on or silently mangled — header arity/vocabulary/range errors, bad
/// half-life and count fields, non-finite or non-positive EWMA terms,
/// zero sample counts and duplicate cells.
#[test]
fn malformed_live_rows_rejected_not_mangled() {
    const H: &str = "#live\tsoc\t2\t952\t32\t10\t1\n";
    let valid = format!("{H}0\t0\tca\tsmall\t5.5\t1.5\t3\n1\t2\tobl\tlarge\t0.25\t2\t8\n");
    assert!(LiveRateTable::parse_text(&valid).is_ok());
    let bad_cases = [
        "".to_string(),                                       // empty
        "#rates\tsoc\t2\t952\t32\t10\t1\n".to_string(),       // wrong marker
        "#live\tsoc\t2\t952\t32\t10\n".to_string(),           // 6-field header
        "#live\tsoc\t2\t952\t32\t10\t1\t9\n".to_string(),     // 8-field header
        "#live\tsoc\tx\t952\t32\t10\t1\n".to_string(),        // bad cluster count
        "#live\tsoc\t0\t952\t32\t10\t1\n".to_string(),        // zero clusters
        "#live\tsoc\t2\t0\t32\t10\t1\n".to_string(),          // zero kc_ref
        "#live\tsoc\t2\t952\t0\t10\t1\n".to_string(),         // zero half-life
        "#live\tsoc\t2\t952\t-32\t10\t1\n".to_string(),       // negative half-life
        "#live\tsoc\t2\t952\tNaN\t10\t1\n".to_string(),       // NaN half-life
        "#live\tsoc\t2\t952\tinf\t10\t1\n".to_string(),       // infinite half-life
        "#live\tsoc\t2\t952\t32\tx\t1\n".to_string(),         // bad accepted count
        "#live\tsoc\t2\t952\t32\t10\t-1\n".to_string(),       // negative rejected count
        format!("{H}0\t0\tca\tsmall\t5.5\t1.5\n"),            // 6-field row
        format!("{H}0\t0\tca\tsmall\t5.5\t1.5\t3\t9\n"),      // 8-field row
        format!("{H}2\t0\tca\tsmall\t5.5\t1.5\t3\n"),         // cluster out of range
        format!("{H}x\t0\tca\tsmall\t5.5\t1.5\t3\n"),         // bad cluster
        format!("{H}0\tx\tca\tsmall\t5.5\t1.5\t3\n"),         // bad opp
        format!("{H}0\t0\twarp\tsmall\t5.5\t1.5\t3\n"),       // bad family
        format!("{H}0\t0\tca\ttiny\t5.5\t1.5\t3\n"),          // bad class
        format!("{H}0\t0\tca\tsmall\t0\t1.5\t3\n"),           // zero num
        format!("{H}0\t0\tca\tsmall\t5.5\t-1\t3\n"),          // negative den
        format!("{H}0\t0\tca\tsmall\tNaN\t1.5\t3\n"),         // NaN num
        format!("{H}0\t0\tca\tsmall\t5.5\tinf\t3\n"),         // infinite den
        format!("{H}0\t0\tca\tsmall\t5.5\t1.5\t0\n"),         // zero samples
        format!("{H}0\t0\tca\tsmall\t5.5\t1.5\t-3\n"),        // negative samples
        format!("{H}0\t0\tca\tsmall\t5.5\t1.5\t3\n0\t0\tca\tsmall\t5.5\t1.5\t3\n"), // duplicate
    ];
    for bad in &bad_cases {
        assert!(LiveRateTable::parse_text(bad).is_err(), "accepted: {bad:?}");
    }
}

/// Exynos stays exynos: building, synthesizing and measuring tables
/// never mutates the descriptor (the regression suite's precondition).
#[test]
fn calibration_does_not_perturb_presets() {
    let before = SocSpec::exynos5422();
    let _ = RateTable::from_analytical(&before);
    let _ = RateTable::measure(&before, &[]);
    let _ = OppPresetStore::tune_measured(&before, ClusterId(1));
    assert_eq!(before, SocSpec::exynos5422());
}
