//! Coordinator + TCP service integration: protocol robustness, failure
//! injection, concurrent mixed workloads and cross-backend agreement.
//!
//! Anti-flake contract (ISSUE 4 satellite): every server in this suite
//! binds `127.0.0.1:0` and reads the kernel-assigned port back from
//! [`server::ServerHandle::addr`] — never a hardcoded port that could
//! collide when cargo runs test binaries in parallel. The
//! `parallel_servers_get_distinct_ports` test pins that property.

use amp_gemm::blis::gemm::GemmShape;
use amp_gemm::coordinator::{server, Backend, Coordinator, Request};
use amp_gemm::sched::ScheduleSpec;
use amp_gemm::soc::SocSpec;
use amp_gemm::util::rng::Rng;
use std::io::Write;
use std::path::Path;
use std::sync::Arc;

fn artifacts_dir() -> std::path::PathBuf {
    Path::new(env!("CARGO_MANIFEST_DIR")).join("artifacts")
}

fn start(with_artifacts: bool) -> (Arc<Coordinator>, server::ServerHandle) {
    let coord = if with_artifacts && artifacts_dir().join("manifest.txt").exists() {
        Coordinator::with_artifacts(SocSpec::exynos5422(), &artifacts_dir()).unwrap()
    } else {
        Coordinator::new(SocSpec::exynos5422())
    };
    let coord = Arc::new(coord);
    let h = server::serve(coord.clone(), "127.0.0.1:0").unwrap();
    (coord, h)
}

/// Fuzz the line protocol with garbage: the server must answer ERR (or
/// close politely) and keep serving — never panic, never wedge.
#[test]
fn protocol_fuzz_never_kills_the_server() {
    let (_c, h) = start(false);
    let mut rng = Rng::new(0xF022);
    let mut cl = server::Client::connect(h.addr).unwrap();
    for _ in 0..200 {
        let len = rng.gen_range(0, 40);
        let garbage: String = (0..len)
            .map(|_| {
                let c = rng.gen_range(32, 127) as u8 as char;
                if c == 'Q' { 'q' } else { c } // avoid accidental QUIT
            })
            .collect();
        let reply = cl.call(&garbage).unwrap();
        assert!(
            reply.starts_with("ERR") || reply.starts_with("OK") || reply == "PONG" || reply.starts_with("STATS"),
            "unexpected reply '{reply}' to '{garbage}'"
        );
    }
    assert_eq!(cl.call("PING").unwrap(), "PONG", "server must still serve");
    h.shutdown();
}

/// Abruptly dropped connections (no QUIT) must not leak into other
/// sessions or take the service down.
#[test]
fn abrupt_disconnects_are_harmless() {
    let (_c, h) = start(false);
    for _ in 0..8 {
        let mut s = std::net::TcpStream::connect(h.addr).unwrap();
        let _ = s.write_all(b"GEMM 48 48 48 1 nat"); // half a request
        drop(s); // vanish mid-line
    }
    let mut cl = server::Client::connect(h.addr).unwrap();
    assert!(cl.call("GEMM 32 32 32 5 native").unwrap().starts_with("OK"));
    h.shutdown();
}

/// A batch containing failing jobs (PJRT shape with no artifact) must
/// return per-job errors without poisoning the healthy jobs.
#[test]
fn failure_injection_in_batches() {
    let with = artifacts_dir().join("manifest.txt").exists();
    let (coord, h) = start(with);
    let rng = Rng::new(3);
    let mk = |id: u64, r: usize, backend: Backend| Request {
        id,
        shape: GemmShape::square(r),
        a: Arc::new(rng.clone().fill_matrix(r * r)),
        b: Arc::new(rng.clone().fill_matrix(r * r)),
        backend,
    };
    let reqs = vec![
        mk(0, 48, Backend::Native(ScheduleSpec::ca_das())),
        // 48 has no PJRT artifact → error either way (no runtime / no shape).
        mk(1, 48, Backend::Pjrt { variant: "big".into() }),
        mk(2, 96, Backend::Native(ScheduleSpec::sss())),
        mk(3, 48, Backend::Sim(ScheduleSpec::das())),
    ];
    let out = coord.execute_batch(reqs);
    assert!(out[0].is_ok());
    assert!(out[1].is_err(), "injected failure must surface as Err");
    assert!(out[2].is_ok());
    assert!(out[3].is_ok());
    h.shutdown();
}

/// Mixed native/sim (and PJRT when available) workload from many
/// concurrent clients: all succeed, metrics add up.
#[test]
fn concurrent_mixed_workload() {
    let with = artifacts_dir().join("manifest.txt").exists();
    let (coord, h) = start(with);
    let addr = h.addr;
    let mut joins = Vec::new();
    for t in 0..6u64 {
        let use_pjrt = with && t % 3 == 0;
        joins.push(std::thread::spawn(move || {
            let mut cl = server::Client::connect(addr).unwrap();
            let mut ok = 0;
            for i in 0..5u64 {
                let backend = if use_pjrt { "pjrt:big" } else if i % 2 == 0 { "native" } else { "sim" };
                let r = if use_pjrt { 64 } else { [32, 48, 64][(i % 3) as usize] };
                let reply = cl
                    .call(&format!("GEMM {r} {r} {r} {} {backend}", t * 10 + i))
                    .unwrap();
                if reply.starts_with("OK") {
                    ok += 1;
                }
            }
            ok
        }));
    }
    let total_ok: usize = joins.into_iter().map(|j| j.join().unwrap()).sum();
    assert_eq!(total_ok, 30, "all requests must succeed");
    assert_eq!(coord.metrics().completed, 30);
    h.shutdown();
}

/// PJRT and native backends agree on the same request (checksum path
/// used by external clients).
#[test]
fn cross_backend_checksums_agree_over_the_wire() {
    if !artifacts_dir().join("manifest.txt").exists() {
        eprintln!("skipping: run `make artifacts` first");
        return;
    }
    let (_c, h) = start(true);
    let mut cl = server::Client::connect(h.addr).unwrap();
    let checksum = |reply: &str| -> f64 {
        reply.split_whitespace().nth(4).unwrap().parse().unwrap()
    };
    for r in [64usize, 128, 256] {
        let native = cl.call(&format!("GEMM {r} {r} {r} 77 native")).unwrap();
        let pjrt_big = cl.call(&format!("GEMM {r} {r} {r} 77 pjrt:big")).unwrap();
        let pjrt_little = cl.call(&format!("GEMM {r} {r} {r} 77 pjrt:little")).unwrap();
        assert!(native.starts_with("OK") && pjrt_big.starts_with("OK"), "{native} / {pjrt_big}");
        let (cn, cb, cl_) = (checksum(&native), checksum(&pjrt_big), checksum(&pjrt_little));
        assert!((cn - cb).abs() < 1e-5 * cn.abs().max(1.0), "r={r}: {cn} vs {cb}");
        assert!((cb - cl_).abs() < 1e-5 * cb.abs().max(1.0), "variants must agree: {cb} vs {cl_}");
    }
    h.shutdown();
}

/// ISSUE 4 satellite: binding port 0 must hand every concurrently
/// running server its own kernel-assigned port — the property that
/// keeps parallel test binaries from colliding. Each server answers on
/// its own address and isolates its own metrics.
#[test]
fn parallel_servers_get_distinct_ports() {
    let servers: Vec<_> = (0..4).map(|_| start(false)).collect();
    let mut ports: Vec<u16> = servers.iter().map(|(_, h)| h.addr.port()).collect();
    assert!(ports.iter().all(|&p| p != 0), "the OS must assign real ports: {ports:?}");
    ports.sort_unstable();
    ports.dedup();
    assert_eq!(ports.len(), 4, "every server needs its own port");
    for (i, (coord, h)) in servers.iter().enumerate() {
        let mut cl = server::Client::connect(h.addr).unwrap();
        assert_eq!(cl.call("PING").unwrap(), "PONG", "server {i}");
        assert!(cl.call(&format!("GEMM 32 32 32 {i} native")).unwrap().starts_with("OK"));
        assert_eq!(coord.metrics().completed, 1, "server {i} counts only its own traffic");
    }
    for (_, h) in servers {
        h.shutdown();
    }
}

/// Out-of-range requests are rejected with a reason, in-range accepted
/// at the boundary.
#[test]
fn request_validation_boundaries() {
    let (_c, h) = start(false);
    let mut cl = server::Client::connect(h.addr).unwrap();
    assert!(cl.call("GEMM 4096 1 1 1 sim").unwrap().starts_with("OK"));
    assert!(cl.call("GEMM 4097 1 1 1 sim").unwrap().starts_with("ERR"));
    assert!(cl.call("GEMM 1 1 0 1 sim").unwrap().starts_with("ERR"));
    assert!(cl.call("GEMM -1 1 1 1 sim").unwrap().starts_with("ERR"));
    assert!(cl.call("GEMM 1 1 1 99999999999999999999 sim").unwrap().starts_with("ERR"));
    h.shutdown();
}
