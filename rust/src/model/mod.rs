//! Analytical performance model of the BLIS GEMM kernels on the
//! asymmetric SoC.
//!
//! This is the hardware-substitution core (DESIGN.md §1): where the paper
//! measures wall time on the Exynos 5422, we compute it from a calibrated
//! model. The model has exactly the structure the paper's analysis
//! appeals to:
//!
//! `rate(cluster, cfg) = peak(cluster) · eff_k(kc) · eff_m(rows/jr-col)
//!                       · L1/L2 fit penalties · cluster contention`
//!
//! * `eff_k` — C-block load/store and loop overhead amortized over the
//!   kc rank-1 updates of one micro-kernel;
//! * `eff_m` — `Br` L1-warmup amortized over the micro-kernels a thread
//!   executes per jr column (this is why fine-grain Loop 5 parallelism,
//!   which divides those rows 4-ways, loses to Loop 4 — Fig. 11/12);
//! * fit penalties — from [`crate::cache::FootprintAnalysis`]; the §4
//!   "architecture-oblivious" mismatch (A15 parameters on the A7) enters
//!   here;
//! * contention — the 4th A15 core's diminishing return (§3.4).
//!
//! Every per-cluster constant comes from the cluster's own
//! [`crate::soc::ClusterTuning`], so the model scales to any N-cluster
//! topology; SoC-level constants live in [`calibration`] with
//! paper-anchored tests.

pub mod calibration;

use crate::blis::params::BlisParams;
use crate::cache::analysis::FootprintAnalysis;
use crate::sched::Weights;
use crate::soc::{ClusterId, SocSpec};
use calibration as cal;

/// Execution-context inputs that vary per scheduling decision.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct MicroCtx {
    /// Depth of this micro-kernel's rank-1 loop (kc, or the k-remainder).
    pub kc_eff: usize,
    /// Rows of the macro-panel this thread sweeps per jr column
    /// (= mc for Loop-4-only fine grain; mc/threads under Loop 5).
    pub rows_per_jr: usize,
    /// Busy cores in this cluster (contention input).
    pub active_in_cluster: usize,
    /// Whether at least one other cluster is simultaneously computing.
    pub other_cluster_active: bool,
}

/// The calibrated performance model, bound to one SoC descriptor.
#[derive(Debug, Clone)]
pub struct PerfModel {
    pub soc: SocSpec,
    /// Per-cluster footprint analyses, indexed by [`ClusterId`].
    fits: Vec<FootprintAnalysis>,
}

impl PerfModel {
    pub fn new(soc: SocSpec) -> Self {
        // L3/SLC-aware per-cluster analyses: identical to the two-level
        // ones when the descriptor has no system-level cache.
        let fits = soc
            .cluster_ids()
            .map(|c| FootprintAnalysis::for_cluster_in(&soc, c))
            .collect();
        PerfModel { soc, fits }
    }

    pub fn exynos() -> Self {
        PerfModel::new(SocSpec::exynos5422())
    }

    fn fit(&self, c: ClusterId) -> &FootprintAnalysis {
        &self.fits[c.0]
    }

    /// Amortization of per-micro-kernel overhead over the kc updates.
    pub fn eff_k(&self, c: ClusterId, kc_eff: usize) -> f64 {
        let kc = kc_eff.max(1) as f64;
        kc / (kc + self.soc[c].tuning.hk)
    }

    /// Amortization of `Br` warmup over the rows swept per jr column.
    pub fn eff_m(&self, c: ClusterId, rows: usize) -> f64 {
        let m = rows.max(1) as f64;
        m / (m + self.soc[c].tuning.hm)
    }

    /// Cache-fit penalty of a configuration on a cluster (≤ 1).
    pub fn cache_penalty(&self, c: ClusterId, p: &BlisParams) -> f64 {
        self.fit(c).fit(p).combined_penalty()
    }

    /// Ideal peak of one core on this SoC: derived from the descriptor
    /// (freq × flops/cycle), so DVFS variants and other AMPs (Juno,
    /// tri-cluster, custom counts) are modelled without re-calibration.
    /// For the Exynos descriptor this equals the calibration constants.
    pub fn peak(&self, c: ClusterId) -> f64 {
        self.soc[c].core.peak_gflops()
    }

    /// Sustained GFLOPS of one core running micro-kernels configured by
    /// `p` under context `ctx`.
    pub fn core_rate_gflops(&self, c: ClusterId, p: &BlisParams, ctx: &MicroCtx) -> f64 {
        let tuning = &self.soc[c].tuning;
        let mut rate = self.peak(c)
            * tuning.register_block_factor(p.mr, p.nr)
            * self.eff_k(c, ctx.kc_eff)
            * self.eff_m(c, ctx.rows_per_jr)
            * self.cache_penalty(c, p)
            * tuning.scale(ctx.active_in_cluster);
        if ctx.other_cluster_active {
            rate *= cal::BOTH_CLUSTERS_FACTOR;
        }
        rate
    }

    /// Steady-state rate at the configured blocking (full tiles, whole
    /// cluster view): convenience for figure generation and weight
    /// auto-selection.
    pub fn steady_rate_gflops(&self, c: ClusterId, p: &BlisParams, active: usize) -> f64 {
        let ctx = MicroCtx {
            kc_eff: p.kc,
            rows_per_jr: p.mc,
            active_in_cluster: active,
            other_cluster_active: false,
        };
        self.core_rate_gflops(c, p, &ctx)
    }

    /// Cluster-aggregate steady rate with `n` active cores.
    pub fn cluster_rate_gflops(&self, c: ClusterId, p: &BlisParams, n: usize) -> f64 {
        self.steady_rate_gflops(c, p, n) * n as f64
    }

    /// Time (s) for one micro-kernel of `mr×nr×kc_eff` in context.
    /// Partial edge tiles are charged the full `mr×nr` register block —
    /// exactly the padding cost real micro-kernels pay.
    pub fn micro_kernel_time(&self, c: ClusterId, p: &BlisParams, ctx: &MicroCtx) -> f64 {
        let flops = 2.0 * p.mr as f64 * p.nr as f64 * ctx.kc_eff.max(1) as f64;
        flops / (self.core_rate_gflops(c, p, ctx) * 1e9)
    }

    /// Time (s) for one thread's share of packing: `bytes` of payload
    /// through the core's effective packing bandwidth (read + write
    /// already folded into the calibrated bandwidth).
    pub fn pack_time(&self, c: ClusterId, bytes: usize) -> f64 {
        bytes as f64 / (self.soc[c].tuning.pack_bw_gbs * 1e9)
    }

    /// Intra-cluster barrier cost (per synchronization point).
    pub fn barrier_time(&self, c: ClusterId) -> f64 {
        self.soc[c].tuning.barrier_s
    }

    /// Dynamic-chunk critical-section cost (§5.4).
    pub fn grab_time(&self, c: ClusterId) -> f64 {
        self.soc[c].tuning.grab_s
    }

    /// Per-cluster aggregate throughputs under the given per-cluster
    /// configurations — the raw ingredients of the weighted-static
    /// split (§5.2, generalized to N clusters).
    pub fn cluster_rates(&self, params: &[BlisParams]) -> Vec<f64> {
        assert_eq!(params.len(), self.soc.num_clusters());
        self.soc
            .cluster_ids()
            .map(|c| self.cluster_rate_gflops(c, &params[c.0], self.soc[c].num_cores))
            .collect()
    }

    /// Model-derived weight vector for *oblivious* SAS: every cluster
    /// runs the lead cluster's parameters (§5.2's ratio knob, N-way).
    pub fn sas_weights(&self) -> Weights {
        let lead = self.soc[self.soc.lead()].tuned;
        let rates = self.cluster_rates(&vec![lead; self.soc.num_clusters()]);
        Weights::from_slice(&rates)
    }

    /// Model-derived weight vector for *cache-aware* SAS: every cluster
    /// runs its own tuned parameters (§5.3).
    pub fn ca_sas_weights(&self) -> Weights {
        let params: Vec<BlisParams> = self.soc.clusters.iter().map(|c| c.tuned).collect();
        Weights::from_slice(&self.cluster_rates(&params))
    }

    /// Weight vector of a strategy family by its cache-awareness — the
    /// single entry point the DVFS retuner recomputes at every OPP
    /// transition (`crate::dvfs`).
    pub fn auto_weights(&self, cache_aware: bool) -> Weights {
        if cache_aware {
            self.ca_sas_weights()
        } else {
            self.sas_weights()
        }
    }

    /// Per-cluster blocking parameters of a strategy family: own tuned
    /// optima when cache-aware, the lead cluster's everywhere otherwise
    /// (§4's architecture-oblivious convention).
    pub fn family_params(&self, cache_aware: bool) -> Vec<BlisParams> {
        if cache_aware {
            self.soc.clusters.iter().map(|c| c.tuned).collect()
        } else {
            vec![self.soc[self.soc.lead()].tuned; self.soc.num_clusters()]
        }
    }

    /// The two-cluster per-cluster throughput ratio under a
    /// configuration — what the paper's SAS `ratio` knob should be set
    /// to (§5.2). `p_little` is the configuration the slow cluster
    /// actually runs (lead params for plain SAS; its own tuned params
    /// for CA-SAS). For N > 2 clusters use [`PerfModel::cluster_rates`].
    pub fn ideal_ratio(&self, p_big: &BlisParams, p_little: &BlisParams) -> f64 {
        assert_eq!(
            self.soc.num_clusters(),
            2,
            "ideal_ratio is the 2-cluster shorthand; use cluster_rates"
        );
        let (b, l) = (ClusterId(0), ClusterId(1));
        self.cluster_rate_gflops(b, p_big, self.soc[b].num_cores)
            / self.cluster_rate_gflops(l, p_little, self.soc[l].num_cores)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::soc::{BIG, LITTLE};

    fn model() -> PerfModel {
        PerfModel::exynos()
    }

    /// §3.4 anchor: single A15 core at its optimum ≈ 2.85–2.95 GFLOPS.
    #[test]
    fn anchor_single_a15() {
        let r = model().steady_rate_gflops(BIG, &BlisParams::a15_opt(), 1);
        assert!((2.80..3.00).contains(&r), "A15 single-core rate {r}");
    }

    /// §3.4 anchor: single A7 core at its optimum ≈ 0.58–0.62 GFLOPS.
    #[test]
    fn anchor_single_a7() {
        let r = model().steady_rate_gflops(LITTLE, &BlisParams::a7_opt(), 1);
        assert!((0.55..0.63).contains(&r), "A7 single-core rate {r}");
    }

    /// §3.4 anchor: full A15 cluster ≈ 9.6 GFLOPS, 4th core diminishing.
    #[test]
    fn anchor_a15_cluster_scaling() {
        let m = model();
        let p = BlisParams::a15_opt();
        let r: Vec<f64> = (1..=4)
            .map(|n| m.cluster_rate_gflops(BIG, &p, n))
            .collect();
        assert!((9.2..10.0).contains(&r[3]), "4-core peak {}", r[3]);
        let inc3 = r[2] - r[1];
        let inc4 = r[3] - r[2];
        assert!(inc4 < 0.6 * inc3, "4th-core increment must diminish: {inc3} vs {inc4}");
        // First increments ≈ 2.8–3.0 GFLOPS per core.
        assert!((2.7..3.1).contains(&(r[1] - r[0])));
    }

    /// §3.4 anchor: full A7 cluster ≈ 2.3–2.4 GFLOPS, near-linear.
    #[test]
    fn anchor_a7_cluster_scaling() {
        let m = model();
        let p = BlisParams::a7_opt();
        let r4 = m.cluster_rate_gflops(LITTLE, &p, 4);
        assert!((2.2..2.5).contains(&r4), "A7 cluster {r4}");
    }

    /// Fig. 7 anchor: ideal aggregate ≈ 11.9–12 GFLOPS.
    #[test]
    fn anchor_ideal_aggregate() {
        let m = model();
        let ideal = m.cluster_rate_gflops(BIG, &BlisParams::a15_opt(), 4)
            + m.cluster_rate_gflops(LITTLE, &BlisParams::a7_opt(), 4);
        assert!((11.5..12.4).contains(&ideal), "ideal {ideal}");
    }

    /// §4 anchor: A15 parameters on the A7 → ×0.75–0.88 of its optimum;
    /// the resulting SAS ratio optimum is ≈ 5 (Fig. 9).
    #[test]
    fn anchor_oblivious_penalty_and_sas_ratio() {
        let m = model();
        let a15 = BlisParams::a15_opt();
        let opt = m.cluster_rate_gflops(LITTLE, &BlisParams::a7_opt(), 4);
        let bad = m.cluster_rate_gflops(LITTLE, &a15, 4);
        let frac = bad / opt;
        assert!((0.75..0.90).contains(&frac), "penalty fraction {frac}");
        let ratio = m.ideal_ratio(&a15, &a15);
        assert!((4.4..5.6).contains(&ratio), "SAS ideal ratio {ratio}");
        // With cache-aware LITTLE parameters the ratio drops toward 4.
        let ca = m.ideal_ratio(&a15, &BlisParams::a7_opt());
        assert!(ca < ratio, "CA ratio {ca} must be below oblivious {ratio}");
        assert!((3.6..4.6).contains(&ca));
    }

    /// Fig. 11 mechanism: Loop-5 fine grain divides rows/jr-column and
    /// must lose throughput relative to Loop 4.
    #[test]
    fn loop5_fine_grain_penalized() {
        let m = model();
        let p = BlisParams::a15_opt();
        let full = m.eff_m(BIG, p.mc);
        let quarter = m.eff_m(BIG, p.mc / 4);
        assert!(quarter < full);
        assert!(quarter / full > 0.80, "loss should be a few %–20 %");
    }

    #[test]
    fn micro_kernel_time_scales_with_kc() {
        let m = model();
        let p = BlisParams::a15_opt();
        let base = MicroCtx {
            kc_eff: p.kc,
            rows_per_jr: p.mc,
            active_in_cluster: 1,
            other_cluster_active: false,
        };
        let t_full = m.micro_kernel_time(BIG, &p, &base);
        let t_half = m.micro_kernel_time(
            BIG,
            &p,
            &MicroCtx { kc_eff: p.kc / 2, ..base },
        );
        assert!(t_half < t_full);
        assert!(t_half > 0.4 * t_full, "sub-linear due to eff_k");
    }

    #[test]
    fn pack_time_proportional_to_bytes() {
        let m = model();
        let t1 = m.pack_time(BIG, 1 << 20);
        let t2 = m.pack_time(BIG, 2 << 20);
        assert!((t2 / t1 - 2.0).abs() < 1e-9);
        assert!(m.pack_time(LITTLE, 1 << 20) > t1, "LITTLE packs slower");
    }

    #[test]
    fn overheads_positive_and_asymmetric() {
        let m = model();
        assert!(m.barrier_time(LITTLE) > m.barrier_time(BIG));
        assert!(m.grab_time(LITTLE) > m.grab_time(BIG));
    }

    #[test]
    fn both_clusters_factor_applies() {
        let m = model();
        let p = BlisParams::a15_opt();
        let solo = MicroCtx {
            kc_eff: p.kc,
            rows_per_jr: p.mc,
            active_in_cluster: 4,
            other_cluster_active: false,
        };
        let both = MicroCtx { other_cluster_active: true, ..solo };
        assert!(m.core_rate_gflops(BIG, &p, &both) < m.core_rate_gflops(BIG, &p, &solo));
    }

    /// §5.2: DVFS changes the right ratio — downclocking the big cluster
    /// must pull the SAS ratio towards 1.
    #[test]
    fn dvfs_shifts_the_sas_ratio() {
        let base = PerfModel::exynos();
        let down = PerfModel::new(SocSpec::exynos5422().with_freqs(0.8, 1.4));
        let p = BlisParams::a15_opt();
        let r_base = base.ideal_ratio(&p, &p);
        let r_down = down.ideal_ratio(&p, &p);
        assert!(r_down < 0.6 * r_base, "downclocked ratio {r_down} vs {r_base}");
        // And the Exynos descriptor's derived peaks match calibration.
        assert!((base.peak(BIG) - 3.2).abs() < 1e-12);
        assert!((base.peak(LITTLE) - 0.7).abs() < 1e-12);
    }

    /// §6 roadmap: the ARMv8 Juno descriptor is modelled without any
    /// recalibration — 2 fast A57s against 4 slow A53s gives a smaller
    /// cluster ratio than the Exynos.
    #[test]
    fn juno_armv8_descriptor_models() {
        let juno = PerfModel::new(SocSpec::juno_r0());
        let p = BlisParams::a15_opt();
        let ratio = juno.ideal_ratio(&p, &p);
        assert!(ratio > 1.0 && ratio < 4.0, "Juno cluster ratio {ratio}");
        let peak = juno.peak(BIG);
        assert!((peak - 4.4).abs() < 1e-9, "A57 peak {peak}");
    }

    /// §6 future work: an 8×4 big-core micro-kernel buys ~5 %; on the
    /// in-order LITTLE core it loses.
    #[test]
    fn per_core_register_blocking() {
        let m = model();
        let p44 = BlisParams::a15_opt();
        let p84 = BlisParams::a15_opt_8x4();
        let r44 = m.steady_rate_gflops(BIG, &p44, 1);
        let r84 = m.steady_rate_gflops(BIG, &p84, 1);
        assert!(r84 > r44 * 1.02 && r84 < r44 * 1.10, "{r44} vs {r84}");
        let l44 = m.steady_rate_gflops(LITTLE, &BlisParams::a7_opt(), 1);
        let base = BlisParams::a7_opt();
        let l84p = BlisParams::new(base.nc, base.kc, base.mc, base.nr, 8);
        let l84 = m.steady_rate_gflops(LITTLE, &l84p, 1);
        assert!(l84 < l44, "LITTLE must lose with 8×4: {l44} vs {l84}");
    }

    #[test]
    fn shared_kc_params_beat_a15_params_on_a7() {
        // §5.3: mc=32/kc=952 on the A7 is suboptimal vs (80,352) but much
        // better than the A15 parameters whose Ac misses the 512 KiB L2.
        let m = model();
        let shared = m.steady_rate_gflops(LITTLE, &BlisParams::a7_shared_kc(), 1);
        let oblivious = m.steady_rate_gflops(LITTLE, &BlisParams::a15_opt(), 1);
        let opt = m.steady_rate_gflops(LITTLE, &BlisParams::a7_opt(), 1);
        assert!(shared > oblivious, "shared {shared} vs oblivious {oblivious}");
        assert!(shared < opt, "shared {shared} vs opt {opt}");
    }

    /// The N-way weight machinery: Exynos SAS weights encode ≈ the
    /// paper's ratio-5 knob; the tri-cluster vector is strictly ordered.
    #[test]
    fn auto_weights_track_cluster_rates() {
        let m = model();
        let w = m.sas_weights();
        assert_eq!(w.len(), 2);
        let ws = w.as_slice();
        let ratio = ws[0] / ws[1];
        assert!((4.4..5.6).contains(&ratio), "oblivious weight ratio {ratio}");
        let ca = m.ca_sas_weights();
        let cs = ca.as_slice();
        assert!(cs[0] / cs[1] < ratio, "CA weights shift toward the LITTLE");

        let tri = PerfModel::new(SocSpec::dynamiq_3c());
        let tw = tri.ca_sas_weights();
        assert_eq!(tw.len(), 3);
        let t = tw.as_slice();
        assert!(t[0] > t[1] && t[1] > t[2], "descending cluster rates: {t:?}");
    }

    #[test]
    #[should_panic(expected = "2-cluster shorthand")]
    fn ideal_ratio_rejects_other_topologies() {
        let tri = PerfModel::new(SocSpec::dynamiq_3c());
        let p = BlisParams::a15_opt();
        tri.ideal_ratio(&p, &p);
    }
}
