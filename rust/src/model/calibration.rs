//! Calibration constants for the performance and energy models.
//!
//! Every constant is anchored to a number the paper reports (§3.4, §4,
//! Figs. 5/7/9/10/12); the unit tests at the bottom of `model/mod.rs`
//! and `energy/mod.rs` assert the anchors, so a change that silently
//! un-calibrates the reproduction fails `cargo test`.
//!
//! Since the N-cluster topology refactor, *per-cluster* constants
//! (amortization half-saturations, contention tables, packing bandwidth,
//! synchronization costs, power rails, L2 fill fractions) live in the
//! descriptor itself — `soc::ClusterTuning`, constructed by
//! `ClusterTuning::a15()` / `a7()` / `mid()` — so that a third or fourth
//! cluster carries its own calibration without touching the models.
//! This module keeps the *SoC-level* constants shared by every cluster,
//! plus the paper-anchor reference values the regression tests pin.
//!
//! Anchors:
//! * single Cortex-A15 core at (mc,kc)=(152,952): ≈ 2.85–2.95 GFLOPS;
//!   cluster scaling ≈ [1, 2, 2.9, 3.25]× (the 4th core adds only
//!   ≈ 1.4 GFLOPS; peak ≈ 9.6 GFLOPS) — §3.4;
//! * single Cortex-A7 core at (80,352): ≈ 0.58–0.62 GFLOPS; cluster
//!   ≈ linear to ≈ 2.3–2.4 GFLOPS — §3.4;
//! * A7 running A15-optimal parameters: ≈ ×0.75–0.85 of its optimum
//!   (drives: SSS ≈ 40 % of A15-only (§4), SAS optimum ratio 5–6
//!   (Fig. 9), CA-SAS gains confined to ratios < 5 (Fig. 10));
//! * energy: best A15 efficiency with 3 cores (+25–40 % over 1 core),
//!   full-A7 ≈ 2× single-A7, full-A7 > single-A15, full-A7 ≈ full-A15,
//!   SSS by far the worst (§3.4, Figs. 5/7).

/// Ideal peak double-precision GFLOPS of one Exynos core at the
/// micro-kernel (paper's hand-tuned 4×4 kernel): freq × flops/cycle.
/// Reference values only — the model always derives peaks from the
/// descriptor, so DVFS variants and other AMPs need no recalibration.
pub const PEAK_GFLOPS_BIG: f64 = 3.2; // 1.6 GHz × 2 dp-flops/cycle
pub const PEAK_GFLOPS_LITTLE: f64 = 0.7; // 1.4 GHz × 0.5 dp-flops/cycle

/// Mild DRAM interference when multiple clusters compute at once.
pub const BOTH_CLUSTERS_FACTOR: f64 = 0.99;

/// ---- Power model (energy/mod.rs), Watts ------------------------------
/// Cluster baselines and per-core increments live in each cluster's
/// `ClusterTuning` (charged for the whole run / while a core computes).
/// Polling (spin-wait) draws a fraction of active power — the paper
/// notes idle-but-polling fast threads burn energy, §5.2.2.
pub const POLL_FACTOR: f64 = 0.70;
pub const P_DRAM_IDLE: f64 = 0.18;
pub const P_GPU_IDLE: f64 = 0.05;
/// DRAM dynamic energy per byte moved (DDR3-class).
pub const DRAM_NJ_PER_BYTE: f64 = 0.0625;

/// pmlib sampling period (§3.2): 250 ms.
pub const PMLIB_SAMPLE_PERIOD_S: f64 = 0.25;

#[cfg(test)]
mod tests {
    use super::*;
    use crate::soc::{ClusterTuning, SocSpec, BIG, LITTLE};

    #[test]
    fn idle_big_cluster_exceeds_active_little_core() {
        // Paper §3.4: "the Cortex-A15 cluster in idle state already
        // dissipates more power than a single Cortex-A7 core in execution".
        let (a15, a7) = (ClusterTuning::a15(), ClusterTuning::a7());
        assert!(a15.p_cluster_idle_w > a7.p_core_active_w + a7.p_cluster_idle_w);
    }

    #[test]
    fn poll_power_below_active() {
        for t in [ClusterTuning::a15(), ClusterTuning::mid(), ClusterTuning::a7()] {
            assert!(t.p_core_poll_w(POLL_FACTOR) < t.p_core_active_w);
            assert!(t.p_core_poll_w(POLL_FACTOR) > 0.5 * t.p_core_active_w);
        }
    }

    #[test]
    fn cluster_scale_monotone_nonincreasing() {
        for t in [ClusterTuning::a15(), ClusterTuning::mid(), ClusterTuning::a7()] {
            for n in 1..8 {
                assert!(t.scale(n + 1) <= t.scale(n));
            }
        }
    }

    #[test]
    fn big_peak_roughly_4x_little() {
        let ratio = PEAK_GFLOPS_BIG / PEAK_GFLOPS_LITTLE;
        assert!((4.0..5.0).contains(&ratio), "ratio {ratio}");
    }

    #[test]
    fn descriptor_peaks_match_reference_constants() {
        // The Exynos descriptor must derive exactly the calibrated peaks.
        let soc = SocSpec::exynos5422();
        assert!((soc[BIG].core.peak_gflops() - PEAK_GFLOPS_BIG).abs() < 1e-12);
        assert!((soc[LITTLE].core.peak_gflops() - PEAK_GFLOPS_LITTLE).abs() < 1e-12);
    }

    #[test]
    fn exynos_tuning_matches_original_tables() {
        // The per-cluster tuning that moved into the descriptor must
        // stay bit-for-bit the original calibration tables.
        let soc = SocSpec::exynos5422();
        let b = &soc[BIG].tuning;
        assert_eq!((b.hk, b.hm), (42.0, 6.0));
        assert_eq!(b.cluster_scale, vec![1.0, 1.0, 0.966, 0.814]);
        assert_eq!(
            (b.pack_bw_gbs, b.barrier_s, b.grab_s),
            (2.0, 3.0e-6, 1.5e-6)
        );
        assert_eq!((b.p_core_active_w, b.p_cluster_idle_w), (1.80, 0.60));
        let l = &soc[LITTLE].tuning;
        assert_eq!((l.hk, l.hm), (35.2, 8.0));
        assert_eq!(
            (l.pack_bw_gbs, l.barrier_s, l.grab_s),
            (0.8, 8.0e-6, 4.0e-6)
        );
        assert_eq!((l.p_core_active_w, l.p_cluster_idle_w), (0.28, 0.12));
        assert_eq!((b.l2_fill, l.l2_fill), (0.5525, 0.4297));
    }
}
