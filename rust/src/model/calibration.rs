//! Calibration constants for the performance and energy models.
//!
//! Every constant here is anchored to a number the paper reports
//! (§3.4, §4, Figs. 5/7/9/10/12); the unit tests at the bottom of
//! `model/mod.rs` and `energy/mod.rs` assert the anchors, so a change
//! that silently un-calibrates the reproduction fails `cargo test`.
//!
//! Anchors:
//! * single Cortex-A15 core at (mc,kc)=(152,952): ≈ 2.85–2.95 GFLOPS;
//!   cluster scaling ≈ [1, 2, 2.9, 3.25]× (the 4th core adds only
//!   ≈ 1.4 GFLOPS; peak ≈ 9.6 GFLOPS) — §3.4;
//! * single Cortex-A7 core at (80,352): ≈ 0.58–0.62 GFLOPS; cluster
//!   ≈ linear to ≈ 2.3–2.4 GFLOPS — §3.4;
//! * A7 running A15-optimal parameters: ≈ ×0.75–0.85 of its optimum
//!   (drives: SSS ≈ 40 % of A15-only (§4), SAS optimum ratio 5–6
//!   (Fig. 9), CA-SAS gains confined to ratios < 5 (Fig. 10));
//! * energy: best A15 efficiency with 3 cores (+25–40 % over 1 core),
//!   full-A7 ≈ 2× single-A7, full-A7 > single-A15, full-A7 ≈ full-A15,
//!   SSS by far the worst (§3.4, Figs. 5/7).

use crate::soc::CoreType;

/// Ideal peak double-precision GFLOPS of one core at the micro-kernel
/// (paper's hand-tuned 4×4 kernel): freq × flops/cycle.
pub const PEAK_GFLOPS_BIG: f64 = 3.2; // 1.6 GHz × 2 dp-flops/cycle
pub const PEAK_GFLOPS_LITTLE: f64 = 0.7; // 1.4 GHz × 0.5 dp-flops/cycle

/// Half-saturation constants of the amortization curves
/// eff_k(kc) = kc/(kc + HK), eff_m(m_rows) = m/(m + HM).
///
/// eff_k amortizes the per-micro-kernel C load/store + loop overhead
/// over the kc rank-1 updates; eff_m amortizes warming the `Br`
/// micro-panel into L1 over the rows a thread sweeps per jr column.
/// Ratios HK/HM are chosen so the model's (mc,kc) optimum under the L2
/// budget lands at the paper's Fig. 4 optima (DESIGN.md §5).
pub const HK_BIG: f64 = 42.0;
pub const HM_BIG: f64 = 6.0;
pub const HK_LITTLE: f64 = 35.2;
pub const HM_LITTLE: f64 = 8.0;

/// Per-core throughput multiplier as a function of the number of active
/// cores in the same cluster (index = active−1). Models shared-L2 and
/// bus contention: the A15 cluster saturates at the 4th core (§3.4:
/// “the utilization of the fourth core yields a smaller increase”).
pub const CLUSTER_SCALE_BIG: [f64; 4] = [1.0, 1.0, 0.966, 0.814];
pub const CLUSTER_SCALE_LITTLE: [f64; 4] = [1.0, 1.0, 1.0, 1.0];

/// Mild DRAM interference when both clusters are computing at once.
pub const BOTH_CLUSTERS_FACTOR: f64 = 0.99;

/// Effective packing bandwidth per core, GB/s (source read + packed
/// write combined). Packing is parallelized across a cluster's threads.
pub const PACK_BW_GBS_BIG: f64 = 2.0;
pub const PACK_BW_GBS_LITTLE: f64 = 0.8;

/// Synchronization overheads (seconds). Barriers close every packing
/// phase; the grab cost is the §5.4 critical section that hands out
/// dynamic Loop-3 chunks.
pub const BARRIER_S_BIG: f64 = 3.0e-6;
pub const BARRIER_S_LITTLE: f64 = 8.0e-6;
pub const GRAB_S_BIG: f64 = 1.5e-6;
pub const GRAB_S_LITTLE: f64 = 4.0e-6;

/// ---- Power model (energy/mod.rs), Watts ------------------------------
/// Baselines are charged for the whole run; per-core increments apply
/// while a core computes (ACTIVE) or spin-waits (POLL — the paper notes
/// idle-but-polling fast threads burn energy, §5.2.2).
pub const P_CLUSTER_IDLE_BIG: f64 = 0.60;
pub const P_CLUSTER_IDLE_LITTLE: f64 = 0.12;
pub const P_CORE_ACTIVE_BIG: f64 = 1.80;
pub const P_CORE_ACTIVE_LITTLE: f64 = 0.28;
/// Polling (spin-wait) draws a fraction of active power.
pub const POLL_FACTOR: f64 = 0.70;
pub const P_DRAM_IDLE: f64 = 0.18;
pub const P_GPU_IDLE: f64 = 0.05;
/// DRAM dynamic energy per byte moved (DDR3-class).
pub const DRAM_NJ_PER_BYTE: f64 = 0.0625;

/// pmlib sampling period (§3.2): 250 ms.
pub const PMLIB_SAMPLE_PERIOD_S: f64 = 0.25;

pub fn peak_gflops(core: CoreType) -> f64 {
    match core {
        CoreType::Big => PEAK_GFLOPS_BIG,
        CoreType::Little => PEAK_GFLOPS_LITTLE,
    }
}

/// Micro-kernel register-blocking factor (§6 future work: per-core-type
/// micro-kernels with their own mr×nr). The paper's hand-tuned kernel is
/// 4×4 on both cores; an 8×4 blocking halves the `Br` load traffic per
/// flop and helps the out-of-order A15 (+5 %), but the added register
/// pressure hurts the in-order A7 (−3 %). Other blockings are served by
/// the generic path at a small penalty.
pub fn register_block_factor(core: CoreType, mr: usize, nr: usize) -> f64 {
    match (core, mr, nr) {
        (_, 4, 4) => 1.0,
        (CoreType::Big, 8, 4) => 1.05,
        (CoreType::Little, 8, 4) => 0.97,
        _ => 0.93,
    }
}

pub fn hk(core: CoreType) -> f64 {
    match core {
        CoreType::Big => HK_BIG,
        CoreType::Little => HK_LITTLE,
    }
}

pub fn hm(core: CoreType) -> f64 {
    match core {
        CoreType::Big => HM_BIG,
        CoreType::Little => HM_LITTLE,
    }
}

/// Cluster contention multiplier for `active` busy cores (1-based).
pub fn cluster_scale(core: CoreType, active: usize) -> f64 {
    assert!(active >= 1, "need at least one active core");
    let table = match core {
        CoreType::Big => &CLUSTER_SCALE_BIG,
        CoreType::Little => &CLUSTER_SCALE_LITTLE,
    };
    // Clamp for ablation SoCs with more cores per cluster than Exynos.
    table[(active - 1).min(table.len() - 1)]
}

pub fn pack_bw_gbs(core: CoreType) -> f64 {
    match core {
        CoreType::Big => PACK_BW_GBS_BIG,
        CoreType::Little => PACK_BW_GBS_LITTLE,
    }
}

pub fn barrier_s(core: CoreType) -> f64 {
    match core {
        CoreType::Big => BARRIER_S_BIG,
        CoreType::Little => BARRIER_S_LITTLE,
    }
}

pub fn grab_s(core: CoreType) -> f64 {
    match core {
        CoreType::Big => GRAB_S_BIG,
        CoreType::Little => GRAB_S_LITTLE,
    }
}

pub fn p_core_active(core: CoreType) -> f64 {
    match core {
        CoreType::Big => P_CORE_ACTIVE_BIG,
        CoreType::Little => P_CORE_ACTIVE_LITTLE,
    }
}

pub fn p_core_poll(core: CoreType) -> f64 {
    p_core_active(core) * POLL_FACTOR
}

pub fn p_cluster_idle(core: CoreType) -> f64 {
    match core {
        CoreType::Big => P_CLUSTER_IDLE_BIG,
        CoreType::Little => P_CLUSTER_IDLE_LITTLE,
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn idle_big_cluster_exceeds_active_little_core() {
        // Paper §3.4: "the Cortex-A15 cluster in idle state already
        // dissipates more power than a single Cortex-A7 core in execution".
        assert!(P_CLUSTER_IDLE_BIG > P_CORE_ACTIVE_LITTLE + P_CLUSTER_IDLE_LITTLE);
    }

    #[test]
    fn poll_power_below_active() {
        for c in CoreType::ALL {
            assert!(p_core_poll(c) < p_core_active(c));
            assert!(p_core_poll(c) > 0.5 * p_core_active(c));
        }
    }

    #[test]
    fn cluster_scale_monotone_nonincreasing() {
        for c in CoreType::ALL {
            for n in 1..4 {
                assert!(cluster_scale(c, n + 1) <= cluster_scale(c, n));
            }
        }
    }

    #[test]
    fn cluster_scale_clamps_beyond_table() {
        assert_eq!(cluster_scale(CoreType::Big, 8), CLUSTER_SCALE_BIG[3]);
    }

    #[test]
    fn big_peak_roughly_4x_little() {
        let ratio = PEAK_GFLOPS_BIG / PEAK_GFLOPS_LITTLE;
        assert!((4.0..5.0).contains(&ratio), "ratio {ratio}");
    }

    #[test]
    #[should_panic]
    fn zero_active_cores_rejected() {
        cluster_scale(CoreType::Big, 0);
    }
}
