//! Multi-level cache hierarchy (private L1d → shared L2 → optional
//! L3/SLC), inclusive-ish: an access goes to L1; on L1 miss it goes to
//! L2; on L2 miss it goes to the system-level cache when the SoC has one
//! ([`crate::soc::SocSpec::l3`]); whatever misses the last level costs a
//! DRAM transfer. The two-level default mirrors the Exynos 5422
//! organization the paper's blocking analysis targets (Fig. 2: `Br` in
//! L1, `Ac` in L2); the third level models the Intel/Apple P/E shapes
//! of the ROADMAP's hierarchy item.

use crate::cache::sim::CacheSim;
use crate::soc::{CacheGeometry, ClusterId, ClusterSpec, SocSpec};

/// Per-level outcome counters for a hierarchy walk.
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq)]
pub struct LevelStats {
    pub l1_hits: u64,
    pub l2_hits: u64,
    /// Hits in the system-level cache; always 0 on two-level SoCs.
    pub l3_hits: u64,
    pub dram_accesses: u64,
}

impl LevelStats {
    pub fn total(&self) -> u64 {
        self.l1_hits + self.l2_hits + self.l3_hits + self.dram_accesses
    }
    pub fn l1_hit_rate(&self) -> f64 {
        if self.total() == 0 {
            0.0
        } else {
            self.l1_hits as f64 / self.total() as f64
        }
    }
    pub fn dram_rate(&self) -> f64 {
        if self.total() == 0 {
            0.0
        } else {
            self.dram_accesses as f64 / self.total() as f64
        }
    }
}

/// One core's view: private L1 plus a (possibly shared) L2. For
/// multi-core cluster studies, create one `Hierarchy` per core sharing
/// an L2 partition, or model the shared L2 as `size / active_cores`
/// (the approximation the paper itself uses when discussing Loop 3
/// parallelization shrinking the effective `Ac`).
#[derive(Debug, Clone)]
pub struct Hierarchy {
    pub l1: CacheSim,
    pub l2: CacheSim,
    /// System-level cache behind the L2, when the SoC has one.
    pub l3: Option<CacheSim>,
    pub stats: LevelStats,
}

impl Hierarchy {
    pub fn new(l1_geo: CacheGeometry, l2_geo: CacheGeometry) -> Self {
        Hierarchy {
            l1: CacheSim::new(l1_geo),
            l2: CacheSim::new(l2_geo),
            l3: None,
            stats: LevelStats::default(),
        }
    }

    /// Attach an L3/SLC level behind the L2 (builder style).
    pub fn with_l3(mut self, l3_geo: CacheGeometry) -> Self {
        self.l3 = Some(CacheSim::new(l3_geo));
        self
    }

    /// Build from a cluster spec, optionally dividing the shared L2
    /// among `sharers` active cores.
    pub fn for_cluster(cluster: &ClusterSpec, sharers: usize) -> Self {
        assert!(sharers >= 1 && sharers <= cluster.num_cores);
        let l2 = cluster.l2;
        // Keep geometry legal: shrink ways, not sets, when dividing.
        let ways = (l2.associativity / sharers).max(1);
        let share = CacheGeometry::new(
            l2.size_bytes / l2.associativity * ways,
            ways,
            l2.line_bytes,
        );
        Hierarchy::new(cluster.core.l1d, share)
    }

    /// Build one core's view within a whole-SoC descriptor: the
    /// cluster's L1/L2 as in [`Hierarchy::for_cluster`], plus the SoC's
    /// system-level cache when present.
    pub fn for_soc_cluster(soc: &SocSpec, id: ClusterId, sharers: usize) -> Self {
        let h = Hierarchy::for_cluster(&soc[id], sharers);
        match soc.l3 {
            Some(geo) => h.with_l3(geo),
            None => h,
        }
    }

    /// Access one byte address through L1 → L2 → (L3 →) DRAM.
    pub fn access(&mut self, addr: u64) {
        if self.l1.access(addr).is_hit() {
            self.stats.l1_hits += 1;
            return;
        }
        if self.l2.access(addr).is_hit() {
            self.stats.l2_hits += 1;
            return;
        }
        if let Some(l3) = &mut self.l3 {
            if l3.access(addr).is_hit() {
                self.stats.l3_hits += 1;
                return;
            }
        }
        self.stats.dram_accesses += 1;
    }

    /// Access each cache line of a contiguous byte range once.
    pub fn access_range(&mut self, addr: u64, len_bytes: usize) {
        if len_bytes == 0 {
            return;
        }
        let line = self.l1.geometry().line_bytes as u64;
        let first = addr / line * line;
        let last = (addr + len_bytes as u64 - 1) / line * line;
        let mut a = first;
        loop {
            self.access(a);
            if a == last {
                break;
            }
            a += line;
        }
    }

    pub fn reset_stats(&mut self) {
        self.stats = LevelStats::default();
        self.l1.reset_stats();
        self.l2.reset_stats();
        if let Some(l3) = &mut self.l3 {
            l3.reset_stats();
        }
    }

    pub fn flush(&mut self) {
        self.l1.flush();
        self.l2.flush();
        if let Some(l3) = &mut self.l3 {
            l3.flush();
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::soc::SocSpec;

    fn small() -> Hierarchy {
        // L1: 512B (4 sets × 2 ways), L2: 4KiB (8 sets × 8 ways).
        Hierarchy::new(
            CacheGeometry::new(512, 2, 64),
            CacheGeometry::new(4096, 8, 64),
        )
    }

    #[test]
    fn l1_hit_after_first_touch() {
        let mut h = small();
        h.access(0x40);
        h.access(0x40);
        assert_eq!(h.stats.l1_hits, 1);
        assert_eq!(h.stats.dram_accesses, 1);
    }

    #[test]
    fn l2_catches_l1_capacity_spill() {
        let mut h = small();
        // Touch 32 lines (2KiB): exceeds L1 (8 lines) but fits L2 (64 lines).
        for i in 0..32u64 {
            h.access(i * 64);
        }
        h.stats = LevelStats::default();
        for i in 0..32u64 {
            h.access(i * 64);
        }
        assert_eq!(h.stats.dram_accesses, 0, "second sweep must not hit DRAM");
        assert!(h.stats.l2_hits > 0);
    }

    #[test]
    fn working_set_beyond_l2_reaches_dram() {
        let mut h = small();
        // 256 lines = 16KiB, 4× the L2.
        for _ in 0..2 {
            for i in 0..256u64 {
                h.access(i * 64);
            }
        }
        assert!(h.stats.dram_accesses > 256);
    }

    #[test]
    fn stats_total_equals_accesses() {
        let mut h = small();
        for i in 0..1000u64 {
            h.access((i * 37) % 8192);
        }
        assert_eq!(h.stats.total(), 1000);
        let rates = h.stats.l1_hit_rate() + h.stats.dram_rate();
        assert!(rates <= 1.0 + 1e-12);
    }

    #[test]
    fn cluster_constructor_uses_soc_geometry() {
        let soc = SocSpec::exynos5422();
        let h = Hierarchy::for_cluster(&soc[crate::soc::BIG], 1);
        assert_eq!(h.l1.geometry().size_bytes, 32 * 1024);
        assert_eq!(h.l2.geometry().size_bytes, 2 * 1024 * 1024);
    }

    #[test]
    fn shared_l2_partition_shrinks_with_sharers() {
        let soc = SocSpec::exynos5422();
        let h4 = Hierarchy::for_cluster(&soc[crate::soc::BIG], 4);
        assert_eq!(h4.l2.geometry().size_bytes, 512 * 1024);
        let h1 = Hierarchy::for_cluster(&soc[crate::soc::LITTLE], 1);
        assert_eq!(h1.l2.geometry().size_bytes, 512 * 1024);
    }

    #[test]
    fn access_range_walks_lines() {
        let mut h = small();
        h.access_range(0, 640); // 10 lines
        assert_eq!(h.stats.total(), 10);
    }

    #[test]
    fn reset_and_flush() {
        let mut h = small();
        h.access(0);
        h.reset_stats();
        assert_eq!(h.stats.total(), 0);
        h.flush();
        h.access(0);
        assert_eq!(h.stats.dram_accesses, 1);
    }

    #[test]
    fn l3_catches_l2_capacity_spill() {
        // L3 of 16 KiB (4× the L2): a working set that spills the L2
        // must be served by the SLC, not DRAM, on the second sweep.
        let mut h = small().with_l3(CacheGeometry::new(16 * 1024, 8, 64));
        for i in 0..128u64 {
            h.access(i * 64); // 8 KiB: 2× the L2, half the L3
        }
        h.stats = LevelStats::default();
        for i in 0..128u64 {
            h.access(i * 64);
        }
        assert_eq!(h.stats.dram_accesses, 0, "second sweep served by SLC");
        assert!(h.stats.l3_hits > 0);
        // A two-level hierarchy on the same trace pays DRAM instead.
        let mut two = small();
        for _ in 0..2 {
            for i in 0..128u64 {
                two.access(i * 64);
            }
        }
        assert!(two.stats.dram_accesses > 128);
    }

    #[test]
    fn soc_constructor_attaches_slc_only_when_present() {
        let pe = SocSpec::pe_hybrid();
        let h = Hierarchy::for_soc_cluster(&pe, crate::soc::LITTLE, 1);
        assert_eq!(
            h.l3.as_ref().map(|c| c.geometry().size_bytes),
            Some(12 * 1024 * 1024)
        );
        let exynos = Hierarchy::for_soc_cluster(&SocSpec::exynos5422(), crate::soc::BIG, 1);
        assert!(exynos.l3.is_none());
    }
}
