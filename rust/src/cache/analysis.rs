//! Analytical BLIS footprint analysis.
//!
//! The fast counterpart of the trace-driven simulator: given blocking
//! parameters and a core's cache geometry, report whether the micro-panel
//! `Br = kc×nr` fits the L1 budget and the macro-panel `Ac = mc×kc` fits
//! the L2 budget, and translate overflows into throughput penalties.
//!
//! Budgets are *effective* capacities — a fraction of the nominal cache
//! reserved for the resident panel, the rest left for the streaming
//! operands (the A micro-slice + C block through L1; the Bc stream + C
//! through L2). The fractions are calibrated so the model's optimum
//! lands at the paper's empirically-found parameters
//! (§3.3: A15 (mc,kc) = (152, 952), A7 (80, 352); §5.3: shared-kc A7
//! refit mc ≈ 32):
//!
//! * A15: Br(952×4×8) = 30.4 KiB ≈ 0.93 × 32 KiB L1 → `L1_FILL = 0.95`;
//!   Ac(152×952×8) = 1.158 MiB ≈ 0.552 × 2 MiB L2 → the A15 cluster's
//!   `tuning.l2_fill`.
//! * A7: Ac(80×352×8) = 225 KiB ≈ 0.43 × 512 KiB L2 → the A7 cluster's
//!   `tuning.l2_fill` (the in-order A7 needs more L2 headroom for the
//!   Bc stream).
//!
//! Overflow penalties are "soft floors": once a panel no longer fits,
//! the micro-kernel degrades towards a bandwidth-bound floor rather than
//! collapsing — matching the paper's observation that the A7 running
//! with A15-optimal parameters is slower but far from useless (the SAS
//! optimum ratio of 5–6 in Fig. 9 *is* that penalty, see DESIGN.md §8).

use crate::blis::params::BlisParams;
use crate::soc::{ClusterId, ClusterSpec, SocSpec};

/// Fraction of L1d usable by the resident `Br` micro-panel.
pub const L1_FILL: f64 = 0.95;

/// Fraction of a system-level cache usable by one cluster's spilled
/// `Ac` panel (the SLC is shared by every cluster plus the `Bc`/C
/// streams, so the budget is conservative).
pub const L3_FILL: f64 = 0.50;

/// Penalty floors/slopes (dimensionless). See module docs.
const L1_OVERFLOW_FLOOR: f64 = 0.60;
const L1_OVERFLOW_SLOPE: f64 = 4.0;
const L2_OVERFLOW_FLOOR: f64 = 0.72;
const L2_OVERFLOW_SLOPE: f64 = 1.35;
/// Raised floor when an `Ac` spill is caught by the SLC: re-streams come
/// from the L3 at far better latency/bandwidth than DRAM, so the
/// bandwidth-bound asymptote is milder.
const L2_SLC_CAUGHT_FLOOR: f64 = 0.88;

/// Element size: the paper evaluates IEEE double precision throughout.
pub const ELEM_BYTES: usize = 8;

/// Report of panel footprints vs cache budgets for one configuration.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct FitReport {
    pub br_bytes: usize,
    pub ac_bytes: usize,
    pub bc_bytes: usize,
    pub l1_budget_bytes: f64,
    pub l2_budget_bytes: f64,
    /// br_bytes / l1_budget (≤ 1 means fits).
    pub l1_pressure: f64,
    /// ac_bytes / l2_budget.
    pub l2_pressure: f64,
    /// ac_bytes / l3_budget on SoCs with a system-level cache; `None`
    /// on two-level hierarchies (all paper presets).
    pub l3_pressure: Option<f64>,
}

impl FitReport {
    pub fn br_fits(&self) -> bool {
        self.l1_pressure <= 1.0
    }
    pub fn ac_fits(&self) -> bool {
        self.l2_pressure <= 1.0
    }
    /// Whether a spilled `Ac` is caught by the system-level cache
    /// (`false` when there is no L3).
    pub fn ac_fits_l3(&self) -> bool {
        self.l3_pressure.is_some_and(|p| p <= 1.0)
    }

    /// Throughput multiplier from L1 pressure (1.0 when `Br` fits).
    pub fn l1_penalty(&self) -> f64 {
        soft_floor_penalty(self.l1_pressure, L1_OVERFLOW_FLOOR, L1_OVERFLOW_SLOPE)
    }

    /// Throughput multiplier from L2 pressure (1.0 when `Ac` fits).
    /// When the SoC has a system-level cache that catches the spill,
    /// the overflow decays towards a milder (SLC-bandwidth) floor than
    /// the DRAM-bound one.
    pub fn l2_penalty(&self) -> f64 {
        let floor = if self.ac_fits_l3() {
            L2_SLC_CAUGHT_FLOOR
        } else {
            L2_OVERFLOW_FLOOR
        };
        soft_floor_penalty(self.l2_pressure, floor, L2_OVERFLOW_SLOPE)
    }

    pub fn combined_penalty(&self) -> f64 {
        self.l1_penalty() * self.l2_penalty()
    }
}

/// 1.0 while `pressure ≤ 1`; beyond that decays hyperbolically towards
/// `floor` with rate `slope` (bandwidth-bound asymptote).
fn soft_floor_penalty(pressure: f64, floor: f64, slope: f64) -> f64 {
    if pressure <= 1.0 {
        1.0
    } else {
        let overflow = pressure - 1.0;
        floor + (1.0 - floor) / (1.0 + slope * overflow)
    }
}

/// Analytical footprint model bound to one cluster's cache geometry.
/// The `Ac` fill fraction comes from the cluster's own tuning (the
/// in-order A7 needs more L2 headroom for the `Bc` stream than the
/// out-of-order A15), so any N-cluster topology carries its own budget.
#[derive(Debug, Clone)]
pub struct FootprintAnalysis {
    l1_bytes: usize,
    l2_bytes: usize,
    l2_fill: f64,
    /// System-level cache capacity, when the SoC has one.
    l3_bytes: Option<usize>,
}

impl FootprintAnalysis {
    pub fn for_cluster(cluster: &ClusterSpec) -> Self {
        FootprintAnalysis {
            l1_bytes: cluster.core.l1d.size_bytes,
            l2_bytes: cluster.l2.size_bytes,
            l2_fill: cluster.tuning.l2_fill,
            l3_bytes: None,
        }
    }

    /// Like [`FootprintAnalysis::for_cluster`], additionally picking up
    /// the SoC's system-level cache so spilled `Ac` panels can be
    /// credited to the SLC instead of DRAM. Identical to the two-level
    /// analysis when `soc.l3` is `None` (all paper presets).
    pub fn for_cluster_in(soc: &SocSpec, id: ClusterId) -> Self {
        let mut a = FootprintAnalysis::for_cluster(&soc[id]);
        a.l3_bytes = soc.l3.map(|g| g.size_bytes);
        a
    }

    pub fn l2_fill(&self) -> f64 {
        self.l2_fill
    }

    /// L1 budget in bytes for the resident Br micro-panel.
    pub fn l1_budget(&self) -> f64 {
        L1_FILL * self.l1_bytes as f64
    }

    /// L2 budget in bytes for the resident Ac macro-panel. When `sharers`
    /// cores pack independent `Ac` panels into the same physical L2
    /// (Loop 3 parallelized within a cluster, paper §3.1), each gets a
    /// 1/sharers slice.
    pub fn l2_budget(&self, sharers: usize) -> f64 {
        assert!(sharers >= 1);
        self.l2_fill() * self.l2_bytes as f64 / sharers as f64
    }

    /// Full fit report for a parameter set.
    pub fn fit(&self, p: &BlisParams) -> FitReport {
        self.fit_shared(p, 1)
    }

    /// Fit report with `sharers` cores dividing the L2 (see `l2_budget`).
    pub fn fit_shared(&self, p: &BlisParams, sharers: usize) -> FitReport {
        let br = p.kc * p.nr * ELEM_BYTES;
        let ac = p.mc * p.kc * ELEM_BYTES;
        let bc = p.kc * p.nc * ELEM_BYTES;
        let l1b = self.l1_budget();
        let l2b = self.l2_budget(sharers);
        FitReport {
            br_bytes: br,
            ac_bytes: ac,
            bc_bytes: bc,
            l1_budget_bytes: l1b,
            l2_budget_bytes: l2b,
            l1_pressure: br as f64 / l1b,
            l2_pressure: ac as f64 / l2b,
            l3_pressure: self.l3_bytes.map(|b| ac as f64 / (L3_FILL * b as f64)),
        }
    }

    /// Largest `kc` (multiple of 8) whose `Br` fits the L1 budget —
    /// the analytic upper bound on the Fig. 4 search range.
    pub fn max_kc_for_l1(&self, nr: usize) -> usize {
        let raw = self.l1_budget() / (nr * ELEM_BYTES) as f64;
        (raw as usize) / 8 * 8
    }

    /// Largest `mc` (multiple of `mr`) whose `Ac` fits the L2 budget
    /// at the given `kc`.
    pub fn max_mc_for_l2(&self, kc: usize, mr: usize) -> usize {
        let raw = self.l2_budget(1) / (kc * ELEM_BYTES) as f64;
        ((raw as usize) / mr * mr).max(mr)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::blis::params::BlisParams;
    use crate::soc::{SocSpec, BIG, LITTLE};

    fn big() -> FootprintAnalysis {
        FootprintAnalysis::for_cluster(&SocSpec::exynos5422()[BIG])
    }
    fn little() -> FootprintAnalysis {
        FootprintAnalysis::for_cluster(&SocSpec::exynos5422()[LITTLE])
    }

    #[test]
    fn paper_optimal_a15_params_fit() {
        let fit = big().fit(&BlisParams::a15_opt());
        assert!(fit.br_fits(), "Br must fit A15 L1: {fit:?}");
        assert!(fit.ac_fits(), "Ac must fit A15 L2: {fit:?}");
        assert_eq!(fit.combined_penalty(), 1.0);
    }

    #[test]
    fn paper_optimal_a7_params_fit() {
        let fit = little().fit(&BlisParams::a7_opt());
        assert!(fit.br_fits());
        assert!(fit.ac_fits());
    }

    #[test]
    fn a15_params_overflow_a7_l2() {
        // The §4 architecture-oblivious mismatch: Ac = 1.16 MiB ≫ 512 KiB.
        let fit = little().fit(&BlisParams::a15_opt());
        assert!(fit.br_fits(), "Br still fits (same 32 KiB L1)");
        assert!(!fit.ac_fits());
        assert!(fit.l2_pressure > 4.0 && fit.l2_pressure < 6.5);
        // Calibrated penalty ≈ 0.75 → SAS ratio optimum lands at 5–6.
        let pen = fit.l2_penalty();
        assert!((0.72..0.80).contains(&pen), "penalty {pen}");
    }

    #[test]
    fn footprint_numbers_match_paper() {
        let fit = big().fit(&BlisParams::a15_opt());
        assert_eq!(fit.br_bytes, 952 * 4 * 8); // 30464 B ≈ 29.75 KiB
        assert_eq!(fit.ac_bytes, 152 * 952 * 8); // ≈ 1.158 MiB
        let fit7 = little().fit(&BlisParams::a7_opt());
        assert_eq!(fit7.ac_bytes, 80 * 352 * 8); // 225 KiB
    }

    #[test]
    fn penalty_is_one_inside_budget_and_monotone_outside() {
        let mut last = 1.0;
        for pressure in [0.5, 1.0, 1.2, 2.0, 4.0, 8.0] {
            let p = soft_floor_penalty(pressure, 0.72, 1.35);
            assert!(p <= last + 1e-12, "penalty must be non-increasing");
            assert!(p >= 0.72, "never below floor");
            last = p;
        }
        assert_eq!(soft_floor_penalty(0.9, 0.72, 1.35), 1.0);
    }

    #[test]
    fn max_kc_bound_contains_paper_value() {
        let bound = big().max_kc_for_l1(4);
        assert!(bound >= 952, "bound {bound} must admit the paper's kc");
        assert!(bound < 1100);
    }

    #[test]
    fn max_mc_bound_near_paper_value() {
        let bound = big().max_mc_for_l2(952, 4);
        assert!((140..=168).contains(&bound), "bound {bound}");
        let bound7 = little().max_mc_for_l2(352, 4);
        assert!((72..=92).contains(&bound7), "bound {bound7}");
    }

    #[test]
    fn shared_kc_refit_lands_near_paper_mc32() {
        // §5.3: kc pinned to 952 on the A7 → mc refits to ≈ 32.
        let bound = little().max_mc_for_l2(952, 4);
        assert!((24..=40).contains(&bound), "bound {bound}");
    }

    #[test]
    fn l2_sharers_divide_budget() {
        let a = little();
        assert!((a.l2_budget(4) - a.l2_budget(1) / 4.0).abs() < 1e-9);
        let fit_shared = a.fit_shared(&BlisParams::a7_opt(), 4);
        assert!(!fit_shared.ac_fits(), "4 sharers: 225 KiB > 512/4 KiB budget");
    }

    #[test]
    fn bc_footprint_reported() {
        let fit = big().fit(&BlisParams::a15_opt());
        assert_eq!(fit.bc_bytes, 952 * 4096 * 8);
    }

    #[test]
    fn two_level_socs_report_no_l3_pressure() {
        let a = FootprintAnalysis::for_cluster_in(&SocSpec::exynos5422(), LITTLE);
        let fit = a.fit(&BlisParams::a15_opt());
        assert_eq!(fit.l3_pressure, None);
        assert!(!fit.ac_fits_l3());
        // Bit-for-bit with the plain two-level analysis.
        let plain = little().fit(&BlisParams::a15_opt());
        assert_eq!(fit.l2_penalty(), plain.l2_penalty());
        assert_eq!(fit.combined_penalty(), plain.combined_penalty());
    }

    #[test]
    fn slc_catches_ac_spill_on_pe_hybrid() {
        // The P/E preset: P-class Ac (1.16 MiB) overflows the E
        // cluster's 512 KiB L2 but fits the 12 MiB SLC budget, so the
        // overflow penalty is milder than the DRAM-bound floor.
        let pe = SocSpec::pe_hybrid();
        let with_slc = FootprintAnalysis::for_cluster_in(&pe, LITTLE);
        let fit = with_slc.fit(&BlisParams::a15_opt());
        assert!(!fit.ac_fits(), "Ac must overflow the E-cluster L2");
        assert!(fit.ac_fits_l3(), "…and land in the SLC: {fit:?}");
        let without = FootprintAnalysis::for_cluster(&pe[LITTLE]).fit(&BlisParams::a15_opt());
        assert!(
            fit.l2_penalty() > without.l2_penalty(),
            "SLC-caught spill {} must beat DRAM-bound spill {}",
            fit.l2_penalty(),
            without.l2_penalty()
        );
        // Inside-budget configurations are not affected by the SLC.
        let small_fit = with_slc.fit(&BlisParams::a7_opt());
        assert_eq!(small_fit.combined_penalty(), 1.0);
    }

    #[test]
    fn ac_overflowing_the_slc_too_falls_back_to_dram_floor() {
        // A tiny 1 MiB SLC: the 1.16 MiB Ac overflows it as well, so the
        // penalty reverts to the two-level DRAM-bound floor.
        let soc = SocSpec::exynos5422()
            .with_l3(crate::soc::CacheGeometry::new(1024 * 1024, 16, 64));
        let a = FootprintAnalysis::for_cluster_in(&soc, LITTLE);
        let fit = a.fit(&BlisParams::a15_opt());
        assert!(!fit.ac_fits_l3());
        assert_eq!(fit.l2_penalty(), little().fit(&BlisParams::a15_opt()).l2_penalty());
    }
}
