//! Set-associative cache simulation and BLIS footprint analysis.
//!
//! The paper's central configuration insight (§3.3) is that the blocking
//! parameters must be chosen so the `kc×nr` micro-panel `Br` streams from
//! L1 while the `mc×kc` macro-panel `Ac` stays resident in L2 — with
//! *different* optima for the Cortex-A15 (2 MiB L2) and Cortex-A7
//! (512 KiB L2). We reproduce that machinery with:
//!
//! * [`sim::CacheSim`] — an exact set-associative LRU cache simulator,
//!   used as the ground-truth substrate (trace-driven) in tests and the
//!   Fig. 4 ablation;
//! * [`hierarchy::Hierarchy`] — a two-level (L1d + shared L2) stack of
//!   simulators;
//! * [`trace`] — synthetic address-trace generators for the micro-kernel
//!   and the packing routines, mirroring the access pattern of Fig. 2;
//! * [`analysis`] — the fast analytical footprint model consumed by the
//!   performance model on every simulated micro-kernel (trace simulation
//!   would be far too slow inside the DES loop).

pub mod analysis;
pub mod hierarchy;
pub mod sim;
pub mod trace;

pub use analysis::{FitReport, FootprintAnalysis};
pub use hierarchy::{Hierarchy, LevelStats};
pub use sim::{AccessResult, CacheSim};
