//! Synthetic address-trace generators for the BLIS GEMM inner kernels.
//!
//! These reproduce, at cache-line granularity, the access pattern of
//! Fig. 2: the micro-kernel streams an `mr×kc` slice of `Ac` and the
//! `kc×nr` micro-panel `Br` while updating an `mr×nr` block of `C`;
//! Loop 4 sweeps `jr` so `Ac` is reused `⌈nc/nr⌉` times; the packing
//! routines stream source matrices into the contiguous packed buffers.
//!
//! Traces are *driven through* a [`crate::cache::Hierarchy`] to obtain
//! ground-truth miss rates. Tests (and the Fig. 4 ablation bench) use
//! them to validate the analytical model in [`crate::cache::analysis`]:
//! parameters inside budget ⇒ low L2-miss traffic for `Ac`; overflowing
//! parameters ⇒ DRAM traffic on every `Ac` sweep.

use crate::blis::params::BlisParams;
use crate::cache::hierarchy::Hierarchy;

/// Byte size of one f64 element.
const E: u64 = 8;

/// Disjoint virtual base addresses for the three buffers, spaced far
/// apart so the layouts never alias.
const AC_BASE: u64 = 0x1000_0000;
const BC_BASE: u64 = 0x2000_0000;
const C_BASE: u64 = 0x3000_0000;
const SRC_BASE: u64 = 0x4000_0000;

/// Drive one full macro-kernel (Loops 4+5 over an `mc×nc` block of C)
/// through the hierarchy. `mc_iters`/`nc_iters` default to the full
/// panel; tests shrink them to keep traces fast.
pub fn macro_kernel_trace(h: &mut Hierarchy, p: &BlisParams, nc_eff: usize, mc_eff: usize) {
    let kc = p.kc as u64;
    let (mr, nr) = (p.mr as u64, p.nr as u64);
    let n_jr = nc_eff.div_ceil(p.nr) as u64;
    let n_ir = mc_eff.div_ceil(p.mr) as u64;

    for jr in 0..n_jr {
        // Micro-panel Br for this jr: kc×nr contiguous in the packed Bc.
        let br_base = BC_BASE + jr * kc * nr * E;
        for ir in 0..n_ir {
            // A micro-slice: mr×kc contiguous in the packed Ac.
            let a_base = AC_BASE + ir * mr * kc * E;
            // The rank-1 update loop: stream A-slice and Br interleaved.
            // At line granularity, touching each line of both panels
            // models the streaming pattern faithfully.
            h.access_range(a_base, (mr * kc * E) as usize);
            h.access_range(br_base, (kc * nr * E) as usize);
            // C block: load + store of mr×nr.
            let c_base = C_BASE + (jr * n_ir + ir) * mr * nr * E;
            h.access_range(c_base, (mr * nr * E) as usize);
        }
    }
}

/// Packing of `Ac` (`mc×kc` from a column-major source with leading
/// dimension `ld` into the contiguous packed buffer).
pub fn pack_a_trace(h: &mut Hierarchy, p: &BlisParams, ld: usize) {
    // Source: mc rows × kc cols, column stride ld.
    for col in 0..p.kc as u64 {
        let col_base = SRC_BASE + col * ld as u64 * E;
        h.access_range(col_base, p.mc * 8);
    }
    // Destination: contiguous write of mc×kc.
    h.access_range(AC_BASE, p.mc * p.kc * 8);
}

/// Result of a residency experiment: DRAM transfer counts for the
/// first (cold) and second (warm) macro-kernel sweeps.
#[derive(Debug, Clone, Copy)]
pub struct ResidencyProbe {
    pub cold_dram: u64,
    pub warm_dram: u64,
}

impl ResidencyProbe {
    /// Warm-to-cold DRAM ratio: ≈0 when `Ac`+`Br` stay resident,
    /// ≈1 when the working set thrashes.
    pub fn warm_ratio(&self) -> f64 {
        if self.cold_dram == 0 {
            0.0
        } else {
            self.warm_dram as f64 / self.cold_dram as f64
        }
    }
}

/// Run two identical macro-kernel sweeps and compare DRAM traffic:
/// the second sweep re-reads the same panels, so if they fit the
/// hierarchy its DRAM traffic collapses.
pub fn residency_probe(h: &mut Hierarchy, p: &BlisParams, nc_eff: usize, mc_eff: usize) -> ResidencyProbe {
    h.flush();
    h.reset_stats();
    macro_kernel_trace(h, p, nc_eff, mc_eff);
    let cold = h.stats.dram_accesses;
    h.reset_stats();
    macro_kernel_trace(h, p, nc_eff, mc_eff);
    ResidencyProbe {
        cold_dram: cold,
        warm_dram: h.stats.dram_accesses,
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::soc::{SocSpec, BIG, LITTLE};

    /// A7-geometry hierarchy (1 sharer).
    fn little_h() -> Hierarchy {
        Hierarchy::for_cluster(&SocSpec::exynos5422()[LITTLE], 1)
    }

    #[test]
    fn a7_opt_params_stay_resident() {
        // Ac(225 KiB) + Br fit the 512 KiB L2 → warm sweep ~free.
        let mut h = little_h();
        // One jr-sweep worth: nc_eff small to keep the trace quick but
        // larger than nr so Ac is reused.
        let p = BlisParams::a7_opt();
        let probe = residency_probe(&mut h, &p, 64, p.mc);
        assert!(
            probe.warm_ratio() < 0.05,
            "expected residency, got warm ratio {} ({:?})",
            probe.warm_ratio(),
            probe
        );
    }

    #[test]
    fn a15_params_thrash_a7_l2() {
        // The §4 mismatch: Ac(1.16 MiB) ≫ 512 KiB L2 → warm sweep still
        // pulls most lines from DRAM.
        let mut h = little_h();
        let p = BlisParams::a15_opt();
        let probe = residency_probe(&mut h, &p, 64, p.mc);
        assert!(
            probe.warm_ratio() > 0.5,
            "expected thrashing, got warm ratio {} ({:?})",
            probe.warm_ratio(),
            probe
        );
    }

    #[test]
    fn a15_params_fit_a15_l2() {
        let mut h = Hierarchy::for_cluster(&SocSpec::exynos5422()[BIG], 1);
        let p = BlisParams::a15_opt();
        let probe = residency_probe(&mut h, &p, 64, p.mc);
        assert!(
            probe.warm_ratio() < 0.05,
            "warm ratio {} ({:?})",
            probe.warm_ratio(),
            probe
        );
    }

    #[test]
    fn shared_kc_refit_restores_a7_residency() {
        // §5.3: (mc,kc) = (32, 952) fits the A7 L2 again. Keep the jr
        // sweep narrow (16 columns) so the streamed Bc region itself
        // does not exceed the cache — Bc is *expected* to stream; the
        // claim under test is Ac residency.
        let mut h = little_h();
        let p = BlisParams::a7_shared_kc();
        let probe = residency_probe(&mut h, &p, 16, p.mc);
        assert!(probe.warm_ratio() < 0.05, "warm ratio {}", probe.warm_ratio());
    }

    #[test]
    fn br_and_ac_stream_from_cache_at_optimal_kc() {
        // Within one jr column the working set is Ac (1.16 MiB) + one Br
        // (30 KiB): both fit the A15 L2, so a warm re-sweep must be
        // served from the hierarchy without DRAM traffic.
        let mut h = Hierarchy::for_cluster(&SocSpec::exynos5422()[BIG], 1);
        let p = BlisParams::a15_opt();
        h.flush();
        macro_kernel_trace(&mut h, &p, p.nr, p.mc); // single jr column
        let dram_cold = h.stats.dram_accesses;
        h.reset_stats();
        macro_kernel_trace(&mut h, &p, p.nr, p.mc);
        assert!(
            (h.stats.dram_accesses as f64) < 0.02 * dram_cold as f64 + 1.0,
            "warm dram {} vs cold {}",
            h.stats.dram_accesses,
            dram_cold
        );
        // The Br re-reads across the 38 ir iterations are hierarchy hits.
        assert!(h.stats.l1_hit_rate() + h.stats.l2_hits as f64 / h.stats.total() as f64 > 0.95);
    }

    #[test]
    fn pack_a_touches_source_and_dest() {
        let mut h = little_h();
        let p = BlisParams::a7_opt();
        pack_a_trace(&mut h, &p, 2048);
        // ≥ one access per destination line.
        assert!(h.stats.total() as usize >= p.mc * p.kc * 8 / 64);
    }

    #[test]
    fn probe_is_deterministic() {
        let p = BlisParams::a7_opt();
        let a = residency_probe(&mut little_h(), &p, 32, p.mc);
        let b = residency_probe(&mut little_h(), &p, 32, p.mc);
        assert_eq!(a.cold_dram, b.cold_dram);
        assert_eq!(a.warm_dram, b.warm_dram);
    }
}
