//! Exact set-associative LRU cache simulator.
//!
//! Deliberately simple and exhaustively tested: a vector of sets, each a
//! small LRU-ordered list of tags. Used trace-driven — fast enough for
//! the validation workloads (millions of accesses), while the hot DES
//! path uses the analytical model in [`crate::cache::analysis`].

use crate::soc::CacheGeometry;

/// Outcome of a single access.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum AccessResult {
    Hit,
    /// Miss; `evicted` carries the victim line's base address, if any.
    Miss { evicted: Option<u64> },
}

impl AccessResult {
    pub fn is_hit(self) -> bool {
        matches!(self, AccessResult::Hit)
    }
}

/// Hit/miss counters.
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq)]
pub struct CacheStats {
    pub accesses: u64,
    pub hits: u64,
    pub misses: u64,
    pub evictions: u64,
}

impl CacheStats {
    pub fn miss_rate(&self) -> f64 {
        if self.accesses == 0 {
            0.0
        } else {
            self.misses as f64 / self.accesses as f64
        }
    }
    pub fn hit_rate(&self) -> f64 {
        1.0 - self.miss_rate()
    }
}

/// One set: ways ordered most-recently-used first.
#[derive(Debug, Clone, Default)]
struct Set {
    /// Tags (full line base addresses), MRU at index 0.
    lines: Vec<u64>,
}

/// Set-associative LRU cache over 64-bit byte addresses.
#[derive(Debug, Clone)]
pub struct CacheSim {
    geo: CacheGeometry,
    sets: Vec<Set>,
    line_shift: u32,
    set_mask: u64,
    pub stats: CacheStats,
}

impl CacheSim {
    pub fn new(geo: CacheGeometry) -> Self {
        geo.validate();
        let num_sets = geo.num_sets();
        CacheSim {
            geo,
            sets: vec![Set::default(); num_sets],
            line_shift: geo.line_bytes.trailing_zeros(),
            set_mask: (num_sets - 1) as u64,
            stats: CacheStats::default(),
        }
    }

    pub fn geometry(&self) -> &CacheGeometry {
        &self.geo
    }

    fn line_base(&self, addr: u64) -> u64 {
        addr >> self.line_shift << self.line_shift
    }

    fn set_index(&self, addr: u64) -> usize {
        ((addr >> self.line_shift) & self.set_mask) as usize
    }

    /// Access one byte address (loads and stores are treated alike:
    /// the GEMM working-set analysis is capacity/conflict driven, and
    /// the paper's caches are write-allocate).
    pub fn access(&mut self, addr: u64) -> AccessResult {
        self.stats.accesses += 1;
        let base = self.line_base(addr);
        let set_idx = self.set_index(addr);
        let set = &mut self.sets[set_idx];

        if let Some(pos) = set.lines.iter().position(|&t| t == base) {
            // Hit: move to MRU position.
            let tag = set.lines.remove(pos);
            set.lines.insert(0, tag);
            self.stats.hits += 1;
            return AccessResult::Hit;
        }

        self.stats.misses += 1;
        let evicted = if set.lines.len() == self.geo.associativity {
            let victim = set.lines.pop().expect("full set has a victim");
            self.stats.evictions += 1;
            Some(victim)
        } else {
            None
        };
        set.lines.insert(0, base);
        AccessResult::Miss { evicted }
    }

    /// Access a whole contiguous byte range, touching each line once.
    pub fn access_range(&mut self, addr: u64, len_bytes: usize) {
        if len_bytes == 0 {
            return;
        }
        let first = self.line_base(addr);
        let last = self.line_base(addr + (len_bytes as u64 - 1));
        let mut line = first;
        loop {
            self.access(line);
            if line == last {
                break;
            }
            line += self.geo.line_bytes as u64;
        }
    }

    /// Is the line containing `addr` currently resident?
    pub fn contains(&self, addr: u64) -> bool {
        let base = self.line_base(addr);
        self.sets[self.set_index(addr)].lines.contains(&base)
    }

    /// Number of resident lines (occupancy).
    pub fn resident_lines(&self) -> usize {
        self.sets.iter().map(|s| s.lines.len()).sum()
    }

    pub fn reset_stats(&mut self) {
        self.stats = CacheStats::default();
    }

    pub fn flush(&mut self) {
        for s in &mut self.sets {
            s.lines.clear();
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::soc::CacheGeometry;
    use crate::util::rng::Rng;

    fn tiny() -> CacheSim {
        // 4 sets × 2 ways × 64B lines = 512 B.
        CacheSim::new(CacheGeometry::new(512, 2, 64))
    }

    #[test]
    fn first_access_misses_second_hits() {
        let mut c = tiny();
        assert!(!c.access(0x100).is_hit());
        assert!(c.access(0x100).is_hit());
        assert!(c.access(0x13f).is_hit(), "same line, different byte");
    }

    #[test]
    fn set_mapping_is_modular() {
        let c = tiny();
        // 64B lines, 4 sets: set = (addr>>6) & 3.
        assert_eq!(c.set_index(0x000), 0);
        assert_eq!(c.set_index(0x040), 1);
        assert_eq!(c.set_index(0x0c0), 3);
        assert_eq!(c.set_index(0x100), 0);
    }

    #[test]
    fn lru_evicts_least_recent() {
        let mut c = tiny();
        // Three lines mapping to set 0 (stride = sets*line = 256B).
        c.access(0x000);
        c.access(0x100);
        c.access(0x000); // touch 0x000 → MRU
        let r = c.access(0x200); // evicts 0x100
        assert_eq!(r, AccessResult::Miss { evicted: Some(0x100) });
        assert!(c.contains(0x000));
        assert!(!c.contains(0x100));
    }

    #[test]
    fn occupancy_bounded_by_capacity() {
        let mut c = tiny();
        let mut rng = Rng::new(5);
        for _ in 0..10_000 {
            c.access(rng.next_u64() % (1 << 20));
        }
        assert!(c.resident_lines() <= 8);
    }

    #[test]
    fn stats_add_up() {
        let mut c = tiny();
        for i in 0..100u64 {
            c.access(i * 64);
        }
        assert_eq!(c.stats.accesses, 100);
        assert_eq!(c.stats.hits + c.stats.misses, 100);
    }

    #[test]
    fn working_set_within_capacity_fully_hits_on_reuse() {
        // 8 lines capacity; touch 8 distinct lines twice.
        let mut c = tiny();
        for i in 0..8u64 {
            c.access(i * 64);
        }
        c.reset_stats();
        for i in 0..8u64 {
            assert!(c.access(i * 64).is_hit(), "line {i} should be resident");
        }
        assert_eq!(c.stats.miss_rate(), 0.0);
    }

    #[test]
    fn working_set_exceeding_capacity_thrashes_under_lru() {
        // Cyclic sweep over 2× capacity with LRU = 100% misses.
        let mut c = tiny();
        for _round in 0..4 {
            for i in 0..16u64 {
                c.access(i * 64);
            }
        }
        // After warmup round, still all misses (classic LRU cyclic thrash).
        assert_eq!(c.stats.hits, 0);
    }

    #[test]
    fn access_range_touches_each_line_once() {
        let mut c = tiny();
        c.access_range(0x10, 64); // spans two lines (0x00 and 0x40)
        assert_eq!(c.stats.accesses, 2);
        c.access_range(0x0, 1);
        assert_eq!(c.stats.accesses, 3);
        c.access_range(0x0, 0);
        assert_eq!(c.stats.accesses, 3);
    }

    #[test]
    fn flush_empties_cache() {
        let mut c = tiny();
        c.access(0x0);
        c.flush();
        assert_eq!(c.resident_lines(), 0);
        assert!(!c.contains(0x0));
    }

    #[test]
    fn associativity_one_is_direct_mapped() {
        let mut c = CacheSim::new(CacheGeometry::new(256, 1, 64));
        c.access(0x000);
        c.access(0x100); // same set (4 sets), evicts
        assert!(!c.contains(0x000));
        assert!(c.contains(0x100));
    }

    #[test]
    fn eviction_count_matches_misses_when_full() {
        let mut c = tiny();
        for i in 0..8u64 {
            c.access(i * 64);
        }
        let misses_before = c.stats.misses;
        assert_eq!(c.stats.evictions, 0);
        for i in 8..16u64 {
            c.access(i * 64);
        }
        assert_eq!(c.stats.misses - misses_before, 8);
        assert_eq!(c.stats.evictions, 8);
    }
}
