//! Layer-3 coordinator: a GEMM service over the native executor, the
//! PJRT runtime and the simulator.
//!
//! The paper's contribution is the scheduling layer itself, so the
//! coordinator is the thin-but-real driver DESIGN.md calls for: a job
//! queue with a same-shape [`Batcher`] (PJRT executables are shape-
//! specialized — grouping identical shapes amortizes dispatch), worker
//! threads, model-driven strategy auto-selection (the §5.2 ratio knob
//! computed from the calibrated performance model rather than an
//! environment variable), and metrics. `std::thread` + `mpsc` replace
//! tokio (offline crate set, DESIGN.md §2); the workload is CPU-bound
//! GEMM, so blocking workers are the right shape anyway.
//!
//! Scale-out: the [`FleetDispatcher`] front-end shards same-shape
//! batches across the boards of a [`crate::fleet::Fleet`] under a
//! board-level strategy (fleet-SSS/SAS/DAS), merges responses back in
//! request order, and aggregates per-board metrics — the coordinator's
//! single-SoC job queue lifted one level (DESIGN.md §3, "Fleet layer").

pub mod server;

use crate::blis::gemm::GemmShape;
use crate::dag::JobSpec;
use crate::fleet::{Fleet, FleetStrategy};
use crate::model::PerfModel;
use crate::native;
use crate::partition::DynamicQueue;
use crate::runtime::worker::PjrtHandle;
use crate::sched::ScheduleSpec;
use crate::sim;
use crate::soc::SocSpec;
use anyhow::{anyhow, Result};
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::mpsc;
use std::sync::{Arc, Mutex};

/// Which engine executes a request.
#[derive(Debug, Clone, PartialEq)]
pub enum Backend {
    /// Real threads + packed GEMM under a schedule (default CA-DAS).
    Native(ScheduleSpec),
    /// AOT artifact via PJRT; `variant` picks the control-tree analogue.
    Pjrt { variant: String },
    /// Virtual-time simulation (capacity planning / what-if).
    Sim(ScheduleSpec),
    /// Model-driven dispatch: PJRT when an exact-shape artifact exists
    /// (compiled executable, no packing cost), native CA-DAS otherwise.
    Auto,
}

/// One GEMM request. Operands are owned so requests can cross threads.
#[derive(Debug, Clone)]
pub struct Request {
    pub id: u64,
    pub shape: GemmShape,
    pub a: Arc<Vec<f64>>,
    pub b: Arc<Vec<f64>>,
    pub backend: Backend,
}

/// Completed response.
#[derive(Debug, Clone)]
pub struct Response {
    pub id: u64,
    /// Result matrix (empty for Sim backend).
    pub c: Vec<f64>,
    pub latency_s: f64,
    pub gflops: f64,
    pub backend_label: String,
    /// Deterministic checksum of C (sum of elements) for cheap
    /// cross-backend verification.
    pub checksum: f64,
}

/// Service metrics.
#[derive(Debug, Clone, Default)]
pub struct Metrics {
    pub completed: u64,
    pub total_flops: f64,
    pub total_latency_s: f64,
    pub batches: u64,
}

/// Largest same-shape group one worker executes back-to-back; bigger
/// runs split into several groups so a huge batch still parallelizes.
pub const MAX_GROUP_LEN: usize = 64;

/// Order-preserving same-key batcher, generic over the batch key
/// (ISSUE 4 generalization — the single-SoC path keys by a
/// `(backend, shape)` string, the fleet dispatchers key by
/// [`GemmShape`] directly): items accumulate into per-key groups; a
/// group is emitted the moment it reaches `max_group`, and
/// [`Batcher::drain`] flushes every partially-filled group immediately,
/// in first-arrival order. The drain is what guarantees a trailing
/// odd-sized group never waits on a timeout path — when the queue is
/// empty, partial groups ship as-is. The keyed variants
/// ([`Batcher::push_keyed`]/[`Batcher::drain_keyed`]) return each
/// group's key alongside its items, which is how the streaming
/// dispatcher packs mixed-shape waves of per-shape subgroups.
#[derive(Debug)]
pub struct Batcher<K, T> {
    max_group: usize,
    /// Pending groups, in first-arrival order of their opening item.
    groups: Vec<(K, Vec<T>)>,
}

impl<K: PartialEq, T> Batcher<K, T> {
    pub fn new(max_group: usize) -> Self {
        assert!(max_group >= 1, "groups need at least one slot");
        Batcher {
            max_group,
            groups: Vec::new(),
        }
    }

    /// Items waiting in partially-filled groups.
    pub fn pending(&self) -> usize {
        self.groups.iter().map(|(_, g)| g.len()).sum()
    }

    /// Add one item under its batch key; returns the completed group
    /// when this item fills one.
    pub fn push(&mut self, key: K, item: T) -> Option<Vec<T>> {
        self.push_keyed(key, item).map(|(_, g)| g)
    }

    /// Like [`Batcher::push`], but a completed group comes back with
    /// its key.
    pub fn push_keyed(&mut self, key: K, item: T) -> Option<(K, Vec<T>)> {
        let idx = match self.groups.iter().position(|(k, _)| *k == key) {
            Some(i) => {
                self.groups[i].1.push(item);
                i
            }
            None => {
                self.groups.push((key, vec![item]));
                self.groups.len() - 1
            }
        };
        if self.groups[idx].1.len() >= self.max_group {
            Some(self.groups.remove(idx))
        } else {
            None
        }
    }

    /// Flush every pending group — partially filled ones included — in
    /// first-arrival order.
    pub fn drain(&mut self) -> Vec<Vec<T>> {
        self.drain_keyed().into_iter().map(|(_, g)| g).collect()
    }

    /// Like [`Batcher::drain`], but each group comes back with its key.
    pub fn drain_keyed(&mut self) -> Vec<(K, Vec<T>)> {
        std::mem::take(&mut self.groups)
    }
}

/// The coordinator service.
#[allow(missing_debug_implementations)]
pub struct Coordinator {
    soc: SocSpec,
    model: PerfModel,
    runtime: Option<PjrtHandle>,
    metrics: Mutex<Metrics>,
}

impl Coordinator {
    /// Build without a PJRT runtime (native/sim backends only).
    pub fn new(soc: SocSpec) -> Self {
        let model = PerfModel::new(soc.clone());
        Coordinator {
            soc,
            model,
            runtime: None,
            metrics: Mutex::new(Metrics::default()),
        }
    }

    /// Build with PJRT artifacts loaded from `dir` (spawns the runtime
    /// thread; see [`PjrtHandle`]).
    pub fn with_artifacts(soc: SocSpec, dir: &std::path::Path) -> Result<Self> {
        let handle = PjrtHandle::spawn(dir)?;
        let mut c = Coordinator::new(soc);
        c.runtime = Some(handle);
        Ok(c)
    }

    pub fn soc(&self) -> &SocSpec {
        &self.soc
    }

    pub fn metrics(&self) -> Metrics {
        self.metrics.lock().unwrap().clone()
    }

    /// Model-driven default schedule: CA-DAS (the paper's best).
    pub fn auto_spec(&self) -> ScheduleSpec {
        ScheduleSpec::ca_das()
    }

    /// Model-driven SAS ratio (§5.2's knob, computed instead of guessed):
    /// the big:LITTLE cluster throughput ratio under the oblivious
    /// single-tree configuration, rounded to the nearest integer.
    pub fn auto_ratio(&self) -> f64 {
        let p = crate::blis::params::BlisParams::a15_opt();
        self.model.ideal_ratio(&p, &p).round().clamp(1.0, 8.0)
    }

    /// [`Coordinator::auto_ratio`] with the throughputs drawn from a
    /// `calibrate::WeightSource`: the service layer's ratio knob tuned
    /// from *measured* rates (shape-classed by the request's `k`)
    /// instead of the analytical model. Two-cluster topologies only,
    /// like `auto_ratio`.
    pub fn auto_ratio_from(
        &self,
        source: &crate::calibrate::WeightSource,
        shape: GemmShape,
    ) -> f64 {
        assert_eq!(self.soc.num_clusters(), 2, "auto_ratio is the 2-cluster shorthand");
        let class = crate::calibrate::ShapeClass::for_soc(&self.soc, shape);
        let w = source.weights(&self.model, false, class);
        (w.as_slice()[0] / w.as_slice()[1]).round().clamp(1.0, 8.0)
    }

    /// Resolve `Auto` to a concrete backend for a shape: a loaded
    /// exact-shape artifact wins (zero compile/packing cost at request
    /// time); otherwise the native CA-DAS executor handles any shape.
    pub fn resolve_auto(&self, shape: GemmShape) -> Backend {
        if let Some(rt) = &self.runtime {
            for variant in ["big", "little"] {
                if let Ok(true) = rt.has(shape, variant) {
                    return Backend::Pjrt { variant: variant.to_string() };
                }
            }
        }
        Backend::Native(self.auto_spec())
    }

    /// Execute one request synchronously.
    pub fn execute(&self, req: &Request) -> Result<Response> {
        if req.backend == Backend::Auto {
            let mut resolved = req.clone();
            resolved.backend = self.resolve_auto(req.shape);
            debug_assert!(resolved.backend != Backend::Auto);
            return self.execute(&resolved);
        }
        let t0 = std::time::Instant::now();
        let (c, label) = match &req.backend {
            Backend::Auto => unreachable!("resolved above"),
            Backend::Native(spec) => {
                let mut c = vec![0.0; req.shape.m * req.shape.n];
                let stats =
                    native::gemm_parallel(&self.soc, spec, req.shape, &req.a, &req.b, &mut c);
                (c, format!("native/{}", stats.label))
            }
            Backend::Pjrt { variant } => {
                let rt = self
                    .runtime
                    .as_ref()
                    .ok_or_else(|| anyhow!("no PJRT runtime configured"))?;
                let (name, c) =
                    rt.execute(req.shape, variant, req.a.to_vec(), req.b.to_vec())?;
                (c, format!("pjrt/{name}"))
            }
            Backend::Sim(spec) => {
                let stats = sim::simulate(&self.model, spec, req.shape);
                (Vec::new(), format!("sim/{} {:.2} GFLOPS(v)", stats.label, stats.gflops))
            }
        };
        let latency = t0.elapsed().as_secs_f64();
        let flops = req.shape.flops();
        {
            let mut m = self.metrics.lock().unwrap();
            m.completed += 1;
            m.total_flops += flops;
            m.total_latency_s += latency;
        }
        Ok(Response {
            id: req.id,
            checksum: c.iter().sum(),
            gflops: flops / latency / 1e9,
            latency_s: latency,
            backend_label: label,
            c,
        })
    }

    /// Batch executor: groups requests by (shape, backend kind) through
    /// the [`Batcher`] so PJRT requests with the same artifact run
    /// back-to-back on the already-compiled executable, then dispatches
    /// each group on a worker thread. Group formation is deterministic
    /// (first-arrival order) and the final drain flushes partially-
    /// filled trailing groups immediately instead of leaving them on a
    /// timeout path. Responses are returned in request order.
    pub fn execute_batch(&self, reqs: Vec<Request>) -> Vec<Result<Response>> {
        let n = reqs.len();
        let mut batcher = Batcher::new(MAX_GROUP_LEN);
        let mut groups: Vec<Vec<usize>> = Vec::new();
        for (i, r) in reqs.iter().enumerate() {
            if let Some(g) = batcher.push(Self::batch_key(r), i) {
                groups.push(g);
            }
        }
        groups.extend(batcher.drain());
        {
            let mut m = self.metrics.lock().unwrap();
            m.batches += groups.len() as u64;
        }

        let (tx, rx) = mpsc::channel::<(usize, Result<Response>)>();
        std::thread::scope(|s| {
            for idxs in &groups {
                let tx = tx.clone();
                let reqs = &reqs;
                s.spawn(move || {
                    for &i in idxs {
                        let resp = self.execute(&reqs[i]);
                        tx.send((i, resp)).expect("result channel");
                    }
                });
            }
            drop(tx);
        });
        let mut out: Vec<Option<Result<Response>>> = (0..n).map(|_| None).collect();
        for (i, r) in rx {
            out[i] = Some(r);
        }
        out.into_iter().map(|o| o.expect("all jobs complete")).collect()
    }

    fn batch_key(r: &Request) -> String {
        let kind = match &r.backend {
            Backend::Native(s) => format!("native/{}", s.label()),
            Backend::Pjrt { variant } => format!("pjrt/{variant}"),
            Backend::Sim(s) => format!("sim/{}", s.label()),
            Backend::Auto => "auto".to_string(),
        };
        format!("{}:{}x{}x{}", kind, r.shape.m, r.shape.n, r.shape.k)
    }
}

/// Per-board and fleet-aggregate service metrics.
#[derive(Debug, Clone)]
pub struct FleetMetrics {
    /// One `(board name, metrics)` entry per board, in fleet order.
    pub boards: Vec<(String, Metrics)>,
    /// Same-shape groups the dispatcher has sharded.
    pub batches: u64,
}

impl FleetMetrics {
    /// Requests completed across all boards.
    pub fn completed(&self) -> u64 {
        self.boards.iter().map(|(_, m)| m.completed).sum()
    }

    /// Total useful flops across all boards.
    pub fn total_flops(&self) -> f64 {
        self.boards.iter().map(|(_, m)| m.total_flops).sum()
    }
}

/// Multi-board front-end: shards same-shape batches across the boards
/// of a [`Fleet`], merges responses back in request order, and
/// aggregates per-board metrics — the board-level twin of
/// [`Coordinator::execute_batch`] (cluster : SoC :: board : fleet).
///
/// Each board gets its own [`Coordinator`] bound to that board's SoC
/// descriptor and executes its shard under the board's own engine
/// ([`crate::fleet::Board::backend`]); the request-level `backend`
/// field is overridden by the dispatcher. Static strategies ship each
/// board one contiguous shard; fleet-DAS runs one puller thread per
/// board grabbing chunks of the board's own grain from a shared
/// [`DynamicQueue`] — the §5.4 critical section, one level up.
#[allow(missing_debug_implementations)]
pub struct FleetDispatcher {
    fleet: Fleet,
    coords: Vec<Coordinator>,
    batches: AtomicU64,
}

impl FleetDispatcher {
    pub fn new(fleet: Fleet) -> Self {
        let coords = fleet
            .boards
            .iter()
            .map(|b| Coordinator::new(b.soc().clone()))
            .collect();
        FleetDispatcher {
            fleet,
            coords,
            batches: AtomicU64::new(0),
        }
    }

    pub fn fleet(&self) -> &Fleet {
        &self.fleet
    }

    pub fn metrics(&self) -> FleetMetrics {
        FleetMetrics {
            boards: self
                .fleet
                .boards
                .iter()
                .zip(&self.coords)
                .map(|(b, c)| (b.name.clone(), c.metrics()))
                .collect(),
            batches: self.batches.load(Ordering::SeqCst),
        }
    }

    /// Execute one request on one board, under the board's engine; the
    /// response label is prefixed with the board name.
    fn execute_on(&self, board: usize, req: &Request) -> Result<Response> {
        let mut r = req.clone();
        r.backend = self.fleet.boards[board].backend.clone();
        self.coords[board].execute(&r).map(|mut resp| {
            resp.backend_label =
                format!("{}/{}", self.fleet.boards[board].name, resp.backend_label);
            resp
        })
    }

    /// Shard a batch across the fleet and execute it. Requests of mixed
    /// shapes are first grouped by the same-shape [`Batcher`] (partial
    /// trailing groups flush on drain); each group is then split across
    /// boards by `strategy`. Responses come back in request order.
    pub fn dispatch(
        &self,
        reqs: Vec<Request>,
        strategy: FleetStrategy,
    ) -> Vec<Result<Response>> {
        let n = reqs.len();
        let mut batcher: Batcher<JobSpec, usize> = Batcher::new(MAX_GROUP_LEN);
        let mut groups: Vec<Vec<usize>> = Vec::new();
        for (i, r) in reqs.iter().enumerate() {
            if let Some(g) = batcher.push(JobSpec::Gemm(r.shape), i) {
                groups.push(g);
            }
        }
        groups.extend(batcher.drain());
        self.batches.fetch_add(groups.len() as u64, Ordering::SeqCst);

        let grains = self.fleet.grains();
        let (tx, rx) = mpsc::channel::<(usize, Result<Response>)>();
        std::thread::scope(|s| {
            for group in &groups {
                match strategy {
                    FleetStrategy::Sss | FleetStrategy::Sas => {
                        let shards = self.fleet.static_shards(group.len(), strategy);
                        let mut offset = 0;
                        for (b, &share) in shards.iter().enumerate() {
                            if share == 0 {
                                continue;
                            }
                            let idxs = &group[offset..offset + share];
                            offset += share;
                            let tx = tx.clone();
                            let reqs = &reqs;
                            s.spawn(move || {
                                for &i in idxs {
                                    tx.send((i, self.execute_on(b, &reqs[i])))
                                        .expect("result channel");
                                }
                            });
                        }
                    }
                    FleetStrategy::Das => {
                        let queue = Arc::new(DynamicQueue::new(group.len()));
                        for b in 0..self.fleet.num_boards() {
                            let queue = queue.clone();
                            let grain = grains[b];
                            let tx = tx.clone();
                            let reqs = &reqs;
                            let group = &group[..];
                            s.spawn(move || {
                                while let Some(chunk) = queue.grab(grain) {
                                    for &i in &group[chunk.start..chunk.end()] {
                                        tx.send((i, self.execute_on(b, &reqs[i])))
                                            .expect("result channel");
                                    }
                                }
                            });
                        }
                    }
                }
            }
            drop(tx);
        });
        let mut out: Vec<Option<Result<Response>>> = (0..n).map(|_| None).collect();
        for (i, r) in rx {
            out[i] = Some(r);
        }
        out.into_iter().map(|o| o.expect("all shards complete")).collect()
    }
}

/// One streamed request: a [`Request`] admitted at a *virtual* arrival
/// instant. The timestamp orders admission (and therefore wave
/// packing) deterministically; execution itself runs as fast as the
/// boards allow.
#[derive(Debug, Clone)]
pub struct StreamRequest {
    pub arrive_s: f64,
    pub req: Request,
}

impl StreamRequest {
    pub fn at(arrive_s: f64, req: Request) -> StreamRequest {
        StreamRequest { arrive_s, req }
    }
}

/// Streaming multi-board front-end (ISSUE 4 tentpole): an asynchronous
/// admission layer over the same per-board [`Coordinator`]s as
/// [`FleetDispatcher`]. Requests carry virtual arrival timestamps;
/// admission order (arrival instant, ties by submission index) drives a
/// shape-keyed [`Batcher`] that packs *mixed-shape* waves of per-shape
/// subgroups. Execution is work-conserving — no wave barrier:
///
/// * static strategies (fleet-SSS/SAS) pre-split every subgroup with
///   [`Fleet::plan_wave`] and seed one private queue per board, in wave
///   order; a board that drains its shard of group *g* starts its shard
///   of group *g+1* immediately;
/// * fleet-DAS runs one puller thread per board grabbing runs of the
///   board's own grain from the shared admission queue — a board that
///   drains grabs the next ready group.
///
/// Responses always merge back in submission order. Degeneracy anchor:
/// when every request arrives at t = 0 with one shape, the static
/// strategies reproduce [`FleetDispatcher::dispatch`]'s responses and
/// deterministic per-board metrics bit for bit (pinned by
/// `tests/stream_props.rs`).
#[allow(missing_debug_implementations)]
pub struct StreamDispatcher {
    inner: FleetDispatcher,
}

impl StreamDispatcher {
    pub fn new(fleet: Fleet) -> Self {
        StreamDispatcher {
            inner: FleetDispatcher::new(fleet),
        }
    }

    pub fn fleet(&self) -> &Fleet {
        self.inner.fleet()
    }

    /// Per-board and aggregate metrics; `batches` counts the same-shape
    /// subgroups the admission layer has packed.
    pub fn metrics(&self) -> FleetMetrics {
        self.inner.metrics()
    }

    /// Execute one admission stream under a board-level strategy,
    /// returning responses in submission order.
    pub fn dispatch_stream(
        &self,
        reqs: Vec<StreamRequest>,
        strategy: FleetStrategy,
    ) -> Vec<Result<Response>> {
        self.dispatch_stream_inner(reqs, strategy, None)
    }

    /// Wall-clock-paced admission (ISSUE 8): like [`dispatch_stream`],
    /// but arrival timestamps are honored in *real time* — request `i`
    /// starts no earlier than `arrive_s / time_scale` wall seconds
    /// after the call begins, instead of executing as fast as the
    /// boards allow. `time_scale` compresses the virtual clock (a
    /// 60-virtual-second trace replays in `60 / time_scale` wall
    /// seconds), which keeps paced runs testable. Responses still merge
    /// in submission order and are bit-for-bit the unpaced responses —
    /// pacing only gates *when* work starts, never what runs where.
    pub fn dispatch_stream_paced(
        &self,
        reqs: Vec<StreamRequest>,
        strategy: FleetStrategy,
        time_scale: f64,
    ) -> Vec<Result<Response>> {
        assert!(
            time_scale.is_finite() && time_scale > 0.0,
            "time scale must be positive and finite, got {time_scale}"
        );
        self.dispatch_stream_inner(reqs, strategy, Some(time_scale))
    }

    fn dispatch_stream_inner(
        &self,
        reqs: Vec<StreamRequest>,
        strategy: FleetStrategy,
        pace: Option<f64>,
    ) -> Vec<Result<Response>> {
        let n = reqs.len();
        if n == 0 {
            return Vec::new();
        }
        // Paced mode: every request has a wall-clock eligibility
        // deadline measured from here; a worker about to execute it
        // sleeps out the remainder first.
        let start = std::time::Instant::now();
        let wait_for = |i: usize| {
            if let Some(scale) = pace {
                let deadline = std::time::Duration::from_secs_f64(reqs[i].arrive_s / scale);
                let elapsed = start.elapsed();
                if deadline > elapsed {
                    std::thread::sleep(deadline - elapsed);
                }
            }
        };
        // Admission order: virtual arrival instants, ties by submission
        // index — the same contract (and validation) as the virtual-time
        // twin, via the shared helper.
        let times: Vec<f64> = reqs.iter().map(|r| r.arrive_s).collect();
        let order = crate::fleet::sim::admission_order_by(&times);
        // Job-aware wave packing: same-job subgroups of at most
        // MAX_GROUP_LEN, in admission order (ISSUE 10: the batch key is
        // the [`JobSpec`], so non-GEMM jobs batch through the same
        // machinery; coordinator requests are GEMMs today).
        let mut batcher: Batcher<JobSpec, usize> = Batcher::new(MAX_GROUP_LEN);
        let mut groups: Vec<(JobSpec, Vec<usize>)> = Vec::new();
        for &i in &order {
            if let Some(g) = batcher.push_keyed(JobSpec::Gemm(reqs[i].req.shape), i) {
                groups.push(g);
            }
        }
        groups.extend(batcher.drain_keyed());
        self.inner.batches.fetch_add(groups.len() as u64, Ordering::SeqCst);

        let nb = self.fleet().num_boards();
        // Pre-plan outside the thread scope so spawned workers can
        // borrow the shared inputs.
        let mut per_board: Vec<Vec<usize>> = vec![Vec::new(); nb];
        let mut admitted: Vec<usize> = Vec::new();
        if strategy.is_dynamic() {
            // The shared queue serves pure admission order (not
            // group-major order), exactly like the virtual-time twin's
            // ready queue — an earlier-arriving request is never queued
            // behind a later one of another shape.
            admitted = order;
        } else {
            let subgroups: Vec<(JobSpec, usize)> =
                groups.iter().map(|(s, g)| (*s, g.len())).collect();
            let plan = self.fleet().plan_wave(&subgroups, strategy);
            for (gp, (_, members)) in plan.groups.iter().zip(&groups) {
                let mut offset = 0;
                for (b, &share) in gp.shards.iter().enumerate() {
                    per_board[b].extend_from_slice(&members[offset..offset + share]);
                    offset += share;
                }
            }
        }
        let grains = self.fleet().grains();

        let (tx, rx) = mpsc::channel::<(usize, Result<Response>)>();
        std::thread::scope(|s| {
            if strategy.is_dynamic() {
                let queue = Arc::new(DynamicQueue::new(admitted.len()));
                for b in 0..nb {
                    let queue = queue.clone();
                    let grain = grains[b];
                    let tx = tx.clone();
                    let reqs = &reqs;
                    let admitted = &admitted[..];
                    let wait_for = &wait_for;
                    s.spawn(move || {
                        while let Some(chunk) = queue.grab(grain) {
                            for &i in &admitted[chunk.start..chunk.end()] {
                                wait_for(i);
                                tx.send((i, self.inner.execute_on(b, &reqs[i].req)))
                                    .expect("result channel");
                            }
                        }
                    });
                }
            } else {
                for (b, idxs) in per_board.into_iter().enumerate() {
                    if idxs.is_empty() {
                        continue;
                    }
                    let tx = tx.clone();
                    let reqs = &reqs;
                    let wait_for = &wait_for;
                    s.spawn(move || {
                        for i in idxs {
                            wait_for(i);
                            tx.send((i, self.inner.execute_on(b, &reqs[i].req)))
                                .expect("result channel");
                        }
                    });
                }
            }
            drop(tx);
        });
        let mut out: Vec<Option<Result<Response>>> = (0..n).map(|_| None).collect();
        for (i, r) in rx {
            out[i] = Some(r);
        }
        out.into_iter().map(|o| o.expect("all shards complete")).collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::blis::gemm::gemm_naive;
    use crate::util::rng::Rng;
    use crate::util::stats::{gemm_tolerance, max_abs_diff};
    use std::path::Path;

    fn artifacts_dir() -> std::path::PathBuf {
        Path::new(env!("CARGO_MANIFEST_DIR")).join("artifacts")
    }

    fn request(id: u64, r: usize, seed: u64, backend: Backend) -> (Request, Vec<f64>) {
        let mut rng = Rng::new(seed);
        let a = rng.fill_matrix(r * r);
        let b = rng.fill_matrix(r * r);
        let mut want = vec![0.0; r * r];
        gemm_naive(GemmShape::square(r), &a, &b, &mut want);
        (
            Request {
                id,
                shape: GemmShape::square(r),
                a: Arc::new(a),
                b: Arc::new(b),
                backend,
            },
            want,
        )
    }

    #[test]
    fn native_backend_correct() {
        let c = Coordinator::new(SocSpec::exynos5422());
        let (req, want) = request(1, 96, 5, Backend::Native(ScheduleSpec::ca_das()));
        let resp = c.execute(&req).unwrap();
        assert!(max_abs_diff(&resp.c, &want) < gemm_tolerance(96));
        assert!(resp.backend_label.starts_with("native/CA-DAS"));
        assert_eq!(c.metrics().completed, 1);
    }

    #[test]
    fn sim_backend_returns_virtual_stats() {
        let c = Coordinator::new(SocSpec::exynos5422());
        let (req, _) = request(2, 512, 6, Backend::Sim(ScheduleSpec::sas(5.0)));
        let resp = c.execute(&req).unwrap();
        assert!(resp.c.is_empty());
        assert!(resp.backend_label.contains("GFLOPS(v)"));
    }

    #[test]
    fn pjrt_backend_correct_and_matches_native() {
        if !artifacts_dir().join("manifest.txt").exists() {
            eprintln!("skipping: run `make artifacts` first");
            return;
        }
        let c = Coordinator::with_artifacts(SocSpec::exynos5422(), &artifacts_dir()).unwrap();
        let (req, want) = request(3, 128, 7, Backend::Pjrt { variant: "big".into() });
        let resp = c.execute(&req).unwrap();
        assert!(max_abs_diff(&resp.c, &want) < gemm_tolerance(128));

        // Same request through the native path: checksums agree.
        let (req_n, _) = request(4, 128, 7, Backend::Native(ScheduleSpec::ca_das()));
        let resp_n = c.execute(&req_n).unwrap();
        assert!((resp.checksum - resp_n.checksum).abs() < 1e-6);
    }

    #[test]
    fn pjrt_without_runtime_errors() {
        let c = Coordinator::new(SocSpec::exynos5422());
        let (req, _) = request(5, 64, 8, Backend::Pjrt { variant: "big".into() });
        assert!(c.execute(&req).is_err());
    }

    #[test]
    fn pjrt_unknown_shape_errors() {
        if !artifacts_dir().join("manifest.txt").exists() {
            eprintln!("skipping: run `make artifacts` first");
            return;
        }
        let c = Coordinator::with_artifacts(SocSpec::exynos5422(), &artifacts_dir()).unwrap();
        let (req, _) = request(6, 99, 9, Backend::Pjrt { variant: "big".into() });
        let err = c.execute(&req).unwrap_err().to_string();
        assert!(err.contains("no artifact"), "{err}");
    }

    #[test]
    fn batch_groups_and_preserves_order() {
        let c = Coordinator::new(SocSpec::exynos5422());
        let mut reqs = Vec::new();
        let mut wants = Vec::new();
        for (i, r) in [64usize, 96, 64, 96, 64].iter().enumerate() {
            let (req, want) = request(i as u64, *r, 20 + i as u64, Backend::Native(ScheduleSpec::sas(5.0)));
            reqs.push(req);
            wants.push(want);
        }
        let resps = c.execute_batch(reqs);
        assert_eq!(resps.len(), 5);
        for (i, (resp, want)) in resps.iter().zip(&wants).enumerate() {
            let resp = resp.as_ref().unwrap();
            assert_eq!(resp.id, i as u64);
            assert!(max_abs_diff(&resp.c, want) < gemm_tolerance(96));
        }
        // 2 distinct shapes × 1 backend = 2 batch groups.
        assert_eq!(c.metrics().batches, 2);
        assert_eq!(c.metrics().completed, 5);
    }

    #[test]
    fn auto_backend_resolves_by_artifact_availability() {
        // Without a runtime, Auto always resolves to native CA-DAS.
        let c = Coordinator::new(SocSpec::exynos5422());
        assert_eq!(
            c.resolve_auto(GemmShape::square(128)),
            Backend::Native(ScheduleSpec::ca_das())
        );
        let (req, want) = request(10, 96, 30, Backend::Auto);
        let resp = c.execute(&req).unwrap();
        assert!(resp.backend_label.starts_with("native/"));
        assert!(max_abs_diff(&resp.c, &want) < gemm_tolerance(96));

        // With artifacts, exact shapes go to PJRT, odd shapes to native.
        if artifacts_dir().join("manifest.txt").exists() {
            let c = Coordinator::with_artifacts(SocSpec::exynos5422(), &artifacts_dir()).unwrap();
            assert!(matches!(
                c.resolve_auto(GemmShape::square(128)),
                Backend::Pjrt { .. }
            ));
            assert_eq!(
                c.resolve_auto(GemmShape::square(99)),
                Backend::Native(ScheduleSpec::ca_das())
            );
            let (req, want) = request(11, 128, 31, Backend::Auto);
            let resp = c.execute(&req).unwrap();
            assert!(resp.backend_label.starts_with("pjrt/"), "{}", resp.backend_label);
            assert!(max_abs_diff(&resp.c, &want) < gemm_tolerance(128));
        }
    }

    #[test]
    fn auto_ratio_matches_paper_knob() {
        let c = Coordinator::new(SocSpec::exynos5422());
        // §5.2.2/Fig. 9: the right ratio is ≈ 5.
        assert_eq!(c.auto_ratio(), 5.0);
        assert_eq!(c.auto_spec(), ScheduleSpec::ca_das());
    }

    /// ISSUE 5: the service-layer ratio knob can run off the calibration
    /// layer — analytically synthesized tables reproduce `auto_ratio`,
    /// and the source is consulted per shape class.
    #[test]
    fn auto_ratio_from_weight_sources() {
        use crate::calibrate::{RateTable, WeightSource};
        let c = Coordinator::new(SocSpec::exynos5422());
        let shape = GemmShape::square(4096);
        assert_eq!(
            c.auto_ratio_from(&WeightSource::Analytical, shape),
            c.auto_ratio(),
            "analytical source is the existing knob"
        );
        let table = RateTable::from_analytical(c.soc());
        assert_eq!(
            c.auto_ratio_from(&WeightSource::Empirical(table.clone()), shape),
            c.auto_ratio(),
            "synthesized table degenerates to the analytical knob"
        );
        let measured = WeightSource::Empirical(RateTable::measure(c.soc(), &[]));
        let r = c.auto_ratio_from(&measured, shape);
        assert!((1.0..=8.0).contains(&r), "measured ratio {r}");
    }

    /// ISSUE satellite: the batcher's drain must flush partially-filled
    /// same-shape groups immediately, in first-arrival order — a
    /// trailing odd-sized group never waits on a timeout path.
    #[test]
    fn batcher_drain_order_pinned() {
        // max_group large: nothing fills, everything rides the drain.
        let mut b: Batcher<String, usize> = Batcher::new(MAX_GROUP_LEN);
        for (i, key) in ["A", "B", "A", "C", "B"].iter().enumerate() {
            assert_eq!(b.push(key.to_string(), i), None);
        }
        assert_eq!(b.pending(), 5);
        let groups = b.drain();
        // First-arrival order of each group's opening item, trailing
        // odd-sized C group included.
        assert_eq!(groups, vec![vec![0, 2], vec![1, 4], vec![3]]);
        assert_eq!(b.pending(), 0);
        assert!(b.drain().is_empty(), "drain leaves the batcher empty");
    }

    #[test]
    fn batcher_emits_full_groups_inline() {
        let mut b: Batcher<String, usize> = Batcher::new(2);
        assert_eq!(b.push("A".into(), 0), None);
        assert_eq!(b.push("B".into(), 1), None);
        // Second A completes that group immediately.
        assert_eq!(b.push("A".into(), 2), Some(vec![0, 2]));
        assert_eq!(b.push("C".into(), 3), None);
        assert_eq!(b.push("B".into(), 4), Some(vec![1, 4]));
        // A new A group reopens after the flush.
        assert_eq!(b.push("A".into(), 5), None);
        assert_eq!(b.drain(), vec![vec![3], vec![5]]);
    }

    /// ISSUE 4: the generic-key batcher returns each group's key with
    /// its items — the wave-packing primitive of the streaming
    /// dispatcher — and non-string keys group correctly.
    #[test]
    fn batcher_keyed_variants_carry_the_key() {
        let mut b: Batcher<GemmShape, usize> = Batcher::new(2);
        let s64 = GemmShape::square(64);
        let s96 = GemmShape::square(96);
        assert_eq!(b.push_keyed(s64, 0), None);
        assert_eq!(b.push_keyed(s96, 1), None);
        assert_eq!(b.push_keyed(s64, 2), Some((s64, vec![0, 2])));
        assert_eq!(b.push_keyed(s64, 3), None);
        assert_eq!(b.pending(), 2);
        // Drain keeps first-arrival order of each group's opener.
        assert_eq!(b.drain_keyed(), vec![(s96, vec![1]), (s64, vec![3])]);
        assert_eq!(b.pending(), 0);
    }

    fn fleet_dispatcher() -> FleetDispatcher {
        use crate::fleet::Board;
        FleetDispatcher::new(Fleet::new(vec![
            Board::native("exynos", SocSpec::exynos5422()),
            Board::native("smp2", SocSpec::symmetric(2)),
        ]))
    }

    /// The fleet front-end on every strategy: responses merge back in
    /// request order and the numerics survive the board hop.
    #[test]
    fn fleet_dispatcher_shards_and_preserves_order() {
        for strategy in [FleetStrategy::Sss, FleetStrategy::Sas, FleetStrategy::Das] {
            let d = fleet_dispatcher();
            let mut reqs = Vec::new();
            let mut wants = Vec::new();
            for (i, r) in [64usize, 96, 64, 96, 64, 64].iter().enumerate() {
                let (req, want) = request(i as u64, *r, 50 + i as u64, Backend::Auto);
                reqs.push(req);
                wants.push(want);
            }
            let resps = d.dispatch(reqs, strategy);
            assert_eq!(resps.len(), 6);
            for (i, (resp, want)) in resps.iter().zip(&wants).enumerate() {
                let resp = resp.as_ref().unwrap_or_else(|e| {
                    panic!("{}: request {i} failed: {e}", strategy.label())
                });
                assert_eq!(resp.id, i as u64);
                assert!(
                    max_abs_diff(&resp.c, want) < gemm_tolerance(96),
                    "{}: request {i} numerics",
                    strategy.label()
                );
                assert!(
                    resp.backend_label.contains("native/"),
                    "board engines are native: {}",
                    resp.backend_label
                );
            }
            let m = d.metrics();
            assert_eq!(m.completed(), 6, "{}", strategy.label());
            assert_eq!(m.batches, 2, "2 same-shape groups, {}", strategy.label());
            assert_eq!(m.boards.len(), 2);
            if strategy == FleetStrategy::Sas {
                // Weighted shards favour the faster Exynos board (the
                // dynamic split depends on host thread timing, so only
                // the deterministic static split is pinned here).
                assert!(
                    m.boards[0].1.completed > m.boards[1].1.completed,
                    "{}: {:?}",
                    strategy.label(),
                    m.boards
                );
            }
        }
    }

    #[test]
    fn fleet_dispatcher_exposes_fleet() {
        let d = fleet_dispatcher();
        assert_eq!(d.fleet().num_boards(), 2);
        assert_eq!(d.metrics().completed(), 0);
    }

    fn stream_dispatcher() -> StreamDispatcher {
        use crate::fleet::Board;
        StreamDispatcher::new(Fleet::new(vec![
            Board::native("exynos", SocSpec::exynos5422()),
            Board::native("smp2", SocSpec::symmetric(2)),
        ]))
    }

    /// ISSUE 4 degeneracy anchor: an all-at-t=0 single-shape stream
    /// under a static strategy reproduces `FleetDispatcher::dispatch`
    /// bit for bit — same responses (matrices, checksums, board
    /// labels) and same deterministic per-board metrics.
    #[test]
    fn stream_dispatcher_degenerates_to_one_wave() {
        for strategy in [FleetStrategy::Sss, FleetStrategy::Sas] {
            let wave = fleet_dispatcher();
            let stream = stream_dispatcher();
            let mut wave_reqs = Vec::new();
            let mut stream_reqs = Vec::new();
            for i in 0..6u64 {
                let (req, _) = request(i, 64, 90 + i, Backend::Auto);
                wave_reqs.push(req.clone());
                stream_reqs.push(StreamRequest::at(0.0, req));
            }
            let a = wave.dispatch(wave_reqs, strategy);
            let b = stream.dispatch_stream(stream_reqs, strategy);
            assert_eq!(a.len(), b.len());
            for (i, (ra, rb)) in a.iter().zip(&b).enumerate() {
                let (ra, rb) = (ra.as_ref().unwrap(), rb.as_ref().unwrap());
                assert_eq!(ra.id, rb.id, "{}: request {i}", strategy.label());
                assert_eq!(ra.c, rb.c, "{}: request {i} matrix", strategy.label());
                assert_eq!(ra.checksum, rb.checksum);
                assert_eq!(
                    ra.backend_label, rb.backend_label,
                    "{}: request {i} must land on the same board",
                    strategy.label()
                );
            }
            let (ma, mb) = (wave.metrics(), stream.metrics());
            assert_eq!(ma.batches, mb.batches, "{}", strategy.label());
            for ((na, a), (nb, b)) in ma.boards.iter().zip(&mb.boards) {
                assert_eq!(na, nb);
                assert_eq!(a.completed, b.completed, "{strategy:?} board {na}");
                assert_eq!(a.total_flops, b.total_flops, "{strategy:?} board {na}");
            }
        }
    }

    /// Mixed shapes with staggered arrivals, every strategy: responses
    /// merge in submission order (not arrival order), the numerics
    /// survive, and every request executes exactly once.
    #[test]
    fn stream_dispatcher_merges_in_submission_order() {
        for strategy in [FleetStrategy::Sss, FleetStrategy::Sas, FleetStrategy::Das] {
            let d = stream_dispatcher();
            let mut reqs = Vec::new();
            let mut wants = Vec::new();
            // Arrival order deliberately scrambles submission order.
            let arrive = [0.5, 0.0, 0.25, 0.0, 0.75, 0.1];
            for (i, r) in [64usize, 96, 64, 96, 64, 64].iter().enumerate() {
                let (req, want) = request(i as u64, *r, 70 + i as u64, Backend::Auto);
                reqs.push(StreamRequest::at(arrive[i], req));
                wants.push(want);
            }
            let resps = d.dispatch_stream(reqs, strategy);
            assert_eq!(resps.len(), 6);
            for (i, (resp, want)) in resps.iter().zip(&wants).enumerate() {
                let resp = resp.as_ref().unwrap_or_else(|e| {
                    panic!("{}: request {i} failed: {e}", strategy.label())
                });
                assert_eq!(resp.id, i as u64, "{}: submission order", strategy.label());
                assert!(
                    max_abs_diff(&resp.c, want) < gemm_tolerance(96),
                    "{}: request {i} numerics",
                    strategy.label()
                );
            }
            let m = d.metrics();
            assert_eq!(m.completed(), 6, "{}", strategy.label());
            assert_eq!(m.boards.len(), 2);
        }
    }

    #[test]
    fn stream_dispatcher_empty_stream_is_empty() {
        let d = stream_dispatcher();
        assert!(d.dispatch_stream(Vec::new(), FleetStrategy::Das).is_empty());
        assert_eq!(d.metrics().completed(), 0);
    }

    /// ISSUE 8: wall-clock-paced admission honors arrival gaps — the
    /// run cannot finish before the last (scaled) arrival instant — and
    /// returns exactly the unpaced responses (pacing gates *when* work
    /// starts, never what runs where).
    #[test]
    fn paced_stream_honors_arrival_gaps() {
        let arrive = [0.0, 2.0, 4.0];
        let time_scale = 50.0; // 4 virtual s → 80 wall ms
        let d = stream_dispatcher();
        let mut reqs = Vec::new();
        let mut wants = Vec::new();
        for (i, &t) in arrive.iter().enumerate() {
            let (req, want) = request(i as u64, 64, 40 + i as u64, Backend::Auto);
            reqs.push(StreamRequest::at(t, req));
            wants.push(want);
        }
        let unpaced = stream_dispatcher().dispatch_stream(reqs.clone(), FleetStrategy::Das);
        let start = std::time::Instant::now();
        let paced = d.dispatch_stream_paced(reqs, FleetStrategy::Das, time_scale);
        let elapsed = start.elapsed().as_secs_f64();
        assert!(
            elapsed >= arrive[2] / time_scale,
            "paced run finished in {elapsed:.3}s, before the last arrival at {:.3}s",
            arrive[2] / time_scale
        );
        assert_eq!(paced.len(), 3);
        for (i, (resp, want)) in paced.iter().zip(&wants).enumerate() {
            let resp = resp.as_ref().unwrap();
            assert_eq!(resp.id, i as u64, "submission order");
            assert!(max_abs_diff(&resp.c, want) < gemm_tolerance(64), "request {i} numerics");
            let twin = unpaced[i].as_ref().unwrap();
            assert_eq!(resp.c, twin.c, "paced numerics must match unpaced");
            assert_eq!(resp.checksum, twin.checksum);
        }
        assert_eq!(d.metrics().completed(), 3);
    }

    #[test]
    #[should_panic(expected = "time scale")]
    fn paced_stream_rejects_bad_time_scale() {
        let d = stream_dispatcher();
        let (req, _) = request(0, 32, 1, Backend::Auto);
        let _ = d.dispatch_stream_paced(
            vec![StreamRequest::at(0.0, req)],
            FleetStrategy::Das,
            f64::NAN,
        );
    }

    #[test]
    #[should_panic(expected = "arrival instant")]
    fn stream_dispatcher_rejects_bad_arrivals() {
        let d = stream_dispatcher();
        let (req, _) = request(0, 32, 1, Backend::Auto);
        let _ = d.dispatch_stream(
            vec![StreamRequest::at(f64::NAN, req)],
            FleetStrategy::Das,
        );
    }
}
