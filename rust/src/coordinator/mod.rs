//! Layer-3 coordinator: a GEMM service over the native executor, the
//! PJRT runtime and the simulator.
//!
//! The paper's contribution is the scheduling layer itself, so the
//! coordinator is the thin-but-real driver DESIGN.md calls for: a job
//! queue with a same-shape batcher (PJRT executables are shape-
//! specialized — grouping identical shapes amortizes dispatch), worker
//! threads, model-driven strategy auto-selection (the §5.2 ratio knob
//! computed from the calibrated performance model rather than an
//! environment variable), and metrics. `std::thread` + `mpsc` replace
//! tokio (offline crate set, DESIGN.md §2); the workload is CPU-bound
//! GEMM, so blocking workers are the right shape anyway.

pub mod server;

use crate::blis::gemm::GemmShape;
use crate::model::PerfModel;
use crate::native;
use crate::runtime::worker::PjrtHandle;
use crate::sched::ScheduleSpec;
use crate::sim;
use crate::soc::SocSpec;
use anyhow::{anyhow, Result};
use std::collections::HashMap;
use std::sync::mpsc;
use std::sync::{Arc, Mutex};

/// Which engine executes a request.
#[derive(Debug, Clone, PartialEq)]
pub enum Backend {
    /// Real threads + packed GEMM under a schedule (default CA-DAS).
    Native(ScheduleSpec),
    /// AOT artifact via PJRT; `variant` picks the control-tree analogue.
    Pjrt { variant: String },
    /// Virtual-time simulation (capacity planning / what-if).
    Sim(ScheduleSpec),
    /// Model-driven dispatch: PJRT when an exact-shape artifact exists
    /// (compiled executable, no packing cost), native CA-DAS otherwise.
    Auto,
}

/// One GEMM request. Operands are owned so requests can cross threads.
#[derive(Debug, Clone)]
pub struct Request {
    pub id: u64,
    pub shape: GemmShape,
    pub a: Arc<Vec<f64>>,
    pub b: Arc<Vec<f64>>,
    pub backend: Backend,
}

/// Completed response.
#[derive(Debug, Clone)]
pub struct Response {
    pub id: u64,
    /// Result matrix (empty for Sim backend).
    pub c: Vec<f64>,
    pub latency_s: f64,
    pub gflops: f64,
    pub backend_label: String,
    /// Deterministic checksum of C (sum of elements) for cheap
    /// cross-backend verification.
    pub checksum: f64,
}

/// Service metrics.
#[derive(Debug, Clone, Default)]
pub struct Metrics {
    pub completed: u64,
    pub total_flops: f64,
    pub total_latency_s: f64,
    pub batches: u64,
}

/// The coordinator service.
#[allow(missing_debug_implementations)]
pub struct Coordinator {
    soc: SocSpec,
    model: PerfModel,
    runtime: Option<PjrtHandle>,
    metrics: Mutex<Metrics>,
}

impl Coordinator {
    /// Build without a PJRT runtime (native/sim backends only).
    pub fn new(soc: SocSpec) -> Self {
        let model = PerfModel::new(soc.clone());
        Coordinator {
            soc,
            model,
            runtime: None,
            metrics: Mutex::new(Metrics::default()),
        }
    }

    /// Build with PJRT artifacts loaded from `dir` (spawns the runtime
    /// thread; see [`PjrtHandle`]).
    pub fn with_artifacts(soc: SocSpec, dir: &std::path::Path) -> Result<Self> {
        let handle = PjrtHandle::spawn(dir)?;
        let mut c = Coordinator::new(soc);
        c.runtime = Some(handle);
        Ok(c)
    }

    pub fn soc(&self) -> &SocSpec {
        &self.soc
    }

    pub fn metrics(&self) -> Metrics {
        self.metrics.lock().unwrap().clone()
    }

    /// Model-driven default schedule: CA-DAS (the paper's best).
    pub fn auto_spec(&self) -> ScheduleSpec {
        ScheduleSpec::ca_das()
    }

    /// Model-driven SAS ratio (§5.2's knob, computed instead of guessed):
    /// the big:LITTLE cluster throughput ratio under the oblivious
    /// single-tree configuration, rounded to the nearest integer.
    pub fn auto_ratio(&self) -> f64 {
        let p = crate::blis::params::BlisParams::a15_opt();
        self.model.ideal_ratio(&p, &p).round().clamp(1.0, 8.0)
    }

    /// Resolve `Auto` to a concrete backend for a shape: a loaded
    /// exact-shape artifact wins (zero compile/packing cost at request
    /// time); otherwise the native CA-DAS executor handles any shape.
    pub fn resolve_auto(&self, shape: GemmShape) -> Backend {
        if let Some(rt) = &self.runtime {
            for variant in ["big", "little"] {
                if let Ok(true) = rt.has(shape, variant) {
                    return Backend::Pjrt { variant: variant.to_string() };
                }
            }
        }
        Backend::Native(self.auto_spec())
    }

    /// Execute one request synchronously.
    pub fn execute(&self, req: &Request) -> Result<Response> {
        if req.backend == Backend::Auto {
            let mut resolved = req.clone();
            resolved.backend = self.resolve_auto(req.shape);
            debug_assert!(resolved.backend != Backend::Auto);
            return self.execute(&resolved);
        }
        let t0 = std::time::Instant::now();
        let (c, label) = match &req.backend {
            Backend::Auto => unreachable!("resolved above"),
            Backend::Native(spec) => {
                let mut c = vec![0.0; req.shape.m * req.shape.n];
                let stats =
                    native::gemm_parallel(&self.soc, spec, req.shape, &req.a, &req.b, &mut c);
                (c, format!("native/{}", stats.label))
            }
            Backend::Pjrt { variant } => {
                let rt = self
                    .runtime
                    .as_ref()
                    .ok_or_else(|| anyhow!("no PJRT runtime configured"))?;
                let (name, c) =
                    rt.execute(req.shape, variant, req.a.to_vec(), req.b.to_vec())?;
                (c, format!("pjrt/{name}"))
            }
            Backend::Sim(spec) => {
                let stats = sim::simulate(&self.model, spec, req.shape);
                (Vec::new(), format!("sim/{} {:.2} GFLOPS(v)", stats.label, stats.gflops))
            }
        };
        let latency = t0.elapsed().as_secs_f64();
        let flops = req.shape.flops();
        {
            let mut m = self.metrics.lock().unwrap();
            m.completed += 1;
            m.total_flops += flops;
            m.total_latency_s += latency;
        }
        Ok(Response {
            id: req.id,
            checksum: c.iter().sum(),
            gflops: flops / latency / 1e9,
            latency_s: latency,
            backend_label: label,
            c,
        })
    }

    /// Batch executor: groups requests by (shape, backend kind) so PJRT
    /// requests with the same artifact run back-to-back on the already-
    /// compiled executable, then dispatches each group on a worker
    /// thread. Responses are returned in request order.
    pub fn execute_batch(&self, reqs: Vec<Request>) -> Vec<Result<Response>> {
        let n = reqs.len();
        // Group indices by batch key.
        let mut groups: HashMap<String, Vec<usize>> = HashMap::new();
        for (i, r) in reqs.iter().enumerate() {
            groups.entry(Self::batch_key(r)).or_default().push(i);
        }
        {
            let mut m = self.metrics.lock().unwrap();
            m.batches += groups.len() as u64;
        }

        let (tx, rx) = mpsc::channel::<(usize, Result<Response>)>();
        std::thread::scope(|s| {
            for (_, idxs) in groups {
                let tx = tx.clone();
                let reqs = &reqs;
                s.spawn(move || {
                    for i in idxs {
                        let resp = self.execute(&reqs[i]);
                        tx.send((i, resp)).expect("result channel");
                    }
                });
            }
            drop(tx);
        });
        let mut out: Vec<Option<Result<Response>>> = (0..n).map(|_| None).collect();
        for (i, r) in rx {
            out[i] = Some(r);
        }
        out.into_iter().map(|o| o.expect("all jobs complete")).collect()
    }

    fn batch_key(r: &Request) -> String {
        let kind = match &r.backend {
            Backend::Native(s) => format!("native/{}", s.label()),
            Backend::Pjrt { variant } => format!("pjrt/{variant}"),
            Backend::Sim(s) => format!("sim/{}", s.label()),
            Backend::Auto => "auto".to_string(),
        };
        format!("{}:{}x{}x{}", kind, r.shape.m, r.shape.n, r.shape.k)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::blis::gemm::gemm_naive;
    use crate::util::rng::Rng;
    use crate::util::stats::{gemm_tolerance, max_abs_diff};
    use std::path::Path;

    fn artifacts_dir() -> std::path::PathBuf {
        Path::new(env!("CARGO_MANIFEST_DIR")).join("artifacts")
    }

    fn request(id: u64, r: usize, seed: u64, backend: Backend) -> (Request, Vec<f64>) {
        let mut rng = Rng::new(seed);
        let a = rng.fill_matrix(r * r);
        let b = rng.fill_matrix(r * r);
        let mut want = vec![0.0; r * r];
        gemm_naive(GemmShape::square(r), &a, &b, &mut want);
        (
            Request {
                id,
                shape: GemmShape::square(r),
                a: Arc::new(a),
                b: Arc::new(b),
                backend,
            },
            want,
        )
    }

    #[test]
    fn native_backend_correct() {
        let c = Coordinator::new(SocSpec::exynos5422());
        let (req, want) = request(1, 96, 5, Backend::Native(ScheduleSpec::ca_das()));
        let resp = c.execute(&req).unwrap();
        assert!(max_abs_diff(&resp.c, &want) < gemm_tolerance(96));
        assert!(resp.backend_label.starts_with("native/CA-DAS"));
        assert_eq!(c.metrics().completed, 1);
    }

    #[test]
    fn sim_backend_returns_virtual_stats() {
        let c = Coordinator::new(SocSpec::exynos5422());
        let (req, _) = request(2, 512, 6, Backend::Sim(ScheduleSpec::sas(5.0)));
        let resp = c.execute(&req).unwrap();
        assert!(resp.c.is_empty());
        assert!(resp.backend_label.contains("GFLOPS(v)"));
    }

    #[test]
    fn pjrt_backend_correct_and_matches_native() {
        if !artifacts_dir().join("manifest.txt").exists() {
            eprintln!("skipping: run `make artifacts` first");
            return;
        }
        let c = Coordinator::with_artifacts(SocSpec::exynos5422(), &artifacts_dir()).unwrap();
        let (req, want) = request(3, 128, 7, Backend::Pjrt { variant: "big".into() });
        let resp = c.execute(&req).unwrap();
        assert!(max_abs_diff(&resp.c, &want) < gemm_tolerance(128));

        // Same request through the native path: checksums agree.
        let (req_n, _) = request(4, 128, 7, Backend::Native(ScheduleSpec::ca_das()));
        let resp_n = c.execute(&req_n).unwrap();
        assert!((resp.checksum - resp_n.checksum).abs() < 1e-6);
    }

    #[test]
    fn pjrt_without_runtime_errors() {
        let c = Coordinator::new(SocSpec::exynos5422());
        let (req, _) = request(5, 64, 8, Backend::Pjrt { variant: "big".into() });
        assert!(c.execute(&req).is_err());
    }

    #[test]
    fn pjrt_unknown_shape_errors() {
        if !artifacts_dir().join("manifest.txt").exists() {
            eprintln!("skipping: run `make artifacts` first");
            return;
        }
        let c = Coordinator::with_artifacts(SocSpec::exynos5422(), &artifacts_dir()).unwrap();
        let (req, _) = request(6, 99, 9, Backend::Pjrt { variant: "big".into() });
        let err = c.execute(&req).unwrap_err().to_string();
        assert!(err.contains("no artifact"), "{err}");
    }

    #[test]
    fn batch_groups_and_preserves_order() {
        let c = Coordinator::new(SocSpec::exynos5422());
        let mut reqs = Vec::new();
        let mut wants = Vec::new();
        for (i, r) in [64usize, 96, 64, 96, 64].iter().enumerate() {
            let (req, want) = request(i as u64, *r, 20 + i as u64, Backend::Native(ScheduleSpec::sas(5.0)));
            reqs.push(req);
            wants.push(want);
        }
        let resps = c.execute_batch(reqs);
        assert_eq!(resps.len(), 5);
        for (i, (resp, want)) in resps.iter().zip(&wants).enumerate() {
            let resp = resp.as_ref().unwrap();
            assert_eq!(resp.id, i as u64);
            assert!(max_abs_diff(&resp.c, want) < gemm_tolerance(96));
        }
        // 2 distinct shapes × 1 backend = 2 batch groups.
        assert_eq!(c.metrics().batches, 2);
        assert_eq!(c.metrics().completed, 5);
    }

    #[test]
    fn auto_backend_resolves_by_artifact_availability() {
        // Without a runtime, Auto always resolves to native CA-DAS.
        let c = Coordinator::new(SocSpec::exynos5422());
        assert_eq!(
            c.resolve_auto(GemmShape::square(128)),
            Backend::Native(ScheduleSpec::ca_das())
        );
        let (req, want) = request(10, 96, 30, Backend::Auto);
        let resp = c.execute(&req).unwrap();
        assert!(resp.backend_label.starts_with("native/"));
        assert!(max_abs_diff(&resp.c, &want) < gemm_tolerance(96));

        // With artifacts, exact shapes go to PJRT, odd shapes to native.
        if artifacts_dir().join("manifest.txt").exists() {
            let c = Coordinator::with_artifacts(SocSpec::exynos5422(), &artifacts_dir()).unwrap();
            assert!(matches!(
                c.resolve_auto(GemmShape::square(128)),
                Backend::Pjrt { .. }
            ));
            assert_eq!(
                c.resolve_auto(GemmShape::square(99)),
                Backend::Native(ScheduleSpec::ca_das())
            );
            let (req, want) = request(11, 128, 31, Backend::Auto);
            let resp = c.execute(&req).unwrap();
            assert!(resp.backend_label.starts_with("pjrt/"), "{}", resp.backend_label);
            assert!(max_abs_diff(&resp.c, &want) < gemm_tolerance(128));
        }
    }

    #[test]
    fn auto_ratio_matches_paper_knob() {
        let c = Coordinator::new(SocSpec::exynos5422());
        // §5.2.2/Fig. 9: the right ratio is ≈ 5.
        assert_eq!(c.auto_ratio(), 5.0);
        assert_eq!(c.auto_spec(), ScheduleSpec::ca_das());
    }
}
