//! Line-protocol TCP front-end for the coordinator (std::net — see
//! DESIGN.md §2 for the no-tokio substitution).
//!
//! Protocol (one request per line, whitespace-separated):
//!
//! ```text
//! GEMM <m> <n> <k> <seed> <backend>   backend ∈ native|pjrt|pjrt:<variant>|sim
//! JOB gemm <m> <n> <k> <seed> <backend>   alias for GEMM
//! JOB chol <n> <nb> <seed>            blocked Cholesky via the task-DAG runtime
//! JOB lu <n> <nb> <seed>              blocked LU (no pivoting), same runtime
//! HELP
//! PING
//! STATS
//! METRICS
//! QUIT
//! ```
//!
//! Operands are generated server-side from the deterministic seed
//! (xorshift64*, same generator as the test suite) so the protocol stays
//! tiny while results remain verifiable: the response carries a checksum
//! any client can recompute. Factorizations seed an SPD (chol) or
//! diagonally-dominant (lu) matrix and run the [`crate::dag`] blocked
//! algorithm on the coordinator's SoC under its auto schedule.
//!
//! Responses: `OK <id> <latency_ms> <gflops> <checksum> <label>` or
//! `ERR <message>`; `PONG`; `STATS <completed> <batches> <avg_gflops>`;
//! `METRICS` replies with a one-line JSON snapshot of the coordinator's
//! [`crate::obs::MetricsRegistry`] view (counters + derived gauges);
//! `HELP` lists the command family on one line. Errors are structured:
//! the first `ERR` token names the failure kind (`ERR empty_request`,
//! `ERR unknown_command <token>`, `ERR unknown_job <kind>` for a `JOB`
//! whose kind is not gemm/chol/lu, `ERR usage ...` for a known job with
//! the wrong arity, `ERR <detail>` for malformed operands), so clients
//! can dispatch on it without scraping prose.

use crate::blis::gemm::GemmShape;
use crate::coordinator::{Backend, Coordinator, Request};
use crate::dag::FactorKind;
use crate::util::rng::Rng;
use std::io::{BufRead, BufReader, Write};
use std::net::{TcpListener, TcpStream};
use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::Arc;

/// A running server; dropping it does not stop the listener — call
/// [`ServerHandle::shutdown`].
pub struct ServerHandle {
    pub addr: std::net::SocketAddr,
    stop: Arc<AtomicBool>,
    join: Option<std::thread::JoinHandle<()>>,
}

impl ServerHandle {
    pub fn shutdown(mut self) {
        self.stop.store(true, Ordering::SeqCst);
        // Nudge the accept loop.
        let _ = TcpStream::connect(self.addr);
        if let Some(j) = self.join.take() {
            let _ = j.join();
        }
    }
}

/// Start serving on `addr` (use port 0 for ephemeral). One thread per
/// connection; the coordinator itself is shared.
pub fn serve(coordinator: Arc<Coordinator>, addr: &str) -> std::io::Result<ServerHandle> {
    let listener = TcpListener::bind(addr)?;
    let local = listener.local_addr()?;
    let stop = Arc::new(AtomicBool::new(false));
    let stop2 = stop.clone();
    let next_id = Arc::new(AtomicU64::new(1));
    let join = std::thread::spawn(move || {
        for conn in listener.incoming() {
            if stop2.load(Ordering::SeqCst) {
                break;
            }
            let Ok(stream) = conn else { continue };
            let coord = coordinator.clone();
            let ids = next_id.clone();
            std::thread::spawn(move || {
                let _ = handle_conn(coord, ids, stream);
            });
        }
    });
    Ok(ServerHandle {
        addr: local,
        stop,
        join: Some(join),
    })
}

fn handle_conn(
    coord: Arc<Coordinator>,
    ids: Arc<AtomicU64>,
    stream: TcpStream,
) -> std::io::Result<()> {
    let mut writer = stream.try_clone()?;
    let reader = BufReader::new(stream);
    for line in reader.lines() {
        let line = line?;
        let reply = match handle_line(&coord, &ids, line.trim()) {
            LineResult::Reply(s) => s,
            LineResult::Quit => break,
        };
        writer.write_all(reply.as_bytes())?;
        writer.write_all(b"\n")?;
    }
    Ok(())
}

enum LineResult {
    Reply(String),
    Quit,
}

fn handle_line(coord: &Coordinator, ids: &AtomicU64, line: &str) -> LineResult {
    let parts: Vec<&str> = line.split_whitespace().collect();
    match parts.as_slice() {
        [] => LineResult::Reply("ERR empty_request".into()),
        ["PING"] => LineResult::Reply("PONG".into()),
        ["QUIT"] => LineResult::Quit,
        ["METRICS"] => LineResult::Reply(metrics_snapshot(coord).to_json()),
        ["STATS"] => {
            let m = coord.metrics();
            let avg = if m.total_latency_s > 0.0 {
                m.total_flops / m.total_latency_s / 1e9
            } else {
                0.0
            };
            LineResult::Reply(format!("STATS {} {} {:.3}", m.completed, m.batches, avg))
        }
        ["HELP"] => LineResult::Reply(HELP_LINE.into()),
        ["GEMM", m, n, k, seed, backend] => {
            match gemm_request(coord, ids, m, n, k, seed, backend) {
                Ok(s) => LineResult::Reply(s),
                Err(e) => LineResult::Reply(format!("ERR {e}")),
            }
        }
        // ISSUE 10: the JOB family routes every workload kind through
        // one verb; `JOB gemm` is an exact alias for the legacy GEMM
        // command, chol/lu run the task-DAG factorization runtime.
        ["JOB", "gemm", m, n, k, seed, backend] => {
            match gemm_request(coord, ids, m, n, k, seed, backend) {
                Ok(s) => LineResult::Reply(s),
                Err(e) => LineResult::Reply(format!("ERR {e}")),
            }
        }
        ["JOB", kind @ ("chol" | "lu"), n, nb, seed] => {
            match factor_request(coord, ids, kind, n, nb, seed) {
                Ok(s) => LineResult::Reply(s),
                Err(e) => LineResult::Reply(format!("ERR {e}")),
            }
        }
        // Known job kind, wrong arity: say what the right call looks
        // like instead of claiming the kind is unknown.
        ["JOB", "gemm", ..] => {
            LineResult::Reply("ERR usage JOB gemm <m> <n> <k> <seed> <backend>".into())
        }
        ["JOB", kind @ ("chol" | "lu"), ..] => {
            LineResult::Reply(format!("ERR usage JOB {kind} <n> <nb> <seed>"))
        }
        // Structured unknown-job error, mirroring unknown_command.
        ["JOB", kind, ..] => LineResult::Reply(format!("ERR unknown_job {kind}")),
        ["JOB"] => LineResult::Reply("ERR usage JOB <kind> <args..> (HELP lists kinds)".into()),
        // Structured unknown-command error: a fixed kind token plus the
        // offending command, machine-dispatchable.
        [cmd, ..] => LineResult::Reply(format!("ERR unknown_command {cmd}")),
    }
}

/// One-line command reference returned by `HELP`.
const HELP_LINE: &str = "OK commands: GEMM <m> <n> <k> <seed> <backend> | \
JOB gemm <m> <n> <k> <seed> <backend> | JOB chol <n> <nb> <seed> | \
JOB lu <n> <nb> <seed> | HELP | PING | STATS | METRICS | QUIT";

/// The coordinator's counters as an observability registry — what the
/// `METRICS` command serializes (one-line JSON) and `amp-gemm metrics`
/// renders as Prometheus text.
pub fn metrics_snapshot(coord: &Coordinator) -> crate::obs::MetricsRegistry {
    let m = coord.metrics();
    let mut reg = crate::obs::MetricsRegistry::new();
    reg.inc("coordinator_completed", m.completed as f64);
    reg.inc("coordinator_batches", m.batches as f64);
    reg.inc("coordinator_total_flops", m.total_flops);
    reg.inc("coordinator_total_latency_s", m.total_latency_s);
    reg.set_gauge(
        "coordinator_avg_gflops",
        if m.total_latency_s > 0.0 { m.total_flops / m.total_latency_s / 1e9 } else { 0.0 },
    );
    reg
}

fn gemm_request(
    coord: &Coordinator,
    ids: &AtomicU64,
    m: &str,
    n: &str,
    k: &str,
    seed: &str,
    backend: &str,
) -> Result<String, String> {
    let parse = |s: &str, what: &str| -> Result<usize, String> {
        s.parse::<usize>()
            .map_err(|_| format!("bad {what} '{s}'"))
            .and_then(|v| {
                if v == 0 || v > 4096 {
                    Err(format!("{what} out of range (1..=4096): {v}"))
                } else {
                    Ok(v)
                }
            })
    };
    let (m, n, k) = (parse(m, "m")?, parse(n, "n")?, parse(k, "k")?);
    let seed: u64 = seed.parse().map_err(|_| format!("bad seed '{seed}'"))?;
    let backend = match backend {
        "native" => Backend::Native(coord.auto_spec()),
        "sim" => Backend::Sim(coord.auto_spec()),
        "pjrt" => Backend::Pjrt { variant: "big".into() },
        "auto" => Backend::Auto,
        other => match other.split_once(':') {
            Some(("pjrt", v)) => Backend::Pjrt { variant: v.to_string() },
            _ => return Err(format!("unknown backend '{other}'")),
        },
    };
    let mut rng = Rng::new(seed);
    let a = rng.fill_matrix(m * k);
    let b = rng.fill_matrix(k * n);
    let req = Request {
        id: ids.fetch_add(1, Ordering::SeqCst),
        shape: GemmShape { m, n, k },
        a: Arc::new(a),
        b: Arc::new(b),
        backend,
    };
    let resp = coord.execute(&req).map_err(|e| e.to_string())?;
    Ok(format!(
        "OK {} {:.3} {:.3} {:.6e} {}",
        resp.id,
        resp.latency_s * 1e3,
        resp.gflops,
        resp.checksum,
        resp.backend_label.replace(' ', "_")
    ))
}

/// Execute `JOB chol|lu <n> <nb> <seed>`: seed a well-conditioned
/// matrix server-side, run the blocked factorization through the
/// task-DAG runtime ([`crate::dag::exec`]) on the coordinator's SoC
/// under its auto schedule, and answer in the same `OK` grammar as
/// GEMM (`gflops` counts the factorization's useful flops).
fn factor_request(
    coord: &Coordinator,
    ids: &AtomicU64,
    kind: &str,
    n: &str,
    nb: &str,
    seed: &str,
) -> Result<String, String> {
    let kind = FactorKind::parse(kind)?;
    let n: usize = n.parse().map_err(|_| format!("bad n '{n}'"))?;
    let nb: usize = nb.parse().map_err(|_| format!("bad nb '{nb}'"))?;
    let seed: u64 = seed.parse().map_err(|_| format!("bad seed '{seed}'"))?;
    if n == 0 || n > 1024 {
        return Err(format!("n out of range (1..=1024): {n}"));
    }
    if nb == 0 || nb > n || n % nb != 0 {
        return Err(format!("nb must divide n (got n={n} nb={nb})"));
    }
    let mut rng = Rng::new(seed);
    let mut a = rng.fill_matrix(n * n);
    match kind {
        // Symmetric + strictly diagonally dominant ⇒ SPD.
        FactorKind::Cholesky => {
            for i in 0..n {
                for j in 0..i {
                    let avg = 0.5 * (a[i * n + j] + a[j * n + i]);
                    a[i * n + j] = avg;
                    a[j * n + i] = avg;
                }
                a[i * n + i] = a[i * n + i].abs() + n as f64 + 1.0;
            }
        }
        // Diagonal dominance keeps pivot-free LU stable.
        FactorKind::Lu => {
            for i in 0..n {
                a[i * n + i] += n as f64 + 1.0;
            }
        }
    }
    let spec = coord.auto_spec();
    let start = std::time::Instant::now();
    let log = match kind {
        FactorKind::Cholesky => crate::dag::exec::cholesky(coord.soc(), &spec, n, nb, &mut a),
        FactorKind::Lu => crate::dag::exec::lu(coord.soc(), &spec, n, nb, &mut a),
    };
    let latency_s = start.elapsed().as_secs_f64();
    debug_assert!(!log.executed.is_empty());
    let checksum: f64 = a.iter().sum();
    let gflops = if latency_s > 0.0 { kind.flops(n) / latency_s / 1e9 } else { 0.0 };
    Ok(format!(
        "OK {} {:.3} {:.3} {:.6e} native/{}_n{}_nb{}",
        ids.fetch_add(1, Ordering::SeqCst),
        latency_s * 1e3,
        gflops,
        checksum,
        kind.label(),
        n,
        nb
    ))
}

/// Minimal blocking client for examples and tests.
pub struct Client {
    reader: BufReader<TcpStream>,
    writer: TcpStream,
}

impl Client {
    pub fn connect(addr: std::net::SocketAddr) -> std::io::Result<Client> {
        let stream = TcpStream::connect(addr)?;
        Ok(Client {
            reader: BufReader::new(stream.try_clone()?),
            writer: stream,
        })
    }

    pub fn call(&mut self, line: &str) -> std::io::Result<String> {
        self.writer.write_all(line.as_bytes())?;
        self.writer.write_all(b"\n")?;
        let mut reply = String::new();
        self.reader.read_line(&mut reply)?;
        Ok(reply.trim_end().to_string())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::soc::SocSpec;

    fn start() -> (Arc<Coordinator>, ServerHandle) {
        let coord = Arc::new(Coordinator::new(SocSpec::exynos5422()));
        let h = serve(coord.clone(), "127.0.0.1:0").unwrap();
        (coord, h)
    }

    #[test]
    fn ping_pong() {
        let (_c, h) = start();
        let mut cl = Client::connect(h.addr).unwrap();
        assert_eq!(cl.call("PING").unwrap(), "PONG");
        h.shutdown();
    }

    #[test]
    fn gemm_native_roundtrip_and_checksum_determinism() {
        let (_c, h) = start();
        let mut cl = Client::connect(h.addr).unwrap();
        let r1 = cl.call("GEMM 64 64 64 42 native").unwrap();
        assert!(r1.starts_with("OK "), "{r1}");
        let checksum1: f64 = r1.split_whitespace().nth(4).unwrap().parse().unwrap();
        let r2 = cl.call("GEMM 64 64 64 42 native").unwrap();
        let checksum2: f64 = r2.split_whitespace().nth(4).unwrap().parse().unwrap();
        assert_eq!(checksum1, checksum2, "same seed → same checksum");
        let r3 = cl.call("GEMM 64 64 64 43 native").unwrap();
        let checksum3: f64 = r3.split_whitespace().nth(4).unwrap().parse().unwrap();
        assert_ne!(checksum1, checksum3);
        h.shutdown();
    }

    #[test]
    fn sim_backend_over_wire() {
        let (_c, h) = start();
        let mut cl = Client::connect(h.addr).unwrap();
        let r = cl.call("GEMM 1024 1024 1024 1 sim").unwrap();
        assert!(r.starts_with("OK "), "{r}");
        h.shutdown();
    }

    #[test]
    fn stats_accumulate() {
        let (_c, h) = start();
        let mut cl = Client::connect(h.addr).unwrap();
        cl.call("GEMM 32 32 32 1 native").unwrap();
        cl.call("GEMM 32 32 32 2 native").unwrap();
        let stats = cl.call("STATS").unwrap();
        let completed: u64 = stats.split_whitespace().nth(1).unwrap().parse().unwrap();
        assert_eq!(completed, 2, "{stats}");
        h.shutdown();
    }

    #[test]
    fn errors_are_reported_not_fatal() {
        let (_c, h) = start();
        let mut cl = Client::connect(h.addr).unwrap();
        assert!(cl.call("GEMM 0 1 1 1 native").unwrap().starts_with("ERR"));
        assert!(cl.call("GEMM 64 64 64 1 warp").unwrap().starts_with("ERR"));
        assert!(cl.call("BOGUS").unwrap().starts_with("ERR"));
        // Connection still alive afterwards.
        assert_eq!(cl.call("PING").unwrap(), "PONG");
        h.shutdown();
    }

    #[test]
    fn unknown_command_error_is_structured() {
        let (_c, h) = start();
        let mut cl = Client::connect(h.addr).unwrap();
        assert_eq!(cl.call("BOGUS one two").unwrap(), "ERR unknown_command BOGUS");
        assert_eq!(cl.call("metrics").unwrap(), "ERR unknown_command metrics");
        h.shutdown();
    }

    /// ISSUE 10: `JOB gemm` is a pure alias — same grammar, same
    /// deterministic checksum as the legacy `GEMM` verb.
    #[test]
    fn job_gemm_aliases_the_legacy_command() {
        let (_c, h) = start();
        let mut cl = Client::connect(h.addr).unwrap();
        let legacy = cl.call("GEMM 48 48 48 7 native").unwrap();
        let alias = cl.call("JOB gemm 48 48 48 7 native").unwrap();
        assert!(alias.starts_with("OK "), "{alias}");
        let nth = |r: &str, i: usize| r.split_whitespace().nth(i).unwrap().to_string();
        // Same checksum, gflops field present, same backend label.
        assert_eq!(nth(&legacy, 4), nth(&alias, 4));
        assert_eq!(nth(&legacy, 5), nth(&alias, 5));
        h.shutdown();
    }

    /// ISSUE 10: factorizations round-trip over the wire — blocked
    /// Cholesky and LU run through the task-DAG runtime, respond in
    /// the GEMM grammar, and checksums are seed-deterministic.
    #[test]
    fn job_factorizations_over_the_wire() {
        let (_c, h) = start();
        let mut cl = Client::connect(h.addr).unwrap();
        let r1 = cl.call("JOB chol 96 32 5 ").unwrap();
        assert!(r1.starts_with("OK "), "{r1}");
        assert!(r1.ends_with("native/chol_n96_nb32"), "{r1}");
        let r2 = cl.call("JOB chol 96 32 5").unwrap();
        let nth = |r: &str, i: usize| r.split_whitespace().nth(i).unwrap().to_string();
        assert_eq!(nth(&r1, 4), nth(&r2, 4), "same seed → same checksum");
        let lu = cl.call("JOB lu 64 32 9").unwrap();
        assert!(lu.starts_with("OK "), "{lu}");
        assert!(lu.ends_with("native/lu_n64_nb32"), "{lu}");
        assert_ne!(nth(&r1, 4), nth(&lu, 4));
        h.shutdown();
    }

    /// ISSUE 10: structured JOB errors — unknown kinds get a fixed
    /// `ERR unknown_job` token, bad arity and bad operands stay
    /// non-fatal, and `HELP` lists the whole command family.
    #[test]
    fn job_errors_and_help_are_structured() {
        let (_c, h) = start();
        let mut cl = Client::connect(h.addr).unwrap();
        assert_eq!(cl.call("JOB qr 96 32 1").unwrap(), "ERR unknown_job qr");
        assert_eq!(
            cl.call("JOB chol 96").unwrap(),
            "ERR usage JOB chol <n> <nb> <seed>"
        );
        assert!(cl.call("JOB chol 100 32 1").unwrap().starts_with("ERR"), "nb must divide n");
        assert!(cl.call("JOB lu 2048 64 1").unwrap().starts_with("ERR"), "n capped at 1024");
        let help = cl.call("HELP").unwrap();
        assert!(help.starts_with("OK commands:"), "{help}");
        for verb in ["GEMM", "JOB gemm", "JOB chol", "JOB lu", "HELP", "STATS"] {
            assert!(help.contains(verb), "HELP missing {verb}: {help}");
        }
        // Connection still alive afterwards.
        assert_eq!(cl.call("PING").unwrap(), "PONG");
        h.shutdown();
    }

    #[test]
    fn metrics_round_trip_through_client() {
        let (_c, h) = start();
        let mut cl = Client::connect(h.addr).unwrap();
        cl.call("GEMM 32 32 32 1 native").unwrap();
        let reply = cl.call("METRICS").unwrap();
        // One line, parseable JSON, with the executed request counted.
        assert!(reply.starts_with('{'), "{reply}");
        let v = crate::obs::json::parse(&reply).unwrap();
        let counters = v.get("counters").unwrap();
        assert_eq!(
            counters.get("coordinator_completed").unwrap().as_num(),
            Some(1.0)
        );
        assert!(counters.get("coordinator_total_flops").unwrap().as_num().unwrap() > 0.0);
        h.shutdown();
    }

    #[test]
    fn concurrent_clients() {
        let (_c, h) = start();
        let addr = h.addr;
        let mut joins = Vec::new();
        for seed in 0..4 {
            joins.push(std::thread::spawn(move || {
                let mut cl = Client::connect(addr).unwrap();
                let r = cl.call(&format!("GEMM 48 48 48 {seed} native")).unwrap();
                assert!(r.starts_with("OK "), "{r}");
            }));
        }
        for j in joins {
            j.join().unwrap();
        }
        h.shutdown();
    }
}
