//! Scheduling strategies (paper §4–§5), generalized to N clusters.
//!
//! A [`ScheduleSpec`] describes one complete configuration of the
//! multi-threaded GEMM:
//!
//! * the **strategy** — who gets how much work and with which control
//!   tree(s): isolated clusters (§3.4), symmetric-static SSS (§4),
//!   static-asymmetric SAS (§5.2), cache-aware CA-SAS (§5.3), dynamic
//!   DAS / CA-DAS (§5.4);
//! * the **coarse-grain loop** distributing micro-kernels between
//!   clusters (Loop 1 or Loop 3, §5.2.1);
//! * the **fine-grain loop** distributing a macro-kernel among the cores
//!   of one cluster (Loop 4, Loop 5 or both, §5.2.1).
//!
//! The paper's big:LITTLE `ratio` is the two-cluster special case of an
//! N-way weight vector ([`Weights`]): SAS/CA-SAS feed it straight into
//! the weighted-static partitioner, so the same machinery schedules a
//! tri-cluster DynamIQ SoC or a symmetric SMP. Cache-aware strategies
//! derive each cluster's control tree from *that cluster's* tuned
//! parameters (and its own shared-`kc` refit under Loop 3), instead of
//! a hard-coded big/LITTLE pair.
//!
//! Both the DES simulator (`crate::sim`) and the real-thread executor
//! (`crate::native`) consume the same spec, so the shapes measured in
//! the figures and the numerics verified in tests come from one
//! description of the schedule.

use crate::blis::control_tree::{ControlTree, Parallelism, TreeSet};
use crate::blis::params::BlisParams;
use crate::soc::{ClusterId, SocSpec};

/// Upper bound on ways a [`Weights`] vector can address — clusters of
/// one SoC, or boards of a fleet. Keeps `ScheduleSpec` `Copy` (stack
/// array, no allocation); far above any real AMP topology or rack.
pub const MAX_WAYS: usize = 8;

/// Anything the weighted-static partitioner can divide work across: a
/// *way* with a throughput-proportional weight. Clusters of one SoC are
/// the paper's case (§5.2); boards of a [`crate::fleet::Fleet`] are the
/// same machinery one level up (cluster : SoC :: board : fleet).
pub trait Weighted {
    /// Relative throughput of this way (any positive unit; only ratios
    /// matter to the partitioner).
    fn weight(&self) -> f64;
}

/// Per-way work-distribution weights for the static-asymmetric
/// strategies: way `i` (a cluster, or a board at the fleet level)
/// receives a share proportional to `w[i]` (§5.2's `ratio` is
/// `Weights::ratio(r)` = `[r, 1]`).
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct Weights {
    w: [f64; MAX_WAYS],
    n: usize,
}

impl Weights {
    /// Build from explicit per-way weights (one per cluster in
    /// [`ClusterId`] order, or one per board in fleet order).
    pub fn from_slice(ws: &[f64]) -> Self {
        assert!(
            (1..=MAX_WAYS).contains(&ws.len()),
            "need 1..={MAX_WAYS} weights, got {}",
            ws.len()
        );
        assert!(
            ws.iter().all(|&x| x.is_finite() && x >= 0.0),
            "weights must be finite and non-negative: {ws:?}"
        );
        assert!(ws.iter().sum::<f64>() > 0.0, "at least one positive weight");
        let mut w = [0.0; MAX_WAYS];
        w[..ws.len()].copy_from_slice(ws);
        Weights { w, n: ws.len() }
    }

    /// Build from anything carrying its own weight — the generic entry
    /// point the fleet layer uses to turn a `&[Board]` into the same
    /// vector a `&[ClusterSpec]`-derived rate table produces.
    pub fn from_weighted<T: Weighted>(items: &[T]) -> Self {
        let ws: Vec<f64> = items.iter().map(Weighted::weight).collect();
        Weights::from_slice(&ws)
    }

    /// The paper's two-cluster ratio: the fast cluster gets `ratio`
    /// times the slow cluster's share (§5.2).
    pub fn ratio(ratio: f64) -> Self {
        assert!(ratio > 0.0, "ratio must be positive, got {ratio}");
        Weights::from_slice(&[ratio, 1.0])
    }

    /// Equal shares for `n` clusters.
    pub fn uniform(n: usize) -> Self {
        Weights::from_slice(&vec![1.0; n])
    }

    pub fn as_slice(&self) -> &[f64] {
        &self.w[..self.n]
    }

    pub fn len(&self) -> usize {
        self.n
    }

    pub fn is_empty(&self) -> bool {
        self.n == 0
    }

    /// Normalized copy: the same proportions, summing to 1 — the
    /// *share* form the DVFS retuner reports and the property tests
    /// check (sum ≈ 1, monotone in each way's throughput).
    pub fn normalized(&self) -> Weights {
        let total: f64 = self.as_slice().iter().sum();
        let ws: Vec<f64> = self.as_slice().iter().map(|w| w / total).collect();
        Weights::from_slice(&ws)
    }

    /// Convex blend of two weight vectors in *share* space:
    /// `(1 - alpha)·self + alpha·other`, both normalized first — the
    /// `calibrate::WeightSource::Hybrid` primitive (analytical shares
    /// hedged against measured ones). `alpha = 0` is exactly
    /// `self.normalized()`, `alpha = 1` exactly `other.normalized()`.
    pub fn blend(&self, other: &Weights, alpha: f64) -> Weights {
        assert_eq!(
            self.len(),
            other.len(),
            "blending weight vectors of different arity ({} vs {})",
            self.len(),
            other.len()
        );
        assert!(
            (0.0..=1.0).contains(&alpha),
            "blend factor must be in [0, 1], got {alpha}"
        );
        let a = self.normalized();
        let b = other.normalized();
        let ws: Vec<f64> = a
            .as_slice()
            .iter()
            .zip(b.as_slice())
            .map(|(x, y)| (1.0 - alpha) * x + alpha * y)
            .collect();
        Weights::from_slice(&ws)
    }

    /// Way `i`'s fraction of the total weight.
    pub fn share(&self, i: usize) -> f64 {
        assert!(i < self.n, "way {i} out of range ({} ways)", self.n);
        self.w[i] / self.as_slice().iter().sum::<f64>()
    }

    /// The two-cluster ratio this weight vector encodes, if it does.
    pub fn as_ratio(&self) -> Option<f64> {
        if self.n == 2 && self.w[1] == 1.0 {
            Some(self.w[0])
        } else {
            None
        }
    }
}

/// Which outer loop distributes work *between clusters* (§5.2.1).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum CoarseLoop {
    /// Loop 1 (jc over n): independent `Ac`/`Bc` buffers per cluster.
    Loop1,
    /// Loop 3 (ic over m): shared `Bc` buffer → common `kc` (§5.3).
    Loop3,
}

impl CoarseLoop {
    pub fn shares_bc(self) -> bool {
        matches!(self, CoarseLoop::Loop3)
    }
    pub fn name(self) -> &'static str {
        match self {
            CoarseLoop::Loop1 => "L1",
            CoarseLoop::Loop3 => "L3",
        }
    }
}

/// Which inner loop(s) distribute a macro-kernel *within a cluster*.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum FineLoop {
    /// Loop 4 (jr over nc): ⌈nc/nr⌉-way concurrency — the good choice.
    Loop4,
    /// Loop 5 (ir over mc): only ⌈mc/mr⌉-way — scarcer (§3.1).
    Loop5,
    /// Both (2×2 within a 4-core cluster).
    Both,
}

impl FineLoop {
    pub fn name(self) -> &'static str {
        match self {
            FineLoop::Loop4 => "L4",
            FineLoop::Loop5 => "L5",
            FineLoop::Both => "L4+L5",
        }
    }

    /// (loop4_ways, loop5_ways) for a cluster of `threads` cores.
    pub fn ways(self, threads: usize) -> (usize, usize) {
        match self {
            FineLoop::Loop4 => (threads, 1),
            FineLoop::Loop5 => (1, threads),
            FineLoop::Both => {
                // Factor threads as evenly as possible (4 → 2×2).
                let a = (1..=threads)
                    .filter(|d| threads % d == 0)
                    .min_by_key(|&d| (threads / d).abs_diff(d))
                    .unwrap_or(1);
                (a, threads / a)
            }
        }
    }
}

/// The workload-distribution strategy across the AMP.
#[derive(Debug, Clone, Copy, PartialEq)]
pub enum Strategy {
    /// Only one cluster, `threads` cores, its optimal parameters
    /// (§3.4's isolated-cluster baselines and the Fig. 5 curves).
    ClusterOnly { cluster: ClusterId, threads: usize },
    /// Symmetric-static: every cluster an equal share, single control
    /// tree with the lead cluster's parameters (§4, Fig. 6/7).
    Sss,
    /// Static-asymmetric with per-cluster `weights`, single
    /// (lead-parameter) control tree (§5.2).
    Sas { weights: Weights },
    /// SAS plus per-cluster cache-aware control trees (§5.3).
    CaSas { weights: Weights },
    /// Dynamic distribution, single control tree (§5.4 "DAS").
    Das,
    /// Dynamic distribution, per-cluster control trees (§5.4 "CA-DAS"):
    /// each cluster grabs chunks of its own native `mc`.
    CaDas,
}

impl Strategy {
    pub fn is_dynamic(self) -> bool {
        matches!(self, Strategy::Das | Strategy::CaDas)
    }
    pub fn is_cache_aware(self) -> bool {
        matches!(self, Strategy::CaSas { .. } | Strategy::CaDas)
    }
    pub fn weights(self) -> Option<Weights> {
        match self {
            Strategy::Sas { weights } | Strategy::CaSas { weights } => Some(weights),
            _ => None,
        }
    }
}

/// A complete schedule description.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct ScheduleSpec {
    pub strategy: Strategy,
    pub coarse: CoarseLoop,
    pub fine: FineLoop,
}

impl ScheduleSpec {
    pub fn new(strategy: Strategy, coarse: CoarseLoop, fine: FineLoop) -> Self {
        let s = ScheduleSpec {
            strategy,
            coarse,
            fine,
        };
        s.validate().expect("invalid schedule spec");
        s
    }

    /// The paper's preferred instantiations.
    pub fn sss() -> Self {
        // §4: Loop 1 across clusters + Loop 4 within.
        ScheduleSpec::new(Strategy::Sss, CoarseLoop::Loop1, FineLoop::Loop4)
    }
    /// Two-cluster SAS with the paper's big:LITTLE ratio (§5.2.2:
    /// reported combination Loop 1 + Loop 4).
    pub fn sas(ratio: f64) -> Self {
        ScheduleSpec::sas_weighted(Weights::ratio(ratio))
    }
    /// N-cluster SAS with an explicit weight vector.
    pub fn sas_weighted(weights: Weights) -> Self {
        ScheduleSpec::new(Strategy::Sas { weights }, CoarseLoop::Loop1, FineLoop::Loop4)
    }
    pub fn ca_sas(ratio: f64) -> Self {
        ScheduleSpec::ca_sas_weighted(Weights::ratio(ratio))
    }
    pub fn ca_sas_weighted(weights: Weights) -> Self {
        ScheduleSpec::new(Strategy::CaSas { weights }, CoarseLoop::Loop1, FineLoop::Loop4)
    }
    pub fn ca_das() -> Self {
        // §5.4: dynamic over Loop 3 + fine Loop 4.
        ScheduleSpec::new(Strategy::CaDas, CoarseLoop::Loop3, FineLoop::Loop4)
    }
    pub fn das() -> Self {
        ScheduleSpec::new(Strategy::Das, CoarseLoop::Loop3, FineLoop::Loop4)
    }
    pub fn cluster_only(cluster: ClusterId, threads: usize) -> Self {
        ScheduleSpec::new(
            Strategy::ClusterOnly { cluster, threads },
            CoarseLoop::Loop1,
            FineLoop::Loop4,
        )
    }

    /// §5.4: `nc` (Loop 1's stride) is far too large a quantum for
    /// dynamic distribution — the dynamic strategies must target Loop 3.
    pub fn validate(&self) -> Result<(), String> {
        if self.strategy.is_dynamic() && self.coarse != CoarseLoop::Loop3 {
            return Err("dynamic strategies require the coarse loop to be Loop 3 (§5.4)".into());
        }
        if let Strategy::ClusterOnly { threads, .. } = self.strategy {
            if threads == 0 {
                return Err("ClusterOnly needs at least one thread".into());
            }
        }
        Ok(())
    }

    /// Validate against a concrete topology: weight vectors must name
    /// exactly one weight per cluster, and `ClusterOnly` must address an
    /// existing cluster.
    pub fn validate_for(&self, soc: &SocSpec) -> Result<(), String> {
        self.validate()?;
        if let Some(w) = self.strategy.weights() {
            if w.len() != soc.num_clusters() {
                return Err(format!(
                    "weight vector has {} entries but '{}' has {} clusters",
                    w.len(),
                    soc.name,
                    soc.num_clusters()
                ));
            }
        }
        if let Strategy::ClusterOnly { cluster, .. } = self.strategy {
            if cluster.0 >= soc.num_clusters() {
                return Err(format!(
                    "cluster {cluster} does not exist on '{}' ({} clusters)",
                    soc.name,
                    soc.num_clusters()
                ));
            }
        }
        Ok(())
    }

    /// Threads used on each cluster, indexed by [`ClusterId`].
    pub fn threads(&self, soc: &SocSpec) -> Vec<usize> {
        match self.strategy {
            Strategy::ClusterOnly { cluster, threads } => soc
                .cluster_ids()
                .map(|c| {
                    if c == cluster {
                        threads.min(soc[c].num_cores)
                    } else {
                        0
                    }
                })
                .collect(),
            _ => soc.clusters.iter().map(|c| c.num_cores).collect(),
        }
    }

    /// The per-cluster control trees this schedule runs with.
    pub fn tree_set(&self, soc: &SocSpec) -> TreeSet {
        self.validate_for(soc).expect("invalid schedule spec for topology");
        let th = self.threads(soc);
        let n_cl = soc.num_clusters();
        let par = |threads: usize, coarse_ways: usize| {
            let (w4, w5) = self.fine.ways(threads.max(1));
            Parallelism {
                loop1_ways: if self.coarse == CoarseLoop::Loop1 { coarse_ways } else { 1 },
                loop3_ways: if self.coarse == CoarseLoop::Loop3 { coarse_ways } else { 1 },
                loop4_ways: w4,
                loop5_ways: w5,
            }
        };
        // Parallelism is always derived from each cluster's OWN thread
        // count — replicating the lead cluster's fine-grain ways onto a
        // differently-sized cluster would hand surplus threads duplicate
        // (jr, ir) assignments. Only the *blocking parameters* are
        // lead-replicated for the oblivious strategies.
        match self.strategy {
            Strategy::ClusterOnly { cluster, .. } => {
                let params = soc[cluster].tuned;
                let trees = soc
                    .cluster_ids()
                    .map(|c| ControlTree::gemm(params, par(th[c.0].max(1), 1)))
                    .collect();
                TreeSet::from_trees(trees, false)
            }
            // Architecture-oblivious configurations run the lead
            // cluster's optimal parameters everywhere (§4: "cache
            // configuration parameters are set to those that are optimal
            // for the Cortex-A15"), including plain SAS and DAS.
            Strategy::Sss | Strategy::Sas { .. } | Strategy::Das => {
                let params = soc[soc.lead()].tuned;
                let trees = soc
                    .cluster_ids()
                    .map(|c| ControlTree::gemm(params, par(th[c.0].max(1), n_cl)))
                    .collect();
                TreeSet::from_trees(trees, self.coarse.shares_bc())
            }
            // Cache-aware configurations build one tree per cluster from
            // that cluster's own tuned parameters; under a shared Bc
            // (coarse Loop 3) every cluster refits to the lead kc AND
            // the lead nc — the Bc buffer is kc×nc, so the joint
            // (jc, pc) walk needs both strides common.
            Strategy::CaSas { .. } | Strategy::CaDas => {
                let shared = self.coarse.shares_bc();
                let lead = soc[soc.lead()].tuned;
                let trees: Vec<ControlTree> = soc
                    .cluster_ids()
                    .map(|c| {
                        let params = if shared {
                            let p = soc[c].params_shared_kc(lead.kc);
                            BlisParams::new(lead.nc, p.kc, p.mc, p.nr, p.mr)
                        } else {
                            soc[c].tuned
                        };
                        ControlTree::gemm(params, par(th[c.0], n_cl))
                    })
                    .collect();
                TreeSet::from_trees(trees, shared)
            }
        }
    }

    /// Static coarse-split weights, one per cluster; `None` for dynamic
    /// strategies and isolated clusters.
    pub fn coarse_weights(&self, soc: &SocSpec) -> Option<Vec<f64>> {
        match self.strategy {
            Strategy::Sss => Some(vec![1.0; soc.num_clusters()]),
            Strategy::Sas { weights } | Strategy::CaSas { weights } => {
                assert_eq!(
                    weights.len(),
                    soc.num_clusters(),
                    "weight vector does not match the topology"
                );
                Some(weights.as_slice().to_vec())
            }
            Strategy::Das | Strategy::CaDas | Strategy::ClusterOnly { .. } => None,
        }
    }

    /// Human-readable label used in figures and CLI output. Needs no
    /// topology: two-cluster ratios print as the paper's `SAS(r=N)`,
    /// general weight vectors as `SAS[w0:w1:…]`.
    pub fn label(&self) -> String {
        let fmt_w = |w: &Weights| -> String {
            match w.as_ratio() {
                Some(r) => format!("(r={r:.0})"),
                None => format!(
                    "[{}]",
                    w.as_slice()
                        .iter()
                        .map(|x| format!("{x:.1}"))
                        .collect::<Vec<_>>()
                        .join(":")
                ),
            }
        };
        let base = match &self.strategy {
            Strategy::ClusterOnly { cluster, threads } => {
                return format!("{}x{}", threads, cluster);
            }
            Strategy::Sss => "SSS".to_string(),
            Strategy::Sas { weights } => format!("SAS{}", fmt_w(weights)),
            Strategy::CaSas { weights } => format!("CA-SAS{}", fmt_w(weights)),
            Strategy::Das => "DAS".to_string(),
            Strategy::CaDas => "CA-DAS".to_string(),
        };
        format!("{base} {}+{}", self.coarse.name(), self.fine.name())
    }

    /// Label with the cluster's microarchitecture name resolved (the
    /// figure-friendly variant of [`ScheduleSpec::label`]).
    pub fn label_on(&self, soc: &SocSpec) -> String {
        if let Strategy::ClusterOnly { cluster, threads } = self.strategy {
            format!("{}x{}", threads, soc[cluster].name)
        } else {
            self.label()
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::soc::{BIG, LITTLE};

    fn soc() -> SocSpec {
        SocSpec::exynos5422()
    }

    #[test]
    fn paper_default_specs_validate() {
        for s in [
            ScheduleSpec::sss(),
            ScheduleSpec::sas(5.0),
            ScheduleSpec::ca_sas(3.0),
            ScheduleSpec::das(),
            ScheduleSpec::ca_das(),
            ScheduleSpec::cluster_only(BIG, 4),
        ] {
            s.validate().unwrap();
            s.validate_for(&soc()).unwrap();
        }
    }

    #[test]
    #[should_panic(expected = "invalid schedule spec")]
    fn dynamic_on_loop1_rejected() {
        // §5.4: Loop 1's nc quantum is too coarse for dynamic scheduling.
        ScheduleSpec::new(Strategy::CaDas, CoarseLoop::Loop1, FineLoop::Loop4);
    }

    #[test]
    fn sss_uses_single_lead_tree() {
        let ts = ScheduleSpec::sss().tree_set(&soc());
        assert!(!ts.is_cache_aware());
        assert_eq!(ts.for_cluster(BIG).params, BlisParams::a15_opt());
        assert_eq!(ts.for_cluster(LITTLE).params, BlisParams::a15_opt());
        // 2-way Loop 1 × 4-way Loop 4 = the paper's 8-way layout (Fig. 6).
        assert_eq!(ts.for_cluster(BIG).par.loop1_ways, 2);
        assert_eq!(ts.for_cluster(BIG).par.loop4_ways, 4);
    }

    #[test]
    fn ca_sas_loop1_uses_independent_optima() {
        let ts = ScheduleSpec::ca_sas(5.0).tree_set(&soc());
        assert!(ts.is_cache_aware());
        assert_eq!(ts.for_cluster(LITTLE).params, BlisParams::a7_opt());
    }

    #[test]
    fn ca_strategies_on_loop3_share_kc() {
        let spec = ScheduleSpec::new(
            Strategy::CaSas { weights: Weights::ratio(5.0) },
            CoarseLoop::Loop3,
            FineLoop::Loop4,
        );
        let ts = spec.tree_set(&soc());
        assert_eq!(ts.for_cluster(LITTLE).params, BlisParams::a7_shared_kc());
        let dyn_ts = ScheduleSpec::ca_das().tree_set(&soc());
        assert_eq!(dyn_ts.for_cluster(LITTLE).params, BlisParams::a7_shared_kc());
        assert_eq!(
            dyn_ts.for_cluster(BIG).params.kc,
            dyn_ts.for_cluster(LITTLE).params.kc
        );
    }

    #[test]
    fn das_is_oblivious_dynamic() {
        let ts = ScheduleSpec::das().tree_set(&soc());
        assert!(!ts.is_cache_aware());
        assert!(Strategy::Das.is_dynamic());
        assert!(!Strategy::Das.is_cache_aware());
    }

    #[test]
    fn threads_accounting() {
        assert_eq!(ScheduleSpec::sss().threads(&soc()), vec![4, 4]);
        assert_eq!(
            ScheduleSpec::cluster_only(LITTLE, 3).threads(&soc()),
            vec![0, 3]
        );
        assert_eq!(
            ScheduleSpec::cluster_only(BIG, 9).threads(&soc()),
            vec![4, 0],
            "clamped to cluster size"
        );
    }

    #[test]
    fn fine_loop_ways() {
        assert_eq!(FineLoop::Loop4.ways(4), (4, 1));
        assert_eq!(FineLoop::Loop5.ways(4), (1, 4));
        assert_eq!(FineLoop::Both.ways(4), (2, 2));
        assert_eq!(FineLoop::Both.ways(3), (1, 3));
        assert_eq!(FineLoop::Loop4.ways(1), (1, 1));
    }

    #[test]
    fn coarse_weights() {
        let s = soc();
        assert_eq!(ScheduleSpec::sss().coarse_weights(&s), Some(vec![1.0, 1.0]));
        assert_eq!(
            ScheduleSpec::sas(5.0).coarse_weights(&s),
            Some(vec![5.0, 1.0])
        );
        assert_eq!(ScheduleSpec::ca_das().coarse_weights(&s), None);
    }

    #[test]
    fn labels_are_descriptive() {
        assert_eq!(ScheduleSpec::sss().label(), "SSS L1+L4");
        assert_eq!(ScheduleSpec::sas(5.0).label(), "SAS(r=5) L1+L4");
        assert_eq!(ScheduleSpec::ca_das().label(), "CA-DAS L3+L4");
        assert_eq!(ScheduleSpec::cluster_only(BIG, 4).label(), "4xc0");
        assert_eq!(
            ScheduleSpec::cluster_only(BIG, 4).label_on(&soc()),
            "4xCortex-A15"
        );
        // N-way weight vectors print in full.
        let w = ScheduleSpec::sas_weighted(Weights::from_slice(&[4.0, 2.0, 1.0]));
        assert_eq!(w.label(), "SAS[4.0:2.0:1.0] L1+L4");
    }

    #[test]
    fn cluster_only_uses_that_clusters_optimum() {
        let ts = ScheduleSpec::cluster_only(LITTLE, 4).tree_set(&soc());
        assert_eq!(ts.for_cluster(BIG).params, BlisParams::a7_opt());
    }

    #[test]
    #[should_panic]
    fn nonpositive_ratio_rejected() {
        ScheduleSpec::sas(0.0);
    }

    #[test]
    fn weights_helpers() {
        let w = Weights::from_slice(&[3.0, 2.0, 1.0]);
        assert_eq!(w.len(), 3);
        assert_eq!(w.as_slice(), &[3.0, 2.0, 1.0]);
        assert_eq!(w.as_ratio(), None);
        assert_eq!(Weights::ratio(5.0).as_ratio(), Some(5.0));
        assert_eq!(Weights::uniform(4).as_slice(), &[1.0; 4]);
    }

    #[test]
    #[should_panic(expected = "positive weight")]
    fn all_zero_weight_vector_rejected() {
        Weights::from_slice(&[0.0, 0.0]);
    }

    #[test]
    fn normalized_weights_sum_to_one() {
        let w = Weights::from_slice(&[6.0, 3.0, 1.0]).normalized();
        let sum: f64 = w.as_slice().iter().sum();
        assert!((sum - 1.0).abs() < 1e-12, "sum {sum}");
        assert!((w.share(0) - 0.6).abs() < 1e-12);
        assert!((w.share(2) - 0.1).abs() < 1e-12);
        // share() agrees on the raw and the normalized vector.
        let raw = Weights::from_slice(&[6.0, 3.0, 1.0]);
        for i in 0..3 {
            assert!((raw.share(i) - w.as_slice()[i]).abs() < 1e-12);
        }
    }

    #[test]
    fn blend_interpolates_shares() {
        let a = Weights::from_slice(&[8.0, 2.0]); // shares 0.8 / 0.2
        let b = Weights::from_slice(&[1.0, 1.0]); // shares 0.5 / 0.5
        let mid = a.blend(&b, 0.5);
        assert!((mid.share(0) - 0.65).abs() < 1e-12, "{}", mid.share(0));
        // Endpoints are the normalized inputs exactly.
        assert_eq!(a.blend(&b, 0.0), a.normalized());
        assert_eq!(a.blend(&b, 1.0), b.normalized());
        // Blending identical vectors is the identity.
        assert_eq!(a.blend(&a, 0.5), a.normalized());
    }

    #[test]
    #[should_panic(expected = "different arity")]
    fn blend_rejects_mismatched_arity() {
        Weights::from_slice(&[1.0, 2.0]).blend(&Weights::uniform(3), 0.5);
    }

    #[test]
    fn weights_from_weighted_things() {
        struct Way(f64);
        impl Weighted for Way {
            fn weight(&self) -> f64 {
                self.0
            }
        }
        let w = Weights::from_weighted(&[Way(6.0), Way(3.0), Way(1.0)]);
        assert_eq!(w.as_slice(), &[6.0, 3.0, 1.0]);
    }

    #[test]
    fn tri_cluster_tree_set_has_three_distinct_trees() {
        let tri = SocSpec::dynamiq_3c();
        let spec = ScheduleSpec::ca_sas_weighted(Weights::from_slice(&[6.0, 3.0, 1.0]));
        let ts = spec.tree_set(&tri);
        assert_eq!(ts.num_clusters(), 3);
        assert!(ts.is_cache_aware());
        for c in tri.cluster_ids() {
            assert_eq!(ts.for_cluster(c).params, tri[c].tuned);
        }
        // Shared-Bc dynamic: all three refit to the lead kc.
        let dyn_ts = ScheduleSpec::ca_das().tree_set(&tri);
        let kc = tri[tri.lead()].tuned.kc;
        for c in tri.cluster_ids() {
            assert_eq!(dyn_ts.for_cluster(c).params.kc, kc);
        }
    }

    #[test]
    fn symmetric_topology_degenerates() {
        let smp = SocSpec::symmetric(4);
        for spec in [
            ScheduleSpec::sss(),
            ScheduleSpec::sas_weighted(Weights::uniform(1)),
            ScheduleSpec::das(),
            ScheduleSpec::ca_das(),
        ] {
            spec.validate_for(&smp).unwrap();
            let ts = spec.tree_set(&smp);
            assert_eq!(ts.num_clusters(), 1);
            assert!(!ts.is_cache_aware());
        }
    }

    #[test]
    fn mismatched_weight_vector_rejected_per_topology() {
        let tri = SocSpec::dynamiq_3c();
        // A two-cluster ratio cannot schedule a tri-cluster SoC.
        assert!(ScheduleSpec::sas(5.0).validate_for(&tri).is_err());
        assert!(ScheduleSpec::cluster_only(ClusterId(7), 2)
            .validate_for(&tri)
            .is_err());
    }
}
