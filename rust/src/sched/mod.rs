//! Scheduling strategies (paper §4–§5).
//!
//! A [`ScheduleSpec`] describes one complete configuration of the
//! multi-threaded GEMM:
//!
//! * the **strategy** — who gets how much work and with which control
//!   tree(s): isolated clusters (§3.4), symmetric-static SSS (§4),
//!   static-asymmetric SAS (§5.2), cache-aware CA-SAS (§5.3), dynamic
//!   DAS / CA-DAS (§5.4);
//! * the **coarse-grain loop** distributing micro-kernels between the
//!   two clusters (Loop 1 or Loop 3, §5.2.1);
//! * the **fine-grain loop** distributing a macro-kernel among the cores
//!   of one cluster (Loop 4, Loop 5 or both, §5.2.1).
//!
//! Both the DES simulator (`crate::sim`) and the real-thread executor
//! (`crate::native`) consume the same spec, so the shapes measured in
//! the figures and the numerics verified in tests come from one
//! description of the schedule.

use crate::blis::control_tree::{Parallelism, TreeSet};
use crate::blis::params::BlisParams;
use crate::soc::{CoreType, SocSpec};

/// Which outer loop distributes work *between clusters* (§5.2.1).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum CoarseLoop {
    /// Loop 1 (jc over n): independent `Ac`/`Bc` buffers per cluster.
    Loop1,
    /// Loop 3 (ic over m): shared `Bc` buffer → common `kc` (§5.3).
    Loop3,
}

impl CoarseLoop {
    pub fn shares_bc(self) -> bool {
        matches!(self, CoarseLoop::Loop3)
    }
    pub fn name(self) -> &'static str {
        match self {
            CoarseLoop::Loop1 => "L1",
            CoarseLoop::Loop3 => "L3",
        }
    }
}

/// Which inner loop(s) distribute a macro-kernel *within a cluster*.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum FineLoop {
    /// Loop 4 (jr over nc): ⌈nc/nr⌉-way concurrency — the good choice.
    Loop4,
    /// Loop 5 (ir over mc): only ⌈mc/mr⌉-way — scarcer (§3.1).
    Loop5,
    /// Both (2×2 within a 4-core cluster).
    Both,
}

impl FineLoop {
    pub fn name(self) -> &'static str {
        match self {
            FineLoop::Loop4 => "L4",
            FineLoop::Loop5 => "L5",
            FineLoop::Both => "L4+L5",
        }
    }

    /// (loop4_ways, loop5_ways) for a cluster of `threads` cores.
    pub fn ways(self, threads: usize) -> (usize, usize) {
        match self {
            FineLoop::Loop4 => (threads, 1),
            FineLoop::Loop5 => (1, threads),
            FineLoop::Both => {
                // Factor threads as evenly as possible (4 → 2×2).
                let a = (1..=threads)
                    .filter(|d| threads % d == 0)
                    .min_by_key(|&d| (threads / d).abs_diff(d))
                    .unwrap_or(1);
                (a, threads / a)
            }
        }
    }
}

/// The workload-distribution strategy across the AMP.
#[derive(Debug, Clone, Copy, PartialEq)]
pub enum Strategy {
    /// Only one cluster, `threads` cores, its optimal parameters
    /// (§3.4's isolated-cluster baselines and the Fig. 5 curves).
    ClusterOnly { core: CoreType, threads: usize },
    /// Symmetric-static: both clusters, equal shares, single control
    /// tree with the big cluster's parameters (§4, Fig. 6/7).
    Sss,
    /// Static-asymmetric with a performance `ratio` (big gets `ratio`×
    /// the LITTLE share), single (big-parameter) control tree (§5.2).
    Sas { ratio: f64 },
    /// SAS plus duplicated cache-aware control trees (§5.3).
    CaSas { ratio: f64 },
    /// Dynamic distribution, single control tree (§5.4 "DAS").
    Das,
    /// Dynamic distribution, duplicated control trees (§5.4 "CA-DAS").
    CaDas,
}

impl Strategy {
    pub fn is_dynamic(self) -> bool {
        matches!(self, Strategy::Das | Strategy::CaDas)
    }
    pub fn is_cache_aware(self) -> bool {
        matches!(self, Strategy::CaSas { .. } | Strategy::CaDas)
    }
    pub fn ratio(self) -> Option<f64> {
        match self {
            Strategy::Sas { ratio } | Strategy::CaSas { ratio } => Some(ratio),
            _ => None,
        }
    }
}

/// A complete schedule description.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct ScheduleSpec {
    pub strategy: Strategy,
    pub coarse: CoarseLoop,
    pub fine: FineLoop,
}

impl ScheduleSpec {
    pub fn new(strategy: Strategy, coarse: CoarseLoop, fine: FineLoop) -> Self {
        let s = ScheduleSpec {
            strategy,
            coarse,
            fine,
        };
        s.validate().expect("invalid schedule spec");
        s
    }

    /// The paper's preferred instantiations.
    pub fn sss() -> Self {
        // §4: Loop 1 across clusters + Loop 4 within.
        ScheduleSpec::new(Strategy::Sss, CoarseLoop::Loop1, FineLoop::Loop4)
    }
    pub fn sas(ratio: f64) -> Self {
        // §5.2.2: reported combination Loop 1 + Loop 4.
        ScheduleSpec::new(Strategy::Sas { ratio }, CoarseLoop::Loop1, FineLoop::Loop4)
    }
    pub fn ca_sas(ratio: f64) -> Self {
        ScheduleSpec::new(Strategy::CaSas { ratio }, CoarseLoop::Loop1, FineLoop::Loop4)
    }
    pub fn ca_das() -> Self {
        // §5.4: dynamic over Loop 3 + fine Loop 4.
        ScheduleSpec::new(Strategy::CaDas, CoarseLoop::Loop3, FineLoop::Loop4)
    }
    pub fn das() -> Self {
        ScheduleSpec::new(Strategy::Das, CoarseLoop::Loop3, FineLoop::Loop4)
    }
    pub fn cluster_only(core: CoreType, threads: usize) -> Self {
        ScheduleSpec::new(
            Strategy::ClusterOnly { core, threads },
            CoarseLoop::Loop1,
            FineLoop::Loop4,
        )
    }

    /// §5.4: `nc` (Loop 1's stride) is far too large a quantum for
    /// dynamic distribution — the dynamic strategies must target Loop 3.
    pub fn validate(&self) -> Result<(), String> {
        if self.strategy.is_dynamic() && self.coarse != CoarseLoop::Loop3 {
            return Err("dynamic strategies require the coarse loop to be Loop 3 (§5.4)".into());
        }
        if let Strategy::ClusterOnly { threads, .. } = self.strategy {
            if threads == 0 {
                return Err("ClusterOnly needs at least one thread".into());
            }
        }
        if let Some(r) = self.strategy.ratio() {
            if !(r > 0.0) {
                return Err(format!("ratio must be positive, got {r}"));
            }
        }
        Ok(())
    }

    /// Threads used on each cluster `(big, little)`.
    pub fn threads(&self, soc: &SocSpec) -> (usize, usize) {
        match self.strategy {
            Strategy::ClusterOnly { core, threads } => match core {
                CoreType::Big => (threads.min(soc.big.num_cores), 0),
                CoreType::Little => (0, threads.min(soc.little.num_cores)),
            },
            _ => (soc.big.num_cores, soc.little.num_cores),
        }
    }

    /// The control tree pair this schedule runs with.
    pub fn tree_set(&self, soc: &SocSpec) -> TreeSet {
        let (tb, tl) = self.threads(soc);
        let par = |threads: usize, coarse_ways: usize| {
            let (w4, w5) = self.fine.ways(threads.max(1));
            Parallelism {
                loop1_ways: if self.coarse == CoarseLoop::Loop1 { coarse_ways } else { 1 },
                loop3_ways: if self.coarse == CoarseLoop::Loop3 { coarse_ways } else { 1 },
                loop4_ways: w4,
                loop5_ways: w5,
            }
        };
        match self.strategy {
            Strategy::ClusterOnly { core, .. } => {
                let params = BlisParams::optimal_for(core);
                TreeSet::single(params, par(tb.max(tl), 1))
            }
            // Architecture-oblivious configurations run the big cluster's
            // optimal parameters everywhere (§4: "cache configuration
            // parameters are set to those that are optimal for the
            // Cortex-A15"), including plain SAS and DAS.
            Strategy::Sss | Strategy::Sas { .. } | Strategy::Das => {
                TreeSet::single(BlisParams::a15_opt(), par(tb, 2))
            }
            Strategy::CaSas { .. } | Strategy::CaDas => TreeSet::cache_aware(
                par(tb, 2),
                par(tl, 2),
                self.coarse.shares_bc(),
            ),
        }
    }

    /// Static coarse-split weights `(big, little)`; `None` for dynamic
    /// strategies and isolated clusters.
    pub fn coarse_weights(&self) -> Option<(f64, f64)> {
        match self.strategy {
            Strategy::Sss => Some((1.0, 1.0)),
            Strategy::Sas { ratio } | Strategy::CaSas { ratio } => Some((ratio, 1.0)),
            Strategy::Das | Strategy::CaDas | Strategy::ClusterOnly { .. } => None,
        }
    }

    /// Human-readable label used in figures and CLI output.
    pub fn label(&self) -> String {
        let base = match self.strategy {
            Strategy::ClusterOnly { core, threads } => {
                return format!("{}x{}", threads, core.name());
            }
            Strategy::Sss => "SSS".to_string(),
            Strategy::Sas { ratio } => format!("SAS(r={ratio:.0})"),
            Strategy::CaSas { ratio } => format!("CA-SAS(r={ratio:.0})"),
            Strategy::Das => "DAS".to_string(),
            Strategy::CaDas => "CA-DAS".to_string(),
        };
        format!("{base} {}+{}", self.coarse.name(), self.fine.name())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn soc() -> SocSpec {
        SocSpec::exynos5422()
    }

    #[test]
    fn paper_default_specs_validate() {
        for s in [
            ScheduleSpec::sss(),
            ScheduleSpec::sas(5.0),
            ScheduleSpec::ca_sas(3.0),
            ScheduleSpec::das(),
            ScheduleSpec::ca_das(),
            ScheduleSpec::cluster_only(CoreType::Big, 4),
        ] {
            s.validate().unwrap();
        }
    }

    #[test]
    #[should_panic(expected = "invalid schedule spec")]
    fn dynamic_on_loop1_rejected() {
        // §5.4: Loop 1's nc quantum is too coarse for dynamic scheduling.
        ScheduleSpec::new(Strategy::CaDas, CoarseLoop::Loop1, FineLoop::Loop4);
    }

    #[test]
    fn sss_uses_single_a15_tree() {
        let ts = ScheduleSpec::sss().tree_set(&soc());
        assert!(!ts.is_cache_aware());
        assert_eq!(ts.big.params, BlisParams::a15_opt());
        assert_eq!(ts.little.params, BlisParams::a15_opt());
        // 2-way Loop 1 × 4-way Loop 4 = the paper's 8-way layout (Fig. 6).
        assert_eq!(ts.big.par.loop1_ways, 2);
        assert_eq!(ts.big.par.loop4_ways, 4);
    }

    #[test]
    fn ca_sas_loop1_uses_independent_optima() {
        let ts = ScheduleSpec::ca_sas(5.0).tree_set(&soc());
        assert!(ts.is_cache_aware());
        assert_eq!(ts.little.params, BlisParams::a7_opt());
    }

    #[test]
    fn ca_strategies_on_loop3_share_kc() {
        let spec = ScheduleSpec::new(Strategy::CaSas { ratio: 5.0 }, CoarseLoop::Loop3, FineLoop::Loop4);
        let ts = spec.tree_set(&soc());
        assert_eq!(ts.little.params, BlisParams::a7_shared_kc());
        let dyn_ts = ScheduleSpec::ca_das().tree_set(&soc());
        assert_eq!(dyn_ts.little.params, BlisParams::a7_shared_kc());
        assert_eq!(dyn_ts.big.params.kc, dyn_ts.little.params.kc);
    }

    #[test]
    fn das_is_oblivious_dynamic() {
        let ts = ScheduleSpec::das().tree_set(&soc());
        assert!(!ts.is_cache_aware());
        assert!(Strategy::Das.is_dynamic());
        assert!(!Strategy::Das.is_cache_aware());
    }

    #[test]
    fn threads_accounting() {
        assert_eq!(ScheduleSpec::sss().threads(&soc()), (4, 4));
        assert_eq!(
            ScheduleSpec::cluster_only(CoreType::Little, 3).threads(&soc()),
            (0, 3)
        );
        assert_eq!(
            ScheduleSpec::cluster_only(CoreType::Big, 9).threads(&soc()),
            (4, 0),
            "clamped to cluster size"
        );
    }

    #[test]
    fn fine_loop_ways() {
        assert_eq!(FineLoop::Loop4.ways(4), (4, 1));
        assert_eq!(FineLoop::Loop5.ways(4), (1, 4));
        assert_eq!(FineLoop::Both.ways(4), (2, 2));
        assert_eq!(FineLoop::Both.ways(3), (1, 3));
        assert_eq!(FineLoop::Loop4.ways(1), (1, 1));
    }

    #[test]
    fn coarse_weights() {
        assert_eq!(ScheduleSpec::sss().coarse_weights(), Some((1.0, 1.0)));
        assert_eq!(ScheduleSpec::sas(5.0).coarse_weights(), Some((5.0, 1.0)));
        assert_eq!(ScheduleSpec::ca_das().coarse_weights(), None);
    }

    #[test]
    fn labels_are_descriptive() {
        assert_eq!(ScheduleSpec::sss().label(), "SSS L1+L4");
        assert_eq!(ScheduleSpec::sas(5.0).label(), "SAS(r=5) L1+L4");
        assert_eq!(ScheduleSpec::ca_das().label(), "CA-DAS L3+L4");
        assert_eq!(
            ScheduleSpec::cluster_only(CoreType::Big, 4).label(),
            "4xCortex-A15"
        );
    }

    #[test]
    fn cluster_only_uses_that_clusters_optimum() {
        let ts = ScheduleSpec::cluster_only(CoreType::Little, 4).tree_set(&soc());
        assert_eq!(ts.big.params, BlisParams::a7_opt());
    }

    #[test]
    #[should_panic]
    fn nonpositive_ratio_rejected() {
        ScheduleSpec::sas(0.0);
    }
}
