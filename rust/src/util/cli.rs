//! Tiny hand-rolled CLI argument parser (`clap` is not in the offline
//! crate set). Supports `--flag`, `--key value`, `--key=value` and
//! positional arguments, with typed accessors and error messages that
//! name the offending flag.

use std::collections::BTreeMap;

/// Parsed command line: positionals in order plus a key→value map
/// (bare flags map to `"true"`).
#[derive(Debug, Clone, Default)]
pub struct Args {
    pub positional: Vec<String>,
    pub options: BTreeMap<String, String>,
}

impl Args {
    /// Parse from an iterator of raw arguments (exclusive of argv[0]).
    pub fn parse<I: IntoIterator<Item = String>>(raw: I) -> Result<Args, String> {
        let mut args = Args::default();
        let mut it = raw.into_iter().peekable();
        while let Some(tok) = it.next() {
            if let Some(stripped) = tok.strip_prefix("--") {
                if stripped.is_empty() {
                    // `--` terminator: rest is positional.
                    args.positional.extend(it);
                    break;
                }
                if let Some((k, v)) = stripped.split_once('=') {
                    args.options.insert(k.to_string(), v.to_string());
                } else {
                    // `--key value` unless the next token is another flag.
                    let takes_value = it
                        .peek()
                        .map(|n| !n.starts_with("--"))
                        .unwrap_or(false);
                    if takes_value {
                        args.options
                            .insert(stripped.to_string(), it.next().unwrap());
                    } else {
                        args.options.insert(stripped.to_string(), "true".to_string());
                    }
                }
            } else {
                args.positional.push(tok);
            }
        }
        Ok(args)
    }

    /// Parse from the process environment, skipping argv[0].
    pub fn from_env() -> Result<Args, String> {
        Args::parse(std::env::args().skip(1))
    }

    pub fn get(&self, key: &str) -> Option<&str> {
        self.options.get(key).map(|s| s.as_str())
    }

    pub fn flag(&self, key: &str) -> bool {
        matches!(self.get(key), Some("true") | Some("1") | Some("yes"))
    }

    pub fn get_or<'a>(&'a self, key: &str, default: &'a str) -> &'a str {
        self.get(key).unwrap_or(default)
    }

    pub fn usize_or(&self, key: &str, default: usize) -> Result<usize, String> {
        match self.get(key) {
            None => Ok(default),
            Some(v) => v
                .parse()
                .map_err(|_| format!("--{key}: expected an integer, got '{v}'")),
        }
    }

    pub fn f64_or(&self, key: &str, default: f64) -> Result<f64, String> {
        match self.get(key) {
            None => Ok(default),
            Some(v) => v
                .parse()
                .map_err(|_| format!("--{key}: expected a number, got '{v}'")),
        }
    }

    /// Comma-separated list of usize, e.g. `--sizes 256,512,1024`.
    pub fn usize_list(&self, key: &str) -> Result<Option<Vec<usize>>, String> {
        match self.get(key) {
            None => Ok(None),
            Some(v) => v
                .split(',')
                .map(|s| {
                    s.trim()
                        .parse()
                        .map_err(|_| format!("--{key}: bad list element '{s}'"))
                })
                .collect::<Result<Vec<_>, _>>()
                .map(Some),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn parse(toks: &[&str]) -> Args {
        Args::parse(toks.iter().map(|s| s.to_string())).unwrap()
    }

    #[test]
    fn positional_and_flags() {
        let a = parse(&["figures", "--fig", "4", "--verbose"]);
        assert_eq!(a.positional, vec!["figures"]);
        assert_eq!(a.get("fig"), Some("4"));
        assert!(a.flag("verbose"));
    }

    #[test]
    fn equals_syntax() {
        let a = parse(&["--out=results", "--n=5"]);
        assert_eq!(a.get("out"), Some("results"));
        assert_eq!(a.usize_or("n", 0).unwrap(), 5);
    }

    #[test]
    fn flag_followed_by_flag_is_bare() {
        let a = parse(&["--quick", "--fig", "5"]);
        assert!(a.flag("quick"));
        assert_eq!(a.get("fig"), Some("5"));
    }

    #[test]
    fn double_dash_terminates_options() {
        let a = parse(&["--a", "1", "--", "--not-a-flag"]);
        assert_eq!(a.positional, vec!["--not-a-flag"]);
    }

    #[test]
    fn typed_errors_name_the_flag() {
        let a = parse(&["--n", "abc"]);
        let err = a.usize_or("n", 0).unwrap_err();
        assert!(err.contains("--n"), "{err}");
    }

    #[test]
    fn usize_list_parses() {
        let a = parse(&["--sizes", "256,512, 1024"]);
        assert_eq!(a.usize_list("sizes").unwrap().unwrap(), vec![256, 512, 1024]);
        assert_eq!(a.usize_list("missing").unwrap(), None);
    }

    #[test]
    fn defaults_apply() {
        let a = parse(&[]);
        assert_eq!(a.get_or("mode", "sim"), "sim");
        assert_eq!(a.f64_or("ratio", 5.0).unwrap(), 5.0);
    }
}
