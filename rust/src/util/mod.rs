//! Shared utilities: deterministic RNG, statistics, table emission,
//! a mini property-testing harness, a bench measurement kit and a tiny
//! CLI parser. These stand in for `rand`/`proptest`/`criterion`/`clap`,
//! which are unavailable in the offline crate set (see DESIGN.md §2).

pub mod benchkit;
pub mod cli;
pub mod prop;
pub mod rng;
pub mod stats;
pub mod table;

/// Round `x` up to the next multiple of `m` (m > 0).
pub fn round_up(x: usize, m: usize) -> usize {
    assert!(m > 0);
    x.div_ceil(m) * m
}

/// Round `x` down to a multiple of `m`, but never below `m` when x > 0.
/// Used when partitioning loop ranges so every non-empty chunk is a
/// whole number of register-block strides.
pub fn round_to_stride_floor(x: usize, m: usize) -> usize {
    assert!(m > 0);
    if x == 0 {
        0
    } else {
        ((x / m).max(1)) * m
    }
}

/// Ceiling division.
pub fn ceil_div(a: usize, b: usize) -> usize {
    assert!(b > 0);
    a.div_ceil(b)
}

/// Relative difference |a-b| / max(|a|,|b|,eps) — convergence checks.
pub fn rel_diff(a: f64, b: f64) -> f64 {
    let denom = a.abs().max(b.abs()).max(1e-300);
    (a - b).abs() / denom
}

/// Parse a strictly positive, finite f64 — the shared validator for
/// persisted physical quantities (GFLOPS rates, frequencies) in the
/// calibration TSV formats (`search::OppPresetStore`,
/// `calibrate::RateTable`): one rule, so the two parsers can never
/// drift apart on what a corrupt row looks like.
pub fn parse_positive_f64(s: &str, what: &str) -> Result<f64, String> {
    let v: f64 = s.parse().map_err(|_| format!("bad {what} '{s}'"))?;
    if !v.is_finite() || v <= 0.0 {
        return Err(format!("{what} must be positive and finite, got '{s}'"));
    }
    Ok(v)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn round_up_basics() {
        assert_eq!(round_up(0, 4), 0);
        assert_eq!(round_up(1, 4), 4);
        assert_eq!(round_up(4, 4), 4);
        assert_eq!(round_up(5, 4), 8);
    }

    #[test]
    fn stride_floor_never_zero_for_positive_input() {
        assert_eq!(round_to_stride_floor(3, 4), 4);
        assert_eq!(round_to_stride_floor(9, 4), 8);
        assert_eq!(round_to_stride_floor(0, 4), 0);
    }

    #[test]
    fn ceil_div_basics() {
        assert_eq!(ceil_div(0, 3), 0);
        assert_eq!(ceil_div(1, 3), 1);
        assert_eq!(ceil_div(6, 3), 2);
        assert_eq!(ceil_div(7, 3), 3);
    }

    #[test]
    fn rel_diff_symmetric_and_zero_safe() {
        assert_eq!(rel_diff(1.0, 1.0), 0.0);
        assert!((rel_diff(1.0, 2.0) - 0.5).abs() < 1e-12);
        assert_eq!(rel_diff(0.0, 0.0), 0.0);
    }

    #[test]
    fn parse_positive_f64_contract() {
        assert_eq!(parse_positive_f64("2.25", "rate").unwrap(), 2.25);
        for bad in ["x", "", "0", "-1", "NaN", "inf", "-inf"] {
            assert!(parse_positive_f64(bad, "rate").is_err(), "accepted {bad:?}");
        }
        let err = parse_positive_f64("0", "freq").unwrap_err();
        assert!(err.contains("freq"), "{err}");
    }
}
