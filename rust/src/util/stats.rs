//! Small statistics helpers shared by the bench harness, the figure
//! generators and the energy meter.

/// Summary statistics over a sample of f64 observations.
#[derive(Debug, Clone, PartialEq)]
pub struct Summary {
    pub n: usize,
    pub mean: f64,
    pub std: f64,
    pub min: f64,
    pub max: f64,
    pub median: f64,
}

impl Summary {
    /// Compute a summary; returns `None` for an empty sample.
    pub fn of(xs: &[f64]) -> Option<Summary> {
        if xs.is_empty() {
            return None;
        }
        let n = xs.len();
        let mean = xs.iter().sum::<f64>() / n as f64;
        let var = if n > 1 {
            xs.iter().map(|x| (x - mean) * (x - mean)).sum::<f64>() / (n - 1) as f64
        } else {
            0.0
        };
        let mut sorted = xs.to_vec();
        sorted.sort_by(|a, b| a.total_cmp(b));
        // Quantiles route through the shared observability kernel;
        // see `obs::metrics::quantile_sorted` for the one
        // linear-interpolation definition the whole repo uses.
        let mut hist = crate::obs::metrics::Histogram::with_samples();
        for &x in xs {
            hist.observe(x);
        }
        let median = hist.quantile(50.0);
        Some(Summary {
            n,
            mean,
            std: var.sqrt(),
            min: sorted[0],
            max: sorted[n - 1],
            median,
        })
    }

    /// Relative standard deviation (coefficient of variation).
    pub fn rsd(&self) -> f64 {
        if self.mean == 0.0 {
            0.0
        } else {
            self.std / self.mean.abs()
        }
    }
}

/// Percentile with linear interpolation, `p` in [0, 100]. A thin
/// wrapper over [`crate::obs::metrics::quantile_sorted`] — the single
/// quantile kernel shared with `obs::metrics::Histogram::quantile`,
/// so the fleet tables and these helpers can never drift apart.
pub fn percentile(xs: &[f64], p: f64) -> f64 {
    assert!(!xs.is_empty(), "percentile of empty sample");
    assert!((0.0..=100.0).contains(&p));
    let mut sorted = xs.to_vec();
    sorted.sort_by(|a, b| a.total_cmp(b));
    crate::obs::metrics::quantile_sorted(&sorted, p)
}

/// Geometric mean; all inputs must be positive.
pub fn geomean(xs: &[f64]) -> f64 {
    assert!(!xs.is_empty());
    let log_sum: f64 = xs
        .iter()
        .map(|&x| {
            assert!(x > 0.0, "geomean requires positive values, got {x}");
            x.ln()
        })
        .sum();
    (log_sum / xs.len() as f64).exp()
}

/// GFLOPS for an (m, n, k) GEMM completed in `seconds`.
pub fn gemm_gflops(m: usize, n: usize, k: usize, seconds: f64) -> f64 {
    assert!(seconds > 0.0);
    2.0 * m as f64 * n as f64 * k as f64 / seconds / 1e9
}

/// Total flop count of an (m, n, k) GEMM (`C += A·B`, 2mnk).
pub fn gemm_flops(m: usize, n: usize, k: usize) -> f64 {
    2.0 * m as f64 * n as f64 * k as f64
}

/// Maximum absolute elementwise difference between two equal-length
/// buffers — the correctness metric for GEMM comparisons.
pub fn max_abs_diff(a: &[f64], b: &[f64]) -> f64 {
    assert_eq!(a.len(), b.len());
    a.iter()
        .zip(b)
        .map(|(x, y)| (x - y).abs())
        .fold(0.0, f64::max)
}

/// Relative error tolerance check appropriate for f64 GEMM of order `k`:
/// error grows ~ sqrt(k) * eps * |A||B|.
pub fn gemm_tolerance(k: usize) -> f64 {
    1e-12 * (k as f64).sqrt().max(1.0) * 16.0
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn summary_of_constant_sample() {
        let s = Summary::of(&[2.0, 2.0, 2.0]).unwrap();
        assert_eq!(s.mean, 2.0);
        assert_eq!(s.std, 0.0);
        assert_eq!(s.min, 2.0);
        assert_eq!(s.max, 2.0);
        assert_eq!(s.median, 2.0);
    }

    #[test]
    fn summary_empty_is_none() {
        assert!(Summary::of(&[]).is_none());
    }

    #[test]
    fn summary_known_values() {
        let s = Summary::of(&[1.0, 2.0, 3.0, 4.0]).unwrap();
        assert!((s.mean - 2.5).abs() < 1e-12);
        assert!((s.median - 2.5).abs() < 1e-12);
        assert!((s.std - (5.0f64 / 3.0).sqrt()).abs() < 1e-12);
    }

    #[test]
    fn median_odd_length() {
        let s = Summary::of(&[5.0, 1.0, 3.0]).unwrap();
        assert_eq!(s.median, 3.0);
    }

    #[test]
    fn percentile_endpoints() {
        let xs = [1.0, 2.0, 3.0, 4.0, 5.0];
        assert_eq!(percentile(&xs, 0.0), 1.0);
        assert_eq!(percentile(&xs, 100.0), 5.0);
        assert_eq!(percentile(&xs, 50.0), 3.0);
    }

    #[test]
    fn percentile_interpolates() {
        let xs = [0.0, 10.0];
        assert!((percentile(&xs, 25.0) - 2.5).abs() < 1e-12);
    }

    #[test]
    fn geomean_known() {
        assert!((geomean(&[1.0, 4.0]) - 2.0).abs() < 1e-12);
        assert!((geomean(&[2.0, 2.0, 2.0]) - 2.0).abs() < 1e-12);
    }

    #[test]
    fn gflops_computation() {
        // 2*1000^3 flops in 1s = 2 GFLOPS.
        assert!((gemm_gflops(1000, 1000, 1000, 1.0) - 2.0).abs() < 1e-12);
    }

    #[test]
    fn max_abs_diff_basic() {
        assert_eq!(max_abs_diff(&[1.0, 2.0], &[1.5, 2.0]), 0.5);
        assert_eq!(max_abs_diff(&[], &[]), 0.0);
    }

    #[test]
    fn tolerance_grows_with_k() {
        assert!(gemm_tolerance(4096) > gemm_tolerance(16));
    }
}
