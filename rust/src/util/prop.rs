//! Minimal deterministic property-testing harness.
//!
//! `proptest` is not in the offline crate set, so we provide the subset we
//! need: run a property over many pseudo-random cases drawn from a seeded
//! generator; on failure report the seed and case index so the exact case
//! can be replayed. No shrinking — cases are kept small by construction.

use crate::util::rng::Rng;

/// Configuration for a property run.
#[derive(Debug, Clone)]
pub struct Config {
    pub cases: usize,
    pub seed: u64,
}

impl Default for Config {
    fn default() -> Self {
        // Seed fixed for reproducibility; override per-test when needed.
        Config { cases: 128, seed: 0xA11CE }
    }
}

/// Run `prop` over `cfg.cases` cases. `gen` draws one case from the RNG.
/// `prop` returns `Err(msg)` to fail. Panics with seed + case index on
/// the first failure so CI output pinpoints the repro.
pub fn check<T: std::fmt::Debug>(
    cfg: &Config,
    mut gen: impl FnMut(&mut Rng) -> T,
    mut prop: impl FnMut(&T) -> Result<(), String>,
) {
    let mut rng = Rng::new(cfg.seed);
    for case_idx in 0..cfg.cases {
        let case = gen(&mut rng);
        if let Err(msg) = prop(&case) {
            panic!(
                "property failed (seed={:#x}, case {}/{}): {}\ncase: {:?}",
                cfg.seed, case_idx, cfg.cases, msg, case
            );
        }
    }
}

/// Convenience: run with the default config.
pub fn check_default<T: std::fmt::Debug>(
    gen: impl FnMut(&mut Rng) -> T,
    prop: impl FnMut(&T) -> Result<(), String>,
) {
    check(&Config::default(), gen, prop)
}

/// Assert helper producing `Result<(), String>` for use inside properties.
#[macro_export]
macro_rules! prop_assert {
    ($cond:expr, $($fmt:tt)*) => {
        if !($cond) {
            return Err(format!($($fmt)*));
        }
    };
}

/// Equality variant with automatic message.
#[macro_export]
macro_rules! prop_assert_eq {
    ($a:expr, $b:expr) => {{
        let (a, b) = (&$a, &$b);
        if a != b {
            return Err(format!(
                "{} != {} ({:?} vs {:?})",
                stringify!($a),
                stringify!($b),
                a,
                b
            ));
        }
    }};
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn passing_property_runs_all_cases() {
        let mut count = 0;
        check(
            &Config { cases: 10, seed: 1 },
            |r| r.gen_range(0, 100),
            |_| {
                count += 1;
                Ok(())
            },
        );
        assert_eq!(count, 10);
    }

    #[test]
    #[should_panic(expected = "property failed")]
    fn failing_property_panics_with_seed() {
        check(
            &Config { cases: 10, seed: 2 },
            |r| r.gen_range(0, 100),
            |&x| {
                if x < 1000 {
                    Err("always fails".to_string())
                } else {
                    Ok(())
                }
            },
        );
    }

    #[test]
    fn prop_assert_macros_work() {
        check_default(
            |r| (r.gen_range(1, 10), r.gen_range(1, 10)),
            |&(a, b)| {
                prop_assert!(a + b >= 2, "sum too small: {} + {}", a, b);
                prop_assert_eq!(a + b, b + a);
                Ok(())
            },
        );
    }

    #[test]
    fn cases_are_deterministic_across_runs() {
        let collect = |seed| {
            let mut v = Vec::new();
            check(
                &Config { cases: 16, seed },
                |r| r.gen_range(0, 1_000_000),
                |&x| {
                    v.push(x);
                    Ok(())
                },
            );
            v
        };
        assert_eq!(collect(7), collect(7));
        assert_ne!(collect(7), collect(8));
    }
}
