//! Measurement harness for `benches/` (criterion is not available in the
//! offline crate set). Provides warmup + sampled timing, summary stats,
//! and markdown reporting so `cargo bench` output is self-describing.

use crate::util::stats::Summary;
use std::time::{Duration, Instant};

/// One benchmark measurement.
#[derive(Debug, Clone)]
pub struct BenchResult {
    pub name: String,
    /// Per-sample wall time in seconds (each sample may contain many
    /// inner iterations; times are normalized per iteration).
    pub samples: Vec<f64>,
    /// Optional throughput denominator (e.g. flops per iteration);
    /// reported as (denominator / time) when present.
    pub throughput_units: Option<(f64, &'static str)>,
}

impl BenchResult {
    pub fn summary(&self) -> Summary {
        Summary::of(&self.samples).expect("bench produced no samples")
    }

    /// Render one markdown row: name, mean, σ, min, optional throughput.
    pub fn to_row(&self) -> String {
        let s = self.summary();
        let tput = match self.throughput_units {
            Some((units, label)) => format!(" | {:.3} {}/s", units / s.mean / 1e9 * 1e9, label),
            None => String::new(),
        };
        format!(
            "| {} | {} | {} | {} |{}",
            self.name,
            fmt_time(s.mean),
            fmt_time(s.std),
            fmt_time(s.min),
            tput
        )
    }
}

/// Human-friendly time formatting.
pub fn fmt_time(seconds: f64) -> String {
    if seconds >= 1.0 {
        format!("{seconds:.3} s")
    } else if seconds >= 1e-3 {
        format!("{:.3} ms", seconds * 1e3)
    } else if seconds >= 1e-6 {
        format!("{:.3} µs", seconds * 1e6)
    } else {
        format!("{:.1} ns", seconds * 1e9)
    }
}

/// Benchmark runner with fixed sample/warmup policy.
pub struct Bencher {
    pub warmup: Duration,
    pub samples: usize,
    pub min_sample_time: Duration,
    results: Vec<BenchResult>,
}

impl Default for Bencher {
    fn default() -> Self {
        Bencher {
            warmup: Duration::from_millis(200),
            samples: 12,
            min_sample_time: Duration::from_millis(30),
            results: Vec::new(),
        }
    }
}

impl Bencher {
    pub fn quick() -> Self {
        Bencher {
            warmup: Duration::from_millis(50),
            samples: 5,
            min_sample_time: Duration::from_millis(10),
            results: Vec::new(),
        }
    }

    /// Measure `f`, automatically choosing an inner iteration count so
    /// each sample lasts at least `min_sample_time`. The closure's return
    /// value is black-boxed to prevent dead-code elimination.
    pub fn bench<T>(&mut self, name: &str, mut f: impl FnMut() -> T) -> &BenchResult {
        // Warmup + calibration: find iterations per sample.
        let start = Instant::now();
        let mut iters_done = 0u64;
        while start.elapsed() < self.warmup || iters_done == 0 {
            std::hint::black_box(f());
            iters_done += 1;
        }
        let per_iter = start.elapsed().as_secs_f64() / iters_done as f64;
        let inner = ((self.min_sample_time.as_secs_f64() / per_iter).ceil() as u64).max(1);

        let mut samples = Vec::with_capacity(self.samples);
        for _ in 0..self.samples {
            let t0 = Instant::now();
            for _ in 0..inner {
                std::hint::black_box(f());
            }
            samples.push(t0.elapsed().as_secs_f64() / inner as f64);
        }
        self.results.push(BenchResult {
            name: name.to_string(),
            samples,
            throughput_units: None,
        });
        self.results.last().unwrap()
    }

    /// Like `bench` but reports throughput as `units_per_iter / time`.
    pub fn bench_throughput<T>(
        &mut self,
        name: &str,
        units_per_iter: f64,
        label: &'static str,
        f: impl FnMut() -> T,
    ) -> &BenchResult {
        self.bench(name, f);
        let last = self.results.last_mut().unwrap();
        last.throughput_units = Some((units_per_iter, label));
        self.results.last().unwrap()
    }

    /// Record a result measured externally (e.g. one long run).
    pub fn record(&mut self, name: &str, samples: Vec<f64>) {
        self.results.push(BenchResult {
            name: name.to_string(),
            samples,
            throughput_units: None,
        });
    }

    pub fn results(&self) -> &[BenchResult] {
        &self.results
    }

    /// Print the markdown report for all results gathered so far.
    pub fn report(&self, title: &str) {
        println!("\n## {title}");
        println!("| benchmark | mean | σ | min | throughput");
        println!("|---|---|---|---|---");
        for r in &self.results {
            println!("{}", r.to_row());
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bench_produces_samples() {
        let mut b = Bencher::quick();
        let r = b.bench("noop-ish", || std::hint::black_box(1 + 1));
        assert_eq!(r.samples.len(), 5);
        assert!(r.samples.iter().all(|&s| s > 0.0));
    }

    #[test]
    fn throughput_row_contains_label() {
        let mut b = Bencher::quick();
        b.bench_throughput("t", 1e9, "flop", || std::hint::black_box(2 * 2));
        let row = b.results()[0].to_row();
        assert!(row.contains("flop/s"), "{row}");
    }

    #[test]
    fn fmt_time_scales() {
        assert_eq!(fmt_time(2.0), "2.000 s");
        assert_eq!(fmt_time(2e-3), "2.000 ms");
        assert_eq!(fmt_time(2e-6), "2.000 µs");
        assert_eq!(fmt_time(2e-9), "2.0 ns");
    }

    #[test]
    fn record_external_samples() {
        let mut b = Bencher::quick();
        b.record("ext", vec![1.0, 2.0, 3.0]);
        let s = b.results()[0].summary();
        assert_eq!(s.mean, 2.0);
    }
}
