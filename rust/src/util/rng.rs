//! Deterministic pseudo-random number generation.
//!
//! The offline crate set does not include `rand`, so we carry a small,
//! well-understood xorshift64* generator. Determinism matters more than
//! statistical perfection here: it seeds property tests, synthetic
//! workload generators and jitter models, all of which must be exactly
//! reproducible from a printed seed.

/// xorshift64* PRNG (Vigna 2014). Passes BigCrush on the high 32 bits;
/// more than adequate for test-case generation and workload synthesis.
#[derive(Debug, Clone)]
pub struct Rng {
    state: u64,
}

impl Rng {
    /// Create a generator from a seed. A zero seed is remapped to a
    /// fixed non-zero constant (xorshift has a zero fixed point).
    pub fn new(seed: u64) -> Self {
        Rng {
            state: if seed == 0 { 0x9E37_79B9_7F4A_7C15 } else { seed },
        }
    }

    /// Next raw 64-bit value.
    pub fn next_u64(&mut self) -> u64 {
        let mut x = self.state;
        x ^= x >> 12;
        x ^= x << 25;
        x ^= x >> 27;
        self.state = x;
        x.wrapping_mul(0x2545_F491_4F6C_DD1D)
    }

    /// Uniform in `[0, 1)`.
    pub fn next_f64(&mut self) -> f64 {
        // 53 random mantissa bits.
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Uniform integer in `[lo, hi)`. Panics if `lo >= hi`.
    pub fn gen_range(&mut self, lo: usize, hi: usize) -> usize {
        assert!(lo < hi, "gen_range: empty range [{lo}, {hi})");
        lo + (self.next_u64() as usize) % (hi - lo)
    }

    /// Uniform f64 in `[lo, hi)`.
    pub fn gen_f64(&mut self, lo: f64, hi: f64) -> f64 {
        lo + self.next_f64() * (hi - lo)
    }

    /// Bernoulli draw.
    pub fn gen_bool(&mut self, p: f64) -> bool {
        self.next_f64() < p
    }

    /// Exponential draw with the given rate (mean `1/rate`): the
    /// inter-arrival gap of a Poisson process — the standard generator
    /// for staggered streaming workloads. Always finite and >= 0.
    pub fn gen_exp(&mut self, rate: f64) -> f64 {
        assert!(
            rate.is_finite() && rate > 0.0,
            "exponential rate must be positive, got {rate}"
        );
        // next_f64() < 1, so the argument stays in (0, 1] and the log
        // is finite.
        -(1.0 - self.next_f64()).ln() / rate
    }

    /// Pick a random element of a slice.
    pub fn choose<'a, T>(&mut self, xs: &'a [T]) -> &'a T {
        &xs[self.gen_range(0, xs.len())]
    }

    /// Fisher–Yates shuffle.
    pub fn shuffle<T>(&mut self, xs: &mut [T]) {
        for i in (1..xs.len()).rev() {
            let j = self.gen_range(0, i + 1);
            xs.swap(i, j);
        }
    }

    /// Fill a matrix-sized buffer with values in [-1, 1); the standard
    /// way tests generate GEMM operands.
    pub fn fill_matrix(&mut self, n: usize) -> Vec<f64> {
        (0..n).map(|_| self.gen_f64(-1.0, 1.0)).collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_for_same_seed() {
        let mut a = Rng::new(42);
        let mut b = Rng::new(42);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn different_seeds_diverge() {
        let mut a = Rng::new(1);
        let mut b = Rng::new(2);
        let same = (0..32).filter(|_| a.next_u64() == b.next_u64()).count();
        assert_eq!(same, 0);
    }

    #[test]
    fn zero_seed_is_usable() {
        let mut r = Rng::new(0);
        assert_ne!(r.next_u64(), 0);
    }

    #[test]
    fn f64_in_unit_interval() {
        let mut r = Rng::new(7);
        for _ in 0..10_000 {
            let x = r.next_f64();
            assert!((0.0..1.0).contains(&x));
        }
    }

    #[test]
    fn gen_range_bounds() {
        let mut r = Rng::new(9);
        for _ in 0..10_000 {
            let x = r.gen_range(3, 17);
            assert!((3..17).contains(&x));
        }
    }

    #[test]
    fn gen_range_covers_all_values() {
        let mut r = Rng::new(11);
        let mut seen = [false; 8];
        for _ in 0..1_000 {
            seen[r.gen_range(0, 8)] = true;
        }
        assert!(seen.iter().all(|&s| s));
    }

    #[test]
    fn mean_of_unit_draws_is_centered() {
        let mut r = Rng::new(13);
        let n = 100_000;
        let mean: f64 = (0..n).map(|_| r.next_f64()).sum::<f64>() / n as f64;
        assert!((mean - 0.5).abs() < 0.01, "mean {mean}");
    }

    #[test]
    fn gen_exp_is_positive_with_the_right_mean() {
        let mut r = Rng::new(19);
        let n = 100_000;
        let rate = 4.0;
        let mut sum = 0.0;
        for _ in 0..n {
            let x = r.gen_exp(rate);
            assert!(x.is_finite() && x >= 0.0, "draw {x}");
            sum += x;
        }
        let mean = sum / n as f64;
        assert!((mean - 1.0 / rate).abs() < 0.01, "mean {mean}");
    }

    #[test]
    fn shuffle_is_a_permutation() {
        let mut r = Rng::new(17);
        let mut xs: Vec<usize> = (0..50).collect();
        r.shuffle(&mut xs);
        let mut sorted = xs.clone();
        sorted.sort_unstable();
        assert_eq!(sorted, (0..50).collect::<Vec<_>>());
    }

    #[test]
    fn fill_matrix_range() {
        let mut r = Rng::new(21);
        let m = r.fill_matrix(256);
        assert_eq!(m.len(), 256);
        assert!(m.iter().all(|&x| (-1.0..1.0).contains(&x)));
    }
}
