//! CSV and markdown table emission for figures and benches.
//!
//! Every paper figure is regenerated as (a) a CSV file consumable by any
//! plotting tool and (b) a markdown table printed to stdout (see DESIGN.md §9).

use std::fmt::Write as _;
use std::fs;
use std::io;
use std::path::Path;

/// A rectangular table with named columns. Cells are strings; numeric
/// helpers format with sensible precision.
#[derive(Debug, Clone)]
pub struct Table {
    pub title: String,
    pub columns: Vec<String>,
    pub rows: Vec<Vec<String>>,
}

impl Table {
    pub fn new(title: &str, columns: &[&str]) -> Self {
        Table {
            title: title.to_string(),
            columns: columns.iter().map(|c| c.to_string()).collect(),
            rows: Vec::new(),
        }
    }

    /// Append a row of preformatted cells. Panics on arity mismatch —
    /// a mismatch is always a bug in the figure generator.
    pub fn push_row(&mut self, cells: Vec<String>) {
        assert_eq!(
            cells.len(),
            self.columns.len(),
            "row arity {} != column count {} in table '{}'",
            cells.len(),
            self.columns.len(),
            self.title
        );
        self.rows.push(cells);
    }

    /// Append a row of f64 cells formatted with `prec` decimals.
    pub fn push_f64_row(&mut self, cells: &[f64], prec: usize) {
        self.push_row(cells.iter().map(|x| format!("{x:.prec$}")).collect());
    }

    /// Render as CSV (RFC-4180-ish; cells containing commas/quotes are quoted).
    pub fn to_csv(&self) -> String {
        let mut out = String::new();
        out.push_str(&csv_line(&self.columns));
        for row in &self.rows {
            out.push_str(&csv_line(row));
        }
        out
    }

    /// Render as a GitHub-flavored markdown table.
    pub fn to_markdown(&self) -> String {
        let mut out = String::new();
        let _ = writeln!(out, "### {}", self.title);
        let _ = writeln!(out, "| {} |", self.columns.join(" | "));
        let _ = writeln!(
            out,
            "|{}|",
            self.columns.iter().map(|_| "---").collect::<Vec<_>>().join("|")
        );
        for row in &self.rows {
            let _ = writeln!(out, "| {} |", row.join(" | "));
        }
        out
    }

    /// Write the CSV rendering to `path`, creating parent directories.
    pub fn write_csv(&self, path: &Path) -> io::Result<()> {
        if let Some(parent) = path.parent() {
            fs::create_dir_all(parent)?;
        }
        fs::write(path, self.to_csv())
    }

    /// Look up a column index by name.
    pub fn col(&self, name: &str) -> Option<usize> {
        self.columns.iter().position(|c| c == name)
    }

    /// Parse a column as f64 (panics on unparsable cells — figure
    /// tables are machine-generated).
    pub fn f64_column(&self, name: &str) -> Vec<f64> {
        let idx = self
            .col(name)
            .unwrap_or_else(|| panic!("no column '{name}' in table '{}'", self.title));
        self.rows
            .iter()
            .map(|r| r[idx].parse::<f64>().expect("non-numeric cell"))
            .collect()
    }
}

fn csv_line(cells: &[String]) -> String {
    let quoted: Vec<String> = cells
        .iter()
        .map(|c| {
            if c.contains(',') || c.contains('"') || c.contains('\n') {
                format!("\"{}\"", c.replace('"', "\"\""))
            } else {
                c.clone()
            }
        })
        .collect();
    format!("{}\n", quoted.join(","))
}

#[cfg(test)]
mod tests {
    use super::*;

    fn sample() -> Table {
        let mut t = Table::new("t", &["a", "b"]);
        t.push_row(vec!["1".into(), "x".into()]);
        t.push_row(vec!["2.5".into(), "y,z".into()]);
        t
    }

    #[test]
    fn csv_rendering_quotes_commas() {
        let csv = sample().to_csv();
        assert_eq!(csv, "a,b\n1,x\n2.5,\"y,z\"\n");
    }

    #[test]
    fn markdown_has_header_and_rows() {
        let md = sample().to_markdown();
        assert!(md.contains("| a | b |"));
        assert!(md.contains("|---|---|"));
        assert!(md.contains("| 2.5 | y,z |"));
    }

    #[test]
    #[should_panic(expected = "row arity")]
    fn arity_mismatch_panics() {
        let mut t = Table::new("t", &["a", "b"]);
        t.push_row(vec!["only-one".into()]);
    }

    #[test]
    fn f64_column_roundtrip() {
        let t = sample();
        assert_eq!(t.f64_column("a"), vec![1.0, 2.5]);
    }

    #[test]
    fn push_f64_row_formats() {
        let mut t = Table::new("t", &["x"]);
        t.push_f64_row(&[1.23456], 2);
        assert_eq!(t.rows[0][0], "1.23");
    }

    #[test]
    fn write_csv_creates_dirs() {
        let dir = std::env::temp_dir().join("amp_gemm_table_test");
        let _ = fs::remove_dir_all(&dir);
        let path = dir.join("nested/t.csv");
        sample().write_csv(&path).unwrap();
        assert!(path.exists());
        let _ = fs::remove_dir_all(&dir);
    }

    #[test]
    fn quotes_escaped() {
        let mut t = Table::new("t", &["a"]);
        t.push_row(vec!["say \"hi\"".into()]);
        assert!(t.to_csv().contains("\"say \"\"hi\"\"\""));
    }
}
