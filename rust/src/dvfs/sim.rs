//! Replay of a [`DvfsSchedule`] through the calibrated engine, with
//! online weight retuning.
//!
//! Two regimes, one entry point ([`simulate_dvfs`]):
//!
//! * **static schedule** (no transitions) — the run delegates to the
//!   DES (`crate::sim::simulate`) on the descriptor at the pinned
//!   operating point. Under the `performance` governor that descriptor
//!   is bit-for-bit the boot descriptor, so the DVFS path reproduces
//!   the fixed-frequency pins exactly (the regression-test guarantee);
//! * **transitions present** — an epoch-fluid replay: virtual time is
//!   cut at every OPP transition; each epoch's per-cluster throughputs
//!   are recomputed from the analytical model at the descriptor in
//!   effect, calibrated against one DES run of the same epoch's
//!   configuration so the fluid aggregate equals the DES aggregate at
//!   every fixed point (no cross-regime optimism). Static-asymmetric
//!   shares are then either **retuned online** — the un-executed work
//!   is repartitioned by the epoch's fresh weight vector — or left at
//!   the **stale boot-time split**, which is exactly what a SAS run
//!   configured once at launch would do under a governor (§5.2's ratio
//!   knob going wrong, arXiv:1509.02058). Dynamic strategies rebalance
//!   through the chunk queue and need no retuning.
//!
//! Everything is deterministic virtual time: same schedule, same
//! timeline, bit for bit.

use crate::blis::gemm::GemmShape;
use crate::calibrate::{ShapeClass, WeightSource};
use crate::dvfs::{DvfsSchedule, Governor, LoadSignal, Ondemand};
use crate::energy::{CoreState, PowerModel};
use crate::model::calibration as cal;
use crate::model::PerfModel;
use crate::obs::{MetricsRegistry, TraceEvent, TraceSink};
use crate::sched::ScheduleSpec;
use crate::sim;
use crate::soc::SocSpec;

/// What happens to the SAS weight vector at an OPP transition.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Retune {
    /// Keep the boot-time split — the stale baseline.
    Boot,
    /// Repartition the remaining work by the fresh weight vector.
    Online,
}

impl Retune {
    pub fn label(self) -> &'static str {
        match self {
            Retune::Boot => "boot weights",
            Retune::Online => "online retune",
        }
    }
}

/// Strategy family the DVFS engine replays. The coarse/fine loop
/// choices of [`ScheduleSpec`] are below the epoch granularity; what
/// matters here is static-vs-dynamic and whose blocking parameters each
/// cluster runs.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum DvfsStrategy {
    /// Static-asymmetric with model-derived weights (§5.2/§5.3).
    Sas { cache_aware: bool },
    /// Dynamic chunk queue (§5.4).
    Das { cache_aware: bool },
}

impl DvfsStrategy {
    pub fn label(self) -> &'static str {
        match self {
            DvfsStrategy::Sas { cache_aware: false } => "SAS",
            DvfsStrategy::Sas { cache_aware: true } => "CA-SAS",
            DvfsStrategy::Das { cache_aware: false } => "DAS",
            DvfsStrategy::Das { cache_aware: true } => "CA-DAS",
        }
    }

    pub fn is_dynamic(self) -> bool {
        matches!(self, DvfsStrategy::Das { .. })
    }

    pub fn cache_aware(self) -> bool {
        match self {
            DvfsStrategy::Sas { cache_aware } | DvfsStrategy::Das { cache_aware } => cache_aware,
        }
    }

    /// The equivalent fixed-frequency schedule spec (analytical weights
    /// from the given model — i.e. from the operating point it was
    /// built at).
    pub fn to_spec(self, model: &PerfModel) -> ScheduleSpec {
        self.to_spec_with(model, &WeightSource::Analytical, ShapeClass::Large)
    }

    /// [`DvfsStrategy::to_spec`] with the weight vector drawn from a
    /// [`WeightSource`] at the model's current per-cluster rungs: the
    /// calibrated (or blended) split for static strategies; dynamic
    /// strategies carry no weights and ignore the source.
    pub fn to_spec_with(
        self,
        model: &PerfModel,
        source: &WeightSource,
        class: ShapeClass,
    ) -> ScheduleSpec {
        match self {
            DvfsStrategy::Sas { cache_aware: false } => {
                ScheduleSpec::sas_weighted(source.weights(model, false, class))
            }
            DvfsStrategy::Sas { cache_aware: true } => {
                ScheduleSpec::ca_sas_weighted(source.weights(model, true, class))
            }
            DvfsStrategy::Das { cache_aware: false } => ScheduleSpec::das(),
            DvfsStrategy::Das { cache_aware: true } => ScheduleSpec::ca_das(),
        }
    }
}

/// Result of one DVFS replay. Deterministic; two runs of the same
/// (schedule, strategy, retune, shape) compare equal.
#[derive(Debug, Clone, PartialEq)]
pub struct DvfsStats {
    pub label: String,
    pub shape: GemmShape,
    /// Virtual makespan (seconds).
    pub time_s: f64,
    pub gflops: f64,
    pub energy_j: f64,
    pub gflops_per_watt: f64,
    /// Fraction of the problem's flops each cluster executed (indexed
    /// by cluster; flop-exact in the epoch replay, busy-time-derived on
    /// the static DES fast path for dynamic strategies).
    pub cluster_share: Vec<f64>,
    /// Virtual instant each cluster retired its last flop.
    pub cluster_finish_s: Vec<f64>,
    /// OPP transitions that fired before the makespan.
    pub transitions_applied: usize,
    /// Weight-vector recomputations (online SAS retuning events).
    pub retunes: usize,
    /// Chunk grabs (dynamic strategies).
    pub grabs: u64,
}

/// One epoch of the fluid replay: the descriptor (and therefore rates,
/// powers and weights) in effect over `[t0, t1)`.
struct Epoch {
    t0: f64,
    t1: f64,
    /// DES-calibrated per-cluster throughput, flops/s.
    rate: Vec<f64>,
    /// Cluster power while computing / while polling at the join, W.
    p_busy: Vec<f64>,
    p_poll: Vec<f64>,
    /// Normalized per-cluster shares at this operating point.
    weights: Vec<f64>,
}

/// Simulate one GEMM under `strat` while the OPP `schedule` plays out,
/// with `retune` governing the SAS weight vector at transitions.
/// Weights come from the analytical model — the pre-calibration
/// behavior, bit for bit ([`simulate_dvfs_with`] selects the source).
pub fn simulate_dvfs(
    base: &SocSpec,
    strat: DvfsStrategy,
    shape: GemmShape,
    schedule: &DvfsSchedule,
    retune: Retune,
) -> DvfsStats {
    simulate_dvfs_with(base, strat, shape, schedule, retune, &WeightSource::Analytical)
}

/// [`simulate_dvfs`] with the SAS weight vector drawn from a
/// [`WeightSource`]: at every epoch (boot and each OPP transition) the
/// split is looked up at that epoch's *per-cluster rung vector* — so an
/// empirical source feeds measured per-OPP rates into the online
/// retuner instead of one global ratio. Epoch *throughputs* (the fluid
/// rates that integrate time and energy) stay DES-calibrated regardless
/// of the source: the engine remains the arbiter of how fast work
/// drains; the source only decides who is assigned what.
pub fn simulate_dvfs_with(
    base: &SocSpec,
    strat: DvfsStrategy,
    shape: GemmShape,
    schedule: &DvfsSchedule,
    retune: Retune,
    source: &WeightSource,
) -> DvfsStats {
    schedule.validate(base).expect("invalid DVFS schedule");
    let label = format!("{} [{}]", strat.label(), retune.label());
    let n = base.num_clusters();
    let class = ShapeClass::for_soc(base, shape);

    if schedule.is_static() {
        // Fixed operating point: the DES is exact — and bit-for-bit the
        // pre-DVFS results when the point is nominal.
        let model = PerfModel::new(schedule.soc_at(base, 0.0));
        let spec = strat.to_spec_with(&model, source, class);
        let st = sim::simulate(&model, &spec, shape);
        let cluster_share = match strat {
            DvfsStrategy::Sas { cache_aware } => source
                .weights(&model, cache_aware, class)
                .normalized()
                .as_slice()
                .to_vec(),
            DvfsStrategy::Das { .. } => {
                let mut busy = vec![0.0; n];
                for c in model.soc.cluster_ids() {
                    for gid in model.soc.core_ids(c) {
                        busy[c.0] += st.activity[gid].busy_s;
                    }
                }
                let total: f64 = busy.iter().sum();
                busy.iter().map(|b| b / total).collect()
            }
        };
        return DvfsStats {
            label,
            shape,
            time_s: st.time_s,
            gflops: st.gflops,
            energy_j: st.energy.energy_j,
            gflops_per_watt: st.gflops_per_watt,
            cluster_share,
            cluster_finish_s: vec![st.time_s; n],
            transitions_applied: 0,
            retunes: 0,
            grabs: st.grabs,
        };
    }

    // ---- epoch-fluid replay over the transition boundaries ----
    let (epochs, bytes_per_flop) = build_epochs(base, strat, shape, schedule, source, class);
    let f_total = shape.flops();
    let (finish, executed, retunes, grabs) = if strat.is_dynamic() {
        let (f, e, g) = run_das(base, strat, shape, &epochs);
        (f, e, 0, g)
    } else {
        let (f, e, r) = run_sas(&epochs, f_total, retune);
        (f, e, r, 0)
    };

    let makespan = finish.iter().cloned().fold(0.0, f64::max);
    let energy_j = integrate_energy(&epochs, &finish, makespan)
        + bytes_per_flop * f_total * cal::DRAM_NJ_PER_BYTE * 1e-9;
    let transitions_applied = schedule
        .transitions
        .iter()
        .filter(|tr| tr.t_s < makespan)
        .count();
    DvfsStats {
        label,
        shape,
        time_s: makespan,
        gflops: f_total / makespan / 1e9,
        energy_j,
        gflops_per_watt: f_total / energy_j / 1e9,
        cluster_share: executed.iter().map(|e| e / f_total).collect(),
        cluster_finish_s: finish,
        transitions_applied,
        retunes,
        grabs,
    }
}

/// [`simulate_dvfs_with`] plus observability: the replay itself is
/// untouched (same arithmetic, same [`DvfsStats`] bit for bit); the
/// trace and metrics are *derived* afterwards from the schedule and
/// the returned makespan. Emits, on process 0: epoch spans between
/// transition boundaries (tid 0), per-cluster OPP-residency spans and
/// transition instants (tid 1+c), and the counters
/// `dvfs_transitions_applied` / `dvfs_retunes` / `dvfs_grabs` plus
/// per-rung residency seconds (`dvfs_residency_c{c}_opp{r}_s`).
pub fn simulate_dvfs_traced(
    base: &SocSpec,
    strat: DvfsStrategy,
    shape: GemmShape,
    schedule: &DvfsSchedule,
    retune: Retune,
    source: &WeightSource,
    sink: &mut dyn TraceSink,
    metrics: &mut MetricsRegistry,
) -> DvfsStats {
    let stats = simulate_dvfs_with(base, strat, shape, schedule, retune, source);
    let makespan = stats.time_s;
    if metrics.enabled() {
        metrics.inc("dvfs_transitions_applied", stats.transitions_applied as f64);
        metrics.inc("dvfs_retunes", stats.retunes as f64);
        metrics.inc("dvfs_grabs", stats.grabs as f64);
    }
    if sink.enabled() {
        sink.record(TraceEvent::process_name(0, &base.name));
        sink.record(TraceEvent::thread_name(0, 0, "epochs"));
        for c in base.cluster_ids() {
            sink.record(TraceEvent::thread_name(0, 1 + c.0, &format!("cluster c{}", c.0)));
        }
        for tr in &schedule.transitions {
            if tr.t_s < makespan {
                sink.record(TraceEvent::instant(
                    &format!("opp c{}->{}", tr.cluster.0, tr.opp),
                    "dvfs",
                    0,
                    1 + tr.cluster.0,
                    tr.t_s,
                ));
            }
        }
        let mut edges = vec![0.0];
        for &t in &schedule.boundaries() {
            if t > 0.0 && t < makespan {
                edges.push(t);
            }
        }
        edges.push(makespan);
        for (i, w) in edges.windows(2).enumerate() {
            if w[1] > w[0] {
                sink.record(TraceEvent::span(&format!("epoch{i}"), "dvfs", 0, 0, w[0], w[1] - w[0]));
            }
        }
    }
    if metrics.enabled() || sink.enabled() {
        // Per-cluster rung residency: cut [0, makespan] at the
        // cluster's own transitions; `opp_at` names the rung in force
        // over each piece.
        for c in base.cluster_ids() {
            let mut cuts = vec![0.0];
            for tr in &schedule.transitions {
                if tr.cluster == c && tr.t_s > 0.0 && tr.t_s < makespan {
                    cuts.push(tr.t_s);
                }
            }
            cuts.push(makespan);
            for w in cuts.windows(2) {
                let (t0, t1) = (w[0], w[1]);
                if t1 <= t0 {
                    continue;
                }
                let rung = schedule.opp_at(c, t0);
                metrics.inc(&format!("dvfs_residency_c{}_opp{rung}_s", c.0), t1 - t0);
                if sink.enabled() {
                    sink.record(TraceEvent::span(
                        &format!("opp{rung}"),
                        "dvfs",
                        0,
                        1 + c.0,
                        t0,
                        t1 - t0,
                    ));
                }
            }
        }
    }
    stats
}

/// Close the governor loop over one GEMM replay: seed with the
/// open-loop ramp, replay it, sample the per-cluster busy trace
/// ([`LoadSignal::from_busy_until`] — each cluster is busy until its
/// own `cluster_finish_s`, idle after), re-plan with
/// [`Governor::plan_closed_loop`], and iterate to a fixed point (the
/// loop converges in two rounds in practice: once the idle tails are
/// observed the down-steps stop moving).
///
/// The result keeps the critical cluster's ramp — a busy cluster is at
/// 100 % utilization every period, which is exactly the open-loop
/// assumption — and steps early-finishing clusters down to the bottom
/// rung for their idle tail: same makespan, strictly less tail energy
/// than the blind time ramp.
pub fn plan_load_driven(
    base: &SocSpec,
    strat: DvfsStrategy,
    shape: GemmShape,
    gov: &Ondemand,
    retune: Retune,
    source: &WeightSource,
) -> DvfsSchedule {
    let mut plan = gov.plan(base, 1e3);
    for _ in 0..4 {
        let st = simulate_dvfs_with(base, strat, shape, &plan, retune, source);
        let sig = LoadSignal::from_busy_until(gov.period_s, &st.cluster_finish_s);
        let next = gov.plan_closed_loop(base, &sig);
        if next == plan {
            break;
        }
        plan = next;
    }
    plan
}

/// [`plan_load_driven`] and replay the converged schedule. Returns the
/// stats together with the plan so callers (figures, CLI) can show the
/// feedback-driven transitions next to the blind ramp's.
pub fn simulate_dvfs_load_driven(
    base: &SocSpec,
    strat: DvfsStrategy,
    shape: GemmShape,
    gov: &Ondemand,
    retune: Retune,
    source: &WeightSource,
) -> (DvfsStats, DvfsSchedule) {
    let plan = plan_load_driven(base, strat, shape, gov, retune, source);
    let mut st = simulate_dvfs_with(base, strat, shape, &plan, retune, source);
    st.label = format!("{} [closed loop]", st.label);
    (st, plan)
}

/// Cut virtual time at every transition and compute each epoch's
/// DES-calibrated per-cluster rates, rail powers and the weight vector
/// the `source` assigns at that epoch's rung vector.
fn build_epochs(
    base: &SocSpec,
    strat: DvfsStrategy,
    shape: GemmShape,
    schedule: &DvfsSchedule,
    source: &WeightSource,
    class: ShapeClass,
) -> (Vec<Epoch>, f64) {
    let mut times = vec![0.0];
    times.extend(schedule.boundaries());
    let mut epochs = Vec::with_capacity(times.len());
    let mut bytes_per_flop = 0.0;
    // Epochs revisiting an operating point (same-rung transitions,
    // governor plateaus) share one recalibration DES instead of paying
    // one full run per epoch: the cache fingerprints the derived
    // at-OPP descriptor, which encodes the rung vector.
    let mut cache = sim::RunCache::new();
    for (i, &t0) in times.iter().enumerate() {
        let t1 = times.get(i + 1).copied().unwrap_or(f64::INFINITY);
        let soc_t = schedule.soc_at(base, t0);
        let model = PerfModel::new(soc_t);
        let params = model.family_params(strat.cache_aware());
        let analytic: Vec<f64> = model
            .soc
            .cluster_ids()
            .map(|c| model.cluster_rate_gflops(c, &params[c.0], model.soc[c].num_cores))
            .collect();
        let total: f64 = analytic.iter().sum();
        // The epoch's *assignment* weights come from the source at this
        // epoch's per-cluster rung vector (the per-OPP empirical rates,
        // when calibrated); with the analytical source this is exactly
        // `analytic[c] / total`, bit for bit.
        let opps: Vec<usize> = base.cluster_ids().map(|c| schedule.opp_at(c, t0)).collect();
        let weights = source
            .weights_for(&model, &opps, strat.cache_aware(), class)
            .normalized()
            .as_slice()
            .to_vec();
        // One DES run of this epoch's fixed-point configuration pins
        // the fluid aggregate to the engine's (packing, barriers,
        // cross-cluster interference included) — the epoch replay can
        // never be optimistic relative to a fixed-frequency DES run.
        let joint = cache.run(&model, &strat.to_spec_with(&model, source, class), shape);
        if i == 0 {
            bytes_per_flop = joint.dram_bytes / joint.flops;
        }
        let eta = joint.gflops / total;
        let pm = PowerModel::new(model.soc.clone());
        let p_busy: Vec<f64> = model
            .soc
            .cluster_ids()
            .map(|c| {
                model.soc[c].tuning.p_cluster_idle_w
                    + model.soc[c].num_cores as f64 * pm.core_increment_w(c, CoreState::Busy)
            })
            .collect();
        let p_poll: Vec<f64> = model
            .soc
            .cluster_ids()
            .map(|c| {
                model.soc[c].tuning.p_cluster_idle_w
                    + model.soc[c].num_cores as f64 * pm.core_increment_w(c, CoreState::Poll)
            })
            .collect();
        epochs.push(Epoch {
            t0,
            t1,
            rate: analytic.iter().map(|r| r * eta * 1e9).collect(),
            p_busy,
            p_poll,
            weights,
        });
    }
    (epochs, bytes_per_flop)
}

/// Static-asymmetric fluid drain: each cluster owns a share of the
/// flops; at every epoch boundary the un-executed remainder is either
/// repartitioned by the fresh weights (online) or left alone (boot).
/// Returns (finish instants, executed flops, retune count).
fn run_sas(epochs: &[Epoch], f_total: f64, retune: Retune) -> (Vec<f64>, Vec<f64>, usize) {
    let n = epochs[0].rate.len();
    let mut remaining: Vec<f64> = epochs[0].weights.iter().map(|w| w * f_total).collect();
    let mut executed = vec![0.0; n];
    let mut finish = vec![0.0; n];
    let mut retunes = 0;
    for (i, ep) in epochs.iter().enumerate() {
        if i > 0 && retune == Retune::Online {
            let pool: f64 = remaining.iter().sum();
            if pool > 0.0 {
                for c in 0..n {
                    remaining[c] = pool * ep.weights[c];
                }
                retunes += 1;
            }
        }
        let dt = ep.t1 - ep.t0;
        let mut all_done = true;
        for c in 0..n {
            if remaining[c] <= 0.0 {
                continue;
            }
            let need = remaining[c] / ep.rate[c];
            if need <= dt {
                finish[c] = ep.t0 + need;
                executed[c] += remaining[c];
                remaining[c] = 0.0;
            } else {
                let done = ep.rate[c] * dt;
                executed[c] += done;
                remaining[c] -= done;
                all_done = false;
            }
        }
        if all_done {
            break;
        }
    }
    (finish, executed, retunes)
}

/// Dynamic fluid drain (§5.4 one epoch-level up): clusters grab chunks
/// of their own `mc` grain from the shared m-queue; a chunk's service
/// time integrates the cluster's rate across epoch boundaries, so a
/// transition firing mid-chunk is handled exactly. Returns (finish
/// instants, executed flops, grabs).
fn run_das(
    base: &SocSpec,
    strat: DvfsStrategy,
    shape: GemmShape,
    epochs: &[Epoch],
) -> (Vec<f64>, Vec<f64>, u64) {
    let n = base.num_clusters();
    let model = PerfModel::new(base.clone());
    let params = model.family_params(strat.cache_aware());
    let grains: Vec<usize> = params.iter().map(|p| p.mc).collect();
    let grab_s: Vec<f64> = base.clusters.iter().map(|c| c.tuning.grab_s).collect();

    let mut next_m = 0usize;
    let mut cs_free = 0.0f64;
    let mut clock = vec![0.0f64; n];
    let mut executed = vec![0.0f64; n];
    let mut grabs = 0u64;
    while next_m < shape.m {
        // The cluster with the earliest clock grabs (ties: lowest id).
        let mut idx = 0;
        for c in 1..n {
            if clock[c] < clock[idx] {
                idx = c;
            }
        }
        let t_work = clock[idx].max(cs_free) + grab_s[idx];
        cs_free = t_work;
        grabs += 1;
        let take = grains[idx].min(shape.m - next_m);
        next_m += take;
        let flops = 2.0 * take as f64 * shape.n as f64 * shape.k as f64;
        clock[idx] = advance(epochs, idx, t_work, flops);
        executed[idx] += flops;
    }
    (clock, executed, grabs)
}

/// Completion instant of `flops` of work for cluster `c` starting at
/// `start`, under the piecewise-constant epoch rates.
fn advance(epochs: &[Epoch], c: usize, start: f64, flops: f64) -> f64 {
    let mut t = start;
    let mut rem = flops;
    let mut i = epochs
        .iter()
        .position(|e| t < e.t1)
        .unwrap_or(epochs.len() - 1);
    loop {
        let ep = &epochs[i];
        let need = rem / ep.rate[c];
        if t + need <= ep.t1 {
            return t + need;
        }
        rem -= ep.rate[c] * (ep.t1 - t);
        t = ep.t1;
        i += 1;
    }
}

/// Rail energy over the run: every cluster computes until its finish
/// instant and polls at the join thereafter (§5.2.2), at the epoch's
/// OPP-scaled powers; DRAM+GPU idle rails run for the whole makespan.
fn integrate_energy(epochs: &[Epoch], finish: &[f64], makespan: f64) -> f64 {
    let mut e = (cal::P_DRAM_IDLE + cal::P_GPU_IDLE) * makespan;
    for ep in epochs {
        let a = ep.t0;
        let b = ep.t1.min(makespan);
        if b <= a {
            continue;
        }
        for c in 0..finish.len() {
            let busy = (finish[c].min(b) - a).max(0.0);
            let poll = (b - a) - busy;
            e += ep.p_busy[c] * busy + ep.p_poll[c] * poll;
        }
    }
    e
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::dvfs::{DvfsSchedule, Governor, Ondemand, Performance, Powersave, Transition};
    use crate::soc::{BIG, LITTLE};

    fn soc() -> SocSpec {
        SocSpec::exynos5422()
    }

    /// ISSUE acceptance criterion: under an ondemand-style schedule,
    /// SAS with online retuning beats stale-boot-weights SAS.
    #[test]
    fn online_retuning_beats_stale_boot_weights() {
        let s = soc();
        let plan = Ondemand::new(0.5).plan(&s, 30.0);
        let shape = GemmShape::square(2048);
        let stale = simulate_dvfs(&s, DvfsStrategy::Sas { cache_aware: true }, shape, &plan, Retune::Boot);
        let online =
            simulate_dvfs(&s, DvfsStrategy::Sas { cache_aware: true }, shape, &plan, Retune::Online);
        assert!(
            online.gflops > stale.gflops * 1.01,
            "online {} must beat stale {} GFLOPS",
            online.gflops,
            stale.gflops
        );
        assert!(online.time_s < stale.time_s);
        assert!(online.retunes > 0, "online path must actually retune");
        assert_eq!(stale.retunes, 0);
        assert!(online.transitions_applied > 0);
        // Both execute the whole problem.
        for st in [&stale, &online] {
            let sum: f64 = st.cluster_share.iter().sum();
            assert!((sum - 1.0).abs() < 1e-9, "{}: shares {sum}", st.label);
        }
        // The stale run keeps the boot split; the online run shifts
        // work toward the LITTLE cluster as its relative speed grows.
        assert!(
            online.cluster_share[1] > stale.cluster_share[1],
            "online little share {} vs stale {}",
            online.cluster_share[1],
            stale.cluster_share[1]
        );
    }

    /// ISSUE satellite: the dynamic queue drains every row even when an
    /// OPP transition fires mid-simulation.
    #[test]
    fn das_drains_everything_across_mid_run_transitions() {
        let s = soc();
        // A deliberately mid-run transition: downclock the big cluster
        // partway through, upclock the LITTLE.
        let plan = DvfsSchedule::new(
            vec![4, 0],
            vec![
                Transition { t_s: 0.3, cluster: BIG, opp: 1 },
                Transition { t_s: 0.6, cluster: LITTLE, opp: 4 },
            ],
        );
        // Large enough that both transitions fire mid-run (the boot
        // configuration sustains ~10 GFLOPS, so r = 2048 runs ~1.7 s).
        let shape = GemmShape::square(2048);
        for strat in [
            DvfsStrategy::Das { cache_aware: true },
            DvfsStrategy::Das { cache_aware: false },
        ] {
            let st = simulate_dvfs(&s, strat, shape, &plan, Retune::Online);
            let sum: f64 = st.cluster_share.iter().sum();
            assert!((sum - 1.0).abs() < 1e-9, "{}: drained {sum} of the work", st.label);
            assert!(st.grabs > 0);
            assert!(st.time_s.is_finite() && st.time_s > 0.0);
            assert_eq!(st.transitions_applied, 2, "{}", st.label);
            assert!(st.cluster_share.iter().all(|&x| x > 0.0), "both clusters work");
        }
    }

    /// ISSUE 5 degeneracy anchor: an empirical table synthesized from
    /// the analytical model feeds the online retuner the exact same
    /// per-OPP weights — the whole replay reproduces bit for bit, so
    /// `Empirical` differs from `Analytical` only by what was measured.
    #[test]
    fn analytical_synthesis_replays_bit_for_bit() {
        use crate::calibrate::RateTable;
        let s = soc();
        let table = WeightSource::Empirical(RateTable::from_analytical(&s));
        let plan = Ondemand::new(0.25).plan(&s, 30.0);
        let shape = GemmShape::square(1024);
        for strat in [
            DvfsStrategy::Sas { cache_aware: true },
            DvfsStrategy::Sas { cache_aware: false },
            DvfsStrategy::Das { cache_aware: true },
        ] {
            for retune in [Retune::Boot, Retune::Online] {
                let ana = simulate_dvfs(&s, strat, shape, &plan, retune);
                let emp = simulate_dvfs_with(&s, strat, shape, &plan, retune, &table);
                assert_eq!(ana, emp, "{} [{}]", strat.label(), retune.label());
            }
        }
        // Static schedules too (the DES fast path).
        let pinned = Performance.plan(&s, 1.0);
        let strat = DvfsStrategy::Sas { cache_aware: true };
        let ana = simulate_dvfs(&s, strat, shape, &pinned, Retune::Online);
        let emp = simulate_dvfs_with(&s, strat, shape, &pinned, Retune::Online, &table);
        assert_eq!(ana, emp);
    }

    /// A genuinely measured table shifts the online split away from the
    /// analytical one — and the empirically weighted replay still
    /// drains everything deterministically.
    #[test]
    fn measured_table_feeds_the_retuner() {
        use crate::calibrate::RateTable;
        let s = soc();
        let source = WeightSource::Empirical(RateTable::measure(&s, &[]));
        let plan = Ondemand::new(0.25).plan(&s, 30.0);
        let shape = GemmShape::square(2048);
        let strat = DvfsStrategy::Sas { cache_aware: true };
        let emp = simulate_dvfs_with(&s, strat, shape, &plan, Retune::Online, &source);
        let ana = simulate_dvfs(&s, strat, shape, &plan, Retune::Online);
        let sum: f64 = emp.cluster_share.iter().sum();
        assert!((sum - 1.0).abs() < 1e-9, "shares {sum}");
        assert!(emp.retunes > 0, "the empirical path must retune per rung");
        assert!(
            emp.cluster_share != ana.cluster_share,
            "measured rates must shift the split: {:?}",
            emp.cluster_share
        );
        // Deterministic replay.
        let again = simulate_dvfs_with(&s, strat, shape, &plan, Retune::Online, &source);
        assert_eq!(emp, again);
    }

    /// ISSUE satellite: same schedule ⇒ identical timeline, twice.
    #[test]
    fn virtual_time_determinism() {
        let s = soc();
        let plan = Ondemand::new(0.25).plan(&s, 30.0);
        let shape = GemmShape::square(1024);
        for strat in [
            DvfsStrategy::Sas { cache_aware: true },
            DvfsStrategy::Das { cache_aware: true },
        ] {
            let a = simulate_dvfs(&s, strat, shape, &plan, Retune::Online);
            let b = simulate_dvfs(&s, strat, shape, &plan, Retune::Online);
            assert_eq!(a, b, "replay must be deterministic");
        }
    }

    /// A pinned non-nominal schedule delegates to the DES on the
    /// at-OPP descriptor — exactly.
    #[test]
    fn pinned_schedule_is_the_des_at_that_opp() {
        let s = soc();
        let plan = Powersave.plan(&s, 10.0);
        let shape = GemmShape::square(1024);
        let st = simulate_dvfs(&s, DvfsStrategy::Das { cache_aware: true }, shape, &plan, Retune::Boot);
        let low = s.at_opp(BIG, 0).at_opp(LITTLE, 0);
        let direct = sim::simulate(&PerfModel::new(low), &ScheduleSpec::ca_das(), shape);
        assert_eq!(st.time_s, direct.time_s);
        assert_eq!(st.gflops, direct.gflops);
        assert_eq!(st.energy_j, direct.energy.energy_j);
        assert_eq!(st.grabs, direct.grabs);
        assert_eq!(st.transitions_applied, 0);
    }

    /// Downclocking must cost performance but buy efficiency — the two
    /// ends of the Pareto frontier (arXiv:1507.05129).
    #[test]
    fn powersave_trades_speed_for_efficiency() {
        let s = soc();
        let shape = GemmShape::square(2048);
        let strat = DvfsStrategy::Sas { cache_aware: true };
        let fast = simulate_dvfs(&s, strat, shape, &Performance.plan(&s, 1.0), Retune::Online);
        let slow = simulate_dvfs(&s, strat, shape, &Powersave.plan(&s, 1.0), Retune::Online);
        assert!(fast.gflops > 1.5 * slow.gflops, "{} vs {}", fast.gflops, slow.gflops);
        assert!(
            slow.gflops_per_watt > 1.2 * fast.gflops_per_watt,
            "{} vs {}",
            slow.gflops_per_watt,
            fast.gflops_per_watt
        );
    }

    /// The epoch replay can never beat the fixed-top-frequency DES: the
    /// calibration pins every epoch's aggregate to the engine's.
    #[test]
    fn ramp_is_never_optimistic() {
        let s = soc();
        let shape = GemmShape::square(1024);
        let strat = DvfsStrategy::Sas { cache_aware: true };
        let top = simulate_dvfs(&s, strat, shape, &Performance.plan(&s, 1.0), Retune::Online);
        let ramp = simulate_dvfs(
            &s,
            strat,
            shape,
            &Ondemand::new(0.1).plan(&s, 10.0),
            Retune::Online,
        );
        assert!(
            ramp.gflops < top.gflops,
            "ramp {} must stay below the pinned top {}",
            ramp.gflops,
            top.gflops
        );
    }

    /// Transitions scheduled after the run ends are not "applied".
    #[test]
    fn late_transitions_do_not_count() {
        let s = soc();
        let plan = DvfsSchedule::new(
            vec![4, 4],
            vec![Transition { t_s: 1e6, cluster: BIG, opp: 0 }],
        );
        let st = simulate_dvfs(
            &s,
            DvfsStrategy::Sas { cache_aware: true },
            GemmShape::square(512),
            &plan,
            Retune::Online,
        );
        assert_eq!(st.transitions_applied, 0);
        assert_eq!(st.retunes, 0, "nothing left to retune at the late epoch");
    }

    /// Tentpole anchor: the closed-loop ondemand plan keeps the blind
    /// ramp while every cluster is busy and steps early finishers down
    /// to the bottom rung for their idle tail — (near-)equal makespan,
    /// strictly lower energy-to-solution than the open-loop time ramp.
    #[test]
    fn load_driven_ondemand_saves_tail_energy_at_equal_makespan() {
        let s = soc();
        let gov = Ondemand::new(0.25);
        // Stale boot weights make the cluster finish instants diverge —
        // exactly the idle tail the feedback loop can reclaim.
        let strat = DvfsStrategy::Sas { cache_aware: true };
        let shape = GemmShape::square(2048);
        let source = WeightSource::Analytical;
        let open =
            simulate_dvfs_with(&s, strat, shape, &gov.plan(&s, 1e3), Retune::Boot, &source);
        let (closed, plan) =
            simulate_dvfs_load_driven(&s, strat, shape, &gov, Retune::Boot, &source);
        plan.validate(&s).unwrap();
        assert!(
            plan.transitions.iter().any(|tr| tr.opp == 0 && tr.t_s > 0.0),
            "the converged plan must contain a down-step: {:?}",
            plan.transitions
        );
        let drift = (closed.time_s - open.time_s).abs() / open.time_s;
        assert!(
            drift < 0.01,
            "closed-loop makespan {} vs open {} drifted {:.3}%",
            closed.time_s,
            open.time_s,
            drift * 100.0
        );
        assert!(
            closed.energy_j < open.energy_j,
            "closed loop {} J must beat the time ramp {} J",
            closed.energy_j,
            open.energy_j
        );
        // The loop is deterministic and at a fixed point.
        let (again, plan2) =
            simulate_dvfs_load_driven(&s, strat, shape, &gov, Retune::Boot, &source);
        assert_eq!(closed, again);
        assert_eq!(plan, plan2);
    }

    /// The engine runs any topology: a tri-cluster ramp drains and
    /// stays deterministic.
    #[test]
    fn tri_cluster_ramp_replays() {
        let s = SocSpec::dynamiq_3c();
        let plan = Ondemand::new(0.2).plan(&s, 10.0);
        let shape = GemmShape::square(1024);
        for strat in [
            DvfsStrategy::Sas { cache_aware: true },
            DvfsStrategy::Das { cache_aware: true },
        ] {
            let st = simulate_dvfs(&s, strat, shape, &plan, Retune::Online);
            assert_eq!(st.cluster_share.len(), 3);
            let sum: f64 = st.cluster_share.iter().sum();
            assert!((sum - 1.0).abs() < 1e-9, "{}", st.label);
            assert!(st.energy_j > 0.0 && st.gflops > 0.0);
        }
    }
}
