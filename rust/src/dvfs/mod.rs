//! DVFS operating-point schedules with online weight retuning.
//!
//! The paper tunes and schedules GEMM for one fixed frequency pair on
//! the Exynos 5422, but a deployed big.LITTLE SoC runs under a governor
//! that moves each cluster through its operating points — and the
//! scheduler/governor interplay is exactly where asymmetric gains are
//! won or lost (arXiv:1509.02058), while the perf/energy optimum shifts
//! with the voltage-frequency point (arXiv:1507.05129). This layer
//! (DESIGN.md §4) adds that axis on top of the N-cluster descriptor:
//!
//! * every [`crate::soc::ClusterSpec`] carries an OPP ladder
//!   ([`OppTable`]; the paper presets get the Exynos A15/A7 `cpufreq`
//!   tables capped at the §3.2 operating point);
//! * a [`Governor`] plans a [`DvfsSchedule`] — timed per-cluster OPP
//!   transitions in *virtual* time — with `performance`, `powersave`
//!   and `ondemand`-style policies;
//! * [`DvfsSchedule::soc_at`] derives the descriptor in effect at any
//!   instant (frequency from the ladder, power rails scaled by the CMOS
//!   `f·V²` law), and [`DvfsSchedule::weights_at`] recomputes the
//!   normalized [`Weights`] vector there — the *online retuning*
//!   primitive: the first place in this codebase where the weight
//!   vector is a function of time rather than a constant;
//! * [`sim`] replays a schedule through the calibrated engine,
//!   repartitioning SAS shares at every transition (online) or keeping
//!   the stale boot-time split (the baseline it must beat).

pub mod sim;

pub use crate::soc::{OperatingPoint, OppTable};

use crate::model::PerfModel;
use crate::sched::Weights;
use crate::soc::{ClusterId, SocSpec};

/// One timed OPP switch: at virtual instant `t_s`, `cluster` moves to
/// ladder rung `opp`.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct Transition {
    pub t_s: f64,
    pub cluster: ClusterId,
    pub opp: usize,
}

/// A replayable plan of per-cluster operating points over virtual time:
/// an initial OPP per cluster plus a time-sorted list of transitions.
/// Governors produce these; the DVFS engine ([`sim::simulate_dvfs`])
/// and the fleet simulator replay them.
#[derive(Debug, Clone, PartialEq)]
pub struct DvfsSchedule {
    /// Initial ladder rung per cluster, in [`ClusterId`] order.
    pub initial: Vec<usize>,
    /// Transitions sorted by time (ties by cluster id).
    pub transitions: Vec<Transition>,
}

impl DvfsSchedule {
    /// Build from raw parts; transitions are sorted into replay order.
    /// The sort is total (`f64::total_cmp`, NaN-last) so a malformed
    /// time can never panic here — [`DvfsSchedule::validate`] is where
    /// non-finite instants are rejected with a clean `Err`.
    pub fn new(initial: Vec<usize>, mut transitions: Vec<Transition>) -> Self {
        transitions.sort_by(|a, b| a.t_s.total_cmp(&b.t_s).then(a.cluster.cmp(&b.cluster)));
        DvfsSchedule { initial, transitions }
    }

    /// Every cluster pinned at its nominal (boot) rung forever — the
    /// schedule under which the DVFS path is provably a no-op.
    pub fn nominal(soc: &SocSpec) -> Self {
        DvfsSchedule::new(
            soc.clusters.iter().map(|c| c.opps.nominal_idx()).collect(),
            Vec::new(),
        )
    }

    /// Every cluster pinned at the given rungs (no transitions).
    pub fn pinned(opps: &[usize]) -> Self {
        DvfsSchedule::new(opps.to_vec(), Vec::new())
    }

    /// Check the plan against a topology: one initial rung per cluster,
    /// every rung inside its ladder, times finite and non-negative.
    pub fn validate(&self, soc: &SocSpec) -> Result<(), String> {
        if self.initial.len() != soc.num_clusters() {
            return Err(format!(
                "schedule has {} initial OPPs but '{}' has {} clusters",
                self.initial.len(),
                soc.name,
                soc.num_clusters()
            ));
        }
        for (i, &opp) in self.initial.iter().enumerate() {
            if opp >= soc.clusters[i].opps.len() {
                return Err(format!(
                    "initial OPP {opp} out of range for cluster c{i} \
                     ({} ladder points)",
                    soc.clusters[i].opps.len()
                ));
            }
        }
        for tr in &self.transitions {
            if tr.cluster.0 >= soc.num_clusters() {
                return Err(format!("transition names missing cluster {}", tr.cluster));
            }
            if tr.opp >= soc[tr.cluster].opps.len() {
                return Err(format!(
                    "transition OPP {} out of range for {} ({} ladder points)",
                    tr.opp,
                    tr.cluster,
                    soc[tr.cluster].opps.len()
                ));
            }
            if !tr.t_s.is_finite() || tr.t_s < 0.0 {
                return Err(format!("transition time must be finite and >= 0, got {}", tr.t_s));
            }
        }
        Ok(())
    }

    /// A schedule with no transitions holds one operating point forever.
    pub fn is_static(&self) -> bool {
        self.transitions.is_empty()
    }

    /// The rung `cluster` runs at instant `t` (transitions at exactly
    /// `t` have already fired).
    pub fn opp_at(&self, cluster: ClusterId, t: f64) -> usize {
        let mut opp = self.initial[cluster.0];
        for tr in &self.transitions {
            if tr.t_s > t {
                break;
            }
            if tr.cluster == cluster {
                opp = tr.opp;
            }
        }
        opp
    }

    /// Distinct future transition instants, ascending (t = 0 switches
    /// are folded into the initial state by [`DvfsSchedule::opp_at`]).
    pub fn boundaries(&self) -> Vec<f64> {
        let mut ts: Vec<f64> = self
            .transitions
            .iter()
            .map(|tr| tr.t_s)
            .filter(|&t| t > 0.0)
            .collect();
        // NaN-total order: a forged/NaN transition instant sorts last
        // instead of panicking the replay (ISSUE 9 hardening).
        ts.sort_by(|a, b| a.total_cmp(b));
        ts.dedup();
        ts
    }

    /// The descriptor in effect at instant `t`: every cluster moved to
    /// its scheduled rung via [`SocSpec::at_opp`]. At the nominal rung
    /// this is bit-for-bit `base`.
    pub fn soc_at(&self, base: &SocSpec, t: f64) -> SocSpec {
        let mut soc = base.clone();
        for c in base.cluster_ids() {
            soc = soc.at_opp(c, self.opp_at(c, t));
        }
        soc
    }

    /// The *online-retuned* weight vector at instant `t`: the
    /// analytical model's per-cluster throughputs under the descriptor
    /// in effect, normalized to shares. With a static schedule this is
    /// exactly the boot-time static vector — the degenerate-case
    /// property the tests pin.
    pub fn weights_at(&self, base: &SocSpec, t: f64, cache_aware: bool) -> Weights {
        PerfModel::new(self.soc_at(base, t))
            .auto_weights(cache_aware)
            .normalized()
    }
}

/// A per-period load trace sampled from a DES replay — the feedback
/// input of a closed-loop governor. Row `p` describes virtual-time
/// window `[p·period_s, (p+1)·period_s)`: the busy fraction of every
/// cluster in that window, plus an optional run-queue depth series for
/// fleet-level streams. This is the signal the open-loop `ondemand`
/// ramp is blind to: it carries *measured* utilization, not elapsed
/// time.
#[derive(Debug, Clone, PartialEq)]
pub struct LoadSignal {
    /// Sampling period (virtual seconds per row).
    pub period_s: f64,
    /// `samples[p][c]` = utilization of cluster `c` in period `p`,
    /// clamped to `[0, 1]`.
    pub samples: Vec<Vec<f64>>,
    /// Mean run-queue depth per period (empty when the replay has no
    /// queue, e.g. a single GEMM).
    pub queue_depth: Vec<f64>,
}

impl LoadSignal {
    pub fn new(period_s: f64, samples: Vec<Vec<f64>>) -> Self {
        assert!(
            period_s.is_finite() && period_s > 0.0,
            "load-signal period must be positive, got {period_s}"
        );
        assert!(
            samples
                .iter()
                .flatten()
                .all(|u| u.is_finite() && (0.0..=1.0).contains(u)),
            "utilization samples must be finite fractions in [0, 1]"
        );
        LoadSignal { period_s, samples, queue_depth: Vec::new() }
    }

    /// A flat signal: every cluster at `util` for `periods` periods.
    /// `util = 1.0` is the saturating trace under which a closed-loop
    /// governor must reproduce the open-loop ramp bit for bit; `0.0` is
    /// the idle trace under which it must never leave the bottom rung.
    pub fn constant(period_s: f64, n_clusters: usize, periods: usize, util: f64) -> Self {
        LoadSignal::new(period_s, vec![vec![util; n_clusters]; periods])
    }

    /// Sample a replay where cluster `c` is busy on `[0, busy_until[c])`
    /// and idle after — the shape every work-conserving GEMM/stream
    /// replay in this codebase produces. Covers the whole horizon:
    /// `ceil(max(busy_until) / period_s)` rows, plus one trailing idle
    /// row so the drain is observable.
    pub fn from_busy_until(period_s: f64, busy_until: &[f64]) -> Self {
        assert!(period_s.is_finite() && period_s > 0.0);
        assert!(busy_until.iter().all(|t| t.is_finite() && *t >= 0.0));
        let horizon = busy_until.iter().fold(0.0_f64, |a, &b| a.max(b));
        let periods = (horizon / period_s).ceil() as usize + 1;
        let samples = (0..periods)
            .map(|p| {
                let start = p as f64 * period_s;
                busy_until
                    .iter()
                    .map(|&f| ((f - start) / period_s).clamp(0.0, 1.0))
                    .collect()
            })
            .collect();
        LoadSignal::new(period_s, samples)
    }

    /// Read the signal back out of an [`crate::obs::MetricsRegistry`]
    /// snapshot of a stream replay (the `board{b}_utilization` gauge +
    /// the `queue_depth_mean` gauge over `periods` rows): the governor
    /// loop consuming the observability layer's numbers instead of
    /// growing private counters. The snapshot is an aggregate, so the
    /// trace is flat — a coarse but *measured* feedback term.
    pub fn from_metrics(
        reg: &crate::obs::MetricsRegistry,
        board: usize,
        period_s: f64,
        n_clusters: usize,
        periods: usize,
    ) -> Option<Self> {
        let util = reg.gauge(&format!("board{board}_utilization"))?;
        let mut sig =
            LoadSignal::constant(period_s, n_clusters, periods, util.clamp(0.0, 1.0));
        if let Some(depth) = reg.gauge("queue_depth_mean") {
            sig.queue_depth = vec![depth; periods];
        }
        Some(sig)
    }

    pub fn with_queue_depth(mut self, depth: Vec<f64>) -> Self {
        assert!(depth.iter().all(|d| d.is_finite() && *d >= 0.0));
        self.queue_depth = depth;
        self
    }

    /// The horizon the trace covers.
    pub fn horizon_s(&self) -> f64 {
        self.samples.len() as f64 * self.period_s
    }
}

/// A DVFS policy: plans a [`DvfsSchedule`] over a virtual-time horizon
/// for a given topology — the simulated counterpart of a `cpufreq`
/// governor (arXiv:1509.02058's scheduler/governor interplay).
pub trait Governor {
    fn name(&self) -> &'static str;
    /// Plan per-cluster OPP transitions over `[0, horizon_s)`.
    fn plan(&self, soc: &SocSpec, horizon_s: f64) -> DvfsSchedule;
    /// Plan against a measured [`LoadSignal`] instead of blind elapsed
    /// time. The default ignores the feedback and falls back to the
    /// open-loop plan over the signal's horizon — pinned governors
    /// (`performance`, `powersave`) are load-independent by definition,
    /// so only policies with a real feedback law override this.
    fn plan_closed_loop(&self, soc: &SocSpec, load: &LoadSignal) -> DvfsSchedule {
        self.plan(soc, load.horizon_s())
    }
}

/// Pin every cluster at the ladder top (= the nominal rung for every
/// preset): the schedule is static and the descriptor identical to the
/// boot descriptor, so results reproduce the fixed-frequency pins
/// bit-for-bit.
#[derive(Debug, Clone, Copy, Default)]
pub struct Performance;

impl Governor for Performance {
    fn name(&self) -> &'static str {
        "performance"
    }
    fn plan(&self, soc: &SocSpec, _horizon_s: f64) -> DvfsSchedule {
        // `len() - 1` trusts the ladder invariants — re-checked here so
        // a malformed descriptor fails with a diagnostic, not an
        // underflow (ISSUE 8).
        soc.validate_ladders().expect("governor planning against a malformed descriptor");
        DvfsSchedule::pinned(
            &soc.clusters
                .iter()
                .map(|c| c.opps.len() - 1)
                .collect::<Vec<_>>(),
        )
    }
}

/// Pin every cluster at the ladder bottom: slowest, lowest-voltage
/// point — the energy-to-solution end of the Pareto frontier.
#[derive(Debug, Clone, Copy, Default)]
pub struct Powersave;

impl Governor for Powersave {
    fn name(&self) -> &'static str {
        "powersave"
    }
    fn plan(&self, soc: &SocSpec, _horizon_s: f64) -> DvfsSchedule {
        DvfsSchedule::pinned(&vec![0; soc.num_clusters()])
    }
}

/// `ondemand`-style ramp driven by virtual time: a compute-bound GEMM
/// pins utilization at 100 %, so the governor walks each cluster up one
/// rung per sampling period from the bottom until the ladder top.
/// Because the A15 and A7 ladders scale differently rung-by-rung, the
/// per-cluster throughput *ratio* shifts at every step — exactly the
/// situation where stale boot-time SAS weights go wrong.
#[derive(Debug, Clone, Copy)]
pub struct Ondemand {
    /// Governor sampling period (virtual seconds per rung).
    pub period_s: f64,
    /// Closed-loop up-step threshold: a cluster whose measured
    /// utilization in a period reaches this raises one rung at the
    /// period boundary (the real `cpufreq` ondemand's `up_threshold`).
    pub up_threshold: f64,
    /// Closed-loop idle threshold: a cluster at or below this drops to
    /// the bottom rung (no point holding voltage for an empty queue).
    pub down_threshold: f64,
}

impl Ondemand {
    pub fn new(period_s: f64) -> Self {
        assert!(
            period_s.is_finite() && period_s > 0.0,
            "ondemand period must be positive, got {period_s}"
        );
        Ondemand { period_s, up_threshold: 0.7, down_threshold: 0.2 }
    }

    /// Override the closed-loop thresholds (open-loop planning is
    /// unaffected — it models a permanently saturated cluster).
    pub fn with_thresholds(mut self, up: f64, down: f64) -> Self {
        assert!(
            up.is_finite() && down.is_finite() && 0.0 <= down && down < up && up <= 1.0,
            "thresholds must satisfy 0 <= down < up <= 1, got up={up} down={down}"
        );
        self.up_threshold = up;
        self.down_threshold = down;
        self
    }
}

impl Default for Ondemand {
    fn default() -> Self {
        Ondemand::new(0.5)
    }
}

impl Governor for Ondemand {
    fn name(&self) -> &'static str {
        "ondemand"
    }
    fn plan(&self, soc: &SocSpec, horizon_s: f64) -> DvfsSchedule {
        soc.validate_ladders().expect("governor planning against a malformed descriptor");
        let mut transitions = Vec::new();
        for c in soc.cluster_ids() {
            for rung in 1..soc[c].opps.len() {
                let t = rung as f64 * self.period_s;
                if t >= horizon_s {
                    break;
                }
                transitions.push(Transition { t_s: t, cluster: c, opp: rung });
            }
        }
        DvfsSchedule::new(vec![0; soc.num_clusters()], transitions)
    }

    /// The feedback law: at every period boundary strictly inside the
    /// signal's horizon, a cluster whose measured utilization reached
    /// `up_threshold` raises one rung; one at or below `down_threshold`
    /// drops to the bottom. Between the thresholds it holds. Under a
    /// saturating trace this emits exactly the open-loop ramp (same
    /// `rung·period` instants — the degeneracy anchor); under a zero
    /// trace it emits nothing and stays pinned at the bottom rung. The
    /// sampling cadence is the *signal's* period: the governor reacts
    /// at the rate it is measured.
    fn plan_closed_loop(&self, soc: &SocSpec, load: &LoadSignal) -> DvfsSchedule {
        soc.validate_ladders().expect("governor planning against a malformed descriptor");
        let n = soc.num_clusters();
        let horizon = load.horizon_s();
        let mut cur = vec![0usize; n];
        let mut transitions = Vec::new();
        'periods: for (p, row) in load.samples.iter().enumerate() {
            assert_eq!(row.len(), n, "load signal row arity vs '{}'", soc.name);
            let t = (p + 1) as f64 * load.period_s;
            if t >= horizon {
                break 'periods;
            }
            for c in soc.cluster_ids() {
                // `opps` is never empty (OppTable::new forbids it), so
                // `len() - 1` cannot underflow; on a single-rung ladder
                // `top == 0` and neither branch can fire.
                let top = soc[c].opps.len() - 1;
                let u = row[c.0];
                if u >= self.up_threshold && cur[c.0] < top {
                    cur[c.0] += 1;
                    transitions.push(Transition { t_s: t, cluster: c, opp: cur[c.0] });
                } else if u <= self.down_threshold && cur[c.0] > 0 {
                    cur[c.0] = 0;
                    transitions.push(Transition { t_s: t, cluster: c, opp: 0 });
                }
            }
        }
        DvfsSchedule::new(vec![0; n], transitions)
    }
}

/// Parse a governor token: `performance`, `powersave`,
/// `ondemand[:PERIOD_MS]`.
pub fn parse_governor(s: &str) -> Result<Box<dyn Governor>, String> {
    match s {
        "performance" => Ok(Box::new(Performance)),
        "powersave" => Ok(Box::new(Powersave)),
        "ondemand" => Ok(Box::new(Ondemand::default())),
        other => match other.strip_prefix("ondemand:") {
            Some(ms) => {
                let ms: f64 = ms
                    .parse()
                    .map_err(|_| format!("bad ondemand period '{ms}' (milliseconds)"))?;
                if !ms.is_finite() || ms <= 0.0 {
                    return Err(format!("ondemand period must be positive, got {ms} ms"));
                }
                Ok(Box::new(Ondemand::new(ms / 1e3)))
            }
            None => Err(format!(
                "unknown governor '{other}' (performance|powersave|ondemand[:ms])"
            )),
        },
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::soc::{BIG, LITTLE};

    fn soc() -> SocSpec {
        SocSpec::exynos5422()
    }

    #[test]
    fn nominal_schedule_is_identity() {
        let s = soc();
        let plan = DvfsSchedule::nominal(&s);
        assert!(plan.is_static());
        plan.validate(&s).unwrap();
        assert_eq!(plan.soc_at(&s, 0.0), s);
        assert_eq!(plan.soc_at(&s, 123.0), s);
        assert_eq!(plan.opp_at(BIG, 5.0), 4);
    }

    #[test]
    fn performance_governor_pins_nominal() {
        let s = soc();
        let plan = Performance.plan(&s, 10.0);
        assert!(plan.is_static());
        assert_eq!(plan, DvfsSchedule::nominal(&s));
        assert_eq!(plan.soc_at(&s, 3.0), s);
    }

    #[test]
    fn powersave_governor_pins_bottom() {
        let s = soc();
        let plan = Powersave.plan(&s, 10.0);
        assert!(plan.is_static());
        let low = plan.soc_at(&s, 0.0);
        assert_eq!(low[BIG].core.freq_ghz, 0.8);
        assert_eq!(low[LITTLE].core.freq_ghz, 0.5);
        assert!(low[BIG].tuning.p_core_active_w < s[BIG].tuning.p_core_active_w);
    }

    #[test]
    fn ondemand_ramps_one_rung_per_period() {
        let s = soc();
        let plan = Ondemand::new(0.5).plan(&s, 10.0);
        plan.validate(&s).unwrap();
        assert!(!plan.is_static());
        // 4 upward steps per cluster, shared instants.
        assert_eq!(plan.transitions.len(), 8);
        assert_eq!(plan.boundaries(), vec![0.5, 1.0, 1.5, 2.0]);
        assert_eq!(plan.opp_at(BIG, 0.0), 0);
        assert_eq!(plan.opp_at(BIG, 0.5), 1, "transition at exactly t has fired");
        assert_eq!(plan.opp_at(BIG, 0.49), 0);
        assert_eq!(plan.opp_at(LITTLE, 9.0), 4);
        // Mid-ramp descriptor: big at rung 2 (1.2 GHz), little at 1.0.
        let mid = plan.soc_at(&s, 1.2);
        assert_eq!(mid[BIG].core.freq_ghz, 1.2);
        assert_eq!(mid[LITTLE].core.freq_ghz, 1.0);
        // A short horizon truncates the ramp.
        let short = Ondemand::new(0.5).plan(&s, 1.2);
        assert_eq!(short.boundaries(), vec![0.5, 1.0]);
    }

    #[test]
    fn retuned_weights_shift_along_the_ramp() {
        let s = soc();
        let plan = Ondemand::new(0.5).plan(&s, 10.0);
        let boot = plan.weights_at(&s, 0.0, true);
        let end = plan.weights_at(&s, 9.0, true);
        let sum: f64 = boot.as_slice().iter().sum();
        assert!((sum - 1.0).abs() < 1e-9, "normalized sum {sum}");
        // At the bottom rungs the big cluster's frequency advantage is
        // larger (0.8 vs 0.5 GHz = 1.6x, against 1.6 vs 1.4 = 1.14x at
        // the top), so its share must shrink as the ramp completes.
        assert!(
            boot.share(0) > end.share(0) + 0.01,
            "boot big share {} vs end {}",
            boot.share(0),
            end.share(0)
        );
        // And the end-of-ramp weights are exactly the static ones.
        let statics = PerfModel::new(s.clone()).auto_weights(true).normalized();
        assert_eq!(end.as_slice(), statics.as_slice());
    }

    #[test]
    fn schedule_validation_catches_bad_plans() {
        let s = soc();
        assert!(DvfsSchedule::pinned(&[0]).validate(&s).is_err(), "wrong arity");
        assert!(DvfsSchedule::pinned(&[0, 9]).validate(&s).is_err(), "bad rung");
        let bad_cluster = DvfsSchedule::new(
            vec![4, 4],
            vec![Transition { t_s: 1.0, cluster: ClusterId(7), opp: 0 }],
        );
        assert!(bad_cluster.validate(&s).is_err());
        let bad_time = DvfsSchedule::new(
            vec![4, 4],
            vec![Transition { t_s: -1.0, cluster: BIG, opp: 0 }],
        );
        assert!(bad_time.validate(&s).is_err());
        let bad_rung = DvfsSchedule::new(
            vec![4, 4],
            vec![Transition { t_s: 1.0, cluster: BIG, opp: 17 }],
        );
        assert!(bad_rung.validate(&s).is_err());
    }

    #[test]
    fn transitions_sort_into_replay_order() {
        let plan = DvfsSchedule::new(
            vec![0, 0],
            vec![
                Transition { t_s: 2.0, cluster: BIG, opp: 2 },
                Transition { t_s: 1.0, cluster: LITTLE, opp: 1 },
                Transition { t_s: 1.0, cluster: BIG, opp: 1 },
            ],
        );
        assert_eq!(plan.transitions[0].t_s, 1.0);
        assert_eq!(plan.transitions[0].cluster, BIG);
        assert_eq!(plan.transitions[1].cluster, LITTLE);
        assert_eq!(plan.transitions[2].t_s, 2.0);
        assert_eq!(plan.boundaries(), vec![1.0, 2.0]);
    }

    /// ISSUE 9 regression: a forged schedule carrying a NaN transition
    /// instant must not panic the sort inside [`DvfsSchedule::new`] or
    /// [`DvfsSchedule::boundaries`] — NaN orders last under
    /// `f64::total_cmp`, the finite prefix stays ascending, and
    /// `validate` is still the place that rejects it with a clean `Err`.
    #[test]
    fn forged_nan_schedule_sorts_instead_of_panicking() {
        let s = soc();
        let forged = DvfsSchedule::new(
            vec![4, 4],
            vec![
                Transition { t_s: f64::NAN, cluster: BIG, opp: 1 },
                Transition { t_s: 2.0, cluster: LITTLE, opp: 2 },
                Transition { t_s: 1.0, cluster: BIG, opp: 0 },
            ],
        );
        // Finite instants first (ascending), the NaN parked at the end.
        assert_eq!(forged.transitions[0].t_s, 1.0);
        assert_eq!(forged.transitions[1].t_s, 2.0);
        assert!(forged.transitions[2].t_s.is_nan());
        // `boundaries` filters on `t > 0.0`, which a NaN instant fails:
        // the forged entry drops out instead of poisoning the epochs.
        assert_eq!(forged.boundaries(), vec![1.0, 2.0]);
        // The replay gate still refuses the forged plan cleanly.
        assert!(forged.validate(&s).is_err());
    }

    #[test]
    fn governor_parser() {
        assert_eq!(parse_governor("performance").unwrap().name(), "performance");
        assert_eq!(parse_governor("powersave").unwrap().name(), "powersave");
        assert_eq!(parse_governor("ondemand").unwrap().name(), "ondemand");
        assert_eq!(parse_governor("ondemand:250").unwrap().name(), "ondemand");
        assert!(parse_governor("ondemand:-5").is_err());
        assert!(parse_governor("ondemand:x").is_err());
        assert!(parse_governor("turbo").is_err());
    }

    /// Malformed ondemand periods must come back as clean `Err`s, never
    /// reach the `assert!` in `Ondemand::new` — the NaN/inf/-0/empty
    /// fuzz set from the closed-loop hardening pass.
    #[test]
    fn governor_parser_rejects_malformed_periods() {
        for tok in [
            "ondemand:NaN",
            "ondemand:nan",
            "ondemand:-NaN",
            "ondemand:inf",
            "ondemand:+inf",
            "ondemand:-inf",
            "ondemand:infinity",
            "ondemand:-0",
            "ondemand:-0.0",
            "ondemand:0",
            "ondemand:0.0",
            "ondemand:",
            "ondemand: 250",
            "ondemand:1e999",
        ] {
            let r = parse_governor(tok);
            assert!(r.is_err(), "'{tok}' must be rejected cleanly");
        }
        // And the surviving boundary cases still parse.
        assert_eq!(parse_governor("ondemand:0.001").unwrap().name(), "ondemand");
        assert_eq!(parse_governor("ondemand:1e3").unwrap().name(), "ondemand");
    }

    /// A degenerate single-rung ladder must neither underflow the
    /// `len() - 1` indexing nor emit spurious transitions under any
    /// governor, open- or closed-loop.
    #[test]
    fn single_rung_ladders_plan_no_transitions() {
        let s = SocSpec::symmetric(2);
        let single: Vec<usize> = s.clusters.iter().map(|_| 0).collect();
        let mut frozen = s.clone();
        for c in &mut frozen.clusters {
            c.opps = OppTable::single(c.core.freq_ghz);
        }
        let govs: [Box<dyn Governor>; 3] = [
            Box::new(Performance),
            Box::new(Powersave),
            Box::new(Ondemand::default()),
        ];
        for gov in &govs {
            let plan = gov.plan(&frozen, 10.0);
            plan.validate(&frozen).unwrap();
            assert!(plan.is_static(), "{} emitted transitions on a 1-rung ladder", gov.name());
            assert_eq!(plan.initial, single);
            let saturated = LoadSignal::constant(0.5, frozen.num_clusters(), 8, 1.0);
            let closed = gov.plan_closed_loop(&frozen, &saturated);
            closed.validate(&frozen).unwrap();
            assert!(closed.is_static(), "{} closed loop on a 1-rung ladder", gov.name());
        }
    }

    /// Degeneracy anchor: a saturating constant load reproduces the
    /// open-loop time ramp bit for bit (same transitions, same f64
    /// instants), because "always above the up-threshold" is exactly
    /// the assumption the open-loop plan hard-codes.
    #[test]
    fn saturating_load_reproduces_open_loop_ramp_bit_for_bit() {
        for s in [soc(), SocSpec::juno_r0(), SocSpec::dynamiq_3c()] {
            let gov = Ondemand::new(0.5);
            let sat = LoadSignal::constant(gov.period_s, s.num_clusters(), 10, 1.0);
            let open = gov.plan(&s, sat.horizon_s());
            let closed = gov.plan_closed_loop(&s, &sat);
            assert_eq!(closed, open, "{}", s.name);
        }
    }

    /// Degeneracy anchor: zero load never leaves the bottom rung — the
    /// closed loop plans exactly the powersave pin.
    #[test]
    fn zero_load_stays_pinned_at_bottom_rung() {
        let s = soc();
        let gov = Ondemand::new(0.5);
        let idle = LoadSignal::constant(gov.period_s, s.num_clusters(), 10, 0.0);
        let plan = gov.plan_closed_loop(&s, &idle);
        assert!(plan.is_static());
        assert_eq!(plan, Powersave.plan(&s, idle.horizon_s()));
        for t in [0.0, 1.0, 4.9] {
            assert_eq!(plan.opp_at(BIG, t), 0);
            assert_eq!(plan.opp_at(LITTLE, t), 0);
        }
    }

    /// The feedback law proper: ramp up while saturated, hold in the
    /// hysteresis band, drop to the bottom once idle.
    #[test]
    fn closed_loop_steps_down_when_idle() {
        let s = soc();
        let gov = Ondemand::new(0.5);
        // Saturated for 3 periods, half-loaded for one, then idle.
        let mut rows = vec![vec![1.0; 2]; 3];
        rows.push(vec![0.5; 2]);
        rows.extend(vec![vec![0.0; 2]; 3]);
        let sig = LoadSignal::new(0.5, rows);
        let plan = gov.plan_closed_loop(&s, &sig);
        plan.validate(&s).unwrap();
        // Up-steps at 0.5/1.0/1.5; hold through the 0.5-util period;
        // down to rung 0 at 2.5.
        assert_eq!(plan.opp_at(BIG, 0.4), 0);
        assert_eq!(plan.opp_at(BIG, 1.6), 3);
        assert_eq!(plan.opp_at(BIG, 2.4), 3, "hysteresis band holds the rung");
        assert_eq!(plan.opp_at(BIG, 2.5), 0, "idle cluster drops to the bottom");
        assert_eq!(plan.opp_at(LITTLE, 9.0), 0);
        // Exactly 3 up-steps + 1 down-step per cluster.
        assert_eq!(plan.transitions.len(), 8);
    }

    /// The default governors ignore feedback: closed-loop planning on a
    /// pinned policy is its open-loop plan.
    #[test]
    fn pinned_governors_are_load_independent() {
        let s = soc();
        let sig = LoadSignal::constant(0.5, s.num_clusters(), 6, 0.9);
        assert_eq!(Performance.plan_closed_loop(&s, &sig), Performance.plan(&s, 3.0));
        assert_eq!(Powersave.plan_closed_loop(&s, &sig), Powersave.plan(&s, 3.0));
    }

    /// NaN transition times no longer panic the constructor's sort;
    /// they sort last and are rejected by `validate` instead.
    #[test]
    fn nan_transition_times_sort_without_panicking() {
        let s = soc();
        let plan = DvfsSchedule::new(
            vec![4, 4],
            vec![
                Transition { t_s: f64::NAN, cluster: BIG, opp: 0 },
                Transition { t_s: 1.0, cluster: LITTLE, opp: 1 },
            ],
        );
        assert_eq!(plan.transitions[0].t_s, 1.0, "NaN sorts last under total_cmp");
        assert!(plan.validate(&s).is_err(), "validate rejects the NaN instant");
    }

    #[test]
    fn load_signal_shapes() {
        let sig = LoadSignal::from_busy_until(0.5, &[1.2, 0.3]);
        // ceil(1.2/0.5) + 1 = 4 rows.
        assert_eq!(sig.samples.len(), 4);
        assert_eq!(sig.horizon_s(), 2.0);
        assert_eq!(sig.samples[0], vec![1.0, 0.6]);
        assert_eq!(sig.samples[2], vec![0.4, 0.0]);
        assert_eq!(sig.samples[3], vec![0.0, 0.0]);
        let flat = LoadSignal::constant(0.25, 3, 4, 0.5).with_queue_depth(vec![1.0; 4]);
        assert_eq!(flat.queue_depth.len(), 4);
        assert_eq!(flat.horizon_s(), 1.0);
    }

    #[test]
    fn weights_at_handles_any_topology() {
        for s in [SocSpec::dynamiq_3c(), SocSpec::symmetric(4), SocSpec::juno_r0()] {
            let plan = Ondemand::default().plan(&s, 10.0);
            plan.validate(&s).unwrap();
            for t in [0.0, 0.7, 2.0, 50.0] {
                let w = plan.weights_at(&s, t, true);
                assert_eq!(w.len(), s.num_clusters());
                let sum: f64 = w.as_slice().iter().sum();
                assert!((sum - 1.0).abs() < 1e-9, "{}: sum {sum}", s.name);
                assert!(w.as_slice().iter().all(|x| x.is_finite() && *x > 0.0));
            }
        }
    }
}
