//! DVFS operating-point schedules with online weight retuning.
//!
//! The paper tunes and schedules GEMM for one fixed frequency pair on
//! the Exynos 5422, but a deployed big.LITTLE SoC runs under a governor
//! that moves each cluster through its operating points — and the
//! scheduler/governor interplay is exactly where asymmetric gains are
//! won or lost (arXiv:1509.02058), while the perf/energy optimum shifts
//! with the voltage-frequency point (arXiv:1507.05129). This layer
//! (DESIGN.md §4) adds that axis on top of the N-cluster descriptor:
//!
//! * every [`crate::soc::ClusterSpec`] carries an OPP ladder
//!   ([`OppTable`]; the paper presets get the Exynos A15/A7 `cpufreq`
//!   tables capped at the §3.2 operating point);
//! * a [`Governor`] plans a [`DvfsSchedule`] — timed per-cluster OPP
//!   transitions in *virtual* time — with `performance`, `powersave`
//!   and `ondemand`-style policies;
//! * [`DvfsSchedule::soc_at`] derives the descriptor in effect at any
//!   instant (frequency from the ladder, power rails scaled by the CMOS
//!   `f·V²` law), and [`DvfsSchedule::weights_at`] recomputes the
//!   normalized [`Weights`] vector there — the *online retuning*
//!   primitive: the first place in this codebase where the weight
//!   vector is a function of time rather than a constant;
//! * [`sim`] replays a schedule through the calibrated engine,
//!   repartitioning SAS shares at every transition (online) or keeping
//!   the stale boot-time split (the baseline it must beat).

pub mod sim;

pub use crate::soc::{OperatingPoint, OppTable};

use crate::model::PerfModel;
use crate::sched::Weights;
use crate::soc::{ClusterId, SocSpec};

/// One timed OPP switch: at virtual instant `t_s`, `cluster` moves to
/// ladder rung `opp`.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct Transition {
    pub t_s: f64,
    pub cluster: ClusterId,
    pub opp: usize,
}

/// A replayable plan of per-cluster operating points over virtual time:
/// an initial OPP per cluster plus a time-sorted list of transitions.
/// Governors produce these; the DVFS engine ([`sim::simulate_dvfs`])
/// and the fleet simulator replay them.
#[derive(Debug, Clone, PartialEq)]
pub struct DvfsSchedule {
    /// Initial ladder rung per cluster, in [`ClusterId`] order.
    pub initial: Vec<usize>,
    /// Transitions sorted by time (ties by cluster id).
    pub transitions: Vec<Transition>,
}

impl DvfsSchedule {
    /// Build from raw parts; transitions are sorted into replay order.
    pub fn new(initial: Vec<usize>, mut transitions: Vec<Transition>) -> Self {
        transitions.sort_by(|a, b| {
            a.t_s
                .partial_cmp(&b.t_s)
                .expect("transition times must be comparable")
                .then(a.cluster.cmp(&b.cluster))
        });
        DvfsSchedule { initial, transitions }
    }

    /// Every cluster pinned at its nominal (boot) rung forever — the
    /// schedule under which the DVFS path is provably a no-op.
    pub fn nominal(soc: &SocSpec) -> Self {
        DvfsSchedule::new(
            soc.clusters.iter().map(|c| c.opps.nominal_idx()).collect(),
            Vec::new(),
        )
    }

    /// Every cluster pinned at the given rungs (no transitions).
    pub fn pinned(opps: &[usize]) -> Self {
        DvfsSchedule::new(opps.to_vec(), Vec::new())
    }

    /// Check the plan against a topology: one initial rung per cluster,
    /// every rung inside its ladder, times finite and non-negative.
    pub fn validate(&self, soc: &SocSpec) -> Result<(), String> {
        if self.initial.len() != soc.num_clusters() {
            return Err(format!(
                "schedule has {} initial OPPs but '{}' has {} clusters",
                self.initial.len(),
                soc.name,
                soc.num_clusters()
            ));
        }
        for (i, &opp) in self.initial.iter().enumerate() {
            if opp >= soc.clusters[i].opps.len() {
                return Err(format!(
                    "initial OPP {opp} out of range for cluster c{i} \
                     ({} ladder points)",
                    soc.clusters[i].opps.len()
                ));
            }
        }
        for tr in &self.transitions {
            if tr.cluster.0 >= soc.num_clusters() {
                return Err(format!("transition names missing cluster {}", tr.cluster));
            }
            if tr.opp >= soc[tr.cluster].opps.len() {
                return Err(format!(
                    "transition OPP {} out of range for {} ({} ladder points)",
                    tr.opp,
                    tr.cluster,
                    soc[tr.cluster].opps.len()
                ));
            }
            if !tr.t_s.is_finite() || tr.t_s < 0.0 {
                return Err(format!("transition time must be finite and >= 0, got {}", tr.t_s));
            }
        }
        Ok(())
    }

    /// A schedule with no transitions holds one operating point forever.
    pub fn is_static(&self) -> bool {
        self.transitions.is_empty()
    }

    /// The rung `cluster` runs at instant `t` (transitions at exactly
    /// `t` have already fired).
    pub fn opp_at(&self, cluster: ClusterId, t: f64) -> usize {
        let mut opp = self.initial[cluster.0];
        for tr in &self.transitions {
            if tr.t_s > t {
                break;
            }
            if tr.cluster == cluster {
                opp = tr.opp;
            }
        }
        opp
    }

    /// Distinct future transition instants, ascending (t = 0 switches
    /// are folded into the initial state by [`DvfsSchedule::opp_at`]).
    pub fn boundaries(&self) -> Vec<f64> {
        let mut ts: Vec<f64> = self
            .transitions
            .iter()
            .map(|tr| tr.t_s)
            .filter(|&t| t > 0.0)
            .collect();
        ts.sort_by(|a, b| a.partial_cmp(b).expect("finite times"));
        ts.dedup();
        ts
    }

    /// The descriptor in effect at instant `t`: every cluster moved to
    /// its scheduled rung via [`SocSpec::at_opp`]. At the nominal rung
    /// this is bit-for-bit `base`.
    pub fn soc_at(&self, base: &SocSpec, t: f64) -> SocSpec {
        let mut soc = base.clone();
        for c in base.cluster_ids() {
            soc = soc.at_opp(c, self.opp_at(c, t));
        }
        soc
    }

    /// The *online-retuned* weight vector at instant `t`: the
    /// analytical model's per-cluster throughputs under the descriptor
    /// in effect, normalized to shares. With a static schedule this is
    /// exactly the boot-time static vector — the degenerate-case
    /// property the tests pin.
    pub fn weights_at(&self, base: &SocSpec, t: f64, cache_aware: bool) -> Weights {
        PerfModel::new(self.soc_at(base, t))
            .auto_weights(cache_aware)
            .normalized()
    }
}

/// A DVFS policy: plans a [`DvfsSchedule`] over a virtual-time horizon
/// for a given topology — the simulated counterpart of a `cpufreq`
/// governor (arXiv:1509.02058's scheduler/governor interplay).
pub trait Governor {
    fn name(&self) -> &'static str;
    /// Plan per-cluster OPP transitions over `[0, horizon_s)`.
    fn plan(&self, soc: &SocSpec, horizon_s: f64) -> DvfsSchedule;
}

/// Pin every cluster at the ladder top (= the nominal rung for every
/// preset): the schedule is static and the descriptor identical to the
/// boot descriptor, so results reproduce the fixed-frequency pins
/// bit-for-bit.
#[derive(Debug, Clone, Copy, Default)]
pub struct Performance;

impl Governor for Performance {
    fn name(&self) -> &'static str {
        "performance"
    }
    fn plan(&self, soc: &SocSpec, _horizon_s: f64) -> DvfsSchedule {
        DvfsSchedule::pinned(
            &soc.clusters
                .iter()
                .map(|c| c.opps.len() - 1)
                .collect::<Vec<_>>(),
        )
    }
}

/// Pin every cluster at the ladder bottom: slowest, lowest-voltage
/// point — the energy-to-solution end of the Pareto frontier.
#[derive(Debug, Clone, Copy, Default)]
pub struct Powersave;

impl Governor for Powersave {
    fn name(&self) -> &'static str {
        "powersave"
    }
    fn plan(&self, soc: &SocSpec, _horizon_s: f64) -> DvfsSchedule {
        DvfsSchedule::pinned(&vec![0; soc.num_clusters()])
    }
}

/// `ondemand`-style ramp driven by virtual time: a compute-bound GEMM
/// pins utilization at 100 %, so the governor walks each cluster up one
/// rung per sampling period from the bottom until the ladder top.
/// Because the A15 and A7 ladders scale differently rung-by-rung, the
/// per-cluster throughput *ratio* shifts at every step — exactly the
/// situation where stale boot-time SAS weights go wrong.
#[derive(Debug, Clone, Copy)]
pub struct Ondemand {
    /// Governor sampling period (virtual seconds per rung).
    pub period_s: f64,
}

impl Ondemand {
    pub fn new(period_s: f64) -> Self {
        assert!(
            period_s.is_finite() && period_s > 0.0,
            "ondemand period must be positive, got {period_s}"
        );
        Ondemand { period_s }
    }
}

impl Default for Ondemand {
    fn default() -> Self {
        Ondemand::new(0.5)
    }
}

impl Governor for Ondemand {
    fn name(&self) -> &'static str {
        "ondemand"
    }
    fn plan(&self, soc: &SocSpec, horizon_s: f64) -> DvfsSchedule {
        let mut transitions = Vec::new();
        for c in soc.cluster_ids() {
            for rung in 1..soc[c].opps.len() {
                let t = rung as f64 * self.period_s;
                if t >= horizon_s {
                    break;
                }
                transitions.push(Transition { t_s: t, cluster: c, opp: rung });
            }
        }
        DvfsSchedule::new(vec![0; soc.num_clusters()], transitions)
    }
}

/// Parse a governor token: `performance`, `powersave`,
/// `ondemand[:PERIOD_MS]`.
pub fn parse_governor(s: &str) -> Result<Box<dyn Governor>, String> {
    match s {
        "performance" => Ok(Box::new(Performance)),
        "powersave" => Ok(Box::new(Powersave)),
        "ondemand" => Ok(Box::new(Ondemand::default())),
        other => match other.strip_prefix("ondemand:") {
            Some(ms) => {
                let ms: f64 = ms
                    .parse()
                    .map_err(|_| format!("bad ondemand period '{ms}' (milliseconds)"))?;
                if !ms.is_finite() || ms <= 0.0 {
                    return Err(format!("ondemand period must be positive, got {ms} ms"));
                }
                Ok(Box::new(Ondemand::new(ms / 1e3)))
            }
            None => Err(format!(
                "unknown governor '{other}' (performance|powersave|ondemand[:ms])"
            )),
        },
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::soc::{BIG, LITTLE};

    fn soc() -> SocSpec {
        SocSpec::exynos5422()
    }

    #[test]
    fn nominal_schedule_is_identity() {
        let s = soc();
        let plan = DvfsSchedule::nominal(&s);
        assert!(plan.is_static());
        plan.validate(&s).unwrap();
        assert_eq!(plan.soc_at(&s, 0.0), s);
        assert_eq!(plan.soc_at(&s, 123.0), s);
        assert_eq!(plan.opp_at(BIG, 5.0), 4);
    }

    #[test]
    fn performance_governor_pins_nominal() {
        let s = soc();
        let plan = Performance.plan(&s, 10.0);
        assert!(plan.is_static());
        assert_eq!(plan, DvfsSchedule::nominal(&s));
        assert_eq!(plan.soc_at(&s, 3.0), s);
    }

    #[test]
    fn powersave_governor_pins_bottom() {
        let s = soc();
        let plan = Powersave.plan(&s, 10.0);
        assert!(plan.is_static());
        let low = plan.soc_at(&s, 0.0);
        assert_eq!(low[BIG].core.freq_ghz, 0.8);
        assert_eq!(low[LITTLE].core.freq_ghz, 0.5);
        assert!(low[BIG].tuning.p_core_active_w < s[BIG].tuning.p_core_active_w);
    }

    #[test]
    fn ondemand_ramps_one_rung_per_period() {
        let s = soc();
        let plan = Ondemand::new(0.5).plan(&s, 10.0);
        plan.validate(&s).unwrap();
        assert!(!plan.is_static());
        // 4 upward steps per cluster, shared instants.
        assert_eq!(plan.transitions.len(), 8);
        assert_eq!(plan.boundaries(), vec![0.5, 1.0, 1.5, 2.0]);
        assert_eq!(plan.opp_at(BIG, 0.0), 0);
        assert_eq!(plan.opp_at(BIG, 0.5), 1, "transition at exactly t has fired");
        assert_eq!(plan.opp_at(BIG, 0.49), 0);
        assert_eq!(plan.opp_at(LITTLE, 9.0), 4);
        // Mid-ramp descriptor: big at rung 2 (1.2 GHz), little at 1.0.
        let mid = plan.soc_at(&s, 1.2);
        assert_eq!(mid[BIG].core.freq_ghz, 1.2);
        assert_eq!(mid[LITTLE].core.freq_ghz, 1.0);
        // A short horizon truncates the ramp.
        let short = Ondemand::new(0.5).plan(&s, 1.2);
        assert_eq!(short.boundaries(), vec![0.5, 1.0]);
    }

    #[test]
    fn retuned_weights_shift_along_the_ramp() {
        let s = soc();
        let plan = Ondemand::new(0.5).plan(&s, 10.0);
        let boot = plan.weights_at(&s, 0.0, true);
        let end = plan.weights_at(&s, 9.0, true);
        let sum: f64 = boot.as_slice().iter().sum();
        assert!((sum - 1.0).abs() < 1e-9, "normalized sum {sum}");
        // At the bottom rungs the big cluster's frequency advantage is
        // larger (0.8 vs 0.5 GHz = 1.6x, against 1.6 vs 1.4 = 1.14x at
        // the top), so its share must shrink as the ramp completes.
        assert!(
            boot.share(0) > end.share(0) + 0.01,
            "boot big share {} vs end {}",
            boot.share(0),
            end.share(0)
        );
        // And the end-of-ramp weights are exactly the static ones.
        let statics = PerfModel::new(s.clone()).auto_weights(true).normalized();
        assert_eq!(end.as_slice(), statics.as_slice());
    }

    #[test]
    fn schedule_validation_catches_bad_plans() {
        let s = soc();
        assert!(DvfsSchedule::pinned(&[0]).validate(&s).is_err(), "wrong arity");
        assert!(DvfsSchedule::pinned(&[0, 9]).validate(&s).is_err(), "bad rung");
        let bad_cluster = DvfsSchedule::new(
            vec![4, 4],
            vec![Transition { t_s: 1.0, cluster: ClusterId(7), opp: 0 }],
        );
        assert!(bad_cluster.validate(&s).is_err());
        let bad_time = DvfsSchedule::new(
            vec![4, 4],
            vec![Transition { t_s: -1.0, cluster: BIG, opp: 0 }],
        );
        assert!(bad_time.validate(&s).is_err());
        let bad_rung = DvfsSchedule::new(
            vec![4, 4],
            vec![Transition { t_s: 1.0, cluster: BIG, opp: 17 }],
        );
        assert!(bad_rung.validate(&s).is_err());
    }

    #[test]
    fn transitions_sort_into_replay_order() {
        let plan = DvfsSchedule::new(
            vec![0, 0],
            vec![
                Transition { t_s: 2.0, cluster: BIG, opp: 2 },
                Transition { t_s: 1.0, cluster: LITTLE, opp: 1 },
                Transition { t_s: 1.0, cluster: BIG, opp: 1 },
            ],
        );
        assert_eq!(plan.transitions[0].t_s, 1.0);
        assert_eq!(plan.transitions[0].cluster, BIG);
        assert_eq!(plan.transitions[1].cluster, LITTLE);
        assert_eq!(plan.transitions[2].t_s, 2.0);
        assert_eq!(plan.boundaries(), vec![1.0, 2.0]);
    }

    #[test]
    fn governor_parser() {
        assert_eq!(parse_governor("performance").unwrap().name(), "performance");
        assert_eq!(parse_governor("powersave").unwrap().name(), "powersave");
        assert_eq!(parse_governor("ondemand").unwrap().name(), "ondemand");
        assert_eq!(parse_governor("ondemand:250").unwrap().name(), "ondemand");
        assert!(parse_governor("ondemand:-5").is_err());
        assert!(parse_governor("ondemand:x").is_err());
        assert!(parse_governor("turbo").is_err());
    }

    #[test]
    fn weights_at_handles_any_topology() {
        for s in [SocSpec::dynamiq_3c(), SocSpec::symmetric(4), SocSpec::juno_r0()] {
            let plan = Ondemand::default().plan(&s, 10.0);
            plan.validate(&s).unwrap();
            for t in [0.0, 0.7, 2.0, 50.0] {
                let w = plan.weights_at(&s, t, true);
                assert_eq!(w.len(), s.num_clusters());
                let sum: f64 = w.as_slice().iter().sum();
                assert!((sum - 1.0).abs() < 1e-9, "{}: sum {sum}", s.name);
                assert!(w.as_slice().iter().all(|x| x.is_finite() && *x > 0.0));
            }
        }
    }
}
