//! Empirical search for the cache configuration parameters (mc, kc) —
//! the §3.3 experiment behind Fig. 4.
//!
//! The paper fixes `nc = 4096` (no L3 cache), `mr = nr = 4` (the tuned
//! micro-kernel) and sweeps (mc, kc) per cluster, first on a coarse
//! grid to locate the promising region, then on a fine grid inside it.
//! We run the same two-phase protocol against the calibrated performance
//! model (where the paper ran wall-clock GEMMs), and additionally support
//! the §5.3 constrained refit: `kc` pinned to the lead cluster's 952 and
//! only `mc` swept (finding mc ≈ 32 for the Exynos LITTLE cluster).
//! Everything is keyed by [`ClusterId`], so the same search tunes any
//! cluster of any topology — the data-driven path to new presets.

use crate::blis::params::BlisParams;
use crate::model::PerfModel;
use crate::soc::ClusterId;
use crate::util::table::Table;

/// One sampled configuration.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct SearchPoint {
    pub mc: usize,
    pub kc: usize,
    pub gflops: f64,
}

/// Result of a (coarse or fine) sweep.
#[derive(Debug, Clone)]
pub struct SearchResult {
    pub cluster: ClusterId,
    pub points: Vec<SearchPoint>,
    pub best: SearchPoint,
}

impl SearchResult {
    /// Heatmap table (rows = mc, cols = kc) as the paper plots it.
    pub fn to_table(&self, title: &str) -> Table {
        let mut t = Table::new(title, &["mc", "kc", "gflops"]);
        for p in &self.points {
            t.push_row(vec![
                p.mc.to_string(),
                p.kc.to_string(),
                format!("{:.4}", p.gflops),
            ]);
        }
        t
    }
}

/// Rate of a single core with candidate parameters (single-thread, the
/// §3.3 setup).
fn rate(model: &PerfModel, cluster: ClusterId, mc: usize, kc: usize) -> f64 {
    let p = BlisParams::new(4096, kc, mc, 4, 4);
    model.steady_rate_gflops(cluster, &p, 1)
}

fn sweep(
    model: &PerfModel,
    cluster: ClusterId,
    mc_range: (usize, usize, usize),
    kc_range: (usize, usize, usize),
) -> SearchResult {
    let mut points = Vec::new();
    let mut best = SearchPoint { mc: 0, kc: 0, gflops: f64::NEG_INFINITY };
    let mut mc = mc_range.0;
    while mc <= mc_range.1 {
        let mut kc = kc_range.0;
        while kc <= kc_range.1 {
            let g = rate(model, cluster, mc, kc);
            let pt = SearchPoint { mc, kc, gflops: g };
            points.push(pt);
            if g > best.gflops {
                best = pt;
            }
            kc += kc_range.2;
        }
        mc += mc_range.2;
    }
    SearchResult { cluster, points, best }
}

/// Coarse sweep over the full plausible region (§3.3's first phase).
pub fn coarse_search(model: &PerfModel, cluster: ClusterId) -> SearchResult {
    // mc up to ~400 rows, kc up to the L1 bound neighbourhood.
    sweep(model, cluster, (16, 400, 16), (64, 1024, 32))
}

/// Fine sweep around a coarse optimum (§3.3's second phase).
pub fn fine_search(model: &PerfModel, cluster: ClusterId, around: SearchPoint) -> SearchResult {
    let mc_lo = around.mc.saturating_sub(32).max(4);
    let kc_lo = around.kc.saturating_sub(64).max(8);
    sweep(model, cluster, (mc_lo, around.mc + 32, 4), (kc_lo, around.kc + 64, 8))
}

/// Full two-phase search: coarse → fine, as in Fig. 4.
pub fn two_phase_search(model: &PerfModel, cluster: ClusterId) -> (SearchResult, SearchResult) {
    let coarse = coarse_search(model, cluster);
    let fine = fine_search(model, cluster, coarse.best);
    (coarse, fine)
}

/// §5.3 constrained refit: kc pinned (shared `Bc`), sweep mc only.
pub fn shared_kc_refit(model: &PerfModel, cluster: ClusterId, kc: usize) -> SearchResult {
    let mut points = Vec::new();
    let mut best = SearchPoint { mc: 0, kc, gflops: f64::NEG_INFINITY };
    let mut mc = 4;
    while mc <= 160 {
        let g = rate(model, cluster, mc, kc);
        let pt = SearchPoint { mc, kc, gflops: g };
        points.push(pt);
        if g > best.gflops {
            best = pt;
        }
        mc += 4;
    }
    SearchResult { cluster, points, best }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::soc::{SocSpec, BIG, LITTLE};

    fn model() -> PerfModel {
        PerfModel::exynos()
    }

    /// Fig. 4: the A15 optimum lands near the paper's (152, 952).
    #[test]
    fn a15_optimum_near_paper() {
        let (_, fine) = two_phase_search(&model(), BIG);
        let b = fine.best;
        assert!(
            (136..=168).contains(&b.mc) && (888..=1000).contains(&b.kc),
            "A15 optimum ({}, {})",
            b.mc,
            b.kc
        );
        assert!((2.7..3.0).contains(&b.gflops), "gflops {}", b.gflops);
    }

    /// Fig. 4: the A7 optimum lands near the paper's (80, 352).
    #[test]
    fn a7_optimum_near_paper() {
        let (_, fine) = two_phase_search(&model(), LITTLE);
        let b = fine.best;
        assert!(
            (64..=96).contains(&b.mc) && (320..=390).contains(&b.kc),
            "A7 optimum ({}, {})",
            b.mc,
            b.kc
        );
    }

    /// §5.3: with kc pinned to 952, the A7's best mc collapses to ≈ 32.
    #[test]
    fn shared_kc_refit_near_mc32() {
        let r = shared_kc_refit(&model(), LITTLE, 952);
        assert!(
            (24..=40).contains(&r.best.mc),
            "shared-kc refit mc {}",
            r.best.mc
        );
        // And it is worse than the unconstrained optimum but better than
        // the oblivious A15 parameters (§5.3's observation).
        let opt = rate(&model(), LITTLE, 80, 352);
        let oblivious = rate(&model(), LITTLE, 152, 952);
        assert!(r.best.gflops < opt);
        assert!(r.best.gflops > oblivious);
    }

    #[test]
    fn coarse_grid_covers_paper_region() {
        let c = coarse_search(&model(), BIG);
        assert!(c.points.len() > 500);
        assert!(c.points.iter().any(|p| p.mc == 144 && p.kc == 928));
    }

    #[test]
    fn fine_search_refines_coarse() {
        let (coarse, fine) = two_phase_search(&model(), LITTLE);
        assert!(fine.best.gflops >= coarse.best.gflops - 1e-12);
    }

    #[test]
    fn heatmap_table_shape() {
        let c = shared_kc_refit(&model(), LITTLE, 952);
        let t = c.to_table("refit");
        assert_eq!(t.columns, vec!["mc", "kc", "gflops"]);
        assert_eq!(t.rows.len(), c.points.len());
    }

    #[test]
    fn big_outperforms_little_everywhere() {
        let m = model();
        for &(mc, kc) in &[(80usize, 352usize), (152, 952), (32, 952)] {
            assert!(rate(&m, BIG, mc, kc) > rate(&m, LITTLE, mc, kc));
        }
    }

    /// The same machinery tunes every cluster of a tri-cluster topology:
    /// the mid cluster's optimum sits between the big and LITTLE ones,
    /// tracking its 1 MiB L2.
    #[test]
    fn tri_cluster_per_cluster_optima_ordered() {
        let tri = PerfModel::new(SocSpec::dynamiq_3c());
        let mut acs = Vec::new();
        for c in tri.soc.cluster_ids() {
            let (_, fine) = two_phase_search(&tri, c);
            acs.push(fine.best.mc * fine.best.kc);
        }
        assert!(
            acs[0] > acs[1] && acs[1] > acs[2],
            "Ac footprints must track L2 sizes: {acs:?}"
        );
    }
}
