//! Empirical search for the cache configuration parameters (mc, kc) —
//! the §3.3 experiment behind Fig. 4.
//!
//! The paper fixes `nc = 4096` (no L3 cache), `mr = nr = 4` (the tuned
//! micro-kernel) and sweeps (mc, kc) per cluster, first on a coarse
//! grid to locate the promising region, then on a fine grid inside it.
//! We run the same two-phase protocol against the calibrated performance
//! model (where the paper ran wall-clock GEMMs), and additionally support
//! the §5.3 constrained refit: `kc` pinned to the lead cluster's 952 and
//! only `mc` swept (finding mc ≈ 32 for the Exynos LITTLE cluster).
//! Everything is keyed by [`ClusterId`], so the same search tunes any
//! cluster of any topology — the data-driven path to new presets.

use crate::blis::params::BlisParams;
use crate::model::PerfModel;
use crate::soc::{ClusterId, SocSpec};
use crate::util::table::Table;

/// One sampled configuration.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct SearchPoint {
    pub mc: usize,
    pub kc: usize,
    pub gflops: f64,
}

/// Result of a (coarse or fine) sweep.
#[derive(Debug, Clone)]
pub struct SearchResult {
    pub cluster: ClusterId,
    pub points: Vec<SearchPoint>,
    pub best: SearchPoint,
}

impl SearchResult {
    /// Heatmap table (rows = mc, cols = kc) as the paper plots it.
    pub fn to_table(&self, title: &str) -> Table {
        let mut t = Table::new(title, &["mc", "kc", "gflops"]);
        for p in &self.points {
            t.push_row(vec![
                p.mc.to_string(),
                p.kc.to_string(),
                format!("{:.4}", p.gflops),
            ]);
        }
        t
    }
}

/// Rate of a single core with candidate parameters (single-thread, the
/// §3.3 setup).
fn rate(model: &PerfModel, cluster: ClusterId, mc: usize, kc: usize) -> f64 {
    let p = BlisParams::new(4096, kc, mc, 4, 4);
    model.steady_rate_gflops(cluster, &p, 1)
}

fn sweep(
    model: &PerfModel,
    cluster: ClusterId,
    mc_range: (usize, usize, usize),
    kc_range: (usize, usize, usize),
) -> SearchResult {
    let mut points = Vec::new();
    let mut best = SearchPoint { mc: 0, kc: 0, gflops: f64::NEG_INFINITY };
    let mut mc = mc_range.0;
    while mc <= mc_range.1 {
        let mut kc = kc_range.0;
        while kc <= kc_range.1 {
            let g = rate(model, cluster, mc, kc);
            let pt = SearchPoint { mc, kc, gflops: g };
            points.push(pt);
            if g > best.gflops {
                best = pt;
            }
            kc += kc_range.2;
        }
        mc += mc_range.2;
    }
    SearchResult { cluster, points, best }
}

/// Coarse sweep over the full plausible region (§3.3's first phase).
pub fn coarse_search(model: &PerfModel, cluster: ClusterId) -> SearchResult {
    // mc up to ~400 rows, kc up to the L1 bound neighbourhood.
    sweep(model, cluster, (16, 400, 16), (64, 1024, 32))
}

/// Fine sweep around a coarse optimum (§3.3's second phase).
pub fn fine_search(model: &PerfModel, cluster: ClusterId, around: SearchPoint) -> SearchResult {
    let mc_lo = around.mc.saturating_sub(32).max(4);
    let kc_lo = around.kc.saturating_sub(64).max(8);
    sweep(model, cluster, (mc_lo, around.mc + 32, 4), (kc_lo, around.kc + 64, 8))
}

/// Full two-phase search: coarse → fine, as in Fig. 4.
pub fn two_phase_search(model: &PerfModel, cluster: ClusterId) -> (SearchResult, SearchResult) {
    let coarse = coarse_search(model, cluster);
    let fine = fine_search(model, cluster, coarse.best);
    (coarse, fine)
}

/// One OPP ladder rung's tuned optimum: the §3.3 search repeated at a
/// DVFS operating point (`crate::dvfs`).
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct OppPreset {
    /// Ladder rung index.
    pub opp: usize,
    pub freq_ghz: f64,
    pub mc: usize,
    pub kc: usize,
    /// Analytical search score of the optimum (single-core steady rate).
    pub gflops: f64,
    /// Measured cluster-aggregate GFLOPS at this rung, per shape class
    /// (`[small, medium, large]`, see `crate::calibrate::ShapeClass`):
    /// the empirical counterpart of `gflops`, filled by
    /// `OppPresetStore::tune_measured`. `None` for analytical-only
    /// stores — the pre-calibration TSV rows parse unchanged.
    pub measured: Option<[f64; 3]>,
}

/// The full two-phase search run at every rung of one cluster's OPP
/// ladder — the data-driven path to per-operating-point presets. (In
/// the analytical model the cache terms are frequency-independent, so
/// the *location* of the optimum is stable across rungs while the rate
/// scales with the clock; the sweep both verifies that and records the
/// per-rung rates the capacity planner and Pareto report consume.)
pub fn tune_opp_ladder(soc: &SocSpec, cluster: ClusterId) -> Vec<OppPreset> {
    (0..soc[cluster].opps.len())
        .map(|opp| {
            let model = PerfModel::new(soc.at_opp(cluster, opp));
            let (_, fine) = two_phase_search(&model, cluster);
            OppPreset {
                opp,
                freq_ghz: soc[cluster].opps.get(opp).freq_ghz,
                mc: fine.best.mc,
                kc: fine.best.kc,
                gflops: fine.best.gflops,
                measured: None,
            }
        })
        .collect()
}

/// Persisted per-OPP tuned presets for one cluster of one SoC: a small
/// line-oriented format (`# soc<TAB>cluster` header, then
/// `opp<TAB>freq<TAB>mc<TAB>kc<TAB>gflops` rows — measured stores
/// append the three shape-classed rates for 8 fields total) that
/// round-trips exactly through f64's shortest-repr `Display`. Plain
/// 5-field rows keep parsing unchanged, so pre-calibration preset files
/// stay readable.
#[derive(Debug, Clone, PartialEq)]
pub struct OppPresetStore {
    pub soc: String,
    pub cluster: ClusterId,
    pub presets: Vec<OppPreset>,
}

impl OppPresetStore {
    /// Run the per-OPP sweep for `cluster` and package it for saving.
    pub fn tune(soc: &SocSpec, cluster: ClusterId) -> OppPresetStore {
        OppPresetStore {
            soc: soc.name.clone(),
            cluster,
            presets: tune_opp_ladder(soc, cluster),
        }
    }

    pub fn to_text(&self) -> String {
        let mut out = format!("# {}\t{}\n", self.soc, self.cluster.0);
        for p in &self.presets {
            out.push_str(&format!(
                "{}\t{}\t{}\t{}\t{}",
                p.opp, p.freq_ghz, p.mc, p.kc, p.gflops
            ));
            if let Some(m) = p.measured {
                out.push_str(&format!("\t{}\t{}\t{}", m[0], m[1], m[2]));
            }
            out.push('\n');
        }
        out
    }

    pub fn parse_text(s: &str) -> Result<OppPresetStore, String> {
        let mut lines = s.lines();
        let header = lines.next().ok_or("empty preset store")?;
        let header = header
            .strip_prefix("# ")
            .ok_or_else(|| format!("bad header '{header}'"))?;
        let (soc, cluster) = header
            .split_once('\t')
            .ok_or_else(|| format!("bad header '{header}'"))?;
        let cluster: usize = cluster
            .parse()
            .map_err(|_| format!("bad cluster index '{cluster}'"))?;
        let mut presets = Vec::new();
        for line in lines {
            if line.is_empty() {
                continue;
            }
            let f: Vec<&str> = line.split('\t').collect();
            if f.len() != 5 && f.len() != 8 {
                return Err(format!("bad preset row '{line}'"));
            }
            // Physical quantities share one validator with
            // `calibrate::RateTable::parse_text`: a frequency or a
            // (measured) throughput is positive and finite or the row
            // is corrupt.
            let rate = crate::util::parse_positive_f64;
            let measured = if f.len() == 8 {
                Some([
                    rate(f[5], "rate")?,
                    rate(f[6], "rate")?,
                    rate(f[7], "rate")?,
                ])
            } else {
                None
            };
            presets.push(OppPreset {
                opp: f[0].parse().map_err(|_| format!("bad opp '{}'", f[0]))?,
                freq_ghz: rate(f[1], "freq")?,
                mc: f[2].parse().map_err(|_| format!("bad mc '{}'", f[2]))?,
                kc: f[3].parse().map_err(|_| format!("bad kc '{}'", f[3]))?,
                gflops: rate(f[4], "gflops")?,
                measured,
            });
        }
        Ok(OppPresetStore {
            soc: soc.to_string(),
            cluster: ClusterId(cluster),
            presets,
        })
    }

    pub fn save(&self, path: &std::path::Path) -> std::io::Result<()> {
        if let Some(dir) = path.parent() {
            std::fs::create_dir_all(dir)?;
        }
        std::fs::write(path, self.to_text())
    }

    pub fn load(path: &std::path::Path) -> Result<OppPresetStore, String> {
        let text = std::fs::read_to_string(path).map_err(|e| e.to_string())?;
        OppPresetStore::parse_text(&text)
    }

    /// The tuned preset at one rung.
    pub fn at(&self, opp: usize) -> Option<&OppPreset> {
        self.presets.iter().find(|p| p.opp == opp)
    }
}

/// §5.3 constrained refit: kc pinned (shared `Bc`), sweep mc only.
pub fn shared_kc_refit(model: &PerfModel, cluster: ClusterId, kc: usize) -> SearchResult {
    let mut points = Vec::new();
    let mut best = SearchPoint { mc: 0, kc, gflops: f64::NEG_INFINITY };
    let mut mc = 4;
    while mc <= 160 {
        let g = rate(model, cluster, mc, kc);
        let pt = SearchPoint { mc, kc, gflops: g };
        points.push(pt);
        if g > best.gflops {
            best = pt;
        }
        mc += 4;
    }
    SearchResult { cluster, points, best }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::soc::{SocSpec, BIG, LITTLE};

    fn model() -> PerfModel {
        PerfModel::exynos()
    }

    /// Fig. 4: the A15 optimum lands near the paper's (152, 952).
    #[test]
    fn a15_optimum_near_paper() {
        let (_, fine) = two_phase_search(&model(), BIG);
        let b = fine.best;
        assert!(
            (136..=168).contains(&b.mc) && (888..=1000).contains(&b.kc),
            "A15 optimum ({}, {})",
            b.mc,
            b.kc
        );
        assert!((2.7..3.0).contains(&b.gflops), "gflops {}", b.gflops);
    }

    /// Fig. 4: the A7 optimum lands near the paper's (80, 352).
    #[test]
    fn a7_optimum_near_paper() {
        let (_, fine) = two_phase_search(&model(), LITTLE);
        let b = fine.best;
        assert!(
            (64..=96).contains(&b.mc) && (320..=390).contains(&b.kc),
            "A7 optimum ({}, {})",
            b.mc,
            b.kc
        );
    }

    /// §5.3: with kc pinned to 952, the A7's best mc collapses to ≈ 32.
    #[test]
    fn shared_kc_refit_near_mc32() {
        let r = shared_kc_refit(&model(), LITTLE, 952);
        assert!(
            (24..=40).contains(&r.best.mc),
            "shared-kc refit mc {}",
            r.best.mc
        );
        // And it is worse than the unconstrained optimum but better than
        // the oblivious A15 parameters (§5.3's observation).
        let opt = rate(&model(), LITTLE, 80, 352);
        let oblivious = rate(&model(), LITTLE, 152, 952);
        assert!(r.best.gflops < opt);
        assert!(r.best.gflops > oblivious);
    }

    #[test]
    fn coarse_grid_covers_paper_region() {
        let c = coarse_search(&model(), BIG);
        assert!(c.points.len() > 500);
        assert!(c.points.iter().any(|p| p.mc == 144 && p.kc == 928));
    }

    #[test]
    fn fine_search_refines_coarse() {
        let (coarse, fine) = two_phase_search(&model(), LITTLE);
        assert!(fine.best.gflops >= coarse.best.gflops - 1e-12);
    }

    #[test]
    fn heatmap_table_shape() {
        let c = shared_kc_refit(&model(), LITTLE, 952);
        let t = c.to_table("refit");
        assert_eq!(t.columns, vec!["mc", "kc", "gflops"]);
        assert_eq!(t.rows.len(), c.points.len());
    }

    #[test]
    fn big_outperforms_little_everywhere() {
        let m = model();
        for &(mc, kc) in &[(80usize, 352usize), (152, 952), (32, 952)] {
            assert!(rate(&m, BIG, mc, kc) > rate(&m, LITTLE, mc, kc));
        }
    }

    /// ISSUE 3: the §3.3 search swept per OPP — rates scale with the
    /// clock while the (mc, kc) optimum stays cache-bound, and the
    /// nominal rung reproduces the plain search exactly.
    #[test]
    fn opp_ladder_tuning_tracks_frequency() {
        let soc = SocSpec::exynos5422();
        let ladder = tune_opp_ladder(&soc, BIG);
        assert_eq!(ladder.len(), 5);
        for w in ladder.windows(2) {
            assert!(w[1].gflops > w[0].gflops, "rate must grow with the clock: {ladder:?}");
            assert!(w[1].freq_ghz > w[0].freq_ghz);
        }
        // The nominal rung is the plain fixed-frequency search.
        let (_, fine) = two_phase_search(&model(), BIG);
        let top = ladder.last().unwrap();
        assert_eq!((top.mc, top.kc), (fine.best.mc, fine.best.kc));
        assert_eq!(top.gflops, fine.best.gflops);
        // The cache-bound optimum does not move with the clock.
        for p in &ladder {
            assert_eq!((p.mc, p.kc), (top.mc, top.kc), "optimum drifted: {p:?}");
        }
        // Rate at half clock ≈ half rate (frequency-linear model).
        let rel = ladder[0].gflops / top.gflops;
        assert!((rel - 0.5).abs() < 1e-9, "0.8/1.6 GHz ratio {rel}");
    }

    /// ISSUE 3: per-OPP presets persist and reload exactly.
    #[test]
    fn opp_preset_store_round_trips() {
        let soc = SocSpec::exynos5422();
        let store = OppPresetStore::tune(&soc, LITTLE);
        assert_eq!(store.presets.len(), 5);
        let text = store.to_text();
        let back = OppPresetStore::parse_text(&text).unwrap();
        assert_eq!(back, store, "text round-trip must be exact");
        assert_eq!(back.at(0).unwrap().freq_ghz, 0.5);
        assert!(back.at(9).is_none());

        let dir = std::env::temp_dir().join("amp_gemm_opp_presets");
        let _ = std::fs::remove_dir_all(&dir);
        let path = dir.join("exynos_little.tsv");
        store.save(&path).unwrap();
        let loaded = OppPresetStore::load(&path).unwrap();
        assert_eq!(loaded, store, "file round-trip must be exact");
        let _ = std::fs::remove_dir_all(&dir);

        // Malformed inputs error cleanly.
        assert!(OppPresetStore::parse_text("").is_err());
        assert!(OppPresetStore::parse_text("junk\n1\t2\t3\t4\t5\n").is_err());
        assert!(OppPresetStore::parse_text("# soc\t0\n1\t2\t3\n").is_err());
        assert!(OppPresetStore::load(std::path::Path::new("/nonexistent/x")).is_err());
    }

    /// Measured-rate extension: 8-field rows round-trip with the rates,
    /// 5-field rows stay the pre-calibration format, and mixed stores
    /// are fine line by line.
    #[test]
    fn measured_rows_round_trip_and_plain_rows_stay_compatible() {
        let plain = "# soc\t1\n0\t0.5\t80\t352\t0.31\n";
        let store = OppPresetStore::parse_text(plain).unwrap();
        assert_eq!(store.presets[0].measured, None);
        assert_eq!(store.to_text(), plain, "5-field rows re-emit unchanged");

        let mut measured = store.clone();
        measured.presets[0].measured = Some([0.9, 1.7, 2.25]);
        let text = measured.to_text();
        assert_eq!(text.lines().nth(1).unwrap().split('\t').count(), 8);
        let back = OppPresetStore::parse_text(&text).unwrap();
        assert_eq!(back, measured, "measured round-trip must be exact");

        // Malformed measured rows error cleanly: wrong arity, bad or
        // non-finite rates.
        assert!(OppPresetStore::parse_text("# s\t0\n0\t1\t80\t352\t0.3\t1\t2\n").is_err());
        assert!(OppPresetStore::parse_text("# s\t0\n0\t1\t80\t352\t0.3\t1\t2\t3\t4\n").is_err());
        assert!(OppPresetStore::parse_text("# s\t0\n0\t1\t80\t352\t0.3\tx\t2\t3\n").is_err());
        assert!(OppPresetStore::parse_text("# s\t0\n0\t1\t80\t352\t0.3\tNaN\t2\t3\n").is_err());
        assert!(OppPresetStore::parse_text("# s\t0\n0\t1\t80\t352\t0.3\tinf\t2\t3\n").is_err());
        assert!(OppPresetStore::parse_text("# s\t0\n0\t1\t80\t352\t0.3\t0\t2\t3\n").is_err());
        assert!(OppPresetStore::parse_text("# s\t0\n0\t1\t80\t352\t0.3\t-2\t2\t3\n").is_err());
        // freq and gflops are physical quantities too: same validator.
        assert!(OppPresetStore::parse_text("# s\t0\n0\tNaN\t80\t352\t0.3\n").is_err());
        assert!(OppPresetStore::parse_text("# s\t0\n0\t-1\t80\t352\t0.3\n").is_err());
        assert!(OppPresetStore::parse_text("# s\t0\n0\t1\t80\t352\tNaN\n").is_err());
        assert!(OppPresetStore::parse_text("# s\t0\n0\t1\t80\t352\tinf\n").is_err());
    }

    /// The same machinery tunes every cluster of a tri-cluster topology:
    /// the mid cluster's optimum sits between the big and LITTLE ones,
    /// tracking its 1 MiB L2.
    #[test]
    fn tri_cluster_per_cluster_optima_ordered() {
        let tri = PerfModel::new(SocSpec::dynamiq_3c());
        let mut acs = Vec::new();
        for c in tri.soc.cluster_ids() {
            let (_, fine) = two_phase_search(&tri, c);
            acs.push(fine.best.mc * fine.best.kc);
        }
        assert!(
            acs[0] > acs[1] && acs[1] > acs[2],
            "Ac footprints must track L2 sizes: {acs:?}"
        );
    }
}
