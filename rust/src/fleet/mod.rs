//! Multi-board scale-out: sharding GEMM batches across a rack of
//! heterogeneous SoCs.
//!
//! The paper schedules micro-kernels across asymmetric clusters *inside*
//! one SoC. A rack of heterogeneous boards is the same problem one level
//! up — `cluster : SoC :: board : fleet` — so this layer reuses the
//! intra-SoC scheduling machinery at the inter-device granularity
//! (DESIGN.md §3, "Fleet layer"; the direction of Catalán et al.'s
//! follow-on multi-device work, arXiv:1511.02171):
//!
//! * a [`Board`] wraps one [`SocSpec`] (any preset, so fleets are
//!   heterogeneous by construction), its calibrated
//!   [`crate::model::PerfModel`], the intra-board [`ScheduleSpec`] it
//!   runs, and the [`crate::coordinator::Backend`] engine that executes
//!   requests on it;
//! * a [`Fleet`] is a `Vec<Board>`; its [`Fleet::weights`] vector is
//!   derived from each board's calibrated aggregate throughput via the
//!   [`Weighted`] trait — exactly how `PerfModel::ca_sas_weights`
//!   derives the per-cluster vector one level down;
//! * [`FleetStrategy`] lifts the paper's vocabulary to the board level:
//!   **fleet-SSS** (equal shards — the architecture-oblivious baseline),
//!   **fleet-SAS** (throughput-weighted static shards) and **fleet-DAS**
//!   (a dynamic queue where each board grabs chunks of its own native
//!   batch grain, mirroring how each cluster grabs its own `mc` in
//!   CA-DAS);
//! * [`sim`] executes a fleet strategy in deterministic virtual time for
//!   capacity-planning sweeps; the real request path is
//!   [`crate::coordinator::FleetDispatcher`].

pub mod autoscale;
pub mod sim;

use crate::blis::gemm::GemmShape;
use crate::calibrate::{RateTable, ShapeClass, WeightSource};
use crate::dag::JobSpec;
use crate::model::PerfModel;
use crate::sched::{ScheduleSpec, Weighted, Weights, MAX_WAYS};
use crate::soc::SocSpec;

/// Index of a board within a [`Fleet`] (the board-level analogue of
/// [`crate::soc::ClusterId`]).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash, PartialOrd, Ord)]
pub struct BoardId(pub usize);

impl std::fmt::Display for BoardId {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "b{}", self.0)
    }
}

/// Per-chunk dispatch cost at the board level, seconds of virtual time:
/// the inter-board analogue of `ClusterTuning::grab_s` — an RPC to a
/// board plus a queue pop instead of an in-memory critical section.
/// Charged once per shard under the static strategies and once per grab
/// under fleet-DAS, so the dynamic quantum trades balance against
/// dispatch overhead exactly like `mc` does one level down (§5.4).
pub const DISPATCH_S: f64 = 2.0e-3;

/// One board of the fleet: a SoC descriptor plus the engine that runs
/// GEMMs on it.
#[derive(Debug, Clone)]
pub struct Board {
    /// Short name used in tables and labels (usually the preset token).
    pub name: String,
    /// The intra-board schedule every request runs under (default
    /// CA-DAS — the paper's best).
    pub sched: ScheduleSpec,
    /// Execution engine for the real request path
    /// ([`crate::coordinator::FleetDispatcher`]); the virtual-time
    /// [`sim`] ignores it.
    pub backend: crate::coordinator::Backend,
    /// Where this board's aggregate throughput (its fleet-SAS weight
    /// and fleet-DAS grain) comes from: the analytical model by
    /// default, or a measured [`RateTable`] via [`Board::calibrated`] /
    /// [`Board::with_weight_source`] — which is how calibrated rates
    /// reach the fleet split and the capacity planner
    /// ([`sim::boards_to_sustain`]) without touching either.
    pub weight_source: WeightSource,
    /// Rental price of the board, $/hour — the cost axis the
    /// [`crate::fleet::autoscale::Autoscaler`] optimizes against the
    /// throughput axis (ISSUE 8). Presets carry list prices
    /// ([`Board::from_preset`]); other constructors default to a
    /// peak-proportional formula; [`Board::with_price`] overrides.
    pub price_per_hour: f64,
    model: PerfModel,
}

/// Default $/hour for a descriptor without a preset list price:
/// proportional to ideal aggregate peak, so a board constructed from a
/// raw [`SocSpec`] is never free (which would break every
/// cost-per-throughput comparison) and bigger silicon always rents for
/// more.
fn default_price_per_hour(soc: &SocSpec) -> f64 {
    0.025 * soc.aggregate_peak_gflops()
}

impl Board {
    /// A board executed in virtual time (capacity planning).
    pub fn sim(name: &str, soc: SocSpec) -> Board {
        soc.validate_ladders().expect("board descriptor has a malformed OPP ladder");
        let sched = ScheduleSpec::ca_das();
        let price_per_hour = default_price_per_hour(&soc);
        Board {
            name: name.to_string(),
            sched,
            backend: crate::coordinator::Backend::Sim(sched),
            weight_source: WeightSource::Analytical,
            price_per_hour,
            model: PerfModel::new(soc),
        }
    }

    /// A board executed by the real-thread native engine.
    pub fn native(name: &str, soc: SocSpec) -> Board {
        soc.validate_ladders().expect("board descriptor has a malformed OPP ladder");
        let sched = ScheduleSpec::ca_das();
        let price_per_hour = default_price_per_hour(&soc);
        Board {
            name: name.to_string(),
            sched,
            backend: crate::coordinator::Backend::Native(sched),
            weight_source: WeightSource::Analytical,
            price_per_hour,
            model: PerfModel::new(soc),
        }
    }

    /// Replace the board's weight source (builder style).
    pub fn with_weight_source(mut self, source: WeightSource) -> Board {
        self.weight_source = source;
        self
    }

    /// Replace the board's rental price (builder style).
    pub fn with_price(mut self, price_per_hour: f64) -> Board {
        assert!(
            price_per_hour.is_finite() && price_per_hour > 0.0,
            "board price must be positive and finite, got {price_per_hour}"
        );
        self.price_per_hour = price_per_hour;
        self
    }

    /// Calibrate this board: measure its own descriptor's rate table
    /// (isolated per-cluster DES runs at every rung) and weigh the
    /// board empirically from it.
    pub fn calibrated(self) -> Board {
        let table = RateTable::measure(self.soc(), &[]);
        self.with_weight_source(WeightSource::Empirical(table))
    }

    /// Build a sim board from a preset token (the `--boards` CLI
    /// vocabulary): `exynos5422`, `juno_r0`, `dynamiq_3c`, `pe_hybrid`
    /// or `symmetric<N>` — optionally pinned at a DVFS governor's
    /// operating point with an `@governor` suffix
    /// (`exynos5422@powersave`), which is how fleets become
    /// frequency-heterogeneous: same silicon, different rungs, and the
    /// capacity planner ([`sim::boards_to_sustain`]) prices each
    /// accordingly.
    pub fn from_preset(token: &str) -> Result<Board, String> {
        if let Some((preset, gov)) = token.split_once('@') {
            let board = Board::from_preset(preset)?;
            let gov = crate::dvfs::parse_governor(gov)?;
            // Pin the governor's t = 0 operating point (boards hold one
            // rung per dispatch wave; time-varying board schedules go
            // through `sim::simulate_fleet_dvfs`).
            let plan = gov.plan(board.soc(), 0.0);
            let soc = plan.soc_at(board.soc(), 0.0);
            // Same silicon rents for the same price whatever rung it is
            // pinned at — the rate card prices hardware, not settings.
            let price = board.price_per_hour;
            return Ok(Board::sim(token, soc).with_price(price));
        }
        let soc = match token {
            "exynos5422" | "exynos" => SocSpec::exynos5422(),
            "juno_r0" | "juno" => SocSpec::juno_r0(),
            "dynamiq_3c" | "dynamiq" => SocSpec::dynamiq_3c(),
            "pe_hybrid" => SocSpec::pe_hybrid(),
            other => match other.strip_prefix("symmetric") {
                Some(n) => {
                    let n: usize = n
                        .parse()
                        .map_err(|_| format!("bad symmetric core count in '{other}'"))?;
                    if n == 0 {
                        return Err("symmetric board needs at least one core".into());
                    }
                    SocSpec::symmetric(n)
                }
                None => {
                    return Err(format!(
                        "unknown board preset '{other}' \
                         (exynos5422|juno_r0|dynamiq_3c|pe_hybrid|symmetric<N>)"
                    ))
                }
            },
        };
        // List prices of the rate card, $/hour. Deliberately *not*
        // proportional to throughput: the big Exynos is the best value,
        // the Juno rents at a premium for its modest rate, the little
        // symmetric boards are cheap top-up capacity — the spread that
        // makes cost-aware scaling decisions non-trivial.
        let price = match token {
            "exynos5422" | "exynos" => 0.30,
            "juno_r0" | "juno" => 0.28,
            "dynamiq_3c" | "dynamiq" => 0.26,
            "pe_hybrid" => 0.48,
            _ => default_price_per_hour(&soc), // symmetric<N>
        };
        Ok(Board::sim(token, soc).with_price(price))
    }

    pub fn soc(&self) -> &SocSpec {
        &self.model.soc
    }

    pub fn model(&self) -> &PerfModel {
        &self.model
    }

    /// Calibrated aggregate steady-state throughput of the board,
    /// GFLOPS: every cluster on its own tuned parameters, summed — from
    /// the analytical model (the rates behind
    /// `PerfModel::ca_sas_weights`) or, for a calibrated board, from
    /// its measured rate table at the descriptor's current rungs
    /// (large-shape class: the steady-state asymptote board-level
    /// sharding keys on). This is the board's weight in the fleet-SAS
    /// split and the scale of its fleet-DAS grain.
    pub fn throughput_gflops(&self) -> f64 {
        self.weight_source
            .board_throughput(&self.model, ShapeClass::Large)
    }
}

impl Weighted for Board {
    fn weight(&self) -> f64 {
        self.throughput_gflops()
    }
}

/// Board-level work-distribution strategy — the paper's intra-SoC
/// vocabulary lifted one level (§4/§5.2/§5.4 one level up).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum FleetStrategy {
    /// Equal shards per board — the architecture-oblivious baseline
    /// (the board-level SSS of §4).
    Sss,
    /// Static shards proportional to each board's calibrated aggregate
    /// throughput (the board-level SAS of §5.2, with the weight vector
    /// computed from the model instead of guessed).
    Sas,
    /// Dynamic queue: each board grabs chunks of its own native batch
    /// grain (the board-level CA-DAS of §5.4).
    Das,
}

impl FleetStrategy {
    pub fn label(self) -> &'static str {
        match self {
            FleetStrategy::Sss => "fleet-SSS",
            FleetStrategy::Sas => "fleet-SAS",
            FleetStrategy::Das => "fleet-DAS",
        }
    }

    pub fn is_dynamic(self) -> bool {
        matches!(self, FleetStrategy::Das)
    }

    pub fn parse(s: &str) -> Result<FleetStrategy, String> {
        match s {
            "sss" => Ok(FleetStrategy::Sss),
            "sas" => Ok(FleetStrategy::Sas),
            "das" => Ok(FleetStrategy::Das),
            other => Err(format!("unknown fleet strategy '{other}' (sss|sas|das)")),
        }
    }
}

/// A rack of boards sharing one batch queue.
#[derive(Debug, Clone)]
pub struct Fleet {
    pub boards: Vec<Board>,
}

impl Fleet {
    pub fn new(boards: Vec<Board>) -> Fleet {
        assert!(
            (1..=MAX_WAYS).contains(&boards.len()),
            "a fleet needs 1..={MAX_WAYS} boards, got {}",
            boards.len()
        );
        Fleet { boards }
    }

    /// Parse a comma-separated preset list (`exynos5422,juno_r0,…`)
    /// into a fleet of sim boards. Repeated tokens are distinct boards.
    /// (`split(',')` always yields at least one token, so an empty list
    /// surfaces as an unknown-preset error for `""`.)
    pub fn parse(list: &str) -> Result<Fleet, String> {
        let boards: Vec<Board> = list
            .split(',')
            .map(|t| Board::from_preset(t.trim()))
            .collect::<Result<_, _>>()?;
        if boards.len() > MAX_WAYS {
            return Err(format!(
                "a fleet holds at most {MAX_WAYS} boards, got {}",
                boards.len()
            ));
        }
        Ok(Fleet::new(boards))
    }

    /// A homogeneous fleet of `n` identical boards (capacity planning:
    /// "how many Exynos boards to sustain X req/s?").
    pub fn homogeneous(n: usize, board: &Board) -> Fleet {
        Fleet::new(
            (0..n)
                .map(|i| {
                    let mut b = board.clone();
                    b.name = format!("{}#{i}", board.name);
                    b
                })
                .collect(),
        )
    }

    pub fn num_boards(&self) -> usize {
        self.boards.len()
    }

    /// Iterate every board id, in order.
    pub fn board_ids(&self) -> impl Iterator<Item = BoardId> {
        (0..self.boards.len()).map(BoardId)
    }

    /// Fleet-SAS weight vector: one entry per board, proportional to the
    /// board's calibrated aggregate throughput — the same derivation as
    /// the per-cluster `ca_sas_weights` one level down.
    pub fn weights(&self) -> Weights {
        Weights::from_weighted(&self.boards)
    }

    /// Per-board dynamic-queue grains: each board grabs chunks sized to
    /// its own throughput relative to the slowest board (the board-level
    /// analogue of "each cluster grabs its own `mc`", §5.4), so one
    /// grab's worth of work takes every board roughly the same time.
    pub fn grains(&self) -> Vec<usize> {
        let rates: Vec<f64> = self.boards.iter().map(Board::throughput_gflops).collect();
        let min = rates.iter().cloned().fold(f64::INFINITY, f64::min);
        rates
            .iter()
            .map(|&r| ((r / min).round() as usize).max(1))
            .collect()
    }

    /// Static shard sizes for a batch of `batch` same-shape items under
    /// an SSS/SAS strategy (items are indivisible, stride 1). The shards
    /// always sum to `batch`; a zero shard means that board idles.
    /// Panics for the dynamic strategy — its shards emerge from the
    /// queue drain.
    pub fn static_shards(&self, batch: usize, strategy: FleetStrategy) -> Vec<usize> {
        let weights = match strategy {
            FleetStrategy::Sss => vec![1.0; self.num_boards()],
            FleetStrategy::Sas => self.weights().as_slice().to_vec(),
            FleetStrategy::Das => panic!("fleet-DAS shards come from the dynamic queue"),
        };
        crate::partition::split_weighted(batch, &weights, 1)
            .into_iter()
            .map(|c| c.len)
            .collect()
    }

    /// Sum of every board's calibrated aggregate throughput — the
    /// fleet-level "ideal" reference line.
    pub fn aggregate_throughput_gflops(&self) -> f64 {
        self.boards.iter().map(Board::throughput_gflops).sum()
    }

    /// Provisioned cost rate of the fleet, $/hour: what this rack rents
    /// for whether or not it is busy — the denominator of every
    /// cost-vs-SLO trade the autoscaler makes.
    pub fn price_per_hour(&self) -> f64 {
        self.boards.iter().map(|b| b.price_per_hour).sum()
    }

    /// Mixed-job shard plan: split every same-job subgroup of one
    /// dispatch wave across the boards independently, under a static
    /// strategy. Each subgroup's shards sum to its item count (the
    /// per-job shard-sum invariant the streaming dispatcher relies
    /// on). Panics for fleet-DAS, whose shards emerge from the queue.
    /// (ISSUE 10: the group key is a [`JobSpec`]; pass
    /// `JobSpec::Gemm(shape)` — or `shape.into()` — for the old
    /// GEMM-only waves.)
    pub fn plan_wave(&self, groups: &[(JobSpec, usize)], strategy: FleetStrategy) -> WavePlan {
        WavePlan {
            groups: groups
                .iter()
                .map(|&(job, count)| WaveGroupPlan {
                    job,
                    shards: self.static_shards(count, strategy),
                })
                .collect(),
        }
    }
}

/// Static shard plan of one same-job subgroup within a mixed wave.
#[derive(Debug, Clone)]
pub struct WaveGroupPlan {
    pub job: JobSpec,
    /// Items of this subgroup assigned to each board, in fleet order.
    pub shards: Vec<usize>,
}

/// Per-job shard plan for one mixed-job dispatch wave
/// ([`Fleet::plan_wave`]): the static-strategy counterpart of the
/// streaming queue — the `coordinator::StreamDispatcher` seeds each
/// board's private queue from the per-group shards, in wave order.
#[derive(Debug, Clone)]
pub struct WavePlan {
    pub groups: Vec<WaveGroupPlan>,
}

impl WavePlan {
    /// Total items across every subgroup.
    pub fn items(&self) -> usize {
        self.groups.iter().map(|g| g.shards.iter().sum::<usize>()).sum()
    }

    /// Items assigned to board `b` across every subgroup.
    pub fn board_items(&self, b: usize) -> usize {
        self.groups.iter().map(|g| g.shards[b]).sum()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prop;

    #[test]
    fn board_presets_parse() {
        for token in ["exynos5422", "juno_r0", "dynamiq_3c", "pe_hybrid", "symmetric4"] {
            let b = Board::from_preset(token).unwrap();
            assert!(b.throughput_gflops() > 0.0, "{token}");
        }
        assert!(Board::from_preset("warp9").is_err());
        assert!(Board::from_preset("symmetricX").is_err());
        assert!(Board::from_preset("symmetric0").is_err());
    }

    /// ISSUE 3: `@governor` pins a board at a DVFS operating point —
    /// the per-board frequency-heterogeneity knob.
    #[test]
    fn governor_pinned_boards() {
        let nominal = Board::from_preset("exynos5422").unwrap();
        let slow = Board::from_preset("exynos5422@powersave").unwrap();
        let fast = Board::from_preset("exynos5422@performance").unwrap();
        assert_eq!(slow.name, "exynos5422@powersave");
        assert_eq!(slow.soc().clusters[0].core.freq_ghz, 0.8);
        assert_eq!(slow.soc().clusters[1].core.freq_ghz, 0.5);
        // performance == nominal bit-for-bit (the no-op pin).
        assert_eq!(fast.soc(), nominal.soc());
        assert!(
            slow.throughput_gflops() < 0.6 * nominal.throughput_gflops(),
            "powersave board {} vs nominal {}",
            slow.throughput_gflops(),
            nominal.throughput_gflops()
        );
        // A frequency-heterogeneous fleet of identical silicon gets
        // throughput-proportional weights.
        let f = Fleet::parse("exynos5422,exynos5422@powersave").unwrap();
        let w = f.weights();
        assert!(w.as_slice()[0] > 1.5 * w.as_slice()[1], "{:?}", w.as_slice());
        assert!(Board::from_preset("exynos5422@turbo").is_err());
        assert!(Board::from_preset("warp9@powersave").is_err());
    }

    /// ISSUE 5: a calibrated board weighs itself from measured DES
    /// rates — strictly below the analytical steady-state aggregate
    /// (packing and barriers are real), with the hybrid in between —
    /// and the fleet-SAS split follows the calibrated weights.
    #[test]
    fn calibrated_boards_weigh_from_measured_rates() {
        let ana = Board::from_preset("exynos5422").unwrap();
        let cal = Board::from_preset("exynos5422").unwrap().calibrated();
        let t_ana = ana.throughput_gflops();
        let t_cal = cal.throughput_gflops();
        assert!(t_cal < t_ana, "measured {t_cal} vs analytical {t_ana}");
        assert!(t_cal > 0.75 * t_ana, "measured {t_cal} vs analytical {t_ana}");
        let table = cal.weight_source.table().expect("calibrated board has a table").clone();
        let hyb = Board::from_preset("exynos5422")
            .unwrap()
            .with_weight_source(WeightSource::Hybrid(table));
        let t_hyb = hyb.throughput_gflops();
        assert!(t_cal < t_hyb && t_hyb < t_ana, "{t_cal} < {t_hyb} < {t_ana}");
        // Mixed sourcing shifts the static split: an analytical board
        // next to its calibrated twin gets the larger shard.
        let f = Fleet::new(vec![ana, cal]);
        let shards = f.static_shards(100, FleetStrategy::Sas);
        assert_eq!(shards.iter().sum::<usize>(), 100);
        assert!(shards[0] > shards[1], "{shards:?}");
    }

    /// ISSUE 8: every board rents for a positive $/hour — presets at
    /// their list price, `@governor` pins at the silicon's price, raw
    /// descriptors at the peak-proportional default — and the fleet's
    /// cost rate is the sum.
    #[test]
    fn boards_carry_list_prices() {
        let ex = Board::from_preset("exynos5422").unwrap();
        assert_eq!(ex.price_per_hour, 0.30);
        let pinned = Board::from_preset("exynos5422@powersave").unwrap();
        assert_eq!(pinned.price_per_hour, 0.30, "same silicon, same rent");
        let sym = Board::from_preset("symmetric2").unwrap();
        assert!(
            sym.price_per_hour > 0.0 && sym.price_per_hour < ex.price_per_hour,
            "symmetric2 is the cheap top-up template: ${}/h",
            sym.price_per_hour
        );
        assert!(Board::sim("raw", crate::soc::SocSpec::juno_r0()).price_per_hour > 0.0);
        let f = Fleet::parse("exynos5422,juno_r0").unwrap();
        assert!((f.price_per_hour() - 0.58).abs() < 1e-12, "{}", f.price_per_hour());
        assert_eq!(ex.clone().with_price(1.25).price_per_hour, 1.25);
    }

    #[test]
    #[should_panic(expected = "positive and finite")]
    fn non_finite_price_rejected() {
        let _ = Board::from_preset("exynos5422").unwrap().with_price(f64::NAN);
    }

    #[test]
    fn fleet_parses_heterogeneous_list() {
        let f = Fleet::parse("exynos5422, juno_r0").unwrap();
        assert_eq!(f.num_boards(), 2);
        assert_eq!(f.boards[0].name, "exynos5422");
        assert!(Fleet::parse("exynos5422,warp").is_err());
        // Oversized board lists error cleanly instead of panicking.
        let nine = vec!["exynos5422"; 9].join(",");
        let err = Fleet::parse(&nine).unwrap_err();
        assert!(err.contains("at most"), "{err}");
    }

    #[test]
    fn weights_track_board_throughput() {
        let f = Fleet::parse("exynos5422,exynos5422").unwrap();
        let w = f.weights();
        assert_eq!(w.len(), 2);
        let ws = w.as_slice();
        assert!((ws[0] / ws[1] - 1.0).abs() < 1e-12, "identical boards, equal weights");
        // The Exynos board sustains ≈ the Fig. 7 ideal aggregate.
        assert!((11.5..12.4).contains(&ws[0]), "Exynos aggregate {}", ws[0]);
    }

    #[test]
    fn grains_scale_with_throughput() {
        let ex = Board::from_preset("exynos5422").unwrap();
        let slow = Board::from_preset("symmetric1").unwrap();
        let f = Fleet::new(vec![ex, slow]);
        let g = f.grains();
        assert_eq!(g[1], 1, "slowest board grabs single items");
        assert!(g[0] >= 3, "fast board grabs proportionally bigger chunks: {g:?}");
    }

    #[test]
    fn homogeneous_builder_names_boards() {
        let f = Fleet::homogeneous(3, &Board::from_preset("exynos5422").unwrap());
        assert_eq!(f.num_boards(), 3);
        assert_eq!(f.boards[2].name, "exynos5422#2");
    }

    #[test]
    #[should_panic(expected = "fleet needs")]
    fn empty_fleet_rejected() {
        Fleet::new(Vec::new());
    }

    #[test]
    #[should_panic(expected = "dynamic queue")]
    fn das_has_no_static_shards() {
        Fleet::parse("exynos5422")
            .unwrap()
            .static_shards(8, FleetStrategy::Das);
    }

    /// ISSUE satellite: fleet-SAS board shards must sum to the batch
    /// size for 1–4 boards of mixed presets (the board-level version of
    /// the 1–6-cluster partition property tests).
    #[test]
    fn prop_fleet_static_shards_sum_to_batch() {
        let presets = ["exynos5422", "juno_r0", "dynamiq_3c", "pe_hybrid", "symmetric2"];
        prop::check_default(
            |r| {
                let n = r.gen_range(1, 5); // 1..=4 boards
                let toks: Vec<&str> =
                    (0..n).map(|_| *r.choose(&presets)).collect();
                (toks.join(","), r.gen_range(0, 300))
            },
            |(list, batch)| {
                let fleet = Fleet::parse(list).map_err(|e| e.to_string())?;
                for strategy in [FleetStrategy::Sss, FleetStrategy::Sas] {
                    let shards = fleet.static_shards(*batch, strategy);
                    if shards.len() != fleet.num_boards() {
                        return Err(format!(
                            "{} shards for {} boards",
                            shards.len(),
                            fleet.num_boards()
                        ));
                    }
                    let total: usize = shards.iter().sum();
                    if total != *batch {
                        return Err(format!(
                            "{} shards {shards:?} sum to {total}, batch {batch}",
                            strategy.label()
                        ));
                    }
                }
                Ok(())
            },
        );
    }

    /// ISSUE 4: mixed-job wave plans shard every same-job subgroup
    /// independently, and each subgroup's shards sum to its item count.
    /// (ISSUE 10: keys are [`JobSpec`]s — GEMMs and factorizations plan
    /// through the same waves.)
    #[test]
    fn plan_wave_shards_each_job_subgroup() {
        let f = Fleet::parse("exynos5422,juno_r0").unwrap();
        let groups = [
            (JobSpec::Gemm(GemmShape::square(512)), 10usize),
            (JobSpec::Gemm(GemmShape::square(1024)), 7),
            (JobSpec::Factor { kind: crate::dag::FactorKind::Cholesky, n: 512, nb: 128 }, 1),
        ];
        for strategy in [FleetStrategy::Sss, FleetStrategy::Sas] {
            let plan = f.plan_wave(&groups, strategy);
            assert_eq!(plan.groups.len(), 3);
            assert_eq!(plan.items(), 18);
            for (g, &(job, count)) in plan.groups.iter().zip(&groups) {
                assert_eq!(g.job, job);
                assert_eq!(g.shards.len(), f.num_boards());
                assert_eq!(g.shards.iter().sum::<usize>(), count, "{}", strategy.label());
            }
            assert_eq!(plan.board_items(0) + plan.board_items(1), 18);
            // Per-group shards must match the single-shape splitter —
            // the wave plan is `static_shards`, job by job.
            assert_eq!(plan.groups[0].shards, f.static_shards(10, strategy));
        }
    }

    #[test]
    #[should_panic(expected = "dynamic queue")]
    fn plan_wave_rejects_das() {
        let f = Fleet::parse("exynos5422").unwrap();
        f.plan_wave(&[(GemmShape::square(256).into(), 4)], FleetStrategy::Das);
    }

    #[test]
    fn strategy_labels_and_parse() {
        assert_eq!(FleetStrategy::parse("das").unwrap(), FleetStrategy::Das);
        assert!(FleetStrategy::parse("warp").is_err());
        assert_eq!(FleetStrategy::Sas.label(), "fleet-SAS");
        assert!(FleetStrategy::Das.is_dynamic());
        assert!(!FleetStrategy::Sss.is_dynamic());
    }

    #[test]
    fn board_id_displays() {
        assert_eq!(format!("{}", BoardId(2)), "b2");
        let f = Fleet::parse("exynos5422,juno_r0").unwrap();
        assert_eq!(f.board_ids().count(), 2);
    }
}
