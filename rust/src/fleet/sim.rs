//! Deterministic virtual-time simulation of a fleet draining one batch
//! of same-shape GEMMs.
//!
//! The unit of work is one GEMM item; each board's per-item virtual time
//! and energy come from one intra-SoC DES run
//! ([`crate::sim::simulate`]) under the board's own schedule, so the
//! fleet layer composes the calibrated single-board model instead of
//! inventing a second one. Boards process their items serially (the
//! coordinator pins one outstanding batch per board); the fleet makespan
//! is the slowest board's finish time, and fleet energy charges every
//! board's idle tail at its baseline power until the makespan — the
//! §3.4 accounting ("the idle cluster still burns its rail") one level
//! up.
//!
//! Capacity planning ("how many Exynos boards sustain X req/s?") is
//! [`boards_to_sustain`]: grow a homogeneous fleet until the simulated
//! sustained rate reaches the target.
//!
//! Streaming (ISSUE 4): [`simulate_fleet_stream`] replays an
//! *arrival-driven* request stream ([`Arrival`]) through the same
//! virtual-time machinery — boards pull same-shape runs of their own
//! grain from the admitted-but-unexecuted queue the moment they drain
//! (work-conserving, no wave barrier), with per-board idle-tail and
//! queue-depth statistics. [`simulate_fleet_waves`] is the synchronous
//! comparator: one wave per same-shape group, each wave barriered until
//! its last member has arrived and the previous wave has finished —
//! today's `FleetDispatcher` discipline made explicit in virtual time.
//! When every request arrives at t = 0 with one shape, both degenerate
//! to [`simulate_fleet`] bit-for-bit (pinned by tests).
//!
//! Perf (ISSUE 6): every simulator here prices items through the
//! engine-layer [`RunCache`]. The `*_cached` variants take a
//! caller-owned cache so sweeps (capacity planning, scaling curves,
//! the trajectory suite) amortize DES runs across calls; the plain
//! entry points run against a fresh cache, and cached == fresh bit for
//! bit. The counters surface as `des_runs`/`cache_hits` on the stats.
//! The streaming replays keep their admission and queue-depth
//! bookkeeping in the engine's [`EventQueue`] — O(log n) per event
//! instead of sorted-`Vec` scans.
//!
//! ISSUE 10 (API redesign): the unit of streamed work is now a
//! [`JobSpec`] — plain GEMMs, level-3 ops and blocked factorizations
//! flow through one queue. GEMM jobs price exactly as before (the
//! bit-for-bit anchor); level-3 jobs price as their equivalent GEMM
//! scaled by the op's flop fraction; `Factor` jobs price through the
//! criticality-aware DAG scheduler ([`crate::dag::sched::factor_price`])
//! under the board's own `WeightSource`. The fractured
//! `simulate_fleet_stream{,_cached,_traced,_live,_live_traced}` ×
//! `simulate_fleet_waves{,_cached}` surface collapsed into one
//! [`StreamSim`] builder; the old names survive as thin delegating
//! wrappers, pinned bit-for-bit in `tests/stream_props.rs`.

use crate::blis::gemm::GemmShape;
use crate::calibrate::live::LiveRateTable;
use crate::calibrate::{current_opps, Family, WeightSource};
use crate::coordinator::Batcher;
use crate::dag::JobSpec;
use crate::dvfs::{DvfsSchedule, Governor, LoadSignal, Ondemand};
use crate::energy::PowerModel;
use crate::fleet::{Fleet, FleetStrategy, DISPATCH_S};
use crate::obs::{Histogram, MetricsRegistry, NullSink, TraceEvent, TraceSink};
use crate::sched::{ScheduleSpec, Strategy};
use crate::sim::engine::{ConfigId, EventQueue, ItemCost, RunCache};
use crate::sim::{simulate, simulate_traced, Timeline};
use crate::util::rng::Rng;
use std::collections::{BTreeMap, HashMap};

/// One board's share of a simulated fleet run.
#[derive(Debug, Clone)]
pub struct BoardStats {
    pub name: String,
    /// Items this board executed.
    pub items: usize,
    /// Dispatches it received (1 per static shard; 1 per dynamic grab).
    pub grabs: u64,
    /// Virtual time spent computing (items × per-item time).
    pub busy_s: f64,
    /// Virtual instant the board went idle.
    pub finish_s: f64,
    /// Sustained rate over the board's own active window.
    pub gflops: f64,
    /// Board energy over the whole fleet run, idle tail included.
    pub energy_j: f64,
}

/// Aggregated result of one simulated fleet run.
#[derive(Debug, Clone)]
pub struct FleetStats {
    pub label: String,
    pub shape: GemmShape,
    pub batch: usize,
    /// Virtual makespan: the slowest board's finish time.
    pub makespan_s: f64,
    /// Useful flops of the whole batch over the makespan.
    pub gflops: f64,
    /// Sustained batch-item throughput, requests per second.
    pub throughput_rps: f64,
    /// Whole-fleet energy (every board charged to the makespan).
    pub energy_j: f64,
    pub gflops_per_watt: f64,
    /// Intra-SoC DES runs this call executed (run-cache misses); 0 on
    /// a warm cache.
    pub des_runs: u64,
    /// Item pricings served from the run cache without a DES run.
    pub cache_hits: u64,
    /// Per-board breakdown, in fleet order.
    pub boards: Vec<BoardStats>,
}

impl FleetStats {
    /// Items executed across all boards (= `batch`, asserted in tests).
    pub fn items_completed(&self) -> usize {
        self.boards.iter().map(|b| b.items).sum()
    }
}

/// Simulate one batch of `batch` same-shape GEMMs over the fleet under
/// a board-level strategy. Deterministic: pure virtual time, no host
/// clock, no RNG.
pub fn simulate_fleet(
    fleet: &Fleet,
    strategy: FleetStrategy,
    shape: GemmShape,
    batch: usize,
) -> FleetStats {
    simulate_fleet_cached(fleet, strategy, shape, batch, &mut RunCache::new())
}

/// [`simulate_fleet`] against a caller-owned [`RunCache`]: sweeps that
/// replay the same boards (capacity planning, scaling curves, the
/// trajectory suite) pay each distinct (board, shape) DES exactly once
/// across the whole sweep. Cached and fresh runs are bit-for-bit
/// identical (property-tested).
pub fn simulate_fleet_cached(
    fleet: &Fleet,
    strategy: FleetStrategy,
    shape: GemmShape,
    batch: usize,
    cache: &mut RunCache,
) -> FleetStats {
    assert!(batch > 0, "empty batch");
    let n = fleet.num_boards();
    let (hits0, misses0) = (cache.hits(), cache.misses());

    // One intra-SoC DES run per distinct board configuration gives the
    // per-item time/energy; every item of the batch has the same shape,
    // so one run suffices — and identical boards (homogeneous capacity
    // sweeps are fleets of clones) intern to the same id and share one
    // cache slot instead of re-simulating.
    let per_item: Vec<ItemCost> = fleet
        .boards
        .iter()
        .map(|b| {
            let cfg = cache.config(b.model(), &b.sched);
            cache.cost_with(cfg, shape, || simulate(b.model(), &b.sched, shape))
        })
        .collect();
    let baseline_w: Vec<f64> = fleet
        .boards
        .iter()
        .map(|b| PowerModel::new(b.soc().clone()).baseline_w())
        .collect();

    let mut items = vec![0usize; n];
    let mut grabs = vec![0u64; n];
    let mut clock = vec![0.0f64; n];

    match strategy {
        FleetStrategy::Sss | FleetStrategy::Sas => {
            for (b, &share) in fleet.static_shards(batch, strategy).iter().enumerate() {
                if share > 0 {
                    items[b] = share;
                    grabs[b] = 1; // the whole shard ships in one dispatch
                    clock[b] = DISPATCH_S + share as f64 * per_item[b].time_s;
                }
            }
        }
        FleetStrategy::Das => {
            // Event loop mirroring the intra-SoC dynamic m-loop (§5.4):
            // the board with the earliest clock grabs the next chunk of
            // its own grain (ties go to the lowest board id).
            let grains = fleet.grains();
            let mut next = 0usize;
            while next < batch {
                let mut idx = 0;
                for b in 1..n {
                    if clock[b] < clock[idx] {
                        idx = b;
                    }
                }
                let take = grains[idx].min(batch - next);
                next += take;
                items[idx] += take;
                grabs[idx] += 1;
                clock[idx] += DISPATCH_S + take as f64 * per_item[idx].time_s;
            }
        }
    }

    let makespan = clock.iter().cloned().fold(0.0, f64::max);
    let flops_item = shape.flops();
    let boards: Vec<BoardStats> = (0..n)
        .map(|b| {
            let busy = items[b] as f64 * per_item[b].time_s;
            // Active window at run power, everything else (dispatch
            // waits + idle tail to the fleet makespan) at baseline.
            let energy =
                items[b] as f64 * per_item[b].energy_j + baseline_w[b] * (makespan - busy);
            BoardStats {
                name: fleet.boards[b].name.clone(),
                items: items[b],
                grabs: grabs[b],
                busy_s: busy,
                finish_s: clock[b],
                gflops: if clock[b] > 0.0 {
                    items[b] as f64 * flops_item / clock[b] / 1e9
                } else {
                    0.0
                },
                energy_j: energy,
            }
        })
        .collect();

    let total_flops = batch as f64 * flops_item;
    let energy_j: f64 = boards.iter().map(|b| b.energy_j).sum();
    FleetStats {
        label: format!(
            "{} [{}]",
            strategy.label(),
            fleet
                .boards
                .iter()
                .map(|b| b.name.as_str())
                .collect::<Vec<_>>()
                .join("+")
        ),
        shape,
        batch,
        makespan_s: makespan,
        gflops: total_flops / makespan / 1e9,
        throughput_rps: batch as f64 / makespan,
        energy_j,
        gflops_per_watt: total_flops / energy_j / 1e9,
        des_runs: cache.misses() - misses0,
        cache_hits: cache.hits() - hits0,
        boards,
    }
}

/// Per-board DVFS replay of one batch: each board runs under its own
/// OPP [`DvfsSchedule`] (`plans[b]`, validated against that board's
/// topology), and an item started at virtual instant `t` executes at
/// the operating point in effect at `t` — boards reconfigure *between*
/// requests, the item-granular quantization a coordinator that pins one
/// outstanding batch per board actually exhibits. When every plan is
/// static and pins the rung each board's descriptor is already derived
/// at, this delegates to [`simulate_fleet`] — the fleet DVFS path is a
/// provable no-op at fixed frequency, for plain and `@governor` boards
/// alike.
pub fn simulate_fleet_dvfs(
    fleet: &Fleet,
    strategy: FleetStrategy,
    shape: GemmShape,
    batch: usize,
    plans: &[DvfsSchedule],
) -> FleetStats {
    simulate_fleet_dvfs_cached(fleet, strategy, shape, batch, plans, &mut RunCache::new())
}

/// [`simulate_fleet_dvfs`] against a caller-owned [`RunCache`]. The
/// cache keys on the *derived* at-OPP descriptor, so the rung vector is
/// part of the fingerprint for free: boards revisiting an operating
/// point — or identical boards visiting the same one — share a single
/// DES run, across calls too.
pub fn simulate_fleet_dvfs_cached(
    fleet: &Fleet,
    strategy: FleetStrategy,
    shape: GemmShape,
    batch: usize,
    plans: &[DvfsSchedule],
    cache: &mut RunCache,
) -> FleetStats {
    assert!(batch > 0, "empty batch");
    let n = fleet.num_boards();
    assert_eq!(plans.len(), n, "one DVFS schedule per board");
    for (b, plan) in plans.iter().enumerate() {
        plan.validate(fleet.boards[b].soc())
            .expect("invalid board DVFS schedule");
    }
    // A static plan pinning every cluster at the rung the board's
    // descriptor is *already* derived at (the nominal rung for plain
    // presets, the pinned rung for `@governor` boards) is exactly the
    // fixed-frequency simulator — delegate, so the DVFS path is a
    // provable no-op there.
    if plans.iter().zip(&fleet.boards).all(|(p, b)| {
        p.is_static()
            && b.soc()
                .cluster_ids()
                .all(|c| p.initial[c.0] == b.soc()[c].opps.current_idx())
    }) {
        return simulate_fleet_cached(fleet, strategy, shape, batch, cache);
    }
    let (hits0, misses0) = (cache.hits(), cache.misses());

    // One DES run per distinct (at-OPP descriptor, schedule) the plans
    // visit: the run cache fingerprints the *derived* descriptor, so
    // boards revisiting a rung vector — and identical boards visiting
    // the same one — intern to the same id. `rung_cfg[b]` memoizes each
    // board's rung-vector → id resolution so the hot loop never
    // re-derives a descriptor it has already fingerprinted.
    let mut rung_cfg: Vec<HashMap<Vec<usize>, ConfigId>> = vec![HashMap::new(); n];
    let item_cost = |cache: &mut RunCache,
                     rung_cfg: &mut [HashMap<Vec<usize>, ConfigId>],
                     b: usize,
                     t: f64|
     -> ItemCost {
        let board = &fleet.boards[b];
        let soc = board.soc();
        let key: Vec<usize> = soc.cluster_ids().map(|c| plans[b].opp_at(c, t)).collect();
        let cfg = match rung_cfg[b].get(&key) {
            Some(&cfg) => cfg,
            None => {
                let model = crate::model::PerfModel::new(plans[b].soc_at(soc, t));
                let cfg = cache.config(&model, &board.sched);
                rung_cfg[b].insert(key, cfg);
                cfg
            }
        };
        cache.cost_with(cfg, shape, || {
            let model = crate::model::PerfModel::new(plans[b].soc_at(soc, t));
            simulate(&model, &board.sched, shape)
        })
    };
    // Baseline (idle-rail) power of board `b` at instant `t` — priced
    // at the operating point in effect, not the boot point.
    let baseline_at = |b: usize, t: f64| -> f64 {
        PowerModel::new(plans[b].soc_at(fleet.boards[b].soc(), t)).baseline_w()
    };

    let mut items = vec![0usize; n];
    let mut grabs = vec![0u64; n];
    let mut clock = vec![0.0f64; n];
    let mut busy = vec![0.0f64; n];
    let mut energy = vec![0.0f64; n];
    let run_items = |cache: &mut RunCache,
                     rung_cfg: &mut [HashMap<Vec<usize>, ConfigId>],
                     clock: &mut [f64],
                     busy: &mut [f64],
                     energy: &mut [f64],
                     b: usize,
                     count: usize| {
        energy[b] += baseline_at(b, clock[b]) * DISPATCH_S;
        clock[b] += DISPATCH_S;
        for _ in 0..count {
            let st = item_cost(cache, rung_cfg, b, clock[b]);
            clock[b] += st.time_s;
            busy[b] += st.time_s;
            energy[b] += st.energy_j;
        }
    };

    match strategy {
        FleetStrategy::Sss | FleetStrategy::Sas => {
            for (b, &share) in fleet.static_shards(batch, strategy).iter().enumerate() {
                if share > 0 {
                    items[b] = share;
                    grabs[b] = 1;
                    run_items(cache, &mut rung_cfg, &mut clock, &mut busy, &mut energy, b, share);
                }
            }
        }
        FleetStrategy::Das => {
            let grains = fleet.grains();
            let mut next = 0usize;
            while next < batch {
                let mut idx = 0;
                for b in 1..n {
                    if clock[b] < clock[idx] {
                        idx = b;
                    }
                }
                let take = grains[idx].min(batch - next);
                next += take;
                items[idx] += take;
                grabs[idx] += 1;
                run_items(cache, &mut rung_cfg, &mut clock, &mut busy, &mut energy, idx, take);
            }
        }
    }

    let makespan = clock.iter().cloned().fold(0.0, f64::max);
    // Idle tail from each board's finish to the fleet makespan, priced
    // piecewise at the operating point in effect over the tail.
    let tail_energy = |b: usize| -> f64 {
        let (t0, t1) = (clock[b], makespan);
        if t1 <= t0 {
            return 0.0;
        }
        let mut cuts = vec![t0];
        cuts.extend(plans[b].boundaries().into_iter().filter(|&t| t > t0 && t < t1));
        cuts.push(t1);
        cuts.windows(2).map(|w| baseline_at(b, w[0]) * (w[1] - w[0])).sum()
    };
    let flops_item = shape.flops();
    let boards: Vec<BoardStats> = (0..n)
        .map(|b| BoardStats {
            name: fleet.boards[b].name.clone(),
            items: items[b],
            grabs: grabs[b],
            busy_s: busy[b],
            finish_s: clock[b],
            gflops: if clock[b] > 0.0 {
                items[b] as f64 * flops_item / clock[b] / 1e9
            } else {
                0.0
            },
            energy_j: energy[b] + tail_energy(b),
        })
        .collect();
    let total_flops = batch as f64 * flops_item;
    let energy_j: f64 = boards.iter().map(|b| b.energy_j).sum();
    FleetStats {
        label: format!(
            "{} +DVFS [{}]",
            strategy.label(),
            fleet
                .boards
                .iter()
                .map(|b| b.name.as_str())
                .collect::<Vec<_>>()
                .join("+")
        ),
        shape,
        batch,
        makespan_s: makespan,
        gflops: total_flops / makespan / 1e9,
        throughput_rps: batch as f64 / makespan,
        energy_j,
        gflops_per_watt: total_flops / energy_j / 1e9,
        des_runs: cache.misses() - misses0,
        cache_hits: cache.hits() - hits0,
        boards,
    }
}

/// Closed-loop fleet DVFS planning (ISSUE 8): iterate the replay and
/// the governor's feedback law to a fixed point. Round 0 gives every
/// board the open-loop [`Ondemand`] ramp; each subsequent round replays
/// the batch under the current plans, samples each board's busy window
/// into a per-period [`LoadSignal`] (saturated until the board's own
/// finish, idle after), and re-plans via
/// [`Governor::plan_closed_loop`]. Boards that finish before the fleet
/// makespan therefore step back to the bottom rung for their idle tail
/// — cheaper idle rails at equal makespan — while the critical board's
/// ramp is untouched. Converges in ≤ 4 rounds (typically 2: the
/// replay's board finishes don't move once the tail plans change,
/// because item pricing only reads the OPP at dispatch instants inside
/// the busy window).
pub fn plan_fleet_dvfs_load_driven(
    fleet: &Fleet,
    strategy: FleetStrategy,
    shape: GemmShape,
    batch: usize,
    gov: &Ondemand,
    cache: &mut RunCache,
) -> Vec<DvfsSchedule> {
    let mut plans: Vec<DvfsSchedule> =
        fleet.boards.iter().map(|b| gov.plan(b.soc(), 1e3)).collect();
    for _ in 0..4 {
        let st = simulate_fleet_dvfs_cached(fleet, strategy, shape, batch, &plans, cache);
        let next: Vec<DvfsSchedule> = fleet
            .boards
            .iter()
            .zip(&st.boards)
            .map(|(board, bs)| {
                let clusters = board.soc().num_clusters();
                let sig =
                    LoadSignal::from_busy_until(gov.period_s, &vec![bs.finish_s; clusters]);
                gov.plan_closed_loop(board.soc(), &sig)
            })
            .collect();
        if next == plans {
            break;
        }
        plans = next;
    }
    plans
}

/// [`simulate_fleet_dvfs`] under load-driven closed-loop plans: plans
/// come from [`plan_fleet_dvfs_load_driven`]'s fixed point instead of
/// an open-loop governor sweep. Returns the stats and the converged
/// plans so callers (figures, the trajectory gate) can pin both.
pub fn simulate_fleet_dvfs_load_driven(
    fleet: &Fleet,
    strategy: FleetStrategy,
    shape: GemmShape,
    batch: usize,
    gov: &Ondemand,
    cache: &mut RunCache,
) -> (FleetStats, Vec<DvfsSchedule>) {
    let plans = plan_fleet_dvfs_load_driven(fleet, strategy, shape, batch, gov, cache);
    let mut st = simulate_fleet_dvfs_cached(fleet, strategy, shape, batch, &plans, cache);
    st.label = format!("{} [closed loop]", st.label);
    (st, plans)
}

/// One streamed request: a [`JobSpec`] admitted at a virtual instant.
/// Vector index = submission order; `arrive_s` orders *admission*, so
/// arrival order and submission order are independent. Plain
/// [`GemmShape`]s convert implicitly, so pre-`JobSpec` call sites
/// (`Arrival::at(shape, t)`) read unchanged.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct Arrival {
    pub job: JobSpec,
    pub arrive_s: f64,
}

impl Arrival {
    pub fn at(job: impl Into<JobSpec>, arrive_s: f64) -> Arrival {
        Arrival { job: job.into(), arrive_s }
    }
}

/// A burst: `count` same-shape requests all arriving at t = 0 — the
/// degenerate stream that must reproduce the one-wave batch paths.
pub fn burst_arrivals(shape: GemmShape, count: usize) -> Vec<Arrival> {
    vec![Arrival::at(shape, 0.0); count]
}

/// Deterministic Poisson-like request stream: exponential inter-arrival
/// gaps at `rate_rps`, shapes drawn uniformly from `shapes`. Arrival
/// instants are non-decreasing, so submission order == arrival order.
pub fn poisson_arrivals(
    rng: &mut Rng,
    shapes: &[GemmShape],
    count: usize,
    rate_rps: f64,
) -> Vec<Arrival> {
    let jobs: Vec<JobSpec> = shapes.iter().map(|&s| JobSpec::Gemm(s)).collect();
    poisson_job_arrivals(rng, &jobs, count, rate_rps)
}

/// [`poisson_arrivals`] over arbitrary [`JobSpec`]s — mixed
/// GEMM + factorization streams draw uniformly from `jobs`.
pub fn poisson_job_arrivals(
    rng: &mut Rng,
    jobs: &[JobSpec],
    count: usize,
    rate_rps: f64,
) -> Vec<Arrival> {
    assert!(!jobs.is_empty(), "need at least one job kind");
    assert!(count > 0, "empty stream");
    let mut t = 0.0;
    (0..count)
        .map(|_| {
            t += rng.gen_exp(rate_rps);
            Arrival::at(*rng.choose(jobs), t)
        })
        .collect()
}

/// One board's share of a streamed (or wave-replayed) run.
#[derive(Debug, Clone, PartialEq)]
pub struct StreamBoardStats {
    pub name: String,
    /// Requests this board executed.
    pub items: usize,
    /// Same-shape runs it grabbed (1 per static shard; 1 per pull).
    pub grabs: u64,
    /// Virtual time spent computing.
    pub busy_s: f64,
    /// Virtual instant the board retired its last request.
    pub finish_s: f64,
    /// Idle tail from the board's last completion to the makespan.
    pub idle_tail_s: f64,
    /// `busy_s / makespan` — the fraction of the run spent computing.
    pub utilization: f64,
    /// Board energy over the whole run, idle rails included.
    pub energy_j: f64,
}

/// Aggregated result of one streamed (or wave-replayed) fleet run.
/// Deterministic: two replays of the same arrivals compare equal.
#[derive(Debug, Clone, PartialEq)]
pub struct StreamStats {
    pub label: String,
    pub requests: usize,
    /// Last completion instant, measured from t = 0.
    pub makespan_s: f64,
    /// Useful flops of the whole stream over the makespan.
    pub gflops: f64,
    pub throughput_rps: f64,
    /// Whole-fleet energy (every board charged to the makespan).
    pub energy_j: f64,
    /// Aggregate busy time over `boards × makespan`.
    pub utilization: f64,
    /// Completion instant of every request, in submission order — the
    /// in-order merge the dispatcher exposes to clients.
    pub completions: Vec<f64>,
    /// Median request sojourn time (completion − arrival, the
    /// client-visible latency), from the submission-indexed completions.
    pub sojourn_p50_s: f64,
    /// 99th-percentile sojourn time — the tail the wave barrier
    /// inflates and streaming admission is meant to cut.
    pub sojourn_p99_s: f64,
    /// Executed requests per distinct job, in first-submission order
    /// (the per-job shard-sum invariant: must equal the submitted
    /// histogram).
    pub per_job: Vec<(JobSpec, usize)>,
    /// Time-averaged depth of the arrived-but-unexecuted queue.
    pub mean_queue_depth: f64,
    /// Peak depth of that queue.
    pub max_queue_depth: usize,
    /// Intra-SoC DES runs this replay executed (run-cache misses); 0
    /// on a warm cache.
    pub des_runs: u64,
    /// Grab pricings served from the run cache without a DES run.
    pub cache_hits: u64,
    /// Per-board breakdown, in fleet order.
    pub boards: Vec<StreamBoardStats>,
}

impl StreamStats {
    /// Requests executed across all boards (= `requests`, pinned in
    /// tests).
    pub fn items_completed(&self) -> usize {
        self.boards.iter().map(|b| b.items).sum()
    }
}

/// Priced service profile of one `(configuration, job)` pair: per-item
/// virtual time, energy, and the per-cluster rail split. For GEMM jobs
/// these are verbatim copies of the cached [`crate::sim::RunStats`]
/// floats, so downstream sums are bit-for-bit the pre-`JobSpec` values.
#[derive(Debug, Clone)]
struct JobPrice {
    time_s: f64,
    energy_j: f64,
    energy_clusters_j: Vec<f64>,
}

/// Price one job on one board configuration. GEMM jobs go through
/// [`RunCache::cost_with`] exactly as before (hit/miss counters
/// included); level-3 jobs price as their [`JobSpec::equiv_gemm`]
/// scaled by [`JobSpec::cost_scale`] (same kernel, fewer flops);
/// `Factor` jobs price the whole task graph through the
/// criticality-aware DAG scheduler under the board's own
/// [`WeightSource`].
fn price_job(
    board: &crate::fleet::Board,
    sched: &ScheduleSpec,
    cfg: ConfigId,
    job: JobSpec,
    cache: &mut RunCache,
) -> JobPrice {
    match job {
        JobSpec::Gemm(shape) => {
            let c = cache.cost_with(cfg, shape, || simulate(board.model(), sched, shape));
            let st = cache.peek(cfg, shape).expect("priced runs are cached");
            JobPrice {
                time_s: c.time_s,
                energy_j: c.energy_j,
                energy_clusters_j: st.energy.energy_clusters_j.clone(),
            }
        }
        JobSpec::Level3 { .. } => {
            let g = job.equiv_gemm();
            let scale = job.cost_scale();
            let c = cache.cost_with(cfg, g, || simulate(board.model(), sched, g));
            let st = cache.peek(cfg, g).expect("priced runs are cached");
            JobPrice {
                time_s: scale * c.time_s,
                energy_j: scale * c.energy_j,
                energy_clusters_j: st.energy.energy_clusters_j.iter().map(|&j| scale * j).collect(),
            }
        }
        JobSpec::Factor { kind, n, nb } => {
            let (cost, rails) =
                crate::dag::sched::factor_price(board.model(), &board.weight_source, kind, n, nb, cache);
            JobPrice { time_s: cost.time_s, energy_j: cost.energy_j, energy_clusters_j: rails }
        }
    }
}

/// Shared post-processing of a virtual-time stream/wave replay: builds
/// [`StreamStats`] from the per-board tallies. `counts[b]` maps each
/// `(config, job)` pair to the number of items board `b` executed
/// under that interned configuration — keyed by [`ConfigId`] as well as
/// job because the live-calibration replay re-plans a board's
/// schedule mid-stream (ISSUE 9), so one board can price the same job
/// under several configurations. Busy time and item energy are
/// recomputed `count × per-item` per pair (deterministic BTreeMap
/// order; `JobSpec::Gemm` is the first enum variant, so GEMM-only
/// streams iterate in the historical `GemmShape` order), so the
/// degenerate single-shape single-config run reproduces
/// [`simulate_fleet`]'s accounting bit for bit.
#[allow(clippy::too_many_arguments)]
fn finish_stream_stats(
    fleet: &Fleet,
    label: String,
    arrivals: &[Arrival],
    priced: &BTreeMap<(ConfigId, JobSpec), JobPrice>,
    counts: &[BTreeMap<(ConfigId, JobSpec), usize>],
    items: &[usize],
    grabs: &[u64],
    finish: &[f64],
    completions: Vec<f64>,
    mut depth_events: EventQueue<i64>,
    des_runs: u64,
    cache_hits: u64,
    sink: &mut dyn TraceSink,
    metrics: &mut MetricsRegistry,
) -> StreamStats {
    let n = fleet.num_boards();
    let makespan = finish.iter().cloned().fold(0.0, f64::max);
    let baseline_w: Vec<f64> = fleet
        .boards
        .iter()
        .map(|b| PowerModel::new(b.soc().clone()).baseline_w())
        .collect();

    let mut boards = Vec::with_capacity(n);
    for b in 0..n {
        let mut busy = 0.0;
        let mut item_energy = 0.0;
        for (&(cfg, job), &count) in &counts[b] {
            let p = priced.get(&(cfg, job)).expect("executed jobs are priced");
            busy += count as f64 * p.time_s;
            item_energy += count as f64 * p.energy_j;
            if metrics.enabled() {
                // Per-cluster joules as monotone counters (the item
                // energy, scaled by how many items ran this job).
                for (c, &j) in p.energy_clusters_j.iter().enumerate() {
                    metrics.inc(&format!("board{b}_energy_c{c}_j"), count as f64 * j);
                }
            }
        }
        boards.push(StreamBoardStats {
            name: fleet.boards[b].name.clone(),
            items: items[b],
            grabs: grabs[b],
            busy_s: busy,
            finish_s: finish[b],
            idle_tail_s: makespan - finish[b],
            utilization: if makespan > 0.0 { busy / makespan } else { 0.0 },
            energy_j: item_energy + baseline_w[b] * (makespan - busy),
        });
    }

    // Executed-per-job histogram, in first-submission order.
    let mut per_job: Vec<(JobSpec, usize)> = Vec::new();
    for a in arrivals {
        if !per_job.iter().any(|(s, _)| *s == a.job) {
            per_job.push((a.job, 0));
        }
    }
    for counts_b in counts {
        for (&(_, job), &count) in counts_b {
            let entry = per_job
                .iter_mut()
                .find(|(s, _)| *s == job)
                .expect("executed job was submitted");
            entry.1 += count;
        }
    }

    // Queue-depth integral: +1 at each arrival instant, -take at each
    // grab instant. The event queue already orders by (time, tie rank):
    // arrivals carry rank −1 and grabs their positive take, so ties
    // process arrivals first and a burst's peak is visible before the
    // first grab drains it.
    let mut depth = 0i64;
    let mut max_depth = 0i64;
    let mut integral = 0.0;
    let mut prev_t = 0.0;
    while let Some((t, delta)) = depth_events.pop() {
        integral += depth as f64 * (t - prev_t);
        prev_t = t;
        depth += delta;
        max_depth = max_depth.max(depth);
        if sink.enabled() {
            // Counter series on the dispatcher process (pid = board
            // count): Perfetto renders it as a stepped area chart.
            sink.record(TraceEvent::counter("queue_depth", n, 0, t, depth as f64));
        }
    }
    integral += depth as f64 * (makespan - prev_t).max(0.0);

    let total_flops: f64 = arrivals.iter().map(|a| a.job.flops()).sum();
    let total_busy: f64 = boards.iter().map(|b| b.busy_s).sum();
    // Sojourn times (completion − arrival) are submission-indexed, so
    // the percentiles line up request-for-request across replay modes.
    // They feed an exact-sample histogram whose `quantile` is the same
    // kernel the old `percentile` calls used — the reported p50/p99
    // stay bit-for-bit while the full distribution reaches the
    // registry.
    let mut sojourn_hist = Histogram::with_samples();
    for (&done, a) in completions.iter().zip(arrivals) {
        sojourn_hist.observe(done - a.arrive_s);
    }
    // An empty stream has no sojourn distribution — report 0.0 rather
    // than panicking in `quantile` (same convention as the ratio
    // fields below).
    let (sojourn_p50_s, sojourn_p99_s) = if arrivals.is_empty() {
        (0.0, 0.0)
    } else {
        (sojourn_hist.quantile(50.0), sojourn_hist.quantile(99.0))
    };
    if metrics.enabled() {
        metrics.record_histogram("sojourn_s", &sojourn_hist);
        metrics.inc("stream_completions", completions.len() as f64);
        metrics.set_gauge("queue_depth_mean", if makespan > 0.0 { integral / makespan } else { 0.0 });
        metrics.set_gauge("queue_depth_max", max_depth as f64);
        for (b, board) in boards.iter().enumerate() {
            metrics.inc(&format!("board{b}_energy_j"), board.energy_j);
            metrics.set_gauge(&format!("board{b}_utilization"), board.utilization);
            metrics.set_gauge(&format!("board{b}_queue_grabs"), board.grabs as f64);
        }
    }
    StreamStats {
        label,
        requests: arrivals.len(),
        makespan_s: makespan,
        // Every ratio over the makespan is zero-guarded: an empty (or
        // degenerate zero-length) stream reports 0.0 instead of NaN,
        // which would poison downstream gates (means, trajectory rows).
        gflops: if makespan > 0.0 { total_flops / makespan / 1e9 } else { 0.0 },
        throughput_rps: if makespan > 0.0 { arrivals.len() as f64 / makespan } else { 0.0 },
        energy_j: boards.iter().map(|b| b.energy_j).sum(),
        utilization: if makespan > 0.0 { total_busy / (n as f64 * makespan) } else { 0.0 },
        completions,
        sojourn_p50_s,
        sojourn_p99_s,
        per_job,
        mean_queue_depth: if makespan > 0.0 { integral / makespan } else { 0.0 },
        max_queue_depth: max_depth as usize,
        des_runs,
        cache_hits,
        boards,
    }
}

fn board_names(fleet: &Fleet) -> String {
    fleet.boards.iter().map(|b| b.name.as_str()).collect::<Vec<_>>().join("+")
}

/// Interned configuration ids for every board of the fleet, in fleet
/// order — identical boards intern to the same id (the homogeneous
/// dedup, now a hash lookup instead of an O(n²) scan).
fn board_configs(fleet: &Fleet, cache: &mut RunCache) -> Vec<ConfigId> {
    fleet.boards.iter().map(|b| cache.config(b.model(), &b.sched)).collect()
}

/// The shared arrival validation (finite, non-negative), with the
/// exact diagnostic both the sims and the dispatcher emit.
fn assert_arrival_instant(i: usize, t: f64) {
    assert!(
        t.is_finite() && t >= 0.0,
        "request {i}: arrival instant must be finite and >= 0, got {t}"
    );
}

/// Admission order over raw arrival instants: by time, ties broken by
/// submission index (stable), with the shared validation (finite,
/// non-negative). One implementation serves the virtual-time sims and
/// the real-thread `coordinator::StreamDispatcher`, so the tie-break
/// contract cannot drift between them.
pub fn admission_order_by(times: &[f64]) -> Vec<usize> {
    for (i, &t) in times.iter().enumerate() {
        assert_arrival_instant(i, t);
    }
    let mut order: Vec<usize> = (0..times.len()).collect();
    order.sort_by(|&i, &j| times[i].total_cmp(&times[j]).then(i.cmp(&j)));
    order
}

fn admission_order(arrivals: &[Arrival]) -> Vec<usize> {
    let times: Vec<f64> = arrivals.iter().map(|a| a.arrive_s).collect();
    admission_order_by(&times)
}

/// One builder over every stream/wave replay mode (the ISSUE 10 API
/// consolidation): pick a discipline (`streaming` by default, or
/// [`StreamSim::waves`]), attach optional state (a caller-owned
/// [`RunCache`], a [`TraceSink`], a [`MetricsRegistry`], a live
/// calibration config), then [`StreamSim::run`] the arrivals.
///
/// ```text
/// StreamSim::new(&fleet).cache(&mut cache).sink(&mut sink).run(&arrivals)
/// ```
///
/// Every legacy entry point (`simulate_fleet_stream{,_cached,_traced,
/// _live,_live_traced}`, `simulate_fleet_waves{,_cached}`) is now a
/// thin delegation through this builder — bit-for-bit equivalence is
/// pinned in `tests/stream_props.rs`. Defaults: a fresh private cache,
/// a [`NullSink`], a disabled registry, no live calibration.
pub struct StreamSim<'a> {
    fleet: &'a Fleet,
    cache: Option<&'a mut RunCache>,
    sink: Option<&'a mut dyn TraceSink>,
    metrics: Option<&'a mut MetricsRegistry>,
    live: Option<LiveStreamConfig>,
    waves: Option<(FleetStrategy, usize)>,
}

impl<'a> StreamSim<'a> {
    /// A streaming replay of `fleet` with all defaults.
    pub fn new(fleet: &'a Fleet) -> StreamSim<'a> {
        StreamSim { fleet, cache: None, sink: None, metrics: None, live: None, waves: None }
    }

    /// Price items through a caller-owned [`RunCache`] (warm replays
    /// are DES-free and bit-for-bit identical to fresh ones).
    pub fn cache(mut self, cache: &'a mut RunCache) -> Self {
        self.cache = Some(cache);
        self
    }

    /// Mirror replay events into a trace sink (zero-overhead contract:
    /// never feeds back into the clock arithmetic).
    pub fn sink(mut self, sink: &'a mut dyn TraceSink) -> Self {
        self.sink = Some(sink);
        self
    }

    /// Export counters/histograms/gauges into a metrics registry.
    pub fn metrics(mut self, metrics: &'a mut MetricsRegistry) -> Self {
        self.metrics = Some(metrics);
        self
    }

    /// Enable online calibration (ISSUE 9): boards learn rates from
    /// their own completions and weighted-static schedules re-plan
    /// mid-stream. Use [`StreamSim::run_live`] to also get the
    /// per-board [`LiveBoardReport`]s back.
    pub fn live(mut self, cfg: LiveStreamConfig) -> Self {
        self.live = Some(cfg);
        self
    }

    /// Replay under the synchronous wave discipline instead of
    /// streaming admission: same-job waves of at most `max_group`
    /// (admission order), each barriered on the previous wave.
    pub fn waves(mut self, strategy: FleetStrategy, max_group: usize) -> Self {
        self.waves = Some((strategy, max_group));
        self
    }

    /// Run the replay. Live mode discards the board reports — call
    /// [`StreamSim::run_live`] to keep them.
    pub fn run(self, arrivals: &[Arrival]) -> StreamStats {
        if self.live.is_some() {
            return self.run_live(arrivals).0;
        }
        let StreamSim { fleet, cache, sink, metrics, waves, .. } = self;
        let mut local_cache = RunCache::new();
        let cache = cache.unwrap_or(&mut local_cache);
        let mut null = NullSink;
        let sink = sink.unwrap_or(&mut null);
        let mut disabled = MetricsRegistry::disabled();
        let metrics = metrics.unwrap_or(&mut disabled);
        match waves {
            Some((strategy, max_group)) => {
                waves_engine(fleet, strategy, arrivals, max_group, cache, sink, metrics)
            }
            None => stream_engine(fleet, arrivals, cache, sink, metrics),
        }
    }

    /// Run with online calibration and return what each board learned.
    /// Incompatible with [`StreamSim::waves`] (the wave barrier has no
    /// re-plan points).
    pub fn run_live(self, arrivals: &[Arrival]) -> (StreamStats, Vec<LiveBoardReport>) {
        let StreamSim { fleet, cache, sink, metrics, live, waves } = self;
        assert!(waves.is_none(), "live calibration replays the streaming discipline, not waves");
        let lcfg = live.unwrap_or_default();
        let mut local_cache = RunCache::new();
        let cache = cache.unwrap_or(&mut local_cache);
        let mut null = NullSink;
        let sink = sink.unwrap_or(&mut null);
        let mut disabled = MetricsRegistry::disabled();
        let metrics = metrics.unwrap_or(&mut disabled);
        live_engine(fleet, arrivals, lcfg, cache, sink, metrics)
    }
}

/// Streaming replay (the ISSUE 4 tentpole): requests are admitted
/// continuously as they arrive; the board with the earliest clock pulls
/// the next same-job run (up to its own grain, [`Fleet::grains`]) from
/// the front of the admitted queue — work-conserving backfill, no wave
/// barrier. A board facing an empty queue idles only until the next
/// arrival. Deterministic: pure virtual time (ties go to the lowest
/// board id), same arrivals ⇒ same timeline, bit for bit.
///
/// Degeneracy anchor: when every request arrives at t = 0 with one
/// shape, the replay is exactly [`simulate_fleet`] under fleet-DAS —
/// same grab sequence, same clock arithmetic, bit-for-bit equal
/// makespan/energy/per-board tallies (pinned by tests).
pub fn simulate_fleet_stream(fleet: &Fleet, arrivals: &[Arrival]) -> StreamStats {
    StreamSim::new(fleet).run(arrivals)
}

/// [`simulate_fleet_stream`] against a caller-owned [`RunCache`]: a
/// warm cache replays a stream without a single DES run (`des_runs`
/// = 0), bit-for-bit identical to the fresh replay.
pub fn simulate_fleet_stream_cached(
    fleet: &Fleet,
    arrivals: &[Arrival],
    cache: &mut RunCache,
) -> StreamStats {
    StreamSim::new(fleet).cache(cache).run(arrivals)
}

/// The streaming replay with observability attached — delegates to
/// [`StreamSim`] with a sink and registry. See [`stream_engine`]'s
/// notes on the trace layout and the zero-overhead contract.
pub fn simulate_fleet_stream_traced(
    fleet: &Fleet,
    arrivals: &[Arrival],
    cache: &mut RunCache,
    sink: &mut dyn TraceSink,
    metrics: &mut MetricsRegistry,
) -> StreamStats {
    StreamSim::new(fleet).cache(cache).sink(sink).metrics(metrics).run(arrivals)
}

/// The streaming engine. Every event the replay computes can be
/// mirrored into `sink` (request flows, execute spans, per-cluster
/// phase spans, cache instants, a queue depth counter series) and
/// `metrics` (admission/completion/grab counters, sojourn +
/// service-time histograms, per-board energy).
///
/// **Zero-overhead contract**: all instrumentation is behind
/// `sink.enabled()` / `metrics.enabled()` guards and never feeds back
/// into the clock arithmetic, so the returned [`StreamStats`] is
/// bit-for-bit identical whichever sink is passed (pinned by
/// `tests/obs_props.rs`), and with the [`NullSink`] pair this *is*
/// the PR 6 fast path (pinned by the `obs_off_events_per_s` /
/// `obs_trace_overhead_ratio` perf-trajectory rows).
///
/// Trace layout: one process per board (pid = board index, tid 0 the
/// request track, tid 1+c the phase track of cluster `c`) plus a
/// dispatcher process (pid = board count) carrying admission instants,
/// flow starts and the queue-depth counter. Phase spans replay the
/// per-item [`Timeline`] of a separate [`simulate_traced`] run per
/// distinct `(board, shape)` — trace mode pays that extra DES, the
/// replay's own cache never sees it. GEMM execute spans keep their
/// historical `gemm {m}x{n}x{k}` names ([`JobSpec::label`]); non-GEMM
/// jobs get a labelled span without per-cluster phase replay.
fn stream_engine(
    fleet: &Fleet,
    arrivals: &[Arrival],
    cache: &mut RunCache,
    sink: &mut dyn TraceSink,
    metrics: &mut MetricsRegistry,
) -> StreamStats {
    // An empty stream is legal: the replay loop below never starts and
    // `finish_stream_stats` reports well-formed all-zero stats (no NaN
    // ratios, no panicking quantiles) — pinned by the empty-arrivals
    // test.
    let n = fleet.num_boards();
    let (hits0, misses0) = (cache.hits(), cache.misses());
    let cfgs = board_configs(fleet, cache);
    let grains = fleet.grains();
    if sink.enabled() {
        for (b, board) in fleet.boards.iter().enumerate() {
            sink.record(TraceEvent::process_name(b, &board.name));
            sink.record(TraceEvent::thread_name(b, 0, "requests"));
            for c in 0..board.soc().clusters.len() {
                sink.record(TraceEvent::thread_name(b, 1 + c, &format!("cluster c{c}")));
            }
        }
        sink.record(TraceEvent::process_name(n, "dispatcher"));
        sink.record(TraceEvent::thread_name(n, 0, "admissions"));
    }
    metrics.inc("stream_admissions", arrivals.len() as f64);

    let mut clock = vec![0.0f64; n];
    // Last-completion instant per board — distinct from the scheduling
    // clock, which idle-waiting also advances (a board that jumps to
    // the next arrival but loses the grab must not report that jump as
    // its finish).
    let mut finish = vec![0.0f64; n];
    let mut items = vec![0usize; n];
    let mut grabs = vec![0u64; n];
    let mut counts: Vec<BTreeMap<(ConfigId, JobSpec), usize>> = vec![BTreeMap::new(); n];
    let mut priced: BTreeMap<(ConfigId, JobSpec), JobPrice> = BTreeMap::new();
    let mut completions = vec![f64::NAN; arrivals.len()];
    let mut depth_events: EventQueue<i64> = EventQueue::with_capacity(2 * arrivals.len());
    // Pending requests, heap-keyed (arrive_s, submission index): the
    // head is always the next item in `admission_order_by` order, at
    // O(log n) per event instead of a full up-front sort. The acting
    // board's clock is the fleet minimum and never decreases, so every
    // request admitted by an earlier iteration still satisfies
    // `arrive_s <= clock[b]` — head-of-heap under that bound is exactly
    // the old sorted-order admission cursor plus FIFO ready queue.
    let mut pending: EventQueue<usize> = EventQueue::with_capacity(arrivals.len());
    for (i, a) in arrivals.iter().enumerate() {
        assert_arrival_instant(i, a.arrive_s);
        pending.push_tied(a.arrive_s, i as i64, i);
        // Queue-depth +1 at each arrival; rank −1 orders arrivals ahead
        // of any same-instant grab (positive rank) in the depth replay.
        depth_events.push_tied(a.arrive_s, -1, 1);
        if sink.enabled() {
            sink.record(TraceEvent::instant("admit", "request", n, 0, a.arrive_s));
            sink.record(TraceEvent::flow_start(
                &format!("req {i}"),
                "request",
                n,
                0,
                a.arrive_s,
                i as u64,
            ));
        }
    }
    let mut run: Vec<usize> = Vec::with_capacity(grains.iter().copied().max().unwrap_or(1));
    let mut executed = 0usize;
    // Per-(board, shape) phase timelines for the cluster tracks —
    // recorded lazily on first execution, trace mode only.
    let mut timelines: HashMap<(usize, GemmShape), Timeline> = HashMap::new();

    while executed < arrivals.len() {
        // The board with the earliest clock acts next (ties: lowest id).
        let mut b = 0;
        for c in 1..n {
            if clock[c] < clock[b] {
                b = c;
            }
        }
        let (t_next, &head) = pending.peek().expect("requests remain");
        if t_next > clock[b] {
            // Nothing admitted yet: idle this board to the next arrival
            // (strictly later than its clock).
            clock[b] = t_next;
            continue;
        }
        // Work-conserving grab: a consecutive same-job run of up to
        // the board's grain from the front of the admitted queue.
        let job = arrivals[head].job;
        run.clear();
        while run.len() < grains[b] {
            match pending.peek() {
                Some((t, &id)) if t <= clock[b] && arrivals[id].job == job => {
                    run.push(id);
                    pending.pop();
                }
                _ => break,
            }
        }
        let take = run.len();
        let hits_before = cache.hits();
        // GEMM/level-3 jobs re-price every grab (preserving the cache
        // hit/miss counters the stats surface); factorizations memoize
        // through `priced` so the graph is scheduled once per
        // (config, job) instead of once per grab.
        let key = (cfgs[b], job);
        let st = match priced.get(&key) {
            Some(p) if matches!(job, JobSpec::Factor { .. }) => p.clone(),
            _ => price_job(&fleet.boards[b], &fleet.boards[b].sched, cfgs[b], job, cache),
        };
        let start = clock[b];
        depth_events.push_tied(start, take as i64, -(take as i64));
        clock[b] += DISPATCH_S + take as f64 * st.time_s;
        finish[b] = clock[b];
        for (j, &id) in run.iter().enumerate() {
            debug_assert!(completions[id].is_nan(), "request {id} executed twice");
            completions[id] = start + DISPATCH_S + (j + 1) as f64 * st.time_s;
        }
        if sink.enabled() {
            sink.record(TraceEvent::instant(
                if cache.hits() > hits_before { "cache_hit" } else { "cache_miss" },
                "cache",
                b,
                0,
                start,
            ));
            let span_name = job.label();
            for (j, &id) in run.iter().enumerate() {
                let t0 = start + DISPATCH_S + j as f64 * st.time_s;
                sink.record(TraceEvent::flow_step(&format!("req {id}"), "request", b, 0, t0, id as u64));
                sink.record(TraceEvent::span(&span_name, "execute", b, 0, t0, st.time_s));
                if let JobSpec::Gemm(shape) = job {
                    let tl = timelines.entry((b, shape)).or_insert_with(|| {
                        simulate_traced(fleet.boards[b].model(), &fleet.boards[b].sched, shape).1
                    });
                    tl.emit_to(sink, b, 1, t0);
                }
                sink.record(TraceEvent::flow_end(
                    &format!("req {id}"),
                    "request",
                    b,
                    0,
                    completions[id],
                    id as u64,
                ));
            }
        }
        if metrics.enabled() {
            metrics.inc("stream_grabs", 1.0);
            metrics.inc(&format!("board{b}_items"), take as f64);
            for _ in 0..take {
                metrics.observe("service_time_s", st.time_s);
            }
        }
        items[b] += take;
        grabs[b] += 1;
        *counts[b].entry(key).or_insert(0) += take;
        priced.entry(key).or_insert(st);
        executed += take;
    }
    if metrics.enabled() {
        metrics.inc("stream_des_runs", (cache.misses() - misses0) as f64);
        metrics.inc("stream_cache_hits", (cache.hits() - hits0) as f64);
        cache.export_metrics(metrics);
    }

    finish_stream_stats(
        fleet,
        format!("stream [{}]", board_names(fleet)),
        arrivals,
        &priced,
        &counts,
        &items,
        &grabs,
        &finish,
        completions,
        depth_events,
        cache.misses() - misses0,
        cache.hits() - hits0,
        sink,
        metrics,
    )
}

/// Knobs of the live-calibrating streaming replay (ISSUE 9).
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct LiveStreamConfig {
    /// EWMA half-life in accepted observations
    /// ([`LiveRateTable::new`]).
    pub half_life_events: f64,
    /// Per-cell confidence threshold: below it the analytical rate
    /// serves ([`WeightSource::Live`]).
    pub min_samples: u64,
    /// Re-plan period: every this-many grabs a board running a
    /// weighted-static schedule (SAS / CA-SAS) re-derives its weight
    /// vector from the live table. Must be >= 1.
    pub replan_every: usize,
}

impl Default for LiveStreamConfig {
    fn default() -> LiveStreamConfig {
        LiveStreamConfig { half_life_events: 32.0, min_samples: 8, replan_every: 16 }
    }
}

/// What one board learned over a live replay.
#[derive(Debug, Clone, PartialEq)]
pub struct LiveBoardReport {
    /// The board's learned table — freeze it with
    /// [`LiveRateTable::snapshot`] for a bit-for-bit deterministic
    /// replay through [`WeightSource::Empirical`].
    pub table: LiveRateTable,
    /// Accepted observations at the instant every learned cell first
    /// crossed the confidence gate (`None` if the board never warmed
    /// up) — the `live_warmup_events` trajectory row.
    pub warmup_events: Option<u64>,
    /// Mid-stream re-plans that actually changed the board's schedule.
    pub replans: u64,
}

/// [`simulate_fleet_stream`] with online calibration in the loop (the
/// ISSUE 9 tentpole): every completed grab feeds per-cluster
/// `(flops, service)` observations into a per-board [`LiveRateTable`],
/// and boards running weighted-static schedules (SAS / CA-SAS)
/// re-derive their weight vector from the live table every
/// `cfg.replan_every` grabs through [`WeightSource::Live`] — the
/// analytical rate serves per-cell until the cell's sample count
/// crosses `cfg.min_samples`. (The DVFS axis re-plans at *epoch
/// boundaries* instead: hand [`WeightSource::Live`] to
/// [`crate::dvfs::DvfsStrategy::to_spec_with`] and every epoch's
/// weight vector is re-derived the same way.)
///
/// The observed per-cluster rate of a completion is busy-time based:
/// cluster `c` retired `cluster_flops[c]` useful flops over a mean
/// per-core busy time of `busy_c / num_cores_c`, so the observation is
/// `flops · n / (busy · 1e9)` GFLOPS — quantization-free under both
/// static shards and dynamic grabs. Clusters a schedule left inactive
/// (zero flops) are skipped silently; degenerate observations
/// (zero/NaN busy time) are *counted* at the
/// [`LiveRateTable::observe`] gate.
///
/// Determinism: the table is a pure fold over the replay's own event
/// sequence and re-planning depends only on it, so two runs over the
/// same arrivals are bit-for-bit identical — stats, tables and re-plan
/// instants alike (property-tested in `tests/live_props.rs`).
pub fn simulate_fleet_stream_live(
    fleet: &Fleet,
    arrivals: &[Arrival],
    cfg: LiveStreamConfig,
) -> (StreamStats, Vec<LiveBoardReport>) {
    StreamSim::new(fleet).live(cfg).run_live(arrivals)
}

/// [`simulate_fleet_stream_live`] against a caller-owned cache, trace
/// sink and metrics registry — delegates to [`StreamSim`]. Per-cell
/// sample-count gauges (`board<b>_live_samples_*`) and
/// accepted/rejected totals reach the registry after the replay;
/// instrumentation never feeds back into the clock arithmetic (same
/// zero-overhead contract as the plain streaming engine).
pub fn simulate_fleet_stream_live_traced(
    fleet: &Fleet,
    arrivals: &[Arrival],
    lcfg: LiveStreamConfig,
    cache: &mut RunCache,
    sink: &mut dyn TraceSink,
    metrics: &mut MetricsRegistry,
) -> (StreamStats, Vec<LiveBoardReport>) {
    StreamSim::new(fleet).live(lcfg).cache(cache).sink(sink).metrics(metrics).run_live(arrivals)
}

/// The live-calibrating streaming engine (ISSUE 9). Non-GEMM jobs ride
/// along: level-3 jobs feed the observation loop through their
/// equivalent GEMM's run stats (time and flops scale together, so the
/// learned *rate* is unchanged); `Factor` jobs feed nothing — their
/// tile kernels run under per-cluster `cluster_only` configurations,
/// not the board's own schedule, so their completions say nothing
/// about the board-schedule rate cells the table learns.
fn live_engine(
    fleet: &Fleet,
    arrivals: &[Arrival],
    lcfg: LiveStreamConfig,
    cache: &mut RunCache,
    sink: &mut dyn TraceSink,
    metrics: &mut MetricsRegistry,
) -> (StreamStats, Vec<LiveBoardReport>) {
    assert!(lcfg.replan_every >= 1, "replan period must be >= 1");
    let n = fleet.num_boards();
    let (hits0, misses0) = (cache.hits(), cache.misses());
    // Mutable per-board schedule state: re-planning swaps the weight
    // vector (and thus the interned configuration) mid-stream; the
    // coarse/fine loop orders of the board's original spec are kept.
    let mut scheds: Vec<ScheduleSpec> = fleet.boards.iter().map(|b| b.sched).collect();
    let mut cfgs: Vec<ConfigId> = fleet
        .boards
        .iter()
        .zip(&scheds)
        .map(|(b, s)| cache.config(b.model(), s))
        .collect();
    let grains = fleet.grains();
    let opps: Vec<Vec<usize>> = fleet.boards.iter().map(|b| current_opps(b.soc())).collect();
    let mut live: Vec<LiveRateTable> = fleet
        .boards
        .iter()
        .map(|b| LiveRateTable::new(b.soc(), lcfg.half_life_events))
        .collect();
    let mut warmup: Vec<Option<u64>> = vec![None; n];
    let mut replans = vec![0u64; n];
    metrics.inc("stream_admissions", arrivals.len() as f64);

    let mut clock = vec![0.0f64; n];
    let mut finish = vec![0.0f64; n];
    let mut items = vec![0usize; n];
    let mut grabs = vec![0u64; n];
    let mut counts: Vec<BTreeMap<(ConfigId, JobSpec), usize>> = vec![BTreeMap::new(); n];
    let mut priced: BTreeMap<(ConfigId, JobSpec), JobPrice> = BTreeMap::new();
    let mut completions = vec![f64::NAN; arrivals.len()];
    let mut depth_events: EventQueue<i64> = EventQueue::with_capacity(2 * arrivals.len());
    let mut pending: EventQueue<usize> = EventQueue::with_capacity(arrivals.len());
    for (i, a) in arrivals.iter().enumerate() {
        assert_arrival_instant(i, a.arrive_s);
        pending.push_tied(a.arrive_s, i as i64, i);
        depth_events.push_tied(a.arrive_s, -1, 1);
    }
    let mut run: Vec<usize> = Vec::with_capacity(grains.iter().copied().max().unwrap_or(1));
    let mut executed = 0usize;

    while executed < arrivals.len() {
        let mut b = 0;
        for c in 1..n {
            if clock[c] < clock[b] {
                b = c;
            }
        }
        let (t_next, &head) = pending.peek().expect("requests remain");
        if t_next > clock[b] {
            clock[b] = t_next;
            continue;
        }
        let job = arrivals[head].job;
        run.clear();
        while run.len() < grains[b] {
            match pending.peek() {
                Some((t, &id)) if t <= clock[b] && arrivals[id].job == job => {
                    run.push(id);
                    pending.pop();
                }
                _ => break,
            }
        }
        let take = run.len();
        let key = (cfgs[b], job);
        let st = match priced.get(&key) {
            Some(p) if matches!(job, JobSpec::Factor { .. }) => p.clone(),
            _ => price_job(&fleet.boards[b], &scheds[b], cfgs[b], job, cache),
        };
        let start = clock[b];
        depth_events.push_tied(start, take as i64, -(take as i64));
        clock[b] += DISPATCH_S + take as f64 * st.time_s;
        finish[b] = clock[b];
        for (j, &id) in run.iter().enumerate() {
            debug_assert!(completions[id].is_nan(), "request {id} executed twice");
            completions[id] = start + DISPATCH_S + (j + 1) as f64 * st.time_s;
        }
        if metrics.enabled() {
            metrics.inc("stream_grabs", 1.0);
            metrics.inc(&format!("board{b}_items"), take as f64);
            for _ in 0..take {
                metrics.observe("service_time_s", st.time_s);
            }
        }
        items[b] += take;
        grabs[b] += 1;
        *counts[b].entry(key).or_insert(0) += take;
        priced.entry(key).or_insert(st);
        executed += take;

        // --- Online calibration: feed the completion back. GEMM jobs
        // observe their own run; level-3 jobs observe their equivalent
        // GEMM (flops and service scale together, so the rate is the
        // same); factorizations observe nothing (their tiles ran under
        // cluster_only configurations, not this board schedule). ---
        let observed = match job {
            JobSpec::Gemm(s) => Some(s),
            JobSpec::Level3 { .. } => Some(job.equiv_gemm()),
            JobSpec::Factor { .. } => None,
        };
        if let Some(shape) = observed {
            let stats = cache.peek(cfgs[b], shape).expect("executed shapes are cached");
            let family = Family::of(scheds[b].strategy.is_cache_aware());
            let soc = fleet.boards[b].soc();
            for c in soc.cluster_ids() {
                let flops_c = stats.cluster_flops[c.0];
                if flops_c <= 0.0 {
                    continue; // cluster left inactive by the schedule
                }
                let busy_c: f64 = soc.core_ids(c).map(|gid| stats.activity[gid].busy_s).sum();
                let service_c = busy_c / soc[c].num_cores as f64;
                live[b].observe_weighted(c, opps[b][c.0], family, shape, flops_c, service_c, take as u64);
            }
            if warmup[b].is_none() && live[b].warmed_up(lcfg.min_samples) {
                warmup[b] = Some(live[b].accepted());
            }
        }

        // --- Re-plan point: every `replan_every` grabs, weighted-static
        // boards re-derive their split from the live table. ---
        if grabs[b] % lcfg.replan_every as u64 == 0 {
            let model = fleet.boards[b].model();
            let source = WeightSource::Live { table: live[b].clone(), min_samples: lcfg.min_samples };
            let class = live[b].classify(job.equiv_gemm());
            let new_strategy = match scheds[b].strategy {
                Strategy::Sas { .. } => {
                    Some(Strategy::Sas { weights: source.weights(model, false, class) })
                }
                Strategy::CaSas { .. } => {
                    Some(Strategy::CaSas { weights: source.weights(model, true, class) })
                }
                _ => None, // dynamic / cluster-only schedules carry no weights
            };
            if let Some(strategy) = new_strategy {
                let spec = ScheduleSpec::new(strategy, scheds[b].coarse, scheds[b].fine);
                if spec != scheds[b] {
                    scheds[b] = spec;
                    cfgs[b] = cache.config(model, &spec);
                    replans[b] += 1;
                    if sink.enabled() {
                        sink.record(TraceEvent::instant("replan", "live", b, 0, clock[b]));
                    }
                }
            }
        }
    }
    if metrics.enabled() {
        metrics.inc("stream_des_runs", (cache.misses() - misses0) as f64);
        metrics.inc("stream_cache_hits", (cache.hits() - hits0) as f64);
        cache.export_metrics(metrics);
        for (b, table) in live.iter().enumerate() {
            table.export_metrics(metrics, &format!("board{b}_live"));
            metrics.set_gauge(&format!("board{b}_live_replans"), replans[b] as f64);
        }
    }

    let stats = finish_stream_stats(
        fleet,
        format!("live stream [{}]", board_names(fleet)),
        arrivals,
        &priced,
        &counts,
        &items,
        &grabs,
        &finish,
        completions,
        depth_events,
        cache.misses() - misses0,
        cache.hits() - hits0,
        sink,
        metrics,
    );
    let reports = live
        .into_iter()
        .zip(warmup)
        .zip(replans)
        .map(|((table, warmup_events), replans)| LiveBoardReport { table, warmup_events, replans })
        .collect();
    (stats, reports)
}

/// Wave-mode comparator: the same arrival stream replayed under
/// today's synchronous discipline — requests group into same-shape
/// waves of at most `max_group` (admission order, the
/// [`Batcher`] contract), and wave `g` starts only when its last
/// member has arrived *and* wave `g-1` has fully finished (the wave
/// barrier). Within a wave the batch is sharded by `strategy` exactly
/// as [`simulate_fleet`] shards it.
///
/// Degeneracy: all requests at t = 0 with one shape (≤ `max_group`)
/// form a single wave starting at 0 — bit-for-bit [`simulate_fleet`]
/// for every strategy (pinned by tests).
pub fn simulate_fleet_waves(
    fleet: &Fleet,
    strategy: FleetStrategy,
    arrivals: &[Arrival],
    max_group: usize,
) -> StreamStats {
    StreamSim::new(fleet).waves(strategy, max_group).run(arrivals)
}

/// [`simulate_fleet_waves`] against a caller-owned [`RunCache`] — the
/// comparator and the stream it is compared to can share one cache, so
/// the comparison never pays the DES twice.
pub fn simulate_fleet_waves_cached(
    fleet: &Fleet,
    strategy: FleetStrategy,
    arrivals: &[Arrival],
    max_group: usize,
    cache: &mut RunCache,
) -> StreamStats {
    StreamSim::new(fleet).waves(strategy, max_group).cache(cache).run(arrivals)
}

/// The wave-discipline engine behind [`StreamSim::waves`].
fn waves_engine(
    fleet: &Fleet,
    strategy: FleetStrategy,
    arrivals: &[Arrival],
    max_group: usize,
    cache: &mut RunCache,
    sink: &mut dyn TraceSink,
    metrics: &mut MetricsRegistry,
) -> StreamStats {
    // Empty streams form zero waves and fall straight through to the
    // all-zero stats, mirroring the streaming replay's convention.
    let n = fleet.num_boards();
    let order = admission_order(arrivals);
    let (hits0, misses0) = (cache.hits(), cache.misses());
    let cfgs = board_configs(fleet, cache);
    let grains = fleet.grains();

    // Same-job waves in admission order.
    let mut batcher: Batcher<JobSpec, usize> = Batcher::new(max_group);
    let mut waves: Vec<(JobSpec, Vec<usize>)> = Vec::new();
    for &i in &order {
        if let Some(g) = batcher.push_keyed(arrivals[i].job, i) {
            waves.push(g);
        }
    }
    waves.extend(batcher.drain_keyed());

    let mut items = vec![0usize; n];
    let mut grabs = vec![0u64; n];
    let mut counts: Vec<BTreeMap<(ConfigId, JobSpec), usize>> = vec![BTreeMap::new(); n];
    let mut priced: BTreeMap<(ConfigId, JobSpec), JobPrice> = BTreeMap::new();
    let mut finish = vec![0.0f64; n];
    let mut completions = vec![f64::NAN; arrivals.len()];
    let mut depth_events: EventQueue<i64> = EventQueue::with_capacity(2 * arrivals.len());
    let mut prev_end = 0.0f64;
    // Per-board pricing closure mirroring the streaming engine's
    // policy: GEMM/level-3 jobs re-price every grab (the hit/miss
    // counters), factorizations memoize their DAG schedule.
    let mut price = |b: usize, job: JobSpec, cache: &mut RunCache,
                     priced: &mut BTreeMap<(ConfigId, JobSpec), JobPrice>|
     -> JobPrice {
        let key = (cfgs[b], job);
        let p = match priced.get(&key) {
            Some(p) if matches!(job, JobSpec::Factor { .. }) => p.clone(),
            _ => price_job(&fleet.boards[b], &fleet.boards[b].sched, cfgs[b], job, cache),
        };
        priced.entry(key).or_insert_with(|| p.clone());
        p
    };

    for (job, members) in &waves {
        let count = members.len();
        let ready = members
            .iter()
            .map(|&i| arrivals[i].arrive_s)
            .fold(0.0, f64::max);
        let start = prev_end.max(ready);
        for &i in members {
            depth_events.push_tied(arrivals[i].arrive_s, -1, 1);
        }
        depth_events.push_tied(start, count as i64, -(count as i64));
        // Per-item times are looked up lazily per participating board —
        // a board whose shard is empty (or that never wins a grab)
        // never pays a DES run for this job; the cache makes repeats
        // free.
        let mut wclock = vec![start; n];
        match strategy {
            FleetStrategy::Sss | FleetStrategy::Sas => {
                let shards = fleet.static_shards(count, strategy);
                let mut offset = 0;
                for (b, &share) in shards.iter().enumerate() {
                    if share == 0 {
                        continue;
                    }
                    let ids = &members[offset..offset + share];
                    offset += share;
                    let time_s = price(b, *job, cache, &mut priced).time_s;
                    wclock[b] = start + (DISPATCH_S + share as f64 * time_s);
                    for (j, &id) in ids.iter().enumerate() {
                        completions[id] = start + (DISPATCH_S + (j + 1) as f64 * time_s);
                    }
                    items[b] += share;
                    grabs[b] += 1;
                    *counts[b].entry((cfgs[b], *job)).or_insert(0) += share;
                    finish[b] = wclock[b];
                }
            }
            FleetStrategy::Das => {
                let mut next = 0usize;
                while next < count {
                    let mut idx = 0;
                    for b in 1..n {
                        if wclock[b] < wclock[idx] {
                            idx = b;
                        }
                    }
                    let take = grains[idx].min(count - next);
                    let t0 = wclock[idx];
                    let time_s = price(idx, *job, cache, &mut priced).time_s;
                    wclock[idx] += DISPATCH_S + take as f64 * time_s;
                    for (j, &id) in members[next..next + take].iter().enumerate() {
                        completions[id] = t0 + DISPATCH_S + (j + 1) as f64 * time_s;
                    }
                    next += take;
                    items[idx] += take;
                    grabs[idx] += 1;
                    *counts[idx].entry((cfgs[idx], *job)).or_insert(0) += take;
                    finish[idx] = wclock[idx];
                }
            }
        }
        // The barrier: no board starts the next wave before this one
        // fully drains. Every wave has members, so the max is always a
        // participating board's finish — `finish` therefore carries the
        // run's makespan and `prev_end` only gates the next start.
        prev_end = wclock.iter().cloned().fold(start, f64::max);
    }

    finish_stream_stats(
        fleet,
        format!("wave {} [{}]", strategy.label(), board_names(fleet)),
        arrivals,
        &priced,
        &counts,
        &items,
        &grabs,
        &finish,
        completions,
        depth_events,
        cache.misses() - misses0,
        cache.hits() - hits0,
        sink,
        metrics,
    )
}

/// Capacity planning: the smallest homogeneous fleet of `board` clones
/// sustaining `target_rps` requests per second on `shape` batches of
/// `batch` items, up to `max_boards` (clamped to the fleet capacity,
/// [`crate::sched::MAX_WAYS`]). `None` if even the largest fleet can't.
/// The plan prices whatever the board's `weight_source` says it
/// sustains — hand it a [`crate::fleet::Board::calibrated`] board and
/// the capacity answer runs off measured rates instead of the
/// analytical model.
pub fn boards_to_sustain(
    board: &crate::fleet::Board,
    shape: GemmShape,
    batch: usize,
    target_rps: f64,
    max_boards: usize,
) -> Option<usize> {
    assert!(target_rps > 0.0 && max_boards >= 1);
    // One cache across the whole growth sweep: the fleets are clones of
    // one board, so the entire search costs a single DES run.
    let mut cache = RunCache::new();
    for n in 1..=max_boards.min(crate::sched::MAX_WAYS) {
        let fleet = Fleet::homogeneous(n, board);
        let st = simulate_fleet_cached(&fleet, FleetStrategy::Das, shape, batch, &mut cache);
        if st.throughput_rps >= target_rps {
            return Some(n);
        }
    }
    None
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::fleet::Board;
    use crate::util::prop;

    fn hetero() -> Fleet {
        Fleet::parse("exynos5422,juno_r0").unwrap()
    }

    /// A strongly asymmetric two-board pair (≈ 1.7× aggregate
    /// throughput gap) for strict win assertions; exynos vs juno is
    /// heterogeneous but nearly throughput-matched.
    fn skewed() -> Fleet {
        Fleet::parse("exynos5422,dynamiq_3c").unwrap()
    }

    /// The ISSUE acceptance criterion: on a heterogeneous two-board
    /// fleet, dynamic fleet-DAS beats the equal-shard fleet-SSS in
    /// virtual time — the paper's intra-SoC result one level up.
    #[test]
    fn das_beats_sss_on_heterogeneous_fleet() {
        let shape = GemmShape::square(1024);
        let sss = simulate_fleet(&skewed(), FleetStrategy::Sss, shape, 32);
        let das = simulate_fleet(&skewed(), FleetStrategy::Das, shape, 32);
        assert!(
            das.makespan_s < 0.90 * sss.makespan_s,
            "fleet-DAS {:.3}s must beat fleet-SSS {:.3}s",
            das.makespan_s,
            sss.makespan_s
        );
        // The oblivious equal split leaves the faster board idling at
        // baseline; the balanced schedule also wins on energy.
        assert!(das.gflops_per_watt > sss.gflops_per_watt);
        // And on the nearly-matched exynos+juno pair the dynamic queue
        // must never lose materially to the equal split.
        let sss2 = simulate_fleet(&hetero(), FleetStrategy::Sss, shape, 32);
        let das2 = simulate_fleet(&hetero(), FleetStrategy::Das, shape, 32);
        assert!(
            das2.makespan_s < 1.02 * sss2.makespan_s,
            "fleet-DAS {:.3}s vs fleet-SSS {:.3}s on a matched pair",
            das2.makespan_s,
            sss2.makespan_s
        );
    }

    #[test]
    fn sas_tracks_das_within_quantization() {
        let shape = GemmShape::square(1024);
        let sas = simulate_fleet(&skewed(), FleetStrategy::Sas, shape, 64);
        let das = simulate_fleet(&skewed(), FleetStrategy::Das, shape, 64);
        let rel = (sas.makespan_s / das.makespan_s - 1.0).abs();
        assert!(rel < 0.20, "fleet-SAS {:.3}s vs fleet-DAS {:.3}s", sas.makespan_s, das.makespan_s);
    }

    #[test]
    fn single_board_fleet_degenerates() {
        let f = Fleet::parse("exynos5422").unwrap();
        let shape = GemmShape::square(512);
        let st = simulate_fleet(&f, FleetStrategy::Das, shape, 8);
        assert_eq!(st.items_completed(), 8);
        assert_eq!(st.boards.len(), 1);
        // Makespan = dispatches + 8 serial items.
        let item = simulate(f.boards[0].model(), &f.boards[0].sched, shape).time_s;
        assert!(st.makespan_s >= 8.0 * item);
        assert!(st.throughput_rps > 0.0);
    }

    #[test]
    fn deterministic() {
        let shape = GemmShape::square(768);
        let a = simulate_fleet(&hetero(), FleetStrategy::Das, shape, 24);
        let b = simulate_fleet(&hetero(), FleetStrategy::Das, shape, 24);
        assert_eq!(a.makespan_s, b.makespan_s);
        assert_eq!(a.energy_j, b.energy_j);
        assert_eq!(
            a.boards.iter().map(|x| x.items).collect::<Vec<_>>(),
            b.boards.iter().map(|x| x.items).collect::<Vec<_>>()
        );
    }

    /// ISSUE satellite: fleet-DAS completes every item for 1–4 boards of
    /// mixed presets (the board-level queue-drain property test).
    #[test]
    fn prop_das_completes_all_items_on_mixed_fleets() {
        let presets = ["exynos5422", "juno_r0", "dynamiq_3c", "symmetric2"];
        prop::check_default(
            |r| {
                let n = r.gen_range(1, 5); // 1..=4 boards
                let toks: Vec<&str> = (0..n).map(|_| *r.choose(&presets)).collect();
                (toks.join(","), r.gen_range(1, 50))
            },
            |(list, batch)| {
                let fleet = Fleet::parse(list).map_err(|e| e.to_string())?;
                let st =
                    simulate_fleet(&fleet, FleetStrategy::Das, GemmShape::square(256), *batch);
                if st.items_completed() != *batch {
                    return Err(format!(
                        "{} of {batch} items completed: {:?}",
                        st.items_completed(),
                        st.boards.iter().map(|b| b.items).collect::<Vec<_>>()
                    ));
                }
                // Per-board accounting must be consistent.
                for b in &st.boards {
                    if b.finish_s > st.makespan_s + 1e-12 {
                        return Err(format!("board {} finishes after the makespan", b.name));
                    }
                    if b.items > 0 && b.grabs == 0 {
                        return Err(format!("board {} has items but no grabs", b.name));
                    }
                }
                Ok(())
            },
        );
    }

    #[test]
    fn static_strategies_complete_and_weight_shards() {
        let shape = GemmShape::square(512);
        let sss = simulate_fleet(&hetero(), FleetStrategy::Sss, shape, 40);
        assert_eq!(sss.items_completed(), 40);
        assert_eq!(sss.boards[0].items, sss.boards[1].items, "SSS splits equally");
        let sas = simulate_fleet(&hetero(), FleetStrategy::Sas, shape, 40);
        assert_eq!(sas.items_completed(), 40);
        // The Exynos board out-rates the Juno r0 → bigger SAS shard.
        let w = hetero().weights();
        if w.as_slice()[0] > w.as_slice()[1] {
            assert!(sas.boards[0].items > sas.boards[1].items, "{:?}", sas.boards);
        } else {
            assert!(sas.boards[1].items > sas.boards[0].items, "{:?}", sas.boards);
        }
    }

    #[test]
    fn energy_accounts_idle_tail() {
        // A single-item batch: one board executes, the other idles the
        // whole run — its rails must still be charged at baseline for
        // the full makespan (the §3.4 idle-cluster accounting, one
        // level up).
        let shape = GemmShape::square(512);
        let st = simulate_fleet(&hetero(), FleetStrategy::Sss, shape, 1);
        assert_eq!(st.items_completed(), 1);
        let idle = st.boards.iter().find(|b| b.items == 0).expect("one idle board");
        assert!(idle.energy_j > 0.0, "idle board still burns its rails");
        let sum: f64 = st.boards.iter().map(|b| b.energy_j).sum();
        assert!((sum - st.energy_j).abs() < 1e-9);
        assert!(st.gflops_per_watt > 0.0);
    }

    #[test]
    fn capacity_planning_grows_with_target() {
        let ex = Board::from_preset("exynos5422").unwrap();
        let shape = GemmShape::square(1024);
        let one = simulate_fleet(&Fleet::homogeneous(1, &ex), FleetStrategy::Das, shape, 16);
        let rps1 = one.throughput_rps;
        assert_eq!(boards_to_sustain(&ex, shape, 16, 0.5 * rps1, 8), Some(1));
        let n = boards_to_sustain(&ex, shape, 16, 2.5 * rps1, 8).unwrap();
        assert!(n >= 3, "2.5× one board's rate needs ≥ 3 boards, got {n}");
        assert_eq!(boards_to_sustain(&ex, shape, 16, 1e9, 2), None);
    }

    /// ISSUE 3: nominal per-board schedules make the fleet DVFS path a
    /// provable no-op (delegates to the fixed-frequency simulator).
    #[test]
    fn fleet_dvfs_nominal_is_a_noop() {
        use crate::dvfs::DvfsSchedule;
        let fleet = hetero();
        let shape = GemmShape::square(512);
        let plans: Vec<DvfsSchedule> = fleet
            .boards
            .iter()
            .map(|b| DvfsSchedule::nominal(b.soc()))
            .collect();
        let a = simulate_fleet(&fleet, FleetStrategy::Das, shape, 16);
        let b = simulate_fleet_dvfs(&fleet, FleetStrategy::Das, shape, 16, &plans);
        assert_eq!(a.makespan_s, b.makespan_s);
        assert_eq!(a.energy_j, b.energy_j);
        assert_eq!(a.label, b.label, "no-op path keeps the static label");
    }

    /// ISSUE 3 satellite: fleet-DAS drains every item even when a
    /// board's OPP transition fires mid-batch — and the dynamic queue
    /// shifts items away from the board that slowed down.
    #[test]
    fn fleet_das_drains_across_mid_batch_transitions() {
        use crate::dvfs::{DvfsSchedule, Transition};
        use crate::soc::{ClusterId, SocSpec};
        let ex = Board::from_preset("exynos5422").unwrap();
        let fleet = Fleet::homogeneous(2, &ex);
        let shape = GemmShape::square(512);
        let batch = 40;
        // Board 0 drops both clusters to the ladder bottom partway
        // through the batch; board 1 stays nominal.
        let item_s = simulate(ex.model(), &ex.sched, shape).time_s;
        let nominal = DvfsSchedule::nominal(ex.soc());
        let mid = 0.5 * batch as f64 / 2.0 * item_s;
        let throttled = DvfsSchedule::new(
            SocSpec::exynos5422()
                .clusters
                .iter()
                .map(|c| c.opps.nominal_idx())
                .collect(),
            vec![
                Transition { t_s: mid, cluster: ClusterId(0), opp: 0 },
                Transition { t_s: mid, cluster: ClusterId(1), opp: 0 },
            ],
        );
        let plans = vec![throttled, nominal];
        let st = simulate_fleet_dvfs(&fleet, FleetStrategy::Das, shape, batch, &plans);
        assert_eq!(st.items_completed(), batch, "{:?}", st.boards);
        assert!(
            st.boards[1].items > st.boards[0].items,
            "the un-throttled board must absorb the imbalance: {:?}",
            st.boards.iter().map(|b| b.items).collect::<Vec<_>>()
        );
        // Deterministic replay, same schedule ⇒ same timeline.
        let again = simulate_fleet_dvfs(&fleet, FleetStrategy::Das, shape, batch, &plans);
        assert_eq!(st.makespan_s, again.makespan_s);
        assert_eq!(st.energy_j, again.energy_j);
        assert_eq!(
            st.boards.iter().map(|b| b.items).collect::<Vec<_>>(),
            again.boards.iter().map(|b| b.items).collect::<Vec<_>>()
        );
        // Static sharding drains too, just slower than the queue.
        let sss = simulate_fleet_dvfs(&fleet, FleetStrategy::Sss, shape, batch, &plans);
        assert_eq!(sss.items_completed(), batch);
        assert!(sss.makespan_s >= st.makespan_s);
    }

    /// An `@governor`-pinned board under a plan holding its own rung is
    /// the fixed-frequency simulator (delegation), while a plan moving
    /// it to the ladder top genuinely up-clocks it — `at_opp` derivation
    /// is absolute, never compounding.
    #[test]
    fn fleet_dvfs_respects_board_pinned_rungs() {
        use crate::dvfs::DvfsSchedule;
        let slow = Board::from_preset("exynos5422@powersave").unwrap();
        let fleet = Fleet::homogeneous(2, &slow);
        let shape = GemmShape::square(512);
        // Plans pinning the boards' own (bottom) rung: exact no-op.
        let hold = vec![DvfsSchedule::pinned(&[0, 0]); 2];
        let a = simulate_fleet(&fleet, FleetStrategy::Das, shape, 8);
        let b = simulate_fleet_dvfs(&fleet, FleetStrategy::Das, shape, 8, &hold);
        assert_eq!(a.makespan_s, b.makespan_s);
        assert_eq!(a.energy_j, b.energy_j);
        // Plans pinning the nominal rung up-clock the powersave boards.
        let up = vec![DvfsSchedule::nominal(slow.soc()); 2];
        let fast = simulate_fleet_dvfs(&fleet, FleetStrategy::Das, shape, 8, &up);
        assert!(
            fast.makespan_s < 0.7 * a.makespan_s,
            "up-clocked {:.3}s vs powersave {:.3}s",
            fast.makespan_s,
            a.makespan_s
        );
        assert_eq!(fast.items_completed(), 8);
    }

    /// ISSUE 3: per-board DVFS heterogeneity in the capacity planner —
    /// a powersave-pinned board sustains less, so the planner buys more
    /// of them for the same target.
    #[test]
    fn capacity_planner_prices_dvfs_heterogeneity() {
        let nominal = Board::from_preset("exynos5422").unwrap();
        let slow = Board::from_preset("exynos5422@powersave").unwrap();
        let shape = GemmShape::square(1024);
        let rps1 = simulate_fleet(&Fleet::homogeneous(1, &nominal), FleetStrategy::Das, shape, 16)
            .throughput_rps;
        let target = 1.5 * rps1;
        let need_nominal = boards_to_sustain(&nominal, shape, 16, target, 8).unwrap();
        let need_slow = boards_to_sustain(&slow, shape, 16, target, 8).unwrap();
        assert!(
            need_slow > need_nominal,
            "powersave boards must cost more: {need_slow} vs {need_nominal}"
        );
        // And a mixed-frequency fleet lands between the two.
        let mixed = Fleet::parse("exynos5422,exynos5422@powersave").unwrap();
        let st = simulate_fleet(&mixed, FleetStrategy::Das, shape, 32);
        let fast2 = simulate_fleet(
            &Fleet::homogeneous(2, &nominal),
            FleetStrategy::Das,
            shape,
            32,
        );
        let slow2 = simulate_fleet(&Fleet::homogeneous(2, &slow), FleetStrategy::Das, shape, 32);
        assert!(st.throughput_rps < fast2.throughput_rps);
        assert!(st.throughput_rps > slow2.throughput_rps);
    }

    /// ISSUE 8 satellite: an empty arrival stream must yield
    /// well-formed all-zero stats — no NaN ratios (the old
    /// `total_busy / (n * makespan)` hole), no panicking quantiles —
    /// in both the streaming replay and the wave comparator.
    #[test]
    fn empty_stream_yields_zero_stats_without_nan() {
        for st in [
            simulate_fleet_stream(&hetero(), &[]),
            simulate_fleet_waves(&hetero(), FleetStrategy::Das, &[], 4),
        ] {
            assert_eq!(st.requests, 0);
            assert_eq!(st.makespan_s, 0.0);
            assert_eq!(st.gflops, 0.0);
            assert_eq!(st.throughput_rps, 0.0);
            assert_eq!(st.utilization, 0.0);
            assert_eq!(st.sojourn_p50_s, 0.0);
            assert_eq!(st.sojourn_p99_s, 0.0);
            assert_eq!(st.mean_queue_depth, 0.0);
            assert_eq!(st.max_queue_depth, 0);
            assert!(st.energy_j == 0.0, "no makespan, no idle-rail charge");
            for b in &st.boards {
                assert_eq!(b.items, 0);
                assert_eq!(b.utilization, 0.0);
                assert!(b.energy_j == 0.0);
            }
        }
    }

    /// ISSUE 8 tentpole (fleet layer): the load-driven closed loop
    /// converges to plans that down-step early-finishing boards for
    /// their idle tail — strictly less energy than the open-loop
    /// time-ramp at (near-)equal makespan — and is deterministic.
    #[test]
    fn fleet_closed_loop_saves_idle_tail_energy() {
        let fleet = skewed(); // asymmetric pair → a real idle tail
        let shape = GemmShape::square(1024);
        let batch = 24;
        let gov = Ondemand::new(0.25);
        let mut cache = RunCache::new();
        let open: Vec<DvfsSchedule> =
            fleet.boards.iter().map(|b| gov.plan(b.soc(), 1e3)).collect();
        let open_st =
            simulate_fleet_dvfs_cached(&fleet, FleetStrategy::Sss, shape, batch, &open, &mut cache);
        let (closed_st, plans) = simulate_fleet_dvfs_load_driven(
            &fleet,
            FleetStrategy::Sss,
            shape,
            batch,
            &gov,
            &mut cache,
        );
        // The fast board finishes early under the oblivious equal split;
        // its converged plan must step back to the bottom rung.
        assert!(
            plans.iter().any(|p| p.transitions.iter().any(|t| t.opp == 0 && t.t_s > 0.0)),
            "no down-step in converged plans: {plans:?}"
        );
        let drift = (closed_st.makespan_s / open_st.makespan_s - 1.0).abs();
        assert!(
            drift < 0.01,
            "closed loop must hold the makespan: {:.4}s vs {:.4}s",
            closed_st.makespan_s,
            open_st.makespan_s
        );
        assert!(
            closed_st.energy_j < open_st.energy_j,
            "idle tail at the bottom rung must be cheaper: {:.1} J vs {:.1} J",
            closed_st.energy_j,
            open_st.energy_j
        );
        // Deterministic: the fixed point and its stats replay bit for bit.
        let (again, plans2) = simulate_fleet_dvfs_load_driven(
            &fleet,
            FleetStrategy::Sss,
            shape,
            batch,
            &gov,
            &mut RunCache::new(),
        );
        assert_eq!(plans, plans2);
        assert_eq!(closed_st.makespan_s, again.makespan_s);
        assert_eq!(closed_st.energy_j, again.energy_j);
    }

    /// ISSUE 4 degeneracy anchor (sim layer): an all-at-t=0
    /// single-shape stream is exactly `simulate_fleet` under fleet-DAS
    /// — same grab sequence, bit-for-bit equal makespan, energy and
    /// per-board tallies.
    #[test]
    fn stream_degenerates_to_one_wave_das_bit_for_bit() {
        for fleet in [hetero(), skewed(), Fleet::parse("exynos5422").unwrap()] {
            let shape = GemmShape::square(512);
            let batch = 17;
            let wave = simulate_fleet(&fleet, FleetStrategy::Das, shape, batch);
            let stream = simulate_fleet_stream(&fleet, &burst_arrivals(shape, batch));
            assert_eq!(stream.makespan_s, wave.makespan_s, "{}", wave.label);
            assert_eq!(stream.energy_j, wave.energy_j, "{}", wave.label);
            assert_eq!(stream.items_completed(), batch);
            for (s, w) in stream.boards.iter().zip(&wave.boards) {
                assert_eq!(s.items, w.items, "{}/{}", wave.label, w.name);
                assert_eq!(s.grabs, w.grabs, "{}/{}", wave.label, w.name);
                assert_eq!(s.busy_s, w.busy_s, "{}/{}", wave.label, w.name);
                assert_eq!(s.finish_s, w.finish_s, "{}/{}", wave.label, w.name);
                assert_eq!(s.energy_j, w.energy_j, "{}/{}", wave.label, w.name);
            }
        }
    }

    /// The wave-mode comparator degenerates the same way, for every
    /// strategy: one all-at-t=0 single-shape wave is `simulate_fleet`
    /// bit for bit.
    #[test]
    fn waves_degenerate_to_simulate_fleet_bit_for_bit() {
        let max_group = crate::coordinator::MAX_GROUP_LEN;
        for strategy in [FleetStrategy::Sss, FleetStrategy::Sas, FleetStrategy::Das] {
            let shape = GemmShape::square(512);
            let batch = 24;
            let direct = simulate_fleet(&hetero(), strategy, shape, batch);
            let waves =
                simulate_fleet_waves(&hetero(), strategy, &burst_arrivals(shape, batch), max_group);
            assert_eq!(waves.makespan_s, direct.makespan_s, "{}", direct.label);
            assert_eq!(waves.energy_j, direct.energy_j, "{}", direct.label);
            for (s, w) in waves.boards.iter().zip(&direct.boards) {
                assert_eq!(s.items, w.items, "{}/{}", direct.label, w.name);
                assert_eq!(s.grabs, w.grabs, "{}/{}", direct.label, w.name);
                assert_eq!(s.busy_s, w.busy_s, "{}/{}", direct.label, w.name);
                assert_eq!(s.finish_s, w.finish_s, "{}/{}", direct.label, w.name);
                assert_eq!(s.energy_j, w.energy_j, "{}/{}", direct.label, w.name);
            }
        }
    }

    /// Two different shapes arriving together: the wave barrier
    /// serializes them, the stream runs them on different boards
    /// concurrently — the structural streaming win.
    #[test]
    fn stream_parallelizes_across_shapes_where_waves_serialize() {
        let arrivals = vec![
            Arrival::at(GemmShape::square(512), 0.0),
            Arrival::at(GemmShape::square(640), 0.0),
        ];
        let stream = simulate_fleet_stream(&hetero(), &arrivals);
        assert_eq!(stream.items_completed(), 2);
        // One request per board.
        assert!(stream.boards.iter().all(|b| b.items == 1), "{:?}", stream.boards);
        for strategy in [FleetStrategy::Sss, FleetStrategy::Sas, FleetStrategy::Das] {
            let waves = simulate_fleet_waves(
                &hetero(),
                strategy,
                &arrivals,
                crate::coordinator::MAX_GROUP_LEN,
            );
            assert_eq!(waves.items_completed(), 2);
            assert!(
                stream.makespan_s < waves.makespan_s,
                "stream {:.4}s must beat {} {:.4}s",
                stream.makespan_s,
                waves.label,
                waves.makespan_s
            );
        }
    }

    /// Work conservation on a uniform burst: splitting one burst into
    /// barriered waves can only add idle, so the stream's makespan
    /// never exceeds the wave replay's.
    #[test]
    fn stream_never_loses_to_barriered_waves_on_uniform_bursts() {
        let shape = GemmShape::square(512);
        let arrivals = burst_arrivals(shape, 40);
        let stream = simulate_fleet_stream(&hetero(), &arrivals);
        // Small groups force several waves with barriers between them.
        let waves = simulate_fleet_waves(&hetero(), FleetStrategy::Das, &arrivals, 8);
        assert_eq!(waves.items_completed(), 40);
        assert!(
            stream.makespan_s <= waves.makespan_s + 1e-12,
            "stream {:.4}s vs barriered waves {:.4}s",
            stream.makespan_s,
            waves.makespan_s
        );
        // Utilization can shift a little with the board allocation, but
        // removing five barriers must not *cost* utilization.
        assert!(
            stream.utilization >= 0.98 * waves.utilization,
            "stream util {:.3} vs waves {:.3}",
            stream.utilization,
            waves.utilization
        );
    }

    /// Streaming bookkeeping: completions merge in submission order,
    /// every completion follows its arrival, idle tails and utilization
    /// are consistent, and the replay is deterministic.
    #[test]
    fn stream_accounting_is_consistent_and_deterministic() {
        let shapes = [GemmShape::square(256), GemmShape::square(384), GemmShape::square(512)];
        let mut rng = Rng::new(0xBEEF);
        let arrivals = poisson_arrivals(&mut rng, &shapes, 30, 40.0);
        let a = simulate_fleet_stream(&skewed(), &arrivals);
        let b = simulate_fleet_stream(&skewed(), &arrivals);
        assert_eq!(a.makespan_s, b.makespan_s);
        assert_eq!(a.energy_j, b.energy_j);
        assert_eq!(a.completions, b.completions);
        assert_eq!(a.mean_queue_depth, b.mean_queue_depth);
        assert_eq!(a.max_queue_depth, b.max_queue_depth);
        assert_eq!(
            a.boards.iter().map(|x| x.items).collect::<Vec<_>>(),
            b.boards.iter().map(|x| x.items).collect::<Vec<_>>()
        );

        assert_eq!(a.requests, 30);
        assert_eq!(a.items_completed(), 30);
        assert_eq!(a.completions.len(), 30);
        for (i, (&done, arr)) in a.completions.iter().zip(&arrivals).enumerate() {
            assert!(done.is_finite(), "request {i} never completed");
            assert!(done > arr.arrive_s, "request {i} completed before arriving");
            assert!(done <= a.makespan_s + 1e-12);
        }
        // Executed-per-job histogram == submitted histogram.
        for &(job, executed) in &a.per_job {
            let submitted = arrivals.iter().filter(|x| x.job == job).count();
            assert_eq!(executed, submitted, "{job:?}");
        }
        assert_eq!(a.per_job.iter().map(|(_, c)| c).sum::<usize>(), 30);
        // Per-board accounting.
        assert!(a.utilization > 0.0 && a.utilization <= 1.0, "{}", a.utilization);
        for bd in &a.boards {
            assert!(bd.finish_s <= a.makespan_s + 1e-12);
            assert!((bd.idle_tail_s - (a.makespan_s - bd.finish_s)).abs() < 1e-12);
            assert!(bd.utilization >= 0.0 && bd.utilization <= 1.0);
            assert!(bd.busy_s <= bd.finish_s + 1e-12, "busy within active window");
        }
        assert!(a.max_queue_depth >= 1);
        assert!(a.mean_queue_depth >= 0.0);
    }

    /// ROADMAP follow-on (ISSUE 5 satellite): sojourn-time percentiles
    /// from the submission-indexed completions — consistent with the
    /// raw vector, ordered, and bounded by the run.
    #[test]
    fn sojourn_percentiles_are_consistent() {
        let shapes = [GemmShape::square(256), GemmShape::square(384), GemmShape::square(512)];
        let mut rng = Rng::new(0xFACE);
        let arrivals = poisson_arrivals(&mut rng, &shapes, 40, 60.0);
        let st = simulate_fleet_stream(&hetero(), &arrivals);
        let mut sojourns: Vec<f64> = st
            .completions
            .iter()
            .zip(&arrivals)
            .map(|(&done, a)| done - a.arrive_s)
            .collect();
        sojourns.sort_by(|a, b| a.total_cmp(b));
        assert!(st.sojourn_p50_s > 0.0);
        assert!(
            st.sojourn_p50_s <= st.sojourn_p99_s,
            "{} vs {}",
            st.sojourn_p50_s,
            st.sojourn_p99_s
        );
        assert!(st.sojourn_p99_s <= sojourns[sojourns.len() - 1] + 1e-12);
        assert!(st.sojourn_p50_s >= sojourns[0] - 1e-12);
        // Every sojourn is below the makespan (nothing completes after
        // the run, nothing arrives before t = 0).
        assert!(st.sojourn_p99_s <= st.makespan_s + 1e-12);
        // The wave comparator reports them too, and the barrier can
        // only lengthen the median wait on this near-capacity stream.
        let waves = simulate_fleet_waves(&hetero(), FleetStrategy::Das, &arrivals, 8);
        assert!(waves.sojourn_p50_s > 0.0 && waves.sojourn_p99_s >= waves.sojourn_p50_s);
    }

    /// An arrival gap idles the whole fleet: the stream waits for the
    /// next request instead of spinning, and the makespan extends past
    /// the late arrival.
    #[test]
    fn stream_idles_across_arrival_gaps() {
        let shape = GemmShape::square(256);
        let arrivals = vec![Arrival::at(shape, 0.0), Arrival::at(shape, 10.0)];
        let st = simulate_fleet_stream(&hetero(), &arrivals);
        assert_eq!(st.items_completed(), 2);
        assert!(st.makespan_s > 10.0, "{}", st.makespan_s);
        assert!(st.completions[1] > 10.0);
        assert!(st.completions[0] < 1.0, "first request served immediately");
        // The fleet mostly idled: utilization reflects the gap.
        assert!(st.utilization < 0.5, "{}", st.utilization);
        // A board that idle-waits toward an arrival another board wins
        // must report its *last completion* as finish, not the wait:
        // here board 0 wins both grabs, so board 1 never finishes
        // anything and its idle tail spans the whole run.
        let idle = st.boards.iter().find(|b| b.items == 0).expect("one idle board");
        assert_eq!(idle.finish_s, 0.0, "idle board never completed anything");
        assert_eq!(idle.idle_tail_s, st.makespan_s);
        let busy = st.boards.iter().find(|b| b.items == 2).expect("one busy board");
        assert!(busy.finish_s > 10.0 && busy.idle_tail_s.abs() < 1e-12);
    }

    /// A single-board burst peaks the admission queue at the burst size
    /// and drains it monotonically.
    #[test]
    fn stream_queue_depth_tracks_bursts() {
        let f = Fleet::parse("exynos5422").unwrap();
        let shape = GemmShape::square(256);
        let st = simulate_fleet_stream(&f, &burst_arrivals(shape, 12));
        assert_eq!(st.max_queue_depth, 12, "burst peak");
        assert!(st.mean_queue_depth > 0.0 && st.mean_queue_depth <= 12.0);
        let grain = f.grains()[0];
        assert_eq!(st.boards[0].grabs, (12usize.div_ceil(grain)) as u64);
    }

    /// ISSUE 6 tentpole: the run cache surfaces its counters — a
    /// 4-clone fleet prices one DES and serves the rest from cache, and
    /// the linear-scan dedup it replaced never showed this.
    #[test]
    fn run_cache_counters_surface_in_fleet_stats() {
        let ex = Board::from_preset("exynos5422").unwrap();
        let shape = GemmShape::square(512);
        let st = simulate_fleet(&Fleet::homogeneous(4, &ex), FleetStrategy::Das, shape, 16);
        assert_eq!(st.des_runs, 1, "4 clones share one DES run");
        assert_eq!(st.cache_hits, 3);
        let het = simulate_fleet(&hetero(), FleetStrategy::Das, shape, 16);
        assert_eq!(het.des_runs, 2, "two distinct boards, two runs");
    }

    /// ISSUE 6 acceptance: a warm cache replays a stream bit for bit
    /// with zero DES runs, and the same cache serves the batch and wave
    /// paths too.
    #[test]
    fn warm_cache_replays_streams_bit_for_bit_without_des_runs() {
        let shapes = [GemmShape::square(256), GemmShape::square(384)];
        let arrivals = poisson_arrivals(&mut Rng::new(0xCAC4E), &shapes, 24, 60.0);
        let fresh = simulate_fleet_stream(&hetero(), &arrivals);
        assert!(fresh.des_runs > 0, "a cold cache must pay the DES");
        assert_eq!(fresh.cache_hits + fresh.des_runs, fresh.boards.iter().map(|b| b.grabs).sum());

        let mut cache = RunCache::new();
        let first = simulate_fleet_stream_cached(&hetero(), &arrivals, &mut cache);
        let warm = simulate_fleet_stream_cached(&hetero(), &arrivals, &mut cache);
        assert_eq!(warm.des_runs, 0, "warm replay must be DES-free");
        assert!(warm.cache_hits > 0);
        assert_eq!(warm.makespan_s, fresh.makespan_s);
        assert_eq!(warm.energy_j, fresh.energy_j);
        assert_eq!(warm.completions, fresh.completions);
        assert_eq!(warm.mean_queue_depth, fresh.mean_queue_depth);
        assert_eq!(first.makespan_s, fresh.makespan_s);
        for (w, f) in warm.boards.iter().zip(&fresh.boards) {
            assert_eq!(w.busy_s, f.busy_s, "{}", f.name);
            assert_eq!(w.energy_j, f.energy_j, "{}", f.name);
        }
        // The wave comparator shares the same slots.
        let wave = simulate_fleet_waves_cached(
            &hetero(),
            FleetStrategy::Das,
            &arrivals,
            crate::coordinator::MAX_GROUP_LEN,
            &mut cache,
        );
        assert!(
            wave.des_runs <= fresh.des_runs && wave.cache_hits > 0,
            "the wave replay must reuse the stream's cache slots: {} runs",
            wave.des_runs
        );
        let wave_fresh = simulate_fleet_waves(
            &hetero(),
            FleetStrategy::Das,
            &arrivals,
            crate::coordinator::MAX_GROUP_LEN,
        );
        assert_eq!(wave.makespan_s, wave_fresh.makespan_s);
        assert_eq!(wave.completions, wave_fresh.completions);
    }

    #[test]
    fn poisson_arrivals_are_monotone_and_deterministic() {
        let shapes = [GemmShape::square(128), GemmShape::square(256)];
        let a = poisson_arrivals(&mut Rng::new(7), &shapes, 50, 10.0);
        let b = poisson_arrivals(&mut Rng::new(7), &shapes, 50, 10.0);
        assert_eq!(a, b, "same seed, same stream");
        assert_eq!(a.len(), 50);
        for w in a.windows(2) {
            assert!(w[1].arrive_s >= w[0].arrive_s, "arrivals must be sorted");
        }
        assert!(a.iter().all(|x| x.arrive_s > 0.0 && x.arrive_s.is_finite()));
        assert!(a.iter().all(|x| shapes.iter().any(|&s| x.job == JobSpec::Gemm(s))));
        // Mean inter-arrival ≈ 1/rate over 50 draws (loose bound).
        let mean = a.last().unwrap().arrive_s / 50.0;
        assert!((0.04..0.25).contains(&mean), "mean gap {mean}");
    }

    /// ISSUE 10: a mixed GEMM + factorization stream drains with
    /// exactly-once completions, a consistent per-job histogram, and
    /// deterministic replays — the JobSpec vocabulary end to end.
    #[test]
    fn mixed_job_stream_drains_exactly_once() {
        use crate::dag::FactorKind;
        let jobs = [
            JobSpec::Gemm(GemmShape::square(256)),
            JobSpec::Factor { kind: FactorKind::Cholesky, n: 512, nb: 128 },
            JobSpec::Level3 { op: crate::dag::Level3Op::TrsmLower, m: 256, n: 128 },
        ];
        let arrivals = poisson_job_arrivals(&mut Rng::new(0xDA6), &jobs, 24, 30.0);
        let a = simulate_fleet_stream(&hetero(), &arrivals);
        assert_eq!(a.items_completed(), 24);
        assert_eq!(a.per_job.iter().map(|(_, c)| c).sum::<usize>(), 24);
        for &(job, executed) in &a.per_job {
            assert_eq!(executed, arrivals.iter().filter(|x| x.job == job).count(), "{job:?}");
        }
        for (i, &done) in a.completions.iter().enumerate() {
            assert!(done.is_finite() && done > arrivals[i].arrive_s, "request {i}");
        }
        assert!(a.energy_j > 0.0 && a.makespan_s > 0.0);
        let b = simulate_fleet_stream(&hetero(), &arrivals);
        assert_eq!(a.makespan_s, b.makespan_s);
        assert_eq!(a.energy_j, b.energy_j);
        assert_eq!(a.completions, b.completions);
        // The wave comparator drains the same mixed stream.
        let w = simulate_fleet_waves(&hetero(), FleetStrategy::Das, &arrivals, 8);
        assert_eq!(w.items_completed(), 24);
    }

    /// ISSUE 10 consolidation: the legacy entry points and the
    /// `StreamSim` builder are the same replay, bit for bit.
    #[test]
    fn stream_sim_builder_matches_legacy_entry_points() {
        let shapes = [GemmShape::square(256), GemmShape::square(384)];
        let arrivals = poisson_arrivals(&mut Rng::new(0x51B), &shapes, 20, 50.0);
        let legacy = simulate_fleet_stream(&hetero(), &arrivals);
        let built = StreamSim::new(&hetero()).run(&arrivals);
        assert_eq!(legacy, built);
        let legacy_w =
            simulate_fleet_waves(&hetero(), FleetStrategy::Das, &arrivals, 8);
        let built_w = StreamSim::new(&hetero()).waves(FleetStrategy::Das, 8).run(&arrivals);
        assert_eq!(legacy_w, built_w);
        let (legacy_l, legacy_r) =
            simulate_fleet_stream_live(&hetero(), &arrivals, LiveStreamConfig::default());
        let (built_l, built_r) =
            StreamSim::new(&hetero()).live(LiveStreamConfig::default()).run_live(&arrivals);
        assert_eq!(legacy_l, built_l);
        assert_eq!(legacy_r, built_r);
    }

    #[test]
    fn fleet_scaling_is_near_linear() {
        let ex = Board::from_preset("exynos5422").unwrap();
        let shape = GemmShape::square(1024);
        let rps: Vec<f64> = (1..=4)
            .map(|n| {
                simulate_fleet(&Fleet::homogeneous(n, &ex), FleetStrategy::Das, shape, 32)
                    .throughput_rps
            })
            .collect();
        for w in rps.windows(2) {
            assert!(w[1] > w[0], "throughput must grow with boards: {rps:?}");
        }
        assert!(
            rps[3] > 3.0 * rps[0],
            "4 boards must sustain > 3× one board: {rps:?}"
        );
    }
}
