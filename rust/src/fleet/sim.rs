//! Deterministic virtual-time simulation of a fleet draining one batch
//! of same-shape GEMMs.
//!
//! The unit of work is one GEMM item; each board's per-item virtual time
//! and energy come from one intra-SoC DES run
//! ([`crate::sim::simulate`]) under the board's own schedule, so the
//! fleet layer composes the calibrated single-board model instead of
//! inventing a second one. Boards process their items serially (the
//! coordinator pins one outstanding batch per board); the fleet makespan
//! is the slowest board's finish time, and fleet energy charges every
//! board's idle tail at its baseline power until the makespan — the
//! §3.4 accounting ("the idle cluster still burns its rail") one level
//! up.
//!
//! Capacity planning ("how many Exynos boards sustain X req/s?") is
//! [`boards_to_sustain`]: grow a homogeneous fleet until the simulated
//! sustained rate reaches the target.

use crate::blis::gemm::GemmShape;
use crate::dvfs::DvfsSchedule;
use crate::energy::PowerModel;
use crate::fleet::{Fleet, FleetStrategy, DISPATCH_S};
use crate::sim::simulate;
use std::collections::HashMap;

/// One board's share of a simulated fleet run.
#[derive(Debug, Clone)]
pub struct BoardStats {
    pub name: String,
    /// Items this board executed.
    pub items: usize,
    /// Dispatches it received (1 per static shard; 1 per dynamic grab).
    pub grabs: u64,
    /// Virtual time spent computing (items × per-item time).
    pub busy_s: f64,
    /// Virtual instant the board went idle.
    pub finish_s: f64,
    /// Sustained rate over the board's own active window.
    pub gflops: f64,
    /// Board energy over the whole fleet run, idle tail included.
    pub energy_j: f64,
}

/// Aggregated result of one simulated fleet run.
#[derive(Debug, Clone)]
pub struct FleetStats {
    pub label: String,
    pub shape: GemmShape,
    pub batch: usize,
    /// Virtual makespan: the slowest board's finish time.
    pub makespan_s: f64,
    /// Useful flops of the whole batch over the makespan.
    pub gflops: f64,
    /// Sustained batch-item throughput, requests per second.
    pub throughput_rps: f64,
    /// Whole-fleet energy (every board charged to the makespan).
    pub energy_j: f64,
    pub gflops_per_watt: f64,
    /// Per-board breakdown, in fleet order.
    pub boards: Vec<BoardStats>,
}

impl FleetStats {
    /// Items executed across all boards (= `batch`, asserted in tests).
    pub fn items_completed(&self) -> usize {
        self.boards.iter().map(|b| b.items).sum()
    }
}

/// Simulate one batch of `batch` same-shape GEMMs over the fleet under
/// a board-level strategy. Deterministic: pure virtual time, no host
/// clock, no RNG.
pub fn simulate_fleet(
    fleet: &Fleet,
    strategy: FleetStrategy,
    shape: GemmShape,
    batch: usize,
) -> FleetStats {
    assert!(batch > 0, "empty batch");
    let n = fleet.num_boards();

    // One intra-SoC DES run per board gives the per-item time/energy;
    // every item of the batch has the same shape, so one run suffices —
    // and identical boards (homogeneous capacity sweeps are fleets of
    // clones) share a single run instead of re-simulating it.
    let mut per_item: Vec<crate::sim::RunStats> = Vec::with_capacity(n);
    for (i, b) in fleet.boards.iter().enumerate() {
        let cached = fleet.boards[..i]
            .iter()
            .position(|p| p.soc() == b.soc() && p.sched == b.sched);
        let st = match cached {
            Some(j) => per_item[j].clone(),
            None => simulate(b.model(), &b.sched, shape),
        };
        per_item.push(st);
    }
    let baseline_w: Vec<f64> = fleet
        .boards
        .iter()
        .map(|b| PowerModel::new(b.soc().clone()).baseline_w())
        .collect();

    let mut items = vec![0usize; n];
    let mut grabs = vec![0u64; n];
    let mut clock = vec![0.0f64; n];

    match strategy {
        FleetStrategy::Sss | FleetStrategy::Sas => {
            for (b, &share) in fleet.static_shards(batch, strategy).iter().enumerate() {
                if share > 0 {
                    items[b] = share;
                    grabs[b] = 1; // the whole shard ships in one dispatch
                    clock[b] = DISPATCH_S + share as f64 * per_item[b].time_s;
                }
            }
        }
        FleetStrategy::Das => {
            // Event loop mirroring the intra-SoC dynamic m-loop (§5.4):
            // the board with the earliest clock grabs the next chunk of
            // its own grain (ties go to the lowest board id).
            let grains = fleet.grains();
            let mut next = 0usize;
            while next < batch {
                let mut idx = 0;
                for b in 1..n {
                    if clock[b] < clock[idx] {
                        idx = b;
                    }
                }
                let take = grains[idx].min(batch - next);
                next += take;
                items[idx] += take;
                grabs[idx] += 1;
                clock[idx] += DISPATCH_S + take as f64 * per_item[idx].time_s;
            }
        }
    }

    let makespan = clock.iter().cloned().fold(0.0, f64::max);
    let flops_item = shape.flops();
    let boards: Vec<BoardStats> = (0..n)
        .map(|b| {
            let busy = items[b] as f64 * per_item[b].time_s;
            // Active window at run power, everything else (dispatch
            // waits + idle tail to the fleet makespan) at baseline.
            let energy =
                items[b] as f64 * per_item[b].energy.energy_j + baseline_w[b] * (makespan - busy);
            BoardStats {
                name: fleet.boards[b].name.clone(),
                items: items[b],
                grabs: grabs[b],
                busy_s: busy,
                finish_s: clock[b],
                gflops: if clock[b] > 0.0 {
                    items[b] as f64 * flops_item / clock[b] / 1e9
                } else {
                    0.0
                },
                energy_j: energy,
            }
        })
        .collect();

    let total_flops = batch as f64 * flops_item;
    let energy_j: f64 = boards.iter().map(|b| b.energy_j).sum();
    FleetStats {
        label: format!(
            "{} [{}]",
            strategy.label(),
            fleet
                .boards
                .iter()
                .map(|b| b.name.as_str())
                .collect::<Vec<_>>()
                .join("+")
        ),
        shape,
        batch,
        makespan_s: makespan,
        gflops: total_flops / makespan / 1e9,
        throughput_rps: batch as f64 / makespan,
        energy_j,
        gflops_per_watt: total_flops / energy_j / 1e9,
        boards,
    }
}

/// Per-board DVFS replay of one batch: each board runs under its own
/// OPP [`DvfsSchedule`] (`plans[b]`, validated against that board's
/// topology), and an item started at virtual instant `t` executes at
/// the operating point in effect at `t` — boards reconfigure *between*
/// requests, the item-granular quantization a coordinator that pins one
/// outstanding batch per board actually exhibits. When every plan is
/// static and pins the rung each board's descriptor is already derived
/// at, this delegates to [`simulate_fleet`] — the fleet DVFS path is a
/// provable no-op at fixed frequency, for plain and `@governor` boards
/// alike.
pub fn simulate_fleet_dvfs(
    fleet: &Fleet,
    strategy: FleetStrategy,
    shape: GemmShape,
    batch: usize,
    plans: &[DvfsSchedule],
) -> FleetStats {
    assert!(batch > 0, "empty batch");
    let n = fleet.num_boards();
    assert_eq!(plans.len(), n, "one DVFS schedule per board");
    for (b, plan) in plans.iter().enumerate() {
        plan.validate(fleet.boards[b].soc())
            .expect("invalid board DVFS schedule");
    }
    // A static plan pinning every cluster at the rung the board's
    // descriptor is *already* derived at (the nominal rung for plain
    // presets, the pinned rung for `@governor` boards) is exactly the
    // fixed-frequency simulator — delegate, so the DVFS path is a
    // provable no-op there.
    if plans.iter().zip(&fleet.boards).all(|(p, b)| {
        p.is_static()
            && b.soc()
                .cluster_ids()
                .all(|c| p.initial[c.0] == b.soc()[c].opps.current_idx())
    }) {
        return simulate_fleet(fleet, strategy, shape, batch);
    }

    // One DES run per (board, OPP vector) the schedules visit; identical
    // boards running identical plans share one cache slot (the
    // homogeneous-fleet dedup `simulate_fleet` also does).
    let canon: Vec<usize> = (0..n)
        .map(|b| {
            (0..b)
                .find(|&p| {
                    fleet.boards[p].soc() == fleet.boards[b].soc()
                        && fleet.boards[p].sched == fleet.boards[b].sched
                        && plans[p] == plans[b]
                })
                .unwrap_or(b)
        })
        .collect();
    let mut cache: Vec<HashMap<Vec<usize>, crate::sim::RunStats>> = vec![HashMap::new(); n];
    let item_stats = |cache: &mut [HashMap<Vec<usize>, crate::sim::RunStats>],
                      b: usize,
                      t: f64|
     -> crate::sim::RunStats {
        let soc = fleet.boards[b].soc();
        let key: Vec<usize> = soc.cluster_ids().map(|c| plans[b].opp_at(c, t)).collect();
        cache[canon[b]]
            .entry(key)
            .or_insert_with(|| {
                let model = crate::model::PerfModel::new(plans[b].soc_at(soc, t));
                simulate(&model, &fleet.boards[b].sched, shape)
            })
            .clone()
    };
    // Baseline (idle-rail) power of board `b` at instant `t` — priced
    // at the operating point in effect, not the boot point.
    let baseline_at = |b: usize, t: f64| -> f64 {
        PowerModel::new(plans[b].soc_at(fleet.boards[b].soc(), t)).baseline_w()
    };

    let mut items = vec![0usize; n];
    let mut grabs = vec![0u64; n];
    let mut clock = vec![0.0f64; n];
    let mut busy = vec![0.0f64; n];
    let mut energy = vec![0.0f64; n];
    let run_items = |cache: &mut [HashMap<Vec<usize>, crate::sim::RunStats>],
                     clock: &mut [f64],
                     busy: &mut [f64],
                     energy: &mut [f64],
                     b: usize,
                     count: usize| {
        energy[b] += baseline_at(b, clock[b]) * DISPATCH_S;
        clock[b] += DISPATCH_S;
        for _ in 0..count {
            let st = item_stats(cache, b, clock[b]);
            clock[b] += st.time_s;
            busy[b] += st.time_s;
            energy[b] += st.energy.energy_j;
        }
    };

    match strategy {
        FleetStrategy::Sss | FleetStrategy::Sas => {
            for (b, &share) in fleet.static_shards(batch, strategy).iter().enumerate() {
                if share > 0 {
                    items[b] = share;
                    grabs[b] = 1;
                    run_items(&mut cache, &mut clock, &mut busy, &mut energy, b, share);
                }
            }
        }
        FleetStrategy::Das => {
            let grains = fleet.grains();
            let mut next = 0usize;
            while next < batch {
                let mut idx = 0;
                for b in 1..n {
                    if clock[b] < clock[idx] {
                        idx = b;
                    }
                }
                let take = grains[idx].min(batch - next);
                next += take;
                items[idx] += take;
                grabs[idx] += 1;
                run_items(&mut cache, &mut clock, &mut busy, &mut energy, idx, take);
            }
        }
    }

    let makespan = clock.iter().cloned().fold(0.0, f64::max);
    // Idle tail from each board's finish to the fleet makespan, priced
    // piecewise at the operating point in effect over the tail.
    let tail_energy = |b: usize| -> f64 {
        let (t0, t1) = (clock[b], makespan);
        if t1 <= t0 {
            return 0.0;
        }
        let mut cuts = vec![t0];
        cuts.extend(plans[b].boundaries().into_iter().filter(|&t| t > t0 && t < t1));
        cuts.push(t1);
        cuts.windows(2).map(|w| baseline_at(b, w[0]) * (w[1] - w[0])).sum()
    };
    let flops_item = shape.flops();
    let boards: Vec<BoardStats> = (0..n)
        .map(|b| BoardStats {
            name: fleet.boards[b].name.clone(),
            items: items[b],
            grabs: grabs[b],
            busy_s: busy[b],
            finish_s: clock[b],
            gflops: if clock[b] > 0.0 {
                items[b] as f64 * flops_item / clock[b] / 1e9
            } else {
                0.0
            },
            energy_j: energy[b] + tail_energy(b),
        })
        .collect();
    let total_flops = batch as f64 * flops_item;
    let energy_j: f64 = boards.iter().map(|b| b.energy_j).sum();
    FleetStats {
        label: format!(
            "{} +DVFS [{}]",
            strategy.label(),
            fleet
                .boards
                .iter()
                .map(|b| b.name.as_str())
                .collect::<Vec<_>>()
                .join("+")
        ),
        shape,
        batch,
        makespan_s: makespan,
        gflops: total_flops / makespan / 1e9,
        throughput_rps: batch as f64 / makespan,
        energy_j,
        gflops_per_watt: total_flops / energy_j / 1e9,
        boards,
    }
}

/// Capacity planning: the smallest homogeneous fleet of `board` clones
/// sustaining `target_rps` requests per second on `shape` batches of
/// `batch` items, up to `max_boards` (clamped to the fleet capacity,
/// [`crate::sched::MAX_WAYS`]). `None` if even the largest fleet can't.
pub fn boards_to_sustain(
    board: &crate::fleet::Board,
    shape: GemmShape,
    batch: usize,
    target_rps: f64,
    max_boards: usize,
) -> Option<usize> {
    assert!(target_rps > 0.0 && max_boards >= 1);
    for n in 1..=max_boards.min(crate::sched::MAX_WAYS) {
        let fleet = Fleet::homogeneous(n, board);
        let st = simulate_fleet(&fleet, FleetStrategy::Das, shape, batch);
        if st.throughput_rps >= target_rps {
            return Some(n);
        }
    }
    None
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::fleet::Board;
    use crate::util::prop;

    fn hetero() -> Fleet {
        Fleet::parse("exynos5422,juno_r0").unwrap()
    }

    /// A strongly asymmetric two-board pair (≈ 1.7× aggregate
    /// throughput gap) for strict win assertions; exynos vs juno is
    /// heterogeneous but nearly throughput-matched.
    fn skewed() -> Fleet {
        Fleet::parse("exynos5422,dynamiq_3c").unwrap()
    }

    /// The ISSUE acceptance criterion: on a heterogeneous two-board
    /// fleet, dynamic fleet-DAS beats the equal-shard fleet-SSS in
    /// virtual time — the paper's intra-SoC result one level up.
    #[test]
    fn das_beats_sss_on_heterogeneous_fleet() {
        let shape = GemmShape::square(1024);
        let sss = simulate_fleet(&skewed(), FleetStrategy::Sss, shape, 32);
        let das = simulate_fleet(&skewed(), FleetStrategy::Das, shape, 32);
        assert!(
            das.makespan_s < 0.90 * sss.makespan_s,
            "fleet-DAS {:.3}s must beat fleet-SSS {:.3}s",
            das.makespan_s,
            sss.makespan_s
        );
        // The oblivious equal split leaves the faster board idling at
        // baseline; the balanced schedule also wins on energy.
        assert!(das.gflops_per_watt > sss.gflops_per_watt);
        // And on the nearly-matched exynos+juno pair the dynamic queue
        // must never lose materially to the equal split.
        let sss2 = simulate_fleet(&hetero(), FleetStrategy::Sss, shape, 32);
        let das2 = simulate_fleet(&hetero(), FleetStrategy::Das, shape, 32);
        assert!(
            das2.makespan_s < 1.02 * sss2.makespan_s,
            "fleet-DAS {:.3}s vs fleet-SSS {:.3}s on a matched pair",
            das2.makespan_s,
            sss2.makespan_s
        );
    }

    #[test]
    fn sas_tracks_das_within_quantization() {
        let shape = GemmShape::square(1024);
        let sas = simulate_fleet(&skewed(), FleetStrategy::Sas, shape, 64);
        let das = simulate_fleet(&skewed(), FleetStrategy::Das, shape, 64);
        let rel = (sas.makespan_s / das.makespan_s - 1.0).abs();
        assert!(rel < 0.20, "fleet-SAS {:.3}s vs fleet-DAS {:.3}s", sas.makespan_s, das.makespan_s);
    }

    #[test]
    fn single_board_fleet_degenerates() {
        let f = Fleet::parse("exynos5422").unwrap();
        let shape = GemmShape::square(512);
        let st = simulate_fleet(&f, FleetStrategy::Das, shape, 8);
        assert_eq!(st.items_completed(), 8);
        assert_eq!(st.boards.len(), 1);
        // Makespan = dispatches + 8 serial items.
        let item = simulate(f.boards[0].model(), &f.boards[0].sched, shape).time_s;
        assert!(st.makespan_s >= 8.0 * item);
        assert!(st.throughput_rps > 0.0);
    }

    #[test]
    fn deterministic() {
        let shape = GemmShape::square(768);
        let a = simulate_fleet(&hetero(), FleetStrategy::Das, shape, 24);
        let b = simulate_fleet(&hetero(), FleetStrategy::Das, shape, 24);
        assert_eq!(a.makespan_s, b.makespan_s);
        assert_eq!(a.energy_j, b.energy_j);
        assert_eq!(
            a.boards.iter().map(|x| x.items).collect::<Vec<_>>(),
            b.boards.iter().map(|x| x.items).collect::<Vec<_>>()
        );
    }

    /// ISSUE satellite: fleet-DAS completes every item for 1–4 boards of
    /// mixed presets (the board-level queue-drain property test).
    #[test]
    fn prop_das_completes_all_items_on_mixed_fleets() {
        let presets = ["exynos5422", "juno_r0", "dynamiq_3c", "symmetric2"];
        prop::check_default(
            |r| {
                let n = r.gen_range(1, 5); // 1..=4 boards
                let toks: Vec<&str> = (0..n).map(|_| *r.choose(&presets)).collect();
                (toks.join(","), r.gen_range(1, 50))
            },
            |(list, batch)| {
                let fleet = Fleet::parse(list).map_err(|e| e.to_string())?;
                let st =
                    simulate_fleet(&fleet, FleetStrategy::Das, GemmShape::square(256), *batch);
                if st.items_completed() != *batch {
                    return Err(format!(
                        "{} of {batch} items completed: {:?}",
                        st.items_completed(),
                        st.boards.iter().map(|b| b.items).collect::<Vec<_>>()
                    ));
                }
                // Per-board accounting must be consistent.
                for b in &st.boards {
                    if b.finish_s > st.makespan_s + 1e-12 {
                        return Err(format!("board {} finishes after the makespan", b.name));
                    }
                    if b.items > 0 && b.grabs == 0 {
                        return Err(format!("board {} has items but no grabs", b.name));
                    }
                }
                Ok(())
            },
        );
    }

    #[test]
    fn static_strategies_complete_and_weight_shards() {
        let shape = GemmShape::square(512);
        let sss = simulate_fleet(&hetero(), FleetStrategy::Sss, shape, 40);
        assert_eq!(sss.items_completed(), 40);
        assert_eq!(sss.boards[0].items, sss.boards[1].items, "SSS splits equally");
        let sas = simulate_fleet(&hetero(), FleetStrategy::Sas, shape, 40);
        assert_eq!(sas.items_completed(), 40);
        // The Exynos board out-rates the Juno r0 → bigger SAS shard.
        let w = hetero().weights();
        if w.as_slice()[0] > w.as_slice()[1] {
            assert!(sas.boards[0].items > sas.boards[1].items, "{:?}", sas.boards);
        } else {
            assert!(sas.boards[1].items > sas.boards[0].items, "{:?}", sas.boards);
        }
    }

    #[test]
    fn energy_accounts_idle_tail() {
        // A single-item batch: one board executes, the other idles the
        // whole run — its rails must still be charged at baseline for
        // the full makespan (the §3.4 idle-cluster accounting, one
        // level up).
        let shape = GemmShape::square(512);
        let st = simulate_fleet(&hetero(), FleetStrategy::Sss, shape, 1);
        assert_eq!(st.items_completed(), 1);
        let idle = st.boards.iter().find(|b| b.items == 0).expect("one idle board");
        assert!(idle.energy_j > 0.0, "idle board still burns its rails");
        let sum: f64 = st.boards.iter().map(|b| b.energy_j).sum();
        assert!((sum - st.energy_j).abs() < 1e-9);
        assert!(st.gflops_per_watt > 0.0);
    }

    #[test]
    fn capacity_planning_grows_with_target() {
        let ex = Board::from_preset("exynos5422").unwrap();
        let shape = GemmShape::square(1024);
        let one = simulate_fleet(&Fleet::homogeneous(1, &ex), FleetStrategy::Das, shape, 16);
        let rps1 = one.throughput_rps;
        assert_eq!(boards_to_sustain(&ex, shape, 16, 0.5 * rps1, 8), Some(1));
        let n = boards_to_sustain(&ex, shape, 16, 2.5 * rps1, 8).unwrap();
        assert!(n >= 3, "2.5× one board's rate needs ≥ 3 boards, got {n}");
        assert_eq!(boards_to_sustain(&ex, shape, 16, 1e9, 2), None);
    }

    /// ISSUE 3: nominal per-board schedules make the fleet DVFS path a
    /// provable no-op (delegates to the fixed-frequency simulator).
    #[test]
    fn fleet_dvfs_nominal_is_a_noop() {
        use crate::dvfs::DvfsSchedule;
        let fleet = hetero();
        let shape = GemmShape::square(512);
        let plans: Vec<DvfsSchedule> = fleet
            .boards
            .iter()
            .map(|b| DvfsSchedule::nominal(b.soc()))
            .collect();
        let a = simulate_fleet(&fleet, FleetStrategy::Das, shape, 16);
        let b = simulate_fleet_dvfs(&fleet, FleetStrategy::Das, shape, 16, &plans);
        assert_eq!(a.makespan_s, b.makespan_s);
        assert_eq!(a.energy_j, b.energy_j);
        assert_eq!(a.label, b.label, "no-op path keeps the static label");
    }

    /// ISSUE 3 satellite: fleet-DAS drains every item even when a
    /// board's OPP transition fires mid-batch — and the dynamic queue
    /// shifts items away from the board that slowed down.
    #[test]
    fn fleet_das_drains_across_mid_batch_transitions() {
        use crate::dvfs::{DvfsSchedule, Transition};
        use crate::soc::{ClusterId, SocSpec};
        let ex = Board::from_preset("exynos5422").unwrap();
        let fleet = Fleet::homogeneous(2, &ex);
        let shape = GemmShape::square(512);
        let batch = 40;
        // Board 0 drops both clusters to the ladder bottom partway
        // through the batch; board 1 stays nominal.
        let item_s = simulate(ex.model(), &ex.sched, shape).time_s;
        let nominal = DvfsSchedule::nominal(ex.soc());
        let mid = 0.5 * batch as f64 / 2.0 * item_s;
        let throttled = DvfsSchedule::new(
            SocSpec::exynos5422()
                .clusters
                .iter()
                .map(|c| c.opps.nominal_idx())
                .collect(),
            vec![
                Transition { t_s: mid, cluster: ClusterId(0), opp: 0 },
                Transition { t_s: mid, cluster: ClusterId(1), opp: 0 },
            ],
        );
        let plans = vec![throttled, nominal];
        let st = simulate_fleet_dvfs(&fleet, FleetStrategy::Das, shape, batch, &plans);
        assert_eq!(st.items_completed(), batch, "{:?}", st.boards);
        assert!(
            st.boards[1].items > st.boards[0].items,
            "the un-throttled board must absorb the imbalance: {:?}",
            st.boards.iter().map(|b| b.items).collect::<Vec<_>>()
        );
        // Deterministic replay, same schedule ⇒ same timeline.
        let again = simulate_fleet_dvfs(&fleet, FleetStrategy::Das, shape, batch, &plans);
        assert_eq!(st.makespan_s, again.makespan_s);
        assert_eq!(st.energy_j, again.energy_j);
        assert_eq!(
            st.boards.iter().map(|b| b.items).collect::<Vec<_>>(),
            again.boards.iter().map(|b| b.items).collect::<Vec<_>>()
        );
        // Static sharding drains too, just slower than the queue.
        let sss = simulate_fleet_dvfs(&fleet, FleetStrategy::Sss, shape, batch, &plans);
        assert_eq!(sss.items_completed(), batch);
        assert!(sss.makespan_s >= st.makespan_s);
    }

    /// An `@governor`-pinned board under a plan holding its own rung is
    /// the fixed-frequency simulator (delegation), while a plan moving
    /// it to the ladder top genuinely up-clocks it — `at_opp` derivation
    /// is absolute, never compounding.
    #[test]
    fn fleet_dvfs_respects_board_pinned_rungs() {
        use crate::dvfs::DvfsSchedule;
        let slow = Board::from_preset("exynos5422@powersave").unwrap();
        let fleet = Fleet::homogeneous(2, &slow);
        let shape = GemmShape::square(512);
        // Plans pinning the boards' own (bottom) rung: exact no-op.
        let hold = vec![DvfsSchedule::pinned(&[0, 0]); 2];
        let a = simulate_fleet(&fleet, FleetStrategy::Das, shape, 8);
        let b = simulate_fleet_dvfs(&fleet, FleetStrategy::Das, shape, 8, &hold);
        assert_eq!(a.makespan_s, b.makespan_s);
        assert_eq!(a.energy_j, b.energy_j);
        // Plans pinning the nominal rung up-clock the powersave boards.
        let up = vec![DvfsSchedule::nominal(slow.soc()); 2];
        let fast = simulate_fleet_dvfs(&fleet, FleetStrategy::Das, shape, 8, &up);
        assert!(
            fast.makespan_s < 0.7 * a.makespan_s,
            "up-clocked {:.3}s vs powersave {:.3}s",
            fast.makespan_s,
            a.makespan_s
        );
        assert_eq!(fast.items_completed(), 8);
    }

    /// ISSUE 3: per-board DVFS heterogeneity in the capacity planner —
    /// a powersave-pinned board sustains less, so the planner buys more
    /// of them for the same target.
    #[test]
    fn capacity_planner_prices_dvfs_heterogeneity() {
        let nominal = Board::from_preset("exynos5422").unwrap();
        let slow = Board::from_preset("exynos5422@powersave").unwrap();
        let shape = GemmShape::square(1024);
        let rps1 = simulate_fleet(&Fleet::homogeneous(1, &nominal), FleetStrategy::Das, shape, 16)
            .throughput_rps;
        let target = 1.5 * rps1;
        let need_nominal = boards_to_sustain(&nominal, shape, 16, target, 8).unwrap();
        let need_slow = boards_to_sustain(&slow, shape, 16, target, 8).unwrap();
        assert!(
            need_slow > need_nominal,
            "powersave boards must cost more: {need_slow} vs {need_nominal}"
        );
        // And a mixed-frequency fleet lands between the two.
        let mixed = Fleet::parse("exynos5422,exynos5422@powersave").unwrap();
        let st = simulate_fleet(&mixed, FleetStrategy::Das, shape, 32);
        let fast2 = simulate_fleet(
            &Fleet::homogeneous(2, &nominal),
            FleetStrategy::Das,
            shape,
            32,
        );
        let slow2 = simulate_fleet(&Fleet::homogeneous(2, &slow), FleetStrategy::Das, shape, 32);
        assert!(st.throughput_rps < fast2.throughput_rps);
        assert!(st.throughput_rps > slow2.throughput_rps);
    }

    #[test]
    fn fleet_scaling_is_near_linear() {
        let ex = Board::from_preset("exynos5422").unwrap();
        let shape = GemmShape::square(1024);
        let rps: Vec<f64> = (1..=4)
            .map(|n| {
                simulate_fleet(&Fleet::homogeneous(n, &ex), FleetStrategy::Das, shape, 32)
                    .throughput_rps
            })
            .collect();
        for w in rps.windows(2) {
            assert!(w[1] > w[0], "throughput must grow with boards: {rps:?}");
        }
        assert!(
            rps[3] > 3.0 * rps[0],
            "4 boards must sustain > 3× one board: {rps:?}"
        );
    }
}
