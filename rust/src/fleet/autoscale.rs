//! SLO-driven fleet autoscaling (ISSUE 8; ROADMAP open item #1).
//!
//! The capacity planner ([`crate::fleet::sim::boards_to_sustain`])
//! answers "how many boards sustain X req/s" on raw throughput. An SLO
//! is a harder contract: hold the **p99 sojourn** (queueing included)
//! of a timestamped arrival stream under a bound — and do it at the
//! lowest provisioned **cost**, now that every [`Board`] carries a
//! $/hour price tag. The [`Autoscaler`] closes that loop against the
//! deterministic stream replay
//! ([`crate::fleet::sim::simulate_fleet_stream_cached`]): grow while
//! the SLO is violated (best marginal p99-per-$ template wins), then
//! shrink and *downgrade* — swap boards for cheaper catalog templates
//! while the SLO still holds — so the converged fleet is cheaper than
//! the smallest homogeneous static fleet whenever mixed hardware can
//! cover the residual load (the fleet-level analogue of "schedule the
//! tail on the LITTLE cluster").
//!
//! Everything is virtual-time deterministic: same arrivals + same
//! catalog ⇒ same decision, bit for bit — which is what lets the
//! rate-sweep figure and the perf-trajectory gate pin the scaler's
//! behavior.

use crate::fleet::sim::{simulate_fleet_stream_cached, Arrival, StreamStats};
use crate::fleet::{Board, Fleet};
use crate::sched::MAX_WAYS;
use crate::sim::engine::RunCache;

/// The service-level objective a fleet must hold on a stream.
#[derive(Debug, Clone, Copy)]
pub struct SloPolicy {
    /// p99 sojourn bound (admission → completion), virtual seconds.
    pub p99_sojourn_s: f64,
}

impl SloPolicy {
    pub fn new(p99_sojourn_s: f64) -> Self {
        assert!(
            p99_sojourn_s.is_finite() && p99_sojourn_s > 0.0,
            "SLO bound must be positive and finite, got {p99_sojourn_s}"
        );
        SloPolicy { p99_sojourn_s }
    }

    /// Does a replay meet the objective?
    pub fn met_by(&self, st: &StreamStats) -> bool {
        st.sojourn_p99_s <= self.p99_sojourn_s
    }
}

/// Grows/shrinks a [`Fleet`] against an [`SloPolicy`] using priced
/// catalog templates.
#[derive(Debug, Clone)]
pub struct Autoscaler {
    pub slo: SloPolicy,
    /// Board templates the scaler may provision, in preference order
    /// (ties in every score break toward the earlier entry). The first
    /// template seeds the fleet.
    pub catalog: Vec<Board>,
    /// Hard rack limit (≤ [`MAX_WAYS`], the sharding fan-out cap).
    pub max_boards: usize,
}

/// One converged scaling decision, with the replay that justified it.
#[derive(Debug, Clone)]
pub struct AutoscaleDecision {
    pub fleet: Fleet,
    /// The final fleet's replay of the full stream.
    pub stats: StreamStats,
    pub slo_met: bool,
    /// Provisioned cost rate of the converged fleet, $/hour.
    pub price_per_hour: f64,
    /// Candidate replays the search paid (all served through the shared
    /// [`RunCache`], so repeated shapes cost one DES run each).
    pub evaluations: usize,
}

impl Autoscaler {
    pub fn new(slo: SloPolicy, catalog: Vec<Board>) -> Self {
        assert!(!catalog.is_empty(), "autoscaler needs at least one board template");
        Autoscaler { slo, catalog, max_boards: MAX_WAYS }
    }

    /// Cap the rack size (builder style).
    pub fn with_max_boards(mut self, max_boards: usize) -> Self {
        assert!(
            (1..=MAX_WAYS).contains(&max_boards),
            "rack limit must be 1..={MAX_WAYS}, got {max_boards}"
        );
        self.max_boards = max_boards;
        self
    }

    /// Converge on the cheapest fleet that holds the SLO for `arrivals`
    /// (or the best-effort fleet at the rack limit if nothing does).
    ///
    /// Three deterministic passes:
    /// 1. **Grow** from one seed template: while the SLO is violated,
    ///    add the catalog template with the best p99 improvement per
    ///    dollar (strictly-improving candidates only; stop at the rack
    ///    limit or when no candidate moves the p99).
    /// 2. **Shrink**: drop any board whose removal keeps the SLO —
    ///    most expensive removable board first. A sub-capacity stream
    ///    therefore never scales past its seed board.
    /// 3. **Downgrade**: replace boards with strictly cheaper catalog
    ///    templates while the SLO still holds — the pass that beats
    ///    same-template static provisioning on cost.
    pub fn plan(&self, arrivals: &[Arrival], cache: &mut RunCache) -> AutoscaleDecision {
        let mut evaluations = 0usize;
        let mut eval = |boards: &[Board], cache: &mut RunCache, n: &mut usize| -> StreamStats {
            *n += 1;
            simulate_fleet_stream_cached(&Fleet::new(boards.to_vec()), arrivals, cache)
        };

        let mut boards = vec![self.instance(0, 0)];
        let mut stats = eval(&boards, cache, &mut evaluations);

        // Pass 1: grow while the SLO is violated.
        while !self.slo.met_by(&stats) && boards.len() < self.max_boards {
            let mut best: Option<(f64, usize, StreamStats)> = None;
            for (t, template) in self.catalog.iter().enumerate() {
                let mut candidate = boards.clone();
                candidate.push(self.named_instance(t, &boards));
                let st = eval(&candidate, cache, &mut evaluations);
                let gain = stats.sojourn_p99_s - st.sojourn_p99_s;
                if gain <= 0.0 {
                    continue; // the extra board did not move the tail
                }
                let score = gain / template.price_per_hour;
                let better = match &best {
                    None => true,
                    Some((s, _, _)) => score > *s,
                };
                if better {
                    best = Some((score, t, st));
                }
            }
            match best {
                Some((_, t, st)) => {
                    boards.push(self.named_instance(t, &boards));
                    stats = st;
                }
                None => break, // saturated: no template improves the tail
            }
        }

        // Pass 2: shrink — drop boards the SLO does not need, most
        // expensive removable first.
        if self.slo.met_by(&stats) {
            loop {
                let mut order: Vec<usize> = (0..boards.len()).collect();
                order.sort_by(|&a, &b| {
                    boards[b].price_per_hour.total_cmp(&boards[a].price_per_hour).then(a.cmp(&b))
                });
                let mut removed = false;
                for &i in &order {
                    if boards.len() == 1 {
                        break;
                    }
                    let mut candidate = boards.clone();
                    candidate.remove(i);
                    let st = eval(&candidate, cache, &mut evaluations);
                    if self.slo.met_by(&st) {
                        boards = candidate;
                        stats = st;
                        removed = true;
                        break;
                    }
                }
                if !removed {
                    break;
                }
            }

            // Pass 3: downgrade — swap each board for the cheapest
            // catalog template that still holds the SLO.
            for i in 0..boards.len() {
                let mut swaps: Vec<usize> = (0..self.catalog.len())
                    .filter(|&t| self.catalog[t].price_per_hour < boards[i].price_per_hour)
                    .collect();
                swaps.sort_by(|&a, &b| {
                    self.catalog[a]
                        .price_per_hour
                        .total_cmp(&self.catalog[b].price_per_hour)
                        .then(a.cmp(&b))
                });
                for t in swaps {
                    let mut candidate = boards.clone();
                    candidate[i] = self.instance(t, i);
                    let st = eval(&candidate, cache, &mut evaluations);
                    if self.slo.met_by(&st) {
                        boards = candidate;
                        stats = st;
                        break;
                    }
                }
            }
        }

        let fleet = Fleet::new(boards);
        let slo_met = self.slo.met_by(&stats);
        let price_per_hour = fleet.price_per_hour();
        AutoscaleDecision { fleet, stats, slo_met, price_per_hour, evaluations }
    }

    /// Catalog template `t`, named for slot `slot`.
    fn instance(&self, t: usize, slot: usize) -> Board {
        let mut b = self.catalog[t].clone();
        b.name = format!("{}#{slot}", self.catalog[t].name);
        b
    }

    /// Catalog template `t`, named after the current fleet size.
    fn named_instance(&self, t: usize, boards: &[Board]) -> Board {
        self.instance(t, boards.len())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::blis::gemm::GemmShape;
    use crate::fleet::sim::{boards_to_sustain, poisson_arrivals, simulate_fleet_stream};
    use crate::fleet::FleetStrategy;
    use crate::util::prop;
    use crate::util::rng::Rng;

    fn stream(rate: f64, count: usize, seed: u64) -> Vec<Arrival> {
        let mut rng = Rng::new(seed);
        poisson_arrivals(&mut rng, &[GemmShape::square(1024)], count, rate)
    }

    /// ISSUE 8 degeneracy anchor: a stream one board sustains with
    /// headroom never scales — the decision matches
    /// `boards_to_sustain`'s single-board answer.
    #[test]
    fn sub_capacity_stream_never_scales() {
        let ex = Board::from_preset("exynos5422").unwrap();
        let shape = GemmShape::square(1024);
        let solo = crate::fleet::sim::simulate_fleet(
            &Fleet::homogeneous(1, &ex),
            FleetStrategy::Das,
            shape,
            16,
        );
        let rate = 0.4 * solo.throughput_rps;
        assert_eq!(boards_to_sustain(&ex, shape, 16, rate, 8), Some(1));
        let arrivals = stream(rate, 60, 7);
        // A loose SLO: 20× one item's service time.
        let item = crate::sim::simulate(ex.model(), &ex.sched, shape).time_s;
        let scaler = Autoscaler::new(SloPolicy::new(20.0 * item), vec![ex]);
        let d = scaler.plan(&arrivals, &mut RunCache::new());
        assert!(d.slo_met, "p99 {:.3}s vs SLO {:.3}s", d.stats.sojourn_p99_s, 20.0 * item);
        assert_eq!(d.fleet.num_boards(), 1, "sub-capacity stream must not scale");
        assert_eq!(d.price_per_hour, d.fleet.boards[0].price_per_hour);
    }

    /// Past single-board saturation the scaler grows until the SLO
    /// holds, and the decision is deterministic.
    #[test]
    fn saturating_stream_grows_until_slo_holds() {
        let ex = Board::from_preset("exynos5422").unwrap();
        let shape = GemmShape::square(1024);
        let solo = crate::fleet::sim::simulate_fleet(
            &Fleet::homogeneous(1, &ex),
            FleetStrategy::Das,
            shape,
            16,
        );
        let rate = 2.2 * solo.throughput_rps;
        let arrivals = stream(rate, 80, 11);
        let item = crate::sim::simulate(ex.model(), &ex.sched, shape).time_s;
        let slo = SloPolicy::new(8.0 * item);
        // One board alone must violate the SLO at this rate.
        let one = simulate_fleet_stream(&Fleet::homogeneous(1, &ex), &arrivals);
        assert!(!slo.met_by(&one), "rate too low to force scaling");
        let scaler = Autoscaler::new(slo, vec![ex.clone()]);
        let d = scaler.plan(&arrivals, &mut RunCache::new());
        assert!(d.slo_met, "p99 {:.3}s vs SLO {:.3}s", d.stats.sojourn_p99_s, slo.p99_sojourn_s);
        assert!(d.fleet.num_boards() >= 2, "saturating stream must scale out");
        // Deterministic: same arrivals + same catalog ⇒ same decision.
        let d2 = scaler.plan(&arrivals, &mut RunCache::new());
        assert_eq!(d.fleet.num_boards(), d2.fleet.num_boards());
        assert_eq!(d.price_per_hour, d2.price_per_hour);
        assert_eq!(d.stats.sojourn_p99_s, d2.stats.sojourn_p99_s);
        // Minimality vs the same template: one fewer board violates.
        let fewer = Fleet::homogeneous(d.fleet.num_boards() - 1, &ex);
        let st = simulate_fleet_stream(&fewer, &arrivals);
        assert!(
            !slo.met_by(&st) || d.price_per_hour < fewer.price_per_hour(),
            "the decision must be minimal or cheaper than the smaller static fleet"
        );
    }

    /// A heterogeneous catalog lets the downgrade pass undercut
    /// same-template static provisioning: the converged fleet holds the
    /// SLO strictly cheaper than the smallest homogeneous fleet of
    /// reference boards that holds it.
    #[test]
    fn downgrade_pass_beats_homogeneous_static_cost() {
        let ex = Board::from_preset("exynos5422").unwrap();
        let little = Board::from_preset("symmetric2").unwrap();
        assert!(little.price_per_hour < ex.price_per_hour, "catalog needs a cheaper template");
        let shape = GemmShape::square(1024);
        let solo = crate::fleet::sim::simulate_fleet(
            &Fleet::homogeneous(1, &ex),
            FleetStrategy::Das,
            shape,
            16,
        );
        let rate = 1.4 * solo.throughput_rps;
        let arrivals = stream(rate, 80, 23);
        let item = crate::sim::simulate(ex.model(), &ex.sched, shape).time_s;
        let slo = SloPolicy::new(10.0 * item);
        let scaler = Autoscaler::new(slo, vec![ex.clone(), little]);
        let mut cache = RunCache::new();
        let d = scaler.plan(&arrivals, &mut cache);
        assert!(d.slo_met);
        // Smallest homogeneous exynos fleet holding the SLO.
        let mut static_n = None;
        for n in 1..=8usize {
            let st = simulate_fleet_stream_cached(
                &Fleet::homogeneous(n, &ex),
                &arrivals,
                &mut cache,
            );
            if slo.met_by(&st) {
                static_n = Some(n);
                break;
            }
        }
        let n = static_n.expect("some static fleet must hold the SLO");
        let static_cost = Fleet::homogeneous(n, &ex).price_per_hour();
        assert!(
            d.price_per_hour <= static_cost,
            "autoscaled ${:.2}/h must not exceed static ${static_cost:.2}/h",
            d.price_per_hour
        );
    }

    /// ISSUE 8 property test: over random fleets, an SLO met at some
    /// rate stays met when the rate decreases (arrival gaps stretch,
    /// service times unchanged ⇒ the tail cannot grow).
    #[test]
    fn prop_slo_stays_met_as_rate_decreases() {
        let presets = ["exynos5422", "juno_r0", "dynamiq_3c", "symmetric4"];
        prop::check_default(
            |r| {
                let n = r.gen_range(1, 4); // 1..=3 boards
                let toks: Vec<&str> = (0..n).map(|_| *r.choose(&presets)).collect();
                (toks.join(","), r.gen_range(20, 60), r.gen_range(1, 1000) as u64)
            },
            |(list, count, seed)| {
                let fleet = Fleet::parse(list).map_err(|e| e.to_string())?;
                let mut rng = Rng::new(*seed);
                let arrivals =
                    poisson_arrivals(&mut rng, &[GemmShape::square(512)], *count, 4.0);
                let st = simulate_fleet_stream(&fleet, &arrivals);
                // The SLO "exactly met" at this rate: its own p99.
                let slo = SloPolicy::new(st.sojourn_p99_s.max(1e-9));
                for stretch in [2.0, 4.0] {
                    let slower: Vec<Arrival> = arrivals
                        .iter()
                        .map(|a| Arrival::at(a.job, a.arrive_s * stretch))
                        .collect();
                    let slow_st = simulate_fleet_stream(&fleet, &slower);
                    if !slo.met_by(&slow_st) {
                        return Err(format!(
                            "p99 grew from {:.4}s to {:.4}s at 1/{stretch} rate on {list}",
                            st.sojourn_p99_s, slow_st.sojourn_p99_s
                        ));
                    }
                }
                Ok(())
            },
        );
    }
}
