//! # amp-gemm
//!
//! Reproduction of *Architecture-Aware Configuration and Scheduling of
//! Matrix Multiplication on Asymmetric Multicore Processors* (Catalán,
//! Igual, Mayo, Rodríguez-Sánchez, Quintana-Ortí; 2015) as a three-layer
//! Rust + JAX + Pallas system, generalized from the paper's two-cluster
//! big.LITTLE testbed to arbitrary N-cluster topologies. See DESIGN.md
//! for the system inventory, the hardware-substitution rationale (§1),
//! the `Topology` model (§2) and the experiment index (§9).
//!
//! Layer map:
//! * [`soc`] — the **topology descriptor**: `SocSpec` holds a
//!   `Vec<ClusterSpec>`, each cluster carrying its core count,
//!   frequency, DVFS operating-point ladder (`OppTable`), cache
//!   geometry, flops/cycle, tuned BLIS parameters and calibrated model
//!   constants (`ClusterTuning`). Cores are addressed
//!   `(ClusterId, core_idx)`; presets cover the paper's Exynos 5422, an
//!   ARMv8 Juno, a tri-cluster DynamIQ-style SoC and a symmetric SMP;
//! * [`dvfs`] — the **frequency axis**: `Governor` policies
//!   (performance/powersave/ondemand) plan `DvfsSchedule`s of timed OPP
//!   transitions in virtual time; the replay engine recomputes the
//!   per-cluster throughputs and the `sched::Weights` vector at every
//!   transition, so SAS repartitions *online* instead of keeping stale
//!   boot-time weights (the first place the weight vector is a function
//!   of time); `Governor::plan_closed_loop` consumes measured
//!   `LoadSignal`s (per-period cluster utilization) so the ondemand
//!   ramp reacts to the workload instead of the clock — saturating
//!   load degenerates to the open-loop ramp bit for bit;
//! * [`cache`], [`model`], [`energy`], [`sim`] — the simulated AMP
//!   substrate (cache simulator, calibrated per-cluster performance and
//!   power models, discrete-event engine); `sim::engine` is its
//!   **performance layer**: a memoizing `RunCache` (DES results keyed
//!   by configuration fingerprint × shape, with `des_runs`/`cache_hits`
//!   counters surfaced through the fleet stats) and a deterministic
//!   binary-heap `EventQueue` ((time, tie, seq) ordering), which
//!   together carry million-arrival streaming sweeps;
//! * [`blis`], [`partition`], [`sched`] — the paper's contribution:
//!   BLIS control trees (one per cluster), N-way loop partitioning
//!   (weighted-static and dynamic-queue) and the SSS/SAS/CA-SAS/DAS/
//!   CA-DAS scheduling strategies driven by per-way weight vectors
//!   (clusters of a SoC, or boards of a fleet — `sched::Weighted`);
//! * [`native`] — real multithreaded packed GEMM applying those
//!   strategies on any topology (numerics verified against the oracle);
//! * [`dag`] — the **task-DAG layer** (DESIGN.md §12): `TaskGraph`
//!   builders for tiled blocked Cholesky/LU whose per-tile kernels
//!   reuse the packing/control-tree layer (`blis::level3::trsm_lower`,
//!   `native::gemm_parallel`), a deterministic criticality-aware list
//!   scheduler (critical path → fastest cluster at its tuned
//!   `(mc, kc)`, trailing updates split by the existing
//!   `sched::Weights` vector, so every `WeightSource` drives it
//!   unchanged) vs a cluster-oblivious comparator, a verified numeric
//!   executor, and the unified `JobSpec` workload API — `Arrival`, the
//!   request `Batcher` key, `Fleet::plan_wave`, the stream DES and the
//!   coordinator `JOB` wire commands all carry
//!   `Gemm | Level3 | Factor` jobs through one set of queues, caches
//!   and stats (GEMM-only paths pinned bit-for-bit);
//! * [`runtime`], [`coordinator`] — the PJRT artifact runtime (HLO text
//!   → compile → execute), the GEMM service on top, the generic-key
//!   request `Batcher`, the one-wave-per-batch `FleetDispatcher` and
//!   the streaming `StreamDispatcher` front-end (timestamped admission,
//!   mixed-shape waves of per-shape subgroups, work-conserving backfill
//!   with no wave barrier, responses merged in submission order);
//! * [`fleet`] — the scale-out layer: a `Fleet` of heterogeneous
//!   `Board`s sharded by the board-level fleet-SSS/SAS/DAS strategies
//!   (cluster : SoC :: board : fleet) with mixed-shape wave shard plans,
//!   plus deterministic virtual-time simulators — one batch wave
//!   (`simulate_fleet`), arrival-driven streaming
//!   (`simulate_fleet_stream`, idle-tail/queue-depth/utilization
//!   accounting) and the synchronous wave comparator, for capacity
//!   planning and streaming-vs-wave studies; `fleet::autoscale` closes
//!   the provisioning loop — $/hour-priced boards grown, shrunk and
//!   downgraded against a p99-sojourn `SloPolicy` (DESIGN.md §11,
//!   `amp-gemm autoscale`);
//! * [`obs`] — the **observability layer** (DESIGN.md §6): a
//!   `MetricsRegistry` of counters/gauges/mergeable log-linear
//!   histograms threaded through the run cache, fleet streams, DVFS
//!   replays and energy accounting (Prometheus/JSON/TSV exports, the
//!   coordinator `METRICS` command, `amp-gemm metrics`), and a
//!   virtual-time `TraceSink` rendering request lifecycles, per-cluster
//!   phase spans and OPP transitions as Perfetto-openable Chrome trace
//!   JSON (`amp-gemm trace`) — with a zero-overhead-when-off contract:
//!   the default `NullSink` + disabled-registry path is bit-for-bit the
//!   PR 6 fast path;
//! * [`calibrate`] — the **empirical calibration layer**: measured
//!   per-cluster rate tables (shape-classed small/medium/large
//!   `kc`-bound regimes, one row per OPP rung and parameter family,
//!   exact TSV round-trip) filled from isolated per-cluster DES runs,
//!   and the `WeightSource::{Analytical, Empirical, Hybrid, Live}`
//!   selector threaded through SAS/CA-SAS weight construction, the
//!   DVFS online retuner (per-OPP rates), fleet-SAS board weights and
//!   capacity planning — with the analytical-degeneracy anchor (a
//!   table synthesized from the model reproduces the analytical
//!   weights bit for bit) and the CI perf-trajectory harness
//!   (`calibrate::trajectory`, `BENCH_baseline.json` gate);
//!   `calibrate::live` learns the same rates *online* from the chunks
//!   the fleet stream is already serving (per-event EWMA cells,
//!   confidence-gated per-cell analytical fallback, mid-stream
//!   re-planning via `simulate_fleet_stream_live`, frozen snapshots
//!   that replay bit for bit — DESIGN.md §5 "Live calibration",
//!   `amp-gemm calibrate --live`);
//! * [`search`], [`figures`] — the per-cluster empirical (mc, kc)
//!   search (swept per OPP, with persisted per-point presets that
//!   optionally carry measured shape-classed rates) and the
//!   regeneration harness for every evaluation figure in the paper
//!   (plus the §6-roadmap ablations, topology sweeps, the
//!   fleet-throughput-scaling report, the DVFS perf/energy
//!   Pareto-frontier report and the calibration report);
//! * [`util`] — deterministic RNG, stats, tables, mini-prop, benchkit,
//!   CLI.
//!
//! The Exynos 5422 preset is pinned bit-for-bit to the paper's §3.2
//! values by `tests/exynos_regression.rs`, so the generalization can
//! never silently drift the reproduction.

pub mod blis;
pub mod cache;
pub mod calibrate;
pub mod coordinator;
pub mod dag;
pub mod dvfs;
pub mod energy;
pub mod figures;
pub mod fleet;
pub mod model;
pub mod native;
pub mod obs;
pub mod partition;
pub mod runtime;
pub mod sched;
pub mod search;
pub mod sim;
pub mod soc;
pub mod util;
