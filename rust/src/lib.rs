//! # amp-gemm
//!
//! Reproduction of *Architecture-Aware Configuration and Scheduling of
//! Matrix Multiplication on Asymmetric Multicore Processors* (Catalán,
//! Igual, Mayo, Rodríguez-Sánchez, Quintana-Ortí; 2015) as a three-layer
//! Rust + JAX + Pallas system. See DESIGN.md for the system inventory,
//! the hardware-substitution rationale and the experiment index, and
//! EXPERIMENTS.md for paper-vs-measured results.
//!
//! Layer map:
//! * `soc`, `cache`, `model`, `energy`, `sim` — the simulated Exynos
//!   5422 substrate (descriptor, cache simulator, calibrated performance
//!   and power models, discrete-event engine);
//! * `blis`, `partition`, `sched` — the paper's contribution: BLIS
//!   control trees, loop partitioning and the SSS/SAS/CA-SAS/DAS/CA-DAS
//!   scheduling strategies;
//! * `native` — real multithreaded packed GEMM applying those
//!   strategies (numerics verified against the oracle);
//! * `runtime`, `coordinator` — the PJRT artifact runtime (HLO text →
//!   compile → execute) and the GEMM service on top;
//! * `search`, `figures` — the empirical (mc,kc) search and the
//!   regeneration harness for every evaluation figure in the paper;
//! * `util` — deterministic RNG, stats, tables, mini-prop, benchkit, CLI.

pub mod blis;
pub mod cache;
pub mod coordinator;
pub mod energy;
pub mod figures;
pub mod model;
pub mod native;
pub mod partition;
pub mod runtime;
pub mod sched;
pub mod search;
pub mod sim;
pub mod soc;
pub mod util;
