//! Deterministic list scheduling of a [`TaskGraph`] across the
//! clusters of one SoC.
//!
//! Two policies share one engine:
//!
//! * [`DagPolicy::CriticalityAware`] — the arXiv:1509.02058 recipe on
//!   this codebase's machinery: tasks on the critical path are pinned
//!   to the fastest cluster (which runs them at its own tuned
//!   `(mc, kc)` — the per-cluster control trees of
//!   [`crate::sched::ScheduleSpec::cluster_only`]), and the trailing
//!   updates are spread so each cluster's accumulated busy time tracks
//!   its share of the existing [`Weights`] vector — the same vector
//!   SAS/CA-SAS use, so `WeightSource::{Analytical, Empirical, Live}`
//!   all drive the DAG unchanged;
//! * [`DagPolicy::Oblivious`] — the asymmetry-blind comparator:
//!   round-robin cluster assignment in dispatch order (the DAG
//!   analogue of SSS's equal split). Tile *physics* stay per-cluster
//!   truthful; only the placement ignores them.
//!
//! Everything is pure f64 virtual time with id-ordered tiebreaks, so a
//! schedule replays bit-for-bit for a given descriptor — the property
//! `tests/dag_props.rs` pins across randomized 1–4-cluster SoCs.

use crate::blis::gemm::GemmShape;
use crate::calibrate::{ShapeClass, WeightSource};
use crate::dag::graph::{FactorKind, TaskGraph};
use crate::model::PerfModel;
use crate::sched::{ScheduleSpec, Weights};
use crate::sim::{simulate, ItemCost, RunCache};
use crate::soc::ClusterId;

/// Placement policy for a DAG schedule.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum DagPolicy {
    /// Critical path to the fastest cluster, trailing updates split by
    /// the cluster weight vector.
    CriticalityAware,
    /// Round-robin placement in dispatch order — asymmetry-blind.
    Oblivious,
}

impl DagPolicy {
    pub fn label(self) -> &'static str {
        match self {
            DagPolicy::CriticalityAware => "criticality-aware",
            DagPolicy::Oblivious => "oblivious",
        }
    }
}

/// Per-cluster cost of one full `nb³` GEMM tile update, from one DES
/// run per cluster at that cluster's tuned parameters (cached in the
/// shared [`RunCache`] under the `cluster_only` configuration, so a
/// stream of factorizations prices its tiles exactly once). Kernel
/// costs derive by flop fraction ([`crate::dag::KernelKind`]).
#[derive(Debug, Clone)]
pub struct TileCosts {
    /// One entry per cluster: the tile GEMM's virtual time and energy.
    pub gemm_tile: Vec<ItemCost>,
}

impl TileCosts {
    pub fn num_clusters(&self) -> usize {
        self.gemm_tile.len()
    }

    /// Virtual seconds of `kind` on cluster `c`.
    pub fn time(&self, c: usize, kind: crate::dag::KernelKind) -> f64 {
        self.gemm_tile[c].time_s * kind.gemm_fraction()
    }

    /// Joules of `kind` on cluster `c`.
    pub fn energy(&self, c: usize, kind: crate::dag::KernelKind) -> f64 {
        self.gemm_tile[c].energy_j * kind.gemm_fraction()
    }

    /// Index of the fastest cluster for a tile (ties → lowest id) —
    /// where the critical path goes.
    pub fn fastest(&self) -> usize {
        let mut best = 0;
        for c in 1..self.gemm_tile.len() {
            if self.gemm_tile[c].time_s < self.gemm_tile[best].time_s {
                best = c;
            }
        }
        best
    }
}

/// Measure [`TileCosts`] for `nb × nb` tiles on every cluster of the
/// model's SoC, memoized through `cache`.
pub fn tile_costs(model: &PerfModel, nb: usize, cache: &mut RunCache) -> TileCosts {
    let shape = GemmShape::square(nb);
    let gemm_tile = model
        .soc
        .cluster_ids()
        .map(|c| {
            let spec = ScheduleSpec::cluster_only(c, model.soc[c].num_cores);
            let cfg = cache.config(model, &spec);
            cache.cost_with(cfg, shape, || simulate(model, &spec, shape))
        })
        .collect();
    TileCosts { gemm_tile }
}

/// One placed task of a schedule.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct ScheduledTask {
    pub task: usize,
    pub cluster: ClusterId,
    pub start_s: f64,
    pub finish_s: f64,
}

/// A complete deterministic schedule of one [`TaskGraph`].
#[derive(Debug, Clone, PartialEq)]
pub struct DagSchedule {
    pub policy: DagPolicy,
    /// Tasks in dispatch order (each task appears exactly once).
    pub order: Vec<ScheduledTask>,
    pub makespan_s: f64,
    /// Active (tile) energy, summed over every task.
    pub energy_j: f64,
    /// Active energy per cluster (rail split of `energy_j`).
    pub energy_clusters_j: Vec<f64>,
    /// Busy seconds per cluster.
    pub busy_s: Vec<f64>,
    /// How many tasks the policy deemed critical.
    pub critical_tasks: usize,
    /// Length of the critical path at fastest-cluster speeds — the
    /// makespan lower bound no schedule can beat.
    pub critical_path_s: f64,
}

impl DagSchedule {
    /// Effective GFLOPS of the factorization under this schedule.
    pub fn gflops(&self, graph: &TaskGraph) -> f64 {
        graph.kind.flops(graph.n) / self.makespan_s / 1e9
    }
}

/// Schedule `graph` over the clusters described by `costs`, splitting
/// non-critical work by `weights` (one entry per cluster). Fully
/// deterministic: ready tasks are picked by (longest bottom level,
/// lowest id), placement tiebreaks go to the lowest cluster id.
pub fn schedule(
    graph: &TaskGraph,
    costs: &TileCosts,
    weights: &Weights,
    policy: DagPolicy,
) -> DagSchedule {
    let n = graph.tasks.len();
    let nc = costs.num_clusters();
    assert!(nc >= 1, "need at least one cluster");
    assert_eq!(
        weights.len(),
        nc,
        "weight vector ({} ways) must match the cluster count ({nc})",
        weights.len()
    );
    let fast = costs.fastest();

    // Critical-path analysis at fastest-cluster speeds: bottom levels
    // (longest path to a sink, inclusive) drive the ready-list
    // priority; top + bottom == CP length marks the critical tasks.
    let succ = graph.successors();
    let t_fast: Vec<f64> = graph.tasks.iter().map(|t| costs.time(fast, t.kind)).collect();
    let mut bottom = vec![0.0f64; n];
    for id in (0..n).rev() {
        let tail = succ[id].iter().map(|&s| bottom[s]).fold(0.0f64, f64::max);
        bottom[id] = t_fast[id] + tail;
    }
    let mut top = vec![0.0f64; n];
    for id in 0..n {
        top[id] = graph.tasks[id]
            .deps
            .iter()
            .map(|&d| top[d] + t_fast[d])
            .fold(0.0f64, f64::max);
    }
    let cp = (0..n).map(|i| top[i] + bottom[i]).fold(0.0f64, f64::max);
    let critical: Vec<bool> =
        (0..n).map(|i| top[i] + bottom[i] >= cp * (1.0 - 1e-9)).collect();

    // Weight shares for the non-critical split; floor away from zero so
    // a degenerate weight vector can't divide by zero.
    let shares: Vec<f64> = (0..nc).map(|c| weights.share(c).max(1e-12)).collect();

    // List scheduling: ready set, highest bottom level first (id
    // breaks ties), one pass per task.
    let mut indeg: Vec<usize> = graph.tasks.iter().map(|t| t.deps.len()).collect();
    let mut ready: Vec<usize> = (0..n).filter(|&i| indeg[i] == 0).collect();
    let mut finish = vec![0.0f64; n];
    let mut clock = vec![0.0f64; nc];
    let mut busy = vec![0.0f64; nc];
    let mut assigned = vec![0.0f64; nc];
    let mut energy = vec![0.0f64; nc];
    let mut order = Vec::with_capacity(n);
    let mut rr = 0usize;
    while let Some(pos) = pick(&ready, &bottom) {
        let id = ready.swap_remove(pos);
        let kind = graph.tasks[id].kind;
        let c = match policy {
            DagPolicy::Oblivious => {
                let c = rr % nc;
                rr += 1;
                c
            }
            DagPolicy::CriticalityAware => {
                if critical[id] {
                    fast
                } else {
                    // Keep each cluster's accumulated busy time on its
                    // weight share: place where (assigned + cost)/share
                    // is smallest (ties → lowest cluster id).
                    let mut best = 0;
                    let mut best_v = f64::INFINITY;
                    for (c, share) in shares.iter().enumerate() {
                        let v = (assigned[c] + costs.time(c, kind)) / share;
                        if v < best_v {
                            best_v = v;
                            best = c;
                        }
                    }
                    best
                }
            }
        };
        let ready_at = graph.tasks[id]
            .deps
            .iter()
            .map(|&d| finish[d])
            .fold(0.0f64, f64::max);
        let start = clock[c].max(ready_at);
        let dur = costs.time(c, kind);
        finish[id] = start + dur;
        clock[c] = finish[id];
        busy[c] += dur;
        assigned[c] += dur;
        energy[c] += costs.energy(c, kind);
        order.push(ScheduledTask {
            task: id,
            cluster: ClusterId(c),
            start_s: start,
            finish_s: finish[id],
        });
        for &s in &succ[id] {
            indeg[s] -= 1;
            if indeg[s] == 0 {
                ready.push(s);
            }
        }
    }
    assert_eq!(order.len(), n, "schedule must place every task exactly once");

    DagSchedule {
        policy,
        makespan_s: clock.iter().cloned().fold(0.0f64, f64::max),
        energy_j: energy.iter().sum(),
        energy_clusters_j: energy,
        busy_s: busy,
        critical_tasks: critical.iter().filter(|&&c| c).count(),
        critical_path_s: cp,
        order,
    }
}

/// Ready-list pick: highest bottom level, lowest id on ties. Returns
/// the *position* in `ready`.
fn pick(ready: &[usize], bottom: &[f64]) -> Option<usize> {
    let mut best: Option<usize> = None;
    for (pos, &id) in ready.iter().enumerate() {
        let better = match best {
            None => true,
            Some(b) => {
                let (bb, bi) = (bottom[ready[b]], ready[b]);
                bottom[id] > bb || (bottom[id] == bb && id < bi)
            }
        };
        if better {
            best = Some(pos);
        }
    }
    best
}

/// Price one `Factor` job for the stream DES: build the graph, measure
/// the tile costs (memoized in `cache`), schedule criticality-aware
/// with the board's weight vector, and return the makespan/energy as
/// the per-item cost plus the per-cluster energy rails.
pub fn factor_price(
    model: &PerfModel,
    source: &WeightSource,
    kind: FactorKind,
    n: usize,
    nb: usize,
    cache: &mut RunCache,
) -> (ItemCost, Vec<f64>) {
    let graph = TaskGraph::build(kind, n, nb);
    let costs = tile_costs(model, nb, cache);
    let class = ShapeClass::for_soc(&model.soc, GemmShape::square(nb));
    let weights = source.weights(model, true, class);
    let s = schedule(&graph, &costs, &weights, DagPolicy::CriticalityAware);
    (
        ItemCost { time_s: s.makespan_s, energy_j: s.energy_j },
        s.energy_clusters_j,
    )
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::dag::KernelKind;
    use crate::soc::SocSpec;

    fn exynos_setup(n: usize, nb: usize) -> (TaskGraph, TileCosts, Weights) {
        let model = PerfModel::new(SocSpec::exynos5422());
        let graph = TaskGraph::cholesky(n, nb);
        let mut cache = RunCache::new();
        let costs = tile_costs(&model, nb, &mut cache);
        let w = WeightSource::Analytical.weights(&model, true, ShapeClass::Large);
        (graph, costs, w)
    }

    #[test]
    fn tile_costs_reflect_the_asymmetry() {
        let model = PerfModel::new(SocSpec::exynos5422());
        let mut cache = RunCache::new();
        let costs = tile_costs(&model, 128, &mut cache);
        assert_eq!(costs.num_clusters(), 2);
        assert_eq!(costs.fastest(), 0, "the A15 cluster is the fast one");
        let ratio = costs.gemm_tile[1].time_s / costs.gemm_tile[0].time_s;
        assert!(ratio > 2.0, "big:LITTLE tile-time ratio {ratio}");
        // Kernel fractions order as documented.
        assert!(costs.time(0, KernelKind::Potrf) < costs.time(0, KernelKind::Trsm));
        assert!(costs.time(0, KernelKind::Trsm) < costs.time(0, KernelKind::GemmUpd));
        // Memoized: a second measurement is pure cache hits.
        let runs = cache.cached_runs();
        let again = tile_costs(&model, 128, &mut cache);
        assert_eq!(cache.cached_runs(), runs);
        assert_eq!(again.gemm_tile[0].time_s, costs.gemm_tile[0].time_s);
    }

    #[test]
    fn both_policies_respect_dependencies_and_place_exactly_once() {
        let (graph, costs, w) = exynos_setup(768, 128);
        for policy in [DagPolicy::CriticalityAware, DagPolicy::Oblivious] {
            let s = schedule(&graph, &costs, &w, policy);
            assert_eq!(s.order.len(), graph.num_tasks());
            let mut seen = vec![false; graph.num_tasks()];
            let mut finish = vec![0.0; graph.num_tasks()];
            for st in &s.order {
                assert!(!seen[st.task], "task {} placed twice", st.task);
                seen[st.task] = true;
                finish[st.task] = st.finish_s;
                for &d in &graph.tasks[st.task].deps {
                    assert!(seen[d], "task {} dispatched before dep {d}", st.task);
                    assert!(
                        st.start_s >= finish[d] - 1e-12,
                        "task {} starts at {} before dep {d} finishes at {}",
                        st.task,
                        st.start_s,
                        finish[d]
                    );
                }
            }
            assert!(s.makespan_s >= s.critical_path_s - 1e-12);
            assert!(s.makespan_s > 0.0 && s.energy_j > 0.0);
        }
    }

    #[test]
    fn criticality_aware_beats_oblivious_on_exynos() {
        let (graph, costs, w) = exynos_setup(1024, 128);
        let ca = schedule(&graph, &costs, &w, DagPolicy::CriticalityAware);
        let obl = schedule(&graph, &costs, &w, DagPolicy::Oblivious);
        assert!(
            ca.makespan_s * 1.05 < obl.makespan_s,
            "CA {} vs oblivious {}",
            ca.makespan_s,
            obl.makespan_s
        );
        // Critical tasks all landed on the fast cluster.
        assert!(ca.critical_tasks > 0);
        assert!(ca.busy_s[0] > ca.busy_s[1], "{:?}", ca.busy_s);
    }

    #[test]
    fn factor_price_is_deterministic_and_positive() {
        let model = PerfModel::new(SocSpec::exynos5422());
        let mut cache = RunCache::new();
        let (a, rails_a) =
            factor_price(&model, &WeightSource::Analytical, FactorKind::Cholesky, 768, 128, &mut cache);
        let (b, rails_b) =
            factor_price(&model, &WeightSource::Analytical, FactorKind::Cholesky, 768, 128, &mut cache);
        assert_eq!(a.time_s, b.time_s);
        assert_eq!(a.energy_j, b.energy_j);
        assert_eq!(rails_a, rails_b);
        assert!(a.time_s > 0.0 && a.energy_j > 0.0);
        assert_eq!(rails_a.len(), 2);
        assert!((rails_a.iter().sum::<f64>() - a.energy_j).abs() < 1e-9);
        // LU does twice the flops — it must cost visibly more.
        let (lu, _) =
            factor_price(&model, &WeightSource::Analytical, FactorKind::Lu, 768, 128, &mut cache);
        assert!(lu.time_s > a.time_s);
    }
}
