//! Tiled task graphs for blocked dense factorizations.
//!
//! A [`TaskGraph`] is a vector of [`Task`]s whose ids are a topological
//! order *by construction*: builders emit tasks in the right-looking
//! elimination order and every dependency points at an earlier id (each
//! tile tracks its last writer). That invariant is what lets the
//! numeric executor ([`crate::dag::exec`]) simply walk ids 0..n and the
//! schedulers treat the id as a deterministic tiebreaker.

/// Which factorization a graph (or a `JobSpec::Factor`) performs.
#[derive(Debug, Clone, Copy, PartialEq, Eq, PartialOrd, Ord, Hash)]
pub enum FactorKind {
    /// A = L·Lᵀ, A symmetric positive definite, lower stored.
    Cholesky,
    /// A = L·U without pivoting (L unit lower), for diagonally
    /// dominant operands.
    Lu,
}

impl FactorKind {
    pub fn label(self) -> &'static str {
        match self {
            FactorKind::Cholesky => "chol",
            FactorKind::Lu => "lu",
        }
    }

    pub fn parse(s: &str) -> Result<FactorKind, String> {
        match s {
            "chol" | "cholesky" => Ok(FactorKind::Cholesky),
            "lu" => Ok(FactorKind::Lu),
            other => Err(format!("unknown factorization '{other}' (chol|lu)")),
        }
    }

    /// Useful flops of the full factorization of an `n × n` matrix.
    pub fn flops(self, n: usize) -> f64 {
        let n = n as f64;
        match self {
            FactorKind::Cholesky => n * n * n / 3.0,
            FactorKind::Lu => 2.0 * n * n * n / 3.0,
        }
    }
}

/// The per-tile kernel a task runs. Costs are expressed as fractions
/// of one full `nb³` GEMM tile update (`2·nb³` flops) — the quantity
/// one DES run per cluster calibrates ([`crate::dag::sched::tile_costs`]).
#[derive(Debug, Clone, Copy, PartialEq, Eq, PartialOrd, Ord, Hash)]
pub enum KernelKind {
    /// Cholesky of the diagonal tile: `nb³/3` flops.
    Potrf,
    /// LU of the diagonal tile: `2·nb³/3` flops.
    Getrf,
    /// Triangular panel solve: `nb³` flops.
    Trsm,
    /// Symmetric rank-k tile update (lower half): `nb³` flops.
    Syrk,
    /// Trailing GEMM tile update: `2·nb³` flops.
    GemmUpd,
}

impl KernelKind {
    /// This kernel's flops as a fraction of the `2·nb³` GEMM tile.
    pub fn gemm_fraction(self) -> f64 {
        match self {
            KernelKind::Potrf => 1.0 / 6.0,
            KernelKind::Getrf => 1.0 / 3.0,
            KernelKind::Trsm => 0.5,
            KernelKind::Syrk => 0.5,
            KernelKind::GemmUpd => 1.0,
        }
    }

    pub fn label(self) -> &'static str {
        match self {
            KernelKind::Potrf => "potrf",
            KernelKind::Getrf => "getrf",
            KernelKind::Trsm => "trsm",
            KernelKind::Syrk => "syrk",
            KernelKind::GemmUpd => "gemm",
        }
    }
}

/// One tiled kernel invocation: writes tile `(row, col)` at elimination
/// step `step`, after every task in `deps` (all with smaller ids).
#[derive(Debug, Clone, PartialEq)]
pub struct Task {
    pub id: usize,
    pub kind: KernelKind,
    /// Block-row of the output tile.
    pub row: usize,
    /// Block-column of the output tile.
    pub col: usize,
    /// Elimination step (the `k` of the right-looking outer loop).
    pub step: usize,
    /// Ids of the tasks that must finish first; strictly smaller than
    /// `id`, so id order is a topological order.
    pub deps: Vec<usize>,
}

/// A blocked factorization as a dependency graph of tiled kernels.
#[derive(Debug, Clone, PartialEq)]
pub struct TaskGraph {
    pub kind: FactorKind,
    /// Matrix dimension; must be a multiple of `nb`.
    pub n: usize,
    /// Tile (block) size.
    pub nb: usize,
    /// Tiles per dimension (`n / nb`).
    pub nt: usize,
    pub tasks: Vec<Task>,
}

impl TaskGraph {
    /// Build the graph for `kind` on an `n × n` matrix with `nb × nb`
    /// tiles. `n` must be a positive multiple of `nb`.
    pub fn build(kind: FactorKind, n: usize, nb: usize) -> TaskGraph {
        match kind {
            FactorKind::Cholesky => TaskGraph::cholesky(n, nb),
            FactorKind::Lu => TaskGraph::lu(n, nb),
        }
    }

    fn builder(kind: FactorKind, n: usize, nb: usize) -> (TaskGraph, TileOwners) {
        assert!(
            nb >= 1 && n >= nb && n % nb == 0,
            "factor graph needs n a positive multiple of nb, got n={n} nb={nb}"
        );
        let nt = n / nb;
        (
            TaskGraph { kind, n, nb, nt, tasks: Vec::new() },
            TileOwners { last_writer: vec![None; nt * nt], nt },
        )
    }

    /// Right-looking blocked Cholesky (arXiv:1509.02058's running
    /// example): per step `k`, `potrf(k,k)`, a `trsm` column panel, then
    /// `syrk` diagonal and `gemm` off-diagonal trailing updates.
    pub fn cholesky(n: usize, nb: usize) -> TaskGraph {
        let (mut g, mut own) = TaskGraph::builder(FactorKind::Cholesky, n, nb);
        for k in 0..g.nt {
            let potrf = g.push(KernelKind::Potrf, k, k, k, own.reads(&[(k, k)]));
            own.write(k, k, potrf);
            let trsm: Vec<usize> = (k + 1..g.nt)
                .map(|i| {
                    let t = g.push(KernelKind::Trsm, i, k, k, own.reads(&[(k, k), (i, k)]));
                    own.write(i, k, t);
                    t
                })
                .collect();
            for i in k + 1..g.nt {
                let ti = trsm[i - k - 1];
                let s = g.push(KernelKind::Syrk, i, i, k, own.reads_plus(&[(i, i)], &[ti]));
                own.write(i, i, s);
                for j in k + 1..i {
                    let tj = trsm[j - k - 1];
                    let u =
                        g.push(KernelKind::GemmUpd, i, j, k, own.reads_plus(&[(i, j)], &[ti, tj]));
                    own.write(i, j, u);
                }
            }
        }
        g
    }

    /// Right-looking blocked LU without pivoting: per step `k`,
    /// `getrf(k,k)`, a `trsm` row panel (U tiles) and column panel
    /// (L tiles), then `gemm` trailing updates.
    pub fn lu(n: usize, nb: usize) -> TaskGraph {
        let (mut g, mut own) = TaskGraph::builder(FactorKind::Lu, n, nb);
        for k in 0..g.nt {
            let getrf = g.push(KernelKind::Getrf, k, k, k, own.reads(&[(k, k)]));
            own.write(k, k, getrf);
            let row: Vec<usize> = (k + 1..g.nt)
                .map(|j| {
                    let t = g.push(KernelKind::Trsm, k, j, k, own.reads(&[(k, k), (k, j)]));
                    own.write(k, j, t);
                    t
                })
                .collect();
            let col: Vec<usize> = (k + 1..g.nt)
                .map(|i| {
                    let t = g.push(KernelKind::Trsm, i, k, k, own.reads(&[(k, k), (i, k)]));
                    own.write(i, k, t);
                    t
                })
                .collect();
            for i in k + 1..g.nt {
                for j in k + 1..g.nt {
                    let deps = vec![col[i - k - 1], row[j - k - 1]];
                    let u = g.push(KernelKind::GemmUpd, i, j, k, own.reads_plus(&[(i, j)], &deps));
                    own.write(i, j, u);
                }
            }
        }
        g
    }

    fn push(&mut self, kind: KernelKind, row: usize, col: usize, step: usize, deps: Vec<usize>) -> usize {
        let id = self.tasks.len();
        debug_assert!(deps.iter().all(|&d| d < id), "deps must precede the task");
        self.tasks.push(Task { id, kind, row, col, step, deps });
        id
    }

    pub fn num_tasks(&self) -> usize {
        self.tasks.len()
    }

    /// Successor adjacency (who waits on each task).
    pub fn successors(&self) -> Vec<Vec<usize>> {
        let mut succ = vec![Vec::new(); self.tasks.len()];
        for t in &self.tasks {
            for &d in &t.deps {
                succ[d].push(t.id);
            }
        }
        succ
    }

    /// Check the structural invariants: ids are dense and ordered,
    /// every dependency points at an earlier task (id order is
    /// topological) with no duplicates.
    pub fn validate(&self) -> Result<(), String> {
        for (i, t) in self.tasks.iter().enumerate() {
            if t.id != i {
                return Err(format!("task {i} carries id {}", t.id));
            }
            let mut seen = std::collections::HashSet::new();
            for &d in &t.deps {
                if d >= i {
                    return Err(format!("task {i} depends on later task {d}"));
                }
                if !seen.insert(d) {
                    return Err(format!("task {i} lists dep {d} twice"));
                }
            }
            if t.row >= self.nt || t.col >= self.nt || t.step >= self.nt {
                return Err(format!("task {i} addresses tile out of range"));
            }
        }
        Ok(())
    }

    /// Total graph flops — the tile-kernel sum, which telescopes to the
    /// closed form of [`FactorKind::flops`] up to the blocked
    /// algorithm's tile granularity.
    pub fn flops(&self) -> f64 {
        let tile = 2.0 * (self.nb as f64).powi(3);
        self.tasks.iter().map(|t| t.kind.gemm_fraction() * tile).sum()
    }
}

/// Last writer of every tile — what turns the elimination order into
/// dependency edges while keeping deps strictly backwards.
struct TileOwners {
    last_writer: Vec<Option<usize>>,
    nt: usize,
}

impl TileOwners {
    fn reads(&self, tiles: &[(usize, usize)]) -> Vec<usize> {
        self.reads_plus(tiles, &[])
    }

    /// Deps = last writers of the read tiles, plus explicit extra task
    /// ids, deduplicated, in ascending order (determinism).
    fn reads_plus(&self, tiles: &[(usize, usize)], extra: &[usize]) -> Vec<usize> {
        let mut deps: Vec<usize> = tiles
            .iter()
            .filter_map(|&(r, c)| self.last_writer[r * self.nt + c])
            .chain(extra.iter().copied())
            .collect();
        deps.sort_unstable();
        deps.dedup();
        deps
    }

    fn write(&mut self, row: usize, col: usize, id: usize) {
        self.last_writer[row * self.nt + col] = Some(id);
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn cholesky_task_counts_match_closed_form() {
        // nt tiles: potrf nt, trsm nt(nt-1)/2, syrk nt(nt-1)/2,
        // gemm nt(nt-1)(nt-2)/6.
        for nt in 1..=6usize {
            let g = TaskGraph::cholesky(64 * nt, 64);
            g.validate().unwrap();
            assert_eq!(g.nt, nt);
            let count = |k: KernelKind| g.tasks.iter().filter(|t| t.kind == k).count();
            assert_eq!(count(KernelKind::Potrf), nt);
            assert_eq!(count(KernelKind::Trsm), nt * (nt - 1) / 2);
            assert_eq!(count(KernelKind::Syrk), nt * (nt - 1) / 2);
            assert_eq!(count(KernelKind::GemmUpd), nt * (nt - 1) * (nt.max(2) - 2) / 6);
        }
    }

    #[test]
    fn lu_task_counts_match_closed_form() {
        for nt in 1..=5usize {
            let g = TaskGraph::lu(32 * nt, 32);
            g.validate().unwrap();
            let count = |k: KernelKind| g.tasks.iter().filter(|t| t.kind == k).count();
            assert_eq!(count(KernelKind::Getrf), nt);
            assert_eq!(count(KernelKind::Trsm), nt * (nt - 1));
            let gemms: usize = (0..nt).map(|k| (nt - 1 - k) * (nt - 1 - k)).sum();
            assert_eq!(count(KernelKind::GemmUpd), gemms);
        }
    }

    #[test]
    fn graph_flops_approach_closed_form() {
        // The blocked sum equals the closed form up to O(n²·nb) tile
        // granularity; at nt = 8 they are within a few percent.
        for kind in [FactorKind::Cholesky, FactorKind::Lu] {
            let g = TaskGraph::build(kind, 1024, 128);
            let exact = kind.flops(1024);
            let rel = (g.flops() - exact).abs() / exact;
            assert!(rel < 0.25, "{kind:?}: blocked {} vs exact {exact}", g.flops());
        }
    }

    #[test]
    fn dependencies_capture_the_elimination_order() {
        let g = TaskGraph::cholesky(384, 128); // nt = 3
        g.validate().unwrap();
        // The final potrf transitively depends on everything that
        // writes tile (2,2): syrk at steps 0 and 1.
        let last = g.tasks.iter().rev().find(|t| t.kind == KernelKind::Potrf).unwrap();
        assert_eq!((last.row, last.col), (2, 2));
        let dep = &g.tasks[*last.deps.last().unwrap()];
        assert_eq!(dep.kind, KernelKind::Syrk);
        assert_eq!((dep.row, dep.col, dep.step), (2, 2, 1));
        // Trsm depends on its step's potrf.
        let trsm = g.tasks.iter().find(|t| t.kind == KernelKind::Trsm).unwrap();
        assert!(trsm.deps.iter().any(|&d| g.tasks[d].kind == KernelKind::Potrf));
    }

    #[test]
    fn successors_mirror_deps() {
        let g = TaskGraph::lu(256, 64);
        let succ = g.successors();
        for t in &g.tasks {
            for &d in &t.deps {
                assert!(succ[d].contains(&t.id));
            }
        }
        // Sources and sinks exist.
        assert!(g.tasks[0].deps.is_empty());
        assert!(succ.last().unwrap().is_empty());
    }

    #[test]
    #[should_panic(expected = "multiple of nb")]
    fn ragged_tiling_rejected() {
        TaskGraph::cholesky(100, 64);
    }
}
