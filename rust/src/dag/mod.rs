//! Task-DAG factorization runtime and the unified `JobSpec` workload
//! API (DESIGN.md §12).
//!
//! The paper schedules the loops of *one* GEMM across asymmetric
//! clusters; its §6 roadmap (and the follow-on work, arXiv:1511.02171
//! for the BLAS-3 family, arXiv:1509.02058 for criticality-aware task
//! scheduling of dense factorizations) points at the natural next
//! level: a *graph* of tiled kernels. This module supplies it:
//!
//! * [`graph`] — [`TaskGraph`]: tiled right-looking blocked Cholesky
//!   and LU builders whose tasks are per-tile kernels
//!   (`potrf`/`getrf`/`trsm`/`syrk`/`gemm`-panel) with structural
//!   dependencies, ids in topological order by construction;
//! * [`sched`] — deterministic list scheduling of a [`TaskGraph`]
//!   across the clusters of a SoC: **criticality-aware** (critical-path
//!   tasks pinned to the fastest cluster at its tuned `(mc, kc)`,
//!   trailing updates split in proportion to the existing
//!   [`crate::sched::Weights`] vector, so
//!   `WeightSource::{Analytical, Empirical, Live}` all drive it
//!   unchanged) vs the **cluster-oblivious** round-robin comparator;
//! * [`exec`] — the numeric executor: runs a graph's tasks in
//!   topological order on real row-major matrices, per-tile kernels
//!   delegating to [`crate::blis::level3`] (`trsm_lower`) and the
//!   packed parallel [`crate::native::gemm_parallel`] for every
//!   trailing update, verified against naive reference factorizations;
//! * [`JobSpec`] — the workload unit the dispatch layers now share.
//!   `Arrival`, the request `Batcher` key, [`crate::fleet::Fleet::plan_wave`]
//!   and the stream DES all carry a `JobSpec` instead of a raw
//!   [`GemmShape`], so factorizations and level-3 ops flow through the
//!   same queues, caches and stats as plain GEMMs. GEMM-only streams
//!   are bit-for-bit the old API (pinned by `tests/stream_props.rs`
//!   and `tests/fleet_golden.rs`).

pub mod exec;
pub mod graph;
pub mod sched;

pub use graph::{FactorKind, KernelKind, Task, TaskGraph};
pub use sched::{factor_price, schedule, tile_costs, DagPolicy, DagSchedule, TileCosts};

use crate::blis::gemm::GemmShape;

/// Level-3 BLAS operations served through the job API. Each maps to a
/// [`crate::blis::level3`] kernel whose DES cost profile is that of an
/// equivalent GEMM ([`JobSpec::equiv_gemm`]) scaled by the op's flop
/// fraction ([`JobSpec::cost_scale`]).
#[derive(Debug, Clone, Copy, PartialEq, Eq, PartialOrd, Ord, Hash)]
pub enum Level3Op {
    /// `symm_lower`: C += A·B with A symmetric (lower stored) — a full
    /// GEMM's worth of flops.
    SymmLower,
    /// `trsm_lower`: solve L·X = B in place — half a GEMM.
    TrsmLower,
    /// `syrk_lower`: C_lower += A·Aᵀ — half a GEMM.
    SyrkLower,
    /// `trmm_lower_left`: B := L·B — half a GEMM.
    TrmmLower,
}

impl Level3Op {
    pub fn label(self) -> &'static str {
        match self {
            Level3Op::SymmLower => "symm",
            Level3Op::TrsmLower => "trsm",
            Level3Op::SyrkLower => "syrk",
            Level3Op::TrmmLower => "trmm",
        }
    }

    pub fn parse(s: &str) -> Result<Level3Op, String> {
        match s {
            "symm" => Ok(Level3Op::SymmLower),
            "trsm" => Ok(Level3Op::TrsmLower),
            "syrk" => Ok(Level3Op::SyrkLower),
            "trmm" => Ok(Level3Op::TrmmLower),
            other => Err(format!("unknown level-3 op '{other}' (symm|trsm|syrk|trmm)")),
        }
    }
}

/// One unit of schedulable work — the workload vocabulary every
/// dispatch layer now shares (`Arrival`, `Batcher` keys,
/// `Fleet::plan_wave`, the stream DES, the `JOB` wire command).
///
/// `Gemm` is deliberately the first variant: the derived `Ord` then
/// sorts GEMM-only job sets exactly as the raw [`GemmShape`] `Ord`
/// did, so every `BTreeMap` tally and per-job stats vector of a
/// GEMM-only stream iterates — and therefore sums — in the historical
/// order, keeping the old entry points bit-for-bit through the new API.
#[derive(Debug, Clone, Copy, PartialEq, Eq, PartialOrd, Ord, Hash)]
pub enum JobSpec {
    /// A plain GEMM — the paper's workload, unchanged.
    Gemm(GemmShape),
    /// One level-3 BLAS op; `m`/`n` are the operand dimensions
    /// (`m` is the triangular/symmetric dimension, `n` the panel width;
    /// for `syrk`, `m` is the output dimension and `n` the inner `k`).
    Level3 { op: Level3Op, m: usize, n: usize },
    /// A blocked factorization of an `n × n` matrix with tile size
    /// `nb`, executed as a task DAG ([`TaskGraph`]).
    Factor { kind: FactorKind, n: usize, nb: usize },
}

impl From<GemmShape> for JobSpec {
    fn from(shape: GemmShape) -> JobSpec {
        JobSpec::Gemm(shape)
    }
}

impl JobSpec {
    /// The GEMM shape, if this is a plain GEMM job.
    pub fn gemm(self) -> Option<GemmShape> {
        match self {
            JobSpec::Gemm(s) => Some(s),
            _ => None,
        }
    }

    /// Useful floating-point operations of one job.
    pub fn flops(self) -> f64 {
        match self {
            JobSpec::Gemm(s) => s.flops(),
            JobSpec::Level3 { op, m, n } => {
                let (m, n) = (m as f64, n as f64);
                match op {
                    // symm_lower runs a full m×n×m GEMM's flops.
                    Level3Op::SymmLower => 2.0 * m * m * n,
                    Level3Op::TrsmLower | Level3Op::TrmmLower | Level3Op::SyrkLower => m * m * n,
                }
            }
            JobSpec::Factor { kind, n, .. } => kind.flops(n),
        }
    }

    /// The GEMM whose DES run profiles this job's per-item service
    /// cost. For a `Factor` job this is the `nb × nb` *tile* GEMM (the
    /// DAG scheduler prices the whole graph from it); level-3 ops map
    /// to the dense GEMM their blocked implementation performs.
    pub fn equiv_gemm(self) -> GemmShape {
        match self {
            JobSpec::Gemm(s) => s,
            JobSpec::Level3 { op, m, n } => match op {
                Level3Op::SymmLower | Level3Op::TrsmLower | Level3Op::TrmmLower => {
                    GemmShape { m, n, k: m }
                }
                Level3Op::SyrkLower => GemmShape { m, n: m, k: n },
            },
            JobSpec::Factor { nb, .. } => GemmShape::square(nb),
        }
    }

    /// Fraction of the equivalent GEMM's cost this job incurs
    /// (time and energy scale together — same kernel, fewer flops).
    /// `Factor` jobs are not priced this way — see
    /// [`sched::factor_price`] — so they report 1.0.
    pub fn cost_scale(self) -> f64 {
        match self {
            JobSpec::Gemm(_) => 1.0,
            JobSpec::Level3 { op, .. } => match op {
                Level3Op::SymmLower => 1.0,
                Level3Op::TrsmLower | Level3Op::SyrkLower | Level3Op::TrmmLower => 0.5,
            },
            JobSpec::Factor { .. } => 1.0,
        }
    }

    /// Human/trace label. For GEMM jobs this is exactly the label the
    /// pre-`JobSpec` stream tracer emitted (`gemm {m}x{n}x{k}`), so
    /// GEMM-only traces are unchanged.
    pub fn label(self) -> String {
        match self {
            JobSpec::Gemm(s) => format!("gemm {}x{}x{}", s.m, s.n, s.k),
            JobSpec::Level3 { op, m, n } => format!("{} {m}x{n}", op.label()),
            JobSpec::Factor { kind, n, nb } => format!("{} n={n} nb={nb}", kind.label()),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn gemm_jobs_sort_like_gemm_shapes() {
        // The bit-for-bit anchor of the workload redesign: GEMM-only
        // job sets must iterate in the historical GemmShape order.
        let mut shapes = vec![
            GemmShape::square(512),
            GemmShape { m: 64, n: 4096, k: 8 },
            GemmShape::square(96),
            GemmShape { m: 512, n: 1, k: 2048 },
        ];
        let mut jobs: Vec<JobSpec> = shapes.iter().map(|&s| JobSpec::Gemm(s)).collect();
        shapes.sort();
        jobs.sort();
        let unwrapped: Vec<GemmShape> = jobs.iter().map(|j| j.gemm().unwrap()).collect();
        assert_eq!(unwrapped, shapes);
        // And Gemm orders strictly before the other variants.
        let f = JobSpec::Factor { kind: FactorKind::Cholesky, n: 1, nb: 1 };
        let l = JobSpec::Level3 { op: Level3Op::SymmLower, m: 1, n: 1 };
        assert!(JobSpec::Gemm(GemmShape::square(usize::MAX / 4)) < l);
        assert!(l < f);
    }

    #[test]
    fn flops_and_equiv_gemm_are_consistent() {
        let g = JobSpec::Gemm(GemmShape::square(128));
        assert_eq!(g.flops(), GemmShape::square(128).flops());
        assert_eq!(g.cost_scale(), 1.0);

        let trsm = JobSpec::Level3 { op: Level3Op::TrsmLower, m: 100, n: 40 };
        // Half the equivalent GEMM's flops, and the scale agrees.
        assert_eq!(trsm.flops(), 0.5 * trsm.equiv_gemm().flops());
        assert_eq!(trsm.cost_scale(), 0.5);
        let symm = JobSpec::Level3 { op: Level3Op::SymmLower, m: 100, n: 40 };
        assert_eq!(symm.flops(), symm.equiv_gemm().flops());
        let syrk = JobSpec::Level3 { op: Level3Op::SyrkLower, m: 60, n: 90 };
        assert_eq!(syrk.equiv_gemm(), GemmShape { m: 60, n: 60, k: 90 });
        assert_eq!(syrk.flops(), 0.5 * syrk.equiv_gemm().flops());

        let chol = JobSpec::Factor { kind: FactorKind::Cholesky, n: 300, nb: 100 };
        assert!((chol.flops() - 300.0f64.powi(3) / 3.0).abs() < 1e-6);
        assert_eq!(chol.equiv_gemm(), GemmShape::square(100));
        let lu = JobSpec::Factor { kind: FactorKind::Lu, n: 300, nb: 100 };
        assert!((lu.flops() - 2.0 * 300.0f64.powi(3) / 3.0).abs() < 1e-6);
    }

    #[test]
    fn labels_are_stable() {
        // The GEMM label is a traced-stream fixture — never change it.
        let g = JobSpec::Gemm(GemmShape { m: 384, n: 512, k: 640 });
        assert_eq!(g.label(), "gemm 384x512x640");
        let c = JobSpec::Factor { kind: FactorKind::Cholesky, n: 768, nb: 128 };
        assert_eq!(c.label(), "chol n=768 nb=128");
        assert_eq!(
            JobSpec::Level3 { op: Level3Op::SyrkLower, m: 64, n: 32 }.label(),
            "syrk 64x32"
        );
        assert_eq!(Level3Op::parse("trsm").unwrap(), Level3Op::TrsmLower);
        assert!(Level3Op::parse("gemv").is_err());
    }

    #[test]
    fn gemm_shapes_convert() {
        let s = GemmShape::square(64);
        let j: JobSpec = s.into();
        assert_eq!(j, JobSpec::Gemm(s));
        assert_eq!(j.gemm(), Some(s));
        assert_eq!(
            JobSpec::Factor { kind: FactorKind::Lu, n: 64, nb: 32 }.gemm(),
            None
        );
    }
}
