//! Numeric executor for [`TaskGraph`]s: runs the tiled kernels in
//! topological (id) order on real row-major matrices.
//!
//! The per-tile kernels reuse the existing packing/control-tree layer:
//! every trailing update is a [`crate::native::gemm_parallel`] call
//! under the caller's [`ScheduleSpec`], and the Cholesky panel solve
//! goes through [`crate::blis::level3::trsm_lower`]. Only the O(nb³)
//! diagonal-tile factorizations and the LU unit/upper tile solves are
//! sequential — the asymptotically dominant work flows through the
//! scheduled GEMM path, which is the whole point of the GEMM-based
//! decomposition (§6 / arXiv:1511.02171).

use crate::blis::gemm::GemmShape;
use crate::blis::level3::trsm_lower;
use crate::dag::graph::{FactorKind, KernelKind, TaskGraph};
use crate::native::gemm_parallel;
use crate::sched::ScheduleSpec;
use crate::soc::SocSpec;

/// Execution record: task ids in the order they ran — the
/// exactly-once / topological-order witness the property tests check.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct ExecLog {
    pub executed: Vec<usize>,
}

/// Blocked Cholesky of the `n × n` matrix `a` (lower triangle result;
/// the strictly-upper part is left unspecified). `n` must be a
/// multiple of `nb`.
pub fn cholesky(soc: &SocSpec, spec: &ScheduleSpec, n: usize, nb: usize, a: &mut [f64]) -> ExecLog {
    factorize(soc, spec, &TaskGraph::cholesky(n, nb), a)
}

/// Blocked LU (no pivoting) of the `n × n` matrix `a`, in place:
/// L (unit lower) and U packed in the usual LAPACK layout.
pub fn lu(soc: &SocSpec, spec: &ScheduleSpec, n: usize, nb: usize, a: &mut [f64]) -> ExecLog {
    factorize(soc, spec, &TaskGraph::lu(n, nb), a)
}

/// Execute `graph` on `a`, task by task in id order (ids are
/// topological by construction, so dependencies are always satisfied).
pub fn factorize(
    soc: &SocSpec,
    spec: &ScheduleSpec,
    graph: &TaskGraph,
    a: &mut [f64],
) -> ExecLog {
    let (n, nb) = (graph.n, graph.nb);
    assert!(a.len() >= n * n, "matrix buffer too small: {} < {}", a.len(), n * n);
    let mut executed = Vec::with_capacity(graph.num_tasks());
    for t in &graph.tasks {
        match (graph.kind, t.kind) {
            (_, KernelKind::Potrf) => {
                let mut d = gather(a, n, nb, t.row, t.col);
                tile_potrf(&mut d, nb);
                scatter(a, n, nb, t.row, t.col, &d);
            }
            (_, KernelKind::Getrf) => {
                let mut d = gather(a, n, nb, t.row, t.col);
                tile_getrf(&mut d, nb);
                scatter(a, n, nb, t.row, t.col, &d);
            }
            (FactorKind::Cholesky, KernelKind::Trsm) => {
                // A_ik := A_ik · L_kk⁻ᵀ, via the left lower solve:
                // L_kk · Xᵀ = A_ikᵀ.
                let l = gather(a, n, nb, t.step, t.step);
                let mut bt = transpose(&gather(a, n, nb, t.row, t.col), nb);
                trsm_lower(soc, spec, nb, nb, &l, &mut bt, nb.div_ceil(2).max(1));
                scatter(a, n, nb, t.row, t.col, &transpose(&bt, nb));
            }
            (FactorKind::Cholesky, KernelKind::Syrk) => {
                // A_ii -= A_ik · A_ikᵀ (full tile update; only the
                // lower half is ever read downstream).
                let p = gather(a, n, nb, t.row, t.step);
                let neg: Vec<f64> = p.iter().map(|&x| -x).collect();
                let pt = transpose(&p, nb);
                let mut c = gather(a, n, nb, t.row, t.col);
                gemm_parallel(soc, spec, GemmShape::square(nb), &neg, &pt, &mut c);
                scatter(a, n, nb, t.row, t.col, &c);
            }
            (FactorKind::Cholesky, KernelKind::GemmUpd) => {
                // A_ij -= A_ik · A_jkᵀ.
                let neg: Vec<f64> =
                    gather(a, n, nb, t.row, t.step).iter().map(|&x| -x).collect();
                let bt = transpose(&gather(a, n, nb, t.col, t.step), nb);
                let mut c = gather(a, n, nb, t.row, t.col);
                gemm_parallel(soc, spec, GemmShape::square(nb), &neg, &bt, &mut c);
                scatter(a, n, nb, t.row, t.col, &c);
            }
            (FactorKind::Lu, KernelKind::Trsm) => {
                let d = gather(a, n, nb, t.step, t.step);
                let mut b = gather(a, n, nb, t.row, t.col);
                if t.row == t.step {
                    // Row panel: A_kj := L_kk⁻¹ · A_kj (unit lower).
                    tile_trsm_unit_lower_left(&d, &mut b, nb);
                } else {
                    // Column panel: A_ik := A_ik · U_kk⁻¹.
                    tile_trsm_upper_right(&d, &mut b, nb);
                }
                scatter(a, n, nb, t.row, t.col, &b);
            }
            (FactorKind::Lu, KernelKind::GemmUpd) => {
                // A_ij -= A_ik · A_kj.
                let neg: Vec<f64> =
                    gather(a, n, nb, t.row, t.step).iter().map(|&x| -x).collect();
                let b = gather(a, n, nb, t.step, t.col);
                let mut c = gather(a, n, nb, t.row, t.col);
                gemm_parallel(soc, spec, GemmShape::square(nb), &neg, &b, &mut c);
                scatter(a, n, nb, t.row, t.col, &c);
            }
            (kind, other) => unreachable!("{other:?} task in a {kind:?} graph"),
        }
        executed.push(t.id);
    }
    ExecLog { executed }
}

/// Copy tile (block `row`, block `col`) out of the `n × n` matrix.
fn gather(a: &[f64], n: usize, nb: usize, row: usize, col: usize) -> Vec<f64> {
    let mut t = vec![0.0; nb * nb];
    for r in 0..nb {
        let src = (row * nb + r) * n + col * nb;
        t[r * nb..(r + 1) * nb].copy_from_slice(&a[src..src + nb]);
    }
    t
}

/// Write tile (block `row`, block `col`) back.
fn scatter(a: &mut [f64], n: usize, nb: usize, row: usize, col: usize, t: &[f64]) {
    for r in 0..nb {
        let dst = (row * nb + r) * n + col * nb;
        a[dst..dst + nb].copy_from_slice(&t[r * nb..(r + 1) * nb]);
    }
}

fn transpose(t: &[f64], nb: usize) -> Vec<f64> {
    let mut out = vec![0.0; nb * nb];
    for r in 0..nb {
        for c in 0..nb {
            out[c * nb + r] = t[r * nb + c];
        }
    }
    out
}

/// Unblocked Cholesky of one tile (lower, in place; the strictly-upper
/// part is left untouched).
fn tile_potrf(t: &mut [f64], nb: usize) {
    for j in 0..nb {
        let mut d = t[j * nb + j];
        for p in 0..j {
            d -= t[j * nb + p] * t[j * nb + p];
        }
        assert!(d > 0.0, "tile lost positive definiteness at column {j}: pivot {d}");
        let d = d.sqrt();
        t[j * nb + j] = d;
        for i in j + 1..nb {
            let mut s = t[i * nb + j];
            for p in 0..j {
                s -= t[i * nb + p] * t[j * nb + p];
            }
            t[i * nb + j] = s / d;
        }
    }
}

/// Unblocked Doolittle LU of one tile (no pivoting), L unit lower and
/// U packed in place.
fn tile_getrf(t: &mut [f64], nb: usize) {
    for k in 0..nb {
        let pivot = t[k * nb + k];
        assert!(pivot.abs() > 1e-300, "zero pivot at {k} (LU runs without pivoting)");
        for i in k + 1..nb {
            let f = t[i * nb + k] / pivot;
            t[i * nb + k] = f;
            for j in k + 1..nb {
                t[i * nb + j] -= f * t[k * nb + j];
            }
        }
    }
}

/// Solve L·X = B in place where L is the *unit* lower triangle of a
/// packed LU tile.
fn tile_trsm_unit_lower_left(l: &[f64], b: &mut [f64], nb: usize) {
    for r in 0..nb {
        for p in 0..r {
            let f = l[r * nb + p];
            if f != 0.0 {
                for j in 0..nb {
                    b[r * nb + j] -= f * b[p * nb + j];
                }
            }
        }
    }
}

/// Solve X·U = B in place where U is the upper triangle of a packed LU
/// tile.
fn tile_trsm_upper_right(u: &[f64], b: &mut [f64], nb: usize) {
    for r in 0..nb {
        for c in 0..nb {
            let mut s = b[r * nb + c];
            for p in 0..c {
                s -= b[r * nb + p] * u[p * nb + c];
            }
            b[r * nb + c] = s / u[c * nb + c];
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::soc::SocSpec;
    use crate::util::rng::Rng;
    use crate::util::stats::{gemm_tolerance, max_abs_diff};

    fn soc() -> SocSpec {
        SocSpec::exynos5422()
    }

    fn spec() -> ScheduleSpec {
        ScheduleSpec::ca_das()
    }

    /// A well-conditioned SPD matrix: A = L·Lᵀ with a boosted diagonal.
    fn spd(rng: &mut Rng, n: usize) -> Vec<f64> {
        let mut l = vec![0.0; n * n];
        for i in 0..n {
            for j in 0..=i {
                l[i * n + j] = rng.gen_f64(-1.0, 1.0);
            }
            l[i * n + i] += 2.0 + n as f64 / 8.0;
        }
        let mut a = vec![0.0; n * n];
        for i in 0..n {
            for j in 0..n {
                let mut s = 0.0;
                for p in 0..=i.min(j) {
                    s += l[i * n + p] * l[j * n + p];
                }
                a[i * n + j] = s;
            }
        }
        a
    }

    fn lower_of(a: &[f64], n: usize) -> Vec<f64> {
        let mut out = vec![0.0; n * n];
        for i in 0..n {
            for j in 0..=i {
                out[i * n + j] = a[i * n + j];
            }
        }
        out
    }

    #[test]
    fn blocked_cholesky_matches_unblocked_reference() {
        let (n, nb) = (192, 64);
        let mut rng = Rng::new(0xC401);
        let a0 = spd(&mut rng, n);

        let mut reference = a0.clone();
        tile_potrf(&mut reference, n); // unblocked on the full matrix

        let mut blocked = a0.clone();
        let log = cholesky(&soc(), &spec(), n, nb, &mut blocked);
        assert_eq!(log.executed, (0..log.executed.len()).collect::<Vec<_>>());

        let d = max_abs_diff(&lower_of(&reference, n), &lower_of(&blocked, n));
        assert!(d < gemm_tolerance(n), "blocked vs unblocked Cholesky diff {d}");

        // And L·Lᵀ reconstructs A.
        let l = lower_of(&blocked, n);
        for i in 0..n {
            for j in 0..=i {
                let mut s = 0.0;
                for p in 0..=j {
                    s += l[i * n + p] * l[j * n + p];
                }
                let d = (s - a0[i * n + j]).abs();
                assert!(d < gemm_tolerance(n) * 10.0, "A[{i}][{j}] off by {d}");
            }
        }
    }

    #[test]
    fn blocked_lu_reconstructs_the_matrix() {
        let (n, nb) = (160, 32);
        let mut rng = Rng::new(0x1007);
        let mut a0 = vec![0.0; n * n];
        for (i, v) in a0.iter_mut().enumerate() {
            *v = rng.gen_f64(-1.0, 1.0);
            if i % (n + 1) == 0 {
                *v += n as f64; // diagonally dominant → pivot-free LU is stable
            }
        }
        let mut f = a0.clone();
        let log = lu(&soc(), &spec(), n, nb, &mut f);
        assert_eq!(log.executed.len(), TaskGraph::lu(n, nb).num_tasks());

        // Rebuild A = L·U from the packed factors.
        for i in 0..n {
            for j in 0..n {
                let mut s = 0.0;
                let lim = i.min(j);
                for p in 0..lim {
                    s += f[i * n + p] * f[p * n + j];
                }
                s += if i <= j { f[i * n + j] } else { f[i * n + j] * f[j * n + j] };
                // (i <= j: L_ii = 1 contributes U_ij; i > j: L_ij·U_jj.)
                let d = (s - a0[i * n + j]).abs();
                assert!(d < gemm_tolerance(n) * 10.0, "A[{i}][{j}] off by {d}");
            }
        }
    }

    #[test]
    fn single_tile_graphs_degenerate_to_the_unblocked_kernels() {
        let n = 48;
        let mut rng = Rng::new(7);
        let a0 = spd(&mut rng, n);
        let mut one = a0.clone();
        cholesky(&soc(), &spec(), n, n, &mut one);
        let mut reference = a0.clone();
        tile_potrf(&mut reference, n);
        assert_eq!(lower_of(&one, n), lower_of(&reference, n), "nb = n is exactly tile_potrf");
    }
}
