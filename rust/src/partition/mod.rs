//! Loop iteration-space partitioning.
//!
//! Three flavours, matching the paper's scheduling vocabulary:
//!
//! * **symmetric static** — BLIS's default: the range divided into
//!   near-equal contiguous chunks, one per way (§3.1/§4);
//! * **weighted static** — the SAS mechanism (§5.2), N-way: chunks
//!   sized proportionally to per-way weights (the paper's `[ratio, 1]`
//!   big/LITTLE split is the two-cluster case; a tri-cluster SoC feeds
//!   a three-entry vector, and so on);
//! * **dynamic queue** — the CA-DAS mechanism (§5.4): ways grab chunks
//!   of *their own* size (the grabber's `mc`) from a shared range under
//!   a critical section — any number of clusters, each with its own
//!   native chunk size.
//!
//! All partitioners round chunk boundaries to a stride (the register
//! blocking `nr`/`mr`, or `mc`/`nc` for coarse loops) so no micro-kernel
//! ever straddles two ways. Invariants (tested): chunks are disjoint,
//! contiguous, cover the range exactly, and interior boundaries are
//! stride-aligned.

use std::sync::Mutex;

/// A contiguous chunk `[start, start+len)` of an iteration space.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct Chunk {
    pub start: usize,
    pub len: usize,
}

impl Chunk {
    pub fn end(&self) -> usize {
        self.start + self.len
    }
    pub fn is_empty(&self) -> bool {
        self.len == 0
    }
}

/// Split `[0, extent)` into `ways` chunks proportional to `weights`,
/// with interior boundaries aligned to `stride`. Zero-weight ways get
/// empty chunks. Rounding error accumulates into the *last non-empty*
/// way so coverage is exact.
pub fn split_weighted(extent: usize, weights: &[f64], stride: usize) -> Vec<Chunk> {
    assert!(stride > 0);
    assert!(!weights.is_empty());
    assert!(weights.iter().all(|&w| w >= 0.0));
    let total_w: f64 = weights.iter().sum();
    assert!(total_w > 0.0, "at least one positive weight");

    let units = extent.div_ceil(stride); // whole strides (last may be short)
    let mut acc = 0.0;
    let mut prev_units = 0usize;
    let mut chunks = Vec::with_capacity(weights.len());
    for (i, &w) in weights.iter().enumerate() {
        acc += w;
        // Cumulative boundary in units, rounded to nearest.
        let mut b = ((acc / total_w) * units as f64).round() as usize;
        if i + 1 == weights.len() {
            b = units; // exact coverage
        }
        let b = b.clamp(prev_units, units);
        let start = (prev_units * stride).min(extent);
        let end = (b * stride).min(extent);
        chunks.push(Chunk {
            start,
            len: end.saturating_sub(start),
        });
        prev_units = b;
    }
    chunks
}

/// BLIS default: equal-share split (all weights 1).
pub fn split_symmetric(extent: usize, ways: usize, stride: usize) -> Vec<Chunk> {
    split_weighted(extent, &vec![1.0; ways], stride)
}

/// The big/LITTLE two-way split with the SAS performance `ratio`
/// (§5.2: "fast threads are assigned `ratio` times more computations").
/// Returns `(big_chunk, little_chunk)`.
pub fn split_ratio(extent: usize, ratio: f64, stride: usize) -> (Chunk, Chunk) {
    assert!(ratio > 0.0);
    let v = split_weighted(extent, &[ratio, 1.0], stride);
    (v[0], v[1])
}

/// Dynamic chunk queue over `[0, extent)` (§5.4). Each grab takes up to
/// `size` iterations from the front; the caller's control tree supplies
/// its own `size` (`mc` of the grabbing cluster in CA-DAS). Thread-safe:
/// the native executor's "critical section" is exactly this mutex; the
/// simulator models its cost in virtual time separately.
#[derive(Debug)]
pub struct DynamicQueue {
    inner: Mutex<usize>,
    extent: usize,
}

impl DynamicQueue {
    pub fn new(extent: usize) -> Self {
        DynamicQueue {
            inner: Mutex::new(0),
            extent,
        }
    }

    /// Grab the next chunk of at most `size`; `None` when exhausted.
    pub fn grab(&self, size: usize) -> Option<Chunk> {
        assert!(size > 0);
        let mut next = self.inner.lock().unwrap();
        if *next >= self.extent {
            return None;
        }
        let start = *next;
        let len = size.min(self.extent - start);
        *next += len;
        Some(Chunk { start, len })
    }

    /// Remaining iterations (racy snapshot; exact under the sim's
    /// single-threaded virtual time).
    pub fn remaining(&self) -> usize {
        self.extent - *self.inner.lock().unwrap()
    }

    pub fn extent(&self) -> usize {
        self.extent
    }
}

/// Check the partition invariants; used by tests and debug assertions.
pub fn validate_partition(extent: usize, stride: usize, chunks: &[Chunk]) -> Result<(), String> {
    let mut pos = 0usize;
    for (i, c) in chunks.iter().enumerate() {
        if c.start != pos {
            return Err(format!("chunk {i} starts at {} expected {pos}", c.start));
        }
        if !c.is_empty() && c.end() != extent && c.end() % stride != 0 {
            return Err(format!(
                "chunk {i} interior boundary {} not stride-aligned",
                c.end()
            ));
        }
        pos = c.end();
    }
    if pos != extent {
        return Err(format!("coverage ends at {pos}, expected {extent}"));
    }
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prop;

    #[test]
    fn symmetric_split_even() {
        let cs = split_symmetric(16, 4, 4);
        assert_eq!(
            cs,
            vec![
                Chunk { start: 0, len: 4 },
                Chunk { start: 4, len: 4 },
                Chunk { start: 8, len: 4 },
                Chunk { start: 12, len: 4 }
            ]
        );
    }

    #[test]
    fn symmetric_split_with_remainder() {
        let cs = split_symmetric(18, 4, 4);
        validate_partition(18, 4, &cs).unwrap();
        assert_eq!(cs.iter().map(|c| c.len).sum::<usize>(), 18);
    }

    #[test]
    fn ratio_split_matches_paper_example() {
        // Fig. 8: ratio 3 → fast cluster gets 3× the slow cluster's share.
        let (big, little) = split_ratio(1600, 3.0, 4);
        assert_eq!(big.len, 1200);
        assert_eq!(little.len, 400);
        validate_partition(1600, 4, &[big, little]).unwrap();
    }

    #[test]
    fn ratio_one_is_symmetric() {
        let (b, l) = split_ratio(1024, 1.0, 4);
        assert_eq!(b.len, 512);
        assert_eq!(l.len, 512);
    }

    #[test]
    fn extreme_ratio_starves_little() {
        let (b, l) = split_ratio(64, 100.0, 4);
        assert_eq!(b.len, 64);
        assert!(l.is_empty());
    }

    #[test]
    fn tiny_extent_smaller_than_stride() {
        let cs = split_symmetric(3, 2, 4);
        validate_partition(3, 4, &cs).unwrap();
        assert_eq!(cs[0].len + cs[1].len, 3);
    }

    #[test]
    fn zero_extent_all_empty() {
        let cs = split_weighted(0, &[5.0, 1.0], 8);
        assert!(cs.iter().all(|c| c.is_empty()));
        validate_partition(0, 8, &cs).unwrap();
    }

    #[test]
    #[should_panic(expected = "positive weight")]
    fn all_zero_weights_rejected() {
        split_weighted(10, &[0.0, 0.0], 1);
    }

    #[test]
    fn dynamic_queue_drains_exactly() {
        let q = DynamicQueue::new(100);
        let mut total = 0;
        let mut chunks = Vec::new();
        let mut big_turn = true;
        while let Some(c) = q.grab(if big_turn { 32 } else { 8 }) {
            total += c.len;
            chunks.push(c);
            big_turn = !big_turn;
        }
        assert_eq!(total, 100);
        validate_partition(100, 1, &chunks).unwrap();
        assert_eq!(q.remaining(), 0);
        assert!(q.grab(32).is_none());
    }

    #[test]
    fn dynamic_queue_last_chunk_short() {
        let q = DynamicQueue::new(10);
        assert_eq!(q.grab(8), Some(Chunk { start: 0, len: 8 }));
        assert_eq!(q.grab(8), Some(Chunk { start: 8, len: 2 }));
        assert_eq!(q.grab(8), None);
    }

    #[test]
    fn dynamic_queue_concurrent_drain_is_exact() {
        // The §5.4 critical section under real contention.
        let q = std::sync::Arc::new(DynamicQueue::new(10_000));
        let mut handles = Vec::new();
        for t in 0..8 {
            let q = q.clone();
            handles.push(std::thread::spawn(move || {
                let size = if t < 4 { 152 } else { 32 };
                let mut got = 0usize;
                while let Some(c) = q.grab(size) {
                    got += c.len;
                }
                got
            }));
        }
        let total: usize = handles.into_iter().map(|h| h.join().unwrap()).sum();
        assert_eq!(total, 10_000);
    }

    #[test]
    fn prop_weighted_partition_invariants() {
        prop::check_default(
            |r| {
                let extent = r.gen_range(0, 5000);
                let stride = *r.choose(&[1usize, 4, 8, 152, 4096]);
                let ways = r.gen_range(1, 9);
                let weights: Vec<f64> = (0..ways).map(|_| r.gen_f64(0.1, 8.0)).collect();
                (extent, stride, weights)
            },
            |(extent, stride, weights)| {
                let cs = split_weighted(*extent, weights, *stride);
                validate_partition(*extent, *stride, &cs)
            },
        );
    }

    /// N-cluster weighted-static invariants: for 1–6 clusters with
    /// heavily skewed weight vectors (up to 3 orders of magnitude, plus
    /// zero-weight clusters), the chunks stay disjoint, contiguous,
    /// exactly covering, and stride-aligned at interior boundaries.
    #[test]
    fn prop_n_cluster_weighted_invariants() {
        prop::check_default(
            |r| {
                let extent = r.gen_range(0, 30_000);
                let stride = *r.choose(&[1usize, 4, 32, 80, 152]);
                let clusters = r.gen_range(1, 7); // 1..=6 clusters
                let weights: Vec<f64> = (0..clusters)
                    .map(|_| {
                        // Skewed: zero, tiny, or huge weights mixed.
                        match r.gen_range(0, 4) {
                            0 if clusters > 1 => 0.0,
                            1 => r.gen_f64(0.01, 0.1),
                            2 => r.gen_f64(0.5, 2.0),
                            _ => r.gen_f64(10.0, 100.0),
                        }
                    })
                    .collect();
                (extent, stride, weights)
            },
            |(extent, stride, weights)| {
                if weights.iter().sum::<f64>() <= 0.0 {
                    return Ok(()); // all-zero vectors are rejected by assert
                }
                let cs = split_weighted(*extent, weights, *stride);
                if cs.len() != weights.len() {
                    return Err(format!("{} chunks for {} ways", cs.len(), weights.len()));
                }
                validate_partition(*extent, *stride, &cs)?;
                // A zero-weight cluster must never get more than the
                // rounding quantum of work.
                for (i, (&w, c)) in weights.iter().zip(&cs).enumerate() {
                    if w == 0.0 && c.len > *stride {
                        return Err(format!(
                            "zero-weight way {i} got {} iterations (stride {stride})",
                            c.len
                        ));
                    }
                }
                Ok(())
            },
        );
    }

    /// N-cluster dynamic queue: clusters with different native chunk
    /// sizes drain a shared range; the grabbed chunks must be disjoint,
    /// contiguous, exactly covering, and every non-final chunk must be
    /// exactly the grabbing cluster's own size (the CA-DAS contract).
    #[test]
    fn prop_n_cluster_dynamic_queue_invariants() {
        prop::check_default(
            |r| {
                let extent = r.gen_range(1, 8_000);
                let clusters = r.gen_range(1, 7); // 1..=6 clusters
                let sizes: Vec<usize> = (0..clusters)
                    .map(|_| *r.choose(&[32usize, 68, 80, 152, 300]))
                    .collect();
                (extent, sizes, r.next_u64())
            },
            |(extent, sizes, seed)| {
                let q = DynamicQueue::new(*extent);
                let mut order = crate::util::rng::Rng::new(*seed);
                let mut chunks = Vec::new();
                loop {
                    // A random cluster reaches the critical section next.
                    let who = order.gen_range(0, sizes.len());
                    match q.grab(sizes[who]) {
                        Some(c) => {
                            if c.len != sizes[who] && c.end() != *extent {
                                return Err(format!(
                                    "non-final chunk {c:?} not the grabber's size {}",
                                    sizes[who]
                                ));
                            }
                            chunks.push(c);
                        }
                        None => break,
                    }
                }
                validate_partition(*extent, 1, &chunks)?;
                if q.remaining() != 0 {
                    return Err(format!("{} iterations left undrained", q.remaining()));
                }
                Ok(())
            },
        );
    }

    #[test]
    fn prop_weighted_shares_track_weights() {
        prop::check_default(
            |r| {
                let extent = r.gen_range(1000, 20_000);
                let ratio = r.gen_f64(1.0, 8.0);
                (extent, ratio)
            },
            |&(extent, ratio)| {
                let (b, l) = split_ratio(extent, ratio, 4);
                if l.len < 40 {
                    return Ok(()); // rounding dominates tiny shares
                }
                let got = b.len as f64 / l.len as f64;
                let slack = 0.15 + 80.0 * ratio / extent as f64;
                if (got / ratio - 1.0).abs() > slack {
                    return Err(format!("ratio {ratio} got {got} (slack {slack})"));
                }
                Ok(())
            },
        );
    }
}
