//! Real multithreaded executor: the paper's schedules with actual
//! threads, actual packed buffers and actual micro-kernels.
//!
//! This is the *numerics* half of the hardware substitution (DESIGN.md
//! §1): the DES in `crate::sim` produces the paper's timing shapes; this
//! executor proves every scheduling strategy computes the right matrix.
//! The thread structure mirrors the simulator phase-for-phase and is
//! cluster-count-agnostic — one worker team per cluster of the topology:
//!
//! * one worker thread per simulated core, grouped into per-cluster
//!   teams;
//! * per-cluster shared packed buffers (`Bc`, `Ac`), with packing split
//!   by micro-panel ranges among the cluster's threads (disjoint
//!   writes), separated from compute by a cluster barrier;
//! * coarse Loop-1 (static): clusters own disjoint column ranges of C
//!   and never synchronize until the join;
//! * coarse Loop-3 (static): clusters own disjoint row ranges; a global
//!   barrier per (jc, pc) keeps every cluster on the same shared-`kc`
//!   `Bc` block (each cluster packs its own copy of the identical
//!   block — same constraint, race-free);
//! * dynamic (DAS/CA-DAS): each cluster's lead grabs row chunks of the
//!   cluster's *own* `mc` from the shared [`DynamicQueue`] inside the
//!   §5.4 critical section and broadcasts to its teammates.
//!
//! Safety: all `C` writes are disjoint by construction (distinct jr/ir
//! panel ranges within a macro-kernel; distinct row/column blocks across
//! clusters; dynamic chunks are disjoint by the queue). Packed-buffer
//! writes are disjoint panel ranges, and packing and compute phases are
//! separated by barriers.

use crate::blis::control_tree::ControlTree;
use crate::blis::gemm::{macro_kernel, GemmShape};
use crate::blis::packing::{pack_a_panels, pack_b_panels};
use crate::partition::{split_symmetric, split_weighted, Chunk, DynamicQueue};
use crate::sched::{CoarseLoop, ScheduleSpec, Strategy};
use crate::soc::SocSpec;
use std::cell::UnsafeCell;
use std::sync::{Barrier, Mutex};
use std::time::Instant;

/// Result of a native run.
#[derive(Debug, Clone)]
pub struct NativeStats {
    pub label: String,
    pub shape: GemmShape,
    pub wall_s: f64,
    pub gflops: f64,
    pub threads: usize,
    pub grabs: u64,
}

/// Shared mutable buffer with externally-enforced disjoint access.
struct SharedBuf(UnsafeCell<Vec<f64>>);
// SAFETY: phases guarantee disjoint writes / read-only sharing, enforced
// by the barriers in the worker protocol below.
unsafe impl Sync for SharedBuf {}

impl SharedBuf {
    fn new(len: usize) -> Self {
        SharedBuf(UnsafeCell::new(vec![0.0; len]))
    }
    /// SAFETY: caller must respect the phase protocol.
    #[allow(clippy::mut_from_ref)]
    unsafe fn slice_mut(&self) -> &mut [f64] {
        unsafe { (*self.0.get()).as_mut_slice() }
    }
    unsafe fn slice(&self) -> &[f64] {
        unsafe { (*self.0.get()).as_slice() }
    }
}

/// Raw pointer to C, sendable across the scoped threads.
#[derive(Clone, Copy)]
struct CPtr(*mut f64, usize /* len */);
unsafe impl Send for CPtr {}
unsafe impl Sync for CPtr {}

/// Per-cluster shared state.
struct ClusterShared {
    bc: SharedBuf,
    ac: SharedBuf,
    barrier: Barrier,
    /// Dynamic-chunk broadcast slot (lead writes, teammates read).
    slot: Mutex<Option<Chunk>>,
    grabs: Mutex<u64>,
}

impl ClusterShared {
    fn new(tree: &ControlTree, threads: usize, m: usize, n: usize, k: usize) -> Self {
        let p = &tree.params;
        let kc = p.kc.min(k.max(1));
        let nc = p.nc.min(n.max(1));
        let mc = p.mc.min(m.max(1));
        ClusterShared {
            bc: SharedBuf::new(kc * nc.div_ceil(p.nr) * p.nr),
            ac: SharedBuf::new(mc.div_ceil(p.mr) * p.mr * kc),
            barrier: Barrier::new(threads),
            slot: Mutex::new(None),
            grabs: Mutex::new(0),
        }
    }
}

/// Inputs shared by every worker.
struct Job<'a> {
    a: &'a [f64],
    b: &'a [f64],
    c: CPtr,
    shape: GemmShape,
}

/// What a cluster's coarse-grain assignment is.
#[derive(Clone, Copy)]
enum CoarseWork<'q> {
    /// Own column range of C (coarse Loop 1): sweep full m.
    Columns(Chunk),
    /// Own row range of C (coarse Loop 3, static): sweep full n jointly.
    Rows(Chunk),
    /// Dynamic row chunks from the shared queue (one queue per (jc, pc)).
    Dynamic(&'q [DynamicQueue]),
}

/// Run `spec` on real threads. Returns wall-clock stats; the result is
/// accumulated into `c` (`C += A·B`).
pub fn gemm_parallel(
    soc: &SocSpec,
    spec: &ScheduleSpec,
    shape: GemmShape,
    a: &[f64],
    b: &[f64],
    c: &mut [f64],
) -> NativeStats {
    spec.validate_for(soc).expect("invalid spec");
    let GemmShape { m, n, k } = shape;
    assert!(a.len() >= m * k && b.len() >= k * n && c.len() >= m * n);
    let th = spec.threads(soc);
    let trees = spec.tree_set(soc);
    let total: usize = th.iter().sum();
    assert!(total > 0);
    let active_clusters = th.iter().filter(|&&t| t > 0).count();

    let c_ptr = CPtr(c.as_mut_ptr(), c.len());
    let job = Job { a, b, c: c_ptr, shape };

    // Packed-buffer state only for clusters that actually run threads —
    // idle clusters of a wide topology must not cost Bc/Ac allocations.
    let shareds: Vec<Option<ClusterShared>> = soc
        .cluster_ids()
        .map(|ci| {
            (th[ci.0] > 0).then(|| ClusterShared::new(trees.for_cluster(ci), th[ci.0], m, n, k))
        })
        .collect();
    // Global barrier across every spawned thread for shared-Bc
    // coordination.
    let global = Barrier::new(total);
    let lead_tree = trees.for_cluster(soc.lead());

    // Dynamic strategies: one queue per (jc, pc) iteration, shared by
    // every cluster. Built up-front so the per-cluster assignments can
    // borrow it.
    let queues: Vec<DynamicQueue> = if spec.strategy.is_dynamic() {
        let nc = lead_tree.params.nc;
        let kc = lead_tree.params.kc;
        let iters = n.div_ceil(nc).max(1) * k.div_ceil(kc).max(1);
        (0..iters).map(|_| DynamicQueue::new(m)).collect()
    } else {
        Vec::new()
    };

    // Per-cluster coarse assignments, indexed by ClusterId.
    let works: Vec<CoarseWork> = match (&spec.strategy, spec.coarse) {
        (Strategy::ClusterOnly { .. }, _) => {
            let full_n = Chunk { start: 0, len: n };
            vec![CoarseWork::Columns(full_n); soc.num_clusters()]
        }
        (Strategy::Das | Strategy::CaDas, _) => {
            vec![CoarseWork::Dynamic(&queues); soc.num_clusters()]
        }
        (_, CoarseLoop::Loop1) => {
            let w = spec.coarse_weights(soc).expect("static");
            let parts = split_weighted(n, &w, lead_tree.params.nr);
            parts.into_iter().map(CoarseWork::Columns).collect()
        }
        (_, CoarseLoop::Loop3) => {
            let w = spec.coarse_weights(soc).expect("static");
            let parts = split_weighted(m, &w, lead_tree.params.mr);
            parts.into_iter().map(CoarseWork::Rows).collect()
        }
    };

    let needs_global = active_clusters > 1
        && works
            .iter()
            .any(|w| matches!(w, CoarseWork::Rows(_) | CoarseWork::Dynamic(_)));

    let t0 = Instant::now();
    std::thread::scope(|s| {
        let mut handles = Vec::new();
        for ci in soc.cluster_ids() {
            let team = th[ci.0];
            if team == 0 {
                continue;
            }
            let tree = trees.for_cluster(ci);
            let shared = shareds[ci.0].as_ref().expect("active cluster has shared state");
            let work = works[ci.0];
            let (global, job) = (&global, &job);
            for local in 0..team {
                handles.push(s.spawn(move || {
                    cluster_worker(local, team, tree, job, shared, global, needs_global, work)
                }));
            }
        }
        for h in handles {
            h.join().expect("worker panicked");
        }
    });
    let wall = t0.elapsed().as_secs_f64();
    let grabs: u64 = shareds
        .iter()
        .flatten()
        .map(|sh| *sh.grabs.lock().unwrap())
        .sum();
    NativeStats {
        label: spec.label_on(soc),
        shape: job.shape,
        wall_s: wall,
        gflops: job.shape.flops() / wall / 1e9,
        threads: total,
        grabs,
    }
}

/// The per-thread body. All threads of a cluster execute the same outer
/// loops in lockstep; phases are separated by the cluster barrier.
#[allow(clippy::too_many_arguments)]
fn cluster_worker(
    local: usize,
    team: usize,
    tree: &ControlTree,
    job: &Job,
    shared: &ClusterShared,
    global: &Barrier,
    needs_global: bool,
    work: CoarseWork,
) {
    let p = tree.params;
    let GemmShape { m, n, k } = job.shape;

    // Column range this cluster owns (Loop-1 coarse) or full n.
    let (n_range, m_static): (Chunk, Option<Chunk>) = match &work {
        CoarseWork::Columns(cols) => (*cols, Some(Chunk { start: 0, len: m })),
        CoarseWork::Rows(rows) => (Chunk { start: 0, len: n }, Some(*rows)),
        CoarseWork::Dynamic(_) => (Chunk { start: 0, len: n }, None),
    };
    if n_range.is_empty() {
        return;
    }

    let mut q_idx = 0usize;
    let mut jc = 0;
    while jc < n_range.len {
        let nc_eff = (n_range.len - jc).min(p.nc);
        let mut pc = 0;
        while pc < k {
            let kc_eff = (k - pc).min(p.kc);

            // --- pack Bc: split micro-panels among the team ---
            let q_panels = nc_eff.div_ceil(p.nr);
            let shares = split_symmetric(q_panels, team, 1);
            // SAFETY: disjoint panel ranges per thread; barrier below
            // separates packing from reads.
            unsafe {
                let bc = shared.bc.slice_mut();
                let sh = shares[local];
                pack_b_panels(
                    job.b, n, pc, n_range.start + jc, kc_eff, nc_eff, p.nr, bc, sh.start,
                    sh.end(),
                );
            }
            shared.barrier.wait();

            // --- the m space for this (jc, pc) ---
            match &work {
                CoarseWork::Columns(_) | CoarseWork::Rows(_) => {
                    let rows = m_static.unwrap();
                    let mut ic = 0;
                    while ic < rows.len {
                        let mc_eff = (rows.len - ic).min(p.mc);
                        process_chunk(
                            tree, job, shared, local, team,
                            Chunk { start: rows.start + ic, len: mc_eff },
                            n_range.start + jc, nc_eff, pc, kc_eff,
                        );
                        ic += p.mc;
                    }
                }
                CoarseWork::Dynamic(queues) => {
                    let q = &queues[q_idx];
                    loop {
                        // Lead grabs inside the critical section (§5.4)
                        // and broadcasts through the slot. The grab size
                        // is this cluster's own mc — the CA-DAS move.
                        if local == 0 {
                            let g = q.grab(p.mc);
                            if g.is_some() {
                                *shared.grabs.lock().unwrap() += 1;
                            }
                            *shared.slot.lock().unwrap() = g;
                        }
                        shared.barrier.wait();
                        let chunk = *shared.slot.lock().unwrap();
                        shared.barrier.wait();
                        let Some(chunk) = chunk else { break };
                        process_chunk(
                            tree, job, shared, local, team, chunk, n_range.start + jc,
                            nc_eff, pc, kc_eff,
                        );
                    }
                }
            }

            // Shared-Bc coordination point (coarse Loop 3 / dynamic).
            if needs_global {
                global.wait();
            }
            pc += p.kc;
            q_idx += 1;
        }
        jc += p.nc;
    }
}

/// Pack `Ac` for one row chunk and run the fine-partitioned macro-kernel.
#[allow(clippy::too_many_arguments)]
fn process_chunk(
    tree: &ControlTree,
    job: &Job,
    shared: &ClusterShared,
    local: usize,
    team: usize,
    rows: Chunk,
    col0: usize,
    nc_eff: usize,
    pc: usize,
    kc_eff: usize,
) {
    let p = tree.params;
    let GemmShape { n, k, .. } = job.shape;
    let mc_eff = rows.len;

    // --- pack Ac (disjoint panel ranges) ---
    let panels = mc_eff.div_ceil(p.mr);
    let shares = split_symmetric(panels, team, 1);
    unsafe {
        let ac = shared.ac.slice_mut();
        let sh = shares[local];
        pack_a_panels(
            job.a, k, rows.start, pc, mc_eff, kc_eff, p.mr, ac, sh.start, sh.end(),
        );
    }
    shared.barrier.wait();

    // --- fine-grain macro-kernel split ---
    let n_jr = nc_eff.div_ceil(p.nr);
    let n_ir = panels;
    let w4 = tree.par.loop4_ways.min(team).max(1);
    let w5 = (team / w4).max(1);
    let (i4, i5) = (local % w4, local / w4);
    // A thread beyond the w4×w5 grid computes nothing (it still takes
    // the barriers below) — a duplicate assignment here would race on C.
    if i5 < w5 {
        let jr_parts = split_symmetric(n_jr, w4, 1);
        let ir_parts = split_symmetric(n_ir, w5, 1);
        let (jr, ir) = (jr_parts[i4], ir_parts[i5]);

        // SAFETY: C windows are disjoint across threads (distinct jr/ir
        // panel ranges; distinct row/col blocks across clusters).
        unsafe {
            let c_all = std::slice::from_raw_parts_mut(job.c.0, job.c.1);
            let ac = shared.ac.slice();
            let bc = shared.bc.slice();
            macro_kernel(
                &p, ac, bc, kc_eff, mc_eff, nc_eff, c_all, n, rows.start, col0,
                jr.start..jr.end(), ir.start..ir.end(),
            );
        }
    }
    shared.barrier.wait();
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::blis::gemm::gemm_naive;
    use crate::sched::Weights;
    use crate::soc::{BIG, LITTLE};
    use crate::util::rng::Rng;
    use crate::util::stats::{gemm_tolerance, max_abs_diff};

    fn soc() -> SocSpec {
        SocSpec::exynos5422()
    }

    fn check_on(soc: &SocSpec, spec: ScheduleSpec, m: usize, n: usize, k: usize, seed: u64) {
        let mut rng = Rng::new(seed);
        let a = rng.fill_matrix(m * k);
        let b = rng.fill_matrix(k * n);
        let c0 = rng.fill_matrix(m * n);
        let mut c_ref = c0.clone();
        gemm_naive(GemmShape { m, n, k }, &a, &b, &mut c_ref);
        let mut c_par = c0.clone();
        let stats = gemm_parallel(soc, &spec, GemmShape { m, n, k }, &a, &b, &mut c_par);
        let d = max_abs_diff(&c_ref, &c_par);
        assert!(
            d < gemm_tolerance(k),
            "{} on {} m={m} n={n} k={k}: diff {d}",
            stats.label,
            soc.name
        );
    }

    fn check(spec: ScheduleSpec, m: usize, n: usize, k: usize, seed: u64) {
        check_on(&soc(), spec, m, n, k, seed);
    }

    #[test]
    fn sss_correct() {
        check(ScheduleSpec::sss(), 96, 120, 64, 1);
        check(ScheduleSpec::sss(), 37, 53, 29, 2);
    }

    #[test]
    fn sas_correct_various_ratios() {
        for (i, r) in [1.0, 3.0, 5.0, 7.0].iter().enumerate() {
            check(ScheduleSpec::sas(*r), 88, 88, 40, 10 + i as u64);
        }
    }

    #[test]
    fn ca_sas_correct_loop1_and_loop3() {
        check(ScheduleSpec::ca_sas(5.0), 100, 100, 60, 20);
        check(
            ScheduleSpec::new(
                Strategy::CaSas { weights: Weights::ratio(3.0) },
                CoarseLoop::Loop3,
                crate::sched::FineLoop::Loop4,
            ),
            100, 64, 60, 21,
        );
    }

    #[test]
    fn dynamic_correct() {
        check(ScheduleSpec::das(), 120, 72, 48, 30);
        check(ScheduleSpec::ca_das(), 120, 72, 48, 31);
        check(ScheduleSpec::ca_das(), 333, 41, 77, 32);
    }

    #[test]
    fn fine_loop_variants_correct() {
        use crate::sched::FineLoop;
        for (i, fine) in [FineLoop::Loop4, FineLoop::Loop5, FineLoop::Both]
            .into_iter()
            .enumerate()
        {
            check(
                ScheduleSpec::new(Strategy::CaDas, CoarseLoop::Loop3, fine),
                90, 90, 50, 40 + i as u64,
            );
            check(
                ScheduleSpec::new(
                    Strategy::Sas { weights: Weights::ratio(5.0) },
                    CoarseLoop::Loop1,
                    fine,
                ),
                90, 90, 50, 50 + i as u64,
            );
        }
    }

    #[test]
    fn cluster_only_correct() {
        for t in 1..=4 {
            check(ScheduleSpec::cluster_only(BIG, t), 64, 64, 64, 60 + t as u64);
            check(
                ScheduleSpec::cluster_only(LITTLE, t),
                48, 80, 32, 70 + t as u64,
            );
        }
    }

    #[test]
    fn degenerate_shapes() {
        check(ScheduleSpec::ca_das(), 1, 1, 1, 80);
        check(ScheduleSpec::sas(5.0), 1, 200, 3, 81);
        check(ScheduleSpec::sss(), 200, 1, 3, 82);
        check(ScheduleSpec::ca_das(), 5, 5, 400, 83);
    }

    #[test]
    fn dynamic_grabs_happen() {
        let mut rng = Rng::new(90);
        let (m, n, k) = (640, 64, 32);
        let a = rng.fill_matrix(m * k);
        let b = rng.fill_matrix(k * n);
        let mut c = vec![0.0; m * n];
        let stats = gemm_parallel(
            &soc(), &ScheduleSpec::ca_das(), GemmShape { m, n, k }, &a, &b, &mut c,
        );
        // 640 rows / (mc 152 or 32) → several grabs.
        assert!(stats.grabs >= 4, "grabs {}", stats.grabs);
    }

    /// The generalized executor on non-Exynos topologies: a tri-cluster
    /// DynamIQ-style SoC (9 threads, three distinct control trees) and
    /// the symmetric single-cluster degenerate case.
    #[test]
    fn other_topologies_correct() {
        let tri = SocSpec::dynamiq_3c();
        check_on(&tri, ScheduleSpec::sss(), 96, 88, 44, 100);
        check_on(
            &tri,
            ScheduleSpec::sas_weighted(Weights::from_slice(&[6.0, 3.0, 1.0])),
            120, 80, 36, 101,
        );
        check_on(
            &tri,
            ScheduleSpec::ca_sas_weighted(Weights::from_slice(&[5.0, 2.0, 1.0])),
            77, 91, 53, 102,
        );
        check_on(&tri, ScheduleSpec::ca_das(), 200, 60, 40, 103);
        check_on(&tri, ScheduleSpec::cluster_only(crate::soc::ClusterId(1), 3), 64, 64, 32, 104);

        let smp = SocSpec::symmetric(4);
        check_on(&smp, ScheduleSpec::sss(), 90, 90, 45, 110);
        check_on(&smp, ScheduleSpec::ca_das(), 150, 70, 38, 111);
    }

    /// Property: random shapes × every strategy family agree with naive.
    #[test]
    fn prop_all_strategies_correct() {
        crate::util::prop::check(
            &crate::util::prop::Config { cases: 24, seed: 0xAB5 },
            |r| {
                let m = r.gen_range(1, 150);
                let n = r.gen_range(1, 150);
                let k = r.gen_range(1, 100);
                let strat = r.gen_range(0, 6);
                (m, n, k, strat, r.next_u64())
            },
            |&(m, n, k, strat, seed)| {
                let spec = match strat {
                    0 => ScheduleSpec::sss(),
                    1 => ScheduleSpec::sas(5.0),
                    2 => ScheduleSpec::ca_sas(3.0),
                    3 => ScheduleSpec::das(),
                    4 => ScheduleSpec::ca_das(),
                    _ => ScheduleSpec::cluster_only(BIG, 4),
                };
                let mut rng = Rng::new(seed);
                let a = rng.fill_matrix(m * k);
                let b = rng.fill_matrix(k * n);
                let mut c_ref = vec![0.0; m * n];
                let mut c_par = vec![0.0; m * n];
                gemm_naive(GemmShape { m, n, k }, &a, &b, &mut c_ref);
                gemm_parallel(&soc(), &spec, GemmShape { m, n, k }, &a, &b, &mut c_par);
                let d = max_abs_diff(&c_ref, &c_par);
                if d > gemm_tolerance(k) {
                    return Err(format!("{}: diff {d}", spec.label()));
                }
                Ok(())
            },
        );
    }
}
