//! Virtual-time span/event tracing with Chrome-trace-event (Perfetto)
//! export.
//!
//! Producers (the DES layers) talk to a [`TraceSink`]; the two
//! built-in sinks bracket the design space:
//!
//! - [`NullSink`] — `enabled()` is `false` and `record` drops. Every
//!   traced entry point's default delegate passes this, and producers
//!   guard all event construction behind `sink.enabled()`, so the
//!   no-trace fast path allocates nothing and its arithmetic is
//!   untouched (the zero-overhead-when-off contract, pinned by
//!   `rust/tests/obs_props.rs` and the `obs_trace_overhead_ratio`
//!   perf-trajectory row).
//! - [`MemorySink`] — buffers events in order;
//!   [`to_chrome_json`] renders them as a `.trace.json` openable in
//!   `ui.perfetto.dev` or `chrome://tracing`.
//!
//! Track layout (fixed, asserted by `rust/tests/trace_golden.rs`):
//! one Chrome *process* per fleet [`crate::fleet::Board`] (pid =
//! board index) plus a final "dispatcher" process (pid = board
//! count); within a board, tid 0 is the request/execute track and
//! tid 1+c is the phase track of [`crate::soc::ClusterId`] `c`.
//! Request lifecycles are flow events (`s`/`t`/`f`) keyed by the
//! submission index; OPP transitions and cache hits/misses are
//! instants; queue depth is a counter series.
//!
//! All timestamps are virtual seconds converted to the trace format's
//! microseconds (`ts = t_s · 1e6`).

use crate::obs::json::escape;

/// An argument value on a trace event (`args` map entry).
#[derive(Debug, Clone, PartialEq)]
pub enum ArgValue {
    Num(f64),
    Str(String),
}

/// One Chrome trace event. `ph` is the phase tag: `X` complete span,
/// `i` instant, `s`/`t`/`f` flow start/step/end, `C` counter, `M`
/// metadata.
#[derive(Debug, Clone, PartialEq)]
pub struct TraceEvent {
    pub name: String,
    pub cat: String,
    pub ph: char,
    /// Timestamp in trace microseconds (virtual seconds × 1e6).
    pub ts_us: f64,
    /// Span duration in microseconds (`X` events only).
    pub dur_us: Option<f64>,
    pub pid: usize,
    pub tid: usize,
    /// Flow-binding id (`s`/`t`/`f` events only).
    pub id: Option<u64>,
    pub args: Vec<(String, ArgValue)>,
}

const US: f64 = 1e6;

impl TraceEvent {
    fn base(name: &str, cat: &str, ph: char, pid: usize, tid: usize, t_s: f64) -> TraceEvent {
        TraceEvent {
            name: name.to_string(),
            cat: cat.to_string(),
            ph,
            ts_us: t_s * US,
            dur_us: None,
            pid,
            tid,
            id: None,
            args: Vec::new(),
        }
    }

    /// Complete span (`ph = X`) covering `[t0_s, t0_s + dur_s]`.
    pub fn span(name: &str, cat: &str, pid: usize, tid: usize, t0_s: f64, dur_s: f64) -> TraceEvent {
        TraceEvent { dur_us: Some(dur_s * US), ..TraceEvent::base(name, cat, 'X', pid, tid, t0_s) }
    }

    /// Thread-scoped instant (`ph = i`).
    pub fn instant(name: &str, cat: &str, pid: usize, tid: usize, t_s: f64) -> TraceEvent {
        TraceEvent::base(name, cat, 'i', pid, tid, t_s)
    }

    /// Flow start (`ph = s`): the first arrow anchor of flow `id`.
    pub fn flow_start(name: &str, cat: &str, pid: usize, tid: usize, t_s: f64, id: u64) -> TraceEvent {
        TraceEvent { id: Some(id), ..TraceEvent::base(name, cat, 's', pid, tid, t_s) }
    }

    /// Flow step (`ph = t`): an intermediate anchor of flow `id`.
    pub fn flow_step(name: &str, cat: &str, pid: usize, tid: usize, t_s: f64, id: u64) -> TraceEvent {
        TraceEvent { id: Some(id), ..TraceEvent::base(name, cat, 't', pid, tid, t_s) }
    }

    /// Flow end (`ph = f`, enclosing-slice binding).
    pub fn flow_end(name: &str, cat: &str, pid: usize, tid: usize, t_s: f64, id: u64) -> TraceEvent {
        TraceEvent { id: Some(id), ..TraceEvent::base(name, cat, 'f', pid, tid, t_s) }
    }

    /// Counter sample (`ph = C`) of series `name`.
    pub fn counter(name: &str, pid: usize, tid: usize, t_s: f64, value: f64) -> TraceEvent {
        TraceEvent {
            args: vec![("value".to_string(), ArgValue::Num(value))],
            ..TraceEvent::base(name, "counter", 'C', pid, tid, t_s)
        }
    }

    /// `process_name` metadata for `pid`.
    pub fn process_name(pid: usize, name: &str) -> TraceEvent {
        TraceEvent {
            args: vec![("name".to_string(), ArgValue::Str(name.to_string()))],
            ..TraceEvent::base("process_name", "__metadata", 'M', pid, 0, 0.0)
        }
    }

    /// `thread_name` metadata for `(pid, tid)`.
    pub fn thread_name(pid: usize, tid: usize, name: &str) -> TraceEvent {
        TraceEvent {
            args: vec![("name".to_string(), ArgValue::Str(name.to_string()))],
            ..TraceEvent::base("thread_name", "__metadata", 'M', pid, tid, 0.0)
        }
    }

    fn to_json(&self) -> String {
        let mut fields = vec![
            format!("\"name\":\"{}\"", escape(&self.name)),
            format!("\"cat\":\"{}\"", escape(&self.cat)),
            format!("\"ph\":\"{}\"", self.ph),
            format!("\"ts\":{}", self.ts_us),
            format!("\"pid\":{}", self.pid),
            format!("\"tid\":{}", self.tid),
        ];
        if let Some(dur) = self.dur_us {
            fields.push(format!("\"dur\":{dur}"));
        }
        if let Some(id) = self.id {
            fields.push(format!("\"id\":{id}"));
        }
        if self.ph == 'i' {
            // Instants need an explicit scope; thread-scoped renders
            // as a small marker on its track.
            fields.push("\"s\":\"t\"".to_string());
        }
        if self.ph == 'f' {
            // Bind the flow end to the enclosing slice.
            fields.push("\"bp\":\"e\"".to_string());
        }
        if !self.args.is_empty() {
            let args: Vec<String> = self
                .args
                .iter()
                .map(|(k, v)| match v {
                    ArgValue::Num(x) if x.is_finite() => format!("\"{}\":{x}", escape(k)),
                    ArgValue::Num(_) => format!("\"{}\":null", escape(k)),
                    ArgValue::Str(s) => format!("\"{}\":\"{}\"", escape(k), escape(s)),
                })
                .collect();
            fields.push(format!("\"args\":{{{}}}", args.join(",")));
        }
        format!("{{{}}}", fields.join(","))
    }
}

/// Where producers send trace events. Producers must guard event
/// construction with `enabled()` so a disabled sink costs nothing.
pub trait TraceSink {
    /// Whether this sink wants events at all. `false` promises the
    /// producer may skip all trace bookkeeping.
    fn enabled(&self) -> bool;
    fn record(&mut self, ev: TraceEvent);
}

/// The zero-overhead sink: disabled, drops everything.
pub struct NullSink;

impl TraceSink for NullSink {
    fn enabled(&self) -> bool {
        false
    }

    fn record(&mut self, _ev: TraceEvent) {}
}

/// Buffers events in record order (deterministic: the DES replay
/// order is pure virtual time).
#[derive(Debug, Default, Clone)]
pub struct MemorySink {
    pub events: Vec<TraceEvent>,
}

impl MemorySink {
    pub fn new() -> MemorySink {
        MemorySink::default()
    }

    /// Render the buffered events as a Chrome trace JSON document.
    pub fn to_chrome_json(&self) -> String {
        to_chrome_json(&self.events)
    }
}

impl TraceSink for MemorySink {
    fn enabled(&self) -> bool {
        true
    }

    fn record(&mut self, ev: TraceEvent) {
        self.events.push(ev);
    }
}

/// Render `events` (in order) as a Chrome trace JSON object:
/// `{"displayTimeUnit":"ms","traceEvents":[...]}`. The output is a
/// single line and parses under [`crate::obs::json::parse`]; CI
/// additionally runs it through `python3 -m json.tool`.
pub fn to_chrome_json(events: &[TraceEvent]) -> String {
    let body: Vec<String> = events.iter().map(|e| e.to_json()).collect();
    format!("{{\"displayTimeUnit\":\"ms\",\"traceEvents\":[{}]}}", body.join(","))
}

/// Validate that `text` is a parseable Chrome trace document with a
/// `traceEvents` array; returns the event count.
pub fn validate_chrome_json(text: &str) -> Result<usize, String> {
    let v = crate::obs::json::parse(text)?;
    let events = v
        .get("traceEvents")
        .and_then(|e| e.as_arr())
        .ok_or_else(|| "missing traceEvents array".to_string())?;
    Ok(events.len())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn chrome_json_round_trips_through_parser() {
        let mut sink = MemorySink::new();
        sink.record(TraceEvent::process_name(0, "exynos5422"));
        sink.record(TraceEvent::thread_name(0, 1, "cluster c0"));
        sink.record(TraceEvent::span("compute", "phase", 0, 1, 0.5e-3, 2.0e-3));
        sink.record(TraceEvent::instant("cache_miss", "cache", 0, 0, 0.5e-3));
        sink.record(TraceEvent::flow_start("req 3", "request", 2, 0, 0.0, 3));
        sink.record(TraceEvent::flow_end("req 3", "request", 0, 0, 2.5e-3, 3));
        sink.record(TraceEvent::counter("queue_depth", 2, 0, 1.0e-3, 4.0));
        let doc = sink.to_chrome_json();
        assert_eq!(validate_chrome_json(&doc).unwrap(), 7);
        let v = crate::obs::json::parse(&doc).unwrap();
        let events = v.get("traceEvents").unwrap().as_arr().unwrap();
        assert_eq!(events[2].get("ph").unwrap().as_str(), Some("X"));
        assert_eq!(events[2].get("dur").unwrap().as_num(), Some(2.0e-3 * 1e6));
        assert_eq!(events[3].get("s").unwrap().as_str(), Some("t"));
        assert_eq!(events[5].get("bp").unwrap().as_str(), Some("e"));
        assert_eq!(events[5].get("id").unwrap().as_num(), Some(3.0));
        assert_eq!(events[6].get("args").unwrap().get("value").unwrap().as_num(), Some(4.0));
    }

    #[test]
    fn null_sink_is_disabled_and_memory_sink_is_not() {
        assert!(!NullSink.enabled());
        assert!(MemorySink::new().enabled());
    }

    #[test]
    fn event_names_are_escaped() {
        let doc = to_chrome_json(&[TraceEvent::instant("a\"b\\c", "x", 0, 0, 0.0)]);
        assert!(validate_chrome_json(&doc).is_ok());
        let v = crate::obs::json::parse(&doc).unwrap();
        let events = v.get("traceEvents").unwrap().as_arr().unwrap();
        assert_eq!(events[0].get("name").unwrap().as_str(), Some("a\"b\\c"));
    }

    #[test]
    fn empty_trace_is_still_valid() {
        assert_eq!(validate_chrome_json(&to_chrome_json(&[])).unwrap(), 0);
    }
}
