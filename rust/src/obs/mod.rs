//! Observability layer: virtual-time tracing + a metrics registry
//! (DESIGN.md §6).
//!
//! Two halves, one contract:
//!
//! - [`metrics`] — a [`MetricsRegistry`] of named counters, gauges,
//!   and mergeable log-linear [`Histogram`]s, threaded through the
//!   hot layers (`sim::engine::RunCache`, the fleet stream replay,
//!   `dvfs::sim`, `energy`). Snapshots export as exact TSV, one-line
//!   JSON (the coordinator `METRICS` command), and Prometheus text
//!   (`amp-gemm metrics`).
//! - [`trace`] — a [`TraceSink`] of virtual-time spans, instants,
//!   flow events, and counters, rendered as Chrome-trace-event JSON
//!   (`amp-gemm trace`, openable in `ui.perfetto.dev`). One process
//!   per fleet board plus a dispatcher process; one track per
//!   cluster.
//!
//! **The zero-overhead-when-off contract**: every traced entry point
//! (`simulate_fleet_stream_traced`, `simulate_dvfs_traced`) takes a
//! sink + registry pair, and the default untraced entry points pass
//! [`NullSink`] + [`MetricsRegistry::disabled`]. Producers guard all
//! event construction behind `sink.enabled()` / the registry's
//! internal flag, and never let instrumentation into the clock
//! arithmetic — so the untraced results are bit-for-bit identical to
//! the traced ones, and the off path keeps PR 6's no-trace speed
//! (pinned by `rust/tests/obs_props.rs` and the
//! `obs_off_events_per_s` / `obs_trace_overhead_ratio`
//! perf-trajectory rows).
//!
//! [`json`] is the shared hand-rolled JSON escape/parse support both
//! exporters lean on (the repo deliberately carries no serde).

pub mod json;
pub mod metrics;
pub mod trace;

pub use metrics::{quantile_sorted, Histogram, MetricsRegistry};
pub use trace::{to_chrome_json, MemorySink, NullSink, TraceEvent, TraceSink};
