//! Metrics registry: named counters, gauges, and log-linear
//! histograms for the virtual-time layers (run cache, fleet streams,
//! DVFS replays, energy accounting).
//!
//! Design rules, in contract order:
//!
//! - **Zero overhead when off.** A registry built with
//!   [`MetricsRegistry::disabled`] turns every mutation into an early
//!   return; the hot loops it instruments never change their
//!   arithmetic, so the no-trace fast path stays bit-for-bit (pinned
//!   by `rust/tests/obs_props.rs` and the perf-trajectory rows
//!   `obs_off_events_per_s` / `obs_trace_overhead_ratio`).
//! - **One quantile kernel.** [`quantile_sorted`] is the single
//!   linear-interpolation quantile in the repo:
//!   [`crate::util::stats::percentile`] and
//!   [`crate::util::stats::Summary`] delegate here, and
//!   [`Histogram::quantile`] uses it verbatim whenever exact samples
//!   are retained — which is how `StreamStats` p50/p99 stay
//!   bit-for-bit after moving onto histograms.
//! - **Mergeable.** Histograms use a fixed log-linear bucket ladder
//!   (8 sub-buckets per octave over 2^-40 ‥ 2^41), so merging two
//!   histograms bucket-wise equals bucketing the pooled sample.
//! - **Exact round-trip.** [`MetricsRegistry::to_tsv`] /
//!   [`MetricsRegistry::from_tsv`] reproduce the registry exactly
//!   (Rust's shortest-round-trip `{}` float formatting), like
//!   `calibrate::RateTable`; [`MetricsRegistry::to_json`] emits a
//!   one-line snapshot consumed by the coordinator `METRICS` command
//!   and validated by [`crate::obs::json::parse`].

use std::collections::BTreeMap;

/// Sub-bucket resolution: 2^3 = 8 linear sub-buckets per octave, a
/// worst-case relative bucket width of 12.5%.
const SUB_BITS: u32 = 3;
const SUBS: usize = 1 << SUB_BITS;
/// Smallest bucketed exponent: values below 2^-40 (~9e-13 — far under
/// any virtual-time duration we record) land in the underflow bucket.
const MIN_EXP: i32 = -40;
/// Largest bucketed exponent: values at or above 2^41 overflow.
const MAX_EXP: i32 = 40;
const OCTAVES: usize = (MAX_EXP - MIN_EXP + 1) as usize;
/// Ladder buckets plus underflow (index 0) and overflow (last index).
pub const NUM_BUCKETS: usize = OCTAVES * SUBS + 2;

/// Bucket index for `v`. Non-positive, sub-ladder, and NaN values go
/// to the underflow bucket; values past the ladder (and +inf) go to
/// the overflow bucket. Pure bit arithmetic — no `log2` calls — so
/// the ladder is identical on every platform.
fn bucket_index(v: f64) -> usize {
    if v.is_nan() || v <= 0.0 {
        return 0;
    }
    if v == f64::INFINITY {
        return NUM_BUCKETS - 1;
    }
    let bits = v.to_bits();
    let exp = ((bits >> 52) & 0x7ff) as i32 - 1023; // floor(log2 v); subnormals give < MIN_EXP
    if exp < MIN_EXP {
        return 0;
    }
    if exp > MAX_EXP {
        return NUM_BUCKETS - 1;
    }
    let sub = ((bits >> (52 - SUB_BITS)) & (SUBS as u64 - 1)) as usize;
    1 + (exp - MIN_EXP) as usize * SUBS + sub
}

/// Inclusive lower edge of ladder bucket `idx` (1 ‥ NUM_BUCKETS-2).
fn bucket_lower(idx: usize) -> f64 {
    let o = (idx - 1) / SUBS;
    let sub = (idx - 1) % SUBS;
    2f64.powi(MIN_EXP + o as i32) * (1.0 + sub as f64 / SUBS as f64)
}

/// Exclusive upper edge of ladder bucket `idx`.
fn bucket_upper(idx: usize) -> f64 {
    let o = (idx - 1) / SUBS;
    let sub = (idx - 1) % SUBS;
    2f64.powi(MIN_EXP + o as i32) * (1.0 + (sub + 1) as f64 / SUBS as f64)
}

/// The repo's single linear-interpolation quantile kernel over an
/// ascending-sorted sample. `p` is a percentile rank in `[0, 100]`;
/// the rank maps to `p/100 · (n−1)` with linear interpolation between
/// neighbours — exactly the historical `util::stats::percentile`
/// contract, which now delegates here.
pub fn quantile_sorted(sorted: &[f64], p: f64) -> f64 {
    assert!(!sorted.is_empty(), "quantile of empty sample");
    assert!((0.0..=100.0).contains(&p), "quantile rank {p} outside [0, 100]");
    if sorted.len() == 1 {
        return sorted[0];
    }
    let rank = p / 100.0 * (sorted.len() - 1) as f64;
    let lo = rank.floor() as usize;
    let hi = rank.ceil() as usize;
    if lo == hi {
        sorted[lo]
    } else {
        let frac = rank - lo as f64;
        sorted[lo] * (1.0 - frac) + sorted[hi] * frac
    }
}

/// A mergeable log-linear histogram with optional exact-sample
/// retention. With samples retained (the default for registry
/// observations and the stream sojourn path), [`Histogram::quantile`]
/// is exact — bit-for-bit [`quantile_sorted`]; without, it answers
/// from the bucket ladder within one bucket's resolution (≤ 12.5%
/// relative).
#[derive(Debug, Clone, PartialEq)]
pub struct Histogram {
    buckets: Vec<u64>,
    count: u64,
    sum: f64,
    min: f64,
    max: f64,
    samples: Option<Vec<f64>>,
}

impl Histogram {
    /// Bucket-only histogram (constant memory).
    pub fn new() -> Histogram {
        Histogram {
            buckets: vec![0; NUM_BUCKETS],
            count: 0,
            sum: 0.0,
            min: f64::INFINITY,
            max: f64::NEG_INFINITY,
            samples: None,
        }
    }

    /// Histogram that additionally retains every observed value, for
    /// exact quantiles (memory grows with the sample).
    pub fn with_samples() -> Histogram {
        Histogram { samples: Some(Vec::new()), ..Histogram::new() }
    }

    pub fn observe(&mut self, v: f64) {
        self.buckets[bucket_index(v)] += 1;
        self.count += 1;
        self.sum += v;
        self.min = self.min.min(v);
        self.max = self.max.max(v);
        if let Some(s) = &mut self.samples {
            s.push(v);
        }
    }

    pub fn count(&self) -> u64 {
        self.count
    }

    pub fn sum(&self) -> f64 {
        self.sum
    }

    /// Minimum observed value (+inf when empty).
    pub fn min(&self) -> f64 {
        self.min
    }

    /// Maximum observed value (−inf when empty).
    pub fn max(&self) -> f64 {
        self.max
    }

    pub fn mean(&self) -> f64 {
        if self.count == 0 {
            0.0
        } else {
            self.sum / self.count as f64
        }
    }

    /// Merge `other` into `self`, bucket-wise. Equivalent to having
    /// observed the pooled sample: bucket counts, count/sum/min/max
    /// add exactly; samples concatenate when both sides retain them
    /// and are dropped otherwise.
    pub fn merge(&mut self, other: &Histogram) {
        for (b, &o) in self.buckets.iter_mut().zip(&other.buckets) {
            *b += o;
        }
        self.count += other.count;
        self.sum += other.sum;
        self.min = self.min.min(other.min);
        self.max = self.max.max(other.max);
        self.samples = match (self.samples.take(), &other.samples) {
            (Some(mut mine), Some(theirs)) => {
                mine.extend_from_slice(theirs);
                Some(mine)
            }
            _ => None,
        };
    }

    /// Percentile-rank quantile, `p` in `[0, 100]`. Exact (the shared
    /// [`quantile_sorted`] kernel) when samples are retained; bucket
    /// midpoint clamped to the observed `[min, max]` otherwise.
    pub fn quantile(&self, p: f64) -> f64 {
        assert!(self.count > 0, "quantile of empty histogram");
        assert!((0.0..=100.0).contains(&p), "quantile rank {p} outside [0, 100]");
        if let Some(s) = &self.samples {
            let mut sorted = s.clone();
            sorted.sort_by(|a, b| a.total_cmp(b));
            return quantile_sorted(&sorted, p);
        }
        let rank = p / 100.0 * (self.count - 1) as f64;
        let mut cum = 0u64;
        for (idx, &c) in self.buckets.iter().enumerate() {
            if c == 0 {
                continue;
            }
            cum += c;
            if cum as f64 > rank {
                let mid = if idx == 0 {
                    self.min
                } else if idx == NUM_BUCKETS - 1 {
                    self.max
                } else {
                    0.5 * (bucket_lower(idx) + bucket_upper(idx))
                };
                return mid.clamp(self.min, self.max);
            }
        }
        self.max
    }

    fn to_tsv_fields(&self) -> String {
        let nonzero: Vec<String> = self
            .buckets
            .iter()
            .enumerate()
            .filter(|(_, &c)| c > 0)
            .map(|(i, &c)| format!("{i}:{c}"))
            .collect();
        let buckets = if nonzero.is_empty() { "-".to_string() } else { nonzero.join(",") };
        let samples = match &self.samples {
            None => "-".to_string(),
            Some(s) => {
                let joined: Vec<String> = s.iter().map(|v| format!("{v}")).collect();
                format!("~{}", joined.join(","))
            }
        };
        format!(
            "{}\t{}\t{}\t{}\t{}\t{}",
            self.count, self.sum, self.min, self.max, buckets, samples
        )
    }

    fn from_tsv_fields(fields: &[&str]) -> Result<Histogram, String> {
        if fields.len() != 6 {
            return Err(format!("hist row wants 6 fields, got {}", fields.len()));
        }
        let mut h = Histogram::new();
        h.count = fields[0].parse().map_err(|_| format!("bad hist count '{}'", fields[0]))?;
        h.sum = parse_f64(fields[1])?;
        h.min = parse_f64(fields[2])?;
        h.max = parse_f64(fields[3])?;
        if fields[4] != "-" {
            for pair in fields[4].split(',') {
                let (i, c) = pair
                    .split_once(':')
                    .ok_or_else(|| format!("bad bucket entry '{pair}'"))?;
                let i: usize = i.parse().map_err(|_| format!("bad bucket index '{i}'"))?;
                if i >= NUM_BUCKETS {
                    return Err(format!("bucket index {i} out of range"));
                }
                h.buckets[i] = c.parse().map_err(|_| format!("bad bucket count '{c}'"))?;
            }
        }
        if let Some(rest) = fields[5].strip_prefix('~') {
            let mut samples = Vec::new();
            if !rest.is_empty() {
                for tok in rest.split(',') {
                    samples.push(parse_f64(tok)?);
                }
            }
            h.samples = Some(samples);
        }
        Ok(h)
    }
}

impl Default for Histogram {
    fn default() -> Self {
        Histogram::new()
    }
}

fn parse_f64(tok: &str) -> Result<f64, String> {
    tok.parse::<f64>().map_err(|_| format!("bad float '{tok}'"))
}

/// Prometheus metric names admit `[a-zA-Z0-9_:]`; everything else
/// becomes `_`.
fn prom_name(name: &str) -> String {
    name.chars()
        .map(|c| if c.is_ascii_alphanumeric() || c == '_' || c == ':' { c } else { '_' })
        .collect()
}

/// A registry of named metrics. Counters are monotone f64 adds (so
/// fractional joules and flop counts fit), gauges are last-write
/// scalars, histograms retain exact samples. All maps are `BTreeMap`,
/// so every export is deterministically ordered.
#[derive(Debug, Clone, PartialEq)]
pub struct MetricsRegistry {
    enabled: bool,
    counters: BTreeMap<String, f64>,
    gauges: BTreeMap<String, f64>,
    histograms: BTreeMap<String, Histogram>,
}

impl MetricsRegistry {
    /// An enabled (recording) registry.
    pub fn new() -> MetricsRegistry {
        MetricsRegistry {
            enabled: true,
            counters: BTreeMap::new(),
            gauges: BTreeMap::new(),
            histograms: BTreeMap::new(),
        }
    }

    /// The zero-overhead registry: every mutation returns immediately
    /// and no allocation ever happens. This is what the default
    /// (untraced) entry points pass.
    pub fn disabled() -> MetricsRegistry {
        MetricsRegistry { enabled: false, ..MetricsRegistry::new() }
    }

    pub fn enabled(&self) -> bool {
        self.enabled
    }

    pub fn is_empty(&self) -> bool {
        self.counters.is_empty() && self.gauges.is_empty() && self.histograms.is_empty()
    }

    /// Add `delta` to counter `name` (created at 0).
    pub fn inc(&mut self, name: &str, delta: f64) {
        if !self.enabled {
            return;
        }
        *self.counters.entry(name.to_string()).or_insert(0.0) += delta;
    }

    /// Set gauge `name` to `v` (last write wins).
    pub fn set_gauge(&mut self, name: &str, v: f64) {
        if !self.enabled {
            return;
        }
        self.gauges.insert(name.to_string(), v);
    }

    /// Observe `v` into histogram `name` (created retaining samples).
    pub fn observe(&mut self, name: &str, v: f64) {
        if !self.enabled {
            return;
        }
        self.histograms.entry(name.to_string()).or_insert_with(Histogram::with_samples).observe(v);
    }

    /// Record a whole pre-built histogram under `name` (merging into
    /// any existing one) — how the stream sim hands over its sojourn
    /// and service-time histograms without re-observing every value.
    pub fn record_histogram(&mut self, name: &str, h: &Histogram) {
        if !self.enabled {
            return;
        }
        self.histograms.entry(name.to_string()).or_insert_with(Histogram::with_samples).merge(h);
    }

    pub fn counter(&self, name: &str) -> Option<f64> {
        self.counters.get(name).copied()
    }

    pub fn gauge(&self, name: &str) -> Option<f64> {
        self.gauges.get(name).copied()
    }

    pub fn histogram(&self, name: &str) -> Option<&Histogram> {
        self.histograms.get(name)
    }

    pub fn counter_names(&self) -> impl Iterator<Item = &str> {
        self.counters.keys().map(|s| s.as_str())
    }

    /// Merge `other` into `self`: counters add, gauges take `other`'s
    /// value, histograms merge bucket-wise.
    pub fn merge(&mut self, other: &MetricsRegistry) {
        if !self.enabled {
            return;
        }
        for (k, v) in &other.counters {
            *self.counters.entry(k.clone()).or_insert(0.0) += v;
        }
        for (k, v) in &other.gauges {
            self.gauges.insert(k.clone(), *v);
        }
        for (k, h) in &other.histograms {
            self.histograms.entry(k.clone()).or_insert_with(Histogram::with_samples).merge(h);
        }
    }

    /// Exact TSV serialization (one metric per line); inverse of
    /// [`MetricsRegistry::from_tsv`], bit-for-bit.
    pub fn to_tsv(&self) -> String {
        let mut out = String::from("# amp-gemm-metrics-v1\n");
        for (k, v) in &self.counters {
            out.push_str(&format!("counter\t{k}\t{v}\n"));
        }
        for (k, v) in &self.gauges {
            out.push_str(&format!("gauge\t{k}\t{v}\n"));
        }
        for (k, h) in &self.histograms {
            out.push_str(&format!("hist\t{k}\t{}\n", h.to_tsv_fields()));
        }
        out
    }

    /// Parse [`MetricsRegistry::to_tsv`] output. The result is an
    /// enabled registry equal to the serialized one.
    pub fn from_tsv(text: &str) -> Result<MetricsRegistry, String> {
        let mut reg = MetricsRegistry::new();
        for (lineno, line) in text.lines().enumerate() {
            let line = line.trim_end();
            if line.is_empty() || line.starts_with('#') {
                continue;
            }
            let fields: Vec<&str> = line.split('\t').collect();
            let err = |m: String| format!("metrics tsv line {}: {m}", lineno + 1);
            match fields[0] {
                "counter" | "gauge" if fields.len() == 3 => {
                    let v = parse_f64(fields[2]).map_err(err)?;
                    if fields[0] == "counter" {
                        reg.counters.insert(fields[1].to_string(), v);
                    } else {
                        reg.gauges.insert(fields[1].to_string(), v);
                    }
                }
                "hist" if fields.len() == 8 => {
                    let h = Histogram::from_tsv_fields(&fields[2..]).map_err(err)?;
                    reg.histograms.insert(fields[1].to_string(), h);
                }
                _ => return Err(err(format!("unrecognized row '{line}'"))),
            }
        }
        Ok(reg)
    }

    /// One-line JSON snapshot (counters, gauges, histogram summaries)
    /// — the coordinator `METRICS` reply. Parses under
    /// [`crate::obs::json::parse`]; keys are in BTreeMap order.
    pub fn to_json(&self) -> String {
        let fmt_map = |m: &BTreeMap<String, f64>| -> String {
            let fields: Vec<String> = m
                .iter()
                .map(|(k, v)| format!("\"{}\":{}", crate::obs::json::escape(k), json_num(*v)))
                .collect();
            format!("{{{}}}", fields.join(","))
        };
        let hists: Vec<String> = self
            .histograms
            .iter()
            .map(|(k, h)| {
                format!(
                    "\"{}\":{{\"count\":{},\"sum\":{},\"min\":{},\"max\":{},\"mean\":{},\"p50\":{},\"p99\":{}}}",
                    crate::obs::json::escape(k),
                    h.count(),
                    json_num(h.sum()),
                    json_num(if h.count() == 0 { 0.0 } else { h.min() }),
                    json_num(if h.count() == 0 { 0.0 } else { h.max() }),
                    json_num(h.mean()),
                    json_num(if h.count() == 0 { 0.0 } else { h.quantile(50.0) }),
                    json_num(if h.count() == 0 { 0.0 } else { h.quantile(99.0) }),
                )
            })
            .collect();
        format!(
            "{{\"counters\":{},\"gauges\":{},\"histograms\":{{{}}}}}",
            fmt_map(&self.counters),
            fmt_map(&self.gauges),
            hists.join(",")
        )
    }

    /// Prometheus text exposition (counters, gauges, and histogram
    /// summaries with p50/p99 quantile labels).
    pub fn to_prometheus(&self) -> String {
        let mut out = String::new();
        for (k, v) in &self.counters {
            let name = prom_name(k);
            out.push_str(&format!("# TYPE {name} counter\n{name} {v}\n"));
        }
        for (k, v) in &self.gauges {
            let name = prom_name(k);
            out.push_str(&format!("# TYPE {name} gauge\n{name} {v}\n"));
        }
        for (k, h) in &self.histograms {
            let name = prom_name(k);
            out.push_str(&format!("# TYPE {name} summary\n"));
            out.push_str(&format!("{name}_count {}\n", h.count()));
            out.push_str(&format!("{name}_sum {}\n", h.sum()));
            if h.count() > 0 {
                out.push_str(&format!("{name}{{quantile=\"0.5\"}} {}\n", h.quantile(50.0)));
                out.push_str(&format!("{name}{{quantile=\"0.99\"}} {}\n", h.quantile(99.0)));
            }
        }
        out
    }
}

impl Default for MetricsRegistry {
    fn default() -> Self {
        MetricsRegistry::new()
    }
}

fn json_num(v: f64) -> String {
    if v.is_finite() {
        format!("{v}")
    } else {
        // JSON has no inf/nan; snapshot consumers get null.
        "null".to_string()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    #[test]
    fn bucket_index_is_monotone_and_in_range() {
        let mut prev = 0usize;
        let mut v = 1e-14;
        while v < 1e14 {
            let idx = bucket_index(v);
            assert!(idx < NUM_BUCKETS);
            assert!(idx >= prev, "bucket index regressed at {v}");
            prev = idx;
            v *= 1.07;
        }
        assert_eq!(bucket_index(0.0), 0);
        assert_eq!(bucket_index(-3.0), 0);
        assert_eq!(bucket_index(f64::INFINITY), NUM_BUCKETS - 1);
    }

    #[test]
    fn ladder_buckets_contain_their_values() {
        for &v in &[1e-9, 0.001, 0.5, 1.0, 1.49, 7.3, 1e6] {
            let idx = bucket_index(v);
            assert!(idx > 0 && idx < NUM_BUCKETS - 1, "{v} fell off the ladder");
            assert!(bucket_lower(idx) <= v && v < bucket_upper(idx), "{v} outside bucket {idx}");
        }
    }

    #[test]
    fn sampled_quantile_matches_percentile_kernel() {
        let mut rng = Rng::new(7);
        let xs: Vec<f64> = (0..257).map(|_| rng.gen_range(0.001, 50.0)).collect();
        let mut h = Histogram::with_samples();
        for &x in &xs {
            h.observe(x);
        }
        let mut sorted = xs.clone();
        sorted.sort_by(|a, b| a.total_cmp(b));
        for &p in &[0.0, 25.0, 50.0, 99.0, 100.0] {
            assert_eq!(h.quantile(p), quantile_sorted(&sorted, p));
        }
    }

    #[test]
    fn bucket_quantile_is_within_bucket_resolution() {
        let mut rng = Rng::new(11);
        let xs: Vec<f64> = (0..400).map(|_| rng.gen_range(0.01, 100.0)).collect();
        let mut bucketed = Histogram::new();
        let mut exact = Histogram::with_samples();
        for &x in &xs {
            bucketed.observe(x);
            exact.observe(x);
        }
        for &p in &[10.0, 50.0, 90.0, 99.0] {
            let approx = bucketed.quantile(p);
            let truth = exact.quantile(p);
            assert!(
                (approx - truth).abs() <= 0.125 * truth.abs() + 1e-12,
                "p{p}: bucket answer {approx} vs exact {truth}"
            );
        }
    }

    #[test]
    fn merge_equals_pooled_sample() {
        let mut rng = Rng::new(3);
        let xs: Vec<f64> = (0..300).map(|_| rng.gen_range(0.001, 20.0)).collect();
        let mut pooled = Histogram::with_samples();
        let mut left = Histogram::with_samples();
        let mut right = Histogram::with_samples();
        for (i, &x) in xs.iter().enumerate() {
            pooled.observe(x);
            if i % 2 == 0 {
                left.observe(x)
            } else {
                right.observe(x)
            }
        }
        left.merge(&right);
        assert_eq!(left.count(), pooled.count());
        assert_eq!(left.min(), pooled.min());
        assert_eq!(left.max(), pooled.max());
        assert_eq!(left.buckets, pooled.buckets);
        for &p in &[0.0, 50.0, 99.0, 100.0] {
            // Same sorted multiset, same kernel ⇒ bit-for-bit.
            assert_eq!(left.quantile(p), pooled.quantile(p));
        }
    }

    #[test]
    fn registry_tsv_round_trips_exactly() {
        let mut reg = MetricsRegistry::new();
        reg.inc("stream_admissions", 24.0);
        reg.inc("energy_j_c0", 1.2345678901234567);
        reg.set_gauge("queue_depth_max", 7.0);
        reg.observe("sojourn_s", 0.125);
        reg.observe("sojourn_s", 3.5e-3);
        reg.observe("sojourn_s", 42.0);
        let parsed = MetricsRegistry::from_tsv(&reg.to_tsv()).unwrap();
        assert_eq!(parsed, reg);
        // And the round-trip is a fixed point of serialization.
        assert_eq!(parsed.to_tsv(), reg.to_tsv());
    }

    #[test]
    fn json_snapshot_parses() {
        let mut reg = MetricsRegistry::new();
        reg.inc("hits", 3.0);
        reg.set_gauge("depth", 2.5);
        reg.observe("lat_s", 0.25);
        reg.observe("lat_s", 0.75);
        let doc = reg.to_json();
        assert!(!doc.contains('\n'), "snapshot must stay a single line");
        let v = crate::obs::json::parse(&doc).unwrap();
        assert_eq!(v.get("counters").unwrap().get("hits").unwrap().as_num(), Some(3.0));
        assert_eq!(v.get("gauges").unwrap().get("depth").unwrap().as_num(), Some(2.5));
        let lat = v.get("histograms").unwrap().get("lat_s").unwrap();
        assert_eq!(lat.get("count").unwrap().as_num(), Some(2.0));
        assert_eq!(lat.get("p50").unwrap().as_num(), Some(0.5));
    }

    #[test]
    fn prometheus_exposition_has_expected_lines() {
        let mut reg = MetricsRegistry::new();
        reg.inc("cache.hits", 5.0);
        reg.observe("sojourn_s", 1.0);
        let text = reg.to_prometheus();
        assert!(text.contains("# TYPE cache_hits counter"));
        assert!(text.contains("cache_hits 5"));
        assert!(text.contains("sojourn_s_count 1"));
        assert!(text.contains("sojourn_s{quantile=\"0.5\"} 1"));
    }

    #[test]
    fn disabled_registry_records_nothing() {
        let mut reg = MetricsRegistry::disabled();
        reg.inc("a", 1.0);
        reg.set_gauge("b", 2.0);
        reg.observe("c", 3.0);
        reg.record_histogram("d", &Histogram::with_samples());
        assert!(!reg.enabled());
        assert!(reg.is_empty());
    }
}
