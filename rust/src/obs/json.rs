//! Minimal hand-rolled JSON support for the observability layer: a
//! string escaper shared by every emitter ([`crate::obs::trace`]'s
//! Chrome trace writer, [`crate::obs::metrics`]'s snapshot line) and a
//! small recursive-descent parser used by round-trip and golden tests
//! to prove the emitted documents actually parse — the repo carries no
//! serde, so "serde round-trip" is spelled emit → [`parse`] → inspect.
//!
//! The parser accepts the JSON grammar our emitters produce (objects,
//! arrays, strings with the standard escapes, numbers, booleans,
//! null) and rejects trailing garbage. It keeps object keys in
//! document order so structural golden tests can pin key layout.

/// A parsed JSON value. Objects preserve key order.
#[derive(Debug, Clone, PartialEq)]
pub enum Value {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Value>),
    Obj(Vec<(String, Value)>),
}

impl Value {
    /// Object field lookup (first match); `None` on non-objects.
    pub fn get(&self, key: &str) -> Option<&Value> {
        match self {
            Value::Obj(fields) => fields.iter().find(|(k, _)| k == key).map(|(_, v)| v),
            _ => None,
        }
    }

    pub fn as_num(&self) -> Option<f64> {
        match self {
            Value::Num(x) => Some(*x),
            _ => None,
        }
    }

    pub fn as_str(&self) -> Option<&str> {
        match self {
            Value::Str(s) => Some(s),
            _ => None,
        }
    }

    pub fn as_arr(&self) -> Option<&[Value]> {
        match self {
            Value::Arr(items) => Some(items),
            _ => None,
        }
    }

    pub fn as_obj(&self) -> Option<&[(String, Value)]> {
        match self {
            Value::Obj(fields) => Some(fields),
            _ => None,
        }
    }
}

/// Escape `s` for embedding inside a JSON string literal (no
/// surrounding quotes). Control characters become `\u00XX`.
pub fn escape(s: &str) -> String {
    let mut out = String::with_capacity(s.len());
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\r' => out.push_str("\\r"),
            '\t' => out.push_str("\\t"),
            c if (c as u32) < 0x20 => out.push_str(&format!("\\u{:04x}", c as u32)),
            c => out.push(c),
        }
    }
    out
}

/// Parse a complete JSON document. Errors carry a byte offset.
pub fn parse(text: &str) -> Result<Value, String> {
    let mut p = Parser { bytes: text.as_bytes(), pos: 0 };
    p.skip_ws();
    let v = p.value()?;
    p.skip_ws();
    if p.pos != p.bytes.len() {
        return Err(format!("trailing content at byte {}", p.pos));
    }
    Ok(v)
}

struct Parser<'a> {
    bytes: &'a [u8],
    pos: usize,
}

impl<'a> Parser<'a> {
    fn skip_ws(&mut self) {
        while let Some(&b) = self.bytes.get(self.pos) {
            if b == b' ' || b == b'\t' || b == b'\n' || b == b'\r' {
                self.pos += 1;
            } else {
                break;
            }
        }
    }

    fn peek(&self) -> Option<u8> {
        self.bytes.get(self.pos).copied()
    }

    fn expect(&mut self, b: u8) -> Result<(), String> {
        if self.peek() == Some(b) {
            self.pos += 1;
            Ok(())
        } else {
            Err(format!("expected '{}' at byte {}", b as char, self.pos))
        }
    }

    fn literal(&mut self, word: &str, v: Value) -> Result<Value, String> {
        if self.bytes[self.pos..].starts_with(word.as_bytes()) {
            self.pos += word.len();
            Ok(v)
        } else {
            Err(format!("invalid literal at byte {}", self.pos))
        }
    }

    fn value(&mut self) -> Result<Value, String> {
        match self.peek() {
            Some(b'{') => self.object(),
            Some(b'[') => self.array(),
            Some(b'"') => Ok(Value::Str(self.string()?)),
            Some(b't') => self.literal("true", Value::Bool(true)),
            Some(b'f') => self.literal("false", Value::Bool(false)),
            Some(b'n') => self.literal("null", Value::Null),
            Some(b) if b == b'-' || b.is_ascii_digit() => self.number(),
            _ => Err(format!("unexpected input at byte {}", self.pos)),
        }
    }

    fn object(&mut self) -> Result<Value, String> {
        self.expect(b'{')?;
        let mut fields = Vec::new();
        self.skip_ws();
        if self.peek() == Some(b'}') {
            self.pos += 1;
            return Ok(Value::Obj(fields));
        }
        loop {
            self.skip_ws();
            let key = self.string()?;
            self.skip_ws();
            self.expect(b':')?;
            self.skip_ws();
            let val = self.value()?;
            fields.push((key, val));
            self.skip_ws();
            match self.peek() {
                Some(b',') => self.pos += 1,
                Some(b'}') => {
                    self.pos += 1;
                    return Ok(Value::Obj(fields));
                }
                _ => return Err(format!("expected ',' or '}}' at byte {}", self.pos)),
            }
        }
    }

    fn array(&mut self) -> Result<Value, String> {
        self.expect(b'[')?;
        let mut items = Vec::new();
        self.skip_ws();
        if self.peek() == Some(b']') {
            self.pos += 1;
            return Ok(Value::Arr(items));
        }
        loop {
            self.skip_ws();
            items.push(self.value()?);
            self.skip_ws();
            match self.peek() {
                Some(b',') => self.pos += 1,
                Some(b']') => {
                    self.pos += 1;
                    return Ok(Value::Arr(items));
                }
                _ => return Err(format!("expected ',' or ']' at byte {}", self.pos)),
            }
        }
    }

    fn string(&mut self) -> Result<String, String> {
        self.expect(b'"')?;
        let mut out = String::new();
        loop {
            match self.peek() {
                None => return Err("unterminated string".into()),
                Some(b'"') => {
                    self.pos += 1;
                    return Ok(out);
                }
                Some(b'\\') => {
                    self.pos += 1;
                    match self.peek() {
                        Some(b'"') => out.push('"'),
                        Some(b'\\') => out.push('\\'),
                        Some(b'/') => out.push('/'),
                        Some(b'b') => out.push('\u{8}'),
                        Some(b'f') => out.push('\u{c}'),
                        Some(b'n') => out.push('\n'),
                        Some(b'r') => out.push('\r'),
                        Some(b't') => out.push('\t'),
                        Some(b'u') => {
                            let code = self.hex4()?;
                            // Surrogate pairs are not produced by our
                            // emitters; map lone surrogates to U+FFFD.
                            out.push(char::from_u32(code).unwrap_or('\u{fffd}'));
                        }
                        _ => return Err(format!("bad escape at byte {}", self.pos)),
                    }
                    self.pos += 1;
                }
                Some(_) => {
                    // Consume one UTF-8 scalar (input is a &str, so
                    // boundaries are valid).
                    let rest = std::str::from_utf8(&self.bytes[self.pos..])
                        .map_err(|_| "invalid utf-8".to_string())?;
                    let c = rest.chars().next().expect("peeked non-empty");
                    out.push(c);
                    self.pos += c.len_utf8();
                }
            }
        }
    }

    fn hex4(&mut self) -> Result<u32, String> {
        let start = self.pos + 1;
        let end = start + 4;
        if end > self.bytes.len() {
            return Err("truncated \\u escape".into());
        }
        let hex = std::str::from_utf8(&self.bytes[start..end])
            .map_err(|_| "invalid \\u escape".to_string())?;
        let code =
            u32::from_str_radix(hex, 16).map_err(|_| format!("bad \\u escape '{hex}'"))?;
        self.pos = end - 1; // caller advances past the final digit
        Ok(code)
    }

    fn number(&mut self) -> Result<Value, String> {
        let start = self.pos;
        while let Some(b) = self.peek() {
            if b.is_ascii_digit() || matches!(b, b'-' | b'+' | b'.' | b'e' | b'E') {
                self.pos += 1;
            } else {
                break;
            }
        }
        let token = std::str::from_utf8(&self.bytes[start..self.pos])
            .map_err(|_| "invalid number".to_string())?;
        token
            .parse::<f64>()
            .map(Value::Num)
            .map_err(|_| format!("invalid number '{token}' at byte {start}"))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parses_nested_document() {
        let v = parse(r#"{"a":[1,2.5,-3e2],"b":{"c":"x\ny"},"d":true,"e":null}"#).unwrap();
        assert_eq!(v.get("a").unwrap().as_arr().unwrap().len(), 3);
        assert_eq!(v.get("a").unwrap().as_arr().unwrap()[2].as_num(), Some(-300.0));
        assert_eq!(v.get("b").unwrap().get("c").unwrap().as_str(), Some("x\ny"));
        assert_eq!(v.get("d"), Some(&Value::Bool(true)));
        assert_eq!(v.get("e"), Some(&Value::Null));
    }

    #[test]
    fn escape_round_trips_through_parse() {
        let raw = "a\"b\\c\nd\te\u{1}f — µs";
        let doc = format!("{{\"k\":\"{}\"}}", escape(raw));
        let v = parse(&doc).unwrap();
        assert_eq!(v.get("k").unwrap().as_str(), Some(raw));
    }

    #[test]
    fn rejects_malformed_documents() {
        assert!(parse("{").is_err());
        assert!(parse("[1,]").is_err());
        assert!(parse("{\"a\":1} x").is_err());
        assert!(parse("{'a':1}").is_err());
        assert!(parse("nul").is_err());
    }

    #[test]
    fn float_display_round_trips() {
        for &x in &[0.25, 1.0, 3.5e-3, 123456.789, 1e12] {
            let v = parse(&format!("{x}")).unwrap();
            assert_eq!(v.as_num(), Some(x));
        }
    }
}
