//! CI perf-trajectory harness: a pinned, deterministic virtual-time
//! metric suite with a JSON artifact and a regression gate.
//!
//! Every metric here is *virtual-time* — pure f64 arithmetic over the
//! calibrated model, no host clock — so two builds of the same source
//! produce identical numbers on any machine. The CI `perf-trajectory`
//! job runs [`Trajectory::collect`], emits `BENCH_ci.json` (uploaded as
//! an artifact) and gates it against the checked-in
//! `BENCH_baseline.json`: a metric drifting past its gate (default
//! ±10 %) fails the build. Because the numbers are deterministic, the
//! gate can only fire on a genuine model/scheduling change, never on CI
//! machine noise — which is what makes a perf gate in CI sane at all.
//!
//! The baseline seeded with this harness derives its values from the
//! invariant *ranges* the test suite already pins (e.g. the §3.4
//! cluster anchors), with per-entry gates sized to those ranges; the
//! first CI run's `BENCH_ci.json` artifact is the natural replacement
//! to tighten the baseline to exact values and extend it to the full
//! metric set.

use crate::blis::gemm::GemmShape;
use crate::calibrate::{ca_sas_spec, RateTable, ShapeClass, WeightSource};
use crate::dvfs::sim::{simulate_dvfs, simulate_dvfs_with, DvfsStrategy, Retune};
use crate::dvfs::{Governor, Ondemand};
use crate::figures::fleet::{pinned_stream_arrivals, pinned_stream_fleet};
use crate::fleet::sim::{
    poisson_arrivals, simulate_fleet, simulate_fleet_stream, simulate_fleet_stream_cached,
};
use crate::fleet::{Fleet, FleetStrategy};
use crate::model::PerfModel;
use crate::sched::ScheduleSpec;
use crate::sim::{simulate, RunCache};
use crate::soc::{SocSpec, BIG, LITTLE};
use crate::util::rng::Rng;

/// Which direction of drift regresses a metric.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Better {
    Higher,
    Lower,
}

impl Better {
    pub fn label(self) -> &'static str {
        match self {
            Better::Higher => "higher",
            Better::Lower => "lower",
        }
    }

    pub fn parse(s: &str) -> Result<Better, String> {
        match s {
            "higher" => Ok(Better::Higher),
            "lower" => Ok(Better::Lower),
            other => Err(format!("bad direction '{other}' (higher|lower)")),
        }
    }
}

/// One tracked metric.
#[derive(Debug, Clone, PartialEq)]
pub struct BenchEntry {
    pub key: String,
    pub value: f64,
    pub better: Better,
    /// Per-entry relative gate overriding the run-wide default, if set
    /// (seeded baselines carry range-derived gates).
    pub gate: Option<f64>,
}

/// A perf-trajectory snapshot: the metric suite of one build.
#[derive(Debug, Clone, PartialEq)]
pub struct Trajectory {
    pub entries: Vec<BenchEntry>,
}

impl Trajectory {
    fn push(&mut self, key: &str, value: f64, better: Better) {
        // Every trajectory metric is a rate, time or utilization —
        // strictly positive by construction. The relative gate depends
        // on it: `(cur - base) / base` is sign-stable only for
        // positive baselines.
        assert!(
            value.is_finite() && value > 0.0,
            "metric {key} must be positive and finite: {value}"
        );
        assert!(
            !self.entries.iter().any(|e| e.key == key),
            "duplicate metric key {key}"
        );
        self.entries.push(BenchEntry {
            key: key.to_string(),
            value,
            better,
            gate: None,
        });
    }

    /// Run the pinned suite. Deterministic: same source, same numbers,
    /// bit for bit, on any machine.
    pub fn collect() -> Trajectory {
        let mut t = Trajectory { entries: Vec::new() };

        // --- Per-preset headline GFLOPS (the figures' subjects). ---
        let soc = SocSpec::exynos5422();
        let model = PerfModel::new(soc.clone());
        let r = GemmShape::square(4096);
        let a15 = simulate(&model, &ScheduleSpec::cluster_only(BIG, 4), r);
        t.push("exynos_a15x4_gflops", a15.gflops, Better::Higher);
        let a7 = simulate(&model, &ScheduleSpec::cluster_only(LITTLE, 4), r);
        t.push("exynos_a7x4_gflops", a7.gflops, Better::Higher);
        t.push(
            "exynos_sss_gflops",
            simulate(&model, &ScheduleSpec::sss(), r).gflops,
            Better::Higher,
        );
        t.push(
            "exynos_sas5_gflops",
            simulate(&model, &ScheduleSpec::sas(5.0), r).gflops,
            Better::Higher,
        );
        let cadas = simulate(&model, &ScheduleSpec::ca_das(), r);
        t.push("exynos_cadas_gflops", cadas.gflops, Better::Higher);
        t.push("exynos_cadas_makespan_s", cadas.time_s, Better::Lower);

        // --- The calibration layer's own trajectory: empirically
        //     weighted CA-SAS on the pinned calibration. ---
        let table = RateTable::measure(&soc, &[]);
        let emp = WeightSource::Empirical(table);
        let spec = ca_sas_spec(&emp, &model, ShapeClass::Large);
        t.push(
            "exynos_casas_empirical_gflops",
            simulate(&model, &spec, r).gflops,
            Better::Higher,
        );
        let ramp = Ondemand::new(0.25).plan(&soc, 1e3);
        let strat = DvfsStrategy::Sas { cache_aware: true };
        let shape = GemmShape::square(2048);
        let online = simulate_dvfs(&soc, strat, shape, &ramp, Retune::Online);
        t.push("exynos_dvfs_online_gflops", online.gflops, Better::Higher);
        let online_emp = simulate_dvfs_with(&soc, strat, shape, &ramp, Retune::Online, &emp);
        t.push(
            "exynos_dvfs_online_empirical_gflops",
            online_emp.gflops,
            Better::Higher,
        );

        // --- Streaming + fleet (the pinned report scenarios). ---
        let stream = simulate_fleet_stream(&pinned_stream_fleet(), &pinned_stream_arrivals(true));
        t.push("stream_pinned_makespan_s", stream.makespan_s, Better::Lower);
        t.push("stream_pinned_utilization", stream.utilization, Better::Higher);
        t.push("stream_pinned_p99_sojourn_s", stream.sojourn_p99_s, Better::Lower);
        let fleet = Fleet::parse("exynos5422,juno_r0").expect("presets");
        let fl = simulate_fleet(&fleet, FleetStrategy::Das, GemmShape::square(1024), 32);
        t.push("fleet_das_rps", fl.throughput_rps, Better::Higher);
        for preset in ["juno_r0", "dynamiq_3c", "pe_hybrid"] {
            let m = PerfModel::new(match preset {
                "juno_r0" => SocSpec::juno_r0(),
                "dynamiq_3c" => SocSpec::dynamiq_3c(),
                _ => SocSpec::pe_hybrid(),
            });
            t.push(
                &format!("{preset}_cadas_gflops"),
                simulate(&m, &ScheduleSpec::ca_das(), GemmShape::square(2048)).gflops,
                Better::Higher,
            );
        }

        // --- Engine layer: the run cache under a long mixed stream. ---
        // 2048 Poisson arrivals over three shapes on the pinned
        // two-board fleet collapse to at most six distinct
        // (board-config, shape) DES runs; every other service event is
        // a cache hit. All three metrics are counter or virtual-time
        // values — deterministic on any machine, so the gate can pin
        // them like the model metrics above.
        let mut cache = RunCache::new();
        let sweep_shapes = [256, 384, 512].map(GemmShape::square);
        let sweep_arrivals = poisson_arrivals(&mut Rng::new(0x51E7), &sweep_shapes, 2048, 120.0);
        let sweep =
            simulate_fleet_stream_cached(&pinned_stream_fleet(), &sweep_arrivals, &mut cache);
        t.push("sim_engine_stream_des_runs", sweep.des_runs as f64, Better::Lower);
        t.push("sim_engine_stream_hit_rate", cache.hit_rate(), Better::Higher);
        let sweep_grabs: u64 = sweep.boards.iter().map(|b| b.grabs).sum();
        t.push(
            "sim_engine_stream_events_per_s",
            (sweep.requests as u64 + sweep_grabs) as f64 / sweep.makespan_s,
            Better::Higher,
        );

        // --- Observability layer: the zero-overhead-when-off pin. ---
        // The same sweep re-run through the *traced* entry point with
        // the NullSink + disabled-registry pair must be the fast path
        // (warm cache, zero DES runs) and bit-for-bit the untraced
        // result; and tracing must never perturb virtual time, so the
        // traced/untraced makespan ratio is exactly 1.0 — any drift is
        // instrumentation leaking into the clock arithmetic.
        let off = crate::fleet::sim::simulate_fleet_stream_traced(
            &pinned_stream_fleet(),
            &sweep_arrivals,
            &mut cache,
            &mut crate::obs::NullSink,
            &mut crate::obs::MetricsRegistry::disabled(),
        );
        let off_grabs: u64 = off.boards.iter().map(|b| b.grabs).sum();
        t.push(
            "obs_off_events_per_s",
            (off.requests as u64 + off_grabs) as f64 / off.makespan_s,
            Better::Higher,
        );
        let small_arrivals =
            poisson_arrivals(&mut Rng::new(0x0B5), &sweep_shapes, 256, 120.0);
        let small_off =
            simulate_fleet_stream_cached(&pinned_stream_fleet(), &small_arrivals, &mut cache);
        let mut sink = crate::obs::MemorySink::new();
        let mut reg = crate::obs::MetricsRegistry::new();
        let small_on = crate::fleet::sim::simulate_fleet_stream_traced(
            &pinned_stream_fleet(),
            &small_arrivals,
            &mut cache,
            &mut sink,
            &mut reg,
        );
        t.push(
            "obs_trace_overhead_ratio",
            small_on.makespan_s / small_off.makespan_s,
            Better::Lower,
        );

        // --- Autoscaler (ISSUE 8): the pinned SLO sweep's decisions.
        //     `boards_at_slo` pins the fleet the scaler provisions at
        //     the sweep's top (past-saturation) rate; `cost_ratio` pins
        //     the sweep-aggregate elastic-vs-peak-static cost — both
        //     integer-plateaued decisions, so the gates are sized to a
        //     whole board of drift, not measurement noise. ---
        let mut as_cache = RunCache::new();
        let sc = crate::figures::autoscale::sweep_scenario(40);
        let decisions = crate::figures::autoscale::sweep_decisions(&sc, &mut as_cache);
        let peak = crate::figures::autoscale::peak_static_boards(&sc, &mut as_cache)
            .expect("a static fleet within the rack limit holds the pinned SLO");
        let auto_total: f64 = decisions.iter().map(|d| d.price_per_hour).sum();
        let static_total = Fleet::homogeneous(peak, &sc.template).price_per_hour()
            * decisions.len() as f64;
        t.push(
            "autoscale_boards_at_slo",
            decisions.last().expect("non-empty sweep").fleet.num_boards() as f64,
            Better::Lower,
        );
        t.push("autoscale_cost_ratio", auto_total / static_total, Better::Lower);

        // --- Live calibration (ISSUE 9): the pinned convergence
        //     scenario. `convergence_pct` is the learned-vs-offline
        //     share error after the stream (floored away from zero —
        //     perfect convergence would trip the positive-value
        //     invariant, and anything below a millipoint is noise-free
        //     perfection anyway); `warmup_events` is the accepted
        //     observation count at which every learned cell first
        //     crossed the confidence gate. Both deterministic, both
        //     lower-is-better. ---
        let live = crate::figures::live::convergence_summary(true);
        t.push("live_convergence_pct", live.convergence_pct.max(1e-3), Better::Lower);
        t.push(
            "live_warmup_events",
            live.report.warmup_events.expect("pinned live scenario warms up") as f64,
            Better::Lower,
        );

        // --- Task-DAG runtime (ISSUE 10): the pinned blocked-Cholesky
        //     schedule pair and the mixed GEMM+factorization stream.
        //     `cholesky_speedup` is oblivious/CA makespan (> 1 means
        //     criticality-awareness pays); `stream_mixed_p99` is the
        //     tail sojourn of the pinned mixed-job stream through the
        //     unified JobSpec DES. Both pure virtual time. ---
        let (ca, obl) = crate::figures::dag::pinned_cholesky_pair();
        t.push("dag_cholesky_speedup", obl.makespan_s / ca.makespan_s, Better::Higher);
        let mixed = crate::figures::dag::mixed_stream_summary(true);
        t.push("dag_stream_mixed_p99", mixed.sojourn_p99_s, Better::Lower);
        t
    }

    /// Emit the artifact: pretty JSON, one entry per line, stable
    /// order. The format is its own parser's fixture
    /// ([`Trajectory::parse_json`]) and is pinned by a round-trip test.
    pub fn to_json(&self) -> String {
        let mut out =
            String::from("{\n  \"schema\": \"amp-gemm-perf-trajectory-v1\",\n  \"entries\": [\n");
        for (i, e) in self.entries.iter().enumerate() {
            let gate = match e.gate {
                Some(g) => format!(", \"gate\": {g}"),
                None => String::new(),
            };
            out.push_str(&format!(
                "    {{\"key\": \"{}\", \"value\": {}, \"better\": \"{}\"{}}}{}\n",
                e.key,
                e.value,
                e.better.label(),
                gate,
                if i + 1 == self.entries.len() { "" } else { "," }
            ));
        }
        out.push_str("  ]\n}\n");
        out
    }

    /// Parse the artifact format emitted by [`Trajectory::to_json`]
    /// (one entry object per line). Not a general JSON parser — the
    /// baseline is machine-written by this module.
    pub fn parse_json(s: &str) -> Result<Trajectory, String> {
        if !s.contains("amp-gemm-perf-trajectory-v1") {
            return Err("not a perf-trajectory artifact (schema marker missing)".into());
        }
        let field = |line: &str, name: &str| -> Option<String> {
            let tag = format!("\"{name}\":");
            let rest = &line[line.find(&tag)? + tag.len()..];
            let rest = rest.trim_start();
            let quoted = rest.starts_with('"');
            let end = rest
                .char_indices()
                .find(|&(i, ch)| {
                    if quoted {
                        i > 0 && ch == '"'
                    } else {
                        ch == ',' || ch == '}'
                    }
                })
                .map(|(i, _)| i)?;
            Some(rest[..end].trim_start_matches('"').to_string())
        };
        let mut entries = Vec::new();
        for line in s.lines() {
            if !line.contains("\"key\":") {
                continue;
            }
            let key = field(line, "key").ok_or_else(|| format!("bad entry line '{line}'"))?;
            let value: f64 = field(line, "value")
                .ok_or_else(|| format!("entry '{key}' has no value"))?
                .parse()
                .map_err(|_| format!("entry '{key}' has a non-numeric value"))?;
            if !value.is_finite() || value <= 0.0 {
                // A zero baseline would make the relative gate NaN
                // (never firing); a negative one would invert it.
                return Err(format!(
                    "entry '{key}' must have a positive finite value, got {value}"
                ));
            }
            let better = Better::parse(
                &field(line, "better").ok_or_else(|| format!("entry '{key}' has no direction"))?,
            )?;
            let gate = match field(line, "gate") {
                Some(g) => {
                    let g: f64 = g.parse().map_err(|_| format!("entry '{key}' has a bad gate"))?;
                    if !g.is_finite() || g <= 0.0 {
                        return Err(format!("entry '{key}' gate must be positive"));
                    }
                    Some(g)
                }
                None => None,
            };
            entries.push(BenchEntry { key, value, better, gate });
        }
        if entries.is_empty() {
            return Err("perf-trajectory artifact has no entries".into());
        }
        Ok(Trajectory { entries })
    }

    pub fn save(&self, path: &std::path::Path) -> std::io::Result<()> {
        if let Some(dir) = path.parent() {
            std::fs::create_dir_all(dir)?;
        }
        std::fs::write(path, self.to_json())
    }

    pub fn load(path: &std::path::Path) -> Result<Trajectory, String> {
        let text = std::fs::read_to_string(path).map_err(|e| e.to_string())?;
        Trajectory::parse_json(&text)
    }

    pub fn get(&self, key: &str) -> Option<&BenchEntry> {
        self.entries.iter().find(|e| e.key == key)
    }

    /// The regression gate: every baseline metric must exist in the
    /// current run and must not have drifted past its gate (the entry's
    /// own, or `default_gate`) in its worse direction. Improvements
    /// never fail. Returns the list of violations (empty = pass);
    /// current-only metrics are allowed (the suite may grow).
    pub fn gate_against(&self, baseline: &Trajectory, default_gate: f64) -> Vec<String> {
        assert!(default_gate > 0.0 && default_gate.is_finite());
        let mut violations = Vec::new();
        for base in &baseline.entries {
            let gate = base.gate.unwrap_or(default_gate);
            let Some(cur) = self.get(&base.key) else {
                violations.push(format!("metric '{}' disappeared from the suite", base.key));
                continue;
            };
            let rel = (cur.value - base.value) / base.value;
            let regressed = match base.better {
                Better::Higher => rel < -gate,
                Better::Lower => rel > gate,
            };
            if regressed {
                violations.push(format!(
                    "{}: {} vs baseline {} ({:+.1}% exceeds the {:.0}% gate, better = {})",
                    base.key,
                    cur.value,
                    base.value,
                    rel * 100.0,
                    gate * 100.0,
                    base.better.label()
                ));
            }
        }
        violations
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn sample() -> Trajectory {
        Trajectory {
            entries: vec![
                BenchEntry {
                    key: "a_gflops".into(),
                    value: 10.0,
                    better: Better::Higher,
                    gate: None,
                },
                BenchEntry {
                    key: "b_makespan_s".into(),
                    value: 2.5,
                    better: Better::Lower,
                    gate: Some(0.2),
                },
            ],
        }
    }

    #[test]
    fn json_round_trips() {
        let t = sample();
        let back = Trajectory::parse_json(&t.to_json()).unwrap();
        assert_eq!(back, t);
        // And through a file.
        let dir = std::env::temp_dir().join("amp_gemm_trajectory");
        let _ = std::fs::remove_dir_all(&dir);
        let path = dir.join("bench.json");
        t.save(&path).unwrap();
        assert_eq!(Trajectory::load(&path).unwrap(), t);
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn malformed_artifacts_rejected() {
        assert!(Trajectory::parse_json("").is_err());
        assert!(Trajectory::parse_json("{}").is_err(), "schema marker missing");
        assert!(
            Trajectory::parse_json("{\"schema\": \"amp-gemm-perf-trajectory-v1\", \"entries\": []}")
                .is_err(),
            "no entries"
        );
        let bad_value = sample().to_json().replace("10", "ten");
        assert!(Trajectory::parse_json(&bad_value).is_err());
        // Zero or negative values would neuter (or invert) the
        // relative gate — rejected at parse time.
        let zero_value = sample().to_json().replace("\"value\": 10", "\"value\": 0");
        assert!(Trajectory::parse_json(&zero_value).is_err());
        let neg_value = sample().to_json().replace("\"value\": 10", "\"value\": -10");
        assert!(Trajectory::parse_json(&neg_value).is_err());
        let bad_dir = sample().to_json().replace("higher", "sideways");
        assert!(Trajectory::parse_json(&bad_dir).is_err());
        let bad_gate = sample().to_json().replace("\"gate\": 0.2", "\"gate\": -1");
        assert!(Trajectory::parse_json(&bad_gate).is_err());
    }

    /// The gate fires on regressions in the worse direction only, honors
    /// per-entry gates, and flags disappeared metrics — exercised here
    /// so the CI job's failure path is itself tested.
    #[test]
    fn gate_catches_regressions_and_allows_improvements() {
        let base = sample();
        // Identical run: clean.
        assert!(base.gate_against(&base, 0.1).is_empty());
        // Improvements in both directions: clean.
        let mut better = base.clone();
        better.entries[0].value = 12.0; // higher is better
        better.entries[1].value = 2.0; // lower is better
        assert!(better.gate_against(&base, 0.1).is_empty());
        // A >10% drop on the higher-is-better metric fails.
        let mut worse = base.clone();
        worse.entries[0].value = 8.5;
        let v = worse.gate_against(&base, 0.1);
        assert_eq!(v.len(), 1, "{v:?}");
        assert!(v[0].contains("a_gflops"), "{v:?}");
        // The lower-is-better metric honors its own 20% gate: +15% is
        // fine, +25% fails.
        let mut slow = base.clone();
        slow.entries[1].value = 2.5 * 1.15;
        assert!(slow.gate_against(&base, 0.1).is_empty());
        slow.entries[1].value = 2.5 * 1.25;
        assert_eq!(slow.gate_against(&base, 0.1).len(), 1);
        // Disappearing metrics fail; new metrics don't.
        let mut gone = base.clone();
        gone.entries.remove(0);
        assert_eq!(gone.gate_against(&base, 0.1).len(), 1);
        let mut grown = base.clone();
        grown.entries.push(BenchEntry {
            key: "new_metric".into(),
            value: 1.0,
            better: Better::Higher,
            gate: None,
        });
        assert!(grown.gate_against(&base, 0.1).is_empty());
    }

    /// The pinned suite runs, stays deterministic, and the checked-in
    /// seeded baseline passes its own gate — the same comparison the CI
    /// `perf-trajectory` job performs, so tier-1 catches a drifting
    /// model before CI does.
    #[test]
    fn collected_suite_is_deterministic_and_in_baseline_envelope() {
        let a = Trajectory::collect();
        let b = Trajectory::collect();
        assert_eq!(a, b, "virtual-time metrics must be deterministic");
        assert!(a.entries.len() >= 12, "suite shrank: {}", a.entries.len());
        for e in &a.entries {
            assert!(e.value.is_finite() && e.value > 0.0, "{}: {}", e.key, e.value);
        }
        // The repo-root baseline (seeded from the pinned anchor ranges).
        let path = std::path::Path::new(env!("CARGO_MANIFEST_DIR"))
            .join("..")
            .join("BENCH_baseline.json");
        let baseline = Trajectory::load(&path).expect("checked-in BENCH_baseline.json parses");
        let violations = a.gate_against(&baseline, 0.10);
        assert!(violations.is_empty(), "perf trajectory regressed:\n{}", violations.join("\n"));
    }
}
