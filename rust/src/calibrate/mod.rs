//! Empirical calibration layer: measured-rate tables and the
//! [`WeightSource`] selector (DESIGN.md §5).
//!
//! The paper's asymmetric-static schedules hinge on ratios tuned from
//! *measured* per-cluster throughput (§4 of arXiv:1506.08988), and the
//! companion work shows the empirical optimum shifts with the operating
//! point (arXiv:1507.05129). Everywhere else in this codebase the
//! `sched::Weights` vector is derived from the *analytical* model
//! ([`PerfModel::auto_weights`]); this layer turns the empirical search
//! into an alternative — and composable — source of truth:
//!
//! * a [`RateTable`] holds **measured per-cluster GFLOPS rates**, one
//!   row per `(cluster, OPP rung, parameter family)`, each row carrying
//!   three shape-classed rates (small/medium/large `kc`-bound regimes,
//!   [`ShapeClass`]). [`RateTable::measure`] fills it from isolated
//!   per-cluster DES runs — the virtual twin of the paper's wall-clock
//!   per-cluster GEMM measurements, so the rates include packing,
//!   barrier and cache-spill effects the analytical steady-state rate
//!   ignores. The table persists as TSV ([`RateTable::to_text`]) with
//!   an exact round-trip (f64 shortest-repr `Display`, like
//!   `search::OppPresetStore`);
//! * a [`WeightSource`] selects how `sched::Weights` are built:
//!   `Analytical` (the pre-calibration behavior, bit-for-bit),
//!   `Empirical` (straight from a rate table) or `Hybrid` (the
//!   arithmetic blend of the two normalized share vectors). It is
//!   threaded through the intra-SoC SAS/CA-SAS split, the DVFS online
//!   retuner (`dvfs::sim::simulate_dvfs_with` — per-OPP rates, not one
//!   global ratio), fleet-SAS board weights and the capacity planner;
//! * the **analytical-degeneracy anchor**: a table synthesized *from*
//!   the analytical model ([`RateTable::from_analytical`]) reproduces
//!   today's weights bit-for-bit on every preset (pinned by
//!   `tests/calibrate_golden.rs`), so all existing regressions keep
//!   their meaning and `Empirical` differs from `Analytical` only by
//!   what was measured;
//! * [`trajectory`] is the CI perf-trajectory harness: a pinned,
//!   deterministic virtual-time metric suite emitted as
//!   `BENCH_ci.json` and gated against the checked-in
//!   `BENCH_baseline.json`.
//!
//! Measurement protocol (documented caveat): isolated runs execute with
//! no other cluster active, while a joint SAS run pays the symmetric
//! cross-cluster interference factor on every cluster's compute phases.
//! The factor is multiplicative and common to all clusters, so it
//! nearly cancels in the *ratios* the weight vector encodes — the
//! residual bias is second-order (packing time is interference-free),
//! far below the first-order packing/barrier asymmetry the analytical
//! rates miss entirely.

pub mod live;
pub mod trajectory;

use crate::blis::gemm::GemmShape;
use crate::blis::params::BlisParams;
use crate::model::PerfModel;
use crate::sched::{ScheduleSpec, Weights};
use crate::search::OppPresetStore;
use crate::sim;
use crate::soc::{ClusterId, SocSpec};

/// Shape regime of a GEMM relative to the tuned `kc` blocking: the
/// measured rate of a cluster depends on how many full-depth rank-1
/// update panels the problem offers (`eff_k` amortization, partial-tile
/// padding), so the table keys rates by a coarse `k`-vs-`kc` class
/// instead of pretending one number fits every shape.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash, PartialOrd, Ord)]
pub enum ShapeClass {
    /// `k < kc`: a single shallow pc block — overhead-bound.
    Small,
    /// `kc <= k < 4·kc`: a few pc blocks — the common service regime.
    Medium,
    /// `k >= 4·kc`: deep problems — the steady-state asymptote.
    Large,
}

impl ShapeClass {
    pub const ALL: [ShapeClass; 3] = [ShapeClass::Small, ShapeClass::Medium, ShapeClass::Large];

    /// Index into a per-row `[small, medium, large]` rate triple.
    pub fn idx(self) -> usize {
        match self {
            ShapeClass::Small => 0,
            ShapeClass::Medium => 1,
            ShapeClass::Large => 2,
        }
    }

    pub fn label(self) -> &'static str {
        match self {
            ShapeClass::Small => "small",
            ShapeClass::Medium => "medium",
            ShapeClass::Large => "large",
        }
    }

    /// Inverse of [`ShapeClass::label`] — the persisted-row vocabulary
    /// of the live table ([`live::LiveRateTable::parse_text`]).
    pub fn parse(s: &str) -> Result<ShapeClass, String> {
        ShapeClass::ALL
            .into_iter()
            .find(|c| c.label() == s)
            .ok_or_else(|| format!("bad shape class '{s}' (small|medium|large)"))
    }

    /// Classify a shape against a reference `kc` (the lead cluster's
    /// tuned depth).
    pub fn of(shape: GemmShape, kc_ref: usize) -> ShapeClass {
        let kc = kc_ref.max(1);
        if shape.k < kc {
            ShapeClass::Small
        } else if shape.k < 4 * kc {
            ShapeClass::Medium
        } else {
            ShapeClass::Large
        }
    }

    /// Classify a shape on a topology: the reference depth is the lead
    /// cluster's tuned `kc` (every preset's oblivious configuration
    /// runs it everywhere, §4).
    pub fn for_soc(soc: &SocSpec, shape: GemmShape) -> ShapeClass {
        ShapeClass::of(shape, soc[soc.lead()].tuned.kc)
    }

    /// Representative square measurement shape of this class for a
    /// reference `kc`: squarely inside the class bounds, *floored* to a
    /// multiple of 8 so every fine-grain split is tidy without ever
    /// rounding up past a class boundary. Stays inside its class for
    /// any `kc_ref >= 16` (the generic measurement path's supported
    /// range; [`RateTable::measure_with_reps`] asserts membership).
    pub fn rep_shape(self, kc_ref: usize) -> GemmShape {
        let kc = kc_ref.max(16);
        let round8 = |x: usize| (x / 8).max(1) * 8;
        let r = match self {
            ShapeClass::Small => round8(kc / 2),
            ShapeClass::Medium => round8(2 * kc),
            ShapeClass::Large => round8(4 * kc + kc / 2),
        };
        GemmShape::square(r)
    }
}

/// The generic measurement triple: one [`ShapeClass::rep_shape`] per
/// class for a reference `kc` — what [`RateTable::measure`] and
/// [`OppPresetStore::tune_measured`] run when no workload shapes are
/// supplied.
pub fn default_reps(kc_ref: usize) -> [GemmShape; 3] {
    [
        ShapeClass::Small.rep_shape(kc_ref),
        ShapeClass::Medium.rep_shape(kc_ref),
        ShapeClass::Large.rep_shape(kc_ref),
    ]
}

/// The evaluation suite's canonical square sizes, one per shape class
/// for the paper presets (lead `kc = 952`): the sizes the figure
/// harness measures and asserts at, shared by `figures::calibrate` and
/// `amp-gemm calibrate` so the persisted table and the report can
/// never drift apart.
pub fn canonical_reps() -> [GemmShape; 3] {
    [
        GemmShape::square(512),
        GemmShape::square(2048),
        GemmShape::square(4096),
    ]
}

/// Which blocking-parameter family a measured rate belongs to — the two
/// configurations the schedulers actually run (§4 vs §5.3).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash, PartialOrd, Ord)]
pub enum Family {
    /// Every cluster on its own tuned optimum (CA-SAS/CA-DAS).
    CacheAware,
    /// Every cluster on the boot-lead cluster's parameters (SSS/SAS/DAS;
    /// the lead is fixed at the *nominal* descriptor so a rung change
    /// can never silently swap whose parameters "oblivious" means).
    Oblivious,
}

impl Family {
    pub const ALL: [Family; 2] = [Family::CacheAware, Family::Oblivious];

    pub fn of(cache_aware: bool) -> Family {
        if cache_aware {
            Family::CacheAware
        } else {
            Family::Oblivious
        }
    }

    pub fn label(self) -> &'static str {
        match self {
            Family::CacheAware => "ca",
            Family::Oblivious => "obl",
        }
    }

    pub fn parse(s: &str) -> Result<Family, String> {
        match s {
            "ca" => Ok(Family::CacheAware),
            "obl" => Ok(Family::Oblivious),
            other => Err(format!("bad family '{other}' (ca|obl)")),
        }
    }
}

/// One calibrated row: a cluster's aggregate GFLOPS at one OPP rung
/// under one parameter family, shape-classed.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct RateRow {
    pub cluster: ClusterId,
    /// Ladder rung index the rates were taken at.
    pub opp: usize,
    pub freq_ghz: f64,
    pub family: Family,
    /// Cluster-aggregate GFLOPS per shape class, indexed by
    /// [`ShapeClass::idx`] (`[small, medium, large]`).
    pub rates: [f64; 3],
}

/// Calibrated per-cluster rate table of one SoC: the persisted product
/// of the empirical search, and the thing a [`WeightSource::Empirical`]
/// reads per-OPP rates from. Line-oriented TSV with an exact text
/// round-trip:
///
/// ```text
/// # <soc name>\t<num clusters>
/// <cluster>\t<opp>\t<freq>\t<family>\t<r_small>\t<r_medium>\t<r_large>
/// ```
#[derive(Debug, Clone, PartialEq)]
pub struct RateTable {
    pub soc: String,
    pub num_clusters: usize,
    pub rows: Vec<RateRow>,
}

impl RateTable {
    /// Measure the table from isolated per-cluster DES runs: for every
    /// cluster, every ladder rung and both parameter families, run the
    /// class-representative shapes through `sim::simulate` on a
    /// descriptor derived at that rung (cluster-only schedule). The
    /// cache-aware family runs the rung's own *searched* optimum when
    /// `presets` carries one (`OppPresetStore` rows from
    /// [`OppPresetStore::tune_measured`] / `search::tune_opp_ladder`),
    /// falling back to the descriptor's tuned parameters; the oblivious
    /// family always runs the boot lead's tuned parameters.
    pub fn measure(soc: &SocSpec, presets: &[OppPresetStore]) -> RateTable {
        let reps = default_reps(soc[soc.lead()].tuned.kc);
        RateTable::measure_with_reps(soc, presets, &reps)
    }

    /// [`RateTable::measure`] with explicit per-class measurement
    /// shapes (one per [`ShapeClass`], validated against the classes).
    /// Use this when the workload's shapes are known: a cluster's rate
    /// depends on the `k mod kc` remainder structure (shallow trailing
    /// pc blocks amortize poorly), so calibrating on the *actual
    /// service shapes* captures the remainder penalty the generic
    /// class representatives can only approximate.
    pub fn measure_with_reps(
        soc: &SocSpec,
        presets: &[OppPresetStore],
        reps: &[GemmShape; 3],
    ) -> RateTable {
        let kc_ref = soc[soc.lead()].tuned.kc;
        for (rep, class) in reps.iter().zip(ShapeClass::ALL) {
            assert_eq!(
                ShapeClass::of(*rep, kc_ref),
                class,
                "measurement shape {rep:?} is not in class {}",
                class.label()
            );
        }
        let lead_params = soc[soc.lead()].tuned;
        let mut rows = Vec::new();
        for c in soc.cluster_ids() {
            let store = presets.iter().find(|s| s.cluster == c);
            for opp in 0..soc[c].opps.len() {
                let at = soc.at_opp(c, opp);
                let freq_ghz = at[c].core.freq_ghz;
                for family in Family::ALL {
                    let params = match family {
                        Family::CacheAware => store
                            .and_then(|s| s.at(opp))
                            .map(|p| {
                                let t = at[c].tuned;
                                BlisParams::new(t.nc, p.kc, p.mc, t.nr, t.mr)
                            })
                            .unwrap_or(at[c].tuned),
                        Family::Oblivious => lead_params,
                    };
                    rows.push(RateRow {
                        cluster: c,
                        opp,
                        freq_ghz,
                        family,
                        rates: measure_cluster(&at, c, params, reps),
                    });
                }
            }
        }
        RateTable {
            soc: soc.name.clone(),
            num_clusters: soc.num_clusters(),
            rows,
        }
    }

    /// Synthesize a table *from* the analytical model: every rate is
    /// exactly `PerfModel::cluster_rate_gflops` at that rung (identical
    /// across shape classes — the steady-state rate is shape-free).
    /// This is the degeneracy anchor: `WeightSource::Empirical` over
    /// this table reproduces `PerfModel::auto_weights` bit for bit,
    /// because a cluster's analytical rate depends only on its own
    /// descriptor (frequency, tuning, cache geometry) — never on the
    /// other clusters' rungs.
    pub fn from_analytical(soc: &SocSpec) -> RateTable {
        let lead_params = soc[soc.lead()].tuned;
        let mut rows = Vec::new();
        for c in soc.cluster_ids() {
            for opp in 0..soc[c].opps.len() {
                let model = PerfModel::new(soc.at_opp(c, opp));
                let freq_ghz = model.soc[c].core.freq_ghz;
                for family in Family::ALL {
                    let params = match family {
                        Family::CacheAware => model.soc[c].tuned,
                        Family::Oblivious => lead_params,
                    };
                    let r = model.cluster_rate_gflops(c, &params, model.soc[c].num_cores);
                    rows.push(RateRow {
                        cluster: c,
                        opp,
                        freq_ghz,
                        family,
                        rates: [r, r, r],
                    });
                }
            }
        }
        RateTable {
            soc: soc.name.clone(),
            num_clusters: soc.num_clusters(),
            rows,
        }
    }

    /// The measured rate of one `(cluster, rung, family, class)` cell.
    pub fn rate(
        &self,
        cluster: ClusterId,
        opp: usize,
        family: Family,
        class: ShapeClass,
    ) -> Option<f64> {
        self.rows
            .iter()
            .find(|r| r.cluster == cluster && r.opp == opp && r.family == family)
            .map(|r| r.rates[class.idx()])
    }

    /// Per-cluster rates at an OPP vector (one rung per cluster, in
    /// [`ClusterId`] order) — the raw ingredients of the empirical
    /// weighted-static split.
    pub fn cluster_rates(
        &self,
        opps: &[usize],
        family: Family,
        class: ShapeClass,
    ) -> Result<Vec<f64>, String> {
        if opps.len() != self.num_clusters {
            return Err(format!(
                "OPP vector has {} entries but the table covers {} clusters",
                opps.len(),
                self.num_clusters
            ));
        }
        opps.iter()
            .enumerate()
            .map(|(i, &opp)| {
                self.rate(ClusterId(i), opp, family, class).ok_or_else(|| {
                    format!(
                        "rate table '{}' has no row for c{i} rung {opp} family {}",
                        self.soc,
                        family.label()
                    )
                })
            })
            .collect()
    }

    /// The empirical weight vector at an OPP vector: per-cluster
    /// measured rates straight into [`Weights`] — exactly how
    /// `PerfModel::auto_weights` builds the analytical vector.
    pub fn weights_at(
        &self,
        opps: &[usize],
        family: Family,
        class: ShapeClass,
    ) -> Result<Weights, String> {
        Ok(Weights::from_slice(&self.cluster_rates(opps, family, class)?))
    }

    /// Aggregate measured throughput of the whole SoC at an OPP vector
    /// (the board-level weight of the fleet layer).
    pub fn board_rate(
        &self,
        opps: &[usize],
        family: Family,
        class: ShapeClass,
    ) -> Result<f64, String> {
        Ok(self.cluster_rates(opps, family, class)?.iter().sum())
    }

    pub fn to_text(&self) -> String {
        let mut out = format!("# {}\t{}\n", self.soc, self.num_clusters);
        for r in &self.rows {
            out.push_str(&format!(
                "{}\t{}\t{}\t{}\t{}\t{}\t{}\n",
                r.cluster.0,
                r.opp,
                r.freq_ghz,
                r.family.label(),
                r.rates[0],
                r.rates[1],
                r.rates[2]
            ));
        }
        out
    }

    pub fn parse_text(s: &str) -> Result<RateTable, String> {
        let mut lines = s.lines();
        let header = lines.next().ok_or("empty rate table")?;
        let header = header
            .strip_prefix("# ")
            .ok_or_else(|| format!("bad header '{header}'"))?;
        let (soc, n) = header
            .rsplit_once('\t')
            .ok_or_else(|| format!("bad header '{header}'"))?;
        let num_clusters: usize = n
            .parse()
            .map_err(|_| format!("bad cluster count '{n}'"))?;
        if num_clusters == 0 {
            return Err("rate table needs at least one cluster".into());
        }
        // Shared with `search::OppPresetStore::parse_text`: persisted
        // physical quantities are positive and finite or the row is
        // corrupt.
        let parse_rate = |s: &str| crate::util::parse_positive_f64(s, "rate");
        let mut rows = Vec::new();
        for line in lines {
            if line.is_empty() {
                continue;
            }
            let f: Vec<&str> = line.split('\t').collect();
            if f.len() != 7 {
                return Err(format!("bad rate row '{line}'"));
            }
            let cluster: usize = f[0].parse().map_err(|_| format!("bad cluster '{}'", f[0]))?;
            if cluster >= num_clusters {
                return Err(format!(
                    "row names cluster {cluster} but the header declares {num_clusters}"
                ));
            }
            rows.push(RateRow {
                cluster: ClusterId(cluster),
                opp: f[1].parse().map_err(|_| format!("bad opp '{}'", f[1]))?,
                freq_ghz: crate::util::parse_positive_f64(f[2], "freq")?,
                family: Family::parse(f[3])?,
                rates: [parse_rate(f[4])?, parse_rate(f[5])?, parse_rate(f[6])?],
            });
        }
        Ok(RateTable {
            soc: soc.to_string(),
            num_clusters,
            rows,
        })
    }

    pub fn save(&self, path: &std::path::Path) -> std::io::Result<()> {
        if let Some(dir) = path.parent() {
            std::fs::create_dir_all(dir)?;
        }
        std::fs::write(path, self.to_text())
    }

    pub fn load(path: &std::path::Path) -> Result<RateTable, String> {
        let text = std::fs::read_to_string(path).map_err(|e| e.to_string())?;
        RateTable::parse_text(&text)
    }
}

/// Measure one cluster's aggregate DES rate (GFLOPS) under `params` at
/// the descriptor's current operating point, once per measurement
/// shape. The cluster runs alone (`ClusterOnly`), every core active —
/// the §3.4 isolated-cluster protocol the paper tunes its ratios from.
fn measure_cluster(
    soc: &SocSpec,
    cluster: ClusterId,
    params: BlisParams,
    reps: &[GemmShape; 3],
) -> [f64; 3] {
    let mut probe = soc.clone();
    probe.clusters[cluster.0].tuned = params;
    let model = PerfModel::new(probe);
    let spec = ScheduleSpec::cluster_only(cluster, soc[cluster].num_cores);
    let mut rates = [0.0; 3];
    for class in ShapeClass::ALL {
        let st = sim::simulate(&model, &spec, reps[class.idx()]);
        rates[class.idx()] = st.gflops;
    }
    rates
}

/// The per-cluster ladders' current rungs of a descriptor, in cluster
/// order — the OPP vector a freshly built preset sits at (nominal), or
/// whatever rung an `@governor` pin / `at_opp` derivation moved it to.
pub fn current_opps(soc: &SocSpec) -> Vec<usize> {
    soc.clusters.iter().map(|c| c.opps.current_idx()).collect()
}

/// Where scheduling weights come from: the selector threaded through
/// `sched::Weights` construction across the stack (intra-SoC SAS/CA-SAS
/// splits, the DVFS online retuner, fleet-SAS board weights, capacity
/// planning).
#[derive(Debug, Clone, PartialEq)]
pub enum WeightSource {
    /// The analytical model (`PerfModel::auto_weights`) — the
    /// pre-calibration behavior, bit for bit.
    Analytical,
    /// Measured rates from a [`RateTable`] (per-OPP, shape-classed).
    Empirical(RateTable),
    /// The arithmetic mean of the analytical and empirical *normalized*
    /// share vectors: trust the measurement but hedge against a stale
    /// table.
    Hybrid(RateTable),
    /// Rates learned online from the serving path itself
    /// ([`live::LiveRateTable`], ISSUE 9): per cell, the learned rate
    /// once its sample count reaches `min_samples`, the analytical
    /// model until then — so a cold table is exactly `Analytical`, bit
    /// for bit, and warms cell by cell as completions arrive.
    Live {
        table: live::LiveRateTable,
        /// Per-cell confidence threshold (accepted observations).
        min_samples: u64,
    },
}

impl WeightSource {
    pub fn label(&self) -> &'static str {
        match self {
            WeightSource::Analytical => "analytical",
            WeightSource::Empirical(_) => "empirical",
            WeightSource::Hybrid(_) => "hybrid",
            WeightSource::Live { .. } => "live",
        }
    }

    /// Parse a CLI token into a source; `empirical`/`hybrid` need a
    /// table (measured by the caller).
    pub fn from_token(
        token: &str,
        table: impl FnOnce() -> RateTable,
    ) -> Result<WeightSource, String> {
        match token {
            "analytical" => Ok(WeightSource::Analytical),
            "empirical" => Ok(WeightSource::Empirical(table())),
            "hybrid" => Ok(WeightSource::Hybrid(table())),
            other => Err(format!(
                "unknown weight source '{other}' (analytical|empirical|hybrid)"
            )),
        }
    }

    /// The offline rate table behind this source, if any (`Live`
    /// carries a [`live::LiveRateTable`] instead — freeze one with
    /// [`live::LiveRateTable::snapshot`] to get a `RateTable`).
    pub fn table(&self) -> Option<&RateTable> {
        match self {
            WeightSource::Analytical | WeightSource::Live { .. } => None,
            WeightSource::Empirical(t) | WeightSource::Hybrid(t) => Some(t),
        }
    }

    /// Weight vector for a model already derived at the descriptor the
    /// schedule runs on, with `opps` naming the rung each cluster's
    /// ladder currently sits at (the table key; the analytical path
    /// ignores it and reads the descriptor directly). Panics if an
    /// empirical table is missing the requested cells — a calibration
    /// table that does not cover the topology is a configuration bug,
    /// not a runtime condition to paper over.
    pub fn weights_for(
        &self,
        model: &PerfModel,
        opps: &[usize],
        cache_aware: bool,
        class: ShapeClass,
    ) -> Weights {
        match self {
            WeightSource::Analytical => model.auto_weights(cache_aware),
            WeightSource::Empirical(t) => t
                .weights_at(opps, Family::of(cache_aware), class)
                .expect("empirical rate table does not cover this topology"),
            WeightSource::Hybrid(t) => {
                let emp = t
                    .weights_at(opps, Family::of(cache_aware), class)
                    .expect("hybrid rate table does not cover this topology");
                model
                    .auto_weights(cache_aware)
                    .normalized()
                    .blend(&emp.normalized(), 0.5)
            }
            WeightSource::Live { table, min_samples } => Weights::from_slice(
                &table.cluster_rates_or_analytical(model, opps, cache_aware, class, *min_samples),
            ),
        }
    }

    /// Weight vector at the descriptor's *current* rungs (nominal for
    /// fresh presets, the pinned rung for `@governor` boards).
    pub fn weights(&self, model: &PerfModel, cache_aware: bool, class: ShapeClass) -> Weights {
        self.weights_for(model, &current_opps(&model.soc), cache_aware, class)
    }

    /// Aggregate throughput of the whole SoC at its current rungs —
    /// the board weight of the fleet layer (absolute GFLOPS, so
    /// heterogeneous boards compare; `Hybrid` averages the two
    /// absolute aggregates).
    pub fn board_throughput(&self, model: &PerfModel, class: ShapeClass) -> f64 {
        let analytical = || -> f64 { model.ca_sas_weights().as_slice().iter().sum() };
        let empirical = |t: &RateTable| -> f64 {
            t.board_rate(&current_opps(&model.soc), Family::CacheAware, class)
                .expect("rate table does not cover this topology")
        };
        match self {
            WeightSource::Analytical => analytical(),
            WeightSource::Empirical(t) => empirical(t),
            WeightSource::Hybrid(t) => 0.5 * (analytical() + empirical(t)),
            WeightSource::Live { table, min_samples } => table
                .cluster_rates_or_analytical(
                    model,
                    &current_opps(&model.soc),
                    true,
                    class,
                    *min_samples,
                )
                .iter()
                .sum(),
        }
    }
}

/// SAS schedule with weights from a source (the oblivious family).
pub fn sas_spec(source: &WeightSource, model: &PerfModel, class: ShapeClass) -> ScheduleSpec {
    ScheduleSpec::sas_weighted(source.weights(model, false, class))
}

/// CA-SAS schedule with weights from a source (the cache-aware family).
pub fn ca_sas_spec(source: &WeightSource, model: &PerfModel, class: ShapeClass) -> ScheduleSpec {
    ScheduleSpec::ca_sas_weighted(source.weights(model, true, class))
}

// The measurement-aware extension of the per-OPP preset store lives
// here (same crate, different module) so `search` stays independent of
// the calibration layer.
impl OppPresetStore {
    /// [`OppPresetStore::tune`] plus measured rates: every rung's
    /// searched `(mc, kc)` optimum is executed through the DES on the
    /// at-rung descriptor (cluster-only, all cores) and the three
    /// shape-classed aggregate GFLOPS are recorded alongside the
    /// analytical search score.
    pub fn tune_measured(soc: &SocSpec, cluster: ClusterId) -> OppPresetStore {
        let reps = default_reps(soc[soc.lead()].tuned.kc);
        let mut store = OppPresetStore::tune(soc, cluster);
        for p in &mut store.presets {
            let at = soc.at_opp(cluster, p.opp);
            let t = at[cluster].tuned;
            let params = BlisParams::new(t.nc, p.kc, p.mc, t.nr, t.mr);
            p.measured = Some(measure_cluster(&at, cluster, params, &reps));
        }
        store
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::soc::{BIG, LITTLE};

    fn soc() -> SocSpec {
        SocSpec::exynos5422()
    }

    #[test]
    fn shape_classes_partition_k() {
        let kc = 952;
        assert_eq!(ShapeClass::of(GemmShape::square(512), kc), ShapeClass::Small);
        assert_eq!(ShapeClass::of(GemmShape::square(2048), kc), ShapeClass::Medium);
        assert_eq!(ShapeClass::of(GemmShape::square(4096), kc), ShapeClass::Large);
        assert_eq!(ShapeClass::for_soc(&soc(), GemmShape::square(4096)), ShapeClass::Large);
        // Representative shapes land inside their own class.
        for class in ShapeClass::ALL {
            let rep = class.rep_shape(kc);
            assert_eq!(ShapeClass::of(rep, kc), class, "{} rep {rep:?}", class.label());
            assert_eq!(rep.m % 8, 0);
        }
    }

    /// The tentpole's degeneracy anchor, module-level: a table
    /// synthesized from the analytical model reproduces
    /// `PerfModel::auto_weights` bit for bit (full preset sweep in
    /// `tests/calibrate_golden.rs`).
    #[test]
    fn analytical_synthesis_degenerates_bit_for_bit() {
        let s = soc();
        let model = PerfModel::new(s.clone());
        let table = RateTable::from_analytical(&s);
        for cache_aware in [true, false] {
            for class in ShapeClass::ALL {
                let emp = WeightSource::Empirical(table.clone());
                assert_eq!(
                    emp.weights(&model, cache_aware, class),
                    model.auto_weights(cache_aware),
                    "ca={cache_aware} class={}",
                    class.label()
                );
            }
        }
        // And the hybrid of two identical share vectors is that vector
        // (up to the blend arithmetic's rounding).
        let hyb = WeightSource::Hybrid(table).weights(&model, true, ShapeClass::Large);
        let ana = model.auto_weights(true).normalized();
        for (h, a) in hyb.as_slice().iter().zip(ana.as_slice()) {
            assert!((h - a).abs() < 1e-15, "{h} vs {a}");
        }
    }

    #[test]
    fn measured_rates_are_sane_and_below_analytical() {
        let s = soc();
        let table = RateTable::measure(&s, &[]);
        // 2 clusters × 5 rungs × 2 families.
        assert_eq!(table.rows.len(), 20);
        let model = PerfModel::new(s.clone());
        for c in s.cluster_ids() {
            let nominal = s[c].opps.nominal_idx();
            let ana = model.cluster_rate_gflops(c, &s[c].tuned, s[c].num_cores);
            let meas = table
                .rate(c, nominal, Family::CacheAware, ShapeClass::Large)
                .unwrap();
            // The DES pays packing + barriers the steady-state rate
            // ignores; the measured rate sits below but near it.
            assert!(meas < ana, "{c}: measured {meas} vs analytical {ana}");
            assert!(meas > 0.75 * ana, "{c}: measured {meas} vs analytical {ana}");
            // Rates grow with the clock along the ladder.
            for opp in 1..s[c].opps.len() {
                let lo = table.rate(c, opp - 1, Family::CacheAware, ShapeClass::Large).unwrap();
                let hi = table.rate(c, opp, Family::CacheAware, ShapeClass::Large).unwrap();
                assert!(hi > lo, "{c} rung {opp}: {hi} vs {lo}");
            }
        }
        // Oblivious parameters hurt the LITTLE cluster, as in §4.
        let nominal = s[LITTLE].opps.nominal_idx();
        let own = table.rate(LITTLE, nominal, Family::CacheAware, ShapeClass::Large).unwrap();
        let obl = table.rate(LITTLE, nominal, Family::Oblivious, ShapeClass::Large).unwrap();
        assert!(obl < own, "oblivious {obl} vs own {own}");
        // On the lead cluster the two families coincide.
        let b_ca = table.rate(BIG, nominal, Family::CacheAware, ShapeClass::Large).unwrap();
        let b_obl = table.rate(BIG, nominal, Family::Oblivious, ShapeClass::Large).unwrap();
        assert_eq!(b_ca, b_obl, "lead cluster runs its own params either way");
    }

    #[test]
    fn text_round_trip_is_exact() {
        let s = soc();
        for table in [RateTable::from_analytical(&s), RateTable::measure(&s, &[])] {
            let back = RateTable::parse_text(&table.to_text()).unwrap();
            assert_eq!(back, table);
        }
        let dir = std::env::temp_dir().join("amp_gemm_rate_table");
        let _ = std::fs::remove_dir_all(&dir);
        let path = dir.join("exynos.tsv");
        let table = RateTable::from_analytical(&s);
        table.save(&path).unwrap();
        assert_eq!(RateTable::load(&path).unwrap(), table);
        let _ = std::fs::remove_dir_all(&dir);
        assert!(RateTable::load(std::path::Path::new("/nonexistent/x")).is_err());
    }

    #[test]
    fn malformed_tables_rejected() {
        assert!(RateTable::parse_text("").is_err());
        assert!(RateTable::parse_text("junk\n").is_err());
        assert!(RateTable::parse_text("# soc\t0\n").is_err(), "zero clusters");
        assert!(RateTable::parse_text("# soc\tx\n").is_err());
        // Row arity, family, cluster range, non-finite and non-positive
        // rates all error cleanly.
        let ok = "# soc\t2\n0\t0\t1.6\tca\t1\t2\t3\n";
        assert!(RateTable::parse_text(ok).is_ok());
        assert!(RateTable::parse_text("# soc\t2\n0\t0\t1.6\tca\t1\t2\n").is_err());
        assert!(RateTable::parse_text("# soc\t2\n0\t0\t1.6\twarp\t1\t2\t3\n").is_err());
        assert!(RateTable::parse_text("# soc\t2\n7\t0\t1.6\tca\t1\t2\t3\n").is_err());
        assert!(RateTable::parse_text("# soc\t2\n0\t0\t1.6\tca\tNaN\t2\t3\n").is_err());
        assert!(RateTable::parse_text("# soc\t2\n0\t0\t1.6\tca\tinf\t2\t3\n").is_err());
        assert!(RateTable::parse_text("# soc\t2\n0\t0\t1.6\tca\t-1\t2\t3\n").is_err());
        assert!(RateTable::parse_text("# soc\t2\n0\t0\t0\tca\t1\t2\t3\n").is_err(), "zero freq");
    }

    #[test]
    fn missing_cells_surface_as_errors() {
        let s = soc();
        let table = RateTable::from_analytical(&s);
        assert!(table.rate(BIG, 99, Family::CacheAware, ShapeClass::Large).is_none());
        assert!(table.weights_at(&[0], Family::CacheAware, ShapeClass::Large).is_err());
        assert!(table
            .weights_at(&[0, 99], Family::CacheAware, ShapeClass::Large)
            .is_err());
        assert!(table.board_rate(&[4, 4], Family::CacheAware, ShapeClass::Large).is_ok());
    }

    #[test]
    fn empirical_weights_shift_toward_the_measured_ratio() {
        let s = soc();
        let model = PerfModel::new(s.clone());
        let table = RateTable::measure(&s, &[]);
        let ana = model.ca_sas_weights().normalized();
        let emp = WeightSource::Empirical(table.clone())
            .weights(&model, true, ShapeClass::Large)
            .normalized();
        // The measured big:LITTLE ratio differs from the analytical one
        // (packing/barrier asymmetry), so the shares move.
        let delta = (emp.share(0) - ana.share(0)).abs();
        assert!(delta > 1e-4, "empirical weights must differ: delta {delta}");
        // The hybrid lands between the two.
        let hyb = WeightSource::Hybrid(table).weights(&model, true, ShapeClass::Large);
        let (lo, hi) = (
            ana.share(0).min(emp.share(0)),
            ana.share(0).max(emp.share(0)),
        );
        assert!(
            (lo..=hi).contains(&hyb.share(0)),
            "hybrid {} outside [{lo}, {hi}]",
            hyb.share(0)
        );
    }

    /// Calibration can target the workload's own shapes: the measured
    /// rate moves with the `k mod kc` remainder structure (a rep whose
    /// trailing pc block is shallow amortizes worse), and reps outside
    /// their class are rejected.
    #[test]
    fn measure_with_reps_targets_the_workload() {
        let s = soc();
        // k = 1904 = 2·952 exactly (no remainder) vs k = 2048 (a
        // 144-deep trailing block on the big cluster): the big
        // cluster's measured medium-class rate must drop.
        let clean = RateTable::measure_with_reps(
            &s,
            &[],
            &[GemmShape::square(512), GemmShape::square(1904), GemmShape::square(4096)],
        );
        let ragged = RateTable::measure_with_reps(
            &s,
            &[],
            &[GemmShape::square(512), GemmShape::square(2048), GemmShape::square(4096)],
        );
        let nominal = s[BIG].opps.nominal_idx();
        let r_clean = clean.rate(BIG, nominal, Family::CacheAware, ShapeClass::Medium).unwrap();
        let r_ragged = ragged.rate(BIG, nominal, Family::CacheAware, ShapeClass::Medium).unwrap();
        assert!(
            r_ragged < r_clean,
            "k-remainder must cost rate: ragged {r_ragged} vs clean {r_clean}"
        );
    }

    #[test]
    #[should_panic(expected = "not in class")]
    fn measure_with_reps_rejects_misclassed_shapes() {
        let s = soc();
        // 2048 is medium-class for kc = 952, not small.
        RateTable::measure_with_reps(
            &s,
            &[],
            &[GemmShape::square(2048), GemmShape::square(2048), GemmShape::square(4096)],
        );
    }

    #[test]
    fn tune_measured_fills_preset_rates() {
        let s = soc();
        let store = OppPresetStore::tune_measured(&s, LITTLE);
        assert_eq!(store.presets.len(), 5);
        for p in &store.presets {
            let m = p.measured.expect("measured rates present");
            assert!(m.iter().all(|r| r.is_finite() && *r > 0.0), "{m:?}");
            // Deep problems amortize overhead best.
            assert!(m[2] > m[0], "large {} vs small {}", m[2], m[0]);
        }
        // The measured store round-trips through the extended TSV.
        let back = OppPresetStore::parse_text(&store.to_text()).unwrap();
        assert_eq!(back, store);
    }

    #[test]
    fn current_opps_track_derivation() {
        let s = soc();
        assert_eq!(current_opps(&s), vec![4, 4]);
        assert_eq!(current_opps(&s.at_opp(BIG, 1)), vec![1, 4]);
    }

    #[test]
    fn source_tokens_parse() {
        let t = || RateTable::from_analytical(&soc());
        assert_eq!(WeightSource::from_token("analytical", t).unwrap().label(), "analytical");
        let t = || RateTable::from_analytical(&soc());
        assert_eq!(WeightSource::from_token("empirical", t).unwrap().label(), "empirical");
        let t = || RateTable::from_analytical(&soc());
        assert_eq!(WeightSource::from_token("hybrid", t).unwrap().label(), "hybrid");
        let t = || RateTable::from_analytical(&soc());
        assert!(WeightSource::from_token("warp", t).is_err());
        assert!(WeightSource::Analytical.table().is_none());
    }
}
