//! Online calibration while serving (ISSUE 9): learn per-cluster
//! service rates from the chunks the fleet is *already* executing,
//! instead of (or on top of) the offline §3.4 probe protocol of
//! [`RateTable::measure`].
//!
//! A [`LiveRateTable`] accumulates an exponentially-weighted moving
//! average of observed rates per `(cluster, rung, family, ShapeClass)`
//! cell. Every completed chunk reports `(flops, service_s)` for the
//! cluster that ran it; the observation is the aggregate GFLOPS that
//! completion implies. Cells carry sample counts, and a consumer-chosen
//! confidence threshold (`min_samples`) gates when a cell's learned
//! rate replaces the analytical fallback — so a cold table behaves
//! exactly like [`WeightSource::Analytical`], bit for bit, and warms
//! cell by cell.
//!
//! Determinism contract: the table is a pure fold over the observation
//! sequence (no wall clock, no randomness — the decay is per *event*,
//! `0.5^(1/half_life_events)`), so a replay that feeds the same
//! completions in the same order reproduces the same table, and a
//! frozen [`LiveRateTable::snapshot`] replays bit for bit through the
//! ordinary [`WeightSource::Empirical`] path (DESIGN.md §5, "Live
//! calibration").

use std::collections::BTreeMap;

use crate::blis::gemm::GemmShape;
use crate::calibrate::{Family, RateTable, ShapeClass, WeightSource};
use crate::model::PerfModel;
use crate::obs::MetricsRegistry;
use crate::soc::{ClusterId, SocSpec};

/// One learned cell: the EWMA numerator/denominator pair plus how many
/// accepted observations fed it. `rate = num / den`; `den` is the decayed
/// event mass, so a cell observed once reports exactly that observation.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct LiveCell {
    num: f64,
    den: f64,
    samples: u64,
}

impl LiveCell {
    pub fn rate(&self) -> f64 {
        self.num / self.den
    }

    pub fn samples(&self) -> u64 {
        self.samples
    }
}

/// Cell key in deterministic iteration order: `(cluster, rung, family,
/// class)` — the same coordinates a [`RateTable`] row is addressed by.
pub type LiveKey = (usize, usize, Family, ShapeClass);

/// Exponentially-weighted per-cell observed service rates, learned from
/// completions on the serving path (see module docs).
#[derive(Debug, Clone, PartialEq)]
pub struct LiveRateTable {
    /// Descriptor name the observations came from (labeling only).
    pub soc: String,
    pub num_clusters: usize,
    /// The boot lead cluster's tuned `kc` the table classifies shapes
    /// against — pinned at construction so live observations and
    /// offline [`RateTable::measure`] rows can never class the same
    /// shape differently (the ISSUE 9 boundary-audit satellite).
    pub kc_ref: usize,
    /// EWMA half-life in *events*: after this many accepted
    /// observations an old observation's weight has halved.
    pub half_life_events: f64,
    accepted: u64,
    rejected: u64,
    cells: BTreeMap<LiveKey, LiveCell>,
}

impl LiveRateTable {
    /// An empty table for a descriptor. Panics on a non-finite or
    /// non-positive half-life — a decay factor outside `(0, 1)` would
    /// let one observation dominate forever or diverge the EWMA.
    pub fn new(soc: &SocSpec, half_life_events: f64) -> LiveRateTable {
        assert!(
            half_life_events.is_finite() && half_life_events > 0.0,
            "EWMA half-life must be positive and finite, got {half_life_events}"
        );
        LiveRateTable {
            soc: soc.name.clone(),
            num_clusters: soc.num_clusters(),
            kc_ref: soc[soc.lead()].tuned.kc,
            half_life_events,
            accepted: 0,
            rejected: 0,
            cells: BTreeMap::new(),
        }
    }

    /// Per-event decay factor, strictly inside `(0, 1)`.
    fn decay(&self) -> f64 {
        0.5f64.powf(1.0 / self.half_life_events)
    }

    /// Classify a shape against the table's pinned reference depth —
    /// the *same* `ShapeClass::of` call the offline measurement path
    /// makes, so a `k == kc` shape lands in the same class either way.
    pub fn classify(&self, shape: GemmShape) -> ShapeClass {
        ShapeClass::of(shape, self.kc_ref)
    }

    /// Feed one completed chunk: `flops` useful flops retired by
    /// `cluster` (running ladder rung `opp` under `family` parameters)
    /// in `service_s` seconds of service. Returns whether the
    /// observation was accepted. Non-finite or non-positive inputs —
    /// a zero-duration completion from a degenerate shape would imply
    /// an infinite rate — are *counted* (`rejected`, surfaced as an
    /// `obs` metric by [`LiveRateTable::export_metrics`]) and dropped
    /// without touching the EWMA.
    pub fn observe(
        &mut self,
        cluster: ClusterId,
        opp: usize,
        family: Family,
        shape: GemmShape,
        flops: f64,
        service_s: f64,
    ) -> bool {
        self.observe_weighted(cluster, opp, family, shape, flops, service_s, 1)
    }

    /// [`LiveRateTable::observe`] applied `multiplicity` times — the
    /// batched form a multi-item grab reports. Implemented as the
    /// literal repeated single-event update, so it is bit-for-bit the
    /// same fold as `multiplicity` sequential `observe` calls (the
    /// determinism contract is stated over the *event sequence*).
    #[allow(clippy::too_many_arguments)]
    pub fn observe_weighted(
        &mut self,
        cluster: ClusterId,
        opp: usize,
        family: Family,
        shape: GemmShape,
        flops: f64,
        service_s: f64,
        multiplicity: u64,
    ) -> bool {
        assert!(
            cluster.0 < self.num_clusters,
            "observation names cluster {cluster} but the table covers {} clusters",
            self.num_clusters
        );
        if multiplicity == 0 {
            return false;
        }
        if !(flops.is_finite() && flops > 0.0 && service_s.is_finite() && service_s > 0.0) {
            self.rejected += multiplicity;
            return false;
        }
        let x = flops / service_s / 1e9;
        let d = self.decay();
        let class = self.classify(shape);
        let cell = self
            .cells
            .entry((cluster.0, opp, family, class))
            .or_insert(LiveCell { num: 0.0, den: 0.0, samples: 0 });
        for _ in 0..multiplicity {
            cell.num = cell.num * d + x;
            cell.den = cell.den * d + 1.0;
        }
        cell.samples += multiplicity;
        self.accepted += multiplicity;
        true
    }

    /// The learned rate of one cell (GFLOPS), if it has ever been fed.
    pub fn rate(&self, cluster: ClusterId, opp: usize, family: Family, class: ShapeClass) -> Option<f64> {
        self.cells.get(&(cluster.0, opp, family, class)).map(LiveCell::rate)
    }

    /// Accepted observations of one cell (0 if the cell is cold).
    pub fn samples(&self, cluster: ClusterId, opp: usize, family: Family, class: ShapeClass) -> u64 {
        self.cells
            .get(&(cluster.0, opp, family, class))
            .map_or(0, LiveCell::samples)
    }

    /// Confidence gate: the cell exists and has at least `min_samples`
    /// accepted observations. Below the gate consumers fall back to
    /// the analytical rate for that cell.
    pub fn confident(
        &self,
        cluster: ClusterId,
        opp: usize,
        family: Family,
        class: ShapeClass,
        min_samples: u64,
    ) -> bool {
        self.cells
            .get(&(cluster.0, opp, family, class))
            .is_some_and(|c| c.samples >= min_samples)
    }

    /// Total accepted observations across every cell.
    pub fn accepted(&self) -> u64 {
        self.accepted
    }

    /// Observations rejected at the [`LiveRateTable::observe`] gate
    /// (non-finite / non-positive flops or service time).
    pub fn rejected(&self) -> u64 {
        self.rejected
    }

    /// Number of cells that have received at least one observation.
    pub fn num_cells(&self) -> usize {
        self.cells.len()
    }

    /// Deterministic iteration over every learned cell.
    pub fn cells(&self) -> impl Iterator<Item = (&LiveKey, &LiveCell)> {
        self.cells.iter()
    }

    /// Whether every learned cell has crossed the confidence gate (and
    /// at least one cell exists) — the "warmed up" predicate the fleet
    /// stream timestamps ([`crate::fleet::sim::simulate_fleet_stream_live`]).
    pub fn warmed_up(&self, min_samples: u64) -> bool {
        !self.cells.is_empty() && self.cells.values().all(|c| c.samples >= min_samples)
    }

    /// Per-cluster rates at an OPP vector with the per-cell analytical
    /// fallback applied: a confident cell contributes its learned rate,
    /// a cold cell the model's `cluster_rate_gflops` under the family's
    /// parameters — exactly the per-cluster values
    /// `PerfModel::auto_weights` is built from, so a fully cold table
    /// reproduces [`WeightSource::Analytical`] bit for bit.
    pub fn cluster_rates_or_analytical(
        &self,
        model: &PerfModel,
        opps: &[usize],
        cache_aware: bool,
        class: ShapeClass,
        min_samples: u64,
    ) -> Vec<f64> {
        assert_eq!(
            opps.len(),
            self.num_clusters,
            "OPP vector has {} entries but the live table covers {} clusters",
            opps.len(),
            self.num_clusters
        );
        let params = model.family_params(cache_aware);
        let family = Family::of(cache_aware);
        model
            .soc
            .cluster_ids()
            .map(|c| {
                if self.confident(c, opps[c.0], family, class, min_samples) {
                    self.rate(c, opps[c.0], family, class).expect("confident cell has a rate")
                } else {
                    model.cluster_rate_gflops(c, &params[c.0], model.soc[c].num_cores)
                }
            })
            .collect()
    }

    /// Freeze the table into an ordinary [`RateTable`]: the analytical
    /// synthesis of `soc` ([`RateTable::from_analytical`]) with every
    /// *confident* live cell overwriting its analytical value. The
    /// snapshot replays through [`WeightSource::Empirical`] bit for bit
    /// — the determinism contract replays are stated in.
    pub fn snapshot(&self, soc: &SocSpec, min_samples: u64) -> RateTable {
        assert_eq!(
            soc.num_clusters(),
            self.num_clusters,
            "snapshot descriptor has {} clusters but the live table covers {}",
            soc.num_clusters(),
            self.num_clusters
        );
        let mut table = RateTable::from_analytical(soc);
        for row in &mut table.rows {
            for class in ShapeClass::ALL {
                if self.confident(row.cluster, row.opp, row.family, class, min_samples) {
                    row.rates[class.idx()] = self
                        .rate(row.cluster, row.opp, row.family, class)
                        .expect("confident cell has a rate");
                }
            }
        }
        table
    }

    /// Line-oriented TSV with an exact text round-trip (the live
    /// sibling of [`RateTable::to_text`]):
    ///
    /// ```text
    /// #live\t<soc>\t<clusters>\t<kc_ref>\t<half_life>\t<accepted>\t<rejected>
    /// <cluster>\t<opp>\t<family>\t<class>\t<num>\t<den>\t<samples>
    /// ```
    pub fn to_text(&self) -> String {
        let mut out = format!(
            "#live\t{}\t{}\t{}\t{}\t{}\t{}\n",
            self.soc, self.num_clusters, self.kc_ref, self.half_life_events, self.accepted, self.rejected
        );
        for ((cluster, opp, family, class), cell) in &self.cells {
            out.push_str(&format!(
                "{}\t{}\t{}\t{}\t{}\t{}\t{}\n",
                cluster,
                opp,
                family.label(),
                class.label(),
                cell.num,
                cell.den,
                cell.samples
            ));
        }
        out
    }

    pub fn parse_text(s: &str) -> Result<LiveRateTable, String> {
        let mut lines = s.lines();
        let header = lines.next().ok_or("empty live rate table")?;
        let h: Vec<&str> = header.split('\t').collect();
        if h.len() != 7 || h[0] != "#live" {
            return Err(format!("bad live header '{header}'"));
        }
        let num_clusters: usize =
            h[2].parse().map_err(|_| format!("bad cluster count '{}'", h[2]))?;
        if num_clusters == 0 {
            return Err("live rate table needs at least one cluster".into());
        }
        let kc_ref: usize = h[3].parse().map_err(|_| format!("bad kc_ref '{}'", h[3]))?;
        if kc_ref == 0 {
            return Err("live rate table needs kc_ref >= 1".into());
        }
        let half_life_events = crate::util::parse_positive_f64(h[4], "half-life")?;
        let accepted: u64 = h[5].parse().map_err(|_| format!("bad accepted count '{}'", h[5]))?;
        let rejected: u64 = h[6].parse().map_err(|_| format!("bad rejected count '{}'", h[6]))?;
        let mut cells = BTreeMap::new();
        for line in lines {
            if line.is_empty() {
                continue;
            }
            let f: Vec<&str> = line.split('\t').collect();
            if f.len() != 7 {
                return Err(format!("bad live row '{line}'"));
            }
            let cluster: usize = f[0].parse().map_err(|_| format!("bad cluster '{}'", f[0]))?;
            if cluster >= num_clusters {
                return Err(format!(
                    "row names cluster {cluster} but the header declares {num_clusters}"
                ));
            }
            let opp: usize = f[1].parse().map_err(|_| format!("bad opp '{}'", f[1]))?;
            let family = Family::parse(f[2])?;
            let class = ShapeClass::parse(f[3])?;
            let num = crate::util::parse_positive_f64(f[4], "num")?;
            let den = crate::util::parse_positive_f64(f[5], "den")?;
            let samples: u64 = f[6].parse().map_err(|_| format!("bad sample count '{}'", f[6]))?;
            if samples == 0 {
                return Err(format!("live row '{line}' carries zero samples"));
            }
            if cells.insert((cluster, opp, family, class), LiveCell { num, den, samples }).is_some()
            {
                return Err(format!("duplicate live cell in row '{line}'"));
            }
        }
        Ok(LiveRateTable {
            soc: h[1].to_string(),
            num_clusters,
            kc_ref,
            half_life_events,
            accepted,
            rejected,
            cells,
        })
    }

    pub fn save(&self, path: &std::path::Path) -> std::io::Result<()> {
        if let Some(dir) = path.parent() {
            std::fs::create_dir_all(dir)?;
        }
        std::fs::write(path, self.to_text())
    }

    pub fn load(path: &std::path::Path) -> Result<LiveRateTable, String> {
        let text = std::fs::read_to_string(path).map_err(|e| e.to_string())?;
        LiveRateTable::parse_text(&text)
    }

    /// Mirror the table into a [`MetricsRegistry`]: per-cell sample
    /// counts as gauges (`<prefix>_samples_c<c>_o<opp>_<family>_<class>`)
    /// plus the accepted/rejected totals — gauges throughout, so
    /// re-exporting after more observations is idempotent-by-overwrite.
    /// No-op on a disabled registry (the zero-overhead contract).
    pub fn export_metrics(&self, metrics: &mut MetricsRegistry, prefix: &str) {
        if !metrics.enabled() {
            return;
        }
        metrics.set_gauge(&format!("{prefix}_accepted"), self.accepted as f64);
        metrics.set_gauge(&format!("{prefix}_rejected"), self.rejected as f64);
        metrics.set_gauge(&format!("{prefix}_cells"), self.cells.len() as f64);
        for ((cluster, opp, family, class), cell) in &self.cells {
            metrics.set_gauge(
                &format!(
                    "{prefix}_samples_c{cluster}_o{opp}_{}_{}",
                    family.label(),
                    class.label()
                ),
                cell.samples as f64,
            );
        }
    }
}

/// Build the live weight source over a table — sugar for the common
/// construction site.
pub fn live_source(table: LiveRateTable, min_samples: u64) -> WeightSource {
    WeightSource::Live { table, min_samples }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::soc::BIG;

    fn soc() -> SocSpec {
        SocSpec::exynos5422()
    }

    fn table() -> LiveRateTable {
        LiveRateTable::new(&soc(), 32.0)
    }

    #[test]
    fn single_observation_reports_itself() {
        let mut t = table();
        let shape = GemmShape::square(2048);
        assert!(t.observe(BIG, 4, Family::CacheAware, shape, 2e9, 0.5));
        let r = t.rate(BIG, 4, Family::CacheAware, ShapeClass::Medium).unwrap();
        // 2e9 flops in 0.5 s = 4 GFLOPS, exactly (den = 1 after one event).
        assert_eq!(r, 4.0);
        assert_eq!(t.samples(BIG, 4, Family::CacheAware, ShapeClass::Medium), 1);
        assert_eq!(t.accepted(), 1);
        assert_eq!(t.num_cells(), 1);
    }

    #[test]
    fn ewma_weighs_recent_events_and_converges() {
        let mut t = LiveRateTable::new(&soc(), 4.0);
        let shape = GemmShape::square(4096);
        for _ in 0..50 {
            t.observe(BIG, 4, Family::CacheAware, shape, 1e9, 1.0); // 1 GFLOPS
        }
        let r0 = t.rate(BIG, 4, Family::CacheAware, ShapeClass::Large).unwrap();
        assert!((r0 - 1.0).abs() < 1e-12, "{r0}");
        // A regime change: the EWMA chases the new 3-GFLOPS level, past
        // halfway within one half-life, within 1% after many.
        t.observe(BIG, 4, Family::CacheAware, shape, 3e9, 1.0);
        let r1 = t.rate(BIG, 4, Family::CacheAware, ShapeClass::Large).unwrap();
        assert!(r1 > 1.0 && r1 < 3.0, "{r1}");
        for _ in 0..100 {
            t.observe(BIG, 4, Family::CacheAware, shape, 3e9, 1.0);
        }
        let r2 = t.rate(BIG, 4, Family::CacheAware, ShapeClass::Large).unwrap();
        assert!((r2 - 3.0).abs() < 0.03, "{r2}");
    }

    #[test]
    fn weighted_observation_is_the_sequential_fold() {
        let shape = GemmShape::square(4096);
        let mut seq = table();
        let mut bat = table();
        for i in 0..5u64 {
            let flops = 1e9 + i as f64 * 1e8;
            for _ in 0..3 {
                seq.observe(BIG, 2, Family::Oblivious, shape, flops, 0.25);
            }
            bat.observe_weighted(BIG, 2, Family::Oblivious, shape, flops, 0.25, 3);
        }
        // Bit-for-bit: the batched form is the literal repeated update.
        assert_eq!(seq, bat);
        assert!(!bat.observe_weighted(BIG, 2, Family::Oblivious, shape, 1e9, 0.25, 0));
    }

    /// ISSUE 9 satellite: non-finite / non-positive observations are
    /// rejected and *counted*, never folded into the EWMA.
    #[test]
    fn degenerate_observations_rejected_and_counted() {
        let mut t = table();
        let shape = GemmShape::square(1024);
        for (flops, service_s) in [
            (1e9, 0.0),            // zero-duration completion => inf rate
            (1e9, -1.0),
            (1e9, f64::NAN),
            (1e9, f64::INFINITY),
            (0.0, 0.5),
            (-1e9, 0.5),
            (f64::NAN, 0.5),
            (f64::INFINITY, 0.5),
        ] {
            assert!(!t.observe(BIG, 4, Family::CacheAware, shape, flops, service_s));
        }
        assert_eq!(t.rejected(), 8);
        assert_eq!(t.accepted(), 0);
        assert_eq!(t.num_cells(), 0, "rejected observations must not create cells");
        // The rejection counter reaches the registry as an obs metric.
        let mut m = MetricsRegistry::new();
        t.export_metrics(&mut m, "live");
        assert_eq!(m.gauge("live_rejected"), Some(8.0));
    }

    /// ISSUE 9 satellite (boundary audit): the live path classifies
    /// with the same pinned `kc_ref` the offline measurement uses, so
    /// `k ∈ {kc-1, kc, kc+1}` land identically: `kc-1` is Small, `kc`
    /// and `kc+1` are Medium (`Small` is `k < kc`, half-open).
    #[test]
    fn classification_matches_offline_at_the_kc_boundary() {
        let s = soc();
        let kc = s[s.lead()].tuned.kc;
        let t = LiveRateTable::new(&s, 32.0);
        assert_eq!(t.kc_ref, kc);
        for (k, expect) in [
            (kc - 1, ShapeClass::Small),
            (kc, ShapeClass::Medium),
            (kc + 1, ShapeClass::Medium),
        ] {
            let shape = GemmShape { m: 256, n: 256, k };
            assert_eq!(t.classify(shape), expect, "k = {k}");
            assert_eq!(ShapeClass::for_soc(&s, shape), expect, "offline path, k = {k}");
        }
    }

    #[test]
    fn snapshot_degenerates_to_analytical_when_cold() {
        let s = soc();
        let t = table();
        assert_eq!(t.snapshot(&s, 1), RateTable::from_analytical(&s));
        // One confident cell overwrites exactly that cell.
        let mut t = table();
        let shape = GemmShape::square(4096);
        t.observe(BIG, 4, Family::CacheAware, shape, 5e9, 1.0);
        let snap = t.snapshot(&s, 1);
        assert_eq!(snap.rate(BIG, 4, Family::CacheAware, ShapeClass::Large), Some(5.0));
        // Below the confidence gate the analytical value stays.
        let gated = t.snapshot(&s, 2);
        assert_eq!(gated, RateTable::from_analytical(&s));
    }

    #[test]
    fn cold_table_reproduces_analytical_weights_bit_for_bit() {
        let s = soc();
        let model = PerfModel::new(s.clone());
        let t = table();
        for cache_aware in [false, true] {
            let live = WeightSource::Live { table: t.clone(), min_samples: 1 }
                .weights(&model, cache_aware, ShapeClass::Large);
            let ana = model.auto_weights(cache_aware);
            assert_eq!(live.as_slice(), ana.as_slice());
        }
        let live_tp = WeightSource::Live { table: t.clone(), min_samples: 1 }
            .board_throughput(&model, ShapeClass::Large);
        assert_eq!(live_tp, WeightSource::Analytical.board_throughput(&model, ShapeClass::Large));
    }

    #[test]
    fn text_round_trip_is_exact() {
        let s = soc();
        let mut t = LiveRateTable::new(&s, 24.0);
        let shapes = [GemmShape::square(512), GemmShape::square(2048), GemmShape::square(4096)];
        for (i, shape) in shapes.iter().enumerate() {
            for c in s.cluster_ids() {
                t.observe_weighted(
                    c,
                    i,
                    Family::CacheAware,
                    *shape,
                    1.23e9 + i as f64 * 0.37e9,
                    0.17 + c.0 as f64 * 0.05,
                    (i + 1) as u64,
                );
            }
        }
        t.observe(BIG, 0, Family::Oblivious, shapes[0], f64::NAN, 1.0); // one rejection
        let back = LiveRateTable::parse_text(&t.to_text()).unwrap();
        assert_eq!(back, t);
        let dir = std::env::temp_dir().join("amp_gemm_live_table");
        let _ = std::fs::remove_dir_all(&dir);
        let path = dir.join("live.tsv");
        t.save(&path).unwrap();
        assert_eq!(LiveRateTable::load(&path).unwrap(), t);
        let _ = std::fs::remove_dir_all(&dir);
        assert!(LiveRateTable::load(std::path::Path::new("/nonexistent/x")).is_err());
    }

    #[test]
    fn malformed_live_tables_rejected() {
        assert!(LiveRateTable::parse_text("").is_err());
        assert!(LiveRateTable::parse_text("junk\n").is_err());
        // Wrong header arity / tag / counts.
        assert!(LiveRateTable::parse_text("#live\tsoc\t2\t952\t32\t0\n").is_err());
        assert!(LiveRateTable::parse_text("# soc\t2\n").is_err());
        assert!(LiveRateTable::parse_text("#live\tsoc\t0\t952\t32\t0\t0\n").is_err());
        assert!(LiveRateTable::parse_text("#live\tsoc\t2\t0\t32\t0\t0\n").is_err());
        assert!(LiveRateTable::parse_text("#live\tsoc\t2\t952\tNaN\t0\t0\n").is_err());
        assert!(LiveRateTable::parse_text("#live\tsoc\t2\t952\t-1\t0\t0\n").is_err());
        assert!(LiveRateTable::parse_text("#live\tsoc\t2\t952\t32\tx\t0\n").is_err());
        let head = "#live\tsoc\t2\t952\t32\t3\t0\n";
        let ok = format!("{head}0\t4\tca\tmedium\t1.5\t1\t3\n");
        assert!(LiveRateTable::parse_text(&ok).is_ok());
        // Row arity, vocabulary, range, non-finite fields, zero
        // samples, duplicate cells.
        for row in [
            "0\t4\tca\tmedium\t1.5\t1\n",
            "0\t4\twarp\tmedium\t1.5\t1\t3\n",
            "0\t4\tca\thuge\t1.5\t1\t3\n",
            "7\t4\tca\tmedium\t1.5\t1\t3\n",
            "0\t4\tca\tmedium\tNaN\t1\t3\n",
            "0\t4\tca\tmedium\t1.5\tinf\t3\n",
            "0\t4\tca\tmedium\t-1.5\t1\t3\n",
            "0\t4\tca\tmedium\t1.5\t1\t0\n",
            "0\t4\tca\tmedium\t1.5\t1\t3\n0\t4\tca\tmedium\t1.5\t1\t3\n",
        ] {
            assert!(
                LiveRateTable::parse_text(&format!("{head}{row}")).is_err(),
                "row '{row}' must be rejected"
            );
        }
    }

    #[test]
    #[should_panic(expected = "half-life must be positive")]
    fn non_positive_half_life_rejected() {
        let _ = LiveRateTable::new(&soc(), 0.0);
    }

    #[test]
    fn sample_count_gauges_reach_the_registry() {
        let mut t = table();
        t.observe_weighted(BIG, 4, Family::CacheAware, GemmShape::square(4096), 1e9, 1.0, 7);
        let mut m = MetricsRegistry::new();
        t.export_metrics(&mut m, "live");
        assert_eq!(m.gauge("live_samples_c0_o4_ca_large"), Some(7.0));
        assert_eq!(m.gauge("live_accepted"), Some(7.0));
        assert_eq!(m.gauge("live_cells"), Some(1.0));
        // Zero overhead when off: a disabled registry stays empty.
        let mut off = MetricsRegistry::disabled();
        t.export_metrics(&mut off, "live");
        assert_eq!(off.gauge("live_accepted"), None);
    }
}
