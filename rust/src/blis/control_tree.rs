//! Control trees (paper §5.1).
//!
//! BLIS drives every operation from a recursive *control tree* encoding
//! which loops run, their strides (the cache parameters), where packing
//! happens, and — for multi-threaded execution — which loops are
//! parallelized and how many ways. The paper's key implementation move
//! is *duplicating* this structure: one tree for "fast" (big) threads
//! and one for "slow" (LITTLE) threads, so each cluster runs its own
//! cache-aware strides (§5.3) and, in CA-DAS, its own dynamic chunk
//! size (§5.4).
//!
//! We reproduce the tree as a typed recursive structure plus builders
//! for the GEMM algorithm of Fig. 1, with validation of the paper's
//! constraints (Loop 2 must never be parallelized — race on C; packing
//! must sit exactly where Fig. 1 puts it).

use crate::blis::params::BlisParams;
use crate::soc::ClusterId;

/// The five loops of the BLIS GEMM (Fig. 1), outermost first.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash, PartialOrd, Ord)]
pub enum LoopId {
    /// jc over n, stride nc.
    Loop1,
    /// pc over k, stride kc (packs Bc; never parallel).
    Loop2,
    /// ic over m, stride mc (packs Ac).
    Loop3,
    /// jr over nc, stride nr.
    Loop4,
    /// ir over mc, stride mr.
    Loop5,
}

impl LoopId {
    pub const ALL: [LoopId; 5] = [
        LoopId::Loop1,
        LoopId::Loop2,
        LoopId::Loop3,
        LoopId::Loop4,
        LoopId::Loop5,
    ];

    pub fn index(self) -> usize {
        match self {
            LoopId::Loop1 => 1,
            LoopId::Loop2 => 2,
            LoopId::Loop3 => 3,
            LoopId::Loop4 => 4,
            LoopId::Loop5 => 5,
        }
    }
}

/// Which operand a packing node materializes.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum PackBuf {
    /// `Bc` (kc×nc), packed inside Loop 2.
    B,
    /// `Ac` (mc×kc), packed inside Loop 3.
    A,
}

/// A node of the control tree.
#[derive(Debug, Clone, PartialEq)]
pub enum Node {
    /// A blocked loop with its stride and parallelization degree.
    Loop {
        id: LoopId,
        stride: usize,
        /// 1 = sequential; >1 = iteration space partitioned this many
        /// ways across threads (static) or served dynamically.
        ways: usize,
        child: Box<Node>,
    },
    /// Packing of one operand, then the child subtree.
    Pack { buf: PackBuf, child: Box<Node> },
    /// The micro-kernel leaf (mr×nr rank-1 update loop).
    MicroKernel,
}

impl Node {
    /// Walk the tree depth-first, calling `f` on every node.
    pub fn visit<'a>(&'a self, f: &mut impl FnMut(&'a Node)) {
        f(self);
        match self {
            Node::Loop { child, .. } | Node::Pack { child, .. } => child.visit(f),
            Node::MicroKernel => {}
        }
    }

    fn find_loop(&self, id: LoopId) -> Option<(&Node, usize, usize)> {
        let mut found = None;
        self.visit(&mut |n| {
            if let Node::Loop { id: nid, stride, ways, .. } = n {
                if *nid == id && found.is_none() {
                    found = Some((n, *stride, *ways));
                }
            }
        });
        found
    }
}

/// Degrees of parallelism for the four parallelizable loops. (Loop 2 is
/// deliberately absent: §3.1 — "multiple threads simultaneously update
/// the same parts of C".)
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub struct Parallelism {
    pub loop1_ways: usize,
    pub loop3_ways: usize,
    pub loop4_ways: usize,
    pub loop5_ways: usize,
}

impl Parallelism {
    pub fn sequential() -> Self {
        Parallelism {
            loop1_ways: 1,
            loop3_ways: 1,
            loop4_ways: 1,
            loop5_ways: 1,
        }
    }

    /// Total thread count this parallelization implies.
    pub fn total_ways(&self) -> usize {
        self.loop1_ways * self.loop3_ways * self.loop4_ways * self.loop5_ways
    }
}

/// A full control tree: the blocking parameters plus the tree built from
/// them. One per thread *type* — the CA-* configurations instantiate two.
#[derive(Debug, Clone, PartialEq)]
pub struct ControlTree {
    pub params: BlisParams,
    pub par: Parallelism,
    pub root: Node,
}

impl ControlTree {
    /// Build the standard GEMM tree of Fig. 1 with the given strides and
    /// parallelization.
    pub fn gemm(params: BlisParams, par: Parallelism) -> Self {
        params.validate();
        assert!(par.loop1_ways >= 1 && par.loop3_ways >= 1);
        assert!(par.loop4_ways >= 1 && par.loop5_ways >= 1);
        let root = Node::Loop {
            id: LoopId::Loop1,
            stride: params.nc,
            ways: par.loop1_ways,
            child: Box::new(Node::Loop {
                id: LoopId::Loop2,
                stride: params.kc,
                ways: 1,
                child: Box::new(Node::Pack {
                    buf: PackBuf::B,
                    child: Box::new(Node::Loop {
                        id: LoopId::Loop3,
                        stride: params.mc,
                        ways: par.loop3_ways,
                        child: Box::new(Node::Pack {
                            buf: PackBuf::A,
                            child: Box::new(Node::Loop {
                                id: LoopId::Loop4,
                                stride: params.nr,
                                ways: par.loop4_ways,
                                child: Box::new(Node::Loop {
                                    id: LoopId::Loop5,
                                    stride: params.mr,
                                    ways: par.loop5_ways,
                                    child: Box::new(Node::MicroKernel),
                                }),
                            }),
                        }),
                    }),
                }),
            }),
        };
        let tree = ControlTree { params, par, root };
        tree.validate();
        tree
    }

    /// Sequential tree with the given parameters.
    pub fn sequential(params: BlisParams) -> Self {
        ControlTree::gemm(params, Parallelism::sequential())
    }

    /// Structural invariants of the Fig. 1 algorithm.
    pub fn validate(&self) {
        // Loop order 1,2,3,4,5 outermost→innermost; Pack B directly
        // under Loop 2; Pack A directly under Loop 3; Loop 2 sequential.
        let mut seq = Vec::new();
        self.root.visit(&mut |n| {
            if let Node::Loop { id, ways, .. } = n {
                seq.push(*id);
                if *id == LoopId::Loop2 {
                    assert_eq!(*ways, 1, "Loop 2 must never be parallelized (race on C)");
                }
            }
        });
        assert_eq!(seq, LoopId::ALL.to_vec(), "loop nesting order broken");

        let (_, s1, _) = self.root.find_loop(LoopId::Loop1).unwrap();
        assert_eq!(s1, self.params.nc);
        let (_, s4, _) = self.root.find_loop(LoopId::Loop4).unwrap();
        assert_eq!(s4, self.params.nr);
    }

    /// Stride of a loop.
    pub fn stride(&self, id: LoopId) -> usize {
        self.root.find_loop(id).expect("loop exists").1
    }

    /// Parallelization ways of a loop.
    pub fn ways(&self, id: LoopId) -> usize {
        self.root.find_loop(id).expect("loop exists").2
    }

    /// Trip count of a loop for a problem extent along its dimension.
    pub fn trips(&self, id: LoopId, m: usize, n: usize, k: usize) -> usize {
        let (extent, stride) = match id {
            LoopId::Loop1 => (n, self.params.nc),
            LoopId::Loop2 => (k, self.params.kc),
            LoopId::Loop3 => (m, self.params.mc),
            LoopId::Loop4 => (n.min(self.params.nc), self.params.nr),
            LoopId::Loop5 => (m.min(self.params.mc), self.params.mr),
        };
        extent.div_ceil(stride)
    }
}

/// The control trees bound to clusters (§5.3, generalized): the paper's
/// "two different control-trees ... for fast and slow threads" becomes
/// one tree per cluster, indexed by [`ClusterId`]. A cache-oblivious
/// configuration simply holds N identical trees.
#[derive(Debug, Clone, PartialEq)]
pub struct TreeSet {
    /// One control tree per cluster, indexed by `ClusterId`.
    pub trees: Vec<ControlTree>,
}

impl TreeSet {
    /// Architecture-oblivious: one configuration replicated to every
    /// cluster (the original BLIS behaviour, §4 / plain SAS §5.2).
    pub fn single(params: BlisParams, par: Parallelism, num_clusters: usize) -> Self {
        assert!(num_clusters >= 1);
        TreeSet {
            trees: vec![ControlTree::gemm(params, par); num_clusters],
        }
    }

    /// Cache-aware: one pre-built tree per cluster (CA-SAS §5.3 /
    /// CA-DAS §5.4). `shared_bc` = the coarse loop is Loop 3, so the
    /// `Bc = kc×nc` buffer is shared and every tree must agree on both
    /// `kc` and `nc` — otherwise the clusters' joint (jc, pc) walks
    /// would desynchronize.
    pub fn from_trees(trees: Vec<ControlTree>, shared_bc: bool) -> Self {
        assert!(!trees.is_empty());
        if shared_bc {
            let kc = trees[0].params.kc;
            assert!(
                trees.iter().all(|t| t.params.kc == kc),
                "shared Bc requires a common kc across trees (§5.3)"
            );
            let nc = trees[0].params.nc;
            assert!(
                trees.iter().all(|t| t.params.nc == nc),
                "shared Bc requires a common nc across trees (§5.3)"
            );
        }
        TreeSet { trees }
    }

    pub fn for_cluster(&self, c: ClusterId) -> &ControlTree {
        &self.trees[c.0]
    }

    pub fn num_clusters(&self) -> usize {
        self.trees.len()
    }

    /// True when at least two clusters run different blocking parameters.
    pub fn is_cache_aware(&self) -> bool {
        self.trees.iter().any(|t| t.params != self.trees[0].params)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn gemm_tree_structure_matches_fig1() {
        let t = ControlTree::sequential(BlisParams::a15_opt());
        // Strides map to the cache parameters.
        assert_eq!(t.stride(LoopId::Loop1), 4096);
        assert_eq!(t.stride(LoopId::Loop2), 952);
        assert_eq!(t.stride(LoopId::Loop3), 152);
        assert_eq!(t.stride(LoopId::Loop4), 4);
        assert_eq!(t.stride(LoopId::Loop5), 4);
        // Pack nodes sit where Fig. 1 puts them.
        let mut packs = Vec::new();
        let mut loops_seen = 0;
        t.root.visit(&mut |n| match n {
            Node::Loop { .. } => loops_seen += 1,
            Node::Pack { buf, .. } => packs.push((*buf, loops_seen)),
            Node::MicroKernel => {}
        });
        assert_eq!(packs, vec![(PackBuf::B, 2), (PackBuf::A, 3)]);
    }

    #[test]
    fn trip_counts() {
        let t = ControlTree::sequential(BlisParams::a15_opt());
        // r = 4096: Loop 1 takes 1 trip (nc=4096), Loop 2 ⌈4096/952⌉=5.
        assert_eq!(t.trips(LoopId::Loop1, 4096, 4096, 4096), 1);
        assert_eq!(t.trips(LoopId::Loop2, 4096, 4096, 4096), 5);
        assert_eq!(t.trips(LoopId::Loop3, 4096, 4096, 4096), 27);
        assert_eq!(t.trips(LoopId::Loop4, 4096, 4096, 4096), 1024);
        assert_eq!(t.trips(LoopId::Loop5, 4096, 4096, 4096), 38);
    }

    #[test]
    fn parallel_ways_recorded() {
        let par = Parallelism {
            loop1_ways: 2,
            loop3_ways: 1,
            loop4_ways: 4,
            loop5_ways: 1,
        };
        let t = ControlTree::gemm(BlisParams::a15_opt(), par);
        assert_eq!(t.ways(LoopId::Loop1), 2);
        assert_eq!(t.ways(LoopId::Loop4), 4);
        assert_eq!(par.total_ways(), 8);
    }

    #[test]
    fn loop2_parallelization_is_impossible_by_construction() {
        // Parallelism has no loop2 field; the built tree always has
        // ways=1 there, and validate() enforces it.
        let t = ControlTree::sequential(BlisParams::a7_opt());
        assert_eq!(t.ways(LoopId::Loop2), 1);
    }

    #[test]
    fn cache_aware_treeset_from_per_cluster_trees() {
        // Independent buffers: each cluster its own optimum.
        let par = Parallelism { loop1_ways: 2, loop4_ways: 4, ..Parallelism::sequential() };
        let s = TreeSet::from_trees(
            vec![
                ControlTree::gemm(BlisParams::a15_opt(), par),
                ControlTree::gemm(BlisParams::a7_opt(), par),
            ],
            false,
        );
        assert_eq!(s.for_cluster(ClusterId(0)).params, BlisParams::a15_opt());
        assert_eq!(s.for_cluster(ClusterId(1)).params, BlisParams::a7_opt());
        assert!(s.is_cache_aware());
        assert_eq!(s.num_clusters(), 2);
    }

    #[test]
    fn shared_bc_treeset_requires_common_kc() {
        // Shared Bc: common kc = 952, LITTLE refits mc = 32 (§5.3).
        let par = Parallelism { loop3_ways: 2, loop4_ways: 4, ..Parallelism::sequential() };
        let s = TreeSet::from_trees(
            vec![
                ControlTree::gemm(BlisParams::a15_opt(), par),
                ControlTree::gemm(BlisParams::a7_shared_kc(), par),
            ],
            true,
        );
        assert_eq!(s.for_cluster(ClusterId(1)).params, BlisParams::a7_shared_kc());
        assert_eq!(
            s.for_cluster(ClusterId(0)).params.kc,
            s.for_cluster(ClusterId(1)).params.kc
        );
    }

    #[test]
    #[should_panic(expected = "common kc")]
    fn shared_bc_with_mismatched_kc_rejected() {
        let par = Parallelism::sequential();
        TreeSet::from_trees(
            vec![
                ControlTree::gemm(BlisParams::a15_opt(), par),
                ControlTree::gemm(BlisParams::a7_opt(), par),
            ],
            true,
        );
    }

    #[test]
    fn single_treeset_is_oblivious() {
        let s = TreeSet::single(BlisParams::a15_opt(), Parallelism::sequential(), 3);
        assert!(!s.is_cache_aware());
        assert_eq!(s.num_clusters(), 3);
        assert_eq!(s.for_cluster(ClusterId(2)).params, BlisParams::a15_opt());
    }

    #[test]
    fn visit_covers_all_nodes() {
        let t = ControlTree::sequential(BlisParams::a7_opt());
        let mut count = 0;
        t.root.visit(&mut |_| count += 1);
        // 5 loops + 2 packs + 1 micro-kernel.
        assert_eq!(count, 8);
    }

    #[test]
    #[should_panic]
    fn zero_ways_rejected() {
        ControlTree::gemm(
            BlisParams::a15_opt(),
            Parallelism { loop1_ways: 0, ..Parallelism::sequential() },
        );
    }
}
