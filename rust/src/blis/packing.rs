//! Packing routines (Fig. 1: "Pack into Ac / Bc").
//!
//! GotoBLAS/BLIS re-lay operands into contiguous, micro-kernel-friendly
//! buffers so the inner loops stream with unit stride:
//!
//! * `Ac` (`mc×kc`): packed as ⌈mc/mr⌉ *row micro-panels*; within panel
//!   `p`, element (i, l) of the source block sits at
//!   `p*(mr*kc) + l*mr + i` — i.e. each panel is column-major mr×kc.
//!   Edge panels (mc % mr ≠ 0) are zero-padded to full mr.
//! * `Bc` (`kc×nc`): packed as ⌈nc/nr⌉ *column micro-panels*; within
//!   panel `q`, element (l, j) sits at `q*(kc*nr) + l*nr + j` (row-major
//!   kc×nr), zero-padded to full nr.
//!
//! All matrices in this crate are row-major; `lda`/`ldb` are row strides.
//! Zero padding lets every interior micro-kernel run the full-register
//! fast path; the write-back window (`m_eff`, `n_eff`) clips edges.

/// Pack the `mc_eff × kc_eff` block of `a` starting at (row0, col0) into
/// `buf` (capacity ≥ round_up(mc_eff, mr) * kc_eff).
pub fn pack_a(
    a: &[f64],
    lda: usize,
    row0: usize,
    col0: usize,
    mc_eff: usize,
    kc_eff: usize,
    mr: usize,
    buf: &mut Vec<f64>,
) {
    let panels = mc_eff.div_ceil(mr);
    buf.clear();
    buf.resize(panels * mr * kc_eff, 0.0);
    // Row-contiguous source reads (perf pass, DESIGN.md §10):
    // each source row of A is walked once sequentially; the strided
    // destination writes stay within the 30 KiB panel.
    for p in 0..panels {
        let base = p * mr * kc_eff;
        let rows_live = (mc_eff - p * mr).min(mr);
        for i in 0..rows_live {
            let src_row = (row0 + p * mr + i) * lda + col0;
            let src = &a[src_row..src_row + kc_eff];
            for (l, &v) in src.iter().enumerate() {
                buf[base + l * mr + i] = v;
            }
        }
        // rows_live..mr remain zero (padding).
    }
}

/// Pack the `kc_eff × nc_eff` block of `b` starting at (row0, col0) into
/// `buf` (capacity ≥ kc_eff * round_up(nc_eff, nr)).
pub fn pack_b(
    b: &[f64],
    ldb: usize,
    row0: usize,
    col0: usize,
    kc_eff: usize,
    nc_eff: usize,
    nr: usize,
    buf: &mut Vec<f64>,
) {
    let panels = nc_eff.div_ceil(nr);
    buf.clear();
    buf.resize(panels * kc_eff * nr, 0.0);
    // Row-major-friendly order (perf pass, DESIGN.md §10): walk
    // each source row once — it is contiguous across *all* panels — and
    // scatter nr-wide segments with `copy_from_slice`. ~2× over the
    // panel-outer order, which re-walked every source row per panel.
    let full_panels = nc_eff / nr;
    for l in 0..kc_eff {
        let src_row = (row0 + l) * ldb + col0;
        let src = &b[src_row..src_row + nc_eff];
        for q in 0..full_panels {
            let dst = q * kc_eff * nr + l * nr;
            buf[dst..dst + nr].copy_from_slice(&src[q * nr..(q + 1) * nr]);
        }
        if full_panels < panels {
            let q = full_panels;
            let cols_live = nc_eff - q * nr;
            let dst = q * kc_eff * nr + l * nr;
            buf[dst..dst + cols_live].copy_from_slice(&src[q * nr..q * nr + cols_live]);
        }
    }
}

/// Pack only A micro-panels `[p0, p1)` into the corresponding region of
/// `buf` (preallocated to ⌈mc_eff/mr⌉·mr·kc_eff). This is the unit the
/// parallel executor splits among a cluster's threads: each thread owns
/// a disjoint panel range, so concurrent packing is race-free.
#[allow(clippy::too_many_arguments)]
pub fn pack_a_panels(
    a: &[f64],
    lda: usize,
    row0: usize,
    col0: usize,
    mc_eff: usize,
    kc_eff: usize,
    mr: usize,
    buf: &mut [f64],
    p0: usize,
    p1: usize,
) {
    let panels = mc_eff.div_ceil(mr);
    debug_assert!(p1 <= panels && buf.len() >= panels * mr * kc_eff);
    for p in p0..p1 {
        let base = p * mr * kc_eff;
        let rows_live = (mc_eff - p * mr).min(mr);
        for l in 0..kc_eff {
            let dst = base + l * mr;
            for i in 0..rows_live {
                buf[dst + i] = a[(row0 + p * mr + i) * lda + col0 + l];
            }
            for i in rows_live..mr {
                buf[dst + i] = 0.0;
            }
        }
    }
}

/// Pack only B micro-panels `[q0, q1)` into `buf` (preallocated to
/// kc_eff·⌈nc_eff/nr⌉·nr). See [`pack_a_panels`].
#[allow(clippy::too_many_arguments)]
pub fn pack_b_panels(
    b: &[f64],
    ldb: usize,
    row0: usize,
    col0: usize,
    kc_eff: usize,
    nc_eff: usize,
    nr: usize,
    buf: &mut [f64],
    q0: usize,
    q1: usize,
) {
    let panels = nc_eff.div_ceil(nr);
    debug_assert!(q1 <= panels && buf.len() >= panels * kc_eff * nr);
    for q in q0..q1 {
        let base = q * kc_eff * nr;
        let cols_live = (nc_eff - q * nr).min(nr);
        for l in 0..kc_eff {
            let dst = base + l * nr;
            let src_row = (row0 + l) * ldb + col0 + q * nr;
            for j in 0..cols_live {
                buf[dst + j] = b[src_row + j];
            }
            for j in cols_live..nr {
                buf[dst + j] = 0.0;
            }
        }
    }
}

/// Bytes moved by packing an `mc×kc` A-block (read + write) — the cost
/// input for the perf model's packing time.
pub fn pack_a_bytes(mc_eff: usize, kc_eff: usize) -> usize {
    2 * mc_eff * kc_eff * 8
}

/// Bytes moved by packing a `kc×nc` B-block.
pub fn pack_b_bytes(kc_eff: usize, nc_eff: usize) -> usize {
    2 * kc_eff * nc_eff * 8
}

/// View of one packed A micro-panel (mr×kc, column-major).
pub fn a_panel(buf: &[f64], panel: usize, mr: usize, kc_eff: usize) -> &[f64] {
    let base = panel * mr * kc_eff;
    &buf[base..base + mr * kc_eff]
}

/// View of one packed B micro-panel (kc×nr, row-major).
pub fn b_panel(buf: &[f64], panel: usize, nr: usize, kc_eff: usize) -> &[f64] {
    let base = panel * kc_eff * nr;
    &buf[base..base + kc_eff * nr]
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    #[test]
    fn pack_a_layout_interior() {
        // 4×3 source block, mr=2 → 2 panels of 2×3.
        let lda = 5;
        let mut a = vec![0.0; 6 * lda];
        for r in 0..6 {
            for c in 0..lda {
                a[r * lda + c] = (10 * r + c) as f64;
            }
        }
        let mut buf = Vec::new();
        pack_a(&a, lda, 1, 2, 4, 3, 2, &mut buf);
        // Panel 0 rows {1,2}, cols {2,3,4}: col-major per column.
        assert_eq!(&buf[0..2], &[12.0, 22.0]); // l=0: a[1][2], a[2][2]
        assert_eq!(&buf[2..4], &[13.0, 23.0]);
        assert_eq!(&buf[4..6], &[14.0, 24.0]);
        // Panel 1 rows {3,4}.
        assert_eq!(&buf[6..8], &[32.0, 42.0]);
    }

    #[test]
    fn pack_a_edge_padding_zeroes() {
        let lda = 4;
        let a: Vec<f64> = (0..16).map(|x| x as f64 + 1.0).collect();
        let mut buf = Vec::new();
        // mc_eff = 3, mr = 2 → second panel has one live row + one pad row.
        pack_a(&a, lda, 0, 0, 3, 2, 2, &mut buf);
        assert_eq!(buf.len(), 2 * 2 * 2);
        // Panel 1, l=0: [a[2][0], 0].
        assert_eq!(buf[4], 9.0);
        assert_eq!(buf[5], 0.0);
    }

    #[test]
    fn pack_b_layout_interior() {
        let ldb = 6;
        let mut b = vec![0.0; 4 * ldb];
        for r in 0..4 {
            for c in 0..ldb {
                b[r * ldb + c] = (10 * r + c) as f64;
            }
        }
        let mut buf = Vec::new();
        // 2×4 block at (1,1), nr=2 → 2 panels of 2×2 row-major.
        pack_b(&b, ldb, 1, 1, 2, 4, 2, &mut buf);
        assert_eq!(&buf[0..2], &[11.0, 12.0]); // panel 0, l=0
        assert_eq!(&buf[2..4], &[21.0, 22.0]); // panel 0, l=1
        assert_eq!(&buf[4..6], &[13.0, 14.0]); // panel 1, l=0
    }

    #[test]
    fn pack_b_edge_padding_zeroes() {
        let ldb = 3;
        let b: Vec<f64> = (0..9).map(|x| x as f64 + 1.0).collect();
        let mut buf = Vec::new();
        // nc_eff = 3, nr = 2 → panel 1 has one live + one padded column.
        pack_b(&b, ldb, 0, 0, 2, 3, 2, &mut buf);
        assert_eq!(buf.len(), 2 * 2 * 2);
        assert_eq!(buf[4], 3.0); // b[0][2]
        assert_eq!(buf[5], 0.0); // pad
    }

    #[test]
    fn panel_views_partition_buffers() {
        let mut rng = Rng::new(55);
        let (mc, kc, mr) = (10, 7, 4);
        let lda = 12;
        let a = rng.fill_matrix(mc * lda);
        let mut buf = Vec::new();
        pack_a(&a, lda, 0, 0, mc, kc, mr, &mut buf);
        let panels = mc.div_ceil(mr);
        let mut total = 0;
        for p in 0..panels {
            total += a_panel(&buf, p, mr, kc).len();
        }
        assert_eq!(total, buf.len());
    }

    #[test]
    fn byte_accounting() {
        assert_eq!(pack_a_bytes(152, 952), 2 * 152 * 952 * 8);
        assert_eq!(pack_b_bytes(952, 4096), 2 * 952 * 4096 * 8);
    }

    /// Property: packing then unpacking reproduces the source block.
    #[test]
    fn prop_pack_roundtrip() {
        crate::util::prop::check_default(
            |r| {
                let mc = r.gen_range(1, 20);
                let kc = r.gen_range(1, 20);
                let mr = r.gen_range(1, 6);
                let lda = kc + r.gen_range(0, 8);
                (mc, kc, mr, lda, r.next_u64())
            },
            |&(mc, kc, mr, lda, seed)| {
                let mut rng = Rng::new(seed);
                let a = rng.fill_matrix(mc * lda.max(kc));
                let mut buf = Vec::new();
                pack_a(&a, lda.max(kc), 0, 0, mc, kc, mr, &mut buf);
                for i in 0..mc {
                    for l in 0..kc {
                        let p = i / mr;
                        let got = buf[p * mr * kc + l * mr + (i % mr)];
                        let want = a[i * lda.max(kc) + l];
                        if got != want {
                            return Err(format!("({i},{l}): {got} != {want}"));
                        }
                    }
                }
                Ok(())
            },
        );
    }

    #[test]
    fn panel_range_packing_matches_whole() {
        let mut rng = Rng::new(77);
        let (mc, kc, mr) = (11, 6, 4);
        let lda = 9;
        let a = rng.fill_matrix(mc * lda);
        let mut whole = Vec::new();
        pack_a(&a, lda, 0, 0, mc, kc, mr, &mut whole);
        let panels = mc.div_ceil(mr);
        let mut by_parts = vec![f64::NAN; panels * mr * kc];
        pack_a_panels(&a, lda, 0, 0, mc, kc, mr, &mut by_parts, 0, 2);
        pack_a_panels(&a, lda, 0, 0, mc, kc, mr, &mut by_parts, 2, panels);
        assert_eq!(whole, by_parts);

        let (kcb, nc, nr) = (5, 14, 4);
        let ldb = 17;
        let b = rng.fill_matrix(kcb * ldb);
        let mut whole_b = Vec::new();
        pack_b(&b, ldb, 0, 0, kcb, nc, nr, &mut whole_b);
        let qn = nc.div_ceil(nr);
        let mut parts_b = vec![f64::NAN; qn * kcb * nr];
        pack_b_panels(&b, ldb, 0, 0, kcb, nc, nr, &mut parts_b, 0, 1);
        pack_b_panels(&b, ldb, 0, 0, kcb, nc, nr, &mut parts_b, 1, qn);
        assert_eq!(whole_b, parts_b);
    }

    /// Property: B packing round-trip.
    #[test]
    fn prop_pack_b_roundtrip() {
        crate::util::prop::check_default(
            |r| {
                let kc = r.gen_range(1, 20);
                let nc = r.gen_range(1, 24);
                let nr = r.gen_range(1, 6);
                (kc, nc, nr, r.next_u64())
            },
            |&(kc, nc, nr, seed)| {
                let mut rng = Rng::new(seed);
                let ldb = nc + 2;
                let b = rng.fill_matrix(kc * ldb);
                let mut buf = Vec::new();
                pack_b(&b, ldb, 0, 0, kc, nc, nr, &mut buf);
                for l in 0..kc {
                    for j in 0..nc {
                        let q = j / nr;
                        let got = buf[q * kc * nr + l * nr + (j % nr)];
                        let want = b[l * ldb + j];
                        if got != want {
                            return Err(format!("({l},{j}): {got} != {want}"));
                        }
                    }
                }
                Ok(())
            },
        );
    }
}
