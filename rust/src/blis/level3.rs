//! Level-3 BLAS built on the scheduled GEMM.
//!
//! The paper's motivation (§1) is that "portable and highly tuned
//! versions of the remaining Level-3 kernels are in general built on
//! top of GEMM" [Kågström et al.], and its stated goal (§6) is "a full
//! BLAS implementation optimized for big.LITTLE architectures". This
//! module delivers that layer: SYMM, SYRK, TRMM and TRSM expressed as
//! partitioned calls into the asymmetric-scheduled GEMM executor, so
//! every Level-3 routine inherits the CA-DAS machinery for free.
//! `trsm_lower` is also the panel-solve kernel of the blocked Cholesky
//! in [`crate::dag::exec`].
//!
//! Matrices are row-major f64, as everywhere in this crate. Only the
//! variants the GEMM-based decomposition needs are implemented
//! (left-side, lower-triangular storage); the pattern extends
//! mechanically.

use crate::blis::gemm::GemmShape;
use crate::native::gemm_parallel;
use crate::sched::ScheduleSpec;
use crate::soc::SocSpec;
use std::cell::RefCell;

thread_local! {
    /// Reused densification scratch for [`symm_lower`]. The mirror loop
    /// overwrites every entry of the `m × m` prefix before the GEMM
    /// reads it, so growth/shrink via `resize` needs no zeroing and the
    /// operand bits are identical to a freshly allocated buffer.
    static SYMM_SCRATCH: RefCell<Vec<f64>> = const { RefCell::new(Vec::new()) };
}

/// C += A·B where A is symmetric (m×m), only its lower triangle stored.
/// Densifies the triangle into a thread-local scratch operand (reused
/// across calls rather than reallocated every time) and dispatches one
/// scheduled GEMM — the standard GEMM-based SYMM decomposition.
pub fn symm_lower(
    soc: &SocSpec,
    spec: &ScheduleSpec,
    m: usize,
    n: usize,
    a_lower: &[f64],
    b: &[f64],
    c: &mut [f64],
) {
    assert!(a_lower.len() >= m * m && b.len() >= m * n && c.len() >= m * n);
    SYMM_SCRATCH.with(|scratch| {
        let mut a = scratch.borrow_mut();
        a.resize(m * m, 0.0);
        // Symmetrize: A[i][j] = A[j][i] = stored lower entry.
        for i in 0..m {
            for j in 0..=i {
                let v = a_lower[i * m + j];
                a[i * m + j] = v;
                a[j * m + i] = v;
            }
        }
        gemm_parallel(soc, spec, GemmShape { m, n, k: m }, &a, b, c);
    });
}

/// Solve L·X = B in place (TRSM, left, lower-triangular, non-unit
/// diagonal; L is m×m, B is m×n and holds X on return). Only the lower
/// triangle of `l` is ever read — callers may leave garbage above the
/// diagonal, as the blocked factorizations in [`crate::dag::exec`] do.
///
/// Block decomposition with block size `nb`, top-down: the trailing
/// panel update `B[i0.., :] -= L[i0.., ..i0] · X[..i0, :]` carries all
/// the flops and flows through the scheduled GEMM (as a negated-panel
/// accumulate); only the small diagonal-block forward substitution is
/// sequential.
pub fn trsm_lower(
    soc: &SocSpec,
    spec: &ScheduleSpec,
    m: usize,
    n: usize,
    l: &[f64],
    b: &mut [f64],
    nb: usize,
) {
    assert!(l.len() >= m * m && b.len() >= m * n);
    assert!(nb > 0);
    let nblocks = m.div_ceil(nb);
    for bi in 0..nblocks {
        let i0 = bi * nb;
        let ib = (m - i0).min(nb);
        if i0 > 0 {
            let mut neg_l21 = vec![0.0; ib * i0];
            for r in 0..ib {
                for q in 0..i0 {
                    neg_l21[r * i0 + q] = -l[(i0 + r) * m + q];
                }
            }
            let x_top = b[..i0 * n].to_vec();
            let tail = &mut b[i0 * n..(i0 + ib) * n];
            gemm_parallel(soc, spec, GemmShape { m: ib, n, k: i0 }, &neg_l21, &x_top, tail);
        }
        // Forward substitution within the diagonal block.
        for r in 0..ib {
            let li = i0 + r;
            for q in 0..r {
                let f = l[li * m + i0 + q];
                if f != 0.0 {
                    for c in 0..n {
                        b[li * n + c] -= f * b[(i0 + q) * n + c];
                    }
                }
            }
            let d = l[li * m + li];
            for c in 0..n {
                b[li * n + c] /= d;
            }
        }
    }
}

/// C += A·Aᵀ (SYRK, lower triangle of C updated; C is m×m, A is m×k).
/// Computed as a scheduled GEMM against the explicit transpose, then
/// the strictly-upper half of the update is discarded — trading the
/// classic 2× flop saving for full reuse of the asymmetric scheduler
/// (the trade BLIS itself makes in its reference SYRK).
pub fn syrk_lower(
    soc: &SocSpec,
    spec: &ScheduleSpec,
    m: usize,
    k: usize,
    a: &[f64],
    c_lower: &mut [f64],
) {
    assert!(a.len() >= m * k && c_lower.len() >= m * m);
    let mut at = vec![0.0; k * m];
    for i in 0..m {
        for l in 0..k {
            at[l * m + i] = a[i * k + l];
        }
    }
    let mut full = vec![0.0; m * m];
    gemm_parallel(soc, spec, GemmShape { m, n: m, k }, a, &at, &mut full);
    for i in 0..m {
        for j in 0..=i {
            c_lower[i * m + j] += full[i * m + j];
        }
    }
}

/// B := L·B (TRMM, left, lower-triangular, non-unit diagonal; L is
/// m×m, B is m×n). Block decomposition with block size `nb`: diagonal
/// blocks are applied by a small in-place triangular kernel, while the
/// large off-diagonal panels go through the scheduled GEMM — where all
/// the flops are.
pub fn trmm_lower_left(
    soc: &SocSpec,
    spec: &ScheduleSpec,
    m: usize,
    n: usize,
    l: &[f64],
    b: &mut [f64],
    nb: usize,
) {
    assert!(l.len() >= m * m && b.len() >= m * n);
    assert!(nb > 0);
    // Walk block rows bottom-up so each row of B is consumed before it
    // is overwritten.
    let nblocks = m.div_ceil(nb);
    for bi in (0..nblocks).rev() {
        let i0 = bi * nb;
        let ib = (m - i0).min(nb);
        // 1. Off-diagonal contribution: B[i0.., :] += L[i0.., 0..i0] · B[0..i0, :].
        if i0 > 0 {
            // Gather the panel L21 (ib × i0) and the top rows of B.
            let mut l21 = vec![0.0; ib * i0];
            for r in 0..ib {
                l21[r * i0..(r + 1) * i0]
                    .copy_from_slice(&l[(i0 + r) * m..(i0 + r) * m + i0]);
            }
            let b_top = b[..i0 * n].to_vec();
            let mut update = vec![0.0; ib * n];
            gemm_parallel(
                soc,
                spec,
                GemmShape { m: ib, n, k: i0 },
                &l21,
                &b_top,
                &mut update,
            );
            // 2. Diagonal block applied in place (small, triangular).
            trmm_diag_block(l, b, m, n, i0, ib);
            for r in 0..ib {
                for c in 0..n {
                    b[(i0 + r) * n + c] += update[r * n + c];
                }
            }
        } else {
            trmm_diag_block(l, b, m, n, i0, ib);
        }
    }
}

/// In-place B[i0..i0+ib, :] := L[i0..i0+ib, i0..i0+ib] · B[i0..i0+ib, :]
/// for the lower-triangular diagonal block (non-unit diagonal).
fn trmm_diag_block(l: &[f64], b: &mut [f64], m: usize, n: usize, i0: usize, ib: usize) {
    // Bottom-up within the block: row r depends on rows ≤ r.
    for r in (0..ib).rev() {
        let li = i0 + r;
        for c in 0..n {
            let mut acc = l[li * m + li] * b[li * n + c];
            for q in 0..r {
                acc += l[li * m + i0 + q] * b[(i0 + q) * n + c];
            }
            b[li * n + c] = acc;
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::blis::gemm::gemm_naive;
    use crate::util::rng::Rng;
    use crate::util::stats::{gemm_tolerance, max_abs_diff};

    fn soc() -> SocSpec {
        SocSpec::exynos5422()
    }
    fn spec() -> ScheduleSpec {
        ScheduleSpec::ca_das()
    }

    #[test]
    fn symm_matches_dense_gemm() {
        let (m, n) = (37, 29);
        let mut rng = Rng::new(301);
        let mut a_lower = vec![0.0; m * m];
        for i in 0..m {
            for j in 0..=i {
                a_lower[i * m + j] = rng.gen_f64(-1.0, 1.0);
            }
        }
        let b = rng.fill_matrix(m * n);
        let c0 = rng.fill_matrix(m * n);

        let mut c = c0.clone();
        symm_lower(&soc(), &spec(), m, n, &a_lower, &b, &mut c);

        // Dense reference.
        let mut a_full = vec![0.0; m * m];
        for i in 0..m {
            for j in 0..=i {
                a_full[i * m + j] = a_lower[i * m + j];
                a_full[j * m + i] = a_lower[i * m + j];
            }
        }
        let mut want = c0.clone();
        gemm_naive(GemmShape { m, n, k: m }, &a_full, &b, &mut want);
        assert!(max_abs_diff(&c, &want) < gemm_tolerance(m));
    }

    #[test]
    fn syrk_matches_reference() {
        let (m, k) = (25, 41);
        let mut rng = Rng::new(302);
        let a = rng.fill_matrix(m * k);
        let c0 = rng.fill_matrix(m * m);

        let mut c = c0.clone();
        syrk_lower(&soc(), &spec(), m, k, &a, &mut c);

        for i in 0..m {
            for j in 0..m {
                if j <= i {
                    let mut want = c0[i * m + j];
                    for l in 0..k {
                        want += a[i * k + l] * a[j * k + l];
                    }
                    assert!(
                        (c[i * m + j] - want).abs() < gemm_tolerance(k),
                        "({i},{j})"
                    );
                } else {
                    assert_eq!(c[i * m + j], c0[i * m + j], "upper half untouched");
                }
            }
        }
    }

    #[test]
    fn trmm_matches_dense_reference() {
        let (m, n) = (43, 19);
        let mut rng = Rng::new(303);
        let mut l = vec![0.0; m * m];
        for i in 0..m {
            for j in 0..=i {
                l[i * m + j] = rng.gen_f64(-1.0, 1.0);
            }
            l[i * m + i] += 2.0; // keep it well-conditioned
        }
        let b0 = rng.fill_matrix(m * n);

        for nb in [8usize, 16, 64] {
            let mut b = b0.clone();
            trmm_lower_left(&soc(), &spec(), m, n, &l, &mut b, nb);
            let mut want = vec![0.0; m * n];
            gemm_naive(GemmShape { m, n, k: m }, &l, &b0, &mut want);
            let d = max_abs_diff(&b, &want);
            assert!(d < gemm_tolerance(m), "nb={nb}: diff {d}");
        }
    }

    #[test]
    fn symm_scratch_reuse_is_bit_identical() {
        // Regression for the per-call densify allocation: interleave
        // sizes so the thread-local scratch grows and shrinks, and pin
        // every result bit-for-bit against a fresh-operand reference.
        let mut rng = Rng::new(305);
        for &(m, n) in &[(33usize, 17usize), (9, 28), (48, 5), (33, 17)] {
            let mut a_lower = vec![0.0; m * m];
            for i in 0..m {
                for j in 0..=i {
                    a_lower[i * m + j] = rng.gen_f64(-1.0, 1.0);
                }
            }
            let b = rng.fill_matrix(m * n);
            let c0 = rng.fill_matrix(m * n);

            let mut c = c0.clone();
            symm_lower(&soc(), &spec(), m, n, &a_lower, &b, &mut c);

            let mut a_full = vec![0.0; m * m];
            for i in 0..m {
                for j in 0..=i {
                    let v = a_lower[i * m + j];
                    a_full[i * m + j] = v;
                    a_full[j * m + i] = v;
                }
            }
            let mut want = c0.clone();
            gemm_parallel(&soc(), &spec(), GemmShape { m, n, k: m }, &a_full, &b, &mut want);
            assert_eq!(c, want, "m={m} n={n}: scratch reuse changed bits");
        }
    }

    #[test]
    fn trsm_solves_the_lower_system() {
        let (m, n) = (45, 21);
        let mut rng = Rng::new(306);
        let mut l = vec![0.0; m * m];
        for i in 0..m {
            for j in 0..=i {
                l[i * m + j] = rng.gen_f64(-1.0, 1.0);
            }
            l[i * m + i] += 2.0; // keep the solve well-conditioned
        }
        // The strictly-upper half must never be read.
        for i in 0..m {
            for j in i + 1..m {
                l[i * m + j] = f64::NAN;
            }
        }
        let b0 = rng.fill_matrix(m * n);
        for nb in [4usize, 16, 64] {
            let mut x = b0.clone();
            trsm_lower(&soc(), &spec(), m, n, &l, &mut x, nb);
            // Residual check: L·X must reproduce B.
            let mut lx = vec![0.0; m * n];
            for i in 0..m {
                for c in 0..n {
                    let mut s = 0.0;
                    for p in 0..=i {
                        s += l[i * m + p] * x[p * n + c];
                    }
                    lx[i * n + c] = s;
                }
            }
            let d = max_abs_diff(&lx, &b0);
            assert!(d < gemm_tolerance(m) * 10.0, "nb={nb}: residual {d}");
        }
    }

    #[test]
    fn trsm_inverts_trmm() {
        let (m, n) = (31, 12);
        let mut rng = Rng::new(307);
        let mut l = vec![0.0; m * m];
        for i in 0..m {
            for j in 0..=i {
                l[i * m + j] = rng.gen_f64(-1.0, 1.0);
            }
            l[i * m + i] += 2.0;
        }
        let x0 = rng.fill_matrix(m * n);
        let mut b = x0.clone();
        trmm_lower_left(&soc(), &spec(), m, n, &l, &mut b, 8); // B = L·X
        trsm_lower(&soc(), &spec(), m, n, &l, &mut b, 8); // solve back
        assert!(max_abs_diff(&b, &x0) < gemm_tolerance(m) * 10.0);
    }

    #[test]
    fn trmm_block_size_larger_than_matrix() {
        let (m, n) = (9, 5);
        let mut rng = Rng::new(304);
        let mut l = vec![0.0; m * m];
        for i in 0..m {
            for j in 0..=i {
                l[i * m + j] = rng.gen_f64(-1.0, 1.0);
            }
        }
        let b0 = rng.fill_matrix(m * n);
        let mut b = b0.clone();
        trmm_lower_left(&soc(), &spec(), m, n, &l, &mut b, 128);
        let mut want = vec![0.0; m * n];
        gemm_naive(GemmShape { m, n, k: m }, &l, &b0, &mut want);
        assert!(max_abs_diff(&b, &want) < gemm_tolerance(m));
    }

    /// Property: all three routines agree with dense references across
    /// random shapes and schedules.
    #[test]
    fn prop_level3_correct() {
        crate::util::prop::check(
            &crate::util::prop::Config { cases: 12, seed: 0x13B3 },
            |r| {
                let m = r.gen_range(1, 40);
                let n = r.gen_range(1, 40);
                let k = r.gen_range(1, 40);
                let sched = r.gen_range(0, 2);
                (m, n, k, sched, r.next_u64())
            },
            |&(m, n, k, sched, seed)| {
                let spec = if sched == 0 {
                    ScheduleSpec::ca_das()
                } else {
                    ScheduleSpec::sas(5.0)
                };
                let mut rng = Rng::new(seed);
                // SYRK check (uses m, k).
                let a = rng.fill_matrix(m * k);
                let mut c = vec![0.0; m * m];
                syrk_lower(&soc(), &spec, m, k, &a, &mut c);
                for i in 0..m {
                    for j in 0..=i {
                        let mut want = 0.0;
                        for l in 0..k {
                            want += a[i * k + l] * a[j * k + l];
                        }
                        if (c[i * m + j] - want).abs() > gemm_tolerance(k) {
                            return Err(format!("syrk ({i},{j})"));
                        }
                    }
                }
                // TRMM check (uses m, n).
                let mut l = vec![0.0; m * m];
                for i in 0..m {
                    for j in 0..=i {
                        l[i * m + j] = rng.gen_f64(-1.0, 1.0);
                    }
                }
                let b0 = rng.fill_matrix(m * n);
                let mut b = b0.clone();
                trmm_lower_left(&soc(), &spec, m, n, &l, &mut b, 16);
                let mut want = vec![0.0; m * n];
                gemm_naive(GemmShape { m, n, k: m }, &l, &b0, &mut want);
                if max_abs_diff(&b, &want) > gemm_tolerance(m) {
                    return Err("trmm".to_string());
                }
                Ok(())
            },
        );
    }
}
