//! The BLIS GEMM machinery: blocking parameters, control trees, packing
//! routines, the native micro-kernel and the sequential five-loop
//! algorithm of Fig. 1. The parallel executors (`crate::native`) and the
//! simulator (`crate::sim`) are built on these pieces.

pub mod control_tree;
pub mod gemm;
pub mod level3;
pub mod microkernel;
pub mod packing;
pub mod params;

pub use control_tree::{ControlTree, LoopId, Parallelism, TreeSet};
pub use gemm::{gemm_blocked, gemm_naive, GemmShape, Workspace};
pub use params::BlisParams;
