//! BLIS blocking/configuration parameters (`nc, kc, mc, nr, mr`).
//!
//! These are the "cache configuration parameters" of paper §3: the loop
//! strides of the five-loop GEMM (Fig. 1) that place `Br (kc×nr)` in L1
//! and `Ac (mc×kc)` in L2. The presets are the paper's empirically
//! determined optima (§3.3, Fig. 4) and the shared-`kc` refit of §5.3.
//!
//! This module is topology-agnostic: *which* parameters a cluster runs
//! is data carried by `soc::ClusterSpec` (its `tuned` field), and the
//! shared-`Bc` refit is a pure function of the pinned `kc` and the
//! cluster's L2 size ([`BlisParams::shared_kc_refit`]).

/// One control-tree's worth of blocking parameters.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub struct BlisParams {
    /// Loop 1 stride (columns of C per macro-pass). No L3 on the Exynos
    /// 5422, so `nc` "plays a minor role" (§3.3) and is fixed at 4096.
    pub nc: usize,
    /// Loop 2 stride (depth of the packed panels).
    pub kc: usize,
    /// Loop 3 stride (rows of the `Ac` macro-panel).
    pub mc: usize,
    /// Loop 4 stride = micro-kernel width.
    pub nr: usize,
    /// Loop 5 stride = micro-kernel height.
    pub mr: usize,
}

impl BlisParams {
    pub fn new(nc: usize, kc: usize, mc: usize, nr: usize, mr: usize) -> Self {
        let p = BlisParams { nc, kc, mc, nr, mr };
        p.validate();
        p
    }

    /// Paper §3.3: optimum for a Cortex-A15 core: (mc, kc) = (152, 952).
    pub fn a15_opt() -> Self {
        BlisParams::new(4096, 952, 152, 4, 4)
    }

    /// Paper §3.3: optimum for a Cortex-A7 core: (mc, kc) = (80, 352).
    pub fn a7_opt() -> Self {
        BlisParams::new(4096, 352, 80, 4, 4)
    }

    /// §6 future work: a per-core-type micro-kernel for the big cores
    /// with an 8×4 register block (halves `Br` traffic per flop on the
    /// out-of-order A15). `mc = 152` is already a multiple of 8.
    pub fn a15_opt_8x4() -> Self {
        BlisParams::new(4096, 952, 152, 4, 8)
    }

    /// Paper §5.3: when Loop 3 is the inter-cluster loop the `Bc` buffer
    /// is shared, forcing a common `kc = 952`; the A7's `mc` then refits
    /// to 32 (suboptimal for the A7, but `Ac` fits its 512 KiB L2 again).
    pub fn a7_shared_kc() -> Self {
        BlisParams::new(4096, 952, 32, 4, 4)
    }

    /// Refit for a *shared-`Bc`* configuration (§5.3): `kc` is pinned to
    /// the common value (the lead cluster's), and `mc` shrinks so the
    /// `Ac = mc×kc` macro-panel occupies at most half the given L2 —
    /// leaving the other half for the `Bc` stream and C traffic. If the
    /// pinned `kc` already equals this configuration's own `kc`, no
    /// refit is needed. For the Exynos LITTLE cluster (512 KiB L2,
    /// kc = 952) this lands exactly on the paper's mc = 32.
    pub fn shared_kc_refit(&self, kc: usize, l2_bytes: usize) -> BlisParams {
        if kc == self.kc {
            return *self;
        }
        let budget = l2_bytes / 2;
        let mc = ((budget / (kc * 8)) / self.mr * self.mr).max(self.mr);
        BlisParams::new(self.nc, kc, mc, self.nr, self.mr)
    }

    pub fn validate(&self) {
        assert!(self.mr > 0 && self.nr > 0, "register block must be non-empty");
        assert!(self.mc >= self.mr, "mc ({}) < mr ({})", self.mc, self.mr);
        assert!(self.nc >= self.nr, "nc ({}) < nr ({})", self.nc, self.nr);
        assert!(self.kc > 0);
        assert_eq!(self.mc % self.mr, 0, "mc must be a multiple of mr");
        assert_eq!(self.nc % self.nr, 0, "nc must be a multiple of nr");
    }

    /// Micro-panel `Br` footprint in bytes (f64 elements).
    pub fn br_bytes(&self) -> usize {
        self.kc * self.nr * 8
    }

    /// Macro-panel `Ac` footprint in bytes.
    pub fn ac_bytes(&self) -> usize {
        self.mc * self.kc * 8
    }

    /// Loop-4 parallelism available: ⌈nc/nr⌉ micro-kernel columns (§3.1).
    pub fn loop4_parallelism(&self) -> usize {
        self.nc.div_ceil(self.nr)
    }

    /// Loop-5 parallelism available: ⌈mc/mr⌉ micro-kernel rows (§3.1).
    pub fn loop5_parallelism(&self) -> usize {
        self.mc.div_ceil(self.mr)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn presets_match_paper() {
        let a15 = BlisParams::a15_opt();
        assert_eq!((a15.mc, a15.kc, a15.nc, a15.mr, a15.nr), (152, 952, 4096, 4, 4));
        let a7 = BlisParams::a7_opt();
        assert_eq!((a7.mc, a7.kc), (80, 352));
        let shared = BlisParams::a7_shared_kc();
        assert_eq!((shared.mc, shared.kc), (32, 952));
    }

    #[test]
    fn loop4_exceeds_loop5_parallelism() {
        // §3.1: Loop 4 (⌈nc/nr⌉) offers far more concurrency than
        // Loop 5 (⌈mc/mr⌉) — the reason Fig. 11/12 favor Loop 4.
        for p in [BlisParams::a15_opt(), BlisParams::a7_opt()] {
            assert!(p.loop4_parallelism() > 10 * p.loop5_parallelism());
        }
    }

    #[test]
    fn footprints() {
        assert_eq!(BlisParams::a15_opt().br_bytes(), 30_464);
        assert_eq!(BlisParams::a15_opt().ac_bytes(), 1_157_632);
        assert_eq!(BlisParams::a7_opt().ac_bytes(), 225_280);
        assert_eq!(BlisParams::a7_shared_kc().ac_bytes(), 243_712);
    }

    #[test]
    fn shared_kc_refit_reproduces_paper_values() {
        // §5.3: A7 optimum refit at the shared kc = 952 on a 512 KiB L2
        // must reproduce the paper's mc = 32 exactly.
        let refit = BlisParams::a7_opt().shared_kc_refit(952, 512 * 1024);
        assert_eq!(refit, BlisParams::a7_shared_kc());
        // Same kc → identity (the lead cluster keeps its own optimum).
        let same = BlisParams::a15_opt().shared_kc_refit(952, 2 * 1024 * 1024);
        assert_eq!(same, BlisParams::a15_opt());
    }

    #[test]
    fn shared_kc_refit_scales_with_l2() {
        // A 1 MiB L2 admits roughly twice the refit mc of a 512 KiB L2.
        let small = BlisParams::a7_opt().shared_kc_refit(952, 512 * 1024);
        let large = BlisParams::a7_opt().shared_kc_refit(952, 1024 * 1024);
        assert!(large.mc >= 2 * small.mc - 4);
        // Never below one register block, even for tiny caches.
        let tiny = BlisParams::a7_opt().shared_kc_refit(952, 16 * 1024);
        assert_eq!(tiny.mc, tiny.mr);
    }

    #[test]
    #[should_panic(expected = "multiple of mr")]
    fn mc_must_be_multiple_of_mr() {
        BlisParams::new(4096, 100, 33, 4, 4);
    }

    #[test]
    #[should_panic(expected = "mc")]
    fn mc_smaller_than_mr_rejected() {
        BlisParams::new(4096, 100, 2, 4, 4);
    }
}
