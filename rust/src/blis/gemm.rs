//! Single-threaded five-loop BLIS GEMM (Fig. 1) over row-major f64
//! matrices: `C(m×n) += A(m×k) · B(k×n)`.
//!
//! This is both the sequential reference used to verify the parallel
//! executors and the per-thread body they are built from: Loop 1 (jc/nc)
//! → Loop 2 (pc/kc, pack `Bc`) → Loop 3 (ic/mc, pack `Ac`) → macro-kernel
//! (Loop 4 jr/nr × Loop 5 ir/mr around the micro-kernel).

use crate::blis::microkernel::micro_kernel;
use crate::blis::packing::{a_panel, b_panel, pack_a, pack_b};
use crate::blis::params::BlisParams;

/// A GEMM problem over borrowed row-major buffers. `Hash`/`Ord` (by
/// `(m, n, k)`) let shapes key the dispatch-layer batch caches and
/// deterministic per-shape tallies directly.
#[derive(Debug, Clone, Copy, PartialEq, Eq, PartialOrd, Ord, Hash)]
pub struct GemmShape {
    pub m: usize,
    pub n: usize,
    pub k: usize,
}

impl GemmShape {
    pub fn square(r: usize) -> Self {
        GemmShape { m: r, n: r, k: r }
    }
    pub fn flops(&self) -> f64 {
        2.0 * self.m as f64 * self.n as f64 * self.k as f64
    }
}

/// Naive triple loop — the correctness oracle for everything else.
pub fn gemm_naive(shape: GemmShape, a: &[f64], b: &[f64], c: &mut [f64]) {
    let GemmShape { m, n, k } = shape;
    assert!(a.len() >= m * k && b.len() >= k * n && c.len() >= m * n);
    for i in 0..m {
        for l in 0..k {
            let ail = a[i * k + l];
            if ail == 0.0 {
                continue;
            }
            let b_row = &b[l * n..l * n + n];
            let c_row = &mut c[i * n..i * n + n];
            for j in 0..n {
                c_row[j] += ail * b_row[j];
            }
        }
    }
}

/// Reusable packing workspace — one per thread in the parallel
/// executors, so the hot loop never allocates.
#[derive(Debug, Default, Clone)]
pub struct Workspace {
    pub ac: Vec<f64>,
    pub bc: Vec<f64>,
}

/// The macro-kernel: Loops 4+5 over one packed (`Ac`, `Bc`) pair,
/// updating the `mc_eff × nc_eff` block of C at (row0, col0).
/// `jr_range`/`ir_range` select a sub-range of micro-kernel columns/rows
/// (in units of nr/mr panels) — the hook the fine-grain (intra-cluster)
/// parallelization uses to split Loop 4 and/or Loop 5 (§3.1).
#[allow(clippy::too_many_arguments)]
pub fn macro_kernel(
    p: &BlisParams,
    ac: &[f64],
    bc: &[f64],
    kc_eff: usize,
    mc_eff: usize,
    nc_eff: usize,
    c: &mut [f64],
    ldc: usize,
    row0: usize,
    col0: usize,
    jr_range: std::ops::Range<usize>,
    ir_range: std::ops::Range<usize>,
) {
    let n_jr = nc_eff.div_ceil(p.nr);
    let n_ir = mc_eff.div_ceil(p.mr);
    debug_assert!(jr_range.end <= n_jr && ir_range.end <= n_ir);

    for jr in jr_range {
        let n_eff = (nc_eff - jr * p.nr).min(p.nr);
        let br = b_panel(bc, jr, p.nr, kc_eff);
        for ir in ir_range.clone() {
            let m_eff = (mc_eff - ir * p.mr).min(p.mr);
            let ap = a_panel(ac, ir, p.mr, kc_eff);
            let c_off = (row0 + ir * p.mr) * ldc + col0 + jr * p.nr;
            micro_kernel(
                p.mr,
                p.nr,
                kc_eff,
                ap,
                br,
                &mut c[c_off..],
                ldc,
                m_eff,
                n_eff,
            );
        }
    }
}

/// Full sequential blocked GEMM with blocking parameters `p`.
pub fn gemm_blocked(
    p: &BlisParams,
    shape: GemmShape,
    a: &[f64],
    b: &[f64],
    c: &mut [f64],
    ws: &mut Workspace,
) {
    let GemmShape { m, n, k } = shape;
    assert!(a.len() >= m * k && b.len() >= k * n && c.len() >= m * n);

    // Loop 1: jc over n in steps of nc.
    let mut jc = 0;
    while jc < n {
        let nc_eff = (n - jc).min(p.nc);
        // Loop 2: pc over k in steps of kc; pack Bc.
        let mut pc = 0;
        while pc < k {
            let kc_eff = (k - pc).min(p.kc);
            pack_b(b, n, pc, jc, kc_eff, nc_eff, p.nr, &mut ws.bc);
            // Loop 3: ic over m in steps of mc; pack Ac.
            let mut ic = 0;
            while ic < m {
                let mc_eff = (m - ic).min(p.mc);
                pack_a(a, k, ic, pc, mc_eff, kc_eff, p.mr, &mut ws.ac);
                macro_kernel(
                    p,
                    &ws.ac,
                    &ws.bc,
                    kc_eff,
                    mc_eff,
                    nc_eff,
                    c,
                    n,
                    ic,
                    jc,
                    0..nc_eff.div_ceil(p.nr),
                    0..mc_eff.div_ceil(p.mr),
                );
                ic += p.mc;
            }
            pc += p.kc;
        }
        jc += p.nc;
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;
    use crate::util::stats::{gemm_tolerance, max_abs_diff};

    fn check_blocked(p: &BlisParams, m: usize, n: usize, k: usize, seed: u64) {
        let mut rng = Rng::new(seed);
        let a = rng.fill_matrix(m * k);
        let b = rng.fill_matrix(k * n);
        let c0 = rng.fill_matrix(m * n);
        let mut c_ref = c0.clone();
        let mut c_blk = c0.clone();
        gemm_naive(GemmShape { m, n, k }, &a, &b, &mut c_ref);
        let mut ws = Workspace::default();
        gemm_blocked(p, GemmShape { m, n, k }, &a, &b, &mut c_blk, &mut ws);
        let d = max_abs_diff(&c_ref, &c_blk);
        assert!(d < gemm_tolerance(k), "m={m} n={n} k={k}: diff {d}");
    }

    #[test]
    fn blocked_matches_naive_small_params() {
        // Tiny blocking forces every loop to take multiple iterations.
        let p = BlisParams::new(8, 5, 4, 4, 4);
        check_blocked(&p, 17, 13, 11, 1);
        check_blocked(&p, 4, 4, 4, 2);
        check_blocked(&p, 1, 1, 1, 3);
        check_blocked(&p, 9, 23, 5, 4);
    }

    #[test]
    fn blocked_matches_naive_paper_params() {
        // Paper parameters on a small problem: single iteration of outer
        // loops plus edge handling everywhere.
        check_blocked(&BlisParams::a15_opt(), 100, 100, 100, 5);
        check_blocked(&BlisParams::a7_opt(), 97, 61, 43, 6);
        check_blocked(&BlisParams::a7_shared_kc(), 64, 64, 64, 7);
    }

    #[test]
    fn blocked_matches_naive_multi_block() {
        // Exceeds mc/kc for the A7 params: all five loops iterate.
        check_blocked(&BlisParams::a7_opt(), 200, 96, 800, 8);
    }

    #[test]
    fn blocked_with_8x4_register_block_matches() {
        // §6 future work: the per-core 8×4 micro-kernel, end to end.
        check_blocked(&BlisParams::a15_opt_8x4(), 100, 64, 80, 12);
        check_blocked(&BlisParams::a15_opt_8x4(), 31, 17, 23, 13);
    }

    #[test]
    fn non_square_extremes() {
        let p = BlisParams::new(16, 8, 8, 4, 4);
        check_blocked(&p, 1, 64, 3, 9); // row vector-ish
        check_blocked(&p, 64, 1, 3, 10); // column vector-ish
        check_blocked(&p, 3, 3, 200, 11); // deep k
    }

    #[test]
    fn macro_kernel_subranges_compose() {
        // Splitting jr/ir ranges must give the same C as the full sweep —
        // the invariant the intra-cluster Loop-4/5 parallelization rests on.
        let mut rng = Rng::new(42);
        let p = BlisParams::new(16, 6, 8, 4, 4);
        let (mc_eff, nc_eff, kc_eff) = (7, 14, 6);
        let mut ws_a = Vec::new();
        let mut ws_b = Vec::new();
        let a_src = rng.fill_matrix(mc_eff * kc_eff);
        let b_src = rng.fill_matrix(kc_eff * nc_eff);
        pack_a(&a_src, kc_eff, 0, 0, mc_eff, kc_eff, p.mr, &mut ws_a);
        pack_b(&b_src, nc_eff, 0, 0, kc_eff, nc_eff, p.nr, &mut ws_b);

        let ldc = nc_eff;
        let n_jr = nc_eff.div_ceil(p.nr);
        let n_ir = mc_eff.div_ceil(p.mr);

        let mut c_full = vec![0.0; mc_eff * ldc];
        macro_kernel(&p, &ws_a, &ws_b, kc_eff, mc_eff, nc_eff, &mut c_full, ldc, 0, 0, 0..n_jr, 0..n_ir);

        let mut c_split = vec![0.0; mc_eff * ldc];
        let mid_jr = n_jr / 2;
        let mid_ir = n_ir / 2;
        for jr in [0..mid_jr, mid_jr..n_jr] {
            for ir in [0..mid_ir, mid_ir..n_ir] {
                macro_kernel(
                    &p, &ws_a, &ws_b, kc_eff, mc_eff, nc_eff, &mut c_split, ldc, 0, 0,
                    jr.clone(), ir,
                );
            }
        }
        assert!(max_abs_diff(&c_full, &c_split) < 1e-12);
    }

    #[test]
    fn gemm_shape_helpers() {
        let s = GemmShape::square(128);
        assert_eq!((s.m, s.n, s.k), (128, 128, 128));
        assert_eq!(s.flops(), 2.0 * 128f64.powi(3));
    }

    /// Property: random shapes and random (legal) blockings agree with
    /// the oracle.
    #[test]
    fn prop_blocked_equals_naive() {
        crate::util::prop::check(
            &crate::util::prop::Config { cases: 48, seed: 0xB10C },
            |r| {
                let m = r.gen_range(1, 40);
                let n = r.gen_range(1, 40);
                let k = r.gen_range(1, 40);
                let mr = r.gen_range(1, 5);
                let nr = r.gen_range(1, 5);
                let mc = mr * r.gen_range(1, 5);
                let nc = nr * r.gen_range(1, 5);
                let kc = r.gen_range(1, 12);
                (m, n, k, BlisParams::new(nc, kc, mc, nr, mr), r.next_u64())
            },
            |&(m, n, k, p, seed)| {
                let mut rng = Rng::new(seed);
                let a = rng.fill_matrix(m * k);
                let b = rng.fill_matrix(k * n);
                let mut c_ref = vec![0.0; m * n];
                let mut c_blk = vec![0.0; m * n];
                gemm_naive(GemmShape { m, n, k }, &a, &b, &mut c_ref);
                gemm_blocked(&p, GemmShape { m, n, k }, &a, &b, &mut c_blk, &mut Workspace::default());
                let d = max_abs_diff(&c_ref, &c_blk);
                if d > gemm_tolerance(k) {
                    return Err(format!("diff {d}"));
                }
                Ok(())
            },
        );
    }
}
